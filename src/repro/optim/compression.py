"""Int8 error-feedback gradient compression for the data-parallel all-reduce.

Distributed-optimization trick (DESIGN.md §4): before the DP all-reduce,
gradients are quantized to int8 with a per-tensor scale; the quantization
residual is carried in an error-feedback buffer and added back next step
(EF-SGD / 1-bit Adam lineage), preserving convergence while cutting DP
all-reduce bytes 4x vs f32 (2x vs bf16).

Used inside shard_map: `compress -> psum(int8 as f32 counts) -> decompress`.
On CPU tests we verify the algebra (quantize/dequantize/error-feedback
contraction) without a mesh.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any  # pytree like grads (f32)


def init_ef(params: Any) -> EFState:
    return EFState(residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress(grads: Any, ef: EFState) -> Tuple[Any, Any, EFState]:
    """Returns (q_grads int8, scales, new_ef). The residual is what int8
    could not represent; it re-enters next step (error feedback)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quantize(x)
        deq = _dequantize(q, s)
        return q, s, x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qs = treedef.unflatten([o[0] for o in outs])
    scales = treedef.unflatten([o[1] for o in outs])
    new_ef = EFState(residual=treedef.unflatten([o[2] for o in outs]))
    return qs, scales, new_ef


def decompress(qs: Any, scales: Any) -> Any:
    return jax.tree.map(_dequantize, qs, scales)


def compressed_psum(grads: Any, ef: EFState, axis_name: str) -> Tuple[Any, EFState]:
    """Error-feedback int8 all-reduce over `axis_name` (call inside shard_map).

    int8 payloads are summed in f32 (hardware all-reduce does not saturate);
    scales are all-gathered implicitly by reducing (q * s) products per shard.
    """
    qs, scales, new_ef = compress(grads, ef)
    deq = decompress(qs, scales)  # local dequantized contribution
    summed = jax.tree.map(lambda d: jax.lax.psum(d, axis_name), deq)
    n = jax.lax.psum(jnp.ones(()), axis_name)
    mean = jax.tree.map(lambda s: s / n, summed)
    return mean, new_ef
