"""AdamW with dtype policies, global-norm clipping, and cosine schedule.

Self-contained (no optax dependency): state is a pytree mirroring params.
``moment_dtype`` controls m/v storage (bf16 for >=100B models so one pod's
HBM holds the full train state; DESIGN.md §4 memory budget).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray   # () int32
    m: Any              # pytree like params
    v: Any              # pytree like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # or "bfloat16"


def _mdtype(cfg: AdamWConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]


def init_adamw(cfg: AdamWConfig, params: Any) -> AdamWState:
    md = _mdtype(cfg)
    zeros = lambda p: jnp.zeros(p.shape, md)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to lr_min."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    cfg: AdamWConfig, params: Any, grads: Any, state: AdamWState
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    md = _mdtype(cfg)
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(md), v32.astype(md)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step, new_m, new_v), metrics
