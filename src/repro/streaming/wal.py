"""Write-ahead event log for the persistent-query service.

Every ingested micro-batch (inserts, deletions, churn ops) is appended —
with its stream clock — BEFORE it is dispatched to the engine, fsync'd in
segment files. Combined with the service's periodic checkpoints this turns
crash recovery from "lose the window" into ``O(events since snapshot)``:
restore the latest committed checkpoint, then replay the WAL suffix
(records with ``lsn`` greater than the checkpoint's recorded ``wal_lsn``)
through the normal ingest path. Replay is exact — the service's result
stream is a deterministic function of the event sequence, so a restored
run reproduces the uninterrupted run's per-event results bit-identically
(tests/test_supervisor.py pins this across injected fault points).

Format (crash-oriented, stdlib-only):

* one directory per log; segment files ``seg_<first_lsn:012d>.wal``;
* one record per line: ``<crc32-hex8> <json payload>\\n`` where the CRC
  covers the exact payload bytes — a torn tail write (the crash landed
  mid-``write``/pre-``fsync``) fails the CRC and replay stops THERE, never
  surfacing a half-record as events;
* payloads carry a monotonically increasing ``lsn`` (one per appended
  batch), the batch's stream clock, and the events as type-tagged tuples
  (the checkpoint interner's vertex encoding, so ``"42"`` vs ``42`` vs
  tuple vertex ids all survive the round trip);
* ``append`` writes, flushes, and (by default) fsyncs before returning —
  the record is durable before the engine ever sees the batch;
* segments rotate at ``segment_records`` appends; ``truncate_upto(lsn)``
  unlinks segments whose records are ALL covered by a committed
  checkpoint, keeping recovery cost proportional to the suffix.

Churn records (``kind="register"``/``"deregister"``) ride the same
sequence so replay can reproduce mid-stream query lifecycle too.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Iterator, List, Optional, Sequence, Tuple

from ..core.engine import _decode_vertex, _encode_vertex
from .stream import SGT

_SEG_PREFIX = "seg_"
_SEG_SUFFIX = ".wal"


@dataclasses.dataclass(frozen=True)
class WALRecord:
    """One durable log entry: a micro-batch of sgts or a churn op."""

    lsn: int
    kind: str                  # "batch" | "register" | "deregister"
    events: Tuple[SGT, ...] = ()
    clock: float = float("-inf")   # max event ts at append time
    meta: Optional[dict] = None    # churn payload (name, expr, kwargs)


def _encode_sgt(s: SGT) -> list:
    return [s.ts, _encode_vertex(s.src), _encode_vertex(s.dst), s.label, s.op]


def _decode_sgt(row: Sequence) -> SGT:
    ts, src, dst, label, op = row
    return SGT(float(ts), _decode_vertex(src), _decode_vertex(dst),
               str(label), str(op))


def _seg_name(first_lsn: int) -> str:
    return f"{_SEG_PREFIX}{first_lsn:012d}{_SEG_SUFFIX}"


def _seg_first_lsn(name: str) -> int:
    return int(name[len(_SEG_PREFIX):-len(_SEG_SUFFIX)])


class WriteAheadLog:
    """Append-ordered, CRC-framed, segment-rotated event log.

    A fresh instance over an existing directory resumes after the last
    VALID record (a torn tail is ignored for sequencing and skipped by
    replay), so the supervisor can reopen the same log after a crash
    without any repair step.
    """

    def __init__(self, directory: str, segment_records: int = 256,
                 fsync: bool = True):
        if segment_records < 1:
            raise ValueError(
                f"segment_records must be >= 1, got {segment_records}")
        self.directory = directory
        self.segment_records = int(segment_records)
        self.fsync = bool(fsync)
        os.makedirs(directory, exist_ok=True)
        self._fh = None                 # open handle on the active segment
        self._seg_count = 0             # records in the active segment
        self._last_lsn = 0
        #: records whose CRC/JSON failed on reopen (torn tail) — counted,
        #: never surfaced as events
        self.torn_records = 0
        self._scan_existing()

    # -- append path ----------------------------------------------------------

    @property
    def last_lsn(self) -> int:
        return self._last_lsn

    def append(self, events: Sequence[SGT]) -> int:
        """Durably log one micro-batch; returns its lsn. The record is on
        disk (flushed + fsync'd) before this returns — append BEFORE
        dispatching the batch and the batch can always be replayed."""
        events = tuple(events)
        if not events:
            raise ValueError("refusing to log an empty batch")
        clock = max(s.ts for s in events)
        return self._write({
            "kind": "batch",
            "clock": clock,
            "events": [_encode_sgt(s) for s in events],
        })

    def append_churn(self, kind: str, name: str,
                     meta: Optional[dict] = None) -> int:
        """Log a query-lifecycle op (kind = "register" | "deregister") so
        replay reproduces mid-stream churn in sequence with the batches."""
        if kind not in ("register", "deregister"):
            raise ValueError(f"unknown churn kind {kind!r}")
        return self._write({"kind": kind, "name": name, "meta": meta or {}})

    def _write(self, payload: dict) -> int:
        self._last_lsn += 1
        payload["lsn"] = self._last_lsn
        blob = json.dumps(payload, sort_keys=True).encode("utf-8")
        line = f"{zlib.crc32(blob) & 0xFFFFFFFF:08x} ".encode("ascii") \
            + blob + b"\n"
        if self._fh is None or self._seg_count >= self.segment_records:
            self._rotate(self._last_lsn)
        self._fh.write(line)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self._seg_count += 1
        return self._last_lsn

    def _rotate(self, first_lsn: int) -> None:
        if self._fh is not None:
            self._fh.close()
        path = os.path.join(self.directory, _seg_name(first_lsn))
        self._fh = open(path, "ab")
        self._seg_count = 0

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- replay / recovery ----------------------------------------------------

    def _segments(self) -> List[str]:
        names = [n for n in os.listdir(self.directory)
                 if n.startswith(_SEG_PREFIX) and n.endswith(_SEG_SUFFIX)]
        return sorted(names, key=_seg_first_lsn)

    def _scan_existing(self) -> None:
        """Resume sequencing after the last valid record on disk."""
        segs = self._segments()
        if not segs:
            return
        for rec in self._iter_records(segs[:-1]):
            self._last_lsn = max(self._last_lsn, rec.lsn)
        # the newest segment seeds the rotation counter and is reopened for
        # append — TRUNCATED back to the end of its last valid record
        # first, else a torn tail would sit between old records and new
        # appends and replay (which stops at the tear) could never reach
        # anything written after recovery
        self._seg_count = 0
        path = os.path.join(self.directory, segs[-1])
        valid_end = 0
        with open(path, "rb") as f:
            for raw in f:
                rec = self._parse(raw)
                if rec is None:
                    self.torn_records += 1
                    break
                self._last_lsn = max(self._last_lsn, rec.lsn)
                self._seg_count += 1
                valid_end += len(raw)
        if valid_end < os.path.getsize(path):
            with open(path, "r+b") as f:
                f.truncate(valid_end)
        self._fh = open(path, "ab")

    def _iter_records(self, seg_names: Sequence[str]) -> Iterator[WALRecord]:
        for i, name in enumerate(seg_names):
            path = os.path.join(self.directory, name)
            with open(path, "rb") as f:
                for raw in f:
                    rec = self._parse(raw)
                    if rec is None:
                        # CRC/JSON failure: a torn tail is expected on the
                        # LAST segment (the crash interrupted the write);
                        # anywhere else it still only truncates replay —
                        # events after a torn record cannot be trusted to
                        # be in sequence
                        self.torn_records += 1
                        return
                    yield rec

    def _parse(self, raw: bytes) -> Optional[WALRecord]:
        line = raw.rstrip(b"\n")
        if len(line) < 10 or line[8:9] != b" ":
            return None
        blob = line[9:]
        try:
            if int(line[:8], 16) != (zlib.crc32(blob) & 0xFFFFFFFF):
                return None
            payload = json.loads(blob.decode("utf-8"))
        except (ValueError, UnicodeDecodeError):
            return None
        kind = payload.get("kind", "batch")
        if kind == "batch":
            return WALRecord(
                lsn=int(payload["lsn"]), kind=kind,
                events=tuple(_decode_sgt(r) for r in payload["events"]),
                clock=float(payload.get("clock", float("-inf"))))
        return WALRecord(lsn=int(payload["lsn"]), kind=kind,
                         meta={"name": payload.get("name"),
                               **payload.get("meta", {})})

    def replay(self, after_lsn: int = 0) -> Iterator[WALRecord]:
        """Records with ``lsn > after_lsn`` in append order — feed the
        checkpoint's ``wal_lsn`` here and the suffix reconstructs the
        crashed run exactly. Stops silently at a torn tail record."""
        for rec in self._iter_records(self._segments()):
            if rec.lsn > after_lsn:
                yield rec

    # -- compaction -----------------------------------------------------------

    def truncate_upto(self, lsn: int) -> int:
        """Unlink segments whose EVERY record has ``lsn <= lsn`` (i.e. is
        covered by a committed checkpoint). Returns the number of segments
        dropped. The active segment is never unlinked — the open handle
        keeps appending to it."""
        segs = self._segments()
        dropped = 0
        # a segment's records are all below the NEXT segment's first lsn,
        # so seg[i] is fully covered iff first_lsn(seg[i+1]) <= lsn + 1
        for i in range(len(segs) - 1):    # never the active (last) segment
            if _seg_first_lsn(segs[i + 1]) <= lsn + 1:
                os.unlink(os.path.join(self.directory, segs[i]))
                dropped += 1
            else:
                break
        return dropped

    def __len__(self) -> int:
        return sum(1 for _ in self._iter_records(self._segments()))
