"""Synthetic streaming-graph generators mirroring the paper's workloads
(§5.1.2): SO-like (homogeneous, highly cyclic, 3 labels), LDBC-like
(social-network interactions, skewed), Yago-like (rich schema, ~100 labels,
sparse), and gMark-like (schema-driven with tunable recursion).

All are deterministic in the seed, emit strictly increasing timestamps, and
scale by (n_vertices, n_edges)."""
from __future__ import annotations

import collections
import random
from typing import List, Sequence, Tuple

from .stream import SGT, Stream

SO_LABELS = ["a2q", "c2a", "c2q"]
LDBC_LABELS = ["knows", "replyOf", "hasCreator", "likes", "hasTag", "isLocatedIn",
               "studyAt", "workAt"]


def so_like(n_vertices: int, n_edges: int, seed: int = 0,
            rate: float = 10.0) -> Stream:
    """StackOverflow-style: one vertex type, 3 interaction labels, heavy
    preferential attachment -> dense cyclic core."""
    rng = random.Random(seed)
    degree = [1] * n_vertices
    tuples = []
    t = 0.0
    for _ in range(n_edges):
        t += rng.expovariate(rate)
        # preferential attachment on both endpoints
        u = _weighted(rng, degree)
        v = _weighted(rng, degree)
        degree[u] += 1
        degree[v] += 1
        tuples.append(SGT(t, u, v, rng.choice(SO_LABELS)))
    return Stream(tuples)


def ldbc_like(n_persons: int, n_edges: int, seed: int = 0,
              rate: float = 10.0) -> Stream:
    """LDBC SNB-style update stream: persons + posts, 8 interaction types,
    recursive relations (knows, replyOf) between same-kind vertices."""
    rng = random.Random(seed)
    n_posts = 3 * n_persons
    tuples = []
    t = 0.0
    for _ in range(n_edges):
        t += rng.expovariate(rate)
        lab = rng.choice(LDBC_LABELS)
        if lab == "knows":
            u = ("p", rng.randrange(n_persons))
            v = ("p", rng.randrange(n_persons))
        elif lab == "replyOf":
            u = ("m", rng.randrange(n_posts))
            v = ("m", rng.randrange(n_posts))
        elif lab in ("hasCreator", "likes"):
            u = ("m", rng.randrange(n_posts))
            v = ("p", rng.randrange(n_persons))
            if lab == "likes":
                u, v = v, u
        else:
            u = ("p", rng.randrange(n_persons))
            v = ("org", rng.randrange(max(n_persons // 10, 1)))
        tuples.append(SGT(t, u, v, lab))
    return Stream(tuples)


def yago_like(n_vertices: int, n_edges: int, n_labels: int = 100,
              seed: int = 0, rate: float = 10.0) -> Stream:
    """RDF-ish: many labels with Zipf label frequency, sparse structure.
    Timestamps assigned at a fixed rate (paper's Yago2s windowing setup)."""
    rng = random.Random(seed)
    labels = [f"p{i}" for i in range(n_labels)]
    weights = [1.0 / (i + 1) for i in range(n_labels)]
    tuples = []
    t = 0.0
    for _ in range(n_edges):
        t += 1.0 / rate  # fixed rate: equal #edges per window
        u = rng.randrange(n_vertices)
        v = rng.randrange(n_vertices)
        tuples.append(SGT(t, u, v, rng.choices(labels, weights)[0]))
    return Stream(tuples)


def gmark_like(n_vertices: int, n_edges: int, labels: Sequence[str],
               seed: int = 0, rate: float = 10.0,
               cyclicity: float = 0.3) -> Stream:
    """Schema-driven generator with a tunable fraction of cycle-closing
    edges (the knob that stresses Kleene-star queries)."""
    rng = random.Random(seed)
    tuples = []
    t = 0.0
    # deque: the sliding 64-vertex recency window drops its oldest entry
    # in O(1) (rng.choice indexes it, so draws are identical to a list)
    recent: collections.deque = collections.deque()
    for _ in range(n_edges):
        t += rng.expovariate(rate)
        if recent and rng.random() < cyclicity:
            u = rng.choice(recent)
            v = rng.choice(recent)
        else:
            u = rng.randrange(n_vertices)
            v = rng.randrange(n_vertices)
        recent.append(v)
        if len(recent) > 64:
            recent.popleft()
        tuples.append(SGT(t, u, v, rng.choice(list(labels))))
    return Stream(tuples)


def with_deletions(stream: Stream, ratio: float, seed: int = 0) -> Stream:
    """Re-emit a fraction of previously inserted edges as negative tuples
    (the paper's §5.4 protocol)."""
    rng = random.Random(seed)
    tuples: List[SGT] = []
    inserted: List[SGT] = []
    t_last = 0.0
    for sgt in stream:
        tuples.append(sgt)
        inserted.append(sgt)
        t_last = sgt.ts
        if inserted and rng.random() < ratio:
            victim = inserted.pop(rng.randrange(len(inserted)))
            t_last += 1e-3
            tuples.append(SGT(t_last, victim.src, victim.dst, victim.label, "-"))
    return Stream(tuples)


# -- adversarial workloads ----------------------------------------------------
#
# The generators above model the paper's steady-state benchmarks. The ones
# below model the traffic that breaks services in production: bursty
# arrival processes, hotspot skew, deletion storms, query churn, and window
# scales spanning 100x. They are the input side of the supervision layer
# (streaming/supervisor.py) — deterministic in the seed like everything
# else here, so chaos results are reproducible.


def bursty_arrivals(n_vertices: int, n_edges: int, seed: int = 0,
                    base_rate: float = 10.0, diurnal_amp: float = 0.8,
                    period: float = 50.0, flash_every: int = 0,
                    flash_len: int = 32, flash_boost: float = 50.0,
                    labels: Sequence[str] = tuple(SO_LABELS)) -> Stream:
    """Diurnal arrivals plus flash crowds: the instantaneous rate follows a
    sinusoid (peak/trough ratio set by ``diurnal_amp``), and every
    ``flash_every`` edges a flash crowd multiplies the rate by
    ``flash_boost`` for ``flash_len`` edges while concentrating endpoints
    on a small hot set — inter-arrival gaps collapse, so micro-batches go
    from sparse to saturated within one window."""
    import math

    rng = random.Random(seed)
    tuples = []
    t = 0.0
    hot = [rng.randrange(n_vertices) for _ in range(max(4, n_vertices // 50))]
    flash_left = 0
    for i in range(n_edges):
        if flash_every and flash_left == 0 and i > 0 and i % flash_every == 0:
            flash_left = flash_len
        rate = base_rate * (1.0 + diurnal_amp * math.sin(
            2.0 * math.pi * (t / period)))
        rate = max(rate, 0.1 * base_rate)
        if flash_left > 0:
            rate *= flash_boost
            flash_left -= 1
            u = rng.choice(hot)
            v = rng.choice(hot) if rng.random() < 0.5 \
                else rng.randrange(n_vertices)
        else:
            u = rng.randrange(n_vertices)
            v = rng.randrange(n_vertices)
        t += rng.expovariate(rate)
        tuples.append(SGT(t, u, v, rng.choice(list(labels))))
    return Stream(tuples)


def powerlaw_hotspot(n_vertices: int, n_edges: int, seed: int = 0,
                     rate: float = 10.0, alpha: float = 1.2,
                     labels: Sequence[str] = tuple(SO_LABELS)) -> Stream:
    """Zipf(``alpha``) endpoint skew: a handful of celebrity vertices absorb
    most edges, driving per-row fanout far past any uniform model — the
    stress case for ELL row caps and row-sparse dist overflow."""
    rng = random.Random(seed)
    weights = [1.0 / ((i + 1) ** alpha) for i in range(n_vertices)]
    total = sum(weights)
    cum, acc = [], 0.0
    for w in weights:
        acc += w
        cum.append(acc / total)
    import bisect

    def draw() -> int:
        return bisect.bisect_left(cum, rng.random())

    tuples = []
    t = 0.0
    for _ in range(n_edges):
        t += rng.expovariate(rate)
        tuples.append(SGT(t, draw(), draw(), rng.choice(list(labels))))
    return Stream(tuples)


def deletion_storm(stream: Stream, storm_ratio: float = 0.5,
                   storm_every: int = 64, storm_len: int = 24,
                   seed: int = 0) -> Stream:
    """Deletion-heavy stream: quiet stretches at a trickle deletion rate,
    then storms where up to ``storm_ratio`` of the live edge set is
    re-emitted negative in timestamp order — the shape that floods the
    cone-seeded re-derivation path and the dist overflow ring."""
    rng = random.Random(seed)
    tuples: List[SGT] = []
    live: List[SGT] = []
    t_last = 0.0
    since_storm = 0
    for sgt in stream:
        tuples.append(sgt)
        live.append(sgt)
        t_last = sgt.ts
        since_storm += 1
        if since_storm >= storm_every and live:
            since_storm = 0
            n_kill = min(len(live),
                         max(1, int(min(storm_len,
                                        storm_ratio * len(live)))))
            for _ in range(n_kill):
                victim = live.pop(rng.randrange(len(live)))
                t_last += 1e-3
                tuples.append(
                    SGT(t_last, victim.src, victim.dst, victim.label, "-"))
    return Stream(tuples)


def mixed_window_streams(n_vertices: int, n_edges: int, seed: int = 0,
                         rate: float = 10.0) -> List[dict]:
    """Window sizes spanning 100x over the same arrival process: each entry
    pairs a stream with (window, slide) so a harness can sweep expiry
    pressure from "almost nothing expires" to "the window churns every
    few batches". Returns ``[{stream, window, slide, name}, ...]``."""
    out = []
    base = so_like(n_vertices, n_edges, seed=seed, rate=rate)
    for i, window in enumerate((2.0, 20.0, 200.0)):
        out.append({
            "name": f"w{window:g}",
            "stream": Stream(list(base)),
            "window": window,
            "slide": max(window / 10.0, 0.2),
            "seed": seed + i,
        })
    return out


def churn_storm_plan(n_batches: int, seed: int = 0,
                     churn_every: int = 8,
                     exprs: Sequence[Tuple[str, str]] = ()) -> List[Tuple]:
    """A deterministic query-churn schedule: every ``churn_every`` batches
    emit a (batch_idx, op, name, expr) op that registers a fresh query or
    deregisters a previously added one — the storm alternates so the live
    query set keeps shifting. ``exprs`` is the pool of (kind, expr) pairs
    to draw from (kind = "rpq" | "rapq")."""
    rng = random.Random(seed)
    pool = list(exprs) or [("rpq", "a2q+"), ("rpq", "c2a . a2q"),
                           ("rpq", "(c2q | c2a) . a2q*")]
    plan: List[Tuple] = []
    live: List[str] = []
    n = 0
    for b in range(churn_every, n_batches, churn_every):
        if live and rng.random() < 0.4:
            name = live.pop(rng.randrange(len(live)))
            plan.append((b, "deregister", name, None, None))
        else:
            kind, expr = pool[rng.randrange(len(pool))]
            name = f"storm_{n}"
            n += 1
            live.append(name)
            plan.append((b, "register", name, kind, expr))
    return plan


def _weighted(rng: random.Random, weights: List[int]) -> int:
    total = sum(weights)
    r = rng.random() * total
    acc = 0
    for i, w in enumerate(weights):
        acc += w
        if r <= acc:
            return i
    return len(weights) - 1
