"""Synthetic streaming-graph generators mirroring the paper's workloads
(§5.1.2): SO-like (homogeneous, highly cyclic, 3 labels), LDBC-like
(social-network interactions, skewed), Yago-like (rich schema, ~100 labels,
sparse), and gMark-like (schema-driven with tunable recursion).

All are deterministic in the seed, emit strictly increasing timestamps, and
scale by (n_vertices, n_edges)."""
from __future__ import annotations

import collections
import random
from typing import List, Sequence

from .stream import SGT, Stream

SO_LABELS = ["a2q", "c2a", "c2q"]
LDBC_LABELS = ["knows", "replyOf", "hasCreator", "likes", "hasTag", "isLocatedIn",
               "studyAt", "workAt"]


def so_like(n_vertices: int, n_edges: int, seed: int = 0,
            rate: float = 10.0) -> Stream:
    """StackOverflow-style: one vertex type, 3 interaction labels, heavy
    preferential attachment -> dense cyclic core."""
    rng = random.Random(seed)
    degree = [1] * n_vertices
    tuples = []
    t = 0.0
    for _ in range(n_edges):
        t += rng.expovariate(rate)
        # preferential attachment on both endpoints
        u = _weighted(rng, degree)
        v = _weighted(rng, degree)
        degree[u] += 1
        degree[v] += 1
        tuples.append(SGT(t, u, v, rng.choice(SO_LABELS)))
    return Stream(tuples)


def ldbc_like(n_persons: int, n_edges: int, seed: int = 0,
              rate: float = 10.0) -> Stream:
    """LDBC SNB-style update stream: persons + posts, 8 interaction types,
    recursive relations (knows, replyOf) between same-kind vertices."""
    rng = random.Random(seed)
    n_posts = 3 * n_persons
    tuples = []
    t = 0.0
    for _ in range(n_edges):
        t += rng.expovariate(rate)
        lab = rng.choice(LDBC_LABELS)
        if lab == "knows":
            u = ("p", rng.randrange(n_persons))
            v = ("p", rng.randrange(n_persons))
        elif lab == "replyOf":
            u = ("m", rng.randrange(n_posts))
            v = ("m", rng.randrange(n_posts))
        elif lab in ("hasCreator", "likes"):
            u = ("m", rng.randrange(n_posts))
            v = ("p", rng.randrange(n_persons))
            if lab == "likes":
                u, v = v, u
        else:
            u = ("p", rng.randrange(n_persons))
            v = ("org", rng.randrange(max(n_persons // 10, 1)))
        tuples.append(SGT(t, u, v, lab))
    return Stream(tuples)


def yago_like(n_vertices: int, n_edges: int, n_labels: int = 100,
              seed: int = 0, rate: float = 10.0) -> Stream:
    """RDF-ish: many labels with Zipf label frequency, sparse structure.
    Timestamps assigned at a fixed rate (paper's Yago2s windowing setup)."""
    rng = random.Random(seed)
    labels = [f"p{i}" for i in range(n_labels)]
    weights = [1.0 / (i + 1) for i in range(n_labels)]
    tuples = []
    t = 0.0
    for _ in range(n_edges):
        t += 1.0 / rate  # fixed rate: equal #edges per window
        u = rng.randrange(n_vertices)
        v = rng.randrange(n_vertices)
        tuples.append(SGT(t, u, v, rng.choices(labels, weights)[0]))
    return Stream(tuples)


def gmark_like(n_vertices: int, n_edges: int, labels: Sequence[str],
               seed: int = 0, rate: float = 10.0,
               cyclicity: float = 0.3) -> Stream:
    """Schema-driven generator with a tunable fraction of cycle-closing
    edges (the knob that stresses Kleene-star queries)."""
    rng = random.Random(seed)
    tuples = []
    t = 0.0
    # deque: the sliding 64-vertex recency window drops its oldest entry
    # in O(1) (rng.choice indexes it, so draws are identical to a list)
    recent: collections.deque = collections.deque()
    for _ in range(n_edges):
        t += rng.expovariate(rate)
        if recent and rng.random() < cyclicity:
            u = rng.choice(recent)
            v = rng.choice(recent)
        else:
            u = rng.randrange(n_vertices)
            v = rng.randrange(n_vertices)
        recent.append(v)
        if len(recent) > 64:
            recent.popleft()
        tuples.append(SGT(t, u, v, rng.choice(list(labels))))
    return Stream(tuples)


def with_deletions(stream: Stream, ratio: float, seed: int = 0) -> Stream:
    """Re-emit a fraction of previously inserted edges as negative tuples
    (the paper's §5.4 protocol)."""
    rng = random.Random(seed)
    tuples: List[SGT] = []
    inserted: List[SGT] = []
    t_last = 0.0
    for sgt in stream:
        tuples.append(sgt)
        inserted.append(sgt)
        t_last = sgt.ts
        if inserted and rng.random() < ratio:
            victim = inserted.pop(rng.randrange(len(inserted)))
            t_last += 1e-3
            tuples.append(SGT(t_last, victim.src, victim.dst, victim.label, "-"))
    return Stream(tuples)


def _weighted(rng: random.Random, weights: List[int]) -> int:
    total = sum(weights)
    r = rng.random() * total
    acc = 0
    for i, w in enumerate(weights):
        acc += w
        if r <= acc:
            return i
    return len(weights) - 1
