"""Streaming-graph tuple (sgt) model and ordered stream abstractions."""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Tuple


@dataclasses.dataclass(frozen=True)
class SGT:
    """Streaming graph tuple (Definition 2): (timestamp, edge, label, op)."""

    ts: float
    src: object
    dst: object
    label: str
    op: str = "+"  # '+' insert | '-' explicit delete

    def as_edge(self) -> Tuple[object, object, str, float]:
        return (self.src, self.dst, self.label, self.ts)


class Stream:
    """An in-order sgt sequence with micro-batch iteration."""

    def __init__(self, tuples: Iterable[SGT]):
        self.tuples: List[SGT] = sorted(tuples, key=lambda t: t.ts)

    def __iter__(self) -> Iterator[SGT]:
        return iter(self.tuples)

    def __len__(self) -> int:
        return len(self.tuples)

    def batches(self, size: int) -> Iterator[List[SGT]]:
        for i in range(0, len(self.tuples), size):
            yield self.tuples[i : i + size]

    def span(self) -> Tuple[float, float]:
        return self.tuples[0].ts, self.tuples[-1].ts
