"""Persistent-query service: the end-to-end serving driver.

Register RPQs (with per-query engine choice + path semantics), ingest an
ordered sgt stream with eager evaluation and lazy expiration (slide
interval β), and emit an append-only result stream per query — exactly the
paper's execution model (§2, §5.1).

Multi-query execution: every query registered with ``engine="dense"`` is
folded into ONE :class:`~repro.core.engine.BatchedDenseRPQEngine` sharing
the labeled adjacency and the vertex interner, so each arriving sgt costs a
single jitted dispatch for the whole dense workload instead of one per
query (benchmarks/fig12_multi_query.py measures the win). Reference
engines (the paper-faithful pointer oracles) stay on the per-query path.
The dense group is materialized lazily at first ingest; registering more
dense queries after ingestion has begun raises (re-padding live device
state is not supported — snapshot, re-register, restore instead).

Fault tolerance: the service checkpoints engine state via
checkpoint/ckpt.py — the batched dense group as one pytree of device
arrays + interner/result metadata in the manifest, reference engines as
pickled leaves — and can re-attach after a crash (tests/test_fault.py
drives crash → restore → identical result stream).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Set, Tuple

from ..core.automaton import compile_query
from ..core.engine import BatchedDenseRPQEngine, RegisteredQuery
from ..core.reference import RAPQ, RSPQ


@dataclasses.dataclass
class QueryStats:
    tuples: int = 0
    results: int = 0
    conflicted: bool = False
    wall_s: float = 0.0
    p99_us: float = 0.0
    latencies_us: Optional[List[float]] = None


class PersistentQueryService:
    def __init__(self, window: float, slide: float):
        self.window = float(window)
        self.slide = float(slide)
        # reference (pointer) engines, one per query
        self._ref_engines: Dict[str, object] = {}
        # dense queries: name -> registration kwargs; grouped lazily
        self._dense_specs: Dict[str, Dict] = {}
        self._group: Optional[BatchedDenseRPQEngine] = None
        self._group_order: List[str] = []
        self._ingest_started = False
        self.stats: Dict[str, QueryStats] = {}
        self._next_expiry = slide

    @property
    def queries(self) -> Dict[str, object]:
        """name -> engine handling it (the batched group for dense queries)."""
        self._ensure_group()
        out: Dict[str, object] = dict(self._ref_engines)
        for name in self._dense_specs:
            out[name] = self._group
        return out

    def register(
        self,
        name: str,
        expr: str,
        engine: str = "dense",            # dense | reference
        path_semantics: str = "arbitrary",  # arbitrary | simple
        n_slots: int = 256,
        batch_size: int = 1,
        backend: str = "jnp",
    ) -> None:
        dfa = compile_query(expr)
        if engine == "dense":
            if self._ingest_started:
                raise RuntimeError(
                    "cannot add dense queries after ingestion started: the "
                    "batched group state is live; snapshot, re-register, restore"
                )
            self._dense_specs[name] = dict(
                dfa=dfa, path_semantics=path_semantics, n_slots=n_slots,
                batch_size=batch_size, backend=backend,
            )
            self._group = None  # rebuilt (empty) at next ingest/snapshot
        elif path_semantics == "simple":
            self._ref_engines[name] = RSPQ(dfa, self.window)
        else:
            self._ref_engines[name] = RAPQ(dfa, self.window)
        self.stats[name] = QueryStats(latencies_us=[])

    def _ensure_group(self) -> None:
        if self._group is not None or not self._dense_specs:
            return
        backends = {s["backend"] for s in self._dense_specs.values()}
        if len(backends) > 1:
            raise ValueError(f"dense queries must share one backend, got {backends}")
        specs = [
            RegisteredQuery(name, s["dfa"], self.window, s["path_semantics"])
            for name, s in self._dense_specs.items()
        ]
        self._group = BatchedDenseRPQEngine(
            specs,
            n_slots=max(s["n_slots"] for s in self._dense_specs.values()),
            # exactness dominates: the smallest requested micro-batch bounds
            # the group's batch-boundary skew for every member query
            batch_size=min(s["batch_size"] for s in self._dense_specs.values()),
            backend=backends.pop(),
        )
        self._group_order = list(self._dense_specs)

    def ingest(self, stream, record_latency: bool = False) -> Dict[str, Set[Tuple]]:
        """Feed the whole stream; returns new result pairs per query."""
        self._ensure_group()
        self._ingest_started = True
        new_results: Dict[str, Set[Tuple]] = {name: set() for name in self.stats}
        for sgt in stream:
            # lazy expiration at slide boundaries (eager evaluation)
            if sgt.ts >= self._next_expiry:
                if self._group is not None:
                    self._group.expire(sgt.ts)
                for eng in self._ref_engines.values():
                    eng.expire(sgt.ts)
                while self._next_expiry <= sgt.ts:
                    self._next_expiry += self.slide
            if self._group is not None:
                t0 = time.perf_counter_ns() if record_latency else 0
                if sgt.op == "+":
                    fresh = self._group.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
                else:
                    self._group.delete(sgt.src, sgt.dst, sgt.label, sgt.ts)
                    fresh = None
                dt = (time.perf_counter_ns() - t0) / 1e3 if record_latency else 0.0
                for qi, name in enumerate(self._group_order):
                    st = self.stats[name]
                    st.tuples += 1
                    if fresh is not None:
                        new_results[name] |= fresh[qi]
                    if record_latency:
                        # one dispatch serves the whole group; each member
                        # observes the group's step latency
                        st.latencies_us.append(dt)
            for name, eng in self._ref_engines.items():
                t0 = time.perf_counter_ns() if record_latency else 0
                if sgt.op == "+":
                    res = eng.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
                    new_results[name] |= res
                else:
                    eng.delete(sgt.src, sgt.dst, sgt.label, sgt.ts)
                st = self.stats[name]
                st.tuples += 1
                if record_latency:
                    st.latencies_us.append((time.perf_counter_ns() - t0) / 1e3)
        for name in self.stats:
            st = self.stats[name]
            st.results = len(self.results(name))
            st.conflicted = self._conflicted(name)
            if st.latencies_us:
                lat = sorted(st.latencies_us)
                st.p99_us = lat[min(int(0.99 * len(lat)), len(lat) - 1)]
        return new_results

    def results(self, name: str) -> Set[Tuple]:
        if name in self._dense_specs:
            self._ensure_group()
            qi = self._group_order.index(name)
            return set(self._group.per_query_results[qi])
        return set(self._ref_engines[name].results)

    def _conflicted(self, name: str) -> bool:
        if name in self._dense_specs and self._group is not None:
            return bool(self._group.per_query_conflicted[self._group_order.index(name)])
        eng = self._ref_engines.get(name)
        return bool(getattr(eng, "conflicts_detected", 0)) if eng else False

    # -- state persistence ----------------------------------------------------

    def snapshot(self, directory: str, step: int) -> None:
        from ..checkpoint import ckpt

        self._ensure_group()
        state: Dict[str, object] = {}
        extra: Dict[str, object] = {
            "step": step,
            "next_expiry": self._next_expiry,
            "reference": sorted(self._ref_engines),
        }
        if self._group is not None:
            state["dense_group"] = self._group.state_arrays()
            extra["dense"] = {
                "order": self._group_order,
                "interner": self._group.interner_state(),
                **self._group.results_state(),
            }
        for name, eng in self._ref_engines.items():
            state[f"refeng.{name}"] = ckpt.pickle_leaf(eng)
        ckpt.save(directory, step, state, extra=extra)

    def restore(self, directory: str) -> int:
        from ..checkpoint import ckpt

        self._ensure_group()
        like: Dict[str, object] = {}
        if self._group is not None:
            like["dense_group"] = self._group.state_arrays()
        for name in self._ref_engines:
            like[f"refeng.{name}"] = ckpt.pickle_like()
        state, extra = ckpt.restore(directory, like=like)
        if self._group is not None:
            meta = extra["dense"]
            if meta["order"] != self._group_order:
                raise ValueError(
                    f"checkpointed query set {meta['order']} does not match "
                    f"registration order {self._group_order}"
                )
            self._group.load_state_arrays(state["dense_group"])
            self._group.load_interner(meta["interner"])
            self._group.load_results_state(meta)
        for name in self._ref_engines:
            self._ref_engines[name] = ckpt.unpickle_leaf(state[f"refeng.{name}"])
        self._next_expiry = float(extra.get("next_expiry", self.slide))
        self._ingest_started = True
        return int(extra["step"])
