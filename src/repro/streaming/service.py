"""Persistent-query service: the end-to-end serving driver.

Register RPQs (with per-query engine choice + path semantics), ingest an
ordered sgt stream with eager evaluation and lazy expiration (slide
interval β), and emit an append-only result stream per query — exactly the
paper's execution model (§2, §5.1).

Multi-query execution: every query registered with ``engine="dense"`` is
folded into ONE :class:`~repro.core.engine.BatchedDenseRPQEngine` sharing
the labeled adjacency and the vertex interner, so each arriving sgt costs a
single jitted dispatch for the whole dense workload instead of one per
query (benchmarks/fig12_multi_query.py measures the win). Reference
engines (the paper-faithful pointer oracles) stay on the per-query path.

Executor selection (PR 3): the service chooses the dense group's device
path — ``executor="local"`` (single device, the default) or
``executor="mesh"`` (Q lanes sharded over the process's device mesh with
convergence-aware dispatch, :mod:`repro.distributed.executor`); an
:class:`~repro.core.executor.Executor` instance is also accepted. Result
streams are identical across executors (tests/test_executor.py).

Async result decode (PR 3, deepened PR 4): with ``async_decode=True`` the
service defers the device→host transfer of each ingest's emit frontier
behind a bounded FIFO of up to ``async_depth`` in-flight dispatches — the
transfer of dispatch *i* overlaps dispatches *i+1..i+k* instead of
blocking the hot path (engine :class:`~repro.core.engine.PendingResults`;
decode safety is preserved by per-dispatch interner snapshots and strict
FIFO drain order, and all handles resolve before any expiry, deletion,
lifecycle event, or the end of :meth:`ingest`, so the returned report is
complete). Recorded latencies then measure dispatch time only.

Adaptive micro-batching (PR 4, opt-in ``adaptive_batch=True``): dense
inserts buffer into micro-batches whose size doubles/halves (power-of-two
bucketing, capped at ``max_batch``) from the executor's skip counters at
each slide boundary — a large no-op relaxation tail means dispatch
overhead dominates useful work, so the batch grows; decisions land in
``batch_size_log`` and B > 1 carries the engine's documented
batch-boundary skew.

Contraction backends (PR 4): dense registrations accept ``backend`` as a
name ("jnp" | "pallas" | "mxu_bucket") or a
:class:`~repro.core.backend.ContractionBackend` instance, validated AT
REGISTRATION (unknown names raise with the known list — they used to fall
back to jnp silently). Both executors run the selected backend.

RSPQ fallback (PR 3): a dense lane running ``path_semantics="simple"``
over-approximates when its automaton lacks the containment property and a
conflict materializes (Definition 16). When ``per_query_conflicted`` fires
for such a lane, the service routes the query to the exact (paper §4.1)
reference RSPQ engine, seeded from the group's
:meth:`~repro.core.engine.BatchedDenseRPQEngine.retained_edges` — the
switch is surfaced in :attr:`IngestReport.fallbacks`, the lane returns to
the group as reclaimable padding, and results from the switch on are
exact (results emitted before the switch may over-report; that window is
exactly what the flag marks). Disable with ``rspq_fallback=False`` to keep
the flag-only PR 2 behavior.

Query lifecycle is LIVE (PR 2): :meth:`PersistentQueryService.register`
works before OR after ingestion has started — a late dense registration
re-pads the running group's device state in place and seeds the new
query's closure over the retained graph, so it immediately answers over
the current window (the initial result pairs are returned).
:meth:`deregister` retires a query mid-stream; its lane becomes inert
padding reclaimed by the next registration. A dense query registered after
ingestion adopts the group's existing capacities (``n_slots``,
``batch_size``, ``backend``) — per-call capacity arguments apply only
while the group is still unmaterialized. Vertex capacity grows on demand
(PR 3), so ``n_slots`` is a starting size, not a ceiling.

Deletion visibility: :meth:`ingest` returns an :class:`IngestReport` — a
plain ``dict`` of NEW result pairs per query (backward compatible) whose
``.invalidated`` attribute carries the result pairs each negative tuple
invalidated (the paper's §3.2 invalidation stream) and whose
``.fallbacks`` attribute names the queries switched to the reference RSPQ
path during the call.

Fault tolerance: the service checkpoints engine state via
checkpoint/ckpt.py — the batched dense group as one pytree of device
arrays + interner/result metadata in the manifest (the manifest records
the LIVE query set lane-by-lane and the label order), reference engines as
pickled leaves — and can re-attach after a crash (tests/test_fault.py
drives crash → restore → identical result stream). Restore matches lanes
by query name and adjacency rows by label name, so a restoring service
whose group has a different churn history (other bucketed-Q/K/label/slot
padding) OR a different executor (mesh-written → local-restored and vice
versa) re-pads the checkpoint onto its own capacities and placement. A
query that fell back to the reference RSPQ checkpoints as a reference
engine; a service restoring such a snapshot must register it with
``engine="reference"`` (the mismatch raises otherwise).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Set, Tuple, Union

from ..core.automaton import compile_query
from ..core.backend import resolve_backend
from ..core.engine import BatchedDenseRPQEngine, PendingResults, RegisteredQuery
from ..core.executor import (
    ADJ_LAYOUTS,
    DIST_LAYOUTS,
    FRONTIER_MODES,
    Executor,
    LocalExecutor,
    _next_pow2,
)
from ..core.reference import RAPQ, RSPQ


@dataclasses.dataclass
class QueryStats:
    tuples: int = 0
    results: int = 0
    conflicted: bool = False
    wall_s: float = 0.0
    p99_us: float = 0.0
    latencies_us: Optional[List[float]] = None


class IngestReport(Dict[str, Set[Tuple]]):
    """New result pairs per query (a plain dict, so existing callers keep
    working), with the deletion-invalidated pairs alongside in
    :attr:`invalidated` (name -> set of (x, y) pairs a negative tuple
    removed from the valid answer set), the queries switched to the
    exact reference RSPQ path in :attr:`fallbacks` (name -> reason), and —
    when the dense group runs frontier-restricted ingest — the call's
    frontier telemetry in :attr:`frontier_stats` (rows relaxed vs the
    dense-loop row equivalent, overflow-fallback count, current capacity;
    empty dict with ``frontier="off"``)."""

    def __init__(self, new: Dict[str, Set[Tuple]],
                 invalidated: Dict[str, Set[Tuple]],
                 fallbacks: Optional[Dict[str, str]] = None,
                 frontier_stats: Optional[Dict[str, object]] = None,
                 deletions: int = 0):
        super().__init__(new)
        self.invalidated: Dict[str, Set[Tuple]] = invalidated
        self.fallbacks: Dict[str, str] = dict(fallbacks or {})
        self.frontier_stats: Dict[str, object] = dict(frontier_stats or {})
        #: negative tuples the dense group processed during this call
        #: (frontier delete telemetry — cone dispatches / fallbacks — rides
        #: in :attr:`frontier_stats` under ``delete_dispatches`` /
        #: ``delete_fallbacks``).
        self.deletions: int = int(deletions)


class RSPQFallback:
    """Exact simple-path engine for a query evicted from the dense group
    after a conflict: the paper-faithful :class:`RSPQ` plus the live-edge
    bookkeeping the dense group used to provide.

    The wrapper keeps its own (u, v, label) -> ts map of window-live edges
    so explicit deletions work even though the paper's RSPQ listing has no
    Delete algorithm: a negative tuple rebuilds a fresh RSPQ from the
    retained edges (the paper's uniform re-derivation machinery, pointer
    form). ``results`` stays monotone across rebuilds — the emitted history
    (including the dense lane's pre-switch results, which may over-report;
    that is what the conflict flag marked) is carried forward, and
    :meth:`insert` returns only pairs NEW to it."""

    def __init__(self, dfa, window: float, emitted: Optional[Set[Tuple]] = None):
        self.dfa = dfa
        self.window = float(window)
        self._edges: Dict[Tuple, float] = {}
        self._rspq = RSPQ(dfa, window)
        self._emitted: Set[Tuple] = set(emitted or ())

    @property
    def results(self) -> Set[Tuple]:
        return self._emitted | self._rspq.results

    @property
    def conflicts_detected(self) -> int:
        return self._rspq.conflicts_detected

    def seed(self, edges, now: float) -> None:
        """Replay the dense group's retained edges and sync the clock, so
        the engine answers over the current window from its first event."""
        for (u, v, label, ts) in edges:
            self._edges[(u, v, label)] = ts
            self._rspq.insert(u, v, label, ts)
        if now > float("-inf"):
            self._rspq.expire(now)

    def insert(self, u, v, label: str, ts: float) -> Set[Tuple]:
        self._edges[(u, v, label)] = ts
        before = self.results
        self._rspq.insert(u, v, label, ts)
        return self.results - before

    def delete(self, u, v, label: str, ts: float) -> Set[Tuple]:
        self._edges.pop((u, v, label), None)
        now = max(self._rspq.now, ts)
        # advance the clock BEFORE snapshotting validity, mirroring the
        # dense engine's _delete (valid_before at the event's `now`):
        # otherwise pairs the deletion event's own clock advance expired
        # would be misreported as invalidated by the negative tuple
        self._rspq.expire(now)
        before_valid = self._rspq.current_results()
        self._emitted |= self._rspq.results
        fresh = RSPQ(self.dfa, self.window)
        low = now - self.window
        for (eu, ev, el), ets in sorted(self._edges.items(), key=lambda kv: kv[1]):
            if ets > low:
                fresh.insert(eu, ev, el, ets)
        fresh.expire(now)
        self._rspq = fresh
        return before_valid - fresh.current_results()

    def expire(self, tau: Optional[float] = None) -> None:
        self._rspq.expire(tau)
        if tau is not None:
            low = tau - self.window
            self._edges = {k: t for k, t in self._edges.items() if t > low}

    def current_results(self) -> Set[Tuple]:
        return self._rspq.current_results()


class PersistentQueryService:
    def __init__(self, window: float, slide: float,
                 executor: Union[str, Executor] = "local",
                 async_decode: bool = False,
                 async_depth: int = 1,
                 rspq_fallback: bool = True,
                 adaptive_batch: bool = False,
                 max_batch: int = 32,
                 frontier: str = "off",
                 frontier_cap: int = 32,
                 adj_layout: str = "dense",
                 ell_cap: int = 8,
                 dist_layout: str = "dense",
                 dist_cap: int = 16):
        self.window = float(window)
        self.slide = float(slide)
        self._executor_spec = executor
        # frontier-restricted ingest (PR 5): "off" = dense dispatch only,
        # "on" = frontier at a fixed capacity, "auto" = frontier whose
        # capacity grows ×2 on observed overflow fallbacks. Results are
        # bit-identical in every mode (overflow falls back to the dense
        # loop IN-DISPATCH); the knob only moves per-event cost between
        # O(J·N³) and O(J·F·N²). Per-interval telemetry lands in
        # :attr:`frontier_log` and each ingest's delta in
        # ``IngestReport.frontier_stats``.
        if frontier not in FRONTIER_MODES:
            raise ValueError(
                f"unknown frontier mode {frontier!r} "
                f"({' | '.join(FRONTIER_MODES)})")
        self._frontier = frontier
        self._frontier_cap = int(frontier_cap)
        # adjacency representation (tentpole of the blocked-sparse PR):
        # "dense" = the (L, N, N) slab, "ell" = padded ELL rows + spill
        # ring (core/sparse_adj.py). Results are bit-identical; memory is
        # ∝ live edges and the seed term drops from O(N²K) to
        # O(F·d_max·K) under ELL. Per-interval occupancy telemetry lands
        # in :attr:`adjacency_log`.
        if adj_layout not in ADJ_LAYOUTS:
            raise ValueError(
                f"unknown adj_layout {adj_layout!r} "
                f"({' | '.join(ADJ_LAYOUTS)})")
        self._adj_layout = adj_layout
        self._ell_cap = int(ell_cap)
        # dist representation (tentpole of the sparse-dist PR): "dense" =
        # the (Q, N, N, K) slab, "row_sparse" = per-source-row reachable
        # sets + bounded overflow table (core/sparse_dist.py). Result
        # streams are identical in every mode; memory is ∝ reachable
        # entries and the emit scan drops from O(Q·N²·K) to
        # O(Q·N·dist_cap). Per-interval occupancy telemetry lands in
        # :attr:`dist_log`.
        if dist_layout not in DIST_LAYOUTS:
            raise ValueError(
                f"unknown dist_layout {dist_layout!r} "
                f"({' | '.join(DIST_LAYOUTS)})")
        self._dist_layout = dist_layout
        self._dist_cap = int(dist_cap)
        #: (tuples_seen_so_far, adjacency_stats snapshot) history, one
        #: entry per slide boundary when the layout is "ell"
        self.adjacency_log: List[Tuple[int, Dict[str, object]]] = []
        #: (tuples_seen_so_far, dist_stats snapshot) history, one entry
        #: per slide boundary when the dist layout is "row_sparse"
        self.dist_log: List[Tuple[int, Dict[str, object]]] = []
        #: (tuples_seen_so_far, per-interval frontier stats delta) history
        self.frontier_log: List[Tuple[int, Dict[str, object]]] = []
        self._frontier_mark: Optional[Dict[str, object]] = None
        self._async_decode = bool(async_decode)
        # bounded deferred-decode FIFO: up to `async_depth` dispatches may
        # be in flight before the oldest emit frontier is pulled off the
        # device (async_decode=True, depth 1 = the PR 3 single-handle
        # behavior). Handles resolve in dispatch order — the engine's
        # monotone per-query result sets require FIFO decode — and each
        # snapshots the interner at dispatch, so slot recycling between
        # dispatch and resolve cannot remap decoded pairs.
        self._async_depth = max(1, int(async_depth))
        self._rspq_fallback = bool(rspq_fallback)
        # adaptive micro-batching (opt-in): grow/shrink the dense group's
        # batch_size in x2 steps from the executor's skip counters — see
        # ingest(). B > 1 trades the documented batch-boundary skew for
        # fewer dispatches, so it is never on by default.
        self._adaptive_batch = bool(adaptive_batch)
        self._max_batch = max(1, int(max_batch))
        self._adapt_marks: Optional[Tuple[int, int]] = None
        #: (tuples_seen_so_far, chosen_size) history of adaptive decisions
        self.batch_size_log: List[Tuple[int, int]] = []
        # reference (pointer) engines, one per query
        self._ref_engines: Dict[str, object] = {}
        # dense queries: name -> registration kwargs; grouped lazily until
        # first ingest, then the group is LIVE and mutated in place
        self._dense_specs: Dict[str, Dict] = {}
        self._group: Optional[BatchedDenseRPQEngine] = None
        self._ingest_started = False
        self.stats: Dict[str, QueryStats] = {}
        self._next_expiry = slide

    def _make_executor(self, backend) -> Executor:
        if isinstance(self._executor_spec, Executor):
            return self._executor_spec
        if self._executor_spec == "mesh":
            from ..distributed.executor import MeshExecutor

            return MeshExecutor(backend=backend, frontier=self._frontier,
                                frontier_cap=self._frontier_cap,
                                adj_layout=self._adj_layout,
                                ell_cap=self._ell_cap,
                                dist_layout=self._dist_layout,
                                dist_cap=self._dist_cap)
        if self._executor_spec == "local":
            return LocalExecutor(backend, frontier=self._frontier,
                                 frontier_cap=self._frontier_cap,
                                 adj_layout=self._adj_layout,
                                 ell_cap=self._ell_cap,
                                 dist_layout=self._dist_layout,
                                 dist_cap=self._dist_cap)
        raise ValueError(
            f"unknown executor {self._executor_spec!r} (local | mesh | instance)")

    @staticmethod
    def _stats_delta(cur: Dict[str, object],
                     prev: Dict[str, object]) -> Dict[str, object]:
        """Difference two frontier-stat snapshots: counters subtract,
        level values (mode, cap, max_lane_rows) pass through, occupancy is
        recomputed over the interval's own rows."""
        level_keys = ("mode", "cap", "max_lane_rows")
        delta = {
            k: (cur[k] - prev.get(k, 0)
                if isinstance(cur[k], int) and k not in level_keys
                else cur[k])
            for k in cur
        }
        dr = delta.get("dense_row_equiv", 0)
        # An interval with zero dense-row-equivalent work carries no
        # occupancy signal at all (no dispatch touched any rows) — report
        # None rather than 0.0 so consumers (adaptive batching) can tell
        # "idle" apart from "genuinely sparse frontiers".
        delta["occupancy"] = (delta.get("rows_relaxed", 0) / dr) if dr else None
        return delta

    @staticmethod
    def _frontier_healthy(finterval: Dict[str, object]) -> bool:
        """True when the interval's frontier telemetry shows cheap, live
        dispatches: some dispatches ran, their measured row occupancy is
        tiny, and none overflowed to the dense loop. An interval with no
        signal — no dispatches at all, or ``occupancy is None`` because
        zero dense-row-equivalent work happened — is NOT healthy: it says
        nothing about the frontier, and treating it as healthy would hold
        the batch size frozen across idle slides."""
        if not finterval or not finterval.get("dispatches", 0):
            return False
        occ = finterval.get("occupancy")
        if occ is None:
            return False
        return occ < 0.05 and not finterval.get("fallbacks", 0)

    def _frontier_delta(self) -> Dict[str, object]:
        """Frontier-stat delta since the last mark (per-interval telemetry;
        empty when the frontier is off or no dense group exists)."""
        if self._group is None or self._frontier == "off":
            return {}
        cur = self._group.executor.frontier_stats
        delta = self._stats_delta(cur, self._frontier_mark or {})
        self._frontier_mark = cur
        return delta

    @property
    def queries(self) -> Dict[str, object]:
        """name -> engine handling it (the batched group for dense queries)."""
        self._ensure_group()
        out: Dict[str, object] = dict(self._ref_engines)
        for name in self._dense_specs:
            out[name] = self._group
        return out

    def register(
        self,
        name: str,
        expr: str,
        engine: str = "dense",            # dense | reference
        path_semantics: str = "arbitrary",  # arbitrary | simple
        n_slots: int = 256,
        batch_size: int = 1,
        backend: str = "jnp",
    ) -> Set[Tuple]:
        """Register a persistent query; works before AND after ingestion has
        started. A dense registration into a live group re-pads device state
        in place and seeds the query over the retained graph; its INITIAL
        result pairs (valid over the current window) are returned — for all
        other paths the returned set is empty.

        Caveat: the FIRST dense query registered after ingestion has started
        cannot be seeded (no dense group retained the graph; prefix content
        seen only by reference engines is not recoverable) — its group is
        materialized empty at registration and answers from this point of
        the stream on."""
        if name in self.stats and (name in self._dense_specs
                                   or name in self._ref_engines):
            raise ValueError(f"query {name!r} already registered")
        if engine == "dense":
            # validate NOW, with the known-backend list ("palas" used to run
            # the jnp oracle without a whisper); resolving also interns
            # string names so the group's backend set dedupes by identity
            backend = resolve_backend(backend)
        dfa = compile_query(expr)
        initial: Set[Tuple] = set()
        if engine == "dense":
            if self._group is not None and self._ingest_started:
                # LIVE registration: the group's device state is re-padded
                # in place; capacity kwargs (n_slots, batch_size, backend)
                # all adopt the group's existing values
                initial = self._group.register_query(
                    RegisteredQuery(name, dfa, self.window, path_semantics)
                )
                self._dense_specs[name] = dict(
                    dfa=dfa, path_semantics=path_semantics,
                    n_slots=self._group.n_slots,
                    batch_size=self._group.batch_size,
                    backend=self._group.backend,
                )
            else:
                self._dense_specs[name] = dict(
                    dfa=dfa, path_semantics=path_semantics, n_slots=n_slots,
                    batch_size=batch_size, backend=backend,
                )
                self._group = None  # rebuilt (empty) at next ingest/snapshot
                if self._ingest_started:
                    # FIRST dense query arriving mid-stream: no dense group
                    # retained the graph, so there is nothing to seed from —
                    # materialize the (empty) group NOW so the query starts
                    # tracking the stream from this point on, rather than
                    # silently deferring to the next ingest. Queries joining
                    # an EXISTING group are seeded over the retained window
                    # (the branch above); prefix content seen only by
                    # reference engines is not recoverable.
                    self._ensure_group()
        elif path_semantics == "simple":
            self._ref_engines[name] = RSPQ(dfa, self.window)
        else:
            self._ref_engines[name] = RAPQ(dfa, self.window)
        if name not in self.stats:  # a reused name keeps its history
            self.stats[name] = QueryStats(latencies_us=[])
        return initial

    def deregister(self, name: str) -> None:
        """Retire a persistent query mid-stream. Dense: the group lane
        becomes inert padding (reclaimed by the next registration); the
        remaining queries' result streams are unaffected. The stats entry is
        kept as history."""
        if name in self._dense_specs:
            del self._dense_specs[name]
            if self._group is not None:
                if self._ingest_started:
                    self._group.deregister_query(name)
                else:
                    self._group = None  # rebuilt without it at next ingest
        elif name in self._ref_engines:
            del self._ref_engines[name]
        else:
            raise KeyError(f"no registered query named {name!r}")

    def _ensure_group(self) -> None:
        if self._group is not None or not self._dense_specs:
            return
        backends = {s["backend"] for s in self._dense_specs.values()}
        if len(backends) > 1:
            raise ValueError(f"dense queries must share one backend, got {backends}")
        backend = backends.pop()
        specs = [
            RegisteredQuery(name, s["dfa"], self.window, s["path_semantics"])
            for name, s in self._dense_specs.items()
        ]
        self._group = BatchedDenseRPQEngine(
            specs,
            n_slots=max(s["n_slots"] for s in self._dense_specs.values()),
            # exactness dominates: the smallest requested micro-batch bounds
            # the group's batch-boundary skew for every member query
            batch_size=min(s["batch_size"] for s in self._dense_specs.values()),
            backend=backend,
            executor=self._make_executor(backend),
        )

    def _maybe_fallback(self, fallbacks: Dict[str, str], resolve_cb) -> None:
        """Route conflicted simple-path dense lanes to the exact reference
        RSPQ engine (seeded from the retained graph); record the switch."""
        if not self._rspq_fallback or self._group is None:
            return
        for qi, spec in list(self._group.live_items()):
            if spec.path_semantics != "simple":
                continue
            if not self._group.per_query_conflicted[qi]:
                continue
            resolve_cb()  # settle deferred decodes before mutating lanes
            name = spec.name
            fb = RSPQFallback(spec.dfa, spec.window,
                              emitted=self._group.per_query_results[qi])
            fb.seed(self._group.retained_edges(), self._group._host_now)
            self._group.deregister_query(name)
            del self._dense_specs[name]
            self._ref_engines[name] = fb
            fallbacks[name] = "conflict -> reference RSPQ"
            if name in self.stats:
                self.stats[name].conflicted = True

    def ingest(self, stream, record_latency: bool = False) -> IngestReport:
        """Feed the whole stream; returns an :class:`IngestReport`: the new
        result pairs per query (dict interface), with the pairs invalidated
        by explicit deletions alongside in ``.invalidated`` and any
        dense→RSPQ switches in ``.fallbacks``.

        With ``adaptive_batch=True`` (opt-in) dense inserts are buffered
        into micro-batches whose size the service steers from the
        executor's skip counters: at each slide boundary it reads the
        interval's ``query_rounds_total`` vs ``unmasked_query_rounds_total``
        delta — a large no-op relaxation tail means most of each dispatch's
        work is already-converged lanes riding along, so per-event dispatch
        overhead dominates useful work and the micro-batch DOUBLES (up to
        ``max_batch``); a small tail means the lanes genuinely relax every
        round and the batch HALVES back toward the exact per-tuple regime
        (B is always a power-of-two multiple of 1, so the bucketed jit
        cache sees few distinct shapes). Decisions land in
        :attr:`batch_size_log`; B > 1 carries the engine's documented
        batch-boundary skew, which is why this is never on by default.
        """
        self._ensure_group()
        self._ingest_started = True
        new_results: Dict[str, Set[Tuple]] = {name: set() for name in self.stats}
        invalidated: Dict[str, Set[Tuple]] = {name: set() for name in self.stats}
        fallbacks: Dict[str, str] = {}
        # reading frontier_stats flushes the executor's queued counters —
        # but the PREVIOUS call's end-of-ingest read already drained them,
        # so this start-of-call snapshot is amortized-free (it only pays
        # when the engine was driven directly between service calls); the
        # per-call cost is bounded by flushing this call's own dispatches,
        # which reporting per-call stats requires anyway
        call_mark: Dict[str, object] = (
            dict(self._group.executor.frontier_stats)
            if self._group is not None and self._frontier != "off" else {})
        # bounded FIFO (async_depth) — deque so the drain below is O(1)
        # per handle instead of list.pop(0)'s O(n) shift
        pending: Deque[PendingResults] = collections.deque()
        dense_buf: List = []               # adaptive micro-batch buffer
        del_buf: List = []                 # negative-tuple micro-batch buffer
        deletions = [0]                    # negative tuples seen by the group

        def resolve_pending(limit: int = 0) -> None:
            """Resolve outstanding decode handles down to `limit` (dispatch
            order; each handle snapshotted the interner at dispatch)."""
            while len(pending) > limit:
                fresh = pending.popleft().resolve()
                for qi, spec in self._group.live_items():
                    new_results[spec.name] |= fresh[qi]

        def flush_dense() -> None:
            """Dispatch the buffered dense inserts as one micro-batch."""
            if not dense_buf:
                return
            batch = [(s.src, s.dst, s.label, s.ts) for s in dense_buf]
            t0 = time.perf_counter_ns() if record_latency else 0
            handle = self._group.insert_batch_pending(batch)
            pending.append(handle)
            # pull results down to the in-flight budget: depth k means the
            # device->host transfer of dispatch i overlaps dispatches
            # i+1..i+k instead of blocking the hot path
            resolve_pending(self._async_depth if self._async_decode else 0)
            dt = (time.perf_counter_ns() - t0) / 1e3 if record_latency else 0.0
            for qi, spec in self._group.live_items():
                st = self.stats[spec.name]
                st.tuples += len(batch)
                if record_latency:
                    # one dispatch serves the whole group; each member
                    # observes the group's step latency (dispatch-only
                    # under async_decode), amortized over the micro-batch
                    st.latencies_us.extend([dt / len(batch)] * len(batch))
            dense_buf.clear()
            self._maybe_fallback(fallbacks, lambda: resolve_pending(0))

        def flush_deletes() -> None:
            """Dispatch the buffered negative tuples as one micro-batch
            through the engine's chunked delete path (frontier cone per
            chunk when the frontier is on). Only one of dense_buf/del_buf
            is ever non-empty — the event loop flushes the other before
            buffering — so stream order is preserved."""
            if not del_buf:
                return
            resolve_pending()
            batch = [(s.src, s.dst, s.label, s.ts) for s in del_buf]
            t0 = time.perf_counter_ns() if record_latency else 0
            inv = self._group.delete_batch(batch)
            dt = (time.perf_counter_ns() - t0) / 1e3 if record_latency else 0.0
            for qi, spec in self._group.live_items():
                st = self.stats[spec.name]
                st.tuples += len(batch)
                invalidated[spec.name] |= inv[qi]
                if record_latency:
                    st.latencies_us.extend([dt / len(batch)] * len(batch))
            deletions[0] += len(batch)
            del_buf.clear()
            self._maybe_fallback(fallbacks, lambda: resolve_pending(0))

        def mark_interval() -> Dict[str, object]:
            """Per-interval frontier telemetry: append the delta since the
            last slide boundary to :attr:`frontier_log` and hand it to the
            batch steering below."""
            delta = self._frontier_delta()
            seen = max((self.stats[s.name].tuples
                        for _qi, s in self._group.live_items()),
                       default=0) if self._group is not None else 0
            if delta:
                self.frontier_log.append((seen, delta))
            if (self._group is not None
                    and self._group.executor.adj_layout == "ell"):
                self.adjacency_log.append(
                    (seen, self._group.executor.adjacency_stats))
            if (self._group is not None
                    and self._group.executor.dist_layout == "row_sparse"):
                self.dist_log.append(
                    (seen, self._group.executor.dist_stats))
            return delta

        def adapt_batch(finterval: Dict[str, object]) -> None:
            """Steer the dense micro-batch size from the interval's no-op
            relaxation tail AND the frontier telemetry (see docstring)."""
            if not self._adaptive_batch or self._group is None:
                return
            ex = self._group.executor
            qr, uqr = ex.query_rounds_total, ex.unmasked_query_rounds_total
            if self._adapt_marks is not None:
                dqr = qr - self._adapt_marks[0]
                duqr = uqr - self._adapt_marks[1]
                if duqr > 0:
                    noop_frac = 1.0 - dqr / duqr
                    b = self._group.batch_size
                    # the no-op tail argues for a bigger B (dispatch
                    # overhead dominates useful work) — but when the
                    # frontier is live and healthy (tiny row occupancy, no
                    # overflow pressure) each dispatch is ALREADY cheap in
                    # proportion to its dirty rows, so growing B would
                    # trade exactness (batch-boundary skew) for little:
                    # hold B instead
                    frontier_healthy = self._frontier_healthy(finterval)
                    if noop_frac >= 0.3 and b < self._max_batch \
                            and not frontier_healthy:
                        b *= 2
                    elif noop_frac < 0.1 and b > 1:
                        b //= 2
                    if b != self._group.batch_size:
                        self._group.batch_size = b
                        seen = max((self.stats[s.name].tuples
                                    for _qi, s in self._group.live_items()),
                                   default=0)
                        self.batch_size_log.append((seen, b))
            self._adapt_marks = (qr, uqr)

        for sgt in stream:
            # lazy expiration at slide boundaries (eager evaluation)
            if sgt.ts >= self._next_expiry:
                flush_dense()
                flush_deletes()
                resolve_pending()
                if self._group is not None:
                    self._group.expire(sgt.ts)
                for eng in self._ref_engines.values():
                    eng.expire(sgt.ts)
                while self._next_expiry <= sgt.ts:
                    self._next_expiry += self.slide
                adapt_batch(mark_interval())
            # snapshot BEFORE the dense step: a fallback fired by this very
            # event must not re-feed the event to its new reference engine
            refs_this_event = list(self._ref_engines.items())
            if self._group is not None:
                if sgt.op == "+":
                    flush_deletes()
                    dense_buf.append(sgt)
                    if (not self._adaptive_batch
                            or len(dense_buf) >= self._group.batch_size):
                        flush_dense()
                else:
                    flush_dense()
                    del_buf.append(sgt)
                    if (not self._adaptive_batch
                            or len(del_buf) >= self._group.batch_size):
                        flush_deletes()
            for name, eng in refs_this_event:
                t0 = time.perf_counter_ns() if record_latency else 0
                if sgt.op == "+":
                    res = eng.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
                    new_results[name] |= res
                else:
                    inv = eng.delete(sgt.src, sgt.dst, sgt.label, sgt.ts)
                    if inv:
                        invalidated[name] |= set(inv)
                st = self.stats[name]
                st.tuples += 1
                if record_latency:
                    st.latencies_us.append((time.perf_counter_ns() - t0) / 1e3)
        flush_dense()
        flush_deletes()
        resolve_pending()
        for name in self.stats:
            st = self.stats[name]
            if name in self._dense_specs or name in self._ref_engines:
                st.results = len(self.results(name))
                st.conflicted = st.conflicted or self._conflicted(name)
            if st.latencies_us:
                lat = sorted(st.latencies_us)
                st.p99_us = lat[min(int(0.99 * len(lat)), len(lat) - 1)]
        fstats: Dict[str, object] = {}
        if call_mark and self._group is not None:
            fstats = self._stats_delta(
                self._group.executor.frontier_stats, call_mark)
        return IngestReport(new_results, invalidated, fallbacks, fstats,
                            deletions=deletions[0])

    def results(self, name: str) -> Set[Tuple]:
        if name in self._dense_specs:
            self._ensure_group()
            return set(self._group.per_query_results[self._group.lane_of(name)])
        return set(self._ref_engines[name].results)

    def _conflicted(self, name: str) -> bool:
        if name in self._dense_specs and self._group is not None:
            return bool(self._group.per_query_conflicted[self._group.lane_of(name)])
        eng = self._ref_engines.get(name)
        return bool(getattr(eng, "conflicts_detected", 0)) if eng else False

    # -- state persistence ----------------------------------------------------

    def snapshot(self, directory: str, step: int, *,
                 wal_lsn: Optional[int] = None,
                 extra_meta: Optional[Dict[str, object]] = None,
                 async_save: bool = False,
                 _crash_after: Optional[str] = None) -> None:
        """Checkpoint the whole service. ``wal_lsn`` records the
        write-ahead-log position this snapshot covers (the supervisor's
        recovery replays only records past it); ``async_save=True`` defers
        the file IO to a background thread (``ckpt.async_save`` — the
        device→host transfer still happens here, so the state is
        consistent no matter what the stream does next); ``_crash_after``
        is the chaos harness's mid-save kill switch (ckpt.save stages).

        The dense group's deferred-decode FIFO is drained FIRST: an
        in-flight async-decode batch (``async_depth>1``) has already
        mutated device state, so saving before its results land in
        ``per_query_results`` would snapshot an emitted mask ahead of the
        recorded results — restore + replay would then drop those pairs
        (the device diff thinks they were already reported). Draining
        makes snapshot a sequence point: state and results agree."""
        from ..checkpoint import ckpt

        self._ensure_group()
        if self._group is not None:
            # belt-and-braces with engine.state_arrays()/results_state()
            # (each drains too): ONE sequence point, visible at the
            # service boundary, regression-pinned in tests/test_fault.py
            self._group._drain_pending()
        state: Dict[str, object] = {}
        extra: Dict[str, object] = {
            "step": step,
            "next_expiry": self._next_expiry,
            "reference": sorted(self._ref_engines),
        }
        if wal_lsn is not None:
            extra["wal_lsn"] = int(wal_lsn)
        if extra_meta:
            # caller metadata (e.g. the supervisor's churn catalog) rides
            # the manifest; reserved keys stay ours
            for k, v in extra_meta.items():
                extra.setdefault(k, v)
        if self._group is not None:
            state["dense_group"] = self._group.state_arrays()
            extra["dense"] = {
                # the LIVE query set, lane by lane (None = inert padding):
                # restore matches lanes by name, so the restoring group may
                # have a different bucketed-Q layout (or executor shard
                # quantum)
                "order": [s.name if s is not None else None
                          for s in self._group.lane_specs],
                "labels": list(self._group.labels),
                "interner": self._group.interner_state(),
                # learned capacity occupancy (all ×2-bucketed): a restored
                # service starts at these instead of re-learning them from
                # overflow pressure — frontier_cap from "auto" growth,
                # dist_cap from row-sparse drains, ell_cap from adjacency
                # packs; harmless no-ops for layouts/modes that are off
                "capacities": {
                    "frontier_cap": int(self._group.executor.frontier_cap),
                    "ell_cap": int(self._group.executor.ell_cap),
                    "dist_cap": int(self._group.executor.dist_cap),
                    "dist_ovf_cap": (
                        int(self._group.executor.dist_ovf_cap)
                        if self._group.executor.dist_ovf_cap is not None
                        else None),
                },
                **self._group.results_state(),
            }
        for name, eng in self._ref_engines.items():
            state[f"refeng.{name}"] = ckpt.pickle_leaf(eng)
        if async_save:
            ckpt.async_save(directory, step, state, extra=extra,
                            _crash_after=_crash_after)
        else:
            ckpt.save(directory, step, state, extra=extra,
                      _crash_after=_crash_after)

    def restore(self, directory: str) -> int:
        from ..checkpoint import ckpt

        self._ensure_group()
        like: Dict[str, object] = {}
        if self._group is not None:
            like["dense_group"] = self._group.state_arrays()
        for name in self._ref_engines:
            like[f"refeng.{name}"] = ckpt.pickle_like()
        state, extra = ckpt.restore(directory, like=like)
        if self._group is not None:
            meta = extra["dense"]
            # adopt the snapshot's LEARNED capacities first (never shrink —
            # max with our own), so the re-placement below packs at the
            # occupancy the crashed service had already learned instead of
            # re-discovering it through overflow pressure
            caps = meta.get("capacities", {})
            ex = self._group.executor
            # saved caps are already ×2-bucketed; _next_pow2 is identity on
            # them and keeps manifest tampering from un-bucketing the jits
            if caps.get("frontier_cap"):
                ex.frontier_cap = max(
                    ex.frontier_cap, _next_pow2(int(caps["frontier_cap"])))
            if caps.get("ell_cap"):
                ex.ell_cap = max(ex.ell_cap, _next_pow2(int(caps["ell_cap"])))
            if caps.get("dist_cap"):
                ex.dist_cap = max(ex.dist_cap,
                                  _next_pow2(int(caps["dist_cap"])))
            if caps.get("dist_ovf_cap"):
                prev = ex.dist_ovf_cap if ex.dist_ovf_cap is not None else 1
                ex.dist_ovf_cap = max(
                    prev, _next_pow2(int(caps["dist_ovf_cap"])))
            # lane-by-name adoption: tolerant of bucketed-Q/K/label/slot
            # padding differences AND executor changes (mesh <-> local);
            # raises if the LIVE query sets differ
            self._group.adopt_state(
                state["dense_group"],
                meta["order"],
                meta.get("labels", list(self._group.labels)),
            )
            self._group.load_interner(meta["interner"])
            self._group.load_results_state(meta)
        for name in self._ref_engines:
            self._ref_engines[name] = ckpt.unpickle_leaf(state[f"refeng.{name}"])
        self._next_expiry = float(extra.get("next_expiry", self.slide))
        self._ingest_started = True
        return int(extra["step"])
