"""Persistent-query service: the end-to-end serving driver.

Register RPQs (with per-query engine choice + path semantics), ingest an
ordered sgt stream with eager evaluation and lazy expiration (slide
interval β), and emit an append-only result stream per query — exactly the
paper's execution model (§2, §5.1).

Fault tolerance: the service checkpoints engine state (dense engines are
pytrees + a python interner) via checkpoint/ckpt.py and can re-attach after
a crash (tested in tests/test_fault.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Set, Tuple

from ..core.automaton import compile_query
from ..core.engine import DenseRPQEngine
from ..core.reference import RAPQ, RSPQ
from .stream import SGT, Stream


@dataclasses.dataclass
class QueryStats:
    tuples: int = 0
    results: int = 0
    conflicted: bool = False
    wall_s: float = 0.0
    p99_us: float = 0.0
    latencies_us: Optional[List[float]] = None


class PersistentQueryService:
    def __init__(self, window: float, slide: float):
        self.window = float(window)
        self.slide = float(slide)
        self.queries: Dict[str, object] = {}
        self.stats: Dict[str, QueryStats] = {}
        self._next_expiry = slide

    def register(
        self,
        name: str,
        expr: str,
        engine: str = "dense",            # dense | reference
        path_semantics: str = "arbitrary",  # arbitrary | simple
        n_slots: int = 256,
        batch_size: int = 1,
        backend: str = "jnp",
    ) -> None:
        dfa = compile_query(expr)
        if engine == "dense":
            eng = DenseRPQEngine(dfa, self.window, n_slots=n_slots,
                                 batch_size=batch_size, backend=backend,
                                 path_semantics=path_semantics)
        elif path_semantics == "simple":
            eng = RSPQ(dfa, self.window)
        else:
            eng = RAPQ(dfa, self.window)
        self.queries[name] = eng
        self.stats[name] = QueryStats(latencies_us=[])

    def ingest(self, stream: Stream, record_latency: bool = False) -> Dict[str, Set[Tuple]]:
        """Feed the whole stream; returns new result pairs per query."""
        new_results: Dict[str, Set[Tuple]] = {name: set() for name in self.queries}
        for sgt in stream:
            # lazy expiration at slide boundaries (eager evaluation)
            if sgt.ts >= self._next_expiry:
                for eng in self.queries.values():
                    eng.expire(sgt.ts)
                while self._next_expiry <= sgt.ts:
                    self._next_expiry += self.slide
            for name, eng in self.queries.items():
                t0 = time.perf_counter_ns() if record_latency else 0
                if sgt.op == "+":
                    res = eng.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
                    new_results[name] |= res
                else:
                    eng.delete(sgt.src, sgt.dst, sgt.label, sgt.ts)
                st = self.stats[name]
                st.tuples += 1
                if record_latency:
                    st.latencies_us.append((time.perf_counter_ns() - t0) / 1e3)
        for name, eng in self.queries.items():
            st = self.stats[name]
            st.results = len(eng.results)
            st.conflicted = bool(getattr(eng, "conflicted", False))
            if st.latencies_us:
                lat = sorted(st.latencies_us)
                st.p99_us = lat[min(int(0.99 * len(lat)), len(lat) - 1)]
        return new_results

    def results(self, name: str) -> Set[Tuple]:
        return set(self.queries[name].results)

    # -- state persistence ----------------------------------------------------

    def snapshot(self, directory: str, step: int) -> None:
        from ..checkpoint import ckpt

        state = {}
        extra = {"step": step, "queries": {}}
        for name, eng in self.queries.items():
            if isinstance(eng, DenseRPQEngine):
                state[name] = {
                    "adj": eng.arrays.adj, "dist": eng.arrays.dist,
                    "emitted": eng.arrays.emitted, "now": eng.arrays.now,
                }
                extra["queries"][name] = {
                    "slot_of": {str(k): v for k, v in eng.slot_of.items()},
                    "results": sorted(map(list, eng.results)),
                }
        ckpt.save(directory, step, state, extra=extra)

    def restore(self, directory: str) -> int:
        from ..checkpoint import ckpt
        from ..core.engine import EngineArrays

        like = {}
        for name, eng in self.queries.items():
            if isinstance(eng, DenseRPQEngine):
                like[name] = {
                    "adj": eng.arrays.adj, "dist": eng.arrays.dist,
                    "emitted": eng.arrays.emitted, "now": eng.arrays.now,
                }
        state, extra = ckpt.restore(directory, like=like)
        for name, eng in self.queries.items():
            if isinstance(eng, DenseRPQEngine):
                s = state[name]
                eng.arrays = EngineArrays(s["adj"], s["dist"], s["emitted"], s["now"])
                q = extra["queries"][name]
                # interner: vertex ids serialize as strings in the manifest
                eng.slot_of = {_maybe_int(k): v for k, v in q["slot_of"].items()}
                eng.vertex_of = [None] * eng.n_slots
                for vtx, slot in eng.slot_of.items():
                    eng.vertex_of[slot] = vtx
                used = set(eng.slot_of.values())
                eng.free = [s for s in range(eng.n_slots - 1, -1, -1) if s not in used]
                eng.results = {tuple(p) for p in q["results"]}
        return int(extra["step"])


def _maybe_int(s: str):
    try:
        return int(s)
    except ValueError:
        return s
