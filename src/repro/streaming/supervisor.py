"""Supervised streaming service: WAL-backed crash recovery, deterministic
fault injection, backpressure, and graceful degradation.

:class:`ServiceSupervisor` wraps a :class:`~repro.streaming.service.\
PersistentQueryService` with the machinery that turns "fast on gmark" into
"survivable under production traffic":

* **Write-ahead log + exact replay** — every micro-batch is appended to a
  :class:`~repro.streaming.wal.WriteAheadLog` (fsync'd) BEFORE dispatch;
  periodic async snapshots (``ckpt.async_save`` + the atomic LATEST
  protocol) record the covered WAL position. On ANY crash the supervisor
  rebuilds the service, restores the latest COMMITTED checkpoint, and
  replays the WAL suffix through the normal ingest path — recovery is
  ``O(events since snapshot)``, and because every engine mode is
  bit-identical per event, the reconstructed result stream equals the
  uninterrupted run's exactly (``verify_replay=True`` asserts it inline:
  a replayed batch whose results diverge from what was recorded before
  the crash raises :class:`ReplayDivergence`).

* **Deterministic fault injection** — a seedable :class:`FaultPlan`
  schedules crashes before/after dispatch, mid-snapshot (through
  ``ckpt.save``'s staged ``_crash_after`` kill switch), during replay,
  slow-dispatch stragglers, and transient decode errors with bounded
  retry/backoff. Every fault fires exactly once, so chaos runs are
  reproducible from the seed alone.

* **Backpressure** — arrivals land in a :class:`BoundedIngestQueue` with
  explicit policies: ``"block"`` (the producer stalls while the service
  drains — counted, nothing dropped) or ``"shed-oldest"``/``"shed-newest"``
  (load shedding with exact drop counters; a shed event is GONE — it is
  shed before the WAL, so replay stays consistent with what the engine
  actually saw).

* **Graceful degradation** — a :class:`CircuitBreaker` watches the
  per-interval overflow-drain rate (frontier fallbacks + ELL spill drains
  + row-sparse dist drains). When pressure exceeds the trip threshold the
  supervisor performs a controlled handover onto the dense fallbacks
  (``frontier="off"``, ``adj_layout="dense"``, ``dist_layout="dense"``)
  via sync-snapshot → rebuild → restore (canonical-dense checkpoints make
  this loss-free), and re-arms back to the preferred sparse config after a
  quiet period. Per-interval telemetry rides :attr:`health_log` in the
  same ``*_log`` pattern as the service's frontier/adjacency/dist logs.

The supervisor OWNS the batching: the stream is cut into ``batch_events``
micro-batches that are the WAL's unit of append and replay, so the
recovered run re-groups events exactly like the original did (grouping is
part of the determinism contract — B > 1 batch-boundary skew is identical
when the batches are identical).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import random
import time
from typing import Callable, Deque, Dict, Iterable, List, Optional, Set, Tuple

from ..checkpoint import ckpt
from ..checkpoint.ckpt import SimulatedCrash
from .stream import SGT
from .wal import WALRecord, WriteAheadLog

QUEUE_POLICIES = ("block", "shed-oldest", "shed-newest")

#: the degradation ladder's bottom rung: every layout pinned to its dense
#: fallback — no overflow surface left to drain
DENSE_FALLBACK_OVERRIDES = {
    "frontier": "off",
    "adj_layout": "dense",
    "dist_layout": "dense",
}


class InjectedCrash(RuntimeError):
    """A FaultPlan-scheduled crash (the in-process stand-in for SIGKILL)."""


class TransientDecodeError(RuntimeError):
    """A FaultPlan-scheduled transient failure: retryable, not a crash."""


class ReplayDivergence(AssertionError):
    """WAL replay produced different results than the pre-crash run
    recorded for the same lsn — the replay-identity contract is broken."""


class FaultPlan:
    """Deterministic, seedable fault schedule. Keys are the WAL lsn of the
    batch (dispatch faults) or the snapshot ordinal (mid-snapshot faults);
    every scheduled fault fires EXACTLY ONCE — the retried/replayed
    occurrence of the same lsn proceeds — so a chaos run always
    terminates and is reproducible from the constructor arguments.

    ``crash_mid_snapshot`` maps snapshot ordinal → a ``ckpt.save`` stage
    (``"shards" | "manifest" | "rename"``), covering a kill at every point
    of the commit protocol.
    """

    def __init__(self,
                 crash_before_dispatch: Iterable[int] = (),
                 crash_after_dispatch: Iterable[int] = (),
                 crash_during_replay: Iterable[int] = (),
                 crash_mid_snapshot: Optional[Dict[int, str]] = None,
                 slow_dispatch: Optional[Dict[int, float]] = None,
                 transient_errors: Optional[Dict[int, int]] = None):
        self._before = set(int(x) for x in crash_before_dispatch)
        self._after = set(int(x) for x in crash_after_dispatch)
        self._replay = set(int(x) for x in crash_during_replay)
        self._mid_snapshot = dict(crash_mid_snapshot or {})
        self._slow = dict(slow_dispatch or {})
        self._transient = dict(transient_errors or {})
        for stage in self._mid_snapshot.values():
            if stage not in ("shards", "manifest", "rename"):
                raise ValueError(f"unknown ckpt crash stage {stage!r}")

    @classmethod
    def chaos(cls, seed: int, n_batches: int,
              crash_rate: float = 0.05,
              straggler_rate: float = 0.05,
              straggler_s: float = 0.002,
              transient_rate: float = 0.05,
              snapshot_crash_every: int = 0) -> "FaultPlan":
        """A reproducible mixed plan over ``n_batches`` lsns: crashes split
        between before/after/replay hooks, stragglers, and transient
        errors, all drawn from one seeded RNG."""
        rng = random.Random(seed)
        before, after, replay = set(), set(), set()
        slow: Dict[int, float] = {}
        transient: Dict[int, int] = {}
        for lsn in range(1, n_batches + 1):
            r = rng.random()
            if r < crash_rate:
                rng.choice((before, after, replay)).add(lsn)
            elif r < crash_rate + straggler_rate:
                slow[lsn] = straggler_s * (1 + rng.random())
            elif r < crash_rate + straggler_rate + transient_rate:
                transient[lsn] = rng.randint(1, 2)
        mid: Dict[int, str] = {}
        if snapshot_crash_every:
            for i, stage in enumerate(("shards", "manifest", "rename")):
                mid[(i + 1) * snapshot_crash_every] = stage
        return cls(before, after, replay, mid, slow, transient)

    # -- fire-once hooks ------------------------------------------------------

    def take_crash(self, hook: str, key: int) -> bool:
        pool = {"before_dispatch": self._before,
                "after_dispatch": self._after,
                "during_replay": self._replay}[hook]
        if key in pool:
            pool.discard(key)
            return True
        return False

    def take_snapshot_crash(self, ordinal: int) -> Optional[str]:
        return self._mid_snapshot.pop(ordinal, None)

    def take_sleep(self, lsn: int) -> float:
        return self._slow.pop(lsn, 0.0)

    def take_transient(self, lsn: int) -> bool:
        left = self._transient.get(lsn, 0)
        if left > 0:
            self._transient[lsn] = left - 1
            return True
        return False

    @property
    def exhausted(self) -> bool:
        return not (self._before or self._after or self._replay
                    or self._mid_snapshot or self._slow
                    or any(self._transient.values()))


class BoundedIngestQueue:
    """Bounded arrival buffer with explicit overload policies.

    ``push`` returns True when the event was accepted. Under ``"block"``
    a full queue REFUSES the event (the caller must drain and re-offer —
    the producer stalls; :attr:`blocked` counts the stalls). Under
    ``"shed-oldest"`` the oldest queued event is dropped to make room;
    under ``"shed-newest"`` the arriving event itself is dropped. All
    drops are counted in :attr:`shed` — load shedding is explicit and
    observable, never silent."""

    def __init__(self, cap: int, policy: str = "block"):
        if cap < 1:
            raise ValueError(f"queue cap must be >= 1, got {cap}")
        if policy not in QUEUE_POLICIES:
            raise ValueError(
                f"unknown queue policy {policy!r} "
                f"({' | '.join(QUEUE_POLICIES)})")
        self.cap = int(cap)
        self.policy = policy
        self._q: Deque[SGT] = collections.deque()
        self.shed = 0
        self.blocked = 0
        self.accepted = 0
        self.high_water = 0

    def __len__(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        return len(self._q) >= self.cap

    def push(self, evt: SGT) -> bool:
        if self.full:
            if self.policy == "block":
                self.blocked += 1
                return False
            if self.policy == "shed-oldest":
                self._q.popleft()
                self.shed += 1
            else:  # shed-newest: the arrival itself is dropped
                self.shed += 1
                return True
        self._q.append(evt)
        self.accepted += 1
        self.high_water = max(self.high_water, len(self._q))
        return True

    def take(self, n: int) -> List[SGT]:
        out: List[SGT] = []
        while self._q and len(out) < n:
            out.append(self._q.popleft())
        return out


class CircuitBreaker:
    """Trip-to-dense / re-arm-after-quiet controller over overflow-drain
    pressure. ``observe(overflow_events, dispatches)`` is called once per
    health interval and returns the action to take: ``"trip"`` (pressure
    rate exceeded ``trip_threshold`` while armed), ``"rearm"``
    (``rearm_after`` consecutive quiet intervals while tripped), or None.
    Transitions land in :attr:`log` as ``(interval_idx, action, rate)``."""

    def __init__(self, trip_threshold: float = 0.25,
                 rearm_threshold: float = 0.0,
                 rearm_after: int = 3):
        self.trip_threshold = float(trip_threshold)
        self.rearm_threshold = float(rearm_threshold)
        self.rearm_after = int(rearm_after)
        self.tripped = False
        self._quiet = 0
        self._interval = 0
        self.log: List[Tuple[int, str, float]] = []

    def observe(self, overflow_events: int, dispatches: int) -> Optional[str]:
        self._interval += 1
        rate = overflow_events / max(dispatches, 1)
        if not self.tripped:
            if rate > self.trip_threshold:
                self.tripped = True
                self._quiet = 0
                self.log.append((self._interval, "trip", rate))
                return "trip"
            return None
        if rate <= self.rearm_threshold:
            self._quiet += 1
            if self._quiet >= self.rearm_after:
                self.tripped = False
                self._quiet = 0
                self.log.append((self._interval, "rearm", rate))
                return "rearm"
        else:
            self._quiet = 0
        return None


@dataclasses.dataclass
class Recovery:
    """One crash → restore → replay cycle's measurements."""

    restart: int
    restored_step: Optional[int]
    restored_wal_lsn: int
    replayed_events: int
    replayed_records: int
    recovery_s: float
    replay_eps: float


class ServiceSupervisor:
    """Crash-supervised, WAL-backed driver for a persistent-query service.

    ``make_service`` builds a FRESH, fully registered service; it must
    accept keyword overrides forwarded to
    :class:`~repro.streaming.service.PersistentQueryService` (the circuit
    breaker rebuilds through it with :data:`DENSE_FALLBACK_OVERRIDES`).
    Determinism contract: ``make_service`` must be pure (same overrides →
    an identically configured service with the same registrations), and
    the service must not enable ``adaptive_batch`` when ``verify_replay``
    is on — adaptive sizing regroups micro-batches from counters a
    restored run cannot reproduce, which voids per-event identity (the
    documented B > 1 batch-boundary skew).
    """

    def __init__(self, make_service: Callable[..., object],
                 ckpt_dir: str,
                 wal_dir: Optional[str] = None,
                 *,
                 batch_events: int = 8,
                 ckpt_every: int = 4,
                 health_every: int = 4,
                 max_restarts: int = 16,
                 max_retries: int = 3,
                 backoff_s: float = 0.0,
                 fault_plan: Optional[FaultPlan] = None,
                 monitor: Optional[object] = None,
                 on_straggler: Optional[Callable[[int], None]] = None,
                 queue_cap: int = 4096,
                 queue_policy: str = "block",
                 drain_batches: int = 2,
                 breaker: Optional[CircuitBreaker] = None,
                 degraded_overrides: Optional[Dict[str, object]] = None,
                 verify_replay: bool = True,
                 segment_records: int = 64):
        from ..distributed.fault import StragglerMonitor

        self.make_service = make_service
        self.ckpt_dir = ckpt_dir
        self.wal = WriteAheadLog(wal_dir or f"{ckpt_dir}/wal",
                                 segment_records=segment_records)
        self.batch_events = max(1, int(batch_events))
        self.ckpt_every = max(1, int(ckpt_every))
        self.health_every = max(1, int(health_every))
        self.max_restarts = int(max_restarts)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.plan = fault_plan
        self.monitor = monitor if monitor is not None else StragglerMonitor()
        self.on_straggler = on_straggler
        self.queue = BoundedIngestQueue(queue_cap, queue_policy)
        self.drain_batches = max(1, int(drain_batches))
        self.breaker = breaker
        self._degraded = dict(degraded_overrides or DENSE_FALLBACK_OVERRIDES)
        self.verify_replay = bool(verify_replay)

        #: per-lsn NEW results / invalidations — the durable result stream
        #: (replay fills gaps and, under verify_replay, re-proves matches)
        self.results_by_lsn: Dict[int, Dict[str, frozenset]] = {}
        self.invalidated_by_lsn: Dict[int, Dict[str, frozenset]] = {}
        #: (lsn, kind, name, meta) query-lifecycle history; persisted into
        #: every checkpoint so recovery can rebuild the exact query set
        #: even after the WAL prefix is truncated
        self.churn_history: List[Tuple[int, str, str, Dict]] = []
        self.health_log: List[Dict[str, object]] = []
        self.recoveries: List[Recovery] = []
        self.restarts = 0
        self.retries = 0
        self.stragglers: List[int] = []
        self.replaying = False

        self._overrides: Dict[str, object] = {}
        self._dispatches = 0
        self._snapshots = 0
        self._health_mark: Dict[str, int] = {}
        self._health_dispatch_mark = 0
        self._stragglers_mark = 0
        self._retries_mark = 0
        self.service = self._fresh_service()

    # -- service lifecycle ----------------------------------------------------

    def _fresh_service(self):
        svc = self.make_service(**self._overrides)
        self._health_mark = {}
        return svc

    def register(self, name: str, expr: str, **kwargs) -> None:
        """WAL-logged live registration (replayable mid-stream churn)."""
        lsn = self.wal.append_churn(
            "register", name, {"expr": expr, "kwargs": kwargs})
        self.churn_history.append(
            (lsn, "register", name, {"expr": expr, "kwargs": kwargs}))
        self.service.register(name, expr, **kwargs)

    def deregister(self, name: str) -> None:
        lsn = self.wal.append_churn("deregister", name)
        self.churn_history.append((lsn, "deregister", name, {}))
        self.service.deregister(name)

    def _apply_churn(self, kind: str, name: str, meta: Dict) -> None:
        if kind == "register":
            self.service.register(name, meta["expr"], **meta.get("kwargs", {}))
        else:
            self.service.deregister(name)

    # -- main loop ------------------------------------------------------------

    def run(self, stream, arrival_chunk: Optional[int] = None
            ) -> Dict[str, Set[Tuple]]:
        """Feed the whole stream under supervision; returns the final
        result sets per query. Arrivals enter in ``arrival_chunk``-sized
        waves (default: exactly the service's drain capacity, so the
        queue never overflows); each tick then drains at most
        ``drain_batches`` micro-batches — an arrival wave larger than
        that models a producer outpacing the service and exercises the
        queue policy."""
        capacity = self.batch_events * self.drain_batches
        chunk = capacity if arrival_chunk is None else max(1, arrival_chunk)
        events = iter(stream)
        exhausted = False
        while not exhausted or len(self.queue):
            wave = list(itertools.islice(events, chunk))
            exhausted = len(wave) < chunk
            for evt in wave:
                while not self.queue.push(evt):
                    # "block": the producer stalls until the service makes
                    # room — drain one batch inline, then re-offer
                    self._drain(1)
            self._drain(self.drain_batches)
        self._drain_all()
        ckpt.wait_pending(self.ckpt_dir)
        return self.results()

    def _drain(self, max_batches: int) -> None:
        for _ in range(max_batches):
            if not len(self.queue):
                return
            batch = self.queue.take(self.batch_events)
            self._process_batch(batch)

    def _drain_all(self) -> None:
        while len(self.queue):
            self._process_batch(self.queue.take(self.batch_events))

    def _process_batch(self, batch: List[SGT]) -> None:
        lsn = self.wal.append(batch)  # durable BEFORE the engine sees it
        try:
            self._dispatch(lsn, batch, replaying=False)
            self._after_dispatch_bookkeeping()
        except (InjectedCrash, SimulatedCrash):
            self._recover()

    def _after_dispatch_bookkeeping(self) -> None:
        self._dispatches += 1
        if self._dispatches % self.ckpt_every == 0:
            self._snapshot()
        if self._dispatches % self.health_every == 0:
            self._flush_health()

    # -- dispatch (fault hooks + bounded retry) -------------------------------

    def _dispatch(self, lsn: int, batch: List[SGT], replaying: bool) -> None:
        plan = self.plan
        hook = "during_replay" if replaying else "before_dispatch"
        if plan is not None:
            if plan.take_crash(hook, lsn):
                raise InjectedCrash(f"{hook} lsn={lsn}")
            delay = plan.take_sleep(lsn)
            if delay > 0:
                time.sleep(delay)  # straggler: observed below as wall time
        attempts = 0
        while True:
            t0 = time.monotonic()
            try:
                if plan is not None and plan.take_transient(lsn):
                    raise TransientDecodeError(f"transient at lsn={lsn}")
                report = self.service.ingest(batch)
                break
            except TransientDecodeError:
                attempts += 1
                self.retries += 1
                if attempts > self.max_retries:
                    raise
                if self.backoff_s > 0:
                    time.sleep(self.backoff_s * (2 ** (attempts - 1)))
        dt = time.monotonic() - t0
        if self.monitor.observe(self._dispatches, dt):
            self.stragglers.append(lsn)
            if self.on_straggler is not None:
                self.on_straggler(lsn)
        new = {name: frozenset(pairs) for name, pairs in report.items()}
        inv = {name: frozenset(pairs)
               for name, pairs in report.invalidated.items()}
        if replaying and self.verify_replay and lsn in self.results_by_lsn:
            if (self.results_by_lsn[lsn] != new
                    or self.invalidated_by_lsn[lsn] != inv):
                raise ReplayDivergence(
                    f"replayed lsn={lsn} diverged from the recorded "
                    f"result stream")
        self.results_by_lsn[lsn] = new
        self.invalidated_by_lsn[lsn] = inv
        if plan is not None and not replaying \
                and plan.take_crash("after_dispatch", lsn):
            raise InjectedCrash(f"after_dispatch lsn={lsn}")

    # -- snapshots ------------------------------------------------------------

    def _snapshot(self) -> None:
        """Async checkpoint at the current WAL position, then truncate the
        WAL below the last COMMITTED snapshot (never the in-flight one —
        a crash before its commit must still find the events it covers)."""
        self._snapshots += 1
        stage = (self.plan.take_snapshot_crash(self._snapshots)
                 if self.plan is not None else None)
        self.service.snapshot(
            self.ckpt_dir, step=self._dispatches,
            wal_lsn=self.wal.last_lsn,
            extra_meta={"churn": [list(c) for c in self.churn_history]},
            async_save=True, _crash_after=stage)
        if stage is not None:
            # the "process" died somewhere inside the save (the background
            # thread left exactly the partial state a kill would)
            raise InjectedCrash(f"mid-snapshot #{self._snapshots} ({stage})")
        committed = self._committed_wal_lsn()
        if committed is not None:
            self.wal.truncate_upto(committed)

    def _committed_wal_lsn(self) -> Optional[int]:
        try:
            extra = ckpt.manifest_extra(self.ckpt_dir)
        except FileNotFoundError:
            return None
        lsn = extra.get("wal_lsn")
        return int(lsn) if lsn is not None else None

    # -- crash recovery -------------------------------------------------------

    def _recover(self) -> None:
        """Restore the latest committed checkpoint and replay the WAL
        suffix; loops until a replay completes without a further injected
        crash (each attempt counts against ``max_restarts``)."""
        while True:
            self.restarts += 1
            if self.restarts > self.max_restarts:
                raise RuntimeError(
                    f"gave up after {self.max_restarts} restarts")
            try:
                self._rebuild_and_replay()
                return
            except (InjectedCrash, SimulatedCrash):
                continue

    def _rebuild_and_replay(self) -> None:
        t0 = time.monotonic()
        # a kill can land with an async save still "in flight" in-process;
        # a real kill would have destroyed the thread — joining here only
        # makes the test double deterministic, it never commits a save the
        # crash staged to abort (SimulatedCrash aborts inside save())
        ckpt.wait_pending(self.ckpt_dir)
        extra = None
        try:
            extra = ckpt.manifest_extra(self.ckpt_dir)
        except FileNotFoundError:
            pass
        self.replaying = True
        try:
            self.service = self._fresh_service()
            restored_step: Optional[int] = None
            ckpt_lsn = 0
            if extra is not None:
                # the checkpointed query set may differ from make_service's
                # base registrations (mid-stream churn): re-apply the
                # churn catalog the snapshot carried BEFORE restoring
                churn = [tuple(c) for c in extra.get("churn", [])]
                for _lsn, kind, name, meta in churn:
                    self._apply_churn(kind, name, dict(meta))
                self.churn_history = [
                    (int(lsn), kind, name, dict(meta))
                    for lsn, kind, name, meta in churn]
                restored_step = self.service.restore(self.ckpt_dir)
                ckpt_lsn = int(extra.get("wal_lsn", 0))
            else:
                self.churn_history = []
            n_events = n_records = 0
            for rec in self.wal.replay(after_lsn=ckpt_lsn):
                n_records += 1
                if rec.kind == "batch":
                    n_events += len(rec.events)
                    self._dispatch(rec.lsn, list(rec.events), replaying=True)
                else:
                    self._apply_churn(rec.kind, rec.meta["name"],
                                      {k: v for k, v in rec.meta.items()
                                       if k != "name"})
                    self.churn_history.append(
                        (rec.lsn, rec.kind, rec.meta["name"],
                         {k: v for k, v in rec.meta.items() if k != "name"}))
        finally:
            self.replaying = False
        dt = time.monotonic() - t0
        self.recoveries.append(Recovery(
            restart=self.restarts, restored_step=restored_step,
            restored_wal_lsn=ckpt_lsn, replayed_events=n_events,
            replayed_records=n_records, recovery_s=dt,
            replay_eps=(n_events / dt) if dt > 0 else float("inf")))

    # -- health / degradation -------------------------------------------------

    def _overflow_counters(self) -> Dict[str, int]:
        """Current cumulative overflow-drain counters of the live service
        (all host-known ints; the stats properties never sync the device
        stream beyond their own documented flush)."""
        svc = self.service
        group = getattr(svc, "_group", None)
        if group is None:
            return {}
        ex = group.executor
        out = {"frontier_fallbacks": int(
            ex.frontier_stats.get("fallbacks", 0))}
        astats = ex.adjacency_stats
        out["adj_spill_drains"] = int(astats.get("spill_drains", 0))
        out["adj_repacks"] = int(astats.get("repacks", 0))
        dstats = ex.dist_stats
        out["dist_drains"] = int(dstats.get("drains", 0))
        out["dist_repacks"] = int(dstats.get("repacks", 0))
        return out

    def _flush_health(self) -> None:
        """Per-interval telemetry flush: overflow-drain deltas, queue
        pressure, stragglers, retries → :attr:`health_log`; feeds the
        circuit breaker and triggers trip/re-arm handovers. This is the
        supervisor's sanctioned counter-flush site (analyzer rule R5)."""
        cur = self._overflow_counters()
        overflow = sum(v - self._health_mark.get(k, 0)
                       for k, v in cur.items())
        self._health_mark = cur
        dispatches = self._dispatches - self._health_dispatch_mark
        self._health_dispatch_mark = self._dispatches
        entry: Dict[str, object] = {
            "dispatches_total": self._dispatches,
            "interval_dispatches": dispatches,
            "wal_lsn": self.wal.last_lsn,
            "queue_depth": len(self.queue),
            "queue_high_water": self.queue.high_water,
            "shed": self.queue.shed,
            "blocked": self.queue.blocked,
            "stragglers": len(self.stragglers) - self._stragglers_mark,
            "retries": self.retries - self._retries_mark,
            "overflow_events": overflow,
            "overflow_rate": overflow / max(dispatches, 1),
            "restarts": self.restarts,
            "degraded": bool(self._overrides),
        }
        self._stragglers_mark = len(self.stragglers)
        self._retries_mark = self.retries
        action = None
        if self.breaker is not None:
            action = self.breaker.observe(overflow, dispatches)
            entry["breaker"] = ("tripped" if self.breaker.tripped
                                else "armed")
        self.health_log.append(entry)
        if action == "trip":
            self._reconfigure(self._degraded)
        elif action == "rearm":
            self._reconfigure({})

    def _reconfigure(self, overrides: Dict[str, object]) -> None:
        """Controlled handover onto a different service configuration:
        sync snapshot at the current WAL position, rebuild with the
        overrides, restore — loss-free (canonical-dense checkpoints
        restore across layouts/executors), and no replay is needed
        because the snapshot is current."""
        self._snapshots += 1
        self.service.snapshot(
            self.ckpt_dir, step=self._dispatches,
            wal_lsn=self.wal.last_lsn,
            extra_meta={"churn": [list(c) for c in self.churn_history]},
            async_save=False)
        self._overrides = dict(overrides)
        self.service = self._fresh_service()
        for _lsn, kind, name, meta in self.churn_history:
            self._apply_churn(kind, name, dict(meta))
        self.service.restore(self.ckpt_dir)
        committed = self._committed_wal_lsn()
        if committed is not None:
            self.wal.truncate_upto(committed)

    # -- reporting ------------------------------------------------------------

    def results(self) -> Dict[str, Set[Tuple]]:
        """Final monotone result sets per query, from the live service."""
        return {name: self.service.results(name)
                for name in self.service.queries}

    def result_stream(self) -> List[Tuple[int, Dict[str, frozenset]]]:
        """The per-batch NEW-result stream in lsn order — the object the
        replay-identity contract is about."""
        return sorted(self.results_by_lsn.items())

    def invalidation_stream(self) -> List[Tuple[int, Dict[str, frozenset]]]:
        return sorted(self.invalidated_by_lsn.items())
