"""Fused Pallas gather for row-sparse dist rows.

Grid ``(M/bm, E/bn)``: each step owns a ``(bm, bn)`` output tile and
the full ``(bm, C)`` slot block of its rows (slot capacity C is small —
it is the pow2 ``dist_cap`` — so the block always fits VMEM).  The
kernel sweeps the C slots with a ``fori_loop``, comparing each slot's
flattened key against the tile's column range and max-folding the hits:
a compare-select per slot on a (bm, bn) vector register, never a
(bm, C, bn) broadcast, so VMEM stays O(bm * (C + bn)) at any capacity.

Every output tile is visited exactly once (no accumulation grid dim),
so no ``pl.when`` init is needed.  Free slots carry ``ts == zero`` and
annihilate under the max; m-padding rows carry key 0 with ``zero``
values, e-padding columns are sliced off — exact by the same argument
as the other semiring kernels (padding is the semiring zero).

Block sizes come from the shared ``pick_block_sizes`` table (rule R3);
the skinny (rows, E) shapes this kernel sees — a handful of gathered
frontier rows against E = N*K columns — are the narrow-m rows PR 9
added to the table.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..maxmin.maxmin import pick_block_sizes

NEG_INF = float("-inf")


def _r8(x: int) -> int:
    return max(x + (-x) % 8, 8)


def _rs_kernel(idx_ref, ts_ref, o_ref, *, bn, c_cap, zero):
    col0 = pl.program_id(1) * bn
    idxb = idx_ref[...]                     # (bm, C)
    tsb = ts_ref[...]
    cols = (lax.broadcasted_iota(jnp.int32, (o_ref.shape[0], bn), 1)
            + col0)                          # (bm, bn) global column ids

    def body(c, acc):
        key = lax.dynamic_slice(idxb, (0, c), (idxb.shape[0], 1))  # (bm, 1)
        val = lax.dynamic_slice(tsb, (0, c), (tsb.shape[0], 1))
        cand = jnp.where(key == cols, val.astype(acc.dtype),
                         jnp.asarray(zero, acc.dtype))
        return jnp.maximum(acc, cand)

    o_ref[...] = lax.fori_loop(
        0, c_cap, body, jnp.full(o_ref.shape, zero, o_ref.dtype))


@functools.partial(jax.jit,
                   static_argnames=("e", "zero", "bm", "bn", "interpret"))
def rowsparse_gather_fused(idx, ts, e: int, *, zero=NEG_INF, bm=None,
                           bn=None, interpret=False):
    """Fused densify of gathered slot rows: idx/ts (M, C) -> (M, E)."""
    m, c_cap = idx.shape
    t_bm, t_bn, _ = pick_block_sizes(m, c_cap, e)
    bm = bm or t_bm
    bn = bn or t_bn
    if interpret:
        bm = min(bm, _r8(m))
        bn = min(bn, _r8(e))

    m_pad = m + (-m) % bm
    e_pad = e + (-e) % bn
    idx_p = jnp.zeros((m_pad, c_cap), jnp.int32).at[:m].set(idx)
    ts_p = jnp.full((m_pad, c_cap), jnp.asarray(zero, ts.dtype),
                    ts.dtype).at[:m].set(ts)

    out = pl.pallas_call(
        functools.partial(_rs_kernel, bn=bn, c_cap=c_cap, zero=zero),
        grid=(m_pad // bm, e_pad // bn),
        in_specs=[
            pl.BlockSpec((bm, c_cap), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, c_cap), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, e_pad), ts.dtype),
        interpret=interpret,
    )(idx_p, ts_p)
    return out[:m, :e]
