"""jnp reference for the row-sparse dist gather.

``out[m, e] = max over slots c with idx[m, c] == e of ts[m, c]`` — the
densify of M gathered row-sparse dist rows (each row a pow2-capacity
set of flattened ``v * K + k`` keys) into the dense (M, E) slab the
frontier round relaxes, where ``E = N * K``.  Free slots carry
``ts == zero`` and their (stale but in-range) ``idx`` is benign: a
zero-valued candidate never wins the max fold.

Pure scatter-max — no reassociation, exact on both the f32 timestamp
lattice and the int32 bucket-level lattice, so every backend can share
this reference (the bucket backend inherits it unchanged).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

NEG_INF = float("-inf")


def rowsparse_gather_ref(idx, ts, e: int, *, zero=NEG_INF,
                         m_chunk: int = 256):
    """Densify gathered slot rows: idx/ts (M, C) -> (M, E).

    The scatter-max runs per m-chunk inside a ``fori_loop`` so the
    scatter working set stays O(chunk * E) while the output accumulates
    in place; the chunk is shrunk to a divisor of M so the loop needs
    no tail (same schedule as the ELL reference's u-chunking).
    """
    m, c = idx.shape
    chunk = min(m_chunk, m)
    while m % chunk:
        chunk //= 2
    out0 = jnp.full((m, e), zero, ts.dtype)

    def body(i, out):
        m0 = i * chunk
        idx_c = lax.dynamic_slice(idx, (m0, 0), (chunk, c))
        ts_c = lax.dynamic_slice(ts, (m0, 0), (chunk, c))
        blk = jnp.full((chunk, e), zero, ts.dtype).at[
            jnp.arange(chunk)[:, None], idx_c].max(ts_c)
        return lax.dynamic_update_slice(out, blk, (m0, 0))

    return lax.fori_loop(0, m // chunk, body, out0)


def rowsparse_gather_naive(idx, ts, e: int, *, zero=NEG_INF):
    """One-hot compare-and-fold oracle; O(M * C * E) scratch, tests only."""
    cand = jnp.where(idx[:, :, None] == jnp.arange(e)[None, None, :],
                     ts[:, :, None], jnp.asarray(zero, ts.dtype))
    return jnp.max(cand, axis=1)
