"""Dispatch layer for the row-sparse dist gather (mirrors
``kernels/ell/ops.py``): jnp chunked reference off-TPU, the fused
Pallas kernel on TPU or under ``interpret=True``."""
from __future__ import annotations

import jax

from .ref import NEG_INF, rowsparse_gather_ref
from .rowsparse import rowsparse_gather_fused


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rowsparse_gather(idx, ts, e: int, *, zero=NEG_INF, use_pallas=None,
                     interpret=None):
    """Densify gathered slot rows: idx/ts (M, C) -> (M, E).

    ``use_pallas=None`` picks the Pallas path on TPU; ``interpret=None``
    interprets off-TPU so the kernel stays testable on CPU CI.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        if interpret is None:
            interpret = not _on_tpu()
        return rowsparse_gather_fused(idx, ts, e, zero=zero,
                                      interpret=interpret)
    return rowsparse_gather_ref(idx, ts, e, zero=zero)
