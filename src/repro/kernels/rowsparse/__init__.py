"""Row-sparse dist gather kernels (PR 9): densify the per-(q, x) slot
sets of a :class:`~repro.core.sparse_dist.RowSparseDist` into the dense
(M, E) row slab the frontier rounds relax."""
from .ops import rowsparse_gather  # noqa: F401
from .ref import rowsparse_gather_naive, rowsparse_gather_ref  # noqa: F401
from .rowsparse import rowsparse_gather_fused  # noqa: F401
