"""Jitted public wrapper for the bottleneck-semiring matmul.

Dispatches between the Pallas TPU kernel and the chunked pure-jnp fallback.
On this CPU host the Pallas path runs with ``interpret=True`` (validation);
on TPU it compiles to a VPU kernel with VMEM tiling.
"""
from __future__ import annotations

import jax

from .maxmin import maxmin_matmul
from .ref import maxmin_matmul_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def maxmin(a, b, *, use_pallas: bool | None = None, interpret: bool | None = None):
    """C[i, j] = max_k min(A[i, k], B[k, j]).

    use_pallas=None -> pallas on TPU, jnp fallback elsewhere.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        if interpret is None:
            interpret = not _on_tpu()
        return maxmin_matmul(a, b, interpret=interpret)
    return maxmin_matmul_ref(a, b)


def maxmin_batched(a, b, **kw):
    return jax.vmap(lambda x, y: maxmin(x, y, **kw))(a, b)
