"""Pallas TPU kernel: (max, min) bottleneck-semiring matmul.

C[i, j] = max_k min(A[i, k], B[k, j])

TPU mapping notes (DESIGN.md §2): the (max, min) semiring has no MXU
contraction, so this runs on the VPU; the kernel's job is the memory
schedule — HBM→VMEM tiling with a k-innermost accumulation grid so each
output tile stays resident in VMEM across k-steps. Block sizes keep the
(bm, bk, bn) broadcast intermediate within VMEM (bm*bk*bn*4B + tiles
≲ 8 MiB of the ~16 MiB/core budget), and bm/bn are 128-aligned for lane
efficiency.

The MXU-friendly alternative (bucketized boolean closure, used by the
engine's ``mxu_bucket`` mode) lives in ``kernels/bucket``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")

# Shape-aware block-size table (PR 5 satellite): rows keyed by the M extent
# of the contraction. The dense round's operands are square-ish (M = N), but
# the frontier-restricted round feeds SKINNY (F, N) slabs — a fixed 128-row
# block would pad a F=16 slab 8x and waste 7/8 of every VPU tile. Small-M
# rows trade bm down and bn up (the broadcast intermediate bm*bk*bn*4B stays
# ≲ 8 MiB of VMEM either way); bn keeps the 128-lane alignment. The M<=4 row
# serves the row-sparse dist gather (PR 9): a Q·F row slab at tiny frontiers
# is a handful of rows against a WIDE N·K entry axis, so bn doubles again —
# the sweep over the entry axis halves its grid steps while bm*bn*4B stays
# a single VMEM tile.
_BLOCK_TABLE = (
    # (max M, (bm, bn, bk))
    (4,    (8, 512, 128)),
    (8,    (8, 256, 128)),
    (16,   (16, 256, 128)),
    (32,   (32, 256, 128)),
    (64,   (64, 128, 128)),
    (None, (128, 128, 64)),
)


def pick_block_sizes(m: int, k: int, n: int):
    """Derive (bm, bn, bk) from the operand shapes (table-driven).

    Blocks clamp to the 8-aligned (m, k) and 128-aligned (n) problem so a
    tiny engine never pays full-tile padding; results are bit-identical for
    ANY block choice (padding is the semiring zero), so this is purely a
    memory-schedule decision — regression-tested against the jnp oracle on
    odd/small shapes in tests/test_kernels.py."""
    def r8(x):
        return max(x + (-x) % 8, 8)

    def r128(x):
        return max(x + (-x) % 128, 128)

    for cap, (bm, bn, bk) in _BLOCK_TABLE:
        if cap is None or m <= cap:
            return (min(bm, r8(m)), min(bn, r128(n)), min(bk, r8(k)))
    raise AssertionError("unreachable: table ends with a None row")


def _maxmin_kernel(a_ref, b_ref, o_ref, *, bk: int):
    """Grid = (m/bm, n/bn, k/bk); k is the innermost (minor) grid dim so the
    o_ref tile is revisited with the same (i, j) while k sweeps."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, NEG_INF)

    a = a_ref[...]  # (bm, bk) VMEM tile
    b = b_ref[...]  # (bk, bn) VMEM tile
    # broadcast-min then max-reduce over k: (bm, bk, bn) stays in VMEM
    c = jnp.max(jnp.minimum(a[:, :, None], b[None, :, :]), axis=1)
    o_ref[...] = jnp.maximum(o_ref[...], c)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def maxmin_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = None,
    bn: int = None,
    bk: int = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """(max, min) matmul via pallas_call. a: (m, k), b: (k, n) -> (m, n).

    Inputs are padded (with -inf, the semiring zero) to block multiples.
    Block sizes default to the shape-aware table (:func:`pick_block_sizes`);
    pass explicit ints to pin them. ``interpret=True`` runs the kernel body
    in Python on CPU (validation path on this host; TPU is the deployment
    target).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    dtype = a.dtype
    abm, abn, abk = pick_block_sizes(m, k, n)
    bm, bn, bk = bm or abm, bn or abn, bk or abk
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        a = jnp.pad(a, ((0, mp), (0, kp)), constant_values=NEG_INF)
    if np_ or kp:
        b = jnp.pad(b, ((0, kp), (0, np_)), constant_values=NEG_INF)
    M, K = a.shape
    _, N = b.shape

    grid = (M // bm, N // bn, K // bk)
    out = pl.pallas_call(
        functools.partial(_maxmin_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), dtype),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def maxmin_matmul_batched(a: jnp.ndarray, b: jnp.ndarray, **kw) -> jnp.ndarray:
    """Batched over a leading J dim (one slice per DFA transition).

    Legacy vmap form: one grid launch PER transition row. The engine's
    batched round uses :func:`maxmin_matmul_fused` instead (all rows share
    one launch); this stays as the conformance oracle for it."""
    return jax.vmap(lambda x, y: maxmin_matmul(x, y, **kw))(a, b)


def _maxmin_fused_kernel(a_ref, b_ref, o_ref):
    """Grid = (J, m/bm, n/bn, k/bk), k innermost (minor): the (1, bm, bn)
    output tile stays VMEM-resident across the k-sweep, and the leading J
    dim walks transition rows WITHIN one launch — row j+1's A/B tiles
    stream HBM→VMEM while row j drains, with no per-row launch/teardown
    (the cost the vmap-of-single-pair form pays J times per round)."""

    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, NEG_INF)

    a = a_ref[0]  # (bm, bk) VMEM tile of row j
    b = b_ref[0]  # (bk, bn)
    c = jnp.max(jnp.minimum(a[:, :, None], b[None, :, :]), axis=1)
    o_ref[0] = jnp.maximum(o_ref[0], c)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def maxmin_matmul_fused(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = None,
    bn: int = None,
    bk: int = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused batched (max, min) matmul: ONE pallas launch for all J rows.

    a: (J, m, k), b: (J, k, n) -> (J, m, n) with out[j] = maxmin(a[j], b[j]).
    This is the engine's batched-round contraction (one row per DFA
    transition): compared with ``vmap(maxmin_matmul)`` the whole round is a
    single grid, so each row's A/B tiles cross HBM→VMEM once per (i, j)
    output tile revisit instead of once per vmap instance, and the VPU sees
    an uninterrupted (J * m/bm * n/bn * k/bk)-step schedule.

    Block sizes default to the shape-aware table (:func:`pick_block_sizes`)
    — the frontier round's skinny (F, N) slabs get a small bm and a wide bn
    instead of 8x row padding. Inputs are padded with -inf (the semiring
    zero) to block multiples. In ``interpret`` mode (CPU validation) blocks
    clamp to the 8-aligned problem so small engines don't pay 128x128
    padding per row.
    """
    j, m, k = a.shape
    j2, k2, n = b.shape
    assert j == j2 and k == k2, (a.shape, b.shape)
    dtype = a.dtype
    abm, abn, abk = pick_block_sizes(m, k, n)
    bm, bn, bk = bm or abm, bn or abn, bk or abk
    if interpret:
        bm = min(bm, m + (-m) % 8)
        bn = min(bn, n + (-n) % 8)
        bk = min(bk, k + (-k) % 8)
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        a = jnp.pad(a, ((0, 0), (0, mp), (0, kp)), constant_values=NEG_INF)
    if np_ or kp:
        b = jnp.pad(b, ((0, 0), (0, kp), (0, np_)), constant_values=NEG_INF)
    _, M, K = a.shape
    _, _, N = b.shape

    grid = (j, M // bm, N // bn, K // bk)
    out = pl.pallas_call(
        _maxmin_fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda jj, i, jn, kk: (jj, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda jj, i, jn, kk: (jj, kk, jn)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda jj, i, jn, kk: (jj, i, jn)),
        out_shape=jax.ShapeDtypeStruct((j, M, N), dtype),
        interpret=interpret,
    )(a, b)
    return out[:, :m, :n]
