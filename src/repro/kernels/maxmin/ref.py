"""Pure-jnp oracle for the (max, min) bottleneck-semiring matmul.

C[i, j] = max_k min(A[i, k], B[k, j])

This is the dense form of the paper's product-graph relaxation (DESIGN.md §2):
A holds source-side bottleneck timestamps, B holds edge timestamps, C the
improved bottleneck timestamps. -inf encodes "unreachable / no edge".
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def maxmin_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, *, chunk: int = 128) -> jnp.ndarray:
    """Reference (max, min) matmul; chunked over k to bound the (m, k, n)
    broadcast intermediate. Shapes: a (m, k), b (k, n) -> (m, n).

    The chunk adapts downward for small k (32-aligned): padding a k=24
    engine to a 128-wide chunk would be >5x wasted inner-dim work."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    chunk = min(chunk, k + (-k) % 32)
    neg = jnp.asarray(-jnp.inf, a.dtype)
    out = jnp.full((m, n), neg, dtype=a.dtype)
    # pad k to a multiple of chunk with -inf columns (identity for max-min)
    pad = (-k) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        b = jnp.pad(b, ((0, pad), (0, 0)), constant_values=-jnp.inf)
    kk = a.shape[1]

    def body(i, out):
        asl = lax.dynamic_slice(a, (0, i * chunk), (m, chunk))
        bsl = lax.dynamic_slice(b, (i * chunk, 0), (chunk, n))
        c = jnp.max(jnp.minimum(asl[:, :, None], bsl[None, :, :]), axis=1)
        return jnp.maximum(out, c)

    return lax.fori_loop(0, kk // chunk, body, out)


def maxmin_matmul_naive(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Unchunked one-liner (test-size inputs only)."""
    return jnp.max(jnp.minimum(a[:, :, None], b[None, :, :]), axis=1)
