"""Jitted public wrapper for the bucketized MXU bottleneck closure step."""
from __future__ import annotations

import jax

from .bucket import bucket_maxmin
from .ref import bucket_maxmin_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def bucket_maxmin_op(a_lvl, b_lvl, *, n_levels: int, use_pallas: bool | None = None,
                     interpret: bool | None = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        if interpret is None:
            interpret = not _on_tpu()
        return bucket_maxmin(a_lvl, b_lvl, n_levels=n_levels, interpret=interpret)
    return bucket_maxmin_ref(a_lvl, b_lvl, n_levels)
