"""Pallas TPU kernel: fused bucketized bottleneck closure step (MXU path).

One pass over the level matrices computes ALL T threshold boolean matmuls:
tiles of A and B are read from HBM into VMEM once, binarized at each
threshold in registers, contracted on the MXU, and the T partial counts are
kept in a VMEM scratch accumulator. Compared with T separate XLA dots this
saves (T-1)x the HBM traffic of A and B — the dominant term once the
closure is memory-bound (see EXPERIMENTS.md §Perf napkin math).

Grid: (m/bm, n/bn, k/bk), k innermost; scratch acc: (T, bm, bn) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..maxmin.maxmin import pick_block_sizes


def _bucket_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_levels: int, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]  # (bm, bk) int32 levels
    b = b_ref[...]  # (bk, bn) int32 levels
    for theta in range(1, n_levels + 1):  # static unroll: T MXU dots per tile
        ab = (a >= theta).astype(jnp.bfloat16)
        bb = (b >= theta).astype(jnp.bfloat16)
        acc_ref[theta - 1] += jnp.dot(
            ab, bb, preferred_element_type=jnp.float32
        )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        counts = acc_ref[...]  # (T, bm, bn)
        o_ref[...] = jnp.sum((counts > 0.5).astype(jnp.int32), axis=0)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "bm", "bn", "bk", "interpret")
)
def bucket_maxmin(
    a_lvl: jnp.ndarray,
    b_lvl: jnp.ndarray,
    *,
    n_levels: int,
    bm: int = None,
    bn: int = None,
    bk: int = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Level-quantized bottleneck matmul on the MXU.

    a_lvl: (m, k) int32 in [0, T]; b_lvl: (k, n) int32. Returns (m, n) int32
    = max_k min(a, b). Level 0 = unreachable (semiring zero). Block sizes
    default to the shape-aware table (kernels/maxmin ``pick_block_sizes``).
    """
    m, k = a_lvl.shape
    k2, n = b_lvl.shape
    assert k == k2
    abm, abn, abk = pick_block_sizes(m, k, n)
    bm, bn, bk = bm or abm, bn or abn, bk or abk
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        a_lvl = jnp.pad(a_lvl, ((0, mp), (0, kp)), constant_values=0)
    if np_ or kp:
        b_lvl = jnp.pad(b_lvl, ((0, kp), (0, np_)), constant_values=0)
    M, K = a_lvl.shape
    _, N = b_lvl.shape
    k_steps = K // bk

    out = pl.pallas_call(
        functools.partial(_bucket_kernel, n_levels=n_levels, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        # (T, bm, bn) f32 accumulator lives in VMEM across the k-sweep
        scratch_shapes=[pltpu.VMEM((n_levels, bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_lvl, b_lvl)
    return out[:m, :n]


def _bucket_fused_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_levels: int,
                         k_steps: int):
    """Batched form of :func:`_bucket_kernel`: grid (J, m/bm, n/bn, k/bk)
    with k innermost — one launch covers every transition row of a round,
    so each row's level tiles are read from HBM once per output-tile visit
    and binarized at all T thresholds in registers (the same (T-1)x HBM
    saving as the single-pair kernel, without J separate launches)."""

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]  # (bm, bk) int32 levels of row j
    b = b_ref[0]  # (bk, bn)
    for theta in range(1, n_levels + 1):  # static unroll: T MXU dots per tile
        ab = (a >= theta).astype(jnp.bfloat16)
        bb = (b >= theta).astype(jnp.bfloat16)
        acc_ref[theta - 1] += jnp.dot(
            ab, bb, preferred_element_type=jnp.float32
        )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _finish():
        counts = acc_ref[...]  # (T, bm, bn)
        o_ref[0] = jnp.sum((counts > 0.5).astype(jnp.int32), axis=0)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "bm", "bn", "bk", "interpret")
)
def bucket_maxmin_fused(
    a_lvl: jnp.ndarray,
    b_lvl: jnp.ndarray,
    *,
    n_levels: int,
    bm: int = None,
    bn: int = None,
    bk: int = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused batched level-quantized bottleneck matmul on the MXU.

    a_lvl: (J, m, k) int32 in [0, T]; b_lvl: (J, k, n). Returns (J, m, n)
    int32 with out[j] = max_k min(a[j], b[j]) computed exactly on levels
    (level 0 = unreachable). One launch for all J rows; blocks default to
    the shape-aware table (the frontier's skinny slabs get small bm). In
    ``interpret`` mode blocks clamp to the 8-aligned problem (CPU
    validation path).
    """
    j, m, k = a_lvl.shape
    j2, k2, n = b_lvl.shape
    assert j == j2 and k == k2, (a_lvl.shape, b_lvl.shape)
    abm, abn, abk = pick_block_sizes(m, k, n)
    bm, bn, bk = bm or abm, bn or abn, bk or abk
    if interpret:
        bm = min(bm, m + (-m) % 8)
        bn = min(bn, n + (-n) % 8)
        bk = min(bk, k + (-k) % 8)
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        a_lvl = jnp.pad(a_lvl, ((0, 0), (0, mp), (0, kp)), constant_values=0)
    if np_ or kp:
        b_lvl = jnp.pad(b_lvl, ((0, 0), (0, kp), (0, np_)), constant_values=0)
    _, M, K = a_lvl.shape
    _, _, N = b_lvl.shape
    k_steps = K // bk

    out = pl.pallas_call(
        functools.partial(_bucket_fused_kernel, n_levels=n_levels,
                          k_steps=k_steps),
        grid=(j, M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda jj, i, jn, kk: (jj, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda jj, i, jn, kk: (jj, kk, jn)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda jj, i, jn, kk: (jj, i, jn)),
        out_shape=jax.ShapeDtypeStruct((j, M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((n_levels, bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_lvl, b_lvl)
    return out[:, :m, :n]
