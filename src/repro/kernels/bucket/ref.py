"""Pure-jnp oracle for the bucketized (MXU) bottleneck closure step.

Timestamps quantized to integer levels 0..T (0 = unreachable / -inf). On
levels, the bottleneck matmul C[i,j] = max_k min(A[i,k], B[k,j]) decomposes
over thresholds:

    C[i,j] = sum_{theta=1..T} [ exists k: A[i,k] >= theta  AND  B[k,j] >= theta ]

because level-valued bottleneck reachability is monotone in theta. Each
threshold term is a boolean matmul == (0/1 dot > 0), which the MXU executes
natively — this is the beyond-paper optimization analyzed in EXPERIMENTS.md
§Perf (T MXU matmuls beat 1 VPU max-min pass for T ≲ MXU/VPU throughput
ratio, and one fused pass reads A/B from HBM once).
"""
from __future__ import annotations

import jax.numpy as jnp


def bucket_maxmin_ref(a_lvl: jnp.ndarray, b_lvl: jnp.ndarray, n_levels: int) -> jnp.ndarray:
    """a_lvl: (m, k) int32 levels in [0, T]; b_lvl: (k, n). Returns (m, n)
    int32 levels = max_k min(a, b) computed exactly on levels."""
    out = jnp.zeros((a_lvl.shape[0], b_lvl.shape[1]), dtype=jnp.int32)
    for theta in range(1, n_levels + 1):
        ab = (a_lvl >= theta).astype(jnp.float32)
        bb = (b_lvl >= theta).astype(jnp.float32)
        reach = (ab @ bb) > 0.5
        out = out + reach.astype(jnp.int32)
    return out


def bucket_maxmin_exact(a_lvl: jnp.ndarray, b_lvl: jnp.ndarray) -> jnp.ndarray:
    """Direct max-min on levels (independent oracle for the decomposition)."""
    return jnp.max(
        jnp.minimum(a_lvl[:, :, None], b_lvl[None, :, :]), axis=1
    ).astype(jnp.int32)
