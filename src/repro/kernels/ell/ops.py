"""Dispatch layer for the ELL gather-contract (mirrors
``kernels/maxmin/ops.py``): jnp chunked reference off-TPU, the fused
Pallas kernel on TPU or under ``interpret=True``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ell import ell_gather_contract_fused
from .ref import NEG_INF, ell_gather_contract_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ell_gather_contract(d, idx, ts, *, zero=NEG_INF, use_pallas=None,
                        interpret=None):
    """Batched gather-contract: d (J, M, U) x idx/ts (J, U, E) -> (J, M, U).

    ``use_pallas=None`` picks the Pallas path on TPU; ``interpret=None``
    interprets off-TPU so the kernel stays testable on CPU CI.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        if interpret is None:
            interpret = not _on_tpu()
        return ell_gather_contract_fused(d, idx, ts, zero=zero,
                                         interpret=interpret)
    return jnp.stack([
        ell_gather_contract_ref(d[ji], idx[ji], ts[ji], zero=zero)
        for ji in range(d.shape[0])])
