"""jnp reference for the ELL gather-contract.

``out[m, v] = max_u max_e min(d[m, u], ts[u, e])`` over slots with
``idx[u, e] == v`` — the (max, min) bottleneck contraction of a row
block ``d`` against a padded-ELL adjacency, without densifying the
(N, N) label slab.  Free slots carry ``ts == zero`` so their candidates
fold away under the scatter-max (min with ``zero`` is ``zero`` for both
the -inf float lattice and the level-0 bucket lattice).

max/min never reassociate rounding, so this is bit-identical to
``maxmin_matmul_ref(d, densify(idx, ts))`` — the conformance tests pin
that equality and the executors rely on it for the dense-spill
contract.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

NEG_INF = float("-inf")


def ell_gather_contract_ref(d, idx, ts, *, zero=NEG_INF, u_chunk: int = 2048):
    """Gather-contract one matrix: d (M, U) x ELL rows idx/ts (U, E)
    -> (M, N) where N == U (square vertex space).

    The candidate tensor (M, u_chunk, E) is built per u-chunk inside a
    ``fori_loop`` so peak memory stays O(M * u_chunk * E) instead of
    O(M * N * E); the chunk is shrunk to a divisor of U so the loop
    needs no tail.
    """
    m, u = d.shape
    e_cap = idx.shape[1]
    chunk = min(u_chunk, u)
    while u % chunk:
        chunk //= 2
    out0 = jnp.full((m, u), zero, d.dtype)

    def body(i, out):
        u0 = i * chunk
        idx_c = lax.dynamic_slice(idx, (u0, 0), (chunk, e_cap))
        ts_c = lax.dynamic_slice(ts, (u0, 0), (chunk, e_cap))
        d_c = lax.dynamic_slice(d, (0, u0), (m, chunk))
        cand = jnp.minimum(d_c[:, :, None], ts_c[None].astype(d.dtype))
        return out.at[:, idx_c.reshape(-1)].max(cand.reshape(m, chunk * e_cap))

    return lax.fori_loop(0, u // chunk, body, out0)


def ell_gather_contract_naive(d, idx, ts, *, zero=NEG_INF):
    """Densify-then-contract one-liner; O(M * N * N) scratch, tests only."""
    u, _ = idx.shape
    a = jnp.full((u, u), zero, ts.dtype)
    a = a.at[jnp.arange(u)[:, None], idx].max(ts)
    return jnp.max(jnp.minimum(d[:, :, None], a[None].astype(d.dtype)), axis=1)
