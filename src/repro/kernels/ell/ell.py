"""Fused Pallas gather-contract over padded-ELL adjacency rows.

Grid ``(J, M/bm, U/bu)`` with the u-axis innermost: each step loads a
``(bm, bu)`` block of the row operand and the ``(bu, E)`` ELL slot
block for transition ``j``, then walks the ``bu * E`` slots performing
``o[:, idx[u, e]] = max(o[:, idx[u, e]], min(d[:, u], ts[u, e]))`` via
single-column ``pl.ds`` read-modify-writes.  The output block spans the
full vertex width and is revisited across the u-grid (the same
accumulator pattern as the k-loop in ``kernels/maxmin``), initialized
to ``zero`` at the first u-step with ``pl.when``.

Block sizes come from the shared ``pick_block_sizes`` table (rule R3);
the scatter axis cannot be blocked, so only (m, u) tile.  Free slots
(``ts == zero``) self-annihilate under the min/max fold, so padding the
u-axis with free rows and the m-axis with ``zero`` rows is exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..maxmin.maxmin import pick_block_sizes

NEG_INF = float("-inf")


def _r8(x: int) -> int:
    return max(x + (-x) % 8, 8)


def _r128(x: int) -> int:
    return max(x + (-x) % 128, 128)


def _ell_kernel(d_ref, idx_ref, ts_ref, o_ref, *, bu, e_cap, zero):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, zero, o_ref.dtype)

    d = d_ref[0]                      # (bm, bu)
    idx_flat = idx_ref[0].reshape(-1)  # (bu * e_cap,) int32
    ts_flat = ts_ref[0].reshape(-1)

    def body(i, _):
        col = lax.dynamic_index_in_dim(idx_flat, i, keepdims=False)
        t = lax.dynamic_index_in_dim(ts_flat, i, keepdims=False)
        u = i // e_cap
        d_col = lax.dynamic_slice(d, (0, u), (d.shape[0], 1))[:, 0]
        cand = jnp.minimum(d_col, t.astype(d.dtype))
        cur = o_ref[0, :, pl.ds(col, 1)]
        o_ref[0, :, pl.ds(col, 1)] = jnp.maximum(cur, cand[:, None])
        return 0

    lax.fori_loop(0, bu * e_cap, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("zero", "bm", "bu", "interpret"))
def ell_gather_contract_fused(d, idx, ts, *, zero=NEG_INF, bm=None, bu=None,
                              interpret=False):
    """Batched fused gather-contract: d (J, M, U) x idx/ts (J, U, E)
    -> (J, M, N) with N == U."""
    j, m, u = d.shape
    e_cap = idx.shape[2]
    t_bm, _, t_bu = pick_block_sizes(m, u, u)
    bm = bm or t_bm
    bu = bu or t_bu
    if interpret:
        bm = min(bm, _r8(m))
        bu = min(bu, _r8(u))

    m_pad = m + (-m) % bm
    u_pad = u + (-u) % bu
    n_out = _r128(u)
    zval = jnp.asarray(zero, d.dtype)
    d_p = jnp.full((j, m_pad, u_pad), zval, d.dtype).at[:, :m, :u].set(d)
    idx_p = jnp.zeros((j, u_pad, e_cap), jnp.int32).at[:, :u, :].set(idx)
    ts_p = jnp.full((j, u_pad, e_cap), jnp.asarray(zero, ts.dtype),
                    ts.dtype).at[:, :u, :].set(ts)

    out = pl.pallas_call(
        functools.partial(_ell_kernel, bu=bu, e_cap=e_cap, zero=zero),
        grid=(j, m_pad // bm, u_pad // bu),
        in_specs=[
            pl.BlockSpec((1, bm, bu), lambda ji, mi, ui: (ji, mi, ui)),
            pl.BlockSpec((1, bu, e_cap), lambda ji, mi, ui: (ji, ui, 0)),
            pl.BlockSpec((1, bu, e_cap), lambda ji, mi, ui: (ji, ui, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, n_out), lambda ji, mi, ui: (ji, mi, 0)),
        out_shape=jax.ShapeDtypeStruct((j, m_pad, n_out), d.dtype),
        interpret=interpret,
    )(d_p, idx_p, ts_p)
    return out[:, :m, :u]
