"""ELL gather-contract kernels: ``ref.py`` (jnp oracle), ``ell.py``
(fused Pallas kernel), ``ops.py`` (dispatch)."""
from .ops import ell_gather_contract  # noqa: F401
from .ref import ell_gather_contract_naive, ell_gather_contract_ref  # noqa: F401
