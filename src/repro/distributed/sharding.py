"""PartitionSpecs for every array family, per mesh flavor.

Mesh axes:
    single-pod:  ('data', 'model')            16 x 16 = 256 chips (v5e pod)
    multi-pod:   ('pod', 'data', 'model')     2 x 16 x 16 = 512 chips

FSDP axis = ('data',) or ('pod', 'data'): parameters and optimizer moments
are additionally sharded over the data-parallel axis (ZeRO-3 style); the
leading (n_periods,) stack dim is never sharded.

Param rules (by array name within a layer dict):
    embed.table      (V, d)        V->model, d->fsdp
    lm_head.w        (d, V)        d->fsdp,  V->model
    attn wq/wk/wv    (d, H*hd)     d->fsdp,  cols->model
    attn wo          (H*hd, d)     rows->model, d->fsdp
    mlp w_gate/up    (d, f)        d->fsdp,  f->model
    mlp w_down       (f, d)        f->model, d->fsdp
    moe router       (d, E)        replicated
    moe w_*          (E, d, f)     E->model, d->fsdp (expert parallelism)
    ssd w_in         (d, ch)       d->fsdp,  ch->model
    ssd w_out        (di, d)       di->model, d->fsdp
    biases/norms/small             replicated

Activation rules (constrain tags):
    hidden  (b, s, d)   b->batch_axes  (train/prefill/decode with b>1)
                        s->batch_axes  (long-context decode with b=1)
    logits  (b, s, V)   b->batch_axes, V->model
"""
from __future__ import annotations

from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh):
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data")
    return ("data",)


def batch_axes(mesh: Mesh):
    return fsdp_axes(mesh)


def _divisible(dim: int, mesh: Mesh, axes) -> bool:
    if dim is None:
        return False
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= mesh.shape[a]
    return dim % total == 0 and dim >= total


def param_spec(path: str, shape, mesh: Mesh) -> P:
    """Map a flattened param path + shape to a PartitionSpec."""
    fs = fsdp_axes(mesh)
    name = path.split("/")[-1]
    stacked = path.startswith("layers")  # leading (n_periods,) dim
    lead = (None,) if stacked else ()

    def spec(*dims):
        out = []
        for d in dims:
            out.append(d)
        return P(*lead, *out)

    dims = shape[1:] if stacked else shape

    if name in ("scale", "norm_scale", "dt_bias", "A_log", "D", "conv_b",
                "bq", "bk", "bv"):
        return P(*lead, *([None] * len(dims)))
    if name == "router":
        return P(*lead, None, None)
    if name == "conv_w":
        return P(*lead, None, "model") if _divisible(dims[-1], mesh, "model") \
            else P(*lead, None, None)
    if name == "table":  # embedding (V, d)
        return spec("model" if _divisible(dims[0], mesh, "model") else None,
                    fs if _divisible(dims[1], mesh, fs) else None)
    if path.startswith("lm_head"):  # (d, V)
        return spec(fs if _divisible(dims[0], mesh, fs) else None,
                    "model" if _divisible(dims[1], mesh, "model") else None)
    if name in ("w_gate", "w_up", "w_down") and len(dims) == 3:  # MoE (E, d, f)
        e = "model" if _divisible(dims[0], mesh, "model") else None
        d1 = fs if _divisible(dims[1], mesh, fs) else None
        return spec(e, d1, None)
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "w_in"):  # (d, cols)
        return spec(fs if _divisible(dims[0], mesh, fs) else None,
                    "model" if _divisible(dims[1], mesh, "model") else None)
    if name in ("wo", "w_down", "w_out"):  # (rows, d)
        return spec("model" if _divisible(dims[0], mesh, "model") else None,
                    fs if _divisible(dims[1], mesh, fs) else None)
    if name == "w":  # frontend_proj (d, d)
        return spec(fs if _divisible(dims[0], mesh, fs) else None,
                    "model" if _divisible(dims[1], mesh, "model") else None)
    return P(*lead, *([None] * len(dims)))


def params_shardings(abstract_params: Any, mesh: Mesh,
                     serving: bool = False) -> Any:
    """NamedSharding pytree matching an abstract (eval_shape) param tree.

    serving=True drops the FSDP axes (params replicate across data; only
    tensor-parallel sharding remains). Decode steps are otherwise dominated
    by per-step FSDP param all-gathers (~0.7 GB/step measured for 3B-class
    archs — §Perf It.5); serving has no optimizer state, so replication
    costs only params/TP of HBM."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = []
    for path, leaf in flat:
        pstr = "/".join(_p(p) for p in path)
        spec = param_spec(pstr, leaf.shape, mesh)
        if serving:
            fs = fsdp_axes(mesh)
            spec = P(*[None if d == fs or d == "data" or
                       (isinstance(d, tuple) and set(d) & {"data", "pod"})
                       else d for d in spec])
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(abstract_params), out
    )


def _p(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def make_constrain(mesh: Mesh, seq_sharded: bool = False) -> Callable:
    """Activation-constraint hook for Model(constrain=...).

    seq_sharded=True (long-context, batch=1): shard sequence instead of batch.
    """
    ba = batch_axes(mesh)

    def constrain(x, tag: str):
        if tag == "hidden" and x.ndim == 3:
            b, s, _d = x.shape
            if seq_sharded:
                spec = P(None, ba, None) if _divisible(s, mesh, ba) else P()
            else:
                spec = P(ba, None, None) if _divisible(b, mesh, ba) else P()
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        if tag == "ssm_heads" and x.ndim == 4:
            b, s, h, _p = x.shape
            h_ax = "model" if _divisible(h, mesh, "model") else None
            if seq_sharded:
                bspec, sspec = None, (ba if _divisible(s, mesh, ba) else None)
            else:
                bspec, sspec = (ba if _divisible(b, mesh, ba) else None), None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bspec, sspec, h_ax, None)))
        if tag == "ssm_dt" and x.ndim == 3:
            b, s, h = x.shape
            h_ax = "model" if _divisible(h, mesh, "model") else None
            bspec = ba if (not seq_sharded and _divisible(b, mesh, ba)) else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bspec, None, h_ax)))
        if tag == "logits" and x.ndim == 3:
            b, s, v = x.shape
            bspec = ba if (not seq_sharded and _divisible(b, mesh, ba)) else None
            vspec = "model" if _divisible(v, mesh, "model") else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bspec, None, vspec)))
        return x

    return constrain


def batch_shardings(mesh: Mesh, seq_sharded: bool = False) -> Callable[[str, tuple], NamedSharding]:
    """Input-batch shardings: tokens (b, s), prefix_embeds (b, p, d)."""
    ba = batch_axes(mesh)

    def shard_for(name: str, shape: tuple) -> NamedSharding:
        b = shape[0]
        if seq_sharded or not _divisible(b, mesh, ba):
            if len(shape) >= 2 and _divisible(shape[1], mesh, ba):
                return NamedSharding(mesh, P(None, ba, *([None] * (len(shape) - 2))))
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(ba, *([None] * (len(shape) - 1))))

    return shard_for


def cache_shardings(mesh: Mesh, abstract_caches: Any, seq_sharded: bool) -> Any:
    """Decode-cache shardings. KV caches (n_periods, b, S, KV, hd):
    b -> batch axes (or S -> batch axes for long-context b=1), KV heads ->
    model when divisible, else head_dim -> model."""
    ba = batch_axes(mesh)

    def one(path, leaf):
        shape = leaf.shape
        name = _p(path[-1]) if path else ""
        if name in ("k", "v") and len(shape) == 5:
            _np, b, s, kv, hd = shape
            kv_ax = "model" if _divisible(kv, mesh, "model") else None
            hd_ax = "model" if kv_ax is None and _divisible(hd, mesh, "model") else None
            if seq_sharded or not _divisible(b, mesh, ba):
                return NamedSharding(mesh, P(None, None, ba if _divisible(s, mesh, ba) else None, kv_ax, hd_ax))
            return NamedSharding(mesh, P(None, ba, None, kv_ax, hd_ax))
        if name == "ssm" and len(shape) == 5:  # (n_periods, b, h, n, p)
            _np, b, h, n, pdim = shape
            h_ax = "model" if _divisible(h, mesh, "model") else None
            if _divisible(b, mesh, ba) and not seq_sharded:
                return NamedSharding(mesh, P(None, ba, h_ax, None, None))
            return NamedSharding(mesh, P(None, None, h_ax, None, None))
        if name == "conv" and len(shape) == 4:  # (n_periods, b, k-1, ch)
            _np, b, _k, ch = shape
            ch_ax = "model" if _divisible(ch, mesh, "model") else None
            if _divisible(b, mesh, ba) and not seq_sharded:
                return NamedSharding(mesh, P(None, ba, None, ch_ax))
            return NamedSharding(mesh, P(None, None, None, ch_ax))
        # len counters etc.
        return NamedSharding(mesh, P(*([None] * len(shape))))

    flat, _ = jax.tree_util.tree_flatten_with_path(abstract_caches)
    out = [one(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(abstract_caches), out
    )
