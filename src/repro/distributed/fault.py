"""Fault-tolerance and elasticity helpers for long-running jobs.

Mechanisms (all exercised by tests on CPU; deployment notes in DESIGN.md §4):

* **checkpoint/restart loop** — `run_with_restarts` wraps a step function,
  snapshots every `ckpt_every` steps (async), and on ANY exception restores
  the latest committed checkpoint and continues — the driver a cluster
  scheduler would supervise. Failures mid-save can never corrupt state
  (atomic manifest+LATEST protocol in checkpoint/ckpt.py).

* **straggler mitigation** — `StragglerMonitor` tracks per-step wall times;
  a step exceeding `deadline_factor` x the trailing median is recorded and
  (on real clusters) would trigger the backup-task path; here the policy
  hook `on_straggler` lets the driver skip a slow data shard (the pipeline
  is deterministic per (host, step), so skipping is reproducible).

* **elastic re-scaling** — checkpoints store logical arrays, so a restore
  may target a different mesh (see checkpoint.restore(shardings=...)); the
  launcher recomputes shardings for the new topology and continues. Tested
  by reshaping a 8-device host mesh between save and restore.
"""
from __future__ import annotations

import time
from statistics import median
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..checkpoint import ckpt


class StragglerMonitor:
    def __init__(self, deadline_factor: float = 3.0, warmup: int = 5):
        self.deadline_factor = deadline_factor
        self.warmup = warmup
        self.times: List[float] = []
        self.stragglers: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if this step was a straggler."""
        is_straggler = False
        if len(self.times) >= self.warmup:
            med = median(self.times[-32:])
            if dt > self.deadline_factor * med:
                self.stragglers.append(step)
                is_straggler = True
        self.times.append(dt)
        return is_straggler


def run_with_restarts(
    step_fn: Callable[[Any, int], Any],
    init_state: Any,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    max_restarts: int = 3,
    on_straggler: Optional[Callable[[int], None]] = None,
    monitor: Optional[StragglerMonitor] = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Supervised training loop: periodic async checkpoints, restore-on-crash."""
    state = init_state
    start = 0
    restarts = 0
    monitor = monitor or StragglerMonitor()
    # resume if a committed checkpoint exists
    try:
        state, extra = ckpt.restore(ckpt_dir, like=state)
        start = int(extra.get("step", 0))
    except FileNotFoundError:
        pass

    step = start
    while step < n_steps:
        try:
            t0 = time.monotonic()
            state = step_fn(state, step)
            dt = time.monotonic() - t0
            if monitor.observe(step, dt) and on_straggler is not None:
                on_straggler(step)
            step += 1
            if step % ckpt_every == 0 or step == n_steps:
                ckpt.async_save(ckpt_dir, step, state, extra={"step": step})
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt.wait_pending(ckpt_dir)
            try:
                state, extra = ckpt.restore(ckpt_dir, like=state)
                step = int(extra.get("step", 0))
            except FileNotFoundError:
                state = init_state
                step = 0
    ckpt.wait_pending(ckpt_dir)
    return state, {
        "restarts": restarts,
        "stragglers": list(monitor.stragglers),
        "final_step": step,
    }
