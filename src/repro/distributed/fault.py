"""Fault-tolerance and elasticity helpers for long-running jobs.

Mechanisms (all exercised by tests on CPU; deployment notes in DESIGN.md §4):

* **checkpoint/restart loop** — `run_with_restarts` wraps a step function,
  snapshots every `ckpt_every` steps (async), and on ANY exception restores
  the latest committed checkpoint and continues — the driver a cluster
  scheduler would supervise. Failures mid-save can never corrupt state
  (atomic manifest+LATEST protocol in checkpoint/ckpt.py).

* **straggler mitigation** — `StragglerMonitor` tracks per-step wall times;
  a step exceeding `deadline_factor` x the trailing median is recorded and
  (on real clusters) would trigger the backup-task path; here the policy
  hook `on_straggler` lets the driver skip a slow data shard (the pipeline
  is deterministic per (host, step), so skipping is reproducible).

* **elastic re-scaling** — checkpoints store logical arrays, so a restore
  may target a different mesh (see checkpoint.restore(shardings=...)); the
  launcher recomputes shardings for the new topology and continues. Tested
  by reshaping a 8-device host mesh between save and restore.
"""
from __future__ import annotations

import time
from statistics import median
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..checkpoint import ckpt


class StragglerMonitor:
    def __init__(self, deadline_factor: float = 3.0, warmup: int = 5):
        self.deadline_factor = deadline_factor
        self.warmup = warmup
        self.times: List[float] = []
        self.stragglers: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if this step was a straggler."""
        is_straggler = False
        if len(self.times) >= self.warmup:
            med = median(self.times[-32:])
            if dt > self.deadline_factor * med:
                self.stragglers.append(step)
                is_straggler = True
        self.times.append(dt)
        return is_straggler


def run_with_restarts(
    step_fn: Callable[[Any, int], Any],
    init_state: Any,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    max_restarts: int = 3,
    on_straggler: Optional[Callable[[int], None]] = None,
    monitor: Optional[StragglerMonitor] = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Supervised training loop: periodic async checkpoints, restore-on-crash."""
    state = init_state
    start = 0
    restarts = 0
    monitor = monitor or StragglerMonitor()
    # resume if a committed checkpoint exists
    try:
        state, extra = ckpt.restore(ckpt_dir, like=state)
        start = int(extra.get("step", 0))
    except FileNotFoundError:
        pass

    step = start
    while step < n_steps:
        try:
            t0 = time.monotonic()
            state = step_fn(state, step)
            dt = time.monotonic() - t0
            if monitor.observe(step, dt) and on_straggler is not None:
                on_straggler(step)
            step += 1
            if step % ckpt_every == 0 or step == n_steps:
                ckpt.async_save(ckpt_dir, step, state, extra={"step": step})
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt.wait_pending(ckpt_dir)
            try:
                state, extra = ckpt.restore(ckpt_dir, like=state)
                step = int(extra.get("step", 0))
            except FileNotFoundError:
                state = init_state
                step = 0
    ckpt.wait_pending(ckpt_dir)
    return state, {
        "restarts": restarts,
        "stragglers": list(monitor.stragglers),
        "final_step": step,
    }


def run_service_with_restarts(
    make_service: Callable[..., Any],
    stream: Any,
    ckpt_dir: str,
    *,
    batch_events: int = 8,
    ckpt_every: int = 4,
    max_restarts: int = 8,
    fault_plan: Any = None,
    on_straggler: Optional[Callable[[int], None]] = None,
    monitor: Optional[StragglerMonitor] = None,
    **supervisor_kwargs: Any,
) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """`run_with_restarts` ported onto `PersistentQueryService`: the same
    supervise/checkpoint/restore contract, but the unit of work is a WAL-logged
    micro-batch instead of a training step, and restore is followed by exact
    WAL-suffix replay (streaming/supervisor.py) rather than recompute-forward.

    Per-batch wall times feed the same `StragglerMonitor`; detected stragglers
    invoke `on_straggler(lsn)` and land in the supervisor's `health_log`.

    Returns ``(final_results, report)`` where the report mirrors
    `run_with_restarts`'s (restarts / stragglers / final step) plus the
    recovery measurements the service path adds.
    """
    from ..streaming.supervisor import ServiceSupervisor

    sup = ServiceSupervisor(
        make_service, ckpt_dir,
        batch_events=batch_events, ckpt_every=ckpt_every,
        max_restarts=max_restarts, fault_plan=fault_plan,
        monitor=monitor or StragglerMonitor(),
        on_straggler=on_straggler, **supervisor_kwargs)
    results = sup.run(stream)
    return results, {
        "restarts": sup.restarts,
        "stragglers": list(sup.stragglers),
        "final_step": sup.wal.last_lsn,
        "recoveries": [
            {"recovery_s": r.recovery_s, "replayed_events": r.replayed_events,
             "replay_eps": r.replay_eps} for r in sup.recoveries],
        "health_log": list(sup.health_log),
    }
