"""MeshExecutor: the batched dense RPQ engine's device work on a mesh.

Layout (reusing the production mesh axis names of launch/mesh.py and the
spec conventions of distributed/sharding.py):

    dist    (Q, N, N, K)  Q -> lane axes (default ``('data',)``), the third
                          (v/u) vertex axis optionally -> 'model'
    emitted (Q, N, N)     Q -> lane axes
    adj     (L, N, N)     v -> 'model' (the closure reshards a u-row view
                          per round; co-locating both views is the ring
                          hillclimb, see launch/dryrun_rpq.py)
    now     ()            replicated

Convergence-aware dispatch — the tentpole win this layer exists for: each
lane shard runs the closure in a shard_map block over ITS OWN transition
rows with the per-query convergence mask device-resident, so

  * a shard whose lanes are all converged/inert SKIPS the round entirely
    (`lax.cond` in semiring.shard_closure) — e.g. seeding a newly
    registered lane relaxes exactly one shard while every other shard does
    zero contraction work;
  * an active shard stops at its OWN fixpoint instead of riding until the
    globally slowest query converges — the ~37% no-op relaxation tail that
    fig12 measured on the single-device path becomes skipped contractions.

The skip is observable in the executor counters: ``shard_rounds_total``
(rounds shards actually relaxed) vs ``n_shards * sync_rounds_total`` (every
shard riding to the global fixpoint); ``skipped_shard_rounds_total`` is
their gap, reported by benchmarks/fig14_sharded_engine.py.

Result streams are BIT-identical to LocalExecutor: the (max, min) semiring
has no floating-point reassociation error, so splitting the u-contraction
into per-shard partials combined by `pmax` is exact, and each query's
fixpoint is independent of every other query (transitions only read their
owning lane's slices).

The per-shard closure contracts with the executor's SELECTED
:class:`~repro.core.backend.ContractionBackend` (PR 4): the fused batched
pallas kernel or the mxu_bucket level mode run per shard exactly as they
do locally (the mesh path used to hardcode the jnp oracle). Identity still
holds per backend — even the bucket mode's quantization is deterministic,
so mesh and local bucket runs emit the same streams.

Tests run this on a host-local CPU mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the tier1-sharded
CI job); a single-device mesh degenerates to one shard and still exercises
the shard_map path.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.executor import (
    BatchedEngineArrays,
    Executor,
    QueryTables,
    apply_batch,
    drop_batch,
    emit_new,
)
from ..core.sparse_adj import EllAdjacency, ell_to_dense
from ..core.sparse_dist import RowSparseDist, rsd_from_dense, rsd_to_dense
from ..core.semiring import (
    NEG_INF,
    BatchedTransitionTable,
    FrontierStats,
    batched_valid_pairs,
    shard_closure,
    shard_frontier_closure,
    shard_frontier_delete,
    shard_relax_round,
    shard_transitions,
)


def host_mesh(model_axis: int = 1) -> Mesh:
    """('data', 'model') mesh over whatever devices this process has
    (launch/mesh.py's host mesh), clamping the model axis to the device
    count — a 1-device run yields the degenerate 1x1 mesh, so the same
    code path works in every tier."""
    from ..launch.mesh import make_host_mesh

    return make_host_mesh(max(1, min(model_axis, len(jax.devices()))))


def _row_specs(q_axes) -> Tuple[P, ...]:
    return tuple(P(q_axes, None) for _ in range(6))


def _adj_dense(adj):
    """Trace-time layout adapter for the shard_map closures: the per-shard
    relaxation contracts the canonical dense slab (one in-jit densify —
    XLA SPMD inserts the reshard), while the ELL pytree itself carries the
    graph between dispatches so insert/delete scatters stay O(B·E)."""
    return ell_to_dense(adj) if isinstance(adj, EllAdjacency) else adj


def _dist_dense(dist):
    """Trace-time dist layout adapter, the dist twin of :func:`_adj_dense`:
    the shard_map closures relax the canonical dense ``(Q, N, N, K)`` slab
    (one in-jit densify), while the row-sparse pytree carries the reachable
    sets between dispatches so checkpoint/emit state stays compact."""
    return rsd_to_dense(dist) if isinstance(dist, RowSparseDist) else dist


def _dist_like(dist0, dense):
    """Repack a dense closure result into ``dist0``'s layout, carrying its
    capacities and loss counter — identity under the dense layout. The
    repack is a canonical pack (fitting rows -> slots, overfull -> table),
    so the mesh path is observably identical to the local sparse path."""
    if isinstance(dist0, RowSparseDist):
        return rsd_from_dense(dense, dist0.dist_cap, dist0.ovf_cap,
                              dist0.lost)
    return dense


def _dist_logical_shape(dist):
    """Logical dense ``(Q, N, N, K)`` shape of either layout (trace-time
    metadata only — never densifies)."""
    if isinstance(dist, RowSparseDist):
        q, n, _c = dist.idx.shape
        return (q, n, n, dist.k)
    return tuple(dist.shape)


def _adj_shardings(mesh: Mesh, adj_layout: str):
    """Canonical adjacency sharding per layout: the dense slab shards its v
    axis over 'model'; the ELL pytree shards idx/ts on the u-ROW axis (rows
    are the scatter unit) and replicates the small spill ring."""
    if adj_layout == "ell":
        row = NamedSharding(mesh, P(None, "model", None))
        rep = NamedSharding(mesh, P())
        return EllAdjacency(idx=row, ts=row, spill_src=rep, spill_dst=rep,
                            spill_lab=rep, spill_ts=rep, spill_ptr=rep)
    return NamedSharding(mesh, P(None, None, "model"))


def _dist_shardings(mesh: Mesh, dist_layout: str, qa):
    """Canonical dist sharding per layout: the dense slab shards Q over the
    lane axes and v over 'model'; the row-sparse pytree shards its source-row
    slabs on the lane axis only (rows are the gather/scatter unit; the v/k
    entries inside a row are the payload) and replicates the small bounded
    overflow table + counters."""
    if dist_layout == "row_sparse":
        row = NamedSharding(mesh, P(qa, None, None))
        rep = NamedSharding(mesh, P())
        return RowSparseDist(idx=row, ts=row, ovf_rows=rep, ovf_ts=rep,
                             ovf_ptr=rep, lost=rep)
    return NamedSharding(mesh, P(qa, None, "model", None))


def make_sharded_closure(mesh: Mesh, backend,
                         q_axes=("data",), model_axis: str = "model"):
    """shard_map-wrapped per-shard closure: (dist, adj_u, adj_v, rows, mask0,
    now, w_max) -> (dist', shard_rounds (n_shards,), query_rounds (Q,)).
    ``now``/``w_max`` are replicated scalars anchoring clock-dependent
    backend representations (the bucket level grid); each active shard
    encodes its own block (elementwise, collective-free)."""
    qa = q_axes[0] if len(q_axes) == 1 else tuple(q_axes)
    n_model = mesh.shape[model_axis]
    dist_spec = P(qa, None, model_axis, None)

    def body(dist_blk, adj_u, adj_v, *rest):
        rows = tuple(r[0] for r in rest[:6])
        mask0, now, w_max = rest[6], rest[7], rest[8]
        d_f, rounds, qrounds = shard_closure(
            dist_blk, adj_u, adj_v, rows, mask0, backend=backend,
            model_axis=model_axis if n_model > 1 else None,
            model_size=n_model, now=now, w_max=w_max,
        )
        return d_f, rounds.reshape(1), qrounds

    return shard_map(
        body, mesh=mesh,
        in_specs=(dist_spec, P(None, model_axis, None), P(None, None, model_axis),
                  *_row_specs(qa), P(qa), P(), P()),
        out_specs=(dist_spec, P(qa), P(qa)),
        check_rep=False,
    )


def make_sharded_frontier_closure(mesh: Mesh, backend, f_cap: int,
                                  q_axes=("data",), model_axis: str = "model"):
    """shard_map-wrapped frontier closure (the ingest form): (dist, adj_u,
    adj_v, rows, mask0, src, smask, now, w_max) -> (dist', shard_rounds,
    query_rounds, rows_relaxed, fell_back, seed_rows, max_lane_rows) with
    the per-shard stats shaped (n_shards,). Each shard seeds its own
    frontier from the (replicated) batch source slots, skips the closure
    entirely when nothing on it is dirty, and falls back to ITS OWN dense
    loop on overflow — other shards keep the frontier rounds."""
    qa = q_axes[0] if len(q_axes) == 1 else tuple(q_axes)
    n_model = mesh.shape[model_axis]
    dist_spec = P(qa, None, model_axis, None)

    def body(dist_blk, adj_u, adj_v, *rest):
        rows = tuple(r[0] for r in rest[:6])
        mask0, src, smask, now, w_max = rest[6:11]
        d_f, rounds, qrounds, rr, fb, seed, mx = shard_frontier_closure(
            dist_blk, adj_u, adj_v, rows, mask0, src, smask, f_cap,
            backend=backend,
            model_axis=model_axis if n_model > 1 else None,
            model_size=n_model, now=now, w_max=w_max,
        )
        return (d_f, rounds.reshape(1), qrounds, rr.reshape(1),
                fb.reshape(1), seed.reshape(1), mx.reshape(1))

    return shard_map(
        body, mesh=mesh,
        in_specs=(dist_spec, P(None, model_axis, None), P(None, None, model_axis),
                  *_row_specs(qa), P(qa), P(None), P(None), P(), P()),
        out_specs=(dist_spec, P(qa), P(qa), P(qa), P(qa), P(qa), P(qa)),
        check_rep=False,
    )


def make_sharded_frontier_delete(mesh: Mesh, backend, f_cap: int,
                                 q_axes=("data",), model_axis: str = "model"):
    """shard_map-wrapped cone-seeded deletion: same signature and output
    layout as :func:`make_sharded_frontier_closure`, but each shard
    computes the deleted edges' cone on its PRE-delete block (``adj_u`` /
    ``adj_v`` carry the RETAINED adjacency), clears its cone rows, and
    re-derives them; a shard with no cone rows skips (its lanes carry no
    derivation through the dropped edges), and an overflowing shard falls
    back to ITS OWN dense from-scratch loop."""
    qa = q_axes[0] if len(q_axes) == 1 else tuple(q_axes)
    n_model = mesh.shape[model_axis]
    dist_spec = P(qa, None, model_axis, None)

    def body(dist_blk, adj_u, adj_v, *rest):
        rows = tuple(r[0] for r in rest[:6])
        mask0, src, smask, now, w_max = rest[6:11]
        d_f, rounds, qrounds, rr, fb, seed, mx = shard_frontier_delete(
            dist_blk, adj_u, adj_v, rows, mask0, src, smask, f_cap,
            backend=backend,
            model_axis=model_axis if n_model > 1 else None,
            model_size=n_model, now=now, w_max=w_max,
        )
        return (d_f, rounds.reshape(1), qrounds, rr.reshape(1),
                fb.reshape(1), seed.reshape(1), mx.reshape(1))

    return shard_map(
        body, mesh=mesh,
        in_specs=(dist_spec, P(None, model_axis, None), P(None, None, model_axis),
                  *_row_specs(qa), P(qa), P(None), P(None), P(), P()),
        out_specs=(dist_spec, P(qa), P(qa), P(qa), P(qa), P(qa), P(qa)),
        check_rep=False,
    )


def make_sharded_round(mesh: Mesh, backend,
                       q_axes=("data",), model_axis: str = "model"):
    """One convergence-masked relaxation round (no fixpoint loop) with the
    same sharding/skip structure — the unit launch/dryrun_rpq.py lowers for
    the roofline (round count is data-dependent, so cost is per round). The
    backend's representation boundary wraps the single round: an active
    shard encodes, contracts, decodes; a masked shard skips all three."""
    from ..core.backend import resolve_backend

    backend = resolve_backend(backend)
    qa = q_axes[0] if len(q_axes) == 1 else tuple(q_axes)
    n_model = mesh.shape[model_axis]
    dist_spec = P(qa, None, model_axis, None)

    def body(dist_blk, adj_u, adj_v, *rest):
        qidx, src, lab, dst, start, active = (r[0] for r in rest[:6])
        mask0, now, w_max = rest[6], rest[7], rest[8]

        def run(_):
            d_op = backend.encode(dist_blk, now, w_max)
            nd, _changed = shard_relax_round(
                d_op, backend.encode(adj_u, now, w_max),
                backend.encode(adj_v, now, w_max),
                qidx, src, lab, dst, start, active,
                mask0, backend=backend,
                model_axis=model_axis if n_model > 1 else None,
                model_size=n_model)
            return backend.decode_state(nd, now, w_max)

        return jax.lax.cond(jnp.any(mask0), run, lambda _: dist_blk, None)

    return shard_map(
        body, mesh=mesh,
        in_specs=(dist_spec, P(None, model_axis, None), P(None, None, model_axis),
                  *_row_specs(qa), P(qa), P(), P()),
        out_specs=dist_spec,
        check_rep=False,
    )


def make_sharded_frontier_round(mesh: Mesh, backend,
                                q_axes=("data",), model_axis: str = "model"):
    """One frontier-restricted relaxation round (no fixpoint loop) with the
    same sharding/skip structure as :func:`make_sharded_round` — the unit
    launch/dryrun_rpq.py lowers so the roofline prices the frontier
    dispatch at O(J·F·N²) instead of the dense O(J·N³). The (Q, F) frontier
    row indices and slot mask ride as runtime, lane-sharded inputs; a shard
    whose rowmask is empty skips encode/contract/decode entirely."""
    from ..core.backend import resolve_backend
    from ..core.semiring import _shard_frontier_round

    backend = resolve_backend(backend)
    qa = q_axes[0] if len(q_axes) == 1 else tuple(q_axes)
    n_model = mesh.shape[model_axis]
    dist_spec = P(qa, None, model_axis, None)

    def body(dist_blk, adj_u, adj_v, *rest):
        rows = tuple(r[0] for r in rest[:6])
        frows, rowmask, now, w_max = rest[6:10]

        def run(_):
            d_op = backend.encode(dist_blk, now, w_max)
            nd, _changed = _shard_frontier_round(
                d_op, backend.encode(adj_u, now, w_max),
                backend.encode(adj_v, now, w_max),
                rows, frows, rowmask, backend,
                model_axis if n_model > 1 else None, n_model)
            return backend.decode_state(nd, now, w_max)

        return jax.lax.cond(jnp.any(rowmask), run, lambda _: dist_blk, None)

    return shard_map(
        body, mesh=mesh,
        in_specs=(dist_spec, P(None, model_axis, None), P(None, None, model_axis),
                  *_row_specs(qa), P(qa, None), P(qa, None), P(), P()),
        out_specs=dist_spec,
        check_rep=False,
    )


def frontier_round_lowering(mesh: Mesh, btt: BatchedTransitionTable,
                            q_cap: int, n_slots: int, f_cap: int,
                            q_axes=("data",), backend="jnp"):
    """Dryrun lowering of the frontier round: like
    :func:`batched_round_lowering` but the contraction is restricted to a
    (q_cap, f_cap) frontier — ``round_fn(dist, adj, frows, rowmask, now,
    w_max)``. Returns ``(round_fn, arg_specs, arg_shardings,
    out_sharding)``."""
    n_shards = int(np.prod([mesh.shape[a] for a in q_axes]))
    if q_cap % n_shards:
        raise ValueError(f"q_cap {q_cap} not divisible by {n_shards} lane shards")
    rows = shard_transitions(btt, q_cap, n_shards)
    sharded_round = make_sharded_frontier_round(mesh, backend, q_axes=q_axes)
    qa = q_axes[0] if len(q_axes) == 1 else tuple(q_axes)
    dist_sh = NamedSharding(mesh, P(qa, None, "model", None))
    adj_sh = NamedSharding(mesh, P(None, None, "model"))
    frow_sh = NamedSharding(mesh, P(qa, None))
    scalar_sh = NamedSharding(mesh, P())
    dist_spec = jax.ShapeDtypeStruct((q_cap, n_slots, n_slots, btt.k), jnp.float32)
    adj_spec = jax.ShapeDtypeStruct((btt.n_labels, n_slots, n_slots), jnp.float32)
    frows_spec = jax.ShapeDtypeStruct((q_cap, f_cap), jnp.int32)
    rmask_spec = jax.ShapeDtypeStruct((q_cap, f_cap), jnp.bool_)
    scalar_spec = jax.ShapeDtypeStruct((), jnp.float32)

    def round_fn(dist, adj, frows, rowmask, now, w_max):
        return sharded_round(dist, adj, adj, *rows, frows, rowmask, now, w_max)

    return (round_fn,
            (dist_spec, adj_spec, frows_spec, rmask_spec, scalar_spec,
             scalar_spec),
            (dist_sh, adj_sh, frow_sh, frow_sh, scalar_sh, scalar_sh),
            dist_sh)


def batched_round_lowering(mesh: Mesh, btt: BatchedTransitionTable,
                           q_cap: int, n_slots: int,
                           q_axes=("data",), backend="jnp"):
    """The dryrun lowering of the mesh executor's round: returns
    ``(round_fn, arg_specs, arg_shardings, out_sharding)`` for
    ``round_fn(dist, adj, query_mask, now, w_max)`` with dist
    (q_cap, N, N, K) sharded Q->q_axes / v->'model', the (Q,) convergence
    mask as a runtime, lane-sharded input, and the replicated stream-clock
    scalars a clock-anchored backend (mxu_bucket) quantizes against.
    ``q_cap`` is the lane capacity after padding the live query count up to
    a multiple of the lane-shard count (inert lanes are exactly the
    engine's bucketed padding). ``backend`` selects the contraction
    substrate the cell lowers — the SAME object the engine would run."""
    n_shards = int(np.prod([mesh.shape[a] for a in q_axes]))
    if q_cap % n_shards:
        raise ValueError(f"q_cap {q_cap} not divisible by {n_shards} lane shards")
    rows = shard_transitions(btt, q_cap, n_shards)
    sharded_round = make_sharded_round(mesh, backend, q_axes=q_axes)
    qa = q_axes[0] if len(q_axes) == 1 else tuple(q_axes)
    dist_sh = NamedSharding(mesh, P(qa, None, "model", None))
    adj_sh = NamedSharding(mesh, P(None, None, "model"))
    mask_sh = NamedSharding(mesh, P(qa))
    scalar_sh = NamedSharding(mesh, P())
    dist_spec = jax.ShapeDtypeStruct((q_cap, n_slots, n_slots, btt.k), jnp.float32)
    adj_spec = jax.ShapeDtypeStruct((btt.n_labels, n_slots, n_slots), jnp.float32)
    mask_spec = jax.ShapeDtypeStruct((q_cap,), jnp.bool_)
    scalar_spec = jax.ShapeDtypeStruct((), jnp.float32)

    def round_fn(dist, adj, query_mask, now, w_max):
        return sharded_round(dist, adj, adj, *rows, query_mask, now, w_max)

    return (round_fn,
            (dist_spec, adj_spec, mask_spec, scalar_spec, scalar_spec),
            (dist_sh, adj_sh, mask_sh, scalar_sh, scalar_sh), dist_sh)


@functools.lru_cache(maxsize=None)
def _mesh_step_fns(mesh: Mesh, q_axes: Tuple[str, ...], backend,
                   adj_layout: str = "dense", dist_layout: str = "dense"):
    """Jitted mesh step functions + canonical shardings, cached per
    (mesh, lane axes, backend object, adjacency layout, dist layout) so
    every MeshExecutor on the same mesh shares one compile cache (mirroring
    the module-level jits of the local executor; string-named backends
    resolve to process-wide singletons, so the cache key is stable). Under
    ``adj_layout="ell"`` the batch fold / drop runs on the sharded ELL
    pytree and the closures contract a one-shot in-jit densified view —
    bit-identical to the dense layout (see core/sparse_adj.py). Under
    ``dist_layout="row_sparse"`` the closures likewise relax an in-jit
    densified dist and the result repacks into the row-sparse pytree on
    the way out — the shard_map bodies stay layout-oblivious (see
    core/sparse_dist.py)."""
    qa = q_axes[0] if len(q_axes) == 1 else tuple(q_axes)
    sh = dict(
        adj=_adj_shardings(mesh, adj_layout),
        dist=_dist_shardings(mesh, dist_layout, qa),
        emitted=NamedSharding(mesh, P(qa, None, None)),
        now=NamedSharding(mesh, P()),
    )
    closure = make_sharded_closure(mesh, backend, q_axes=q_axes)
    state_sh = BatchedEngineArrays(sh["adj"], sh["dist"], sh["emitted"], sh["now"])
    lane_sh = NamedSharding(mesh, P(qa))

    def ingest_impl(arrays, src, dst, lab, ts, mask, ts_floor,
                    rows, finals_mask, windows, live_mask, w_max):
        adj, now = apply_batch(arrays, src, dst, lab, ts, mask, ts_floor)
        adj_d = _adj_dense(adj)
        dist, shard_rounds, qrounds = closure(
            _dist_dense(arrays.dist), adj_d, adj_d, *rows, live_mask, now,
            w_max)
        out, new = emit_new(arrays, dist, adj, now, finals_mask, windows)
        out = out._replace(dist=_dist_like(arrays.dist, dist))
        return out, new, shard_rounds, qrounds

    def delete_impl(arrays, src, dst, lab, mask, ts_now,
                    rows, finals_mask, windows, live_mask, w_max):
        now = jnp.maximum(arrays.now, ts_now)
        low = now - windows
        valid_before = batched_valid_pairs(arrays.dist, finals_mask, low)
        adj = drop_batch(arrays, src, dst, lab, mask)
        adj_d = _adj_dense(adj)
        q, n, _, k = _dist_logical_shape(arrays.dist)
        dist0 = jnp.full((q, n, n, k), NEG_INF, jnp.float32)
        dist, shard_rounds, qrounds = closure(
            dist0, adj_d, adj_d, *rows, live_mask, now, w_max)
        valid_after = batched_valid_pairs(dist, finals_mask, low)
        invalidated = jnp.logical_and(valid_before, jnp.logical_not(valid_after))
        return (BatchedEngineArrays(adj, _dist_like(arrays.dist, dist),
                                    arrays.emitted, now),
                invalidated, shard_rounds, qrounds)

    def relax_impl(arrays, rows, query_mask, w_max):
        adj_d = _adj_dense(arrays.adj)
        dist, shard_rounds, qrounds = closure(
            _dist_dense(arrays.dist), adj_d, adj_d, *rows, query_mask,
            arrays.now, w_max)
        return (arrays._replace(dist=_dist_like(arrays.dist, dist)),
                shard_rounds, qrounds)

    return dict(
        shardings=sh,
        ingest=jax.jit(ingest_impl, donate_argnums=(0,),
                       out_shardings=(state_sh, sh["emitted"], lane_sh, lane_sh)),
        delete=jax.jit(delete_impl, donate_argnums=(0,),
                       out_shardings=(state_sh, sh["emitted"], lane_sh, lane_sh)),
        relax=jax.jit(relax_impl, donate_argnums=(0,),
                      out_shardings=(state_sh, lane_sh, lane_sh)),
    )


@functools.lru_cache(maxsize=None)
def _mesh_frontier_ingest(mesh: Mesh, q_axes: Tuple[str, ...], backend,
                          f_cap: int, adj_layout: str = "dense",
                          dist_layout: str = "dense"):
    """Jitted frontier ingest for the mesh executor, cached per (mesh, lane
    axes, backend, frontier capacity, layouts) — capacity grows ×2
    like Q/K bucketing, so each step of the auto-growth compiles once and
    the previous steps' entries stay warm for other groups."""
    fns = _mesh_step_fns(mesh, q_axes, backend, adj_layout, dist_layout)
    sh = fns["shardings"]
    qa = q_axes[0] if len(q_axes) == 1 else tuple(q_axes)
    closure = make_sharded_frontier_closure(mesh, backend, f_cap,
                                            q_axes=q_axes)
    state_sh = BatchedEngineArrays(sh["adj"], sh["dist"], sh["emitted"],
                                  sh["now"])
    lane_sh = NamedSharding(mesh, P(qa))

    def ingest_impl(arrays, src, dst, lab, ts, mask, ts_floor,
                    rows, finals_mask, windows, live_mask, w_max):
        adj, now = apply_batch(arrays, src, dst, lab, ts, mask, ts_floor)
        adj_d = _adj_dense(adj)
        dist, shard_rounds, qrounds, rr, fb, seed, mx = closure(
            _dist_dense(arrays.dist), adj_d, adj_d, *rows, live_mask, src,
            mask, now, w_max)
        out, new = emit_new(arrays, dist, adj, now, finals_mask, windows)
        out = out._replace(dist=_dist_like(arrays.dist, dist))
        return out, new, shard_rounds, qrounds, rr, fb, seed, mx

    return jax.jit(
        ingest_impl, donate_argnums=(0,),
        out_shardings=(state_sh, sh["emitted"], lane_sh, lane_sh,
                       lane_sh, lane_sh, lane_sh, lane_sh))


@functools.lru_cache(maxsize=None)
def _mesh_frontier_delete(mesh: Mesh, q_axes: Tuple[str, ...], backend,
                          f_cap: int, adj_layout: str = "dense",
                          dist_layout: str = "dense"):
    """Jitted cone-seeded deletion for the mesh executor, cached per (mesh,
    lane axes, backend, frontier capacity, layouts) — the delete
    twin of :func:`_mesh_frontier_ingest`, sharing its capacity-bucketing
    discipline."""
    fns = _mesh_step_fns(mesh, q_axes, backend, adj_layout, dist_layout)
    sh = fns["shardings"]
    qa = q_axes[0] if len(q_axes) == 1 else tuple(q_axes)
    closure = make_sharded_frontier_delete(mesh, backend, f_cap,
                                           q_axes=q_axes)
    state_sh = BatchedEngineArrays(sh["adj"], sh["dist"], sh["emitted"],
                                  sh["now"])
    lane_sh = NamedSharding(mesh, P(qa))

    def delete_impl(arrays, src, dst, lab, mask, ts_now,
                    rows, finals_mask, windows, live_mask, w_max):
        now = jnp.maximum(arrays.now, ts_now)
        low = now - windows
        valid_before = batched_valid_pairs(arrays.dist, finals_mask, low)
        adj = drop_batch(arrays, src, dst, lab, mask)
        adj_d = _adj_dense(adj)
        dist, shard_rounds, qrounds, rr, fb, seed, mx = closure(
            _dist_dense(arrays.dist), adj_d, adj_d, *rows, live_mask, src,
            mask, now, w_max)
        valid_after = batched_valid_pairs(dist, finals_mask, low)
        invalidated = jnp.logical_and(valid_before,
                                      jnp.logical_not(valid_after))
        return (BatchedEngineArrays(adj, _dist_like(arrays.dist, dist),
                                    arrays.emitted, now),
                invalidated, shard_rounds, qrounds, rr, fb, seed, mx)

    return jax.jit(
        delete_impl, donate_argnums=(0,),
        out_shardings=(state_sh, sh["emitted"], lane_sh, lane_sh,
                       lane_sh, lane_sh, lane_sh, lane_sh))


class MeshExecutor(Executor):
    """Sharded executor: Q lanes over the mesh's data axis (optionally the
    vertex axis over model), convergence-aware per-shard dispatch.

    ``q_multiple`` / ``n_multiple`` advertise the shard counts so the
    engine rounds its lane and vertex capacities to them (inert padding
    lanes land on real shards and are skipped by the mask). State placement
    and every jitted step carry explicit NamedShardings, so checkpoints
    written by a mesh run restore onto a local executor and vice versa
    (arrays are saved logically; placement is re-derived here).
    """

    def __init__(self, mesh: Optional[Mesh] = None, model_axis: int = 1,
                 q_axes: Sequence[str] = ("data",), backend="jnp",
                 frontier: str = "off", frontier_cap: int = 32,
                 adj_layout: str = "dense", ell_cap: int = 8,
                 spill_cap: int = 256, dist_layout: str = "dense",
                 dist_cap: int = 16, dist_ovf_cap: Optional[int] = None):
        super().__init__(backend, frontier=frontier, frontier_cap=frontier_cap,
                         adj_layout=adj_layout, ell_cap=ell_cap,
                         spill_cap=spill_cap, dist_layout=dist_layout,
                         dist_cap=dist_cap, dist_ovf_cap=dist_ovf_cap)
        self.mesh = mesh if mesh is not None else host_mesh(model_axis)
        self.q_axes = tuple(q_axes)
        self.n_shards = int(np.prod([self.mesh.shape[a] for a in self.q_axes]))
        self.n_model = self.mesh.shape["model"]
        self.q_multiple = self.n_shards
        self.n_multiple = self.n_model
        # the RESOLVED backend object keys the cache (stable identity for
        # string-named backends), and its contraction is what the per-shard
        # closure runs — no jnp-oracle hardcode on the mesh path
        fns = _mesh_step_fns(self.mesh, self.q_axes, self.backend,
                             self.adj_layout, self.dist_layout)
        self._sh = fns["shardings"]
        self._jit_ingest = fns["ingest"]
        self._jit_delete = fns["delete"]
        self._jit_relax = fns["relax"]
        # sharded-table cache: rebuilt when the engine's transition table
        # object changes (query lifecycle events), reused across dispatches
        self._rows_src: Optional[BatchedTransitionTable] = None
        self._rows: Optional[Tuple[jnp.ndarray, ...]] = None
        # convergence-aware dispatch accounting (see module docstring)
        self._shard_rounds_total = 0
        self._sync_rounds_total = 0
        self._skipped_shard_rounds_total = 0

    # -- placement -----------------------------------------------------------

    def _put(self, arr: np.ndarray, name: str):
        return jax.device_put(arr, self._sh[name])

    def _put_adj(self, ell):
        # _sh["adj"] is the EllAdjacency-of-shardings tree under
        # adj_layout="ell" (see _adj_shardings): u-rows over 'model',
        # spill ring replicated
        return jax.device_put(ell, self._sh["adj"])

    def _put_dist(self, sd):
        # _sh["dist"] is the RowSparseDist-of-shardings tree under
        # dist_layout="row_sparse" (see _dist_shardings): source rows over
        # the lane axes, overflow table + counters replicated
        return jax.device_put(sd, self._sh["dist"])

    def _rows_for(self, btt: BatchedTransitionTable, q_cap: int):
        if self._rows_src is not btt:
            self._rows = shard_transitions(btt, q_cap, self.n_shards)
            self._rows_src = btt
        return self._rows

    # -- Executor interface --------------------------------------------------

    def ingest_batch(self, src, dst, lab, ts, mask, ts_floor: float,
                     tables: QueryTables):
        q_cap = self.dist_shape[0]
        rows = self._rows_for(tables.btt, q_cap)
        if self.adj_layout == "ell":
            self._reserve_spill(len(src))
        if self.dist_layout == "row_sparse":
            self._reserve_dist(self.frontier != "off")
        if self.frontier != "off":
            ingest = _mesh_frontier_ingest(
                self.mesh, self.q_axes, self.backend, self.frontier_cap,
                self.adj_layout, self.dist_layout)
            (self._arrays, new, shard_rounds, qrounds,
             rr, fb, seed, mx) = ingest(
                self._arrays,
                jnp.asarray(src), jnp.asarray(dst), jnp.asarray(lab),
                jnp.asarray(ts), jnp.asarray(mask),
                jnp.asarray(ts_floor, jnp.float32),
                rows, tables.finals_mask, tables.windows, tables.live_mask,
                jnp.asarray(tables.max_window, jnp.float32),
            )
            self._account(shard_rounds, qrounds, tables.n_live,
                          FrontierStats(seed, mx, rr, fb))
            self.steps += 1
            return new
        self._arrays, new, shard_rounds, qrounds = self._jit_ingest(
            self._arrays,
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(lab),
            jnp.asarray(ts), jnp.asarray(mask),
            jnp.asarray(ts_floor, jnp.float32),
            rows, tables.finals_mask, tables.windows, tables.live_mask,
            jnp.asarray(tables.max_window, jnp.float32),
        )
        self._account(shard_rounds, qrounds, tables.n_live)
        self.steps += 1
        return new

    def delete_batch(self, src, dst, lab, mask, ts_now: float,
                     tables: QueryTables):
        q_cap = self.dist_shape[0]
        rows = self._rows_for(tables.btt, q_cap)
        if self.dist_layout == "row_sparse":
            self._reserve_dist(self.frontier != "off")
        if self.frontier != "off":
            delete = _mesh_frontier_delete(
                self.mesh, self.q_axes, self.backend, self.frontier_cap,
                self.adj_layout, self.dist_layout)
            (self._arrays, invalidated, shard_rounds, qrounds,
             rr, fb, seed, mx) = delete(
                self._arrays,
                jnp.asarray(src), jnp.asarray(dst), jnp.asarray(lab),
                jnp.asarray(mask), jnp.asarray(ts_now, jnp.float32),
                rows, tables.finals_mask, tables.windows, tables.live_mask,
                jnp.asarray(tables.max_window, jnp.float32),
            )
            self._account(shard_rounds, qrounds, tables.n_live,
                          FrontierStats(seed, mx, rr, fb), is_delete=True)
            self.steps += 1
            return invalidated
        self._arrays, invalidated, shard_rounds, qrounds = self._jit_delete(
            self._arrays,
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(lab),
            jnp.asarray(mask), jnp.asarray(ts_now, jnp.float32),
            rows, tables.finals_mask, tables.windows, tables.live_mask,
            jnp.asarray(tables.max_window, jnp.float32),
        )
        self._account(shard_rounds, qrounds, tables.n_live)
        self.steps += 1
        return invalidated

    def relax(self, tables: QueryTables,
              query_mask: Optional[np.ndarray] = None) -> None:
        q_cap = self.dist_shape[0]
        rows = self._rows_for(tables.btt, q_cap)
        if self.dist_layout == "row_sparse":
            self._reserve_dist(False)
        mask = tables.live_mask if query_mask is None else jnp.asarray(
            np.asarray(query_mask, bool))
        self._arrays, shard_rounds, qrounds = self._jit_relax(
            self._arrays, rows, mask,
            jnp.asarray(tables.max_window, jnp.float32))
        self._account(shard_rounds, qrounds, tables.n_live)

    # -- accounting ----------------------------------------------------------

    def _consume_count(self, shard_rounds, qrounds, n_live: int) -> None:
        sr = np.asarray(shard_rounds)
        sync = int(sr.max()) if sr.size else 0
        self._rounds_total += sync
        self._sync_rounds_total += sync
        self._shard_rounds_total += int(sr.sum())
        self._skipped_shard_rounds_total += int((sync - sr).sum())
        self._query_rounds_total += int(np.asarray(qrounds).sum())
        self._unmasked_query_rounds_total += n_live * sync

    @property
    def shard_rounds_total(self) -> int:
        """Rounds shards ACTUALLY relaxed (skip-aware), summed over shards
        and dispatches."""
        self._flush_counts()
        return self._shard_rounds_total

    @property
    def sync_rounds_total(self) -> int:
        """Per-dispatch max over shards, summed — the rounds every shard
        would ride in a convergence-oblivious (bulk-synchronous) regime."""
        self._flush_counts()
        return self._sync_rounds_total

    @property
    def skipped_shard_rounds_total(self) -> int:
        """Shard-rounds of contraction work the convergence-aware dispatch
        skipped: ``n_shards * sync_rounds_total - shard_rounds_total``."""
        self._flush_counts()
        return self._skipped_shard_rounds_total
