"""R5 — accounting hygiene.

Two bug classes, both shipped and fixed in past PRs:

* **Quadratic FIFO drains** (PR 6): ``list.pop(0)`` shifts the whole
  list — O(n) per pop, O(n²) per drain. Service queues and the
  async-decode FIFO are deques now; any ``.pop(0)`` / ``.insert(0, _)``
  reintroduces the class.
* **Eager counter flushes**: the executors queue device scalars
  (``_pending_counts``) and convert them only at the sanctioned flush
  sites (``_flush_counts`` / ``_consume_count`` / ``_consume_frontier``,
  plus the supervisor's ``_flush_health`` telemetry interval)
  so the hot ingest path never blocks on a device→host sync. A
  ``float(...now)`` or ``np.asarray(rounds)`` anywhere else serializes
  the async dispatch chain behind a telemetry read — the engine keeps a
  host clock mirror (``host_now``) for exactly this.

Flagged, project-wide:

* ``x.pop(0)`` and ``x.insert(0, ...)``
* ``float()`` / ``int()`` / ``bool()`` / ``np.asarray`` / ``np.float32``
  over an expression containing a ``.now`` attribute read, unless the
  expression routes through ``jax.device_get`` (an explicit, sanctioned
  sync) or the enclosing function is a sanctioned flush site
* ``np.asarray`` of a name matching ``*rounds``/``*counts`` outside the
  sanctioned flush sites
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, Optional

from ..analyzer import Finding, Module, Project, dotted

RULE = "R5"
TITLE = "accounting hygiene (FIFO drains, eager device-scalar reads)"

#: `_flush_health` is the supervisor's per-interval telemetry flush
#: (streaming/supervisor.py): like the executor flush sites it reads
#: host-known counters between dispatches, never on the hot path
_SANCTIONED_FNS = ("_flush_counts", "_consume_count", "_consume_frontier",
                   "_flush_health")
_COUNTER_NAME_RE = re.compile(r"(rounds|counts)$")
_CONVERTERS = ("float", "int", "bool", "np.asarray", "np.array",
               "np.float32", "np.float64", "numpy.asarray")


def _contains_now_attr(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr == "now"
               for n in ast.walk(node))


def _contains_explicit_sync(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            f = dotted(n.func).rsplit(".", 1)[-1]
            if f in ("device_get", "block_until_ready"):
                return True
        if isinstance(n, ast.Attribute) and n.attr == "block_until_ready":
            return True
    return False


def _enclosing_fn(mod: Module, node: ast.AST) -> Optional[str]:
    """Innermost function qualname containing the node's line (the func
    index spans are enough — rules don't need a parent map)."""
    best, best_span = None, None
    for qual, fn in mod.funcs.items():
        end = getattr(fn, "end_lineno", fn.lineno)
        if fn.lineno <= node.lineno <= end:
            span = end - fn.lineno
            if best_span is None or span < best_span:
                best, best_span = qual, span
    return best


def check(project: Project) -> Iterator[Finding]:
    for mod in project:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            # -- FIFO drains ----------------------------------------------
            if isinstance(func, ast.Attribute):
                if (func.attr == "pop" and node.args
                        and isinstance(node.args[0], ast.Constant)
                        and node.args[0].value == 0):
                    yield Finding(
                        RULE, mod.relpath, node.lineno, node.col_offset,
                        "`pop(0)` is O(n) per pop (O(n^2) per drain) — "
                        "use collections.deque.popleft()")
                    continue
                if (func.attr == "insert" and node.args
                        and isinstance(node.args[0], ast.Constant)
                        and node.args[0].value == 0):
                    yield Finding(
                        RULE, mod.relpath, node.lineno, node.col_offset,
                        "`insert(0, ...)` shifts the whole list — use "
                        "collections.deque.appendleft()")
                    continue
            # -- eager device-scalar reads --------------------------------
            conv = dotted(func)
            if conv not in _CONVERTERS or not node.args:
                continue
            arg = node.args[0]
            enclosing = _enclosing_fn(mod, node)
            fn_name = (enclosing or "").rsplit(".", 1)[-1]
            if fn_name in _SANCTIONED_FNS:
                continue
            if _contains_now_attr(arg) and not _contains_explicit_sync(arg):
                yield Finding(
                    RULE, mod.relpath, node.lineno, node.col_offset,
                    f"eager `{conv}()` of the device stream clock "
                    "serializes async dispatch — read the host mirror "
                    "(`host_now`) or go through jax.device_get at a "
                    "flush site")
            elif (conv.endswith("asarray") and isinstance(arg, ast.Name)
                  and _COUNTER_NAME_RE.search(arg.id)):
                yield Finding(
                    RULE, mod.relpath, node.lineno, node.col_offset,
                    f"eager counter read `{conv}({arg.id})` outside the "
                    "sanctioned flush sites — queue it via _account and "
                    "convert in _flush_counts")
