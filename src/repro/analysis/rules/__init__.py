"""Rule registry. Each rule is a module exposing ``RULE`` (the id used in
findings and ``# repro: noqa[...]``), ``TITLE``, and ``check(project)``
yielding :class:`~repro.analysis.analyzer.Finding`."""
from . import (r1_jit_boundary, r2_recompile, r3_kernel_contracts,
               r4_backend_conformance, r5_accounting)

ALL_RULES = (r1_jit_boundary, r2_recompile, r3_kernel_contracts,
             r4_backend_conformance, r5_accounting)

__all__ = ["ALL_RULES"]
