"""R3 — kernel contracts (scope: ``kernels/``).

Invariant: Pallas block sizes come from ``pick_block_sizes`` (the shared
shape-aware table in kernels/maxmin/maxmin.py), not hand-written
literals, so every kernel follows the same VMEM-budget and
lane-alignment rules and the autotune campaign (ROADMAP) has a single
table to retune. And grid index maps must be pure functions of the grid
indices — an index map that closes over module state changes meaning
under the jit compile cache (the lambda identity is the cache key, its
captured value is not).

Flagged, in files under ``kernels/``:

* int literals >= 8 inside the block-shape tuple of ``pl.BlockSpec`` or
  a VMEM scratch shape (``pltpu.VMEM``) — small structural dims (1, a
  level count) stay legal, real tile sizes must be named values derived
  from ``pick_block_sizes``
* ``BlockSpec`` index-map lambdas whose body reads a module-level name
  (captured module state)
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from ..analyzer import Finding, Module, Project

RULE = "R3"
TITLE = "kernel contracts (literal block sizes, stateful index maps)"

_SHAPE_CTORS = ("BlockSpec", "VMEM", "SMEM", "ANY")
_MIN_TILE_LITERAL = 8


def _module_level_names(mod: Module) -> Set[str]:
    names: Set[str] = set()
    for n in mod.tree.body:
        if isinstance(n, ast.Assign):
            for t in n.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(n, (ast.AnnAssign, ast.AugAssign)):
            if isinstance(n.target, ast.Name):
                names.add(n.target.id)
    return names


def _ctor_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def check(project: Project) -> Iterator[Finding]:
    for mod in project:
        if "kernels/" not in mod.relpath:
            continue
        globals_ = _module_level_names(mod)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            ctor = _ctor_name(node)
            if ctor not in _SHAPE_CTORS:
                continue
            shape = node.args[0] if node.args else None
            for kw in node.keywords:
                if kw.arg == "block_shape":
                    shape = kw.value
            if isinstance(shape, ast.Tuple):
                for elt in shape.elts:
                    if (isinstance(elt, ast.Constant)
                            and isinstance(elt.value, int)
                            and elt.value >= _MIN_TILE_LITERAL):
                        yield Finding(
                            RULE, mod.relpath, elt.lineno, elt.col_offset,
                            f"literal tile size {elt.value} in "
                            f"`{ctor}` shape — block sizes must come from "
                            "pick_block_sizes")
            if ctor != "BlockSpec":
                continue
            index_map = node.args[1] if len(node.args) > 1 else None
            for kw in node.keywords:
                if kw.arg == "index_map":
                    index_map = kw.value
            if not isinstance(index_map, ast.Lambda):
                continue
            params = {a.arg for a in index_map.args.args}
            for n in ast.walk(index_map.body):
                if (isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
                        and n.id not in params and n.id in globals_):
                    yield Finding(
                        RULE, mod.relpath, n.lineno, n.col_offset,
                        f"index map captures module state `{n.id}` — index "
                        "maps must be pure functions of the grid indices")
