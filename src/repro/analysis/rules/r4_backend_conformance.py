"""R4 — backend conformance.

Invariant: the ``ContractionBackend`` hook set is the engine's hardware
ABI — every subclass must define the full set (a subclass that forgets
``contract_rows`` silently inherits the base and only breaks on the
frontier path, possibly only on the mesh), and every *string* backend
reference must resolve against ``KNOWN_BACKENDS``. The motivating bug is
PR 4's ``"palas"`` typo: a misspelled backend name silently fell back to
the jnp oracle and the Pallas kernels never ran — benchmarks measured
the wrong engine.

Flagged, project-wide:

* a class whose bases include ``ContractionBackend`` for which any of
  the hook set ``contract`` / ``contract_rows`` / ``contract_batched`` /
  ``prepare_state`` / ``decode_state`` / ``zero`` / ``exact`` fails to
  resolve concretely: hooks the base leaves abstract (body raises
  ``NotImplementedError``) must be defined in the subclass; hooks with a
  concrete base default (the identity representation, the generic
  gather) may be inherited
* a string literal backend reference (``backend="..."`` keyword or
  default, or the first argument of ``resolve_backend``) not in
  ``KNOWN_BACKENDS`` — read from ``core/backend.py``'s AST when present
  so the rule tracks the real registry
"""
from __future__ import annotations

import ast
from typing import Iterator, Set, Tuple

from ..analyzer import Finding, Project, dotted

RULE = "R4"
TITLE = "backend conformance (hook set, KNOWN_BACKENDS resolution)"

REQUIRED_HOOKS = ("contract", "contract_rows", "contract_batched",
                  "prepare_state", "decode_state", "zero", "exact")
_FALLBACK_KNOWN = ("jnp", "pallas", "mxu_bucket")


def _known_backends(project: Project) -> Tuple[str, ...]:
    mod = project.by_suffix("core/backend.py")
    if mod is None:
        return _FALLBACK_KNOWN
    for n in mod.tree.body:
        if isinstance(n, ast.Assign):
            for t in n.targets:
                if isinstance(t, ast.Name) and t.id == "KNOWN_BACKENDS":
                    if isinstance(n.value, (ast.Tuple, ast.List)):
                        vals = tuple(
                            e.value for e in n.value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str))
                        if vals:
                            return vals
    return _FALLBACK_KNOWN


def _class_defines(cls: ast.ClassDef) -> Set[str]:
    names: Set[str] = set()
    for n in cls.body:
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(n.name)
        elif isinstance(n, ast.Assign):
            names.update(t.id for t in n.targets if isinstance(t, ast.Name))
        elif isinstance(n, ast.AnnAssign) and isinstance(n.target, ast.Name):
            names.add(n.target.id)
    return names


def _raises_not_implemented(fn: ast.AST) -> bool:
    for n in ast.walk(fn):
        if isinstance(n, ast.Raise) and n.exc is not None:
            exc = n.exc.func if isinstance(n.exc, ast.Call) else n.exc
            if dotted(exc).rsplit(".", 1)[-1] == "NotImplementedError":
                return True
    return False


def _abstract_hooks(project: Project) -> Set[str]:
    """Hooks the base class leaves abstract — a subclass MUST define
    these; the rest have concrete base defaults and may be inherited.
    With no base class in scope (rule fixtures), the full set is
    required."""
    for mod in project:
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.ClassDef)
                    and node.name == "ContractionBackend"):
                concrete: Set[str] = set()
                for n in node.body:
                    if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        if (n.name in REQUIRED_HOOKS
                                and not _raises_not_implemented(n)):
                            concrete.add(n.name)
                    elif isinstance(n, ast.Assign):
                        concrete.update(
                            t.id for t in n.targets
                            if isinstance(t, ast.Name)
                            and t.id in REQUIRED_HOOKS)
                    elif (isinstance(n, ast.AnnAssign)
                          and isinstance(n.target, ast.Name)
                          and n.target.id in REQUIRED_HOOKS
                          and n.value is not None):
                        concrete.add(n.target.id)
                return set(REQUIRED_HOOKS) - concrete
    return set(REQUIRED_HOOKS)


def check(project: Project) -> Iterator[Finding]:
    known = _known_backends(project)
    must_define = _abstract_hooks(project)
    for mod in project:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                if not any(
                        dotted(b).rsplit(".", 1)[-1] == "ContractionBackend"
                        for b in node.bases):
                    continue
                missing = [h for h in REQUIRED_HOOKS
                           if h in must_define
                           and h not in _class_defines(node)]
                if missing:
                    yield Finding(
                        RULE, mod.relpath, node.lineno, node.col_offset,
                        f"backend `{node.name}` missing hook(s) "
                        f"{', '.join(missing)} — every abstract "
                        "ContractionBackend hook must be defined")
            elif isinstance(node, ast.Call):
                callee = dotted(node.func).rsplit(".", 1)[-1]
                if (callee == "resolve_backend" and node.args
                        and isinstance(node.args[0], ast.Constant)
                        and isinstance(node.args[0].value, str)
                        and node.args[0].value not in known):
                    yield Finding(
                        RULE, mod.relpath, node.args[0].lineno,
                        node.args[0].col_offset,
                        f"backend name '{node.args[0].value}' not in "
                        f"KNOWN_BACKENDS {known}")
                for kw in node.keywords:
                    if (kw.arg == "backend"
                            and isinstance(kw.value, ast.Constant)
                            and isinstance(kw.value.value, str)
                            and kw.value.value not in known):
                        yield Finding(
                            RULE, mod.relpath, kw.value.lineno,
                            kw.value.col_offset,
                            f"backend name '{kw.value.value}' not in "
                            f"KNOWN_BACKENDS {known}")
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                pairs = list(zip(reversed(args.args), reversed(args.defaults)))
                pairs += [(a, d) for a, d in zip(args.kwonlyargs,
                                                 args.kw_defaults)
                          if d is not None]
                for arg, default in pairs:
                    if (arg.arg == "backend"
                            and isinstance(default, ast.Constant)
                            and isinstance(default.value, str)
                            and default.value not in known):
                        yield Finding(
                            RULE, mod.relpath, default.lineno,
                            default.col_offset,
                            f"default backend '{default.value}' not in "
                            f"KNOWN_BACKENDS {known}")
