"""R1 — jit-boundary hygiene.

Invariant: functions reachable from a jitted dispatch entry point (the
``@jax.jit`` impls in core/executor.py, the semiring rounds they call,
the mesh step fns and shard_map bodies in distributed/executor.py) must
never force a device→host sync or branch Python control flow on a tracer.
A single ``.item()`` / ``np.asarray`` / ``float(tracer)`` inside the
traced region either raises a ``TracerConversionError`` at trace time or
— worse, when it happens on a concrete leak — silently serializes the
async dispatch pipeline the whole executor design exists to keep full.

Flagged inside jit-reachable functions:

* ``x.item()`` — unconditional host sync
* ``np.asarray`` / ``np.array`` / ``np.ascontiguousarray`` — numpy pulls
  the operand to host; traced values must stay ``jnp``
* ``float(x)`` / ``int(x)`` / ``bool(x)`` on non-static expressions
  (shape/ndim/len arithmetic stays legal — those are Python ints at
  trace time)
* ``len(x.attr)`` — ``len()`` of device state (carried arrays); ``len``
  of tuples/lists by name stays legal
* Python ``if`` whose test calls into ``jnp.*`` — a tracer boolean;
  inside jit this must be ``lax.cond``/``jnp.where``

The call graph is described in :mod:`repro.analysis.analyzer`; attribute
calls (backend method dispatch) are not traversed.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..analyzer import Finding, Project, dotted, is_static_expr, scan_region

RULE = "R1"
TITLE = "jit-boundary hygiene (host syncs inside traced dispatch)"

_NP_NAMES = ("np", "numpy", "onp")
_NP_SYNC_FUNCS = ("asarray", "array", "ascontiguousarray")
_CAST_FUNCS = ("float", "int", "bool")


def _finding(mod, node, qual, msg) -> Finding:
    return Finding(RULE, mod.relpath, node.lineno, node.col_offset,
                   f"{msg} inside jit-reachable function `{qual}`")


def _test_touches_jnp(test: ast.AST) -> bool:
    for n in ast.walk(test):
        if isinstance(n, ast.Call):
            d = dotted(n.func)
            if d.startswith("jnp.") or d.startswith("jax.numpy."):
                return True
    return False


def check(project: Project) -> Iterator[Finding]:
    graph = project.callgraph()
    for mod, qual, fn in graph.reachable_functions():
        for n in scan_region(fn):
            if isinstance(n, ast.If) and _test_touches_jnp(n.test):
                yield _finding(
                    mod, n, qual,
                    "Python `if` on a jnp (tracer) value — use lax.cond/"
                    "jnp.where")
                continue
            if not isinstance(n, ast.Call):
                continue
            func = n.func
            if (isinstance(func, ast.Attribute) and func.attr == "item"
                    and not n.args):
                yield _finding(mod, n, qual, "host sync `.item()`")
            elif (isinstance(func, ast.Attribute)
                  and isinstance(func.value, ast.Name)
                  and func.value.id in _NP_NAMES
                  and func.attr in _NP_SYNC_FUNCS):
                yield _finding(
                    mod, n, qual,
                    f"`{func.value.id}.{func.attr}` forces device->host; "
                    "traced values must stay jnp")
            elif (isinstance(func, ast.Name) and func.id in _CAST_FUNCS
                  and len(n.args) == 1):
                arg = n.args[0]
                if is_static_expr(arg):
                    continue
                # Name args are unknowable statically — only flag
                # attribute chains (device state) and call results
                if isinstance(arg, (ast.Attribute, ast.Call)) or (
                        isinstance(arg, ast.Subscript)
                        and isinstance(arg.value, ast.Attribute)):
                    yield _finding(
                        mod, n, qual,
                        f"`{func.id}()` of a non-static value is a host "
                        "sync under trace")
            elif (isinstance(func, ast.Name) and func.id == "len"
                  and len(n.args) == 1
                  and isinstance(n.args[0], ast.Attribute)
                  and not is_static_expr(n.args[0])):
                yield _finding(
                    mod, n, qual,
                    "`len()` of device state — use a static `.shape` dim")
