"""R2 — recompile hazards.

Invariant: every traced-shape capacity (``f_cap``, ``frontier_cap``,
``q_cap``, ``n_slots``, Q/K pads, the ELL degree/spill-ring caps
``ell_cap``/``spill_cap``, the row-sparse dist slot/overflow caps
``dist_cap``/``ovf_cap``) is bucketed — pow2 growth via
``_next_pow2``, multiple-round-up via ``_round_up``, or ×2 doubling of an
already-bucketed value — so the jit compile cache is shared across
capacity steps instead of recompiling per exact size. Raw capacity
arithmetic (``n + (-n) % k`` inline, literal non-pow2 caps) silently
reintroduces one-compile-per-shape; that is exactly the hazard the
engine's ``Q_BUCKET``/``LABEL_BUCKET`` and the executor's frontier
auto-growth were built to avoid.

Second hazard class: unhashable arguments reaching ``lru_cache``-wrapped
dispatch factories (the mesh step-fn caches key on
``(mesh, q_axes, backend)``) — a list/dict/set literal in such a call
raises ``TypeError: unhashable`` only at runtime, on the rarely-hit
cache path.

Flagged:

* assignment to a capacity-named target whose RHS does raw arithmetic or
  a non-power-of-two int literal without routing through a bucketing
  helper (``_next_pow2`` / ``_round_up`` / ``pick_block_sizes``),
  doubling (``cap * 2``, ``cap <<= 1``), a ``.shape`` mirror, or a plain
  alias of an already-bucketed name
* calls to an ``lru_cache``-decorated function (same module or imported)
  with a list/dict/set literal or comprehension argument
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, Set, Tuple

from ..analyzer import Finding, Module, Project, dotted

RULE = "R2"
TITLE = "recompile hazards (un-bucketed capacities, unhashable cache keys)"

_CAP_RE = re.compile(
    r"(?:^|_)(f_cap|frontier_cap|q_cap|k_cap|n_cap|n_slots|q_pad|k_pad"
    r"|ell_cap|spill_cap|dist_cap|ovf_cap)$")
_BUCKET_HELPERS = {
    "_next_pow2", "next_pow2", "_round_up", "round_up", "pick_block_sizes",
}
_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp)


def _target_cap_name(target: ast.AST) -> str:
    name = ""
    if isinstance(target, ast.Name):
        name = target.id
    elif isinstance(target, ast.Attribute):
        name = target.attr
    m = _CAP_RE.search(name.lstrip("_"))
    return name if m else ""


def _is_pow2(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool) and (
        v in (0, 1) or (v > 0 and (v & (v - 1)) == 0))


def _rhs_is_bucketed(node: ast.AST, cap_name: str) -> bool:
    """True when the value expression provably rides the bucketing
    discipline (helper call / doubling / shape mirror / alias)."""
    if isinstance(node, (ast.Name, ast.Attribute)):
        # alias of an existing (already bucketed) value; .shape mirrors
        return True
    if isinstance(node, ast.Constant):
        # None is the "unset, sized later" sentinel (e.g. dist_ovf_cap
        # before first placement), not a capacity value
        return node.value is None or _is_pow2(node.value)
    if isinstance(node, ast.Call):
        f = dotted(node.func).rsplit(".", 1)[-1]
        if f in _BUCKET_HELPERS:
            return True
        if f in ("int", "float", "min", "max"):
            return all(_rhs_is_bucketed(a, cap_name) for a in node.args)
        return False
    if isinstance(node, ast.IfExp):
        return (_rhs_is_bucketed(node.body, cap_name)
                and _rhs_is_bucketed(node.orelse, cap_name))
    if isinstance(node, ast.BinOp):
        if isinstance(node.op, ast.Mult):
            for a, b in ((node.left, node.right), (node.right, node.left)):
                if (isinstance(a, ast.Constant) and a.value == 2
                        and _rhs_is_bucketed(b, cap_name)):
                    return True
        if (isinstance(node.op, ast.LShift)
                and isinstance(node.right, ast.Constant)):
            return _rhs_is_bucketed(node.left, cap_name)
        return False
    if isinstance(node, ast.Subscript):
        return _rhs_is_bucketed(node.value, cap_name)
    return False


def _lru_cached_names(mod: Module) -> Set[str]:
    out: Set[str] = set()
    for qual, fn in mod.funcs.items():
        decs = getattr(fn, "decorator_list", [])
        for d in decs:
            for n in ast.walk(d):
                if (isinstance(n, ast.Attribute) and n.attr in
                        ("lru_cache", "cache")) or (
                        isinstance(n, ast.Name) and n.id in
                        ("lru_cache", "cache")):
                    out.add(qual.rsplit(".", 1)[-1])
    return out


def check(project: Project) -> Iterator[Finding]:
    cached_by_mod = {m.dotted: _lru_cached_names(m) for m in project}
    for mod in project:
        for node in ast.walk(mod.tree):
            # -- capacity assignments --------------------------------------
            targets: Tuple[ast.AST, ...] = ()
            value = None
            if isinstance(node, ast.Assign):
                targets, value = tuple(node.targets), node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = (node.target,), node.value
            elif isinstance(node, ast.AugAssign):
                # cap *= 2 / cap <<= 1 are the sanctioned growth steps
                if _target_cap_name(node.target) and not (
                        (isinstance(node.op, ast.Mult)
                         and isinstance(node.value, ast.Constant)
                         and node.value.value == 2)
                        or isinstance(node.op, ast.LShift)):
                    yield Finding(
                        RULE, mod.relpath, node.lineno, node.col_offset,
                        f"augmented capacity update to "
                        f"`{_target_cap_name(node.target)}` is not a x2 "
                        "doubling — route through _next_pow2/_round_up")
                continue
            for t in targets:
                cap = _target_cap_name(t)
                if cap and value is not None and not _rhs_is_bucketed(
                        value, cap):
                    yield Finding(
                        RULE, mod.relpath, node.lineno, node.col_offset,
                        f"capacity `{cap}` assigned from raw arithmetic/"
                        "literal — route through _next_pow2/_round_up so "
                        "the jit compile cache stays shared")
            # -- unhashable lru_cache arguments ----------------------------
            if isinstance(node, ast.Call):
                callee = dotted(node.func)
                if not callee or "." in callee:
                    continue
                target_mod = mod.dotted
                name = callee
                if name not in cached_by_mod.get(target_mod, ()):  # local?
                    imp = mod.imports.get(name)
                    if imp is None or imp[1] not in cached_by_mod.get(
                            imp[0], ()):
                        continue
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    if isinstance(arg, _UNHASHABLE):
                        yield Finding(
                            RULE, mod.relpath, arg.lineno, arg.col_offset,
                            f"unhashable literal passed to lru_cache'd "
                            f"`{name}` — raises TypeError at call time; "
                            "use a tuple")
