"""Dispatch-hygiene static analysis for the streaming-RPQ engine.

An AST-based rule engine that mechanically enforces the invariants six
PRs of layering produced but nothing checked: jitted dispatch paths stay
host-sync-free (R1), traced-shape capacities ride the pow2/x4 bucketing
that keeps the compile cache shared (R2), Pallas kernels take their block
sizes from ``pick_block_sizes`` and keep index maps pure (R3), every
``ContractionBackend`` implements the full hook set and every string
backend name resolves against ``KNOWN_BACKENDS`` (R4), and FIFO/counter
paths stay amortized-O(1) and lazy (R5).

Pure stdlib — importing or running this package never imports jax, so the
CI gate runs on a bare interpreter. See docs/invariants.md for the rule
catalog and ``# repro: noqa[RULE]`` suppression syntax.

Usage::

    PYTHONPATH=src python -m repro.analysis src/ --format=json
"""
from .analyzer import Finding, Module, Project, load_project, run  # noqa: F401

__all__ = ["Finding", "Module", "Project", "load_project", "run"]
