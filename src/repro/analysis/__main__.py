"""CLI: ``python -m repro.analysis [paths...] [--format=text|json]``.

Exits non-zero iff any unsuppressed finding remains — the CI `analysis`
job and ``benchmarks/run.py --check`` both gate on this.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from .analyzer import format_json, format_text, run


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Dispatch-hygiene static analysis (rules R1-R5; see "
                    "docs/invariants.md)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to analyze (default: src)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--rules", default="",
                    help="comma-separated rule subset, e.g. R1,R5")
    args = ap.parse_args(argv)

    paths = args.paths or ["src"]
    rules = [r for r in args.rules.split(",") if r.strip()] or None
    findings, n_files = run(paths, rules)
    if args.format == "json":
        print(format_json(findings, n_files))
    else:
        print(format_text(findings, n_files))
    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
