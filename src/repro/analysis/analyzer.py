"""The rule-engine core: module loading, noqa suppression, the jit
call graph, and shared AST predicates.

Everything here is plain ``ast`` — no jax import, so the analyzer runs
as a CI gate on a bare interpreter before the test deps install.

Model
-----
``load_project(paths)`` parses every ``*.py`` under the given paths into
:class:`Module` objects (source + AST + per-line noqa directives) and
wraps them in a :class:`Project`. Rules (see ``rules/``) are modules with
``RULE``/``TITLE`` constants and a ``check(project)`` generator yielding
:class:`Finding`; :func:`run` applies the suppression directives and
returns the findings plus the file count.

Suppressions: ``# repro: noqa[R1]`` (or ``noqa[R1,R5]``) on the flagged
line suppresses those rules there; a bare ``# repro: noqa`` suppresses
every rule on the line. Suppressed findings are still reported (marked),
so a justification comment stays reviewable, but they don't fail the
gate.

The jit call graph (:class:`CallGraph`) is what scopes rule R1: roots
are functions decorated with ``jax.jit`` (directly or through
``functools.partial``), functions passed by name to a ``jit``/``pjit``
call or to ``shard_map``, and everything reachable from those through
same-module calls, cross-module ``from X import f`` calls, and
function-reference arguments (``lax.cond(pred, run, ...)``). Attribute
calls (``backend.contract``) are not resolved — method dispatch is out
of scope and documented as such in docs/invariants.md.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\[([A-Za-z0-9_,\s]+)\])?")

# scopes that stop a region scan: nodes inside them belong to the nested
# scope, not the one being scanned (lambdas stay inline — they trace and
# execute in the enclosing scope)
_SCOPE_TYPES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def _dotted_name(relpath: str) -> str:
    """'src/repro/core/executor.py' -> 'repro.core.executor' (what the
    import resolver keys on). Fixture files without the src/ prefix keep
    their path-derived name."""
    p = relpath.replace(os.sep, "/").lstrip("./")
    if "/src/" in p:
        p = p.split("/src/", 1)[1]
    elif p.startswith("src/"):
        p = p[len("src/"):]
    if p.endswith(".py"):
        p = p[: -len(".py")]
    if p.endswith("/__init__"):
        p = p[: -len("/__init__")]
    return p.replace("/", ".")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def format(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}{tag}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Module:
    """One parsed source file: AST, raw lines, noqa directives, and the
    function/import indexes the call graph and rules share."""

    def __init__(self, relpath: str, source: str):
        self.relpath = relpath.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source)
        self.dotted = _dotted_name(self.relpath)
        # line -> suppressed rule ids; empty set means "all rules"
        self.noqa: Dict[int, Set[str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _NOQA_RE.search(line)
            if m:
                codes = m.group(1)
                self.noqa[i] = (
                    {c.strip().upper() for c in codes.split(",") if c.strip()}
                    if codes else set())
        self.funcs: Dict[str, ast.AST] = {}
        self._index_functions()
        self.imports: Dict[str, Tuple[str, str]] = {}
        self._index_imports()

    # -- indexes ------------------------------------------------------------

    def _index_functions(self) -> None:
        def rec(scope: ast.AST, qual: str) -> None:
            for n in scan_region(scope):
                if isinstance(n, _SCOPE_TYPES):
                    q = f"{qual}.{n.name}" if qual else n.name
                    if not isinstance(n, ast.ClassDef):
                        self.funcs[q] = n
                    rec(n, q)

        rec(self.tree, "")

    def _index_imports(self) -> None:
        pkg = self.dotted.rsplit(".", 1)[0] if "." in self.dotted else ""
        for n in ast.walk(self.tree):
            if not isinstance(n, ast.ImportFrom):
                continue
            if n.level:
                base = pkg.split(".") if pkg else []
                base = base[: len(base) - (n.level - 1)] if n.level > 1 else base
                target = ".".join(base + (n.module.split(".") if n.module else []))
            else:
                target = n.module or ""
            for alias in n.names:
                self.imports[alias.asname or alias.name] = (target, alias.name)

    # -- suppression --------------------------------------------------------

    def is_suppressed(self, line: int, rule: str) -> bool:
        codes = self.noqa.get(line)
        if codes is None:
            return False
        return not codes or rule.upper() in codes


class Project:
    def __init__(self, modules: Sequence[Module]):
        self.modules: Dict[str, Module] = {m.dotted: m for m in modules}
        self.by_path: Dict[str, Module] = {m.relpath: m for m in modules}
        self._callgraph: Optional[CallGraph] = None

    def __iter__(self) -> Iterator[Module]:
        return iter(self.modules.values())

    def by_suffix(self, suffix: str) -> Optional[Module]:
        for m in self.modules.values():
            if m.relpath.endswith(suffix):
                return m
        return None

    def callgraph(self) -> "CallGraph":
        if self._callgraph is None:
            self._callgraph = CallGraph(self)
        return self._callgraph


# -- AST helpers shared by the rules ----------------------------------------


def scan_region(node: ast.AST) -> Iterator[ast.AST]:
    """Yield every node in ``node``'s own scope, without descending into
    nested function/class definitions (the defs themselves ARE yielded;
    lambdas are descended — they run inline)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, _SCOPE_TYPES):
            stack.extend(ast.iter_child_nodes(n))


def dotted(node: ast.AST) -> str:
    """'jax.jit' for Attribute(Name('jax'), 'jit'); '' if unresolvable."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def mentions_jit(node: ast.AST) -> bool:
    """True if the expression names jit/pjit anywhere — covers
    ``@jax.jit``, ``@functools.partial(jax.jit, ...)``, ``jit(f)``."""
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in ("jit", "pjit"):
            return True
        if isinstance(n, ast.Name) and n.id in ("jit", "pjit"):
            return True
    return False


_STATIC_ATTRS = ("shape", "ndim", "size", "dtype", "itemsize")


def is_static_expr(node: ast.AST) -> bool:
    """Conservatively true when the expression is trace-time static
    (shape/ndim/len arithmetic, constants) — casting those to Python
    scalars inside jit is fine and flagged by no rule."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Attribute):
        return node.attr in _STATIC_ATTRS
    if isinstance(node, ast.Subscript):
        return is_static_expr(node.value)
    if isinstance(node, ast.UnaryOp):
        return is_static_expr(node.operand)
    if isinstance(node, ast.BinOp):
        return is_static_expr(node.left) and is_static_expr(node.right)
    if isinstance(node, ast.Call):
        f = dotted(node.func)
        if f == "len":
            return True
        if f in ("int", "float", "bool", "min", "max", "abs"):
            return all(is_static_expr(a) for a in node.args)
        if f in ("np.prod", "math.prod", "numpy.prod"):
            return True
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(is_static_expr(e) for e in node.elts)
    return False


# -- the jit call graph ------------------------------------------------------

FuncKey = Tuple[str, str]  # (module dotted name, function qualname)


class CallGraph:
    """Reachability from jitted entry points, project-wide."""

    def __init__(self, project: Project):
        self.project = project
        self.roots: Set[FuncKey] = set()
        self.edges: Dict[FuncKey, Set[FuncKey]] = {}
        for mod in project:
            self._scan_module(mod)
        self.reachable: Set[FuncKey] = self._bfs()

    def _resolve(self, mod: Module, qual: str, name: str) -> Optional[FuncKey]:
        parts = qual.split(".") if qual else []
        for i in range(len(parts), -1, -1):
            cand = ".".join(parts[:i] + [name])
            if cand in mod.funcs:
                return (mod.dotted, cand)
        imp = mod.imports.get(name)
        if imp is not None:
            tmod = self.project.modules.get(imp[0])
            if tmod is not None and imp[1] in tmod.funcs:
                return (tmod.dotted, imp[1])
        return None

    def _scan_scope(self, mod: Module, qual: str, scope: ast.AST) -> None:
        key = (mod.dotted, qual)
        out = self.edges.setdefault(key, set())
        for n in scan_region(scope):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # decorators evaluate in THIS scope; a jit decorator roots
                # the function it wraps
                if any(mentions_jit(d) for d in n.decorator_list):
                    child = f"{qual}.{n.name}" if qual else n.name
                    self.roots.add((mod.dotted, child))
                continue
            if not isinstance(n, ast.Call):
                continue
            callee = dotted(n.func)
            if callee and "." not in callee:
                tgt = self._resolve(mod, qual, callee)
                if tgt is not None:
                    out.add(tgt)
            # function-reference arguments: jit(f)/shard_map(f) make f a
            # root; lax.cond(p, f, g)/scan/while pass callees by name
            is_jit_call = mentions_jit(n.func)
            is_trace_hof = callee.rsplit(".", 1)[-1] in (
                "shard_map", "cond", "scan", "while_loop", "switch",
                "fori_loop", "checkpoint", "remat", "vmap", "pmap")
            for arg in list(n.args) + [kw.value for kw in n.keywords]:
                if isinstance(arg, ast.Name):
                    tgt = self._resolve(mod, qual, arg.id)
                    if tgt is None:
                        continue
                    if is_jit_call:
                        self.roots.add(tgt)
                    elif is_trace_hof:
                        out.add(tgt)
                    else:
                        # unknown higher-order use: treat as an edge, not
                        # a root — reachability still flows through it
                        out.add(tgt)

    def _scan_module(self, mod: Module) -> None:
        self._scan_scope(mod, "", mod.tree)
        for qual, node in mod.funcs.items():
            self._scan_scope(mod, qual, node)

    def _bfs(self) -> Set[FuncKey]:
        seen: Set[FuncKey] = set()
        frontier = list(self.roots)
        while frontier:
            key = frontier.pop()
            if key in seen:
                continue
            seen.add(key)
            for nxt in self.edges.get(key, ()):
                if nxt not in seen:
                    frontier.append(nxt)
        return seen

    def reachable_functions(self) -> Iterator[Tuple[Module, str, ast.AST]]:
        for mod_name, qual in sorted(self.reachable):
            mod = self.project.modules.get(mod_name)
            if mod is not None and qual in mod.funcs:
                yield mod, qual, mod.funcs[qual]


# -- driving ----------------------------------------------------------------


def load_project(paths: Sequence[str], root: Optional[str] = None) -> Project:
    """Parse every ``*.py`` under ``paths`` (files or directories) into a
    Project. ``root`` anchors the reported relative paths (defaults to the
    common prefix's repo layout: paths are kept as given, normalized)."""
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames if d != "__pycache__"]
                files.extend(
                    os.path.join(dirpath, f) for f in filenames
                    if f.endswith(".py"))
    modules = []
    for f in sorted(set(files)):
        rel = os.path.relpath(f, root) if root else os.path.normpath(f)
        with open(f, "r", encoding="utf-8") as fh:
            modules.append(Module(rel, fh.read()))
    return Project(modules)


def analyze_sources(sources: Dict[str, str],
                    rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the rules over in-memory {relpath: source} — the fixture-test
    entry point."""
    project = Project([Module(rp, src) for rp, src in sources.items()])
    return _run_project(project, rules)


def run(paths: Sequence[str],
        rules: Optional[Sequence[str]] = None) -> Tuple[List[Finding], int]:
    project = load_project(paths)
    return _run_project(project, rules), len(project.modules)


def _run_project(project: Project,
                 rules: Optional[Sequence[str]] = None) -> List[Finding]:
    from .rules import ALL_RULES

    wanted = {r.upper() for r in rules} if rules else None
    findings: List[Finding] = []
    for rule_mod in ALL_RULES:
        if wanted is not None and rule_mod.RULE.upper() not in wanted:
            continue
        for f in rule_mod.check(project):
            mod = project.by_path.get(f.path)
            if mod is not None and mod.is_suppressed(f.line, f.rule):
                f = dataclasses.replace(f, suppressed=True)
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def format_text(findings: Iterable[Finding], n_files: int) -> str:
    findings = list(findings)
    live = [f for f in findings if not f.suppressed]
    lines = [f.format() for f in findings]
    lines.append(
        f"{len(live)} finding(s) ({len(findings) - len(live)} suppressed) "
        f"in {n_files} file(s)")
    return "\n".join(lines)


def format_json(findings: Iterable[Finding], n_files: int) -> str:
    findings = list(findings)
    live = [f for f in findings if not f.suppressed]
    counts: Dict[str, int] = {}
    for f in live:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return json.dumps(
        {
            "findings": [f.to_json() for f in findings],
            "unsuppressed": len(live),
            "suppressed": len(findings) - len(live),
            "counts_by_rule": counts,
            "checked_files": n_files,
        },
        indent=1, sort_keys=True)
