"""qwen2.5-32b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27_648,
    vocab_size=152_064,
    qkv_bias=True,
    microbatches=8,     # grad accumulation: fits one pod (§Perf It.4)
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)
