"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base;
unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10_752,
    vocab_size=100_352,
    n_experts=16,
    experts_per_token=4,
    moe_every=1,
    microbatches=8,     # grad accumulation: fits one pod (§Perf It.4)
    source="hf:databricks/dbrx-base; unverified",
)
