"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

Backbone only: the EnCodec frontend is a STUB — input_specs() provides
precomputed frame embeddings (conditioning prefix)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,       # MHA
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio_stub",
    prefix_len=128,
    source="arXiv:2306.05284; hf",
)
