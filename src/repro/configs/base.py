"""Config system: architecture + shape + run configs.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``repro.configs.get_config(name)`` resolves them.
``reduced()`` derives the CPU-smoke-test variant of any config.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1             # layer i is MoE iff i % moe_every == moe_every-1
    capacity_factor: float = 1.25
    moe_groups: int = 1            # GShard dispatch groups (= batch shards at scale)
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    attn_every: int = 0            # hybrid: layer i is attention iff i % attn_every == attn_every//2; 0 = all-attn (or no attn for pure ssm)
    # --- modality stub frontends ---
    frontend: str = "none"         # none | vlm_stub | audio_stub
    prefix_len: int = 0            # precomputed patch/frame embedding prefix
    # --- numerics / memory policy ---
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    remat: bool = True
    q_chunk: int = 512             # attention query-block size
    microbatches: int = 1          # gradient-accumulation splits of the global batch
    # --- source provenance ---
    source: str = ""

    # ---- derived -----------------------------------------------------------

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for layer i."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid" and self.attn_every > 0:
            return "attn" if i % self.attn_every == self.attn_every // 2 else "ssm"
        return "attn"

    def mlp_kind(self, i: int) -> str:
        """'moe' or 'dense' for layer i."""
        if self.n_experts > 0 and i % self.moe_every == self.moe_every - 1:
            return "moe"
        return "dense"

    @property
    def period(self) -> int:
        """Smallest repeating layer pattern (for scan-over-layers stacking)."""
        p = 1
        if self.family == "hybrid" and self.attn_every:
            p = self.attn_every
        if self.n_experts:
            p = _lcm(p, self.moe_every)
        if self.family == "ssm":
            p = max(p, 1)
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        return p

    def padded_heads(self, tp: int) -> Tuple[int, int]:
        """(n_heads, n_kv) padded up to multiples of the tensor-parallel
        degree (zero-filled slots; DESIGN.md sharding notes)."""
        if self.n_heads == 0:
            return 0, 0
        h = _round_up(self.n_heads, tp)
        kv = _round_up(self.n_kv_heads, tp)
        kv = min(kv, h)
        # grouped attention requires kv | h
        while h % kv != 0:
            kv += tp
        return h, kv

    def padded_vocab(self, tp: int) -> int:
        return _round_up(self.vocab_size, tp * 8)

    # ---- parameter counting (for roofline MODEL_FLOPS) ----------------------

    def param_count(self, logical: bool = True, tp: int = 1) -> int:
        """Total parameters; logical=True uses the paper head counts."""
        h, kv = (self.n_heads, self.n_kv_heads) if logical else self.padded_heads(tp)
        v = self.vocab_size if logical else self.padded_vocab(tp)
        d, hd = self.d_model, self.head_dim
        total = v * d + d * v  # embed + untied head
        for i in range(self.n_layers):
            if self.layer_kind(i) == "attn":
                total += d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
                if self.qkv_bias:
                    total += (h + 2 * kv) * hd
            else:  # ssm
                di, n, sh = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * di + 2 * n + sh)   # in_proj
                total += 4 * (di + 2 * n)            # conv
                total += di * d                      # out_proj
            if self.mlp_kind(i) == "moe":
                total += d * self.n_experts + 3 * self.n_experts * d * self.d_ff
            elif self.d_ff > 0:
                total += 3 * d * self.d_ff
            total += 2 * d  # norms
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: only routed experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        n_moe = sum(1 for i in range(self.n_layers) if self.mlp_kind(i) == "moe")
        inactive = n_moe * 3 * d * self.d_ff * (self.n_experts - self.experts_per_token)
        return total - inactive

    # ---- reduced (smoke-test) variant ---------------------------------------

    def reduced(self) -> "ModelConfig":
        period = self.period
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2 * period,
            d_model=64,
            n_heads=min(self.n_heads, 4) or 0,
            n_kv_heads=min(self.n_kv_heads, 2) or 0,
            head_dim=16,
            d_ff=min(self.d_ff, 128),
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            # drop-free capacity so prefill/decode exactly match the full
            # forward regardless of sequence length (tests rely on it)
            capacity_factor=8.0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16,
            ssm_chunk=8,
            prefix_len=min(self.prefix_len, 8),
            param_dtype="float32",
            q_chunk=16,
            microbatches=1,  # smoke tests use tiny batches
        )


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs able to run long_500k (sub-quadratic long-context decode)
LONG_CONTEXT_ARCHS = ("mamba2-370m", "jamba-1.5-large-398b")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.name in LONG_CONTEXT_ARCHS
    return True
