"""Config registry: one module per assigned architecture (+ RPQ workloads)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import LONG_CONTEXT_ARCHS, SHAPES, ModelConfig, ShapeConfig, shape_applicable

_ARCH_MODULES = {
    "mamba2-370m": "mamba2_370m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen1.5-4b": "qwen1_5_4b",
    "smollm-360m": "smollm_360m",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen2.5-32b": "qwen2_5_32b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "dbrx-132b": "dbrx_132b",
    "paligemma-3b": "paligemma_3b",
    "musicgen-large": "musicgen_large",
}

ARCH_NAMES: List[str] = list(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f".{_ARCH_MODULES[name]}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {name: get_config(name) for name in ARCH_NAMES}


__all__ = [
    "ARCH_NAMES",
    "LONG_CONTEXT_ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "all_configs",
    "get_config",
    "shape_applicable",
]
