"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,           # attention-free
    n_kv_heads=0,
    d_ff=0,              # no separate MLP: the SSD mixer is the whole block
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    source="arXiv:2405.21060; unverified",
)
