"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,        # GQA kv=8 (padded to 16 for TP=16)
    head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    n_experts=16,
    experts_per_token=2,
    moe_every=2,         # MoE every other layer (Jamba convention)
    attn_every=8,        # 1 attention layer per 8 (1:7 Mamba:attn)
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=32,   # small chunk: intra-chunk residuals scale with Q
    opt_state_dtype="bfloat16",  # 398B: f32 moments would not fit one pod
    microbatches=16,     # grad accumulation: activation live-set / 16 (§Perf It.4)
    source="arXiv:2403.19887; hf",
)
