"""llama4-scout-17b-a16e [moe] — MoE 16e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    n_experts=16,
    experts_per_token=1,
    moe_every=1,
    microbatches=8,     # grad accumulation: fits one pod (§Perf It.4)
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
