"""paligemma-3b [vlm] — SigLIP + gemma [arXiv:2407.07726; hf].

Backbone only: the SigLIP vision frontend is a STUB — input_specs() provides
precomputed patch embeddings of length ``prefix_len`` (task convention)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,        # MQA
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    frontend="vlm_stub",
    prefix_len=256,      # 224/14 = 16x16 patches
    source="arXiv:2407.07726; hf",
)
