"""Training driver: step builder (used by the dry-run and the CPU example)
plus a runnable CLI for reduced-config end-to-end training with
checkpoint/restart and straggler monitoring.

CLI (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Callable

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models.transformer import Model
from ..optim.adamw import AdamWConfig, adamw_update, init_adamw


def make_train_step(model: Model, opt_cfg: AdamWConfig) -> Callable:
    """Single step with optional gradient accumulation: the global batch is
    split into cfg.microbatches scanned chunks, shrinking the activation
    live set M-fold at the cost of M sequential passes (EXPERIMENTS.md
    §Perf It.4 — required to fit jamba-398B train on one pod). Grads
    accumulate in bf16 (mean of means; error <= 2^-8 relative, dominated by
    bf16 gradient noise itself)."""
    M = model.cfg.microbatches

    def train_step(params, opt_state, batch):
        if M <= 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]), batch)

            def micro(carry, mb):
                g_acc, l_acc = carry
                loss_i, g_i = jax.value_and_grad(model.loss)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype) / M, g_acc, g_i)
                return (g_acc, l_acc + loss_i / M), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
            (grads, loss), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32)), mbs)
        new_params, new_opt, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        return model.loss(params, batch)

    return eval_step


# ---------------------------------------------------------------------------
# CPU end-to-end driver (reduced configs)
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full arch config (default: reduced)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    model = Model(cfg, tp=1)
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=10, total_steps=args.steps,
                          moment_dtype=cfg.opt_state_dtype)

    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_adamw(opt_cfg, params)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))

    from ..data.tokens import TokenPipeline

    pipe = TokenPipeline(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_per_host=args.batch,
        prefix_len=cfg.prefix_len if cfg.frontend != "none" else 0,
        d_model=cfg.d_model,
    )

    from ..distributed.fault import StragglerMonitor

    monitor = StragglerMonitor()
    losses = []
    t_start = time.monotonic()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        t0 = time.monotonic()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        monitor.observe(step, time.monotonic() - t0)
        losses.append(loss)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {loss:.4f}  lr {float(metrics['lr']):.2e}"
                  f"  gnorm {float(metrics['grad_norm']):.3f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            from ..checkpoint import ckpt

            ckpt.async_save(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state},
                            extra={"step": step + 1, "cursor": pipe.cursor()})
    if args.ckpt_dir:
        from ..checkpoint import ckpt

        ckpt.wait_pending(args.ckpt_dir)
    wall = time.monotonic() - t_start
    print(f"done: {args.steps} steps in {wall:.1f}s "
          f"({args.steps * args.batch * args.seq / wall:.0f} tok/s); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"stragglers: {len(monitor.stragglers)}")
    pipe.close()


if __name__ == "__main__":
    main()
