"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation — the dry-run lowers against these (weak-type-correct,
shardable). Decode shapes describe ONE new token against a KV/SSM cache of
`seq_len` (capacity seq_len + 8 headroom so the cache write stays in
bounds), lowering `serve_step`, not `train_step`.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ShapeConfig
from ..models.transformer import Model

DECODE_HEADROOM = 512  # keeps cache seq divisible by the batch axes (32-way)


def token_specs(model: Model, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    prefix = cfg.prefix_len if cfg.frontend != "none" else 0
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s - prefix), jnp.int32),
    }
    if prefix:
        specs["prefix_embeds"] = jax.ShapeDtypeStruct((b, prefix, cfg.d_model), jnp.float32)
    return specs


def decode_specs(model: Model, shape: ShapeConfig) -> Tuple[jax.ShapeDtypeStruct, Any]:
    b, s = shape.global_batch, shape.seq_len
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    caches = jax.eval_shape(lambda: model.init_caches(b, s + DECODE_HEADROOM))
    return token, caches


def abstract_params(model: Model) -> Any:
    return model.init_abstract()


def abstract_opt_state(model: Model, opt_cfg) -> Any:
    from ..optim.adamw import init_adamw

    params = abstract_params(model)
    return jax.eval_shape(lambda p: init_adamw(opt_cfg, p), params)
