import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the PAPER'S TECHNIQUE on the production mesh: one bottleneck
relaxation round of the dense streaming-RPQ engine (the repeated unit of
ingest/expiry/delete closures — round count is data-dependent, so the
roofline is reported per round).

Distributed layout (DESIGN.md §4):
    dist (x, u, s): x -> (pod,)data, u -> model    (frontier)
    adj  (l, u, v): v -> model                      (timestamped adjacency)
Contraction over u needs the full frontier per chip -> the per-round
all-gather over 'model' is the engine's collective term (baseline; the ring
schedule is the §Perf hillclimb).

Run: PYTHONPATH=src python -m repro.launch.dryrun_rpq [--all]
"""
import argparse
import json
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.automaton import compile_query
from ..core.backend import BucketBackend, resolve_backend
from ..core.engine import _round_up
from ..core.semiring import (NEG_INF, BatchedTransitionTable, TransitionTable,
                             relax_round)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

# engine cells: (name, n_slots, query, v-chunk)
RPQ_CELLS = [
    ("rpq_n4096_k2", 4096, "a . b*", 512),
    ("rpq_n8192_k3", 8192, "a . b* . c", 512),
    ("rpq_n16384_k2", 16384, "(a | b)*", 512),
]

N_LEVELS = 8  # |W|/beta buckets for the MXU mode (paper: 1-month/1-day ~ 30;
              # 8 keeps the napkin conservative)

F_CAP = 256   # frontier capacity the "batched-frontier" cell lowers: the
              # dirty-row slab is (Q, F, N, K) with F << N, so the round's
              # contraction prices O(J·F·N²) instead of O(J·N³)

ELL_CAP_ANALYTIC = 8    # degree cap for the padded-ELL adjacency napkin
SPILL_CAP_ANALYTIC = 256  # replicated spill-ring slots (16 B each)

# multi-query serving cell (mode="batched"): the Table-2 workload stacked
# into ONE (Q, N, N, K) relaxation — the BatchedDenseRPQEngine's round on
# the production mesh
BATCHED_QUERIES = ["a*", "a . b*", "a . b* . c*", "(a | b | c)*", "a . b* . c",
                   "a* . b*", "a . b . c*", "a? . b*"]


def _cost_dict(ca):
    """jax version compat: cost_analysis() returns a dict (>=0.5) or a
    one-element list of dicts (0.4.x)."""
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca or {}


def make_ring_round(mesh, tt: TransitionTable, n_slots: int, multi_pod: bool):
    """Manual ring reduce-scatter(max) schedule via shard_map: each chip
    contracts its LOCAL u-block (dist and adj are co-sharded on u), then the
    partial results ring around the model axis with max-accumulation —
    bytes-on-wire ~1x frontier (vs 2x for all-reduce-max) and every hop can
    overlap with the next partial contraction on TPU.

    (The base term — direct edges from start transitions — is applied once
    per ingest outside the iterated round, so it is not part of this
    lowering.)"""
    from jax.sharding import PartitionSpec as P

    tp = mesh.shape["model"]
    xa = ("pod", "data") if multi_pod else "data"

    def local_partial(dist_blk, adj_blk, j):
        # dist_blk: (x_l, u_l, K); adj_blk: (L, u_l, N) -> partial (x_l, N)
        s_ = tt.src[j]
        l_ = tt.lab[j]
        d_s = jax.lax.dynamic_index_in_dim(
            jnp.moveaxis(dist_blk, 2, 0), s_, axis=0, keepdims=False)
        a_l = jax.lax.dynamic_index_in_dim(adj_blk, l_, axis=0, keepdims=False)
        n = a_l.shape[1]
        vc = min(512, n)

        def per_chunk(c, out):
            a = jax.lax.dynamic_slice(a_l, (0, c * vc), (a_l.shape[0], vc))
            contrib = jnp.max(jnp.minimum(d_s[:, :, None], a[None, :, :]), axis=1)
            return jax.lax.dynamic_update_slice(out, contrib, (0, c * vc))

        return jax.lax.fori_loop(0, n // vc, per_chunk,
                                 jnp.full((d_s.shape[0], n), NEG_INF, jnp.float32))

    def body(dist_blk, adj_blk):
        def per_t(j, acc):
            part = local_partial(dist_blk, adj_blk, j)       # (x_l, N)
            upd = jnp.where(tt.dst_onehot[j][None, None, :] > 0,
                            part[:, :, None], NEG_INF)
            return jnp.maximum(acc, upd)

        x_l = dist_blk.shape[0]
        n = adj_blk.shape[2]
        part = jax.lax.fori_loop(
            0, tt.src.shape[0], per_t,
            jnp.full((x_l, n, tt.k), NEG_INF, jnp.float32))

        # ring reduce-scatter(max) over 'model': after tp-1 hops each chip
        # owns the fully-reduced u-block matching its dist_blk shard.
        idx = jax.lax.axis_index("model")
        u_l = n // tp
        perm = [(k, (k - 1) % tp) for k in range(tp)]

        def take(block_idx):
            start = (block_idx % tp) * u_l
            return jax.lax.dynamic_slice(part, (0, start, 0), (x_l, u_l, tt.k))

        def hop(i, acc):
            acc = jax.lax.ppermute(acc, "model", perm)
            return jnp.maximum(acc, take(idx + 2 + i))

        acc0 = take(idx + 1)
        out_blk = jax.lax.fori_loop(0, tp - 1, hop, acc0)
        return jnp.maximum(dist_blk, out_blk)

    from jax.experimental.shard_map import shard_map
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(xa, "model", None), P(None, "model", None)),
        out_specs=P(xa, "model", None),
        check_rep=False,
    )


def relax_round_vchunked(dist, adj, tt: TransitionTable, v_chunk: int):
    """One relaxation round, chunked over the OUTPUT v dim so the broadcast
    intermediate stays bounded and the u-contraction triggers the frontier
    all-gather (dist's u dim is model-sharded)."""
    n = dist.shape[0]

    def per_transition(j, acc):
        s = tt.src[j]
        l = tt.lab[j]
        dist_s = jax.lax.dynamic_index_in_dim(
            jnp.moveaxis(dist, 2, 0), s, axis=0, keepdims=False)      # (x, u)
        adj_l = jax.lax.dynamic_index_in_dim(adj, l, axis=0, keepdims=False)  # (u, v)

        def per_chunk(c, out):
            a = jax.lax.dynamic_slice(adj_l, (0, c * v_chunk), (n, v_chunk))
            contrib = jnp.max(
                jnp.minimum(dist_s[:, :, None], a[None, :, :]), axis=1
            )  # (x, v_chunk)
            return jax.lax.dynamic_update_slice(out, contrib, (0, c * v_chunk))

        contrib = jax.lax.fori_loop(
            0, n // v_chunk, per_chunk, jnp.full((n, n), NEG_INF, dist.dtype))
        contrib = jnp.where(tt.start_mask[j], jnp.maximum(contrib, adj_l), contrib)
        upd = jnp.where(tt.dst_onehot[j][None, None, :] > 0,
                        contrib[:, :, None], NEG_INF)
        return jnp.maximum(acc, upd)

    return jax.lax.fori_loop(0, tt.src.shape[0], per_transition, dist)


def run_rpq_cell(name: str, n_slots: int, query: str, v_chunk: int,
                 multi_pod: bool, force: bool = False,
                 mode: str = "baseline") -> Dict[str, Any]:
    from .dryrun import scrape_collectives  # shares the HLO scraper
    from .mesh import make_production_mesh, mesh_context

    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh_tag = "multipod" if multi_pod else "pod"
    path = os.path.join(RESULTS_DIR, f"{name}-{mode}__ingest_round__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    dfa = compile_query(query)
    tt = TransitionTable.from_dfa(dfa)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    xa = ("pod", "data") if multi_pod else "data"

    dtype = jnp.int32 if mode == "mxu" else jnp.float32
    # analytic metadata (semiring ops, k, alphabet, query tag) must describe
    # the program actually lowered — the batched mode stacks BATCHED_QUERIES,
    # not the cell's single query
    query_tag, meta_k, meta_labels = query, dfa.k, dfa.n_labels
    n_transitions = len(dfa.transitions())
    if mode.startswith("batched"):
        # Q stacked queries, shared adjacency — a thin wrapper over the
        # MeshExecutor round lowering (distributed/executor.py): the lane
        # axis is SHARDED over the data axes (padded with inert lanes to a
        # shard multiple, exactly the engine's bucketing), the vertex axis
        # over model, and the (Q,) per-lane convergence mask rides along as
        # a runtime input — a lane shard whose queries have all converged
        # skips its contraction entirely (lax.cond inside shard_map), which
        # is the production form of the masked round the
        # BatchedDenseRPQEngine iterates. A "batched-<backend>" mode lowers
        # the SAME cell with that contraction backend (e.g. batched-pallas,
        # batched-mxu_bucket), so the roofline prices whichever substrate
        # the engine is configured to run. "batched-frontier" lowers the
        # FRONTIER-restricted round instead: the (Q, F) dirty-row indices
        # ride as runtime inputs and the contraction touches an (F, N)
        # slab per transition — O(F·N²), the PR 5 per-event cost model.
        from ..distributed.executor import (batched_round_lowering,
                                            frontier_round_lowering)

        suffix = mode.split("-", 1)[1] if "-" in mode else "jnp"
        dfas = [compile_query(q) for q in BATCHED_QUERIES]
        labels = sorted(set().union(*[set(d.labels) for d in dfas]))
        btt = BatchedTransitionTable.from_dfas(dfas, labels)
        query_tag = f"batched[{len(dfas)}]: " + " ; ".join(BATCHED_QUERIES)
        meta_k, meta_labels = btt.k, len(labels)
        n_transitions = sum(len(d.transitions()) for d in dfas)
        q_axes = ("pod", "data") if multi_pod else ("data",)
        n_lane_shards = int(np.prod([mesh.shape[a] for a in q_axes]))
        q_cap = _round_up(len(dfas), n_lane_shards)
        if suffix == "frontier":
            round_fn, arg_specs, arg_shardings, dist_sh = \
                frontier_round_lowering(mesh, btt, q_cap, n_slots,
                                        min(F_CAP, n_slots), q_axes=q_axes)
        else:
            backend = (BucketBackend(n_levels=N_LEVELS, use_pallas=False)
                       if suffix == "mxu_bucket" else resolve_backend(suffix))
            round_fn, arg_specs, arg_shardings, dist_sh = \
                batched_round_lowering(mesh, btt, q_cap, n_slots,
                                       q_axes=q_axes, backend=backend)
        dist_spec, adj_spec = arg_specs[0], arg_specs[1]
    elif mode == "ring":
        dist_spec = jax.ShapeDtypeStruct((n_slots, n_slots, dfa.k), dtype)
        adj_spec = jax.ShapeDtypeStruct((dfa.n_labels, n_slots, n_slots), dtype)
        dist_sh = NamedSharding(mesh, P(xa, "model", None))
        adj_sh = NamedSharding(mesh, P(None, "model", None))  # u co-sharded
        arg_specs = (dist_spec, adj_spec)
        arg_shardings = (dist_sh, adj_sh)
        round_fn = make_ring_round(mesh, tt, n_slots, multi_pod)
    else:  # baseline | mxu
        dist_spec = jax.ShapeDtypeStruct((n_slots, n_slots, dfa.k), dtype)
        adj_spec = jax.ShapeDtypeStruct((dfa.n_labels, n_slots, n_slots), dtype)
        dist_sh = NamedSharding(mesh, P(xa, "model", None))
        adj_sh = NamedSharding(mesh, P(None, None, "model"))
        arg_specs = (dist_spec, adj_spec)
        arg_shardings = (dist_sh, adj_sh)

        def round_fn(dist, adj):
            if mode == "mxu":
                # level-quantized single-query round through the engine's
                # own BucketBackend contraction (the old hand-rolled
                # relax_round_mxu_bucket special case, deleted in PR 4):
                # pure-jnp T-dot decomposition so GSPMD can partition it
                out = relax_round(
                    dist, adj, tt,
                    BucketBackend(n_levels=N_LEVELS, use_pallas=False))
            else:
                out = relax_round_vchunked(dist, adj, tt, v_chunk)
            return jax.lax.with_sharding_constraint(out, dist_sh)

    t0 = time.monotonic()
    with mesh_context(mesh):
        lowered = jax.jit(round_fn, in_shardings=arg_shardings,
                          out_shardings=dist_sh).lower(*arg_specs)
    global_flops = _cost_dict(lowered.cost_analysis()).get("flops", 0.0)
    compiled = lowered.compile()
    t_total = time.monotonic() - t0
    ca = _cost_dict(compiled.cost_analysis())
    ma = compiled.memory_analysis()
    colls = scrape_collectives(compiled.as_text())
    state_bytes = (np.prod(dist_spec.shape) * 4 + np.prod(adj_spec.shape) * 4) / chips
    by_kind: Dict[str, float] = {}
    for c in colls:
        by_kind[c["kind"]] = by_kind.get(c["kind"], 0.0) + c["wire_bytes"]

    result = {
        "arch": f"{name}-{mode}", "shape": "ingest_round",
        "engine_mode": mode,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "kind": "rpq",
        "query": query_tag, "k": meta_k, "n_labels": meta_labels,
        "n_slots": n_slots,
        "ok": True,
        "compile_s": round(t_total, 2),
        "global_flops": global_flops,
        "device_flops": ca.get("flops", 0.0),
        "device_bytes": ca.get("bytes accessed", 0.0),
        "device_flops_extrap": ca.get("flops", 0.0),
        "device_bytes_extrap": ca.get("bytes accessed", 0.0),
        "global_flops_extrap": global_flops,
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        },
        "state_bytes_per_chip": state_bytes,
        "peak_bytes_per_chip": state_bytes + getattr(ma, "temp_size_in_bytes", 0),
        "fits_hbm": bool(state_bytes + getattr(ma, "temp_size_in_bytes", 0)
                         <= 16 * 1024**3),
        "n_collectives": len(colls),
        # ring mode: the ppermute sits inside a fori_loop executed (tp-1)
        # times; HLO text counts the body once, so scale the wire model
        "collective_wire_bytes_extrap": sum(c["wire_bytes"] for c in colls)
        * ((mesh.shape["model"] - 1) if mode == "ring" else 1),
        "collectives_by_kind_extrap": by_kind,
        # semiring ops (max+min per MAC-equivalent) for the analytic term:
        # the frontier round contracts an (F, N) slab per transition row —
        # O(F·N²) — instead of the dense (N, N) row block's O(N³)
        "semiring_ops": (2.0 * n_transitions * min(F_CAP, n_slots) * n_slots**2
                         if mode.endswith("frontier")
                         else 2.0 * n_transitions * n_slots**3),
        "frontier_cap": min(F_CAP, n_slots) if mode.endswith("frontier") else 0,
        # every level-quantized lowering (single-query "mxu" AND the
        # batched bucket-backend cell) is priced by its EXECUTED boolean
        # dot count: BucketBackend allocates n_levels + 1 thresholds (the
        # extra level absorbs the origin-snap slack), so T+1 dots run
        "n_levels": (N_LEVELS if (mode == "mxu" or mode.endswith("mxu_bucket"))
                     else 0),
        "level_dots": (N_LEVELS + 1
                       if (mode == "mxu" or mode.endswith("mxu_bucket"))
                       else 0),
        # adjacency-layout napkin (PR 8, adj_layout="ell"): every lowered
        # cell here still carries the dense (L, N, N) slab — these analytic
        # twins price what the SAME cell's adjacency state and base-term
        # reads cost off the O(N²) wall (idx int32 + ts f32 rows at the
        # default degree cap, plus the replicated 16 B/slot spill ring)
        "adjacency": {
            "dense_bytes": 4.0 * meta_labels * n_slots**2,
            "ell_cap": ELL_CAP_ANALYTIC,
            "ell_bytes": (8.0 * meta_labels * n_slots * ELL_CAP_ANALYTIC
                          + 16.0 * SPILL_CAP_ANALYTIC),
            # gather-contract op count for the frontier round's base term:
            # O(J·F·E·N) instead of the slab's O(J·F·N²)
            "ell_gather_ops": (2.0 * n_transitions * min(F_CAP, n_slots)
                               * ELL_CAP_ANALYTIC * n_slots),
        },
    }
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--modes", default="baseline,mxu,ring,batched,batched-frontier")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    for (name, n, q, vc) in RPQ_CELLS:
        if args.cell and args.cell != name:
            continue
        for mp in meshes:
            for mode in args.modes.split(","):
                r = run_rpq_cell(name, n, q, vc, mp, force=args.force, mode=mode)
                print(f"[ok] {name}/{mode} x {'2x16x16' if mp else '16x16'}: "
                      f"compile {r['compile_s']}s, colls={r['n_collectives']}, "
                      f"wire {r['collective_wire_bytes_extrap']/2**20:.1f} MiB/round",
                      flush=True)


if __name__ == "__main__":
    main()
