import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the two lines above must execute before any
jax import anywhere): ``PYTHONPATH=src python -m repro.launch.dryrun --arch
<id> --shape <name> --mesh pod|multipod`` or ``--all``.

Per cell it records into benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json:
  * memory_analysis (argument/output/temp bytes per device) + a <=16 GiB/chip
    HBM assertion (params+opt+cache shards + temps),
  * cost_analysis flops / bytes (per-device, post-SPMD — includes sharding
    redundancy), and the pre-partition global flops from the lowered module,
  * the collective schedule scraped from the compiled HLO: op kind, shape,
    bytes, replica-group size, and ring-model bytes-on-wire per device.
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, List

import jax
import numpy as np

from ..configs import ARCH_NAMES, SHAPES, get_config, shape_applicable
from ..distributed.sharding import (
    batch_shardings,
    cache_shardings,
    make_constrain,
    params_shardings,
)
from ..launch.mesh import make_production_mesh, mesh_context
from .dryrun_rpq import _cost_dict
from ..launch.specs import abstract_opt_state, abstract_params, decode_specs, token_specs
from ..launch.train import make_train_step
from ..models.transformer import Model
from ..optim.adamw import AdamWConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

HBM_PER_CHIP = 16 * 1024**3  # v5e

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\b"
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def scrape_collectives(hlo_text: str) -> List[Dict[str, Any]]:
    """Collect collective ops with output bytes + group size + wire model."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _name, dt, dims, kind = m.groups()
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        size = nbytes * int(np.prod([int(d) for d in dims.split(",") if d])) \
            if dims else nbytes
        g = _GROUPS_RE.search(line)
        if g:
            group = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            group = int(gi.group(2)) if gi else 1
        # ring-model bytes on the wire per participating device
        if kind == "all-reduce":
            wire = 2 * size * (group - 1) / max(group, 1)
        elif kind in ("all-gather",):
            wire = size * (group - 1) / max(group, 1)
        elif kind in ("reduce-scatter", "all-to-all"):
            wire = size * (group - 1) / max(group, 1)
        else:  # collective-permute
            wire = size
        out.append({"kind": kind, "bytes": size, "group": group, "wire_bytes": wire})
    return out


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               scan_unroll: bool = False, n_layers: int = 0):
    import dataclasses
    cfg = get_config(arch)
    if n_layers:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    if cfg.n_experts:
        mesh_probe = make_production_mesh(multi_pod=multi_pod)
        shards = mesh_probe.shape["data"] * mesh_probe.shape.get("pod", 1)
        shape_probe = SHAPES[shape_name]
        tokens = shape_probe.global_batch * (1 if shape_probe.kind == "decode"
                                             else shape_probe.seq_len)
        groups = shards
        while tokens % groups != 0 or groups > tokens:
            groups //= 2
        cfg = dataclasses.replace(cfg, moe_groups=max(groups, 1))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    seq_sharded = shape.global_batch < mesh.shape["data"]
    model = Model(cfg, tp=tp, constrain=make_constrain(mesh, seq_sharded=seq_sharded),
                  scan_unroll=scan_unroll)
    return cfg, shape, mesh, model, seq_sharded


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               scan_unroll: bool = False, n_layers: int = 0,
               serving_sharding: bool = False):
    """Returns (lowered, static_arg_bytes_per_device, meta)."""
    cfg, shape, mesh, model, seq_sharded = build_cell(
        arch, shape_name, multi_pod, scan_unroll=scan_unroll, n_layers=n_layers)
    chips = int(np.prod(list(mesh.shape.values())))
    p_abs = abstract_params(model)
    p_shard = params_shardings(
        p_abs, mesh, serving=(serving_sharding and shape.kind != "train"))
    bshard = batch_shardings(mesh, seq_sharded)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=cfg.opt_state_dtype)
        o_abs = abstract_opt_state(model, opt_cfg)
        o_shard = opt_shardings(o_abs, p_abs, p_shard, mesh)
        batch = token_specs(model, shape)
        b_shard = {k: bshard(k, v.shape) for k, v in batch.items()}
        step = make_train_step(model, opt_cfg)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            donate_argnums=(0, 1),
        )
        with mesh_context(mesh):
            lowered = jitted.lower(p_abs, o_abs, batch)
        state_bytes = (_tree_bytes(p_abs) + _tree_bytes(o_abs)) / chips
    elif shape.kind == "prefill":
        batch = token_specs(model, shape)
        b_shard = {k: bshard(k, v.shape) for k, v in batch.items()}

        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"], batch.get("prefix_embeds"))

        jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard))
        with mesh_context(mesh):
            lowered = jitted.lower(p_abs, batch)
        state_bytes = _tree_bytes(p_abs) / chips
    else:  # decode
        token, caches = decode_specs(model, shape)
        c_shard = cache_shardings(mesh, caches, seq_sharded)
        t_shard = bshard("tokens", token.shape)

        def serve_step(params, token, caches):
            return model.decode_step(params, token, caches)

        jitted = jax.jit(
            serve_step,
            in_shardings=(p_shard, t_shard, c_shard),
            donate_argnums=(2,),
        )
        with mesh_context(mesh):
            lowered = jitted.lower(p_abs, token, caches)
        state_bytes = (_tree_bytes(p_abs) + _tree_bytes(caches)) / chips

    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "seq_sharded": seq_sharded,
        "params_logical": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "params_padded": cfg.param_count(logical=False, tp=mesh.shape["model"]),
        "state_bytes_per_chip": state_bytes,
    }
    return lowered, meta


def opt_shardings(o_abs, p_abs, p_shard, mesh):
    """Optimizer moments share the param shardings; step is replicated."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    rep = NamedSharding(mesh, P())
    return type(o_abs)(
        step=rep,
        m=jax.tree.map(lambda _l, s: s, o_abs.m, p_shard),
        v=jax.tree.map(lambda _l, s: s, o_abs.v, p_shard),
    )


def analytic_activation_bytes(cfg, shape, mesh, model) -> float:
    """Per-chip activation bound under the nested-remat schedule (what TPU
    buffer assignment would see). XLA:CPU's temp accounting materializes an
    f32 copy of every bf16 dot operand and keeps conservative liveness for
    rolled loops, so the CPU `memory.temp_bytes` is reported as a diagnostic
    only (EXPERIMENTS.md §Perf It.3 forensics).

    Terms (bf16 activations = 2B, f32 transients = 4B):
      boundaries : n_periods x (b_l*s*d) x 2          (outer remat residuals)
      layer_in   : period x (b_l*s*d) x 2             (inner remat residuals)
      cotangent  : 3 x (b_l*s*d) x 4
      work       : max over layer kinds of its transient set
      head/loss  : (b_l*q_chunk*V_l) x 4 x 2
    """
    tp = mesh.shape["model"]
    bs = mesh.shape["data"] * mesh.shape.get("pod", 1)
    b, sq = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        sq = 1
    b_l = max(b // bs, 1)
    if b < bs:  # seq sharded
        sq = max(sq // bs, 1)
        b_l = b
    d = cfg.d_model
    hidden = b_l * sq * d
    n_periods = cfg.n_layers // cfg.period
    V_l = cfg.padded_vocab(tp) // tp
    H, KV = cfg.padded_heads(tp)
    h_l = max(H // tp, 1) if H else 0
    work = 0.0
    for o in range(cfg.period):
        w = 0.0
        if cfg.layer_kind(o) == "attn":
            kv_len = shape.seq_len if shape.kind == "decode" else sq
            w += b_l * h_l * cfg.q_chunk * kv_len * 4          # score chunk
            w += 3 * b_l * sq * h_l * cfg.head_dim * 2         # qkv slices
        else:
            sh_l = max(cfg.ssm_heads // tp, 1)
            w += 3 * b_l * sq * cfg.ssm_chunk * sh_l * 4       # intra-chunk L/W/dW
            w += b_l * sq * (2 * cfg.d_inner // tp + 2 * cfg.ssm_state) * 2
        if cfg.mlp_kind(o) == "moe":
            E_l = max(cfg.n_experts // tp, 1)
            T_g = b_l * sq if shape.kind != "train" else (b * shape.seq_len) // max(cfg.moe_groups, 1)
            C = max(int(np.ceil(cfg.capacity_factor * T_g * cfg.experts_per_token / cfg.n_experts)), 1)
            w += 2 * E_l * C * (d + cfg.d_ff) * 2
        elif cfg.d_ff:
            w += 2 * b_l * sq * (cfg.d_ff // tp if cfg.d_ff % tp == 0 else cfg.d_ff) * 2
        work = max(work, w)
    M = max(cfg.microbatches, 1) if shape.kind == "train" else 1
    total = (n_periods * hidden * 2 + cfg.period * hidden * 2
             + 3 * hidden * 4 + work + b_l * cfg.q_chunk * V_l * 4 * 2) / M
    if shape.kind == "train" and M > 1:
        total += _grad_buffer_bytes(cfg, mesh)  # bf16 accumulation buffer
    if shape.kind != "train":
        # no backward: boundaries/cotangents absent; keep layer transit + head
        total = cfg.period * hidden * 2 + work + b_l * max(sq, 1) * V_l * 4
    return float(total)


def _grad_buffer_bytes(cfg, mesh) -> float:
    chips = int(np.prod(list(mesh.shape.values())))
    return 2.0 * cfg.param_count(logical=False, tp=mesh.shape["model"]) / chips


def _tree_bytes(tree) -> int:
    return sum(
        int(np.prod(l.shape)) * l.dtype.itemsize for l in jax.tree.leaves(tree)
    )




def probe_period_costs(arch: str, shape_name: str, multi_pod: bool,
                       serving_sharding: bool = False):
    """Per-period flop/byte/collective accounting.

    XLA's HloCostAnalysis counts a while-loop body ONCE, so the rolled-scan
    full model undercounts by ~n_periods. We lower UNROLLED 1-period and
    2-period variants (cheap: 1-2 layers of the same width/sharding) and
    extrapolate linearly — exact for a homogeneous layer stack:
        cost(n) = base + n * per_period,  per_period = c2 - c1.
    """
    cfg = get_config(arch)
    out = {}
    for npd in (1, 2):
        lowered, _meta = lower_cell(arch, shape_name, multi_pod,
                                    scan_unroll=True,
                                    n_layers=npd * cfg.period,
                                    serving_sharding=serving_sharding)
        compiled = lowered.compile()
        ca = _cost_dict(compiled.cost_analysis())
        colls = scrape_collectives(compiled.as_text())
        out[npd] = {
            "flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "wire": sum(c["wire_bytes"] for c in colls),
            "by_kind": _sum_by_kind(colls),
            "global_flops": _cost_dict(lowered.cost_analysis()).get("flops", 0.0),
        }
    n_periods = cfg.n_layers // cfg.period
    per = {k: out[2][k] - out[1][k] for k in ("flops", "bytes", "wire", "global_flops")}
    base = {k: out[1][k] - per[k] for k in per}
    per_kind = {k: out[2]["by_kind"].get(k, 0.0) - out[1]["by_kind"].get(k, 0.0)
                for k in set(out[1]["by_kind"]) | set(out[2]["by_kind"])}
    base_kind = {k: out[1]["by_kind"].get(k, 0.0) - per_kind.get(k, 0.0)
                 for k in per_kind}
    total = {k: base[k] + n_periods * per[k] for k in per}
    total_kind = {k: base_kind[k] + n_periods * per_kind[k] for k in per_kind}
    return {
        "device_flops_extrap": total["flops"],
        "device_bytes_extrap": total["bytes"],
        "global_flops_extrap": total["global_flops"],
        "collective_wire_bytes_extrap": total["wire"],
        "collectives_by_kind_extrap": total_kind,
        "per_period": per,
    }


def _sum_by_kind(colls):
    out = {}
    for c in colls:
        out[c["kind"]] = out.get(c["kind"], 0.0) + c["wire_bytes"]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR, force: bool = False,
             serving_sharding: bool = False) -> Dict[str, Any]:
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "multipod" if multi_pod else "pod"
    tag = f"{arch}-servshard" if serving_sharding else arch
    path = os.path.join(out_dir, f"{tag}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    t0 = time.monotonic()
    lowered, meta = lower_cell(arch, shape_name, multi_pod,
                               serving_sharding=serving_sharding)
    t_lower = time.monotonic() - t0
    if serving_sharding:
        meta["arch"] = tag
    global_flops = _cost_dict(lowered.cost_analysis()).get("flops", 0.0)

    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    ca = _cost_dict(compiled.cost_analysis())
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = scrape_collectives(hlo)
    probe = probe_period_costs(arch, shape_name, multi_pod,
                               serving_sharding=serving_sharding)

    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
        "output_bytes": getattr(ma, "output_size_in_bytes", 0),
        "temp_bytes_cpu_backend": getattr(ma, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
    }
    # peak per-chip: live state + ANALYTIC activation bound. The CPU
    # backend's temp number is kept as a diagnostic: XLA:CPU materializes
    # f32 copies of bf16 dot operands and schedules rolled loops
    # conservatively, neither of which exists on TPU (HLO forensics in
    # EXPERIMENTS.md §Perf It.3).
    cfg_m = get_config(arch)
    shape_m = SHAPES[shape_name]
    mesh_m = make_production_mesh(multi_pod=multi_pod)
    model_m = None
    act = analytic_activation_bytes(cfg_m, shape_m, mesh_m, model_m)
    mem["activation_bytes_analytic"] = act
    peak = meta["state_bytes_per_chip"] + act
    coll_wire = sum(c["wire_bytes"] for c in colls)
    by_kind: Dict[str, float] = {}
    for c in colls:
        by_kind[c["kind"]] = by_kind.get(c["kind"], 0.0) + c["wire_bytes"]

    # gradient-accumulation scan bodies are counted ONCE by cost analysis:
    # scale per-step costs by M for train cells
    M = get_config(arch).microbatches if SHAPES[shape_name].kind == "train" else 1
    if M > 1:
        for key in ("device_flops_extrap", "device_bytes_extrap",
                    "global_flops_extrap", "collective_wire_bytes_extrap"):
            if key in probe:
                probe[key] *= M
        probe["collectives_by_kind_extrap"] = {
            k: v * M for k, v in probe.get("collectives_by_kind_extrap", {}).items()}
    result = {
        **meta,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "global_flops": global_flops,
        "device_flops": ca.get("flops", 0.0),
        "device_bytes": ca.get("bytes accessed", 0.0),
        "memory": mem,
        "peak_bytes_per_chip": peak,
        "fits_hbm": bool(peak <= HBM_PER_CHIP),
        "n_collectives": len(colls),
        "collective_wire_bytes_rolled": coll_wire,
        "collectives_by_kind_rolled": by_kind,
        **probe,
    }
    # HBM check: report, and hard-fail only when state alone cannot fit
    if meta["state_bytes_per_chip"] > HBM_PER_CHIP:
        result["ok"] = False
        result["error"] = "state exceeds HBM"

    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--serving-sharding", action="store_true",
                    help="replicate params over data axes for serve cells")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            cfg = get_config(arch)
            for shape_name, shape in SHAPES.items():
                if not shape_applicable(cfg, shape):
                    continue
                for mp in ((False, True) if args.mesh in ("both",) else
                           ((args.mesh == "multipod"),)):
                    cells.append((arch, shape_name, mp))
    else:
        meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    failures = 0
    for arch, shape_name, mp in cells:
        tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
        try:
            r = run_cell(arch, shape_name, mp, force=args.force,
                         serving_sharding=args.serving_sharding)
            print(f"[ok] {tag}: compile {r['compile_s']}s, "
                  f"state {r['state_bytes_per_chip']/2**30:.2f} GiB/chip, "
                  f"fits_hbm={r['fits_hbm']}, colls={r['n_collectives']}",
                  flush=True)
            if not r["ok"]:
                failures += 1
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
