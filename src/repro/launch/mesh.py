"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module never
touches jax device state — required by the dry-run protocol.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per v5e pod; the multi-pod mesh adds a leading 'pod' axis
    (2 pods = 512 chips). Sources sharded over 'pod' need no per-round
    collectives in the RPQ engine (tree independence — DESIGN.md §4)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 2):
    """Small mesh over whatever devices exist (CPU tests: set
    XLA_FLAGS=--xla_force_host_platform_device_count=8 in the TEST process)."""
    n = len(jax.devices())
    data = max(n // model_axis, 1)
    return jax.make_mesh((data, model_axis), ("data", "model"))


def mesh_context(mesh):
    """`jax.set_mesh(mesh)` where it exists (jax >= 0.5); the legacy
    `with mesh:` context otherwise. All in-repo mesh-scoped blocks go
    through here so one jax upgrade path touches one line."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
