"""Synthetic token pipeline: deterministic, sharded, prefetching.

Production posture: each host draws only ITS batch shard (host_id-keyed
PRNG), the global batch is assembled by the runtime via device_put with the
batch sharding; the cursor (`step`) lives in checkpoints for exact resume.
A background prefetch thread keeps `depth` batches ready — the straggler
knob in distributed/fault.py builds on this.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np


class TokenPipeline:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        batch_per_host: int,
        seed: int = 0,
        host_id: int = 0,
        prefix_len: int = 0,
        d_model: int = 0,
        start_step: int = 0,
        prefetch_depth: int = 2,
    ):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_per_host
        self.seed = seed
        self.host = host_id
        self.prefix_len = prefix_len
        self.d_model = d_model
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch_depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self.host, step))
        tok_len = self.seq - self.prefix_len
        batch = {
            "tokens": rng.integers(0, self.vocab, (self.batch, tok_len), dtype=np.int32)
        }
        if self.prefix_len:
            batch["prefix_embeds"] = rng.standard_normal(
                (self.batch, self.prefix_len, self.d_model), dtype=np.float32
            )
        return batch

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put(self._make(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self._q.get()
        self.step += 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def cursor(self) -> int:
        return self.step

    def close(self):
        self._stop.set()
