"""(max, min) bottleneck-semiring relaxation over the product graph.

The dense Δ index is ``dist[x, v, s]`` = best (max over paths) bottleneck
(min over edges) timestamp of any path x→v whose label drives the DFA from
s0 to s (DESIGN.md §2). One *relaxation round* applies every DFA transition
(s, l, t):

    out[x, v, t] ∨= max_u min(dist[x, u, s], adj[l, u, v])     (∨ = max)

plus the *base* term for transitions out of s0 (seed paths of length 1):

    out[x, v, t] ∨= adj[l, x, v]          for (s0, l, t)

The closure iterates rounds to a fixpoint (monotone, so `lax.while_loop`
on a changed-flag terminates in at most product-graph-diameter rounds).

Every round is parameterized by a :class:`~repro.core.backend.ContractionBackend`
object (PR 4) — ``jnp`` oracle, fused-batched ``pallas`` VPU kernel, or the
level-quantized ``mxu_bucket`` MXU mode. Plain strings are accepted and
VALIDATED (unknown names raise; they used to fall back to jnp silently).
The closure entry points additionally thread ``now``/``w_max`` so a backend
whose operand representation is anchored to the stream clock (the bucket
level grid) can ``prepare_state``/``decode_state`` at the dispatch
boundary; the round loop itself never leaves the backend's representation.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .backend import BackendLike, ContractionBackend, resolve_backend

NEG_INF = float("-inf")


class TransitionTable(NamedTuple):
    """Static DFA transition arrays (built once at query registration)."""

    src: jnp.ndarray      # (J,) int32 source state of each transition
    lab: jnp.ndarray      # (J,) int32 label index
    dst: jnp.ndarray      # (J,) int32 destination state
    dst_onehot: jnp.ndarray  # (J, K) f32 one-hot of dst (for scatter-max)
    start_mask: jnp.ndarray  # (J,) bool: src == s0
    k: int
    n_labels: int

    @staticmethod
    def from_dfa(dfa) -> "TransitionTable":
        trans = dfa.transitions()
        if not trans:
            trans = [(0, 0, 0)]  # degenerate: empty language; never fires
            src = np.array([0], np.int32)
            lab = np.array([0], np.int32)
            dst = np.array([0], np.int32)
            oh = np.zeros((1, max(dfa.k, 1)), np.float32)
            return TransitionTable(
                jnp.asarray(src), jnp.asarray(lab), jnp.asarray(dst),
                jnp.asarray(oh), jnp.asarray(np.array([False])),
                max(dfa.k, 1), max(dfa.n_labels, 1),
            )
        src = np.array([s for (s, _l, _t) in trans], np.int32)
        lab = np.array([l for (_s, l, _t) in trans], np.int32)
        dst = np.array([t for (_s, _l, t) in trans], np.int32)
        oh = np.zeros((len(trans), dfa.k), np.float32)
        oh[np.arange(len(trans)), dst] = 1.0
        return TransitionTable(
            src=jnp.asarray(src),
            lab=jnp.asarray(lab),
            dst=jnp.asarray(dst),
            dst_onehot=jnp.asarray(oh),
            start_mask=jnp.asarray(src == dfa.start),
            k=dfa.k,
            n_labels=dfa.n_labels,
        )


def relax_round(
    dist: jnp.ndarray,          # (N, N, K) in the backend's representation
    adj: jnp.ndarray,           # (L, N, N)
    tt: TransitionTable,
    backend: BackendLike = "jnp",
) -> jnp.ndarray:
    """One relaxation round; returns the pointwise max of dist and all
    transition contributions (monotone). Operands are in the backend's
    representation (f32 timestamps for jnp/pallas, int32 levels for
    mxu_bucket — callers of the raw round encode themselves; the closure
    entry points do it via ``prepare_state``)."""
    backend = resolve_backend(backend)
    zero = jnp.asarray(backend.zero, dist.dtype)

    def per_transition(j, acc):
        s = tt.src[j]
        l = tt.lab[j]
        dist_s = jax.lax.dynamic_index_in_dim(
            jnp.moveaxis(dist, 2, 0), s, axis=0, keepdims=False
        )  # (N, N) [x, u]
        adj_l = jax.lax.dynamic_index_in_dim(adj, l, axis=0, keepdims=False)
        contrib = backend.contract(dist_s, adj_l)             # (N, N) [x, v]
        # base term: seed (x, x, s0) = +inf => min(+inf, adj[l, x, v]) = adj
        contrib = jnp.where(tt.start_mask[j], jnp.maximum(contrib, adj_l), contrib)
        # scatter-max into destination state slice
        oh = tt.dst_onehot[j]                                  # (K,)
        upd = jnp.where(oh[None, None, :] > 0, contrib[:, :, None], zero)
        return jnp.maximum(acc, upd)

    out = jax.lax.fori_loop(0, tt.src.shape[0], per_transition, dist)
    return out


def closure(
    dist: jnp.ndarray,
    adj: jnp.ndarray,
    tt: TransitionTable,
    backend: BackendLike = "jnp",
    max_rounds: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Iterate relaxation to fixpoint. Returns (dist, rounds_used).

    max_rounds=0 -> bound by N*K (longest simple product path)."""
    backend = resolve_backend(backend)
    n, _, k = dist.shape
    bound = max_rounds if max_rounds > 0 else n * k + 1

    def cond(carry):
        _d, changed, it = carry
        return jnp.logical_and(changed, it < bound)

    def body(carry):
        d, _changed, it = carry
        nd = relax_round(d, adj, tt, backend)
        return nd, jnp.any(nd > d), it + 1

    dist0 = relax_round(dist, adj, tt, backend)
    dist_f, _, rounds = jax.lax.while_loop(
        cond, body, (dist0, jnp.asarray(True), jnp.asarray(1, jnp.int32))
    )
    return dist_f, rounds


def valid_pairs(
    dist: jnp.ndarray, finals: jnp.ndarray, low: jnp.ndarray
) -> jnp.ndarray:
    """(N, N) bool: pair (x, v) has an accepting path fully inside the
    window, i.e. max over final states of dist > low. `finals` is a (K,)
    bool mask."""
    acc = jnp.where(finals[None, None, :], dist, NEG_INF)
    best = jnp.max(acc, axis=2)
    return best > low


# ---------------------------------------------------------------------------
# Multi-query batched formulation
#
# All registered queries share one (L, N, N) adjacency over the UNION label
# alphabet; per-query closure state is stacked into dist (Q, N, N, K) with K
# padded to max_q k_q (padding states are inert: no transition ever scatters
# into them and finals masks are padded False). The per-query DFA transition
# tables are flattened into ONE global transition list — `qidx` names the
# owning query, `lab` indexes the shared alphabet — so a relaxation round is
# a single gather -> batched max-min contraction -> segment-max scatter, and
# one jitted step evaluates every query.
# ---------------------------------------------------------------------------


class BatchedTransitionTable(NamedTuple):
    """Flattened transition arrays of Q stacked DFAs (built at registration).

    J = total transitions across all queries, rounded UP to a bucket
    multiple so different query mixes reuse the same compiled step (J and K
    are trace-time shapes; without bucketing every registration set would
    recompile the closure). Padding rows are inert (`active` False -> their
    contribution is -inf, the semiring zero); padded K states are inert
    because no transition scatters into them and finals masks pad False.
    Queries with an empty language contribute no rows.
    """

    qidx: jnp.ndarray        # (J,) int32 owning query
    src: jnp.ndarray         # (J,) int32 source DFA state (< k_q)
    lab: jnp.ndarray         # (J,) int32 label index in the SHARED alphabet
    dst: jnp.ndarray         # (J,) int32 destination DFA state
    start_mask: jnp.ndarray  # (J,) bool: src == s0 of the owning query
    active: jnp.ndarray      # (J,) bool: False for shape-padding rows
    n_queries: int
    k: int                   # K_max (padded per-query state count)
    n_labels: int            # |union alphabet|

    @staticmethod
    def from_dfas(
        dfas: Sequence, labels: Sequence[str],
        j_bucket: int = 8, k_bucket: int = 2, k_min: int = 1,
    ) -> "BatchedTransitionTable":
        """Stack per-query DFAs over a shared label alphabet.

        ``k_min`` floors the padded state count: a live engine whose device
        state already has K state slots passes ``k_min=K`` so deregistering
        its deepest query never *shrinks* the table below the allocated dist
        axis (the extra states are inert padding either way).
        """
        labels = tuple(labels)
        lab_index = {lab: i for i, lab in enumerate(labels)}
        k_max = max([d.k for d in dfas] + [1, k_min])
        k_max += (-k_max) % k_bucket
        qidx, src, lab, dst, start = [], [], [], [], []
        for q, dfa in enumerate(dfas):
            for (s, li, t) in dfa.transitions():
                qidx.append(q)
                src.append(s)
                lab.append(lab_index[dfa.labels[li]])
                dst.append(t)
                start.append(s == dfa.start)
        n_active = len(qidx)
        n_rows = max(n_active + (-n_active) % j_bucket, j_bucket)
        pad = n_rows - n_active
        qidx += [0] * pad
        src += [0] * pad
        lab += [0] * pad
        dst += [0] * pad
        start += [False] * pad
        return BatchedTransitionTable(
            qidx=jnp.asarray(np.array(qidx, np.int32)),
            src=jnp.asarray(np.array(src, np.int32)),
            lab=jnp.asarray(np.array(lab, np.int32)),
            dst=jnp.asarray(np.array(dst, np.int32)),
            start_mask=jnp.asarray(np.array(start, bool)),
            active=jnp.asarray(np.array([True] * n_active + [False] * pad)),
            n_queries=len(dfas),
            k=k_max,
            n_labels=max(len(labels), 1),
        )


def batched_relax_round(
    dist: jnp.ndarray,          # (Q, N, N, K) in the backend's representation
    adj: jnp.ndarray,           # (L, N, N) shared adjacency (same repr)
    btt: BatchedTransitionTable,
    backend: BackendLike = "jnp",
    query_mask: Optional[jnp.ndarray] = None,   # (Q,) bool, True = relax
) -> jnp.ndarray:
    """One relaxation round over ALL queries' transitions at once.

    ``query_mask`` is the per-query convergence mask: rows owned by a masked
    (False) query contribute the semiring zero and the query's dist slices
    pass through untouched, so an already-converged (or inert padding) lane
    stops participating in the round instead of relaxing as a no-op.
    Transitions only ever read their OWN query's dist slices, so masking one
    lane cannot perturb another (the soundness condition for early per-query
    convergence in :func:`batched_closure`). Note the dense round is
    shape-static: masked rows are still contracted, then zeroed — the mask
    buys exact per-query round accounting (and, on a Q-sharded deployment,
    the signal to skip a converged lane's contraction entirely), not fewer
    FLOPs on a single device."""
    backend = resolve_backend(backend)
    q, n, _, k = dist.shape
    active = btt.active
    if query_mask is not None:
        active = jnp.logical_and(active, query_mask[btt.qidx])
    # contraction (masked rows carry the semiring zero already)
    contrib = backend.contract_batched(dist, adj, btt, active)  # (J, N, N)
    # base term: seed (x, x, s0) = +inf => min(+inf, adj[l, x, v]) = adj
    # (applied only to ACTIVE start rows so it cannot unmask a zeroed row)
    a_l = adj[btt.lab]                                # (J, N, N) [u, v]
    base_rows = jnp.logical_and(btt.start_mask, active)
    contrib = jnp.where(base_rows[:, None, None],
                        jnp.maximum(contrib, a_l), contrib)
    # scatter-max into (query, dst-state) slices; empty segments fill the
    # dtype minimum (below the semiring zero in every representation)
    seg = btt.qidx * k + btt.dst                      # (J,)
    scat = jax.ops.segment_max(contrib, seg, num_segments=q * k)
    upd = jnp.transpose(scat.reshape(q, k, n, n), (0, 2, 3, 1))
    out = jnp.maximum(dist, upd)
    if query_mask is not None:
        out = jnp.where(query_mask[:, None, None, None], out, dist)
    return out


def batched_closure(
    dist: jnp.ndarray,
    adj: jnp.ndarray,
    btt: BatchedTransitionTable,
    backend: BackendLike = "jnp",
    max_rounds: int = 0,
    query_mask: Optional[jnp.ndarray] = None,   # (Q,) bool initial mask
    now: Optional[jnp.ndarray] = None,          # () stream clock
    w_max: Optional[jnp.ndarray] = None,        # () group's largest window
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Iterate batched relaxation with per-query convergence masking.

    Each round relaxes only the queries still changing: once a query's round
    produces no update it is at its fixpoint (its transitions read only its
    own slices and the shared adjacency, which is constant during the
    closure), so it is masked out of every subsequent round. The loop ends
    when the slowest query converges.

    ``query_mask`` optionally restricts which queries participate at all
    (inert padding lanes of a live engine, or a single lane being seeded at
    registration); masked-from-the-start queries count zero rounds.

    Returns ``(dist, rounds, query_rounds)``: ``rounds`` is the global
    iteration count (max over participating queries; identical to the
    unmasked regime — the loop still runs until the slowest member
    settles), ``query_rounds`` is the (Q,) int32 per-query count of rounds
    the query actively relaxed. ``query_rounds.sum()`` vs Q * ``rounds``
    (benchmarks/fig12_multi_query.py) quantifies how much of the group's
    relaxation is no-op tail a Q-sharded execution could skip.

    ``now``/``w_max`` (the stream clock and the group's largest window)
    anchor backends whose operand representation moves with the clock:
    ``prepare_state`` converts the f32 timestamp arrays once at entry,
    every round runs in the backend's representation, ``decode_state``
    converts back once at exit (identity for jnp/pallas)."""
    backend = resolve_backend(backend)
    q, n, _, k = dist.shape
    bound = max_rounds if max_rounds > 0 else n * k + 1
    mask0 = (jnp.ones((q,), bool) if query_mask is None
             else jnp.asarray(query_mask, bool))
    dist_op, adj_op = backend.prepare_state(dist, adj, now, w_max)

    def cond(carry):
        _d, mask, it, _qr = carry
        return jnp.logical_and(jnp.any(mask), it < bound)

    def body(carry):
        d, mask, it, qr = carry
        nd = batched_relax_round(d, adj_op, btt, backend, query_mask=mask)
        changed = jnp.any(nd > d, axis=(1, 2, 3))     # (Q,) per-query
        return nd, jnp.logical_and(mask, changed), it + 1, qr + mask

    dist0 = batched_relax_round(dist_op, adj_op, btt, backend, query_mask=mask0)
    changed0 = jnp.logical_and(mask0, jnp.any(dist0 > dist_op, axis=(1, 2, 3)))
    qr0 = mask0.astype(jnp.int32)
    dist_f, _, rounds, query_rounds = jax.lax.while_loop(
        cond, body, (dist0, changed0, jnp.asarray(1, jnp.int32), qr0)
    )
    return backend.decode_state(dist_f, now, w_max), rounds, query_rounds


def batched_valid_pairs(
    dist: jnp.ndarray, finals: jnp.ndarray, low: jnp.ndarray
) -> jnp.ndarray:
    """(Q, N, N) bool validity per query: finals is (Q, K), low is (Q,)
    (per-query window thresholds applied at read time)."""
    acc = jnp.where(finals[:, None, None, :], dist, NEG_INF)
    best = jnp.max(acc, axis=3)
    return best > low[:, None, None]


# ---------------------------------------------------------------------------
# Sharded (shard_map-local) round variants
#
# The mesh executor (distributed/executor.py) shards the Q lane axis over
# the mesh's data axis and (optionally) the vertex axis over model. Inside
# a shard_map block each shard sees dist (Q_l, N, N_m, K) plus ONLY its own
# queries' transition rows, relaxes them to ITS OWN fixpoint, and skips the
# contraction entirely once its lanes have all converged — the realized form
# of the per-query convergence masking that the dense single-device round
# could only account for (batched_relax_round docstring). The row layout is
# built host-side by `shard_transitions`.
# ---------------------------------------------------------------------------


def shard_transitions(
    btt: BatchedTransitionTable, q_cap: int, n_shards: int, j_bucket: int = 8
) -> Tuple[jnp.ndarray, ...]:
    """Regroup a flattened transition table by lane shard.

    Lanes are block-partitioned: shard i owns lanes [i*q_cap/n_shards,
    (i+1)*q_cap/n_shards). Returns six (n_shards, J_s) arrays — qidx
    (SHARD-LOCAL lane index), src, lab, dst, start_mask, active — with J_s
    the bucketed max row count over shards (padding rows inert). ``q_cap``
    must be a multiple of ``n_shards`` (the engine rounds lane capacity to
    the executor's ``q_multiple``).
    """
    if q_cap % n_shards:
        raise ValueError(f"q_cap {q_cap} not divisible by {n_shards} shards")
    q_shard = q_cap // n_shards
    qidx = np.asarray(btt.qidx)
    active = np.asarray(btt.active)
    src = np.asarray(btt.src)
    lab = np.asarray(btt.lab)
    dst = np.asarray(btt.dst)
    start = np.asarray(btt.start_mask)
    rows: List[List[int]] = [[] for _ in range(n_shards)]
    for j in np.nonzero(active)[0].tolist():
        rows[int(qidx[j]) // q_shard].append(j)
    j_max = max([len(r) for r in rows] + [1])
    j_s = max(j_max + (-j_max) % j_bucket, j_bucket)
    out = {
        "qidx": np.zeros((n_shards, j_s), np.int32),
        "src": np.zeros((n_shards, j_s), np.int32),
        "lab": np.zeros((n_shards, j_s), np.int32),
        "dst": np.zeros((n_shards, j_s), np.int32),
        "start": np.zeros((n_shards, j_s), bool),
        "active": np.zeros((n_shards, j_s), bool),
    }
    for sh, row_ids in enumerate(rows):
        for jj, j in enumerate(row_ids):
            out["qidx"][sh, jj] = qidx[j] - sh * q_shard
            out["src"][sh, jj] = src[j]
            out["lab"][sh, jj] = lab[j]
            out["dst"][sh, jj] = dst[j]
            out["start"][sh, jj] = start[j]
            out["active"][sh, jj] = True
    return (jnp.asarray(out["qidx"]), jnp.asarray(out["src"]),
            jnp.asarray(out["lab"]), jnp.asarray(out["dst"]),
            jnp.asarray(out["start"]), jnp.asarray(out["active"]))


def shard_relax_round(
    dist_blk: jnp.ndarray,     # (Q_l, N, N_m, K) shard-local lane block
    adj_u: jnp.ndarray,        # (L, N_m, N) adjacency, u rows local
    adj_v: jnp.ndarray,        # (L, N, N_m) adjacency, v cols local
    qidx: jnp.ndarray,         # (J_s,) SHARD-LOCAL owning lane
    src: jnp.ndarray,          # (J_s,)
    lab: jnp.ndarray,          # (J_s,)
    dst: jnp.ndarray,          # (J_s,)
    start_mask: jnp.ndarray,   # (J_s,)
    active: jnp.ndarray,       # (J_s,)
    query_mask: jnp.ndarray,   # (Q_l,) bool, True = relax
    backend: BackendLike = "jnp",
    model_axis: Optional[str] = None,
    model_size: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One relaxation round on one lane shard (shard_map-local).

    The u-contraction runs over the shard's LOCAL u-block; when the vertex
    axis is sharded (``model_size > 1``) the per-block partials are
    max-combined across ``model_axis`` (exact: max is associative) and the
    shard keeps its v-column block. Returns ``(new_dist_blk, changed)``
    with ``changed`` (Q_l,) synchronized across the model axis so every
    peer of a lane shard agrees on convergence (uniform loop trip counts —
    the condition that makes collectives inside the closure loop safe).

    Masking semantics mirror :func:`batched_relax_round` exactly: masked
    lanes contribute the semiring zero and pass through untouched.
    Operands are in the backend's representation (:func:`shard_closure`
    converts at the dispatch boundary).
    """
    backend = resolve_backend(backend)
    q_l, n, n_m, k = dist_blk.shape
    act = jnp.logical_and(active, query_mask[qidx])
    d_s = dist_blk[qidx, :, :, src]               # (J, N, N_m) [x, u_local]
    a_u = adj_u[lab]                              # (J, N_m, N) [u_local, v]
    part = backend.contract_rows(d_s, a_u)        # (J, N, N)   [x, v] partial
    if model_axis is not None and model_size > 1:
        part = jax.lax.pmax(part, model_axis)
        vstart = jax.lax.axis_index(model_axis) * n_m
        contrib = jax.lax.dynamic_slice(
            part, (0, 0, vstart), (part.shape[0], n, n_m))
    else:
        contrib = part
    # base term: seed (x, x, s0) = +inf => min(+inf, adj[l, x, v]) = adj
    a_v = adj_v[lab]                              # (J, N, N_m)
    contrib = jnp.where(start_mask[:, None, None],
                        jnp.maximum(contrib, a_v), contrib)
    contrib = jnp.where(act[:, None, None], contrib,
                        jnp.asarray(backend.zero, contrib.dtype))
    seg = qidx * k + dst
    scat = jax.ops.segment_max(contrib, seg, num_segments=q_l * k)
    upd = jnp.transpose(scat.reshape(q_l, k, n, n_m), (0, 2, 3, 1))
    nd = jnp.maximum(dist_blk, upd)
    nd = jnp.where(query_mask[:, None, None, None], nd, dist_blk)
    changed = jnp.any(nd > dist_blk, axis=(1, 2, 3))
    if model_axis is not None and model_size > 1:
        changed = jax.lax.pmax(changed.astype(jnp.int32), model_axis) > 0
    return nd, changed


def shard_closure(
    dist_blk: jnp.ndarray,
    adj_u: jnp.ndarray,
    adj_v: jnp.ndarray,
    rows: Tuple[jnp.ndarray, ...],   # six (J_s,) arrays (shard_transitions)
    query_mask: jnp.ndarray,         # (Q_l,) bool initial mask
    backend: BackendLike = "jnp",
    model_axis: Optional[str] = None,
    model_size: int = 1,
    max_rounds: int = 0,
    now: Optional[jnp.ndarray] = None,    # () stream clock (replicated)
    w_max: Optional[jnp.ndarray] = None,  # () group's largest window
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Shard-local closure with convergence-aware dispatch.

    A shard whose lanes are all masked (converged or inert padding) SKIPS
    the closure entirely (`lax.cond`) — zero contraction work, the win the
    single-device masked round could only account for. Otherwise the shard
    iterates to its OWN fixpoint: its loop ends when its slowest lane
    settles, independent of other shards (no cross-shard data flow — a
    transition only reads its owning lane's slices and the adjacency, which
    is constant during the closure).

    Returns ``(dist_blk, rounds, query_rounds)``: ``rounds`` () int32 is
    the rounds THIS shard actually relaxed (0 when skipped — the per-shard
    skip/finish-early signal the mesh executor aggregates into its
    masked-skip counters), ``query_rounds`` (Q_l,) matches the local
    engine's per-lane accounting.

    The backend's representation boundary sits INSIDE the run branch:
    operands are encoded once per dispatch, the loop runs on them, and the
    result decodes back to f32 timestamps. The skip branch returns the
    raw block untouched (zero work, exact passthrough). Encoding is
    elementwise and ``now`` is replicated, so the per-shard conversion is
    collective-free.
    """
    backend = resolve_backend(backend)
    qidx, src, lab, dst, start, active = rows
    q_l, n, _n_m, k = dist_blk.shape
    bound = max_rounds if max_rounds > 0 else n * k + 1

    def one_round(d, a_u, a_v, mask):
        return shard_relax_round(
            d, a_u, a_v, qidx, src, lab, dst, start, active, mask,
            backend=backend, model_axis=model_axis, model_size=model_size)

    def run(_):
        d_op = backend.encode(dist_blk, now, w_max)
        au_op = backend.encode(adj_u, now, w_max)
        av_op = backend.encode(adj_v, now, w_max)
        d0, ch0 = one_round(d_op, au_op, av_op, query_mask)
        m0 = jnp.logical_and(query_mask, ch0)
        qr0 = query_mask.astype(jnp.int32)
        it0 = jnp.asarray(1, jnp.int32)

        def cond(carry):
            return carry[4]

        def body(carry):
            d, mask, it, qr, _keep = carry
            nd, ch = one_round(d, au_op, av_op, mask)
            nmask = jnp.logical_and(mask, ch)
            it = it + 1
            keep = jnp.logical_and(jnp.any(nmask), it < bound)
            return nd, nmask, it, qr + mask.astype(jnp.int32), keep

        keep0 = jnp.logical_and(jnp.any(m0), it0 < bound)
        d_f, _, it_f, qr_f, _ = jax.lax.while_loop(
            cond, body, (d0, m0, it0, qr0, keep0))
        return backend.decode_state(d_f, now, w_max), it_f, qr_f

    def skip(_):
        return (dist_blk, jnp.asarray(0, jnp.int32),
                jnp.zeros((q_l,), jnp.int32))

    # uniform across the model peers of this lane shard (query_mask is
    # replicated along model), so collectives inside `run` stay safe
    return jax.lax.cond(jnp.any(query_mask), run, skip, None)
