"""(max, min) bottleneck-semiring relaxation over the product graph.

The dense Δ index is ``dist[x, v, s]`` = best (max over paths) bottleneck
(min over edges) timestamp of any path x→v whose label drives the DFA from
s0 to s (DESIGN.md §2). One *relaxation round* applies every DFA transition
(s, l, t):

    out[x, v, t] ∨= max_u min(dist[x, u, s], adj[l, u, v])     (∨ = max)

plus the *base* term for transitions out of s0 (seed paths of length 1):

    out[x, v, t] ∨= adj[l, x, v]          for (s0, l, t)

The closure iterates rounds to a fixpoint (monotone, so `lax.while_loop`
on a changed-flag terminates in at most product-graph-diameter rounds).

Every round is parameterized by a :class:`~repro.core.backend.ContractionBackend`
object (PR 4) — ``jnp`` oracle, fused-batched ``pallas`` VPU kernel, or the
level-quantized ``mxu_bucket`` MXU mode. Plain strings are accepted and
VALIDATED (unknown names raise; they used to fall back to jnp silently).
The closure entry points additionally thread ``now``/``w_max`` so a backend
whose operand representation is anchored to the stream clock (the bucket
level grid) can ``prepare_state``/``decode_state`` at the dispatch
boundary; the round loop itself never leaves the backend's representation.

Since PR 5 the ingest closure also comes in a FRONTIER-RESTRICTED form
(:func:`frontier_closure` / :func:`shard_frontier_closure`): only the
source rows a micro-batch dirties are gathered and relaxed, making
per-event work O(J·F·N²) instead of O(J·N³) on low-degree windows, with an
in-dispatch dense fallback on frontier overflow (bit-identical results
always — see the frontier section below). PR 6 extends the same machinery
to explicit DELETIONS (:func:`frontier_delete` /
:func:`shard_frontier_delete`): the deleted edge's cone — the rows whose
derivations can pass through it — is the same reachability reduction run
against the pre-delete state, so deletes are cone-cleared and re-derived
at frontier prices instead of resetting every row.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .backend import BackendLike, ContractionBackend, resolve_backend
from .sparse_adj import EllAdjacency, ell_label_rows, ell_rows_dense
from .sparse_dist import (RowSparseDist, rsd_from_dense, rsd_gather_rows,
                          rsd_scatter_rows, rsd_seed_gathered, rsd_to_dense,
                          rsd_valid_pairs)

NEG_INF = float("-inf")


class TransitionTable(NamedTuple):
    """Static DFA transition arrays (built once at query registration)."""

    src: jnp.ndarray      # (J,) int32 source state of each transition
    lab: jnp.ndarray      # (J,) int32 label index
    dst: jnp.ndarray      # (J,) int32 destination state
    dst_onehot: jnp.ndarray  # (J, K) f32 one-hot of dst (for scatter-max)
    start_mask: jnp.ndarray  # (J,) bool: src == s0
    k: int
    n_labels: int

    @staticmethod
    def from_dfa(dfa) -> "TransitionTable":
        trans = dfa.transitions()
        if not trans:
            trans = [(0, 0, 0)]  # degenerate: empty language; never fires
            src = np.array([0], np.int32)
            lab = np.array([0], np.int32)
            dst = np.array([0], np.int32)
            oh = np.zeros((1, max(dfa.k, 1)), np.float32)
            return TransitionTable(
                jnp.asarray(src), jnp.asarray(lab), jnp.asarray(dst),
                jnp.asarray(oh), jnp.asarray(np.array([False])),
                max(dfa.k, 1), max(dfa.n_labels, 1),
            )
        src = np.array([s for (s, _l, _t) in trans], np.int32)
        lab = np.array([l for (_s, l, _t) in trans], np.int32)
        dst = np.array([t for (_s, _l, t) in trans], np.int32)
        oh = np.zeros((len(trans), dfa.k), np.float32)
        oh[np.arange(len(trans)), dst] = 1.0
        return TransitionTable(
            src=jnp.asarray(src),
            lab=jnp.asarray(lab),
            dst=jnp.asarray(dst),
            dst_onehot=jnp.asarray(oh),
            start_mask=jnp.asarray(src == dfa.start),
            k=dfa.k,
            n_labels=dfa.n_labels,
        )


def relax_round(
    dist: jnp.ndarray,          # (N, N, K) in the backend's representation
    adj: jnp.ndarray,           # (L, N, N)
    tt: TransitionTable,
    backend: BackendLike = "jnp",
) -> jnp.ndarray:
    """One relaxation round; returns the pointwise max of dist and all
    transition contributions (monotone). Operands are in the backend's
    representation (f32 timestamps for jnp/pallas, int32 levels for
    mxu_bucket — callers of the raw round encode themselves; the closure
    entry points do it via ``prepare_state``)."""
    backend = resolve_backend(backend)
    zero = jnp.asarray(backend.zero, dist.dtype)

    def per_transition(j, acc):
        s = tt.src[j]
        l = tt.lab[j]
        dist_s = jax.lax.dynamic_index_in_dim(
            jnp.moveaxis(dist, 2, 0), s, axis=0, keepdims=False
        )  # (N, N) [x, u]
        adj_l = jax.lax.dynamic_index_in_dim(adj, l, axis=0, keepdims=False)
        contrib = backend.contract(dist_s, adj_l)             # (N, N) [x, v]
        # base term: seed (x, x, s0) = +inf => min(+inf, adj[l, x, v]) = adj
        contrib = jnp.where(tt.start_mask[j], jnp.maximum(contrib, adj_l), contrib)
        # scatter-max into destination state slice
        oh = tt.dst_onehot[j]                                  # (K,)
        upd = jnp.where(oh[None, None, :] > 0, contrib[:, :, None], zero)
        return jnp.maximum(acc, upd)

    out = jax.lax.fori_loop(0, tt.src.shape[0], per_transition, dist)
    return out


def closure(
    dist: jnp.ndarray,
    adj: jnp.ndarray,
    tt: TransitionTable,
    backend: BackendLike = "jnp",
    max_rounds: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Iterate relaxation to fixpoint. Returns (dist, rounds_used).

    max_rounds=0 -> bound by N*K (longest simple product path)."""
    backend = resolve_backend(backend)
    n, _, k = dist.shape
    bound = max_rounds if max_rounds > 0 else n * k + 1

    def cond(carry):
        _d, changed, it = carry
        return jnp.logical_and(changed, it < bound)

    def body(carry):
        d, _changed, it = carry
        nd = relax_round(d, adj, tt, backend)
        return nd, jnp.any(nd > d), it + 1

    dist0 = relax_round(dist, adj, tt, backend)
    dist_f, _, rounds = jax.lax.while_loop(
        cond, body, (dist0, jnp.asarray(True), jnp.asarray(1, jnp.int32))
    )
    return dist_f, rounds


def valid_pairs(
    dist: jnp.ndarray, finals: jnp.ndarray, low: jnp.ndarray
) -> jnp.ndarray:
    """(N, N) bool: pair (x, v) has an accepting path fully inside the
    window, i.e. max over final states of dist > low. `finals` is a (K,)
    bool mask."""
    acc = jnp.where(finals[None, None, :], dist, NEG_INF)
    best = jnp.max(acc, axis=2)
    return best > low


# ---------------------------------------------------------------------------
# Multi-query batched formulation
#
# All registered queries share one (L, N, N) adjacency over the UNION label
# alphabet; per-query closure state is stacked into dist (Q, N, N, K) with K
# padded to max_q k_q (padding states are inert: no transition ever scatters
# into them and finals masks are padded False). The per-query DFA transition
# tables are flattened into ONE global transition list — `qidx` names the
# owning query, `lab` indexes the shared alphabet — so a relaxation round is
# a single gather -> batched max-min contraction -> segment-max scatter, and
# one jitted step evaluates every query.
# ---------------------------------------------------------------------------


class BatchedTransitionTable(NamedTuple):
    """Flattened transition arrays of Q stacked DFAs (built at registration).

    J = total transitions across all queries, rounded UP to a bucket
    multiple so different query mixes reuse the same compiled step (J and K
    are trace-time shapes; without bucketing every registration set would
    recompile the closure). Padding rows are inert (`active` False -> their
    contribution is -inf, the semiring zero); padded K states are inert
    because no transition scatters into them and finals masks pad False.
    Queries with an empty language contribute no rows.
    """

    qidx: jnp.ndarray        # (J,) int32 owning query
    src: jnp.ndarray         # (J,) int32 source DFA state (< k_q)
    lab: jnp.ndarray         # (J,) int32 label index in the SHARED alphabet
    dst: jnp.ndarray         # (J,) int32 destination DFA state
    start_mask: jnp.ndarray  # (J,) bool: src == s0 of the owning query
    active: jnp.ndarray      # (J,) bool: False for shape-padding rows
    n_queries: int
    k: int                   # K_max (padded per-query state count)
    n_labels: int            # |union alphabet|

    @staticmethod
    def from_dfas(
        dfas: Sequence, labels: Sequence[str],
        j_bucket: int = 8, k_bucket: int = 2, k_min: int = 1,
    ) -> "BatchedTransitionTable":
        """Stack per-query DFAs over a shared label alphabet.

        ``k_min`` floors the padded state count: a live engine whose device
        state already has K state slots passes ``k_min=K`` so deregistering
        its deepest query never *shrinks* the table below the allocated dist
        axis (the extra states are inert padding either way).
        """
        labels = tuple(labels)
        lab_index = {lab: i for i, lab in enumerate(labels)}
        k_max = max([d.k for d in dfas] + [1, k_min])
        k_max += (-k_max) % k_bucket
        qidx, src, lab, dst, start = [], [], [], [], []
        for q, dfa in enumerate(dfas):
            for (s, li, t) in dfa.transitions():
                qidx.append(q)
                src.append(s)
                lab.append(lab_index[dfa.labels[li]])
                dst.append(t)
                start.append(s == dfa.start)
        n_active = len(qidx)
        n_rows = max(n_active + (-n_active) % j_bucket, j_bucket)
        pad = n_rows - n_active
        qidx += [0] * pad
        src += [0] * pad
        lab += [0] * pad
        dst += [0] * pad
        start += [False] * pad
        return BatchedTransitionTable(
            qidx=jnp.asarray(np.array(qidx, np.int32)),
            src=jnp.asarray(np.array(src, np.int32)),
            lab=jnp.asarray(np.array(lab, np.int32)),
            dst=jnp.asarray(np.array(dst, np.int32)),
            start_mask=jnp.asarray(np.array(start, bool)),
            active=jnp.asarray(np.array([True] * n_active + [False] * pad)),
            n_queries=len(dfas),
            k=k_max,
            n_labels=max(len(labels), 1),
        )


def batched_relax_round(
    dist: jnp.ndarray,          # (Q, N, N, K) in the backend's representation
    adj: jnp.ndarray,           # (L, N, N) shared adjacency (same repr)
    btt: BatchedTransitionTable,
    backend: BackendLike = "jnp",
    query_mask: Optional[jnp.ndarray] = None,   # (Q,) bool, True = relax
) -> jnp.ndarray:
    """One relaxation round over ALL queries' transitions at once.

    ``query_mask`` is the per-query convergence mask: rows owned by a masked
    (False) query contribute the semiring zero and the query's dist slices
    pass through untouched, so an already-converged (or inert padding) lane
    stops participating in the round instead of relaxing as a no-op.
    Transitions only ever read their OWN query's dist slices, so masking one
    lane cannot perturb another (the soundness condition for early per-query
    convergence in :func:`batched_closure`). Note the dense round is
    shape-static: masked rows are still contracted, then zeroed — the mask
    buys exact per-query round accounting (and, on a Q-sharded deployment,
    the signal to skip a converged lane's contraction entirely), not fewer
    FLOPs on a single device."""
    backend = resolve_backend(backend)
    q, n, _, k = dist.shape
    active = btt.active
    if query_mask is not None:
        active = jnp.logical_and(active, query_mask[btt.qidx])
    # contraction (masked rows carry the semiring zero already); the adj
    # operand's LAYOUT dispatches at trace time — an EllAdjacency is a
    # different pytree, so the jitted callers key separate traces and the
    # Python isinstance is resolved once per compile, never per step
    if isinstance(adj, EllAdjacency):
        contrib = backend.contract_batched_ell(dist, adj, btt, active)
        a_l = ell_label_rows(adj, btt.lab, backend.zero)  # (J, N, N)
    else:
        contrib = backend.contract_batched(dist, adj, btt, active)  # (J, N, N)
        a_l = adj[btt.lab]                            # (J, N, N) [u, v]
    # base term: seed (x, x, s0) = +inf => min(+inf, adj[l, x, v]) = adj
    # (applied only to ACTIVE start rows so it cannot unmask a zeroed row)
    base_rows = jnp.logical_and(btt.start_mask, active)
    contrib = jnp.where(base_rows[:, None, None],
                        jnp.maximum(contrib, a_l), contrib)
    # scatter-max into (query, dst-state) slices; empty segments fill the
    # dtype minimum (below the semiring zero in every representation)
    seg = btt.qidx * k + btt.dst                      # (J,)
    scat = jax.ops.segment_max(contrib, seg, num_segments=q * k)
    upd = jnp.transpose(scat.reshape(q, k, n, n), (0, 2, 3, 1))
    out = jnp.maximum(dist, upd)
    if query_mask is not None:
        out = jnp.where(query_mask[:, None, None, None], out, dist)
    return out


def _masked_closure_loop(
    dist_op: jnp.ndarray,
    adj_op: jnp.ndarray,
    btt: BatchedTransitionTable,
    backend: ContractionBackend,
    mask0: jnp.ndarray,
    bound: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The convergence-masked fixpoint loop on operands ALREADY in the
    backend's representation (shared by :func:`batched_closure` and the
    frontier path's overflow fallback — the fallback must run the exact
    dense loop so a fallback dispatch stays bit-identical to ``frontier="off"``)."""

    def cond(carry):
        _d, mask, it, _qr = carry
        return jnp.logical_and(jnp.any(mask), it < bound)

    def body(carry):
        d, mask, it, qr = carry
        nd = batched_relax_round(d, adj_op, btt, backend, query_mask=mask)
        changed = jnp.any(nd > d, axis=(1, 2, 3))     # (Q,) per-query
        return nd, jnp.logical_and(mask, changed), it + 1, qr + mask

    dist0 = batched_relax_round(dist_op, adj_op, btt, backend, query_mask=mask0)
    changed0 = jnp.logical_and(mask0, jnp.any(dist0 > dist_op, axis=(1, 2, 3)))
    qr0 = mask0.astype(jnp.int32)
    dist_f, _, rounds, query_rounds = jax.lax.while_loop(
        cond, body, (dist0, changed0, jnp.asarray(1, jnp.int32), qr0)
    )
    return dist_f, rounds, query_rounds


def batched_closure(
    dist: jnp.ndarray,
    adj: jnp.ndarray,
    btt: BatchedTransitionTable,
    backend: BackendLike = "jnp",
    max_rounds: int = 0,
    query_mask: Optional[jnp.ndarray] = None,   # (Q,) bool initial mask
    now: Optional[jnp.ndarray] = None,          # () stream clock
    w_max: Optional[jnp.ndarray] = None,        # () group's largest window
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Iterate batched relaxation with per-query convergence masking.

    Each round relaxes only the queries still changing: once a query's round
    produces no update it is at its fixpoint (its transitions read only its
    own slices and the shared adjacency, which is constant during the
    closure), so it is masked out of every subsequent round. The loop ends
    when the slowest query converges.

    ``query_mask`` optionally restricts which queries participate at all
    (inert padding lanes of a live engine, or a single lane being seeded at
    registration); masked-from-the-start queries count zero rounds.

    Returns ``(dist, rounds, query_rounds)``: ``rounds`` is the global
    iteration count (max over participating queries; identical to the
    unmasked regime — the loop still runs until the slowest member
    settles), ``query_rounds`` is the (Q,) int32 per-query count of rounds
    the query actively relaxed. ``query_rounds.sum()`` vs Q * ``rounds``
    (benchmarks/fig12_multi_query.py) quantifies how much of the group's
    relaxation is no-op tail a Q-sharded execution could skip.

    ``now``/``w_max`` (the stream clock and the group's largest window)
    anchor backends whose operand representation moves with the clock:
    ``prepare_state`` converts the f32 timestamp arrays once at entry,
    every round runs in the backend's representation, ``decode_state``
    converts back once at exit (identity for jnp/pallas).

    A :class:`~repro.core.sparse_dist.RowSparseDist` ``dist`` takes the
    dense-superset round trip: densify, run the identical dense loop,
    re-pack (the non-frontier dispatches — query registration, relax —
    are whole-state fixpoints anyway; only the frontier paths have a
    row-local form worth keeping sparse end-to-end)."""
    if isinstance(dist, RowSparseDist):
        dense, rounds, qrounds = batched_closure(
            rsd_to_dense(dist), adj, btt, backend, max_rounds,
            query_mask, now, w_max)
        return (rsd_from_dense(dense, dist.dist_cap, dist.ovf_cap,
                               dist.lost), rounds, qrounds)
    backend = resolve_backend(backend)
    q, n, _, k = dist.shape
    bound = max_rounds if max_rounds > 0 else n * k + 1
    mask0 = (jnp.ones((q,), bool) if query_mask is None
             else jnp.asarray(query_mask, bool))
    dist_op, adj_op = backend.prepare_state(dist, adj, now, w_max)
    dist_f, rounds, query_rounds = _masked_closure_loop(
        dist_op, adj_op, btt, backend, mask0, bound)
    return backend.decode_state(dist_f, now, w_max), rounds, query_rounds


def batched_valid_pairs(
    dist: jnp.ndarray, finals: jnp.ndarray, low: jnp.ndarray
) -> jnp.ndarray:
    """(Q, N, N) bool validity per query: finals is (Q, K), low is (Q,)
    (per-query window thresholds applied at read time).

    A :class:`~repro.core.sparse_dist.RowSparseDist` ``dist`` routes to
    the sparse emit (:func:`~repro.core.sparse_dist.rsd_valid_pairs`):
    only stored entries are reduced — O(Q·N·C) instead of the dense
    O(Q·N²·K) scan that dominates per-event cost at large N."""
    if isinstance(dist, RowSparseDist):
        return rsd_valid_pairs(dist, finals, low)
    acc = jnp.where(finals[:, None, None, :], dist, NEG_INF)
    best = jnp.max(acc, axis=3)
    return best > low[:, None, None]


# ---------------------------------------------------------------------------
# Frontier-restricted relaxation (PR 5 tentpole)
#
# The dense round contracts ALL N source rows of every lane even when a
# micro-batch of B inserted edges can only perturb a few of them. But the
# (max, min) recurrence couples dist[q, x, v, t] only to dist[q, x, u, s] —
# the SAME source row x — so each row evolves independently given the shared
# adjacency, and a closure that was at fixpoint before the batch can only
# change on rows that either start at an inserted edge's source (the base
# term) or already reach one with a finite entry (any longer path through a
# new edge factors as x →* u → v, and the x →* u prefix is recorded at the
# pre-batch fixpoint). Those DIRTY rows are an O(Q·N²·K) elementwise
# reduction to find — cheap next to the O(J·N³) contraction they avoid —
# and a round restricted to them reaches the exact dense fixpoint: clean
# rows are provably stable (their round-1 update is a no-op), and dirty
# rows see the same contributions they would in the dense round.
#
# F (the frontier capacity) is a trace-time shape, bucketed ×2 by the
# executor so compile caches are reused; when the live frontier overflows F
# the dispatch falls back to the dense loop IN-DISPATCH (lax.cond) — sound
# and bit-identical, since the dense round is a superset — so worst-case
# cost never exceeds the dense path. Rows that stop changing are masked out
# (never re-added: a row's fate depends only on itself), so the frontier
# only shrinks across rounds and per-event work is O(R·J·F·N²).
# ---------------------------------------------------------------------------


class FrontierStats(NamedTuple):
    """Per-dispatch frontier telemetry (device scalars; the executor queues
    them with the round counters and converts lazily)."""

    seed_rows: jnp.ndarray      # () int32 dirty rows across all lanes
    max_lane_rows: jnp.ndarray  # () int32 largest single-lane frontier
    rows_relaxed: jnp.ndarray   # () int32 sum over rounds of rows relaxed
    fell_back: jnp.ndarray      # () bool dense fallback taken (overflow)


def frontier_seed(
    dist: jnp.ndarray,          # (Q, N, N, K) f32 timestamps (pre-encode)
    src: jnp.ndarray,           # (B,) int32 inserted-edge source slots
    smask: jnp.ndarray,         # (B,) bool batch padding mask
    query_mask: Optional[jnp.ndarray] = None,   # (Q,) bool live lanes
) -> jnp.ndarray:
    """(Q, N) bool dirty-row mask for a batch of inserted edges: rows
    x = src (base term) plus rows with a finite entry reaching an inserted
    edge's source in any DFA state. Computed on the RAW f32 timestamps
    (finite = ``> -inf``), which is exact for the float backends and a
    conservative superset for clock-anchored representations (an ancient
    finite timestamp encodes to the bucket zero; relaxing its row is then a
    no-op, never an error)."""
    q, n, _, k = dist.shape
    idx = jnp.where(smask, src, n)     # out-of-range -> dropped
    src_mask = jnp.zeros((n,), bool).at[idx].set(True, mode="drop")
    reach = jnp.any(
        jnp.logical_and(dist > NEG_INF, src_mask[None, None, :, None]),
        axis=(2, 3),
    )                                   # (Q, N) rows reaching a batch source
    dirty = jnp.logical_or(reach, src_mask[None, :])
    if query_mask is not None:
        dirty = jnp.logical_and(dirty, query_mask[:, None])
    return dirty


def frontier_seed_gathered(
    dist: jnp.ndarray,          # (Q, N, N, K) f32 timestamps (pre-encode)
    src: jnp.ndarray,           # (B,) int32 inserted-edge source slots
    smask: jnp.ndarray,         # (B,) bool batch padding mask
    query_mask: Optional[jnp.ndarray] = None,   # (Q,) bool live lanes
) -> jnp.ndarray:
    """:func:`frontier_seed` with the O(N²) scan replaced by a gather.

    The dense seed tests EVERY dist column against a scattered (N,) source
    mask — O(Q·N²·K) reads per event, the term that dominates once the
    relaxation itself is frontier-restricted. But the batch names its
    sources outright, so gathering the B columns ``dist[:, :, src, :]``
    and reducing over (B, K) reads O(Q·N·B·K) — the seed cost scales with
    the batch, not the graph. Duplicated sources in the batch are benign
    (``any`` folds them), masked slots are excluded explicitly, and the
    result is EXACTLY the dense seed's mask: both reduce the same set of
    columns. Used by the ELL layout (whose whole point is breaking the
    O(N²) wall); the dense layout keeps the scan so its dispatch shapes
    and telemetry stay byte-stable."""
    q, n, _, k = dist.shape
    cols = dist[:, :, jnp.where(smask, src, 0), :]       # (Q, N, B, K)
    reach = jnp.any(
        jnp.logical_and(cols > NEG_INF, smask[None, None, :, None]),
        axis=(2, 3),
    )                                   # (Q, N) rows reaching a batch source
    idx = jnp.where(smask, src, n)
    src_mask = jnp.zeros((n,), bool).at[idx].set(True, mode="drop")
    dirty = jnp.logical_or(reach, src_mask[None, :])
    if query_mask is not None:
        dirty = jnp.logical_and(dirty, query_mask[:, None])
    return dirty


def pack_frontier(
    dirty: jnp.ndarray, f_cap: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compact a (Q, N) dirty mask into per-lane row indices.

    Returns ``(rows, rowmask, counts)``: rows (Q, F) int32 (first
    ``min(count, F)`` slots hold the dirty row ids in ascending order,
    padding is 0 — harmless: padded slots are masked and a masked slot's
    contribution is the semiring zero), rowmask (Q, F) bool, counts (Q,)
    int32 of TRUE dirty rows (counts > F signals overflow; the overflowing
    rows are dropped here, which is why callers must take the dense
    fallback in that case)."""
    q, n = dirty.shape
    cnt = jnp.sum(dirty, axis=1).astype(jnp.int32)
    pos = jnp.cumsum(dirty, axis=1) - 1                  # (Q, N)
    pos = jnp.where(dirty, jnp.minimum(pos, f_cap), f_cap)
    rows = jnp.zeros((q, f_cap), jnp.int32).at[
        jnp.arange(q)[:, None], pos
    ].set(jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (q, n)),
          mode="drop")
    rowmask = jnp.arange(f_cap)[None, :] < jnp.minimum(cnt, f_cap)[:, None]
    return rows, rowmask, cnt


def frontier_relax_round(
    dist: jnp.ndarray,          # (Q, N, N, K) in the backend's representation
    adj: jnp.ndarray,           # (L, N, N) shared adjacency (same repr)
    btt: BatchedTransitionTable,
    backend: BackendLike,
    rows: jnp.ndarray,          # (Q, F) int32 frontier row indices
    rowmask: jnp.ndarray,       # (Q, F) bool valid-slot mask
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One relaxation round restricted to the frontier rows.

    Gathers the (Q, F, N, K) slab of dirty source rows, contracts it
    against the shared adjacency through the backend's ``contract_rows``
    hook (the same substrate the dense round uses — pallas/bucket kernels
    see a skinny (F, N) operand), applies the base term at the frontier
    rows, scatter-maxes the slab back, and reports which slots changed.
    Returns ``(dist', changed)`` with changed (Q, F) already intersected
    with ``rowmask`` — the next round's mask (a row whose round produced no
    update is at its fixpoint forever: it depends only on itself)."""
    backend = resolve_backend(backend)
    q = dist.shape[0]
    lane = jnp.arange(q)[:, None]
    slab = dist[lane, rows]                            # (Q, F, N, K)
    new_slab, changed = _frontier_slab_round(slab, adj, btt, backend,
                                             rows, rowmask)
    out = dist.at[lane, rows].max(new_slab)
    return out, changed


def _frontier_slab_round(
    slab: jnp.ndarray,          # (Q, F, N, K) gathered frontier rows
    adj: jnp.ndarray,           # (L, N, N) shared adjacency (same repr)
    btt: BatchedTransitionTable,
    backend: ContractionBackend,
    rows: jnp.ndarray,          # (Q, F) int32 frontier row indices
    rowmask: jnp.ndarray,       # (Q, F) bool valid-slot mask
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One frontier round on the gathered slab itself (no scatter back).

    The (max, min) recurrence couples a source row only to ITSELF and the
    shared adjacency, and the frontier only shrinks, so a round never
    needs to read a row outside the slab: keeping the whole round loop
    slab-local is bit-identical to re-gathering from ``dist`` each round
    (valid rows are unique per lane — ``pack_frontier`` packs a mask —
    and padded slots are masked to zero contribution). The dense-layout
    :func:`frontier_relax_round` wraps this with its per-round
    gather/scatter-max; the row-sparse layout gathers ONCE, loops here,
    and scatters once at the end of the dispatch."""
    q, f, n, k = slab.shape
    zero = jnp.asarray(backend.zero, slab.dtype)
    slab_s = slab[btt.qidx, :, :, btt.src]             # (J, F, N) [f, u]
    rows_j = rows[btt.qidx]                            # (J, F)
    if isinstance(adj, EllAdjacency):
        # gather-contract straight off the ELL rows: O(F·N·E) per
        # transition, and the base term densifies ONLY the F frontier rows
        # — nothing O(N²) is materialized on this path
        contrib = backend.contract_rows_ell(slab_s, adj, btt.lab)
        a_base = ell_rows_dense(adj, btt.lab, rows_j, backend.zero)
    else:
        a_l = adj[btt.lab]                             # (J, N, N) [u, v]
        contrib = backend.contract_rows(slab_s, a_l)   # (J, F, N) [f, v]
        # base term at the frontier rows: adj[l, x, v] for x = rows[q, f]
        a_base = jnp.take_along_axis(a_l, rows_j[:, :, None], axis=1)
    base_rows = jnp.logical_and(btt.start_mask, btt.active)
    contrib = jnp.where(base_rows[:, None, None],
                        jnp.maximum(contrib, a_base), contrib)
    # zero inactive transition rows and invalid/converged frontier slots
    act = jnp.logical_and(btt.active[:, None], rowmask[btt.qidx])  # (J, F)
    contrib = jnp.where(act[:, :, None], contrib, zero)
    seg = btt.qidx * k + btt.dst
    scat = jax.ops.segment_max(contrib, seg, num_segments=q * k)  # (QK, F, N)
    upd = jnp.transpose(scat.reshape(q, k, f, n), (0, 2, 3, 1))   # (Q, F, N, K)
    new_slab = jnp.maximum(slab, upd)
    changed = jnp.logical_and(
        jnp.any(new_slab > slab, axis=(2, 3)), rowmask)
    return new_slab, changed


def frontier_closure(
    dist: jnp.ndarray,
    adj: jnp.ndarray,
    btt: BatchedTransitionTable,
    backend: BackendLike,
    src: jnp.ndarray,           # (B,) int32 inserted-edge source slots
    smask: jnp.ndarray,         # (B,) bool batch padding mask
    f_cap: int,                 # trace-time frontier capacity (bucketed ×2)
    query_mask: Optional[jnp.ndarray] = None,
    max_rounds: int = 0,
    now: Optional[jnp.ndarray] = None,
    w_max: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, FrontierStats]:
    """Frontier-restricted closure with in-dispatch dense fallback.

    Seeds the frontier from the batch itself (see :func:`frontier_seed`),
    iterates frontier rounds until every row settles, and — when any
    lane's dirty set overflows ``f_cap`` — runs the exact dense masked
    loop instead (``lax.cond``: both branches are traced, the choice is a
    runtime bit, so there is no recompile storm on overflow). Results are
    bit-identical to :func:`batched_closure` either way.

    Returns ``(dist, rounds, query_rounds, stats)``. ``query_rounds``
    counts rounds a lane had a non-empty frontier — a live lane the batch
    never dirtied counts ZERO rounds here (the dense loop charges every
    live lane its round-1 no-op), which is exactly the per-event work
    decoupling the frontier buys."""
    if isinstance(dist, RowSparseDist):
        return _rowsparse_frontier_closure(
            dist, adj, btt, backend, src, smask, f_cap,
            query_mask=query_mask, max_rounds=max_rounds,
            now=now, w_max=w_max)
    backend = resolve_backend(backend)
    q, n, _, k = dist.shape
    bound = max_rounds if max_rounds > 0 else n * k + 1
    mask0 = (jnp.ones((q,), bool) if query_mask is None
             else jnp.asarray(query_mask, bool))
    # ELL dispatches seed via the batch-column gather (O(Q·N·B·K), the
    # representation's headline win); dense keeps the scan — same mask
    # either way (frontier_seed_gathered docstring), so results and the
    # overflow decision are layout-independent
    seed_fn = (frontier_seed_gathered if isinstance(adj, EllAdjacency)
               else frontier_seed)
    dirty = seed_fn(dist, src, smask, mask0)
    rows, rowmask0, cnt = pack_frontier(dirty, f_cap)
    seed_rows = jnp.sum(cnt)
    max_lane_rows = jnp.max(cnt)
    overflow = jnp.any(cnt > f_cap)
    dist_op, adj_op = backend.prepare_state(dist, adj, now, w_max)

    def dense_branch(_):
        d_f, rounds, qrounds = _masked_closure_loop(
            dist_op, adj_op, btt, backend, mask0, bound)
        live_rows = jnp.sum(mask0.astype(jnp.int32)) * n
        return d_f, rounds, qrounds, rounds * live_rows

    def frontier_branch(_):
        def cond(carry):
            _d, rm, it, _qr, _rr = carry
            return jnp.logical_and(jnp.any(rm), it < bound)

        def body(carry):
            d, rm, it, qr, rr = carry
            nd, changed = frontier_relax_round(d, adj_op, btt, backend,
                                               rows, rm)
            qactive = jnp.any(rm, axis=1).astype(jnp.int32)
            return (nd, changed, it + 1, qr + qactive,
                    rr + jnp.sum(rm.astype(jnp.int32)))

        d_f, _, rounds, qrounds, rr = jax.lax.while_loop(
            cond, body,
            (dist_op, rowmask0, jnp.asarray(0, jnp.int32),
             jnp.zeros((q,), jnp.int32), jnp.asarray(0, jnp.int32)))
        return d_f, rounds, qrounds, rr

    dist_f, rounds, qrounds, rows_relaxed = jax.lax.cond(
        overflow, dense_branch, frontier_branch, None)
    stats = FrontierStats(seed_rows, max_lane_rows, rows_relaxed, overflow)
    return backend.decode_state(dist_f, now, w_max), rounds, qrounds, stats


# ---------------------------------------------------------------------------
# Frontier-restricted DELETION (PR 6 tentpole)
#
# A deleted edge (u, v, l) can only invalidate derivations whose path passes
# through it — and every such path factors as x →* u → v →* ·, where the
# x →* u prefix is recorded at the PRE-delete fixpoint as a finite
# dist[q, x, u, s] entry (the length-0 prefix x = u is the base-term case).
# So the set of rows whose value can change is EXACTLY the reachability test
# `frontier_seed` already runs for inserts, evaluated against the pre-delete
# state: the deleted edge's *cone*. Rows outside the cone keep their
# pre-delete values, which remain exact fixpoints of the retained adjacency
# (their contraction term at u' = u reads dist[x, u, s] = -inf and the base
# term requires x = u — both excluded by cone membership), while cone rows
# are cleared to the semiring zero and re-derived from scratch over the
# retained adjacency: round 1 re-applies their base terms (`a_base` in
# `frontier_relax_round`), later rounds propagate, and monotone convergence
# lands each row on the least fixpoint — the same value a dense
# from-scratch re-closure computes, so the overflow fallback (which IS the
# dense from-scratch loop) is bit-identical by construction.
#
# One caveat on RAW-array identity: rows outside the cone keep their stored
# values VERBATIM, including window-dead entries whose supporting edges have
# already been expired out of the adjacency (expiry is lazy and never
# touches dist). A dense from-scratch delete garbage-collects those as a
# side effect. The two states agree on every entry above the window
# threshold — an entry > now - w has its best witnessing path fully
# retained (expiry only evicts edges <= the monotone threshold), so the
# stored value equals the retained adjacency's least fixpoint there, and a
# dead entry can never resurface (bottlenecks only age, the threshold only
# rises). Emitted results, invalidation sets, and every thresholded read
# are therefore identical; only the unobservable dead entries may differ.
# ---------------------------------------------------------------------------


def delete_cone(
    dist: jnp.ndarray,          # (Q, N, N, K) PRE-delete f32 timestamps
    src: jnp.ndarray,           # (B,) int32 deleted-edge source slots
    smask: jnp.ndarray,         # (B,) bool batch padding mask
    query_mask: Optional[jnp.ndarray] = None,   # (Q,) bool live lanes
) -> jnp.ndarray:
    """(Q, N) bool invalidation cone of a batch of deleted edges: rows x
    whose pre-delete ``dist[q, x, :, :]`` has a finite entry reaching a
    deleted edge's source u in any DFA state, plus the rows x = u
    themselves (base-term derivations). This is the same reduction as
    :func:`frontier_seed` — for inserts it bounds where new derivations can
    APPEAR, for deletes (run against the pre-delete state) it bounds where
    existing derivations can have PASSED THROUGH the dropped edge — so the
    two paths share one implementation and one cost: O(Q·N²·K)
    elementwise."""
    return frontier_seed(dist, src, smask, query_mask)


def frontier_delete(
    dist: jnp.ndarray,          # (Q, N, N, K) PRE-delete state
    adj: jnp.ndarray,           # (L, N, N) RETAINED adjacency (edge dropped)
    btt: BatchedTransitionTable,
    backend: BackendLike,
    src: jnp.ndarray,           # (B,) int32 deleted-edge source slots
    smask: jnp.ndarray,         # (B,) bool batch padding mask
    f_cap: int,
    query_mask: Optional[jnp.ndarray] = None,
    max_rounds: int = 0,
    now: Optional[jnp.ndarray] = None,
    w_max: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, FrontierStats]:
    """Cone-seeded incremental re-derivation after a batch of deletions.

    Computes the deleted edges' cone on the pre-delete ``dist``, clears
    exactly those rows to the semiring zero, and re-derives them with the
    same frontier round loop ingest uses — rows outside the cone are
    untouched (they are already at the retained adjacency's fixpoint on
    every window-valid entry; see the section comment for the argument and
    for the one place raw arrays may differ — window-dead entries in clean
    rows). On cone overflow the dispatch falls back IN-DISPATCH to the
    dense from-scratch re-closure (all rows cleared), which is the exact
    computation the non-frontier delete path runs — observable results are
    identical either way.

    Returns ``(dist, rounds, query_rounds, stats)`` with the same contract
    as :func:`frontier_closure`."""
    if isinstance(dist, RowSparseDist):
        return _rowsparse_frontier_delete(
            dist, adj, btt, backend, src, smask, f_cap,
            query_mask=query_mask, max_rounds=max_rounds,
            now=now, w_max=w_max)
    backend = resolve_backend(backend)
    q, n, _, k = dist.shape
    bound = max_rounds if max_rounds > 0 else n * k + 1
    mask0 = (jnp.ones((q,), bool) if query_mask is None
             else jnp.asarray(query_mask, bool))
    # same layout split as frontier_closure: the cone IS the seed reduction
    cone_fn = (frontier_seed_gathered if isinstance(adj, EllAdjacency)
               else delete_cone)
    dirty = cone_fn(dist, src, smask, mask0)
    rows, rowmask0, cnt = pack_frontier(dirty, f_cap)
    seed_rows = jnp.sum(cnt)
    max_lane_rows = jnp.max(cnt)
    overflow = jnp.any(cnt > f_cap)
    cleared = jnp.where(dirty[:, :, None, None], NEG_INF, dist)
    dist_op, adj_op = backend.prepare_state(cleared, adj, now, w_max)

    def dense_branch(_):
        # from-scratch over ALL rows — exactly what the non-frontier delete
        # dispatch runs, so a fallback stays bit-identical to frontier="off"
        d0 = backend.encode(jnp.full_like(dist, NEG_INF), now, w_max)
        d_f, rounds, qrounds = _masked_closure_loop(
            d0, adj_op, btt, backend, mask0, bound)
        live_rows = jnp.sum(mask0.astype(jnp.int32)) * n
        return d_f, rounds, qrounds, rounds * live_rows

    def frontier_branch(_):
        def cond(carry):
            _d, rm, it, _qr, _rr = carry
            return jnp.logical_and(jnp.any(rm), it < bound)

        def body(carry):
            d, rm, it, qr, rr = carry
            nd, changed = frontier_relax_round(d, adj_op, btt, backend,
                                               rows, rm)
            qactive = jnp.any(rm, axis=1).astype(jnp.int32)
            return (nd, changed, it + 1, qr + qactive,
                    rr + jnp.sum(rm.astype(jnp.int32)))

        d_f, _, rounds, qrounds, rr = jax.lax.while_loop(
            cond, body,
            (dist_op, rowmask0, jnp.asarray(0, jnp.int32),
             jnp.zeros((q,), jnp.int32), jnp.asarray(0, jnp.int32)))
        return d_f, rounds, qrounds, rr

    dist_f, rounds, qrounds, rows_relaxed = jax.lax.cond(
        overflow, dense_branch, frontier_branch, None)
    stats = FrontierStats(seed_rows, max_lane_rows, rows_relaxed, overflow)
    return backend.decode_state(dist_f, now, w_max), rounds, qrounds, stats


# ---------------------------------------------------------------------------
# Row-sparse dist frontier paths (PR 9 tentpole)
#
# Same closure/delete contracts as the dense-layout functions above, with
# the (Q, N, N, K) slab replaced by a RowSparseDist. The single-source row
# independence that justifies the frontier in the first place also means a
# whole DISPATCH only ever reads and writes the frontier rows — so instead
# of gathering and scattering per round, the row-sparse path densifies the
# frontier rows ONCE (the backend's gather_dist_rows kernel), runs every
# round slab-local (`_frontier_slab_round`), and scatters the finished rows
# back into the per-row sets once at the end. Backend encode/decode wraps
# the slab at the same boundary the dense path wraps the full state, so
# clock-anchored representations never leak into the stored sparse state.
#
# Overflow keeps the dense lax.cond fallback, upgraded to a round trip:
# densify -> exact dense loop -> in-jit re-pack (rsd_from_dense). Rows that
# outgrow dist_cap during the re-pack or the scatter land in the bounded
# overflow table; the executor's host-side budget drains and grows the
# capacity before the table can fill (docs/invariants.md, "the row-sparse
# overflow contract"). Results are bit-identical to the dense layout for
# the float backends; for the bucket backend identity is OBSERVABLE (same
# emitted streams) rather than raw — untouched sparse rows keep
# window-dead entries a dense round trip would garbage-collect, the same
# caveat the PR 6 delete section documents above.
# ---------------------------------------------------------------------------


def _rowsparse_frontier_closure(
    sd: RowSparseDist,
    adj,
    btt: BatchedTransitionTable,
    backend: BackendLike,
    src: jnp.ndarray,
    smask: jnp.ndarray,
    f_cap: int,
    query_mask: Optional[jnp.ndarray] = None,
    max_rounds: int = 0,
    now: Optional[jnp.ndarray] = None,
    w_max: Optional[jnp.ndarray] = None,
) -> Tuple[RowSparseDist, jnp.ndarray, jnp.ndarray, FrontierStats]:
    """:func:`frontier_closure` on a :class:`RowSparseDist` (see the
    section comment): gather-once / slab-local rounds / scatter-once,
    with the overflow fallback as a densify round trip."""
    backend = resolve_backend(backend)
    q, n, _c = sd.idx.shape
    k = sd.k
    bound = max_rounds if max_rounds > 0 else n * k + 1
    mask0 = (jnp.ones((q,), bool) if query_mask is None
             else jnp.asarray(query_mask, bool))
    # the seed walks stored entries only — same mask as the dense scan on
    # the densified state (rsd_seed_gathered docstring), so the overflow
    # decision and telemetry are layout-independent
    dirty = rsd_seed_gathered(sd, src, smask, mask0)
    rows, rowmask0, cnt = pack_frontier(dirty, f_cap)
    seed_rows = jnp.sum(cnt)
    max_lane_rows = jnp.max(cnt)
    overflow = jnp.any(cnt > f_cap)
    # encode the adjacency operand once, shared by both branches (the
    # dist operand of prepare_state is a dummy scalar: the branches
    # encode their own slab/state at their own boundary)
    _, adj_op = backend.prepare_state(
        jnp.asarray(NEG_INF, jnp.float32), adj, now, w_max)

    def dense_branch(_):
        d_op = backend.encode(rsd_to_dense(sd), now, w_max)
        d_f, rounds, qrounds = _masked_closure_loop(
            d_op, adj_op, btt, backend, mask0, bound)
        dense_f = backend.decode_state(d_f, now, w_max)
        out = rsd_from_dense(dense_f, sd.dist_cap, sd.ovf_cap, sd.lost)
        live_rows = jnp.sum(mask0.astype(jnp.int32)) * n
        return out, rounds, qrounds, rounds * live_rows

    def frontier_branch(_):
        slab0 = rsd_gather_rows(sd, rows, backend.gather_dist_rows)
        slab_op = backend.encode(slab0, now, w_max)

        def cond(carry):
            _s, rm, it, _qr, _rr = carry
            return jnp.logical_and(jnp.any(rm), it < bound)

        def body(carry):
            s, rm, it, qr, rr = carry
            ns, changed = _frontier_slab_round(s, adj_op, btt, backend,
                                               rows, rm)
            qactive = jnp.any(rm, axis=1).astype(jnp.int32)
            return (ns, changed, it + 1, qr + qactive,
                    rr + jnp.sum(rm.astype(jnp.int32)))

        s_f, _, rounds, qrounds, rr = jax.lax.while_loop(
            cond, body,
            (slab_op, rowmask0, jnp.asarray(0, jnp.int32),
             jnp.zeros((q,), jnp.int32), jnp.asarray(0, jnp.int32)))
        slab_f = backend.decode_state(s_f, now, w_max)
        out = rsd_scatter_rows(sd, rows, rowmask0, slab_f)
        return out, rounds, qrounds, rr

    out, rounds, qrounds, rows_relaxed = jax.lax.cond(
        overflow, dense_branch, frontier_branch, None)
    stats = FrontierStats(seed_rows, max_lane_rows, rows_relaxed, overflow)
    return out, rounds, qrounds, stats


def _rowsparse_frontier_delete(
    sd: RowSparseDist,
    adj,
    btt: BatchedTransitionTable,
    backend: BackendLike,
    src: jnp.ndarray,
    smask: jnp.ndarray,
    f_cap: int,
    query_mask: Optional[jnp.ndarray] = None,
    max_rounds: int = 0,
    now: Optional[jnp.ndarray] = None,
    w_max: Optional[jnp.ndarray] = None,
) -> Tuple[RowSparseDist, jnp.ndarray, jnp.ndarray, FrontierStats]:
    """:func:`frontier_delete` on a :class:`RowSparseDist`: the cone is
    seeded from the stored entries of the PRE-delete state, cone rows
    re-derive from a zeroed slab (clearing + re-deriving in one scatter:
    the final scatter's full-row overwrite IS the clear — exact even for
    rows that shrink), non-cone rows are never touched."""
    backend = resolve_backend(backend)
    q, n, _c = sd.idx.shape
    k = sd.k
    bound = max_rounds if max_rounds > 0 else n * k + 1
    mask0 = (jnp.ones((q,), bool) if query_mask is None
             else jnp.asarray(query_mask, bool))
    dirty = rsd_seed_gathered(sd, src, smask, mask0)
    rows, rowmask0, cnt = pack_frontier(dirty, f_cap)
    seed_rows = jnp.sum(cnt)
    max_lane_rows = jnp.max(cnt)
    overflow = jnp.any(cnt > f_cap)
    _, adj_op = backend.prepare_state(
        jnp.asarray(NEG_INF, jnp.float32), adj, now, w_max)

    def dense_branch(_):
        # from-scratch over ALL rows — exactly the non-frontier delete
        # computation, re-packed in-jit on the way out
        d0 = backend.encode(
            jnp.full((q, n, n, k), NEG_INF, jnp.float32), now, w_max)
        d_f, rounds, qrounds = _masked_closure_loop(
            d0, adj_op, btt, backend, mask0, bound)
        dense_f = backend.decode_state(d_f, now, w_max)
        out = rsd_from_dense(dense_f, sd.dist_cap, sd.ovf_cap, sd.lost)
        live_rows = jnp.sum(mask0.astype(jnp.int32)) * n
        return out, rounds, qrounds, rounds * live_rows

    def frontier_branch(_):
        # cone rows start at the semiring zero (re-derivation from
        # scratch); rounds only read slab rows, so no gather is needed
        slab0 = backend.encode(
            jnp.full((q, f_cap, n, k), NEG_INF, jnp.float32), now, w_max)

        def cond(carry):
            _s, rm, it, _qr, _rr = carry
            return jnp.logical_and(jnp.any(rm), it < bound)

        def body(carry):
            s, rm, it, qr, rr = carry
            ns, changed = _frontier_slab_round(s, adj_op, btt, backend,
                                               rows, rm)
            qactive = jnp.any(rm, axis=1).astype(jnp.int32)
            return (ns, changed, it + 1, qr + qactive,
                    rr + jnp.sum(rm.astype(jnp.int32)))

        s_f, _, rounds, qrounds, rr = jax.lax.while_loop(
            cond, body,
            (slab0, rowmask0, jnp.asarray(0, jnp.int32),
             jnp.zeros((q,), jnp.int32), jnp.asarray(0, jnp.int32)))
        slab_f = backend.decode_state(s_f, now, w_max)
        out = rsd_scatter_rows(sd, rows, rowmask0, slab_f)
        return out, rounds, qrounds, rr

    out, rounds, qrounds, rows_relaxed = jax.lax.cond(
        overflow, dense_branch, frontier_branch, None)
    stats = FrontierStats(seed_rows, max_lane_rows, rows_relaxed, overflow)
    return out, rounds, qrounds, stats


# ---------------------------------------------------------------------------
# Sharded (shard_map-local) round variants
#
# The mesh executor (distributed/executor.py) shards the Q lane axis over
# the mesh's data axis and (optionally) the vertex axis over model. Inside
# a shard_map block each shard sees dist (Q_l, N, N_m, K) plus ONLY its own
# queries' transition rows, relaxes them to ITS OWN fixpoint, and skips the
# contraction entirely once its lanes have all converged — the realized form
# of the per-query convergence masking that the dense single-device round
# could only account for (batched_relax_round docstring). The row layout is
# built host-side by `shard_transitions`.
# ---------------------------------------------------------------------------


def shard_transitions(
    btt: BatchedTransitionTable, q_cap: int, n_shards: int, j_bucket: int = 8
) -> Tuple[jnp.ndarray, ...]:
    """Regroup a flattened transition table by lane shard.

    Lanes are block-partitioned: shard i owns lanes [i*q_cap/n_shards,
    (i+1)*q_cap/n_shards). Returns six (n_shards, J_s) arrays — qidx
    (SHARD-LOCAL lane index), src, lab, dst, start_mask, active — with J_s
    the bucketed max row count over shards (padding rows inert). ``q_cap``
    must be a multiple of ``n_shards`` (the engine rounds lane capacity to
    the executor's ``q_multiple``).
    """
    if q_cap % n_shards:
        raise ValueError(f"q_cap {q_cap} not divisible by {n_shards} shards")
    q_shard = q_cap // n_shards
    qidx = np.asarray(btt.qidx)
    active = np.asarray(btt.active)
    src = np.asarray(btt.src)
    lab = np.asarray(btt.lab)
    dst = np.asarray(btt.dst)
    start = np.asarray(btt.start_mask)
    rows: List[List[int]] = [[] for _ in range(n_shards)]
    for j in np.nonzero(active)[0].tolist():
        rows[int(qidx[j]) // q_shard].append(j)
    j_max = max([len(r) for r in rows] + [1])
    j_s = max(j_max + (-j_max) % j_bucket, j_bucket)
    out = {
        "qidx": np.zeros((n_shards, j_s), np.int32),
        "src": np.zeros((n_shards, j_s), np.int32),
        "lab": np.zeros((n_shards, j_s), np.int32),
        "dst": np.zeros((n_shards, j_s), np.int32),
        "start": np.zeros((n_shards, j_s), bool),
        "active": np.zeros((n_shards, j_s), bool),
    }
    for sh, row_ids in enumerate(rows):
        for jj, j in enumerate(row_ids):
            out["qidx"][sh, jj] = qidx[j] - sh * q_shard
            out["src"][sh, jj] = src[j]
            out["lab"][sh, jj] = lab[j]
            out["dst"][sh, jj] = dst[j]
            out["start"][sh, jj] = start[j]
            out["active"][sh, jj] = True
    return (jnp.asarray(out["qidx"]), jnp.asarray(out["src"]),
            jnp.asarray(out["lab"]), jnp.asarray(out["dst"]),
            jnp.asarray(out["start"]), jnp.asarray(out["active"]))


def shard_relax_round(
    dist_blk: jnp.ndarray,     # (Q_l, N, N_m, K) shard-local lane block
    adj_u: jnp.ndarray,        # (L, N_m, N) adjacency, u rows local
    adj_v: jnp.ndarray,        # (L, N, N_m) adjacency, v cols local
    qidx: jnp.ndarray,         # (J_s,) SHARD-LOCAL owning lane
    src: jnp.ndarray,          # (J_s,)
    lab: jnp.ndarray,          # (J_s,)
    dst: jnp.ndarray,          # (J_s,)
    start_mask: jnp.ndarray,   # (J_s,)
    active: jnp.ndarray,       # (J_s,)
    query_mask: jnp.ndarray,   # (Q_l,) bool, True = relax
    backend: BackendLike = "jnp",
    model_axis: Optional[str] = None,
    model_size: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One relaxation round on one lane shard (shard_map-local).

    The u-contraction runs over the shard's LOCAL u-block; when the vertex
    axis is sharded (``model_size > 1``) the per-block partials are
    max-combined across ``model_axis`` (exact: max is associative) and the
    shard keeps its v-column block. Returns ``(new_dist_blk, changed)``
    with ``changed`` (Q_l,) synchronized across the model axis so every
    peer of a lane shard agrees on convergence (uniform loop trip counts —
    the condition that makes collectives inside the closure loop safe).

    Masking semantics mirror :func:`batched_relax_round` exactly: masked
    lanes contribute the semiring zero and pass through untouched.
    Operands are in the backend's representation (:func:`shard_closure`
    converts at the dispatch boundary).
    """
    backend = resolve_backend(backend)
    q_l, n, n_m, k = dist_blk.shape
    act = jnp.logical_and(active, query_mask[qidx])
    d_s = dist_blk[qidx, :, :, src]               # (J, N, N_m) [x, u_local]
    a_u = adj_u[lab]                              # (J, N_m, N) [u_local, v]
    part = backend.contract_rows(d_s, a_u)        # (J, N, N)   [x, v] partial
    if model_axis is not None and model_size > 1:
        part = jax.lax.pmax(part, model_axis)
        vstart = jax.lax.axis_index(model_axis) * n_m
        contrib = jax.lax.dynamic_slice(
            part, (0, 0, vstart), (part.shape[0], n, n_m))
    else:
        contrib = part
    # base term: seed (x, x, s0) = +inf => min(+inf, adj[l, x, v]) = adj
    a_v = adj_v[lab]                              # (J, N, N_m)
    contrib = jnp.where(start_mask[:, None, None],
                        jnp.maximum(contrib, a_v), contrib)
    contrib = jnp.where(act[:, None, None], contrib,
                        jnp.asarray(backend.zero, contrib.dtype))
    seg = qidx * k + dst
    scat = jax.ops.segment_max(contrib, seg, num_segments=q_l * k)
    upd = jnp.transpose(scat.reshape(q_l, k, n, n_m), (0, 2, 3, 1))
    nd = jnp.maximum(dist_blk, upd)
    nd = jnp.where(query_mask[:, None, None, None], nd, dist_blk)
    changed = jnp.any(nd > dist_blk, axis=(1, 2, 3))
    if model_axis is not None and model_size > 1:
        changed = jax.lax.pmax(changed.astype(jnp.int32), model_axis) > 0
    return nd, changed


def shard_closure(
    dist_blk: jnp.ndarray,
    adj_u: jnp.ndarray,
    adj_v: jnp.ndarray,
    rows: Tuple[jnp.ndarray, ...],   # six (J_s,) arrays (shard_transitions)
    query_mask: jnp.ndarray,         # (Q_l,) bool initial mask
    backend: BackendLike = "jnp",
    model_axis: Optional[str] = None,
    model_size: int = 1,
    max_rounds: int = 0,
    now: Optional[jnp.ndarray] = None,    # () stream clock (replicated)
    w_max: Optional[jnp.ndarray] = None,  # () group's largest window
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Shard-local closure with convergence-aware dispatch.

    A shard whose lanes are all masked (converged or inert padding) SKIPS
    the closure entirely (`lax.cond`) — zero contraction work, the win the
    single-device masked round could only account for. Otherwise the shard
    iterates to its OWN fixpoint: its loop ends when its slowest lane
    settles, independent of other shards (no cross-shard data flow — a
    transition only reads its owning lane's slices and the adjacency, which
    is constant during the closure).

    Returns ``(dist_blk, rounds, query_rounds)``: ``rounds`` () int32 is
    the rounds THIS shard actually relaxed (0 when skipped — the per-shard
    skip/finish-early signal the mesh executor aggregates into its
    masked-skip counters), ``query_rounds`` (Q_l,) matches the local
    engine's per-lane accounting.

    The backend's representation boundary sits INSIDE the run branch:
    operands are encoded once per dispatch, the loop runs on them, and the
    result decodes back to f32 timestamps. The skip branch returns the
    raw block untouched (zero work, exact passthrough). Encoding is
    elementwise and ``now`` is replicated, so the per-shard conversion is
    collective-free.
    """
    backend = resolve_backend(backend)
    qidx, src, lab, dst, start, active = rows
    q_l, n, _n_m, k = dist_blk.shape
    bound = max_rounds if max_rounds > 0 else n * k + 1

    def run(_):
        d_op = backend.encode(dist_blk, now, w_max)
        au_op = backend.encode(adj_u, now, w_max)
        av_op = backend.encode(adj_v, now, w_max)
        d_f, it_f, qr_f = _shard_dense_loop(
            d_op, au_op, av_op, rows, query_mask, backend,
            model_axis, model_size, bound)
        return backend.decode_state(d_f, now, w_max), it_f, qr_f

    def skip(_):
        return (dist_blk, jnp.asarray(0, jnp.int32),
                jnp.zeros((q_l,), jnp.int32))

    # uniform across the model peers of this lane shard (query_mask is
    # replicated along model), so collectives inside `run` stay safe
    return jax.lax.cond(jnp.any(query_mask), run, skip, None)


def _shard_dense_loop(
    d_op: jnp.ndarray,
    au_op: jnp.ndarray,
    av_op: jnp.ndarray,
    rows: Tuple[jnp.ndarray, ...],
    query_mask: jnp.ndarray,
    backend: ContractionBackend,
    model_axis: Optional[str],
    model_size: int,
    bound: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The shard-local masked fixpoint loop on encoded operands (shared by
    :func:`shard_closure` and the frontier path's overflow fallback)."""
    qidx, src, lab, dst, start, active = rows

    def one_round(d, mask):
        return shard_relax_round(
            d, au_op, av_op, qidx, src, lab, dst, start, active, mask,
            backend=backend, model_axis=model_axis, model_size=model_size)

    d0, ch0 = one_round(d_op, query_mask)
    m0 = jnp.logical_and(query_mask, ch0)
    qr0 = query_mask.astype(jnp.int32)
    it0 = jnp.asarray(1, jnp.int32)

    def cond(carry):
        return carry[4]

    def body(carry):
        d, mask, it, qr, _keep = carry
        nd, ch = one_round(d, mask)
        nmask = jnp.logical_and(mask, ch)
        it = it + 1
        keep = jnp.logical_and(jnp.any(nmask), it < bound)
        return nd, nmask, it, qr + mask.astype(jnp.int32), keep

    keep0 = jnp.logical_and(jnp.any(m0), it0 < bound)
    d_f, _, it_f, qr_f, _ = jax.lax.while_loop(
        cond, body, (d0, m0, it0, qr0, keep0))
    return d_f, it_f, qr_f


def _shard_frontier_round(
    d_op: jnp.ndarray,         # (Q_l, N, N_m, K) encoded lane block
    au_op: jnp.ndarray,        # (L, N_m, N) encoded adjacency, u rows local
    av_op: jnp.ndarray,        # (L, N, N_m) encoded adjacency, v cols local
    rows: Tuple[jnp.ndarray, ...],
    frows: jnp.ndarray,        # (Q_l, F) frontier row indices (replicated
                               # across the model peers of this lane shard)
    rowmask: jnp.ndarray,      # (Q_l, F) valid-slot mask (replicated)
    backend: ContractionBackend,
    model_axis: Optional[str],
    model_size: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One frontier-restricted round on one lane shard: the shard-local
    form of :func:`frontier_relax_round` — the (Q_l, F, N_m, K) slab
    contracts over the LOCAL u block, partials max-combine across the
    model axis (exact), and ``changed`` is synchronized across model peers
    so the frontier mask stays uniform (the condition that keeps the
    collectives inside the closure loop safe)."""
    qidx, src, lab, dst, start, active = rows
    q_l, n, n_m, k = d_op.shape
    f = frows.shape[1]
    zero = jnp.asarray(backend.zero, d_op.dtype)
    lane = jnp.arange(q_l)[:, None]
    slab = d_op[lane, frows]                           # (Q_l, F, N_m, K)
    slab_s = slab[qidx, :, :, src]                     # (J, F, N_m) [f, u_l]
    a_u = au_op[lab]                                   # (J, N_m, N)
    part = backend.contract_rows(slab_s, a_u)          # (J, F, N) partial
    if model_axis is not None and model_size > 1:
        part = jax.lax.pmax(part, model_axis)
        vstart = jax.lax.axis_index(model_axis) * n_m
        contrib = jax.lax.dynamic_slice(
            part, (0, 0, vstart), (part.shape[0], f, n_m))
    else:
        contrib = part
    # base term at the frontier rows (the x axis of a_v is the FULL N)
    a_v = av_op[lab]                                   # (J, N, N_m)
    rows_j = frows[qidx]                               # (J, F)
    a_base = jnp.take_along_axis(a_v, rows_j[:, :, None], axis=1)
    base_rows = jnp.logical_and(start, active)
    contrib = jnp.where(base_rows[:, None, None],
                        jnp.maximum(contrib, a_base), contrib)
    act = jnp.logical_and(active[:, None], rowmask[qidx])
    contrib = jnp.where(act[:, :, None], contrib, zero)
    seg = qidx * k + dst
    scat = jax.ops.segment_max(contrib, seg, num_segments=q_l * k)
    upd = jnp.transpose(scat.reshape(q_l, k, f, n_m), (0, 2, 3, 1))
    new_slab = jnp.maximum(slab, upd)
    changed = jnp.logical_and(
        jnp.any(new_slab > slab, axis=(2, 3)), rowmask)
    if model_axis is not None and model_size > 1:
        changed = jax.lax.pmax(changed.astype(jnp.int32), model_axis) > 0
    return d_op.at[lane, frows].max(new_slab), changed


def _shard_dirty_rows(
    dist_blk: jnp.ndarray,     # (Q_l, N, N_m, K) raw f32 lane block
    src: jnp.ndarray,          # (B,) int32 batch source slots (replicated)
    smask: jnp.ndarray,        # (B,) bool batch padding mask
    query_mask: jnp.ndarray,   # (Q_l,) bool live lanes (replicated)
    model_axis: Optional[str],
    model_size: int,
) -> jnp.ndarray:
    """(Q_l, N) dirty-row mask of a batch on one lane shard: the shard-map
    form of :func:`frontier_seed` / :func:`delete_cone`. The reachability
    reduction runs over the shard's LOCAL u block (the batch sources that
    land in it), partial reach max-combines across the model peers of the
    lane shard (one pmax — the result is then uniform across peers, which
    keeps the skip/run and fallback decisions collective-safe), and the
    global base-term rows x = src fold in from the replicated batch.
    Computed on the RAW timestamp block (conservative superset for
    clock-anchored representations, exact for the float backends)."""
    _q_l, n, n_m, _k = dist_blk.shape
    if model_axis is not None and model_size > 1:
        u_start = jax.lax.axis_index(model_axis) * n_m
    else:
        u_start = 0
    lidx = src - u_start
    lidx = jnp.where(
        jnp.logical_and(smask,
                        jnp.logical_and(lidx >= 0, lidx < n_m)), lidx, n_m)
    src_local = jnp.zeros((n_m,), bool).at[lidx].set(True, mode="drop")
    reach = jnp.any(
        jnp.logical_and(dist_blk > NEG_INF,
                        src_local[None, None, :, None]), axis=(2, 3))
    if model_axis is not None and model_size > 1:
        reach = jax.lax.pmax(reach.astype(jnp.int32), model_axis) > 0
    gidx = jnp.where(smask, src, n)
    src_global = jnp.zeros((n,), bool).at[gidx].set(True, mode="drop")
    return jnp.logical_and(jnp.logical_or(reach, src_global[None, :]),
                           query_mask[:, None])


def shard_frontier_closure(
    dist_blk: jnp.ndarray,
    adj_u: jnp.ndarray,
    adj_v: jnp.ndarray,
    rows: Tuple[jnp.ndarray, ...],
    query_mask: jnp.ndarray,
    src: jnp.ndarray,            # (B,) int32 batch source slots (replicated)
    smask: jnp.ndarray,          # (B,) bool batch padding mask
    f_cap: int,
    backend: BackendLike = "jnp",
    model_axis: Optional[str] = None,
    model_size: int = 1,
    max_rounds: int = 0,
    now: Optional[jnp.ndarray] = None,
    w_max: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, ...]:
    """Shard-local frontier closure: the ingest form of
    :func:`shard_closure` with the frontier gather composed into the
    per-shard skip — a shard SKIPS the closure entirely when its lanes are
    all converged/inert OR the batch dirtied none of its rows (the dirty
    reduction runs over the shard's local u block, max-combined across the
    model peers, so the decision is uniform and collective-free beyond one
    pmax). An overflowing shard falls back to ITS OWN dense loop
    (lax.cond): other shards keep their frontier rounds.

    Returns ``(dist_blk, rounds, query_rounds, rows_relaxed, fell_back,
    seed_rows, max_lane_rows)`` — the last four are this shard's
    :class:`FrontierStats` terms, aggregated host-side by the executor."""
    backend = resolve_backend(backend)
    q_l, n, n_m, k = dist_blk.shape
    bound = max_rounds if max_rounds > 0 else n * k + 1
    dirty = _shard_dirty_rows(dist_blk, src, smask, query_mask,
                              model_axis, model_size)
    frows, rowmask0, cnt = pack_frontier(dirty, f_cap)
    seed_rows = jnp.sum(cnt)
    max_lane_rows = jnp.max(cnt)
    overflow = jnp.any(cnt > f_cap)

    def run(_):
        d_op = backend.encode(dist_blk, now, w_max)
        au_op = backend.encode(adj_u, now, w_max)
        av_op = backend.encode(adj_v, now, w_max)

        def dense(_):
            d_f, it, qr = _shard_dense_loop(
                d_op, au_op, av_op, rows, query_mask, backend,
                model_axis, model_size, bound)
            live_rows = jnp.sum(query_mask.astype(jnp.int32)) * n
            return d_f, it, qr, it * live_rows

        def frontier(_):
            def cond(carry):
                _d, rm, it, _qr, _rr = carry
                return jnp.logical_and(jnp.any(rm), it < bound)

            def body(carry):
                d, rm, it, qr, rr = carry
                nd, changed = _shard_frontier_round(
                    d, au_op, av_op, rows, frows, rm, backend,
                    model_axis, model_size)
                qactive = jnp.any(rm, axis=1).astype(jnp.int32)
                return (nd, changed, it + 1, qr + qactive,
                        rr + jnp.sum(rm.astype(jnp.int32)))

            d_f, _, it, qr, rr = jax.lax.while_loop(
                cond, body,
                (d_op, rowmask0, jnp.asarray(0, jnp.int32),
                 jnp.zeros((q_l,), jnp.int32), jnp.asarray(0, jnp.int32)))
            return d_f, it, qr, rr

        d_f, it, qr, rr = jax.lax.cond(overflow, dense, frontier, None)
        return backend.decode_state(d_f, now, w_max), it, qr, rr

    def skip(_):
        return (dist_blk, jnp.asarray(0, jnp.int32),
                jnp.zeros((q_l,), jnp.int32), jnp.asarray(0, jnp.int32))

    # any dirty row anywhere on this shard? (uniform across model peers:
    # `dirty` folds the pmax'd reach and the replicated masks)
    d, it, qr, rr = jax.lax.cond(jnp.any(cnt > 0), run, skip, None)
    return d, it, qr, rr, overflow, seed_rows, max_lane_rows


def shard_frontier_delete(
    dist_blk: jnp.ndarray,       # (Q_l, N, N_m, K) PRE-delete lane block
    adj_u: jnp.ndarray,          # (L, N_m, N) RETAINED adjacency, u local
    adj_v: jnp.ndarray,          # (L, N, N_m) RETAINED adjacency, v local
    rows: Tuple[jnp.ndarray, ...],
    query_mask: jnp.ndarray,
    src: jnp.ndarray,            # (B,) int32 deleted-edge sources (replicated)
    smask: jnp.ndarray,          # (B,) bool batch padding mask
    f_cap: int,
    backend: BackendLike = "jnp",
    model_axis: Optional[str] = None,
    model_size: int = 1,
    max_rounds: int = 0,
    now: Optional[jnp.ndarray] = None,
    w_max: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, ...]:
    """Shard-local cone-seeded deletion: the delete form of
    :func:`shard_frontier_closure`. The deleted edges' cone is computed on
    the shard's pre-delete block over its LOCAL u rows (pmax-combined
    across model peers — same reduction as ingest, see
    :func:`_shard_dirty_rows`), the cone rows of the local v-column block
    are cleared to the semiring zero, and the shard re-derives them with
    its frontier round loop. A shard none of whose lanes have a cone row
    SKIPS entirely (its rows carry no derivation through the dropped edge,
    so the retained adjacency's fixpoint is already in hand); an
    overflowing shard falls back to ITS OWN dense from-scratch loop (all
    local rows cleared) — the exact non-frontier delete computation, so
    results stay bit-identical per shard.

    Returns the same 7-tuple as :func:`shard_frontier_closure`."""
    backend = resolve_backend(backend)
    q_l, n, n_m, k = dist_blk.shape
    bound = max_rounds if max_rounds > 0 else n * k + 1
    dirty = _shard_dirty_rows(dist_blk, src, smask, query_mask,
                              model_axis, model_size)
    frows, rowmask0, cnt = pack_frontier(dirty, f_cap)
    seed_rows = jnp.sum(cnt)
    max_lane_rows = jnp.max(cnt)
    overflow = jnp.any(cnt > f_cap)
    cleared = jnp.where(dirty[:, :, None, None], NEG_INF, dist_blk)

    def run(_):
        d_op = backend.encode(cleared, now, w_max)
        au_op = backend.encode(adj_u, now, w_max)
        av_op = backend.encode(adj_v, now, w_max)

        def dense(_):
            d0 = backend.encode(jnp.full_like(dist_blk, NEG_INF),
                                now, w_max)
            d_f, it, qr = _shard_dense_loop(
                d0, au_op, av_op, rows, query_mask, backend,
                model_axis, model_size, bound)
            live_rows = jnp.sum(query_mask.astype(jnp.int32)) * n
            return d_f, it, qr, it * live_rows

        def frontier(_):
            def cond(carry):
                _d, rm, it, _qr, _rr = carry
                return jnp.logical_and(jnp.any(rm), it < bound)

            def body(carry):
                d, rm, it, qr, rr = carry
                nd, changed = _shard_frontier_round(
                    d, au_op, av_op, rows, frows, rm, backend,
                    model_axis, model_size)
                qactive = jnp.any(rm, axis=1).astype(jnp.int32)
                return (nd, changed, it + 1, qr + qactive,
                        rr + jnp.sum(rm.astype(jnp.int32)))

            d_f, _, it, qr, rr = jax.lax.while_loop(
                cond, body,
                (d_op, rowmask0, jnp.asarray(0, jnp.int32),
                 jnp.zeros((q_l,), jnp.int32), jnp.asarray(0, jnp.int32)))
            return d_f, it, qr, rr

        d_f, it, qr, rr = jax.lax.cond(overflow, dense, frontier, None)
        return backend.decode_state(d_f, now, w_max), it, qr, rr

    def skip(_):
        return (dist_blk, jnp.asarray(0, jnp.int32),
                jnp.zeros((q_l,), jnp.int32), jnp.asarray(0, jnp.int32))

    d, it, qr, rr = jax.lax.cond(jnp.any(cnt > 0), run, skip, None)
    return d, it, qr, rr, overflow, seed_rows, max_lane_rows
