"""Dense TPU-native streaming RPQ engine (the paper's technique, tensorized),
multi-query batched: Q persistent queries share ONE adjacency and step as one
jitted program, and the query set is LIVE — queries register and deregister
while the stream keeps flowing (the paper's persistent-query execution
model, §2).

Layering (PR 3): the engine is pure ORCHESTRATION — vertex interning, query
lifecycle, result decoding, checkpoint metadata. Everything device-facing
(state arrays, jitted dispatches, round accounting) lives behind the
executor interface (:mod:`repro.core.executor`):

    stream -> service -> engine -> executor -> semiring rounds -> kernels

Two executors plug in: :class:`~repro.core.executor.LocalExecutor` (the
single-device path, bit-identical to the pre-refactor engine) and
:class:`~repro.distributed.executor.MeshExecutor` (Q lanes sharded over a
device mesh with convergence-aware per-shard dispatch — converged/inert
lanes finally SKIP their contraction work instead of being accounted and
zeroed). Result streams are identical across executors (asserted by
tests/test_executor.py and benchmarks/fig14_sharded_engine.py).

State (all fixed-capacity, jit-static shapes between lifecycle events;
capacities GROW at runtime — Q/K/label since PR 2, the vertex axis since
this PR):
    adj     (L, N, N)    f32   newest edge timestamp per (label, u, v); -inf
                               none. L = |union alphabet| of ALL registered
                               queries — the stream is ingested ONCE, not
                               re-ingested per query.
    dist    (Q, N, N, K) f32   per-query bottleneck closure D[q, x, v, s]
                               (DESIGN.md §2); K padded to max_q k_q, the
                               padding states are inert (never scattered
                               into, finals masks padded False).
    emitted (Q, N, N)    bool  pairs already reported per query
                               (implicit-window monotone)
    now     ()           f32   latest event time seen (shared stream clock;
                               EVERY event timestamp advances it, including
                               tuples outside the union alphabet)

The per-query DFA transition tables are flattened into one global list
(semiring.BatchedTransitionTable): a relaxation round is a single
gather → batched max-min contraction → segment-max scatter, so `ingest →
relax → emit` for all Q queries is ONE dispatch per micro-batch instead of
Q. Per-query windows are a (Q,) vector applied as read-time thresholds.

Query lifecycle (beyond-paper, PR 2): the Q axis is a set of LANES.
:meth:`register_query` works at any point of the stream — it re-pads device
state in place (Q grows in buckets, K to the new ``max_q k_q``, the label
axis when the union alphabet expands; all growth is append-only so existing
state keeps its indices and the jit cache is reused within a bucket), then
seeds the new lane with one closure pass over the EXISTING shared
adjacency, so the query immediately answers over the live window (its
initial valid pairs are returned and count as emitted).
:meth:`deregister_query` clears the lane to inert padding; the next
registration reclaims it. Capacities never shrink. Lane capacity is rounded
to the executor's ``q_multiple`` (1 locally; the lane-shard count on a
mesh) so inert padding lands on whole shards the convergence mask skips.

Vertex capacity (beyond-paper, this PR): ``n_slots`` grows on demand — when
the interner runs out of live slots even after compaction, the vertex axes
re-pad append-only (doubling, rounded to the executor's ``n_multiple``)
instead of raising. Checkpoints restore across differing vertex capacities
(the smaller side is padded; a larger checkpoint grows the engine first).

Per-query convergence masking: the closure masks each query out of the
relaxation as soon as its own round produces no change (sound: a transition
only ever reads its owning query's slices), so a converged query's lane
settles at ITS OWN fixpoint. On the dense single-device path the round is
shape-static — the mask buys exact accounting (executor counters
``query_rounds_total`` vs ``unmasked_query_rounds_total``) — while the mesh
executor turns the same mask into skipped contractions per lane shard.

Frontier-restricted ingest (beyond-paper, PR 5): with ``frontier="on" |
"auto"`` the executor's ingest dispatch relaxes only the source rows the
micro-batch dirties (seeded in-dispatch from the batch's source slots —
the engine already threads them through ``ingest_batch``), so per-event
cost is O(J·F·N²) instead of O(J·N³); overflow falls back to the dense
loop inside the dispatch, so results are bit-identical in every mode.
Explicit deletions ride the same machinery since PR 6: the deleted edge's
cone (the rows whose derivations can pass through it, computed on the
pre-delete state) is cleared and re-derived at frontier prices instead of
resetting every row, and :meth:`delete_batch` chunks negative tuples
through the micro-batch path exactly like inserts. Lane-seeding closures
(:meth:`register_query`) and checkpoint adoption stay on the dense
closure — each is a from-scratch re-derivation that dirties every row by
construction — and compaction needs no frontier bookkeeping because no
frontier state persists across dispatches (the dirty set is recomputed
per dispatch, so slot recycling and vertex-axis growth cannot invalidate
stale row indices).

Key property of the (max, min) formulation (beyond-paper, §Perf): *window
expiry needs no index maintenance* — a pair is valid iff its bottleneck
timestamp exceeds ``now - |W_q|``, so expiry is a threshold at read time.
The paper's ExpiryRAPQ machinery is only needed for (a) explicit deletions
(closure re-computation, the paper's own uniform machinery) and (b) vertex
slot recycling (python-side compaction, thresholded at the LARGEST window
of the group so no query loses live state; with no live queries the last
retention threshold is kept so the shared graph survives an empty interval
of the query set).

Semantics vs the paper (B = micro-batch size, Q = #queries):
  * B = 1: the per-query result streams match the paper tuple-for-tuple for
    every query in the group (tested) — a tuple outside query q's alphabet
    steps q's closure with an unchanged adjacency, a no-op.
  * B > 1: results are evaluated at batch boundaries (documented skew: a
    path valid only strictly inside a batch interval is not reported).
    Additionally, with Q > 1 the batch PACKING differs from Q independent
    engines: independent engines drop out-of-alphabet tuples before filling
    a batch, while the group packs every tuple in the union alphabet — so
    batch boundaries (and hence which intra-batch paths are observable)
    can differ per query from a solo run of that query. B = 1 has no skew.
  * implicit windows, eager evaluation, lazy expiration — as in the paper.
  * a query registered mid-stream answers over the CURRENT window content
    from its first instant: its result stream is identical to a freshly
    built group fed the retained graph and then the tail of the stream
    (benchmarks/fig13_query_churn.py asserts this).
"""
from __future__ import annotations

import collections
import math
from typing import (Deque, Dict, List, NamedTuple, Optional, Sequence, Set,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from .automaton import DFA
from .executor import (
    BatchedEngineArrays,
    Executor,
    LocalExecutor,
    QueryTables,
    init_batched_arrays,
)
from .semiring import NEG_INF, BatchedTransitionTable, TransitionTable

Pair = Tuple[object, object]

Q_BUCKET = 4        # lane-capacity growth quantum (compile-cache reuse)
LABEL_BUCKET = 4    # label-axis rounding (absorbs small alphabet growth)


def _round_up(n: int, b: int) -> int:
    return max(n + (-n) % b, b)


# a lane with no registered query: empty language, no transitions, k=1
_INERT_DFA = DFA(
    labels=(),
    delta=np.full((1, 0), -1, np.int32),
    start=0,
    finals=frozenset(),
)


class EngineArrays(NamedTuple):
    """Single-query view (legacy layout) — the Q=1 slice of the batched
    state, kept as the public surface of :class:`DenseRPQEngine` so sharded
    deployments can re-place individual leaves (examples/distributed_rpq)."""

    adj: jnp.ndarray      # (L, N, N) f32
    dist: jnp.ndarray     # (N, N, K) f32
    emitted: jnp.ndarray  # (N, N) bool
    now: jnp.ndarray      # () f32


def init_arrays(n_slots: int, n_labels: int, k: int) -> EngineArrays:
    b = init_batched_arrays(n_slots, n_labels, 1, k)
    return EngineArrays(b.adj, b.dist[0], b.emitted[0], b.now)


@jax.jit
def _conflict_possible(
    dist: jnp.ndarray,           # (Q, N, N, K)
    not_contained: jnp.ndarray,  # (Q, K, K), 1 where [s] !>= [t]
    low: jnp.ndarray,            # (Q,)
) -> jnp.ndarray:
    """Over-approximate RSPQ conflict detection (Definition 16), per query:
    some root reaches some vertex v in states s and t with [s] ⊉ [t].
    Ancestorship is over-approximated by co-reachability (sound: never
    misses a conflict)."""
    p = (dist > low[:, None, None, None]).astype(jnp.float32)  # (Q, N, N, K)
    m = not_contained.astype(jnp.float32)
    cnt = jnp.einsum("qxvs,qst,qxvt->q", p, m, p)
    return cnt > 0


# ---------------------------------------------------------------------------
# Python orchestration: vertex interning, query lifecycle, result decoding
# ---------------------------------------------------------------------------


class RegisteredQuery(NamedTuple):
    """One persistent query of a batched group."""

    name: str
    dfa: DFA
    window: float
    path_semantics: str = "arbitrary"  # arbitrary | simple


class PendingResults:
    """Deferred result decoding for one :meth:`insert_batch_pending` call.

    The device->host transfer of the emit frontier happens at
    :meth:`resolve` time, so a caller (streaming/service.py's async path)
    can dispatch the NEXT micro-batch before pulling the previous one's
    results — the transfer overlaps device compute instead of blocking the
    hot path. Each chunk snapshots the vertex interner (slot recycling
    between dispatch and resolve must not remap decoded pairs). Handles
    resolve in dispatch order (FIFO through the engine) so the monotone
    per-query result sets dedup correctly; the engine drains outstanding
    handles before any lane-set mutation (register/deregister/adopt)."""

    def __init__(self, engine: "BatchedDenseRPQEngine", q_cap: int):
        self._engine = engine
        self._chunks: List[Tuple[object, List[Optional[object]], float]] = []
        self._fresh: List[Set[Pair]] = [set() for _ in range(q_cap)]
        self._decoded = False

    def _add(self, new_dev, vertex_of: List[Optional[object]], t: float) -> None:
        self._chunks.append((new_dev, vertex_of, t))

    def _decode_chunks(self) -> None:
        for new_dev, vertex_of, t in self._chunks:
            self._engine._decode_new_into(
                np.asarray(new_dev), vertex_of, t, self._fresh)
        self._chunks.clear()
        self._decoded = True

    def resolve(self) -> List[Set[Pair]]:
        """Per-lane NEW result pairs (idempotent; forces the host sync)."""
        if not self._decoded:
            self._engine._drain_pending(upto=self)
        return self._fresh


class BatchedDenseRPQEngine:
    """Q persistent RPQs over ONE stream, stepped as one jitted program.

    All queries share the vertex interner and the (L, N, N) adjacency over
    the union label alphabet; per-query closure state is stacked along the
    leading Q axis as LANES. The lane list (``lane_specs``) may contain
    ``None`` holes — inert padding left by :meth:`deregister_query`, by
    bucketed Q growth, or by rounding to the executor's lane-shard count —
    which the next :meth:`register_query` reclaims. Per-lane accessors
    (``per_query_results``, ``current_results``, the lists returned by
    :meth:`insert_batch` / :meth:`delete`) are indexed by lane;
    :meth:`lane_of` maps a query name to its lane.

    ``executor`` selects the device path: default
    :class:`~repro.core.executor.LocalExecutor` (single device), or a
    :class:`~repro.distributed.executor.MeshExecutor` for Q-sharded
    execution with convergence-aware dispatch. The engine itself never
    touches device arrays directly.

    Per-query ``path_semantics`` follows the single-engine contract:
    "simple" (RSPQ) uses the Mendelzon–Wood tractable class and flags
    possibly-over-reporting windows in :attr:`per_query_conflicted`.
    """

    def __init__(
        self,
        queries: Sequence[RegisteredQuery],
        n_slots: int = 128,
        batch_size: int = 32,
        backend="jnp",  # name in backend.KNOWN_BACKENDS or a ContractionBackend
        executor: Optional[Executor] = None,
        frontier: str = "off",   # off | on | auto (executor ingest mode)
        frontier_cap: int = 32,
        adj_layout: str = "dense",  # dense | ell (executor adjacency layout)
        ell_cap: int = 8,
        dist_layout: str = "dense",  # dense | row_sparse (dist layout)
        dist_cap: int = 16,
    ):
        queries = list(queries)
        if not queries:
            raise ValueError("register at least one query")
        for q in queries:
            if q.dfa.containment is None:
                raise ValueError(f"compile query {q.name!r} with compile_query()")
        names = [q.name for q in queries]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate query names: {names}")
        # frontier kwargs configure the default executor only; an explicit
        # executor instance arrives already configured
        self.executor = executor if executor is not None else LocalExecutor(
            backend, frontier=frontier, frontier_cap=frontier_cap,
            adj_layout=adj_layout, ell_cap=ell_cap,
            dist_layout=dist_layout, dist_cap=dist_cap)
        self.backend = self.executor.backend
        self.lane_specs: List[Optional[RegisteredQuery]] = list(queries)
        # round lane capacity to the executor's shard quantum (inert padding
        # lanes; the convergence mask skips them wholesale)
        pad = _round_up(len(queries), self.executor.q_multiple) - len(queries)
        self.lane_specs.extend([None] * pad)
        self.n_slots = _round_up(n_slots, self.executor.n_multiple)
        self.batch_size = batch_size
        # shared alphabet = union over queries; sorted at construction, new
        # labels APPEND at live registration (existing adj rows keep their
        # index — the ×4-rounded label slots absorb small growth)
        self.labels: Tuple[str, ...] = tuple(
            sorted(set().union(*[set(q.dfa.labels) for q in queries]))
        )
        self._label_index = {lab: i for i, lab in enumerate(self.labels)}
        self.k = 0           # padded state count; set by _rebuild_tables
        self.max_window = 0.0
        self._rebuild_tables()
        n_label_slots = _round_up(len(self.labels), LABEL_BUCKET)
        self.executor.init_state(self.n_slots, n_label_slots, self.q_cap, self.k)
        # host-side mirror of the device stream clock (decode timestamps
        # without forcing a device sync; identical by construction — both
        # advance by the max event time seen)
        self._host_now = NEG_INF
        # vertex interning (shared across queries: the stream is one graph)
        self.slot_of: Dict[object, int] = {}
        self.vertex_of: List[Optional[object]] = [None] * self.n_slots
        self.free: List[int] = list(range(self.n_slots - 1, -1, -1))
        # slots referenced by the chunk currently being packed: compaction
        # triggered mid-chunk must not recycle them (they may have no
        # adjacency yet and would otherwise look dead)
        self._chunk_pinned: Set[int] = set()
        # deferred-decode FIFO (PendingResults handles not yet resolved);
        # a deque so the drain is O(1) per handle — at async_depth-deep
        # service queues list.pop(0) was O(n) per pop, O(n²) per drain
        self._pending_fifo: Deque[PendingResults] = collections.deque()
        # per-lane results
        self.per_query_results: List[Set[Pair]] = [set() for _ in range(self.q_cap)]
        self.per_query_log: List[List[Tuple[float, Pair]]] = [[] for _ in range(self.q_cap)]
        self.per_query_conflicted: List[bool] = [False] * self.q_cap

    # -- executor-backed accounting (back-compat surface) ---------------------

    @property
    def batched_arrays(self) -> BatchedEngineArrays:
        """The device state (owned by the executor; read-only view)."""
        return self.executor.arrays

    @property
    def host_now(self) -> float:
        """Host mirror of the device stream clock — identical by
        construction (both advance by the max event time seen), so
        maintenance and telemetry paths read this instead of blocking the
        async dispatch chain on ``arrays.now``."""
        return self._host_now

    @property
    def total_rounds(self) -> int:
        """Global closure iterations (max over queries per dispatch)."""
        return self.executor.rounds_total

    @property
    def total_query_rounds(self) -> int:
        """Sum over queries of ACTIVE rounds (convergence-masked)."""
        return self.executor.query_rounds_total

    @property
    def steps(self) -> int:
        """Jitted ingest/delete dispatches (the Q-sharing win)."""
        return self.executor.steps

    # -- lane bookkeeping ----------------------------------------------------

    @property
    def q_cap(self) -> int:
        """Allocated lane capacity (the Q axis of the device arrays)."""
        return len(self.lane_specs)

    @property
    def n_queries(self) -> int:
        """Number of LIVE queries (non-inert lanes)."""
        return sum(1 for s in self.lane_specs if s is not None)

    @property
    def query_specs(self) -> List[RegisteredQuery]:
        """Live query specs in lane order (back-compat view)."""
        return [s for s in self.lane_specs if s is not None]

    def live_items(self) -> List[Tuple[int, RegisteredQuery]]:
        return [(qi, s) for qi, s in enumerate(self.lane_specs) if s is not None]

    def lane_of(self, name: str) -> int:
        for qi, s in enumerate(self.lane_specs):
            if s is not None and s.name == name:
                return qi
        raise KeyError(f"no live query named {name!r}")

    def _rebuild_tables(self) -> None:
        """Recompute the flattened transition table and per-lane metadata
        from the current lane list (inert lanes contribute nothing). K and
        max_window never shrink below live device state / the last retention
        threshold."""
        dfas = [s.dfa if s is not None else _INERT_DFA for s in self.lane_specs]
        self.btt = BatchedTransitionTable.from_dfas(dfas, self.labels, k_min=self.k)
        self.k = self.btt.k
        qc = self.q_cap
        fm = np.zeros((qc, self.k), bool)
        nc = np.zeros((qc, self.k, self.k), bool)
        self._simple = np.zeros((qc,), bool)
        self._check_conflict = np.zeros((qc,), bool)
        windows = np.zeros((qc,), np.float32)
        live = np.zeros((qc,), bool)
        for qi, spec in enumerate(self.lane_specs):
            if spec is None:
                continue
            dfa = spec.dfa
            for f in dfa.finals:
                fm[qi, f] = True
            nc[qi, : dfa.k, : dfa.k] = ~dfa.containment
            windows[qi] = spec.window
            self._simple[qi] = spec.path_semantics == "simple"
            self._check_conflict[qi] = (
                spec.path_semantics == "simple" and not dfa.has_containment_property
            )
            live[qi] = True
        self.finals_mask = jnp.asarray(fm)
        self.not_contained = jnp.asarray(nc)
        self.windows = jnp.asarray(windows)
        self.live_mask = jnp.asarray(live)
        if live.any():
            self.max_window = float(windows[live].max())
        # else: keep the previous retention threshold — with no live queries
        # the shared graph is retained at the last group policy so a future
        # registration still answers over the live window
        self.tables = QueryTables(
            self.btt, self.finals_mask, self.windows, self.live_mask,
            int(live.sum()), float(self.max_window),
        )

    def _repad_arrays(self) -> None:
        """Grow device state in place to the current (q_cap, label-slot, K)
        capacities. Growth only — inert padding is reclaimable, never
        reshaped away — and append-only, so existing lanes/labels/states
        keep their indices and compiled steps are reused within a bucket."""
        self.executor.grow(
            q_cap=self.q_cap,
            k=self.k,
            n_label_slots=_round_up(len(self.labels), LABEL_BUCKET),
        )

    # -- query lifecycle -----------------------------------------------------

    def register_query(self, spec: RegisteredQuery) -> Set[Pair]:
        """Add a persistent query to the LIVE group (works mid-stream).

        Re-pads device state in place (Q bucketed, K to the new
        ``max_q k_q``, label axis on union-alphabet growth), then seeds the
        new lane's closure with one closure pass over the existing shared
        adjacency — only the new lane relaxes; converged lanes stay masked
        (on a mesh executor, whole shards skip). Returns the query's
        INITIAL result pairs (valid over the current window), which are
        recorded as emitted: the subsequent result stream is identical to a
        freshly built group fed the retained graph and then the tail of the
        stream.
        """
        if spec.dfa.containment is None:
            raise ValueError(f"compile query {spec.name!r} with compile_query()")
        if any(s is not None and s.name == spec.name for s in self.lane_specs):
            raise ValueError(f"query {spec.name!r} already registered")
        self._drain_pending()
        # union alphabet growth: append-only
        for lab in sorted(spec.dfa.labels):
            if lab not in self._label_index:
                self._label_index[lab] = len(self.labels)
                self.labels = self.labels + (lab,)
        # lane: reclaim an inert hole, else grow the Q axis to the next
        # bucket (rounded to the executor's lane-shard quantum)
        lane = next((i for i, s in enumerate(self.lane_specs) if s is None), None)
        if lane is None:
            lane = len(self.lane_specs)
            q_quantum = Q_BUCKET * self.executor.q_multiple // math.gcd(
                Q_BUCKET, self.executor.q_multiple)
            new_cap = _round_up(lane + 1, q_quantum)
            grow = new_cap - lane
            self.lane_specs.extend([None] * grow)
            self.per_query_results.extend(set() for _ in range(grow))
            self.per_query_log.extend([] for _ in range(grow))
            self.per_query_conflicted.extend([False] * grow)
        self.lane_specs[lane] = spec
        self._rebuild_tables()
        self._repad_arrays()
        # the lane may be a reclaimed hole: make sure it starts inert
        self.executor.clear_lane(lane)
        self.per_query_results[lane] = set()
        self.per_query_log[lane] = []
        self.per_query_conflicted[lane] = False
        if not self.slot_of:
            return set()  # nothing ingested yet: nothing to seed
        # seed: one closure pass over the EXISTING shared adjacency, only
        # the new lane unmasked (every other lane is already at fixpoint)
        lane_mask = np.zeros((self.q_cap,), bool)
        lane_mask[lane] = True
        self.executor.relax(self.tables, query_mask=lane_mask)
        valid = self.executor.emit(self.tables)
        self.executor.set_lane_emitted(lane, valid[lane])
        if self._check_conflict[lane]:
            a = self.executor.arrays
            low = a.now - self.windows
            flags = np.asarray(_conflict_possible(
                self.executor.dense_dist(), self.not_contained, low))
            if flags[lane]:
                self.per_query_conflicted[lane] = True
        initial = self._decode_pairs(np.asarray(valid[lane]), bool(self._simple[lane]))
        t = self._host_now
        for p in sorted(initial, key=repr):
            self.per_query_results[lane].add(p)
            self.per_query_log[lane].append((t, p))
        return initial

    def deregister_query(self, name: str) -> None:
        """Remove a live query: its lane becomes inert padding (dist/emitted
        cleared, no transitions, window 0) reclaimable by the next
        :meth:`register_query`. Other lanes are untouched — their result
        streams are unaffected by the departure (tested). Capacities (Q, K,
        labels, vertex slots) never shrink; if the departing query held the
        group's largest window, the retention threshold tightens to the
        remaining queries' maximum."""
        lane = self.lane_of(name)
        self._drain_pending()
        self.lane_specs[lane] = None
        self.executor.clear_lane(lane)
        self.per_query_results[lane] = set()
        self.per_query_log[lane] = []
        self.per_query_conflicted[lane] = False
        self._rebuild_tables()

    # -- interning ----------------------------------------------------------

    def _slot(self, vertex: object) -> int:
        s = self.slot_of.get(vertex)
        if s is None:
            if not self.free:
                self.compact()
            if not self.free:
                # grow-on-demand (beyond-paper): double the vertex axis,
                # rounded to the executor's vertex-shard quantum — the
                # engine never raises on capacity mid-stream
                self._grow_slots(
                    _round_up(self.n_slots * 2, self.executor.n_multiple))
            s = self.free.pop()
            self.slot_of[vertex] = s
            self.vertex_of[s] = vertex
        return s

    def _grow_slots(self, new_n: int) -> None:
        """Append-only growth of the vertex axis (adj/dist/emitted re-pad;
        slot indices survive, so the interner and any checkpoint metadata
        remain valid)."""
        if new_n <= self.n_slots:
            return
        self.executor.grow(n_slots=new_n)
        old_n = self.n_slots
        self.n_slots = new_n
        self.vertex_of.extend([None] * (new_n - old_n))
        # existing free slots keep priority (pop from the end)
        self.free = list(range(new_n - 1, old_n - 1, -1)) + self.free

    # -- public API ----------------------------------------------------------

    def insert(self, u: object, v: object, label: str, ts: float) -> List[Set[Pair]]:
        return self.insert_batch([(u, v, label, ts)])

    def insert_batch(
        self, edges: Sequence[Tuple[object, object, str, float]]
    ) -> List[Set[Pair]]:
        """Ingest a micro-batch of append sgts (timestamp-ordered). Returns
        the NEW result pairs per lane (list indexed like lane_specs)."""
        return self.insert_batch_pending(edges).resolve()

    def insert_batch_pending(
        self, edges: Sequence[Tuple[object, object, str, float]]
    ) -> PendingResults:
        """Like :meth:`insert_batch` but returns a :class:`PendingResults`
        handle without forcing the device->host result transfer — the async
        micro-batched decode path (the service overlaps the transfer with
        the next ingest dispatch)."""
        pending = PendingResults(self, self.q_cap)
        self._pending_fifo.append(pending)
        B = self.batch_size
        for i in range(0, len(edges), B):
            self._ingest_chunk(edges[i : i + B], pending)
        return pending

    def _ingest_chunk(self, edges, pending: PendingResults) -> None:
        B = self.batch_size
        src = np.zeros((B,), np.int32)
        dst = np.zeros((B,), np.int32)
        lab = np.zeros((B,), np.int32)
        ts = np.full((B,), NEG_INF, np.float32)
        mask = np.zeros((B,), bool)
        # the stream clock advances from EVERY event in the chunk, packed or
        # not: a mixed chunk whose trailing tuples are out-of-alphabet must
        # not evaluate window validity against a stale `now`
        chunk_now = max(t for (_u, _v, _l, t) in edges)
        j = 0
        self._chunk_pinned.clear()
        try:
            for (u, v, label, t) in edges:
                li = self._label_index.get(label)
                if li is None:
                    continue  # outside the union Sigma_Q: discarded (paper §5.2)
                # pin each slot as soon as it is interned: _slot() may
                # compact mid-chunk, and a chunk-local vertex with no
                # adjacency yet must not be recycled before its edge lands
                si = self._slot(u)
                self._chunk_pinned.add(si)
                di = self._slot(v)
                self._chunk_pinned.add(di)
                src[j] = si
                dst[j] = di
                lab[j] = li
                ts[j] = t
                mask[j] = True
                j += 1
            self._host_now = max(self._host_now, chunk_now)
            if j == 0:
                # still advance the clock
                self.executor.advance_clock(chunk_now)
                return
            new = self.executor.ingest_batch(
                src, dst, lab, ts, mask, chunk_now, self.tables
            )
        finally:
            self._chunk_pinned.clear()
        if self._check_conflict.any():
            a = self.executor.arrays
            low = a.now - self.windows
            flags = np.asarray(_conflict_possible(
                self.executor.dense_dist(), self.not_contained, low))
            for qi in np.nonzero(flags & self._check_conflict)[0]:
                self.per_query_conflicted[int(qi)] = True
        # decode deferred: snapshot the interner so later slot recycling
        # cannot remap this chunk's pairs
        pending._add(new, list(self.vertex_of), self._host_now)

    def _drain_pending(self, upto: Optional[PendingResults] = None) -> None:
        """Resolve outstanding deferred decodes in dispatch order (through
        ``upto`` when given, else all)."""
        while self._pending_fifo:
            head = self._pending_fifo.popleft()
            head._decode_chunks()
            if head is upto:
                break

    def delete(self, u: object, v: object, label: str, ts: float) -> List[Set[Pair]]:
        """Explicit deletion (negative tuple). Returns invalidated pairs
        per lane."""
        return self.delete_batch([(u, v, label, ts)])

    def delete_batch(
        self, edges: Sequence[Tuple[object, object, str, float]]
    ) -> List[Set[Pair]]:
        """Delete a micro-batch of negative sgts (timestamp-ordered)
        through the same chunked dispatch path as :meth:`insert_batch`: up
        to ``batch_size`` negative tuples share ONE jitted delete dispatch
        (with ``frontier != "off"`` their cones merge into one dirty set).
        Returns the invalidated pairs per lane, unioned over the batch.

        B = 1 matches per-event semantics exactly; B > 1 evaluates each
        chunk's invalidation at the chunk's max event time (the same
        batch-boundary skew contract as :meth:`insert_batch`). Only LIVE
        lanes are decoded — inert padding lanes (deregistered holes, bucket
        growth) return empty sets without an O(N²) scan each, and a stale
        padding lane can never surface pairs."""
        self._drain_pending()
        out: List[Set[Pair]] = [set() for _ in range(self.q_cap)]
        B = self.batch_size
        for i in range(0, len(edges), B):
            self._delete_chunk(edges[i : i + B], out)
        return out

    def _delete_chunk(self, edges, out: List[Set[Pair]]) -> None:
        B = self.batch_size
        src = np.zeros((B,), np.int32)
        dst = np.zeros((B,), np.int32)
        lab = np.zeros((B,), np.int32)
        mask = np.zeros((B,), bool)
        chunk_now = max(t for (_u, _v, _l, t) in edges)
        self._host_now = max(self._host_now, chunk_now)
        j = 0
        for (u, v, label, _t) in edges:
            li = self._label_index.get(label)
            if li is None or u not in self.slot_of or v not in self.slot_of:
                continue  # unknown label/vertex: nothing retained to drop
            src[j] = self.slot_of[u]
            dst[j] = self.slot_of[v]
            lab[j] = li
            mask[j] = True
            j += 1
        if j == 0:
            # still advance the clock (every event timestamp moves it)
            self.executor.advance_clock(chunk_now)
            return
        invalidated = self.executor.delete_batch(
            src, dst, lab, mask, chunk_now, self.tables)
        inv = np.asarray(invalidated)
        for qi, _spec in self.live_items():
            out[qi] |= self._decode_pairs(inv[qi], bool(self._simple[qi]))

    def expire(self, tau: Optional[float] = None) -> None:
        """Slide-boundary maintenance: adjacency masking + slot recycling.
        Safe with deferred decodes outstanding (they snapshot the interner);
        the device dispatch is sequenced after the pending ingests."""
        t = tau if tau is not None else self._host_now
        self._host_now = max(self._host_now, t)
        live = self.executor.expire(t, self.max_window)
        self._recycle(live)

    def compact(self) -> None:
        self.expire()

    def _recycle(self, live: np.ndarray) -> None:
        dead_slots = [
            s for s, vtx in enumerate(self.vertex_of)
            if vtx is not None and not bool(live[s])
            and s not in self._chunk_pinned  # chunk-local: edge not landed yet
        ]
        if not dead_slots:
            return
        self.executor.clear_slots(dead_slots)
        for s in dead_slots:
            vtx = self.vertex_of[s]
            self.vertex_of[s] = None
            del self.slot_of[vtx]
            self.free.append(s)

    # -- result decoding ------------------------------------------------------

    def _decode_pairs(self, mat: np.ndarray, simple: bool) -> Set[Pair]:
        pairs: Set[Pair] = set()
        xs, vs = np.nonzero(mat)
        for x, v in zip(xs.tolist(), vs.tolist()):
            if simple and x == v:
                continue  # a simple path never revisits its source
            xv = self.vertex_of[x]
            vv = self.vertex_of[v]
            if xv is not None and vv is not None:
                pairs.add((xv, vv))
        return pairs

    def _decode_new_into(
        self,
        arr: np.ndarray,                       # (Q, N, N) bool
        vertex_of: List[Optional[object]],     # interner snapshot at dispatch
        t: float,
        fresh: List[Set[Pair]],
    ) -> None:
        """Merge per-lane pairs NEW to the monotone result set into `fresh`:
        after slot recycling the emitted matrices forget old occupants, so
        the device diff may resurface already-reported pairs — the
        python-side sets are the source of truth for implicit-window
        monotonicity."""
        qs, xs, vs = np.nonzero(arr)
        for q, x, v in zip(qs.tolist(), xs.tolist(), vs.tolist()):
            if self._simple[q] and x == v:
                continue
            xv = vertex_of[x]
            vv = vertex_of[v]
            if xv is None or vv is None:
                continue
            p = (xv, vv)
            if p not in self.per_query_results[q]:
                self.per_query_results[q].add(p)
                self.per_query_log[q].append((t, p))
                fresh[q].add(p)

    def current_results(self, qi: int = 0) -> Set[Pair]:
        """Snapshot view (explicit-window semantics) for lane `qi`."""
        valid = self.executor.emit(self.tables)
        return self._decode_pairs(np.asarray(valid[qi]), bool(self._simple[qi]))

    def retained_edges(self) -> List[Tuple[object, object, str, float]]:
        """The shared graph's current content as (u, v, label, ts) tuples in
        timestamp order — everything a newly registered query's seeding
        closure sees. Feeding these into a fresh engine (and syncing its
        clock to this engine's `now`) reproduces this engine's dist for any
        query, because the closure fixpoint depends only on the final
        adjacency: the oracle construction of the churn conformance tests
        and benchmarks/fig13_query_churn.py."""
        adj = np.asarray(jax.device_get(self.executor.dense_adj()))
        out: List[Tuple[object, object, str, float]] = []
        ls, us, vs = np.nonzero(adj > NEG_INF)
        for l, u, v in zip(ls.tolist(), us.tolist(), vs.tolist()):
            if l >= len(self.labels):
                continue
            uu = self.vertex_of[u]
            vv = self.vertex_of[v]
            if uu is None or vv is None:
                continue
            out.append((uu, vv, self.labels[l], float(adj[l, u, v])))
        out.sort(key=lambda e: e[3])
        return out

    def index_size(self, qi: Optional[int] = None) -> Tuple[int, int]:
        """(active roots, populated (x,v,s) entries) — Fig. 5 analogue.
        `qi=None` aggregates over the whole group."""
        a = self.executor.arrays
        # host clock mirror instead of a.now: windows is static (no
        # pending dispatch feeds it), so only the dist read below has to
        # wait on the in-flight closure
        low = self._host_now - np.asarray(self.windows)  # (Q,)
        pop = np.asarray(self.executor.dense_dist()) > low[:, None, None, None]
        if qi is not None:
            pop = pop[qi : qi + 1]
        roots = int(pop.any(axis=(2, 3)).sum())
        return roots, int(pop.sum())

    # -- state persistence (checkpoint/ckpt.py rides this) --------------------

    def state_arrays(self) -> Dict[str, jnp.ndarray]:
        """The device state as one pytree (checkpointable as-is; sharded
        executors hand back globally-addressable arrays that device_get
        gathers)."""
        self._drain_pending()
        a = self.executor.arrays
        return {"adj": self.executor.dense_adj(),
                "dist": self.executor.dense_dist(),
                "emitted": a.emitted, "now": a.now}

    def load_state_arrays(self, state: Dict[str, jnp.ndarray]) -> None:
        """Exact-shape reload (same capacities). For checkpoints written by
        a group with a different churn history (other Q/K/label/slot
        padding), use :meth:`adopt_state`."""
        self._drain_pending()
        self.executor.place({k: np.asarray(jax.device_get(v))
                             for k, v in state.items()})
        self._host_now = float(np.asarray(jax.device_get(state["now"])))

    def adopt_state(
        self,
        state: Dict[str, jnp.ndarray],
        lane_names: Sequence[Optional[str]],
        labels: Sequence[str],
    ) -> None:
        """Load checkpointed device arrays whose Q/K/label/vertex capacities
        may differ from this engine's (bucketed-Q padding, different churn
        history, a vertex axis that grew at runtime, a different executor's
        shard quanta). Lanes are matched by query NAME, adjacency rows by
        label NAME; slot indices are positional (the interner metadata
        refers to them), so the smaller vertex capacity is padded and a
        LARGER checkpoint grows this engine first. The live query sets must
        agree. Labels present only in the checkpoint (e.g. retained from
        queries deregistered pre-snapshot) are appended so the shared graph
        survives intact. Works across executors: a mesh-written checkpoint
        restores onto a local executor and vice versa (arrays are logical;
        placement is the executor's concern)."""
        self._drain_pending()
        adj_ck = np.asarray(jax.device_get(state["adj"]))
        dist_ck = np.asarray(jax.device_get(state["dist"]))
        emitted_ck = np.asarray(jax.device_get(state["emitted"]))
        ck_n = adj_ck.shape[1]
        if ck_n > self.n_slots:
            self._grow_slots(_round_up(ck_n, self.executor.n_multiple))
        ours = {spec.name: qi for qi, spec in self.live_items()}
        theirs = {name: qi for qi, name in enumerate(lane_names) if name is not None}
        if set(ours) != set(theirs):
            raise ValueError(
                f"checkpointed query set {sorted(theirs)} does not match "
                f"registered set {sorted(ours)}"
            )
        for lab in labels:
            if lab not in self._label_index:
                self._label_index[lab] = len(self.labels)
                self.labels = self.labels + (lab,)
        self._rebuild_tables()
        self._repad_arrays()
        a = self.executor.arrays
        adj = np.full(self.executor.adj_shape, NEG_INF, np.float32)
        for li_ck, lab in enumerate(labels):
            adj[self._label_index[lab], :ck_n, :ck_n] = adj_ck[li_ck]
        dist = np.full(self.executor.dist_shape, NEG_INF, np.float32)
        emitted = np.zeros(tuple(a.emitted.shape), bool)
        # states beyond a lane's own dfa.k are provably -inf padding (no
        # transition ever scatters into them), so the K prefix carries
        # everything real in either direction
        kk = min(dist_ck.shape[3], self.k)
        for name, qi in ours.items():
            dist[qi, :ck_n, :ck_n, :kk] = dist_ck[theirs[name], :, :, :kk]
            emitted[qi, :ck_n, :ck_n] = emitted_ck[theirs[name]]
        now = np.float32(np.asarray(jax.device_get(state["now"])))
        self.executor.place(
            {"adj": adj, "dist": dist, "emitted": emitted, "now": now})
        self._host_now = float(now)

    def interner_state(self) -> Dict[str, object]:
        """Vertex interner as JSON-able metadata with TYPE TAGS: string ids
        like "42" and int ids like 42 both survive a snapshot → restore
        round trip (the untyped v1 format guessed int() on load and turned
        numeric-string vertices into ints)."""
        return {
            "format": 2,
            "entries": [
                [_encode_vertex(v), int(slot)]
                for v, slot in sorted(self.slot_of.items(), key=lambda kv: kv[1])
            ],
        }

    def load_interner(self, state: Dict) -> None:
        # v2 detection must not be fooled by a LEGACY checkpoint whose
        # stream contained vertices literally named "format"/"entries"
        # (v1 values are all int slots, never a list)
        if (isinstance(state, dict) and state.get("format") == 2
                and isinstance(state.get("entries"), list)):
            self.slot_of = {
                _decode_vertex(enc): int(slot) for enc, slot in state["entries"]
            }
        else:  # legacy v1 checkpoints: untyped str keys, int guessed on load
            self.slot_of = {_maybe_int(k): v for k, v in state.items()}
        self.vertex_of = [None] * self.n_slots
        for vtx, slot in self.slot_of.items():
            self.vertex_of[slot] = vtx
        used = set(self.slot_of.values())
        self.free = [s for s in range(self.n_slots - 1, -1, -1) if s not in used]

    def results_state(self) -> Dict[str, object]:
        self._drain_pending()
        return {
            "format": 2,
            "results": {
                spec.name: [
                    [_encode_vertex(a), _encode_vertex(b)]
                    for (a, b) in sorted(self.per_query_results[qi], key=repr)
                ]
                for qi, spec in self.live_items()
            },
            "conflicted": {
                spec.name: self.per_query_conflicted[qi]
                for qi, spec in self.live_items()
            },
        }

    def load_results_state(self, state: Dict[str, object]) -> None:
        tagged = state.get("format", 1) >= 2
        for qi, spec in self.live_items():
            pairs = state["results"][spec.name]
            if tagged:
                self.per_query_results[qi] = {
                    (_decode_vertex(a), _decode_vertex(b)) for a, b in pairs
                }
            else:
                self.per_query_results[qi] = {tuple(p) for p in pairs}
            self.per_query_log[qi] = []
            self.per_query_conflicted[qi] = bool(state["conflicted"][spec.name])


def _encode_vertex(v: object) -> List:
    """Type-tagged JSON-able encoding of a vertex id (satellite fix: the
    checkpoint must not guess types on load)."""
    if isinstance(v, bool):  # before int: bool is an int subclass
        return ["b", bool(v)]
    if isinstance(v, int):
        return ["i", int(v)]
    if isinstance(v, float):
        return ["f", float(v)]
    if isinstance(v, str):
        return ["s", v]
    if isinstance(v, tuple):
        return ["t", [_encode_vertex(x) for x in v]]
    import base64
    import pickle

    return ["p", base64.b64encode(pickle.dumps(v)).decode("ascii")]


def _decode_vertex(enc: Sequence) -> object:
    tag, val = enc
    if tag == "b":
        return bool(val)
    if tag == "i":
        return int(val)
    if tag == "f":
        return float(val)
    if tag == "s":
        return str(val)
    if tag == "t":
        return tuple(_decode_vertex(x) for x in val)
    if tag == "p":
        import base64
        import pickle

        return pickle.loads(base64.b64decode(val))
    raise ValueError(f"unknown vertex tag {tag!r}")


def _maybe_int(s: str):
    """Legacy v1 interner decoding (type-guessing; kept for old manifests)."""
    try:
        return int(s)
    except ValueError:
        return s


class DenseRPQEngine(BatchedDenseRPQEngine):
    """Streaming RPQ engine over fixed-capacity dense state — the thin Q=1
    view over the batched core (one registered query).

    path_semantics: "arbitrary" (RAPQ) or "simple" (RSPQ). Simple-path mode
    uses the Mendelzon–Wood tractable class: if the automaton has the suffix
    containment property the dense answer set is provably identical under
    both semantics (DESIGN.md §2); otherwise runtime conflict detection
    flags windows where the dense answer may over-report, and
    ``conflicted`` exposes it (the service layer falls back to the
    reference RSPQ for exactness — the paper's exponential case).
    """

    def __init__(
        self,
        dfa: DFA,
        window: float,
        n_slots: int = 128,
        batch_size: int = 32,
        backend="jnp",
        path_semantics: str = "arbitrary",
        executor: Optional[Executor] = None,
        frontier: str = "off",
        frontier_cap: int = 32,
        adj_layout: str = "dense",
        ell_cap: int = 8,
        dist_layout: str = "dense",
        dist_cap: int = 16,
    ):
        super().__init__(
            [RegisteredQuery("q0", dfa, float(window), path_semantics)],
            n_slots=n_slots, batch_size=batch_size, backend=backend,
            executor=executor, frontier=frontier, frontier_cap=frontier_cap,
            adj_layout=adj_layout, ell_cap=ell_cap,
            dist_layout=dist_layout, dist_cap=dist_cap,
        )
        self.dfa = dfa
        self.window = float(window)
        self.path_semantics = path_semantics
        self.tt = TransitionTable.from_dfa(dfa)  # legacy consumers (dryrun)

    # -- Q=1 adapters --------------------------------------------------------

    @property
    def arrays(self) -> EngineArrays:
        # adj/dist are always presented as canonical dense slabs — legacy
        # consumers (dryrun, examples) are layout-agnostic
        b = self.executor.arrays
        return EngineArrays(self.executor.dense_adj(),
                            self.executor.dense_dist()[0],
                            b.emitted[0], b.now)

    @arrays.setter
    def arrays(self, a: EngineArrays) -> None:
        adj = a.adj
        if self.executor.adj_layout == "ell":
            adj = self.executor.pack_adj(np.asarray(jax.device_get(adj)))
        dist = a.dist[None]
        if self.executor.dist_layout == "row_sparse":
            dist = self.executor.pack_dist(np.asarray(jax.device_get(dist)))
        self.executor.set_arrays(BatchedEngineArrays(
            adj, dist, a.emitted[None], a.now
        ))

    @property
    def results(self) -> Set[Pair]:
        self._drain_pending()
        return self.per_query_results[0]

    @results.setter
    def results(self, value: Set[Pair]) -> None:
        self.per_query_results[0] = set(value)

    @property
    def result_log(self) -> List[Tuple[float, Pair]]:
        return self.per_query_log[0]

    @property
    def conflicted(self) -> bool:
        return self.per_query_conflicted[0]

    @conflicted.setter
    def conflicted(self, value: bool) -> None:
        self.per_query_conflicted[0] = bool(value)

    def insert(self, u: object, v: object, label: str, ts: float) -> Set[Pair]:
        return super().insert_batch([(u, v, label, ts)])[0]

    def insert_batch(self, edges) -> Set[Pair]:
        return super().insert_batch(edges)[0]

    def delete(self, u: object, v: object, label: str, ts: float) -> Set[Pair]:
        return super().delete(u, v, label, ts)[0]

    def current_results(self) -> Set[Pair]:
        return super().current_results(0)

    def index_size(self) -> Tuple[int, int]:
        return super().index_size(0)


def make_churn_oracle(
    dfa: DFA,
    live_group: BatchedDenseRPQEngine,
    window: float,
    n_slots: int,
    path_semantics: str = "arbitrary",
) -> Tuple[DenseRPQEngine, Set[Pair]]:
    """Fresh-engine oracle for a query registered mid-stream — the single
    construction tests/test_query_churn.py and benchmarks/fig13_query_churn
    assert against. Exact by this recipe, in this order:

    1. sync the fresh engine's clock to the live group's `now` BEFORE
       seeding (expire() on the empty engine), so the seed's emitted
       baseline is "valid over the current window" — the same baseline
       :meth:`BatchedDenseRPQEngine.register_query` records;
    2. feed the group's :meth:`~BatchedDenseRPQEngine.retained_edges` as
       ONE batch — exact because the closure fixpoint depends only on the
       final adjacency, and a single evaluation at the synced clock emits
       exactly the live-window-valid pairs (per-tuple replay would also
       emit pairs only valid at interior instants);
    3. replay the tail per-tuple (batch_size=1: no boundary skew).

    Returns (oracle, seed_results); seed_results must equal the live
    registration's initial answer set."""
    retained = live_group.retained_edges()
    oracle = DenseRPQEngine(dfa, window, n_slots=n_slots,
                            batch_size=max(1, len(retained)),
                            path_semantics=path_semantics)
    oracle.expire(live_group.host_now)
    seed = oracle.insert_batch(retained) if retained else set()
    oracle.batch_size = 1
    return oracle, seed
