"""Dense TPU-native streaming RPQ engine (the paper's technique, tensorized),
multi-query batched: Q persistent queries share ONE adjacency and step as one
jitted program.

State (all fixed-capacity, jit-static shapes):
    adj     (L, N, N)    f32   newest edge timestamp per (label, u, v); -inf
                               none. L = |union alphabet| of ALL registered
                               queries — the stream is ingested ONCE, not
                               re-ingested per query.
    dist    (Q, N, N, K) f32   per-query bottleneck closure D[q, x, v, s]
                               (DESIGN.md §2); K padded to max_q k_q, the
                               padding states are inert (never scattered
                               into, finals masks padded False).
    emitted (Q, N, N)    bool  pairs already reported per query
                               (implicit-window monotone)
    now     ()           f32   latest event time seen (shared stream clock)

The per-query DFA transition tables are flattened into one global list
(semiring.BatchedTransitionTable): a relaxation round is a single
gather → batched max-min contraction → segment-max scatter, so `ingest →
relax → emit` for all Q queries is ONE dispatch per micro-batch instead of
Q. Per-query windows are a (Q,) vector applied as read-time thresholds.

Key property of the (max, min) formulation (beyond-paper, §Perf): *window
expiry needs no index maintenance* — a pair is valid iff its bottleneck
timestamp exceeds ``now - |W_q|``, so expiry is a threshold at read time.
The paper's ExpiryRAPQ machinery is only needed for (a) explicit deletions
(closure re-computation, the paper's own uniform machinery) and (b) vertex
slot recycling (python-side compaction, thresholded at the LARGEST window
of the group so no query loses live state).

Semantics vs the paper (B = micro-batch size, Q = #queries):
  * B = 1: the per-query result streams match the paper tuple-for-tuple for
    every query in the group (tested) — a tuple outside query q's alphabet
    steps q's closure with an unchanged adjacency, a no-op.
  * B > 1: results are evaluated at batch boundaries (documented skew: a
    path valid only strictly inside a batch interval is not reported).
    Additionally, with Q > 1 the batch PACKING differs from Q independent
    engines: independent engines drop out-of-alphabet tuples before filling
    a batch, while the group packs every tuple in the union alphabet — so
    batch boundaries (and hence which intra-batch paths are observable)
    can differ per query from a solo run of that query. B = 1 has no skew.
  * implicit windows, eager evaluation, lazy expiration — as in the paper.
  * closure rounds run until the SLOWEST query converges; converged queries
    relax as no-ops (monotone, so results are unaffected).
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .automaton import DFA
from .semiring import (
    NEG_INF,
    BatchedTransitionTable,
    TransitionTable,
    batched_closure,
    batched_valid_pairs,
)

Pair = Tuple[object, object]


class EngineArrays(NamedTuple):
    """Single-query view (legacy layout) — the Q=1 slice of the batched
    state, kept as the public surface of :class:`DenseRPQEngine` so sharded
    deployments can re-place individual leaves (examples/distributed_rpq)."""

    adj: jnp.ndarray      # (L, N, N) f32
    dist: jnp.ndarray     # (N, N, K) f32
    emitted: jnp.ndarray  # (N, N) bool
    now: jnp.ndarray      # () f32


class BatchedEngineArrays(NamedTuple):
    adj: jnp.ndarray      # (L, N, N) f32 shared
    dist: jnp.ndarray     # (Q, N, N, K) f32
    emitted: jnp.ndarray  # (Q, N, N) bool
    now: jnp.ndarray      # () f32


def init_arrays(n_slots: int, n_labels: int, k: int) -> EngineArrays:
    b = init_batched_arrays(n_slots, n_labels, 1, k)
    return EngineArrays(b.adj, b.dist[0], b.emitted[0], b.now)


def init_batched_arrays(
    n_slots: int, n_labels: int, n_queries: int, k: int
) -> BatchedEngineArrays:
    return BatchedEngineArrays(
        adj=jnp.full((n_labels, n_slots, n_slots), NEG_INF, jnp.float32),
        dist=jnp.full((n_queries, n_slots, n_slots, k), NEG_INF, jnp.float32),
        emitted=jnp.zeros((n_queries, n_slots, n_slots), bool),
        now=jnp.asarray(NEG_INF, jnp.float32),
    )


# ---------------------------------------------------------------------------
# jitted step functions (pure; BatchedTransitionTable & co. passed as consts)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("backend",), donate_argnums=(0,))
def _ingest(
    arrays: BatchedEngineArrays,
    src: jnp.ndarray,          # (B,) int32 slot ids
    dst: jnp.ndarray,          # (B,) int32
    lab: jnp.ndarray,          # (B,) int32 shared-alphabet label ids
    ts: jnp.ndarray,           # (B,) f32
    mask: jnp.ndarray,         # (B,) bool  (padding)
    btt: BatchedTransitionTable,
    finals_mask: jnp.ndarray,  # (Q, K) bool
    windows: jnp.ndarray,      # (Q,) f32
    backend: str = "jnp",
):
    eff_ts = jnp.where(mask, ts, NEG_INF)
    adj = arrays.adj.at[lab, src, dst].max(eff_ts, mode="drop")
    now = jnp.maximum(arrays.now, jnp.max(eff_ts))
    dist, rounds = batched_closure(arrays.dist, adj, btt, backend)
    low = now - windows
    valid = batched_valid_pairs(dist, finals_mask, low)
    new = jnp.logical_and(valid, jnp.logical_not(arrays.emitted))
    emitted = jnp.logical_or(arrays.emitted, valid)
    return BatchedEngineArrays(adj, dist, emitted, now), new, rounds


@functools.partial(jax.jit, static_argnames=("backend",), donate_argnums=(0,))
def _delete(
    arrays: BatchedEngineArrays,
    src: jnp.ndarray,          # (B,) int32
    dst: jnp.ndarray,
    lab: jnp.ndarray,
    mask: jnp.ndarray,
    ts_now: jnp.ndarray,       # () f32 event time of the negative tuple(s)
    btt: BatchedTransitionTable,
    finals_mask: jnp.ndarray,
    windows: jnp.ndarray,
    backend: str = "jnp",
):
    """Explicit deletion (negative tuple): clear adjacency entries and
    recompute every query's closure from scratch — the paper's uniform
    machinery (Delete -> ExpiryRAPQ re-derivation) in dense batched form."""
    now = jnp.maximum(arrays.now, ts_now)
    low = now - windows
    valid_before = batched_valid_pairs(arrays.dist, finals_mask, low)
    drop = jnp.where(mask, jnp.asarray(NEG_INF, jnp.float32), arrays.adj[lab, src, dst])
    adj = arrays.adj.at[lab, src, dst].set(drop, mode="drop")
    dist0 = jnp.full_like(arrays.dist, NEG_INF)
    dist, rounds = batched_closure(dist0, adj, btt, backend)
    valid_after = batched_valid_pairs(dist, finals_mask, low)
    invalidated = jnp.logical_and(valid_before, jnp.logical_not(valid_after))
    return BatchedEngineArrays(adj, dist, arrays.emitted, now), invalidated, rounds


@jax.jit
def _expire(arrays: BatchedEngineArrays, tau: jnp.ndarray, max_window: jnp.ndarray):
    """Lazy expiration at slide boundaries: mask dead adjacency entries and
    report per-slot liveness for python-side slot recycling. Thresholded at
    the group's LARGEST window (an edge live for any query stays); dist
    needs no update (stale entries fall below each query's own read-time
    validity threshold by construction)."""
    now = jnp.maximum(arrays.now, tau)
    low = now - max_window
    adj = jnp.where(arrays.adj > low, arrays.adj, NEG_INF)
    incident = jnp.maximum(
        jnp.max(adj, axis=(0, 2)),  # outgoing per u
        jnp.max(adj, axis=(0, 1)),  # incoming per v
    )
    live = incident > low
    return BatchedEngineArrays(adj, arrays.dist, arrays.emitted, now), live


@jax.jit
def _clear_slots(arrays: BatchedEngineArrays, slots: jnp.ndarray):
    """Zero out rows/cols of recycled slots (−inf / False) for ALL queries."""
    adj = arrays.adj.at[:, slots, :].set(NEG_INF, mode="drop")
    adj = adj.at[:, :, slots].set(NEG_INF, mode="drop")
    dist = arrays.dist.at[:, slots, :, :].set(NEG_INF, mode="drop")
    dist = dist.at[:, :, slots, :].set(NEG_INF, mode="drop")
    emitted = arrays.emitted.at[:, slots, :].set(False, mode="drop")
    emitted = emitted.at[:, :, slots].set(False, mode="drop")
    return BatchedEngineArrays(adj, dist, emitted, arrays.now)


@jax.jit
def _conflict_possible(
    dist: jnp.ndarray,           # (Q, N, N, K)
    not_contained: jnp.ndarray,  # (Q, K, K), 1 where [s] !>= [t]
    low: jnp.ndarray,            # (Q,)
) -> jnp.ndarray:
    """Over-approximate RSPQ conflict detection (Definition 16), per query:
    some root reaches some vertex v in states s and t with [s] ⊉ [t].
    Ancestorship is over-approximated by co-reachability (sound: never
    misses a conflict)."""
    p = (dist > low[:, None, None, None]).astype(jnp.float32)  # (Q, N, N, K)
    m = not_contained.astype(jnp.float32)
    cnt = jnp.einsum("qxvs,qst,qxvt->q", p, m, p)
    return cnt > 0


# ---------------------------------------------------------------------------
# Python orchestration: vertex interning, result decoding
# ---------------------------------------------------------------------------


class RegisteredQuery(NamedTuple):
    """One persistent query of a batched group."""

    name: str
    dfa: DFA
    window: float
    path_semantics: str = "arbitrary"  # arbitrary | simple


class BatchedDenseRPQEngine:
    """Q persistent RPQs over ONE stream, stepped as one jitted program.

    All queries share the vertex interner and the (L, N, N) adjacency over
    the union label alphabet; per-query closure state is stacked along the
    leading Q axis. Per-query ``path_semantics`` follows the single-engine
    contract: "simple" (RSPQ) uses the Mendelzon–Wood tractable class and
    flags possibly-over-reporting windows in :attr:`per_query_conflicted`.
    """

    def __init__(
        self,
        queries: Sequence[RegisteredQuery],
        n_slots: int = 128,
        batch_size: int = 32,
        backend: str = "jnp",
    ):
        if not queries:
            raise ValueError("register at least one query")
        for q in queries:
            if q.dfa.containment is None:
                raise ValueError(f"compile query {q.name!r} with compile_query()")
        self.query_specs: List[RegisteredQuery] = list(queries)
        self.n_queries = len(self.query_specs)
        self.n_slots = n_slots
        self.batch_size = batch_size
        self.backend = backend
        # shared alphabet = union over queries, sorted for determinism
        self.labels: Tuple[str, ...] = tuple(
            sorted(set().union(*[set(q.dfa.labels) for q in self.query_specs]))
        )
        self._label_index = {lab: i for i, lab in enumerate(self.labels)}
        self.btt = BatchedTransitionTable.from_dfas(
            [q.dfa for q in self.query_specs], self.labels
        )
        self.k = self.btt.k
        qn, k = self.n_queries, self.k
        fm = np.zeros((qn, k), bool)
        nc = np.zeros((qn, k, k), bool)
        self._simple = np.zeros((qn,), bool)
        self._check_conflict = np.zeros((qn,), bool)
        windows = np.zeros((qn,), np.float32)
        for qi, spec in enumerate(self.query_specs):
            dfa = spec.dfa
            for f in dfa.finals:
                fm[qi, f] = True
            nc[qi, : dfa.k, : dfa.k] = ~dfa.containment
            windows[qi] = spec.window
            self._simple[qi] = spec.path_semantics == "simple"
            self._check_conflict[qi] = (
                spec.path_semantics == "simple" and not dfa.has_containment_property
            )
        self.finals_mask = jnp.asarray(fm)
        self.not_contained = jnp.asarray(nc)
        self.windows = jnp.asarray(windows)
        self.max_window = float(windows.max())
        # label axis rounded up so alphabet-size changes reuse compiled steps
        n_label_slots = max(len(self.labels) + (-len(self.labels)) % 4, 4)
        self.batched_arrays = init_batched_arrays(n_slots, n_label_slots, qn, k)
        # vertex interning (shared across queries: the stream is one graph)
        self.slot_of: Dict[object, int] = {}
        self.vertex_of: List[Optional[object]] = [None] * n_slots
        self.free: List[int] = list(range(n_slots - 1, -1, -1))
        # per-query results
        self.per_query_results: List[Set[Pair]] = [set() for _ in range(qn)]
        self.per_query_log: List[List[Tuple[float, Pair]]] = [[] for _ in range(qn)]
        self.per_query_conflicted: List[bool] = [False] * qn
        self.total_rounds = 0
        self.steps = 0  # jitted ingest/delete dispatches (the Q-sharing win)

    # -- interning ----------------------------------------------------------

    def _slot(self, vertex: object) -> int:
        s = self.slot_of.get(vertex)
        if s is None:
            if not self.free:
                self.compact()
                if not self.free:
                    raise RuntimeError(
                        f"vertex capacity {self.n_slots} exhausted; raise n_slots"
                    )
            s = self.free.pop()
            self.slot_of[vertex] = s
            self.vertex_of[s] = vertex
        return s

    # -- public API ----------------------------------------------------------

    def insert(self, u: object, v: object, label: str, ts: float) -> List[Set[Pair]]:
        return self.insert_batch([(u, v, label, ts)])

    def insert_batch(
        self, edges: Sequence[Tuple[object, object, str, float]]
    ) -> List[Set[Pair]]:
        """Ingest a micro-batch of append sgts (timestamp-ordered). Returns
        the NEW result pairs per query (list indexed like query_specs)."""
        out: List[Set[Pair]] = [set() for _ in range(self.n_queries)]
        B = self.batch_size
        for i in range(0, len(edges), B):
            fresh = self._ingest_chunk(edges[i : i + B])
            for qi in range(self.n_queries):
                out[qi] |= fresh[qi]
        return out

    def _ingest_chunk(self, edges) -> List[Set[Pair]]:
        B = self.batch_size
        src = np.zeros((B,), np.int32)
        dst = np.zeros((B,), np.int32)
        lab = np.zeros((B,), np.int32)
        ts = np.full((B,), NEG_INF, np.float32)
        mask = np.zeros((B,), bool)
        j = 0
        for (u, v, label, t) in edges:
            li = self._label_index.get(label)
            if li is None:
                continue  # outside the union Sigma_Q: discarded (paper §5.2)
            src[j] = self._slot(u)
            dst[j] = self._slot(v)
            lab[j] = li
            ts[j] = t
            mask[j] = True
            j += 1
        if j == 0:
            # still advance the clock
            times = [t for (_u, _v, _l, t) in edges]
            if times:
                self.batched_arrays = self.batched_arrays._replace(
                    now=jnp.maximum(
                        self.batched_arrays.now,
                        jnp.asarray(max(times), jnp.float32),
                    )
                )
            return [set() for _ in range(self.n_queries)]
        self.batched_arrays, new, rounds = _ingest(
            self.batched_arrays,
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(lab),
            jnp.asarray(ts), jnp.asarray(mask),
            self.btt, self.finals_mask, self.windows,
            backend=self.backend,
        )
        self.total_rounds += int(rounds)
        self.steps += 1
        if self._check_conflict.any():
            low = self.batched_arrays.now - self.windows
            flags = np.asarray(
                _conflict_possible(self.batched_arrays.dist, self.not_contained, low)
            )
            for qi in np.nonzero(flags & self._check_conflict)[0]:
                self.per_query_conflicted[int(qi)] = True
        return self._decode_new(new)

    def delete(self, u: object, v: object, label: str, ts: float) -> List[Set[Pair]]:
        """Explicit deletion (negative tuple). Returns invalidated pairs
        per query."""
        li = self._label_index.get(label)
        if li is None or u not in self.slot_of or v not in self.slot_of:
            self.batched_arrays = self.batched_arrays._replace(
                now=jnp.maximum(self.batched_arrays.now, jnp.asarray(ts, jnp.float32))
            )
            return [set() for _ in range(self.n_queries)]
        src = jnp.asarray([self.slot_of[u]], jnp.int32)
        dst = jnp.asarray([self.slot_of[v]], jnp.int32)
        labj = jnp.asarray([li], jnp.int32)
        mask = jnp.asarray([True])
        self.batched_arrays, invalidated, rounds = _delete(
            self.batched_arrays, src, dst, labj, mask,
            jnp.asarray(ts, jnp.float32),
            self.btt, self.finals_mask, self.windows,
            backend=self.backend,
        )
        self.total_rounds += int(rounds)
        self.steps += 1
        inv = np.asarray(invalidated)
        return [
            self._decode_pairs(inv[qi], bool(self._simple[qi]))
            for qi in range(self.n_queries)
        ]

    def expire(self, tau: Optional[float] = None) -> None:
        """Slide-boundary maintenance: adjacency masking + slot recycling."""
        t = jnp.asarray(
            tau if tau is not None else float(self.batched_arrays.now), jnp.float32
        )
        self.batched_arrays, live = _expire(
            self.batched_arrays, t, jnp.asarray(self.max_window, jnp.float32)
        )
        self._recycle(np.asarray(live))

    def compact(self) -> None:
        self.expire()

    def _recycle(self, live: np.ndarray) -> None:
        dead_slots = [
            s for s, vtx in enumerate(self.vertex_of)
            if vtx is not None and not bool(live[s])
        ]
        if not dead_slots:
            return
        self.batched_arrays = _clear_slots(
            self.batched_arrays, jnp.asarray(dead_slots, jnp.int32)
        )
        for s in dead_slots:
            vtx = self.vertex_of[s]
            self.vertex_of[s] = None
            del self.slot_of[vtx]
            self.free.append(s)

    # -- result decoding ------------------------------------------------------

    def _decode_pairs(self, mat: np.ndarray, simple: bool) -> Set[Pair]:
        pairs: Set[Pair] = set()
        xs, vs = np.nonzero(mat)
        for x, v in zip(xs.tolist(), vs.tolist()):
            if simple and x == v:
                continue  # a simple path never revisits its source
            xv = self.vertex_of[x]
            vv = self.vertex_of[v]
            if xv is not None and vv is not None:
                pairs.add((xv, vv))
        return pairs

    def _decode_new(self, new: jnp.ndarray) -> List[Set[Pair]]:
        """Per-query pairs NEW to the monotone result set: after slot
        recycling the emitted matrices forget old occupants, so the device
        diff may resurface already-reported pairs — the python-side sets are
        the source of truth for implicit-window monotonicity."""
        arr = np.asarray(new)  # (Q, N, N) bool
        t = float(self.batched_arrays.now)
        fresh: List[Set[Pair]] = [set() for _ in range(self.n_queries)]
        qs, xs, vs = np.nonzero(arr)
        for q, x, v in zip(qs.tolist(), xs.tolist(), vs.tolist()):
            if self._simple[q] and x == v:
                continue
            xv = self.vertex_of[x]
            vv = self.vertex_of[v]
            if xv is None or vv is None:
                continue
            p = (xv, vv)
            if p not in self.per_query_results[q]:
                self.per_query_results[q].add(p)
                self.per_query_log[q].append((t, p))
                fresh[q].add(p)
        return fresh

    def current_results(self, qi: int = 0) -> Set[Pair]:
        """Snapshot view (explicit-window semantics) for query `qi`."""
        low = self.batched_arrays.now - self.windows
        valid = batched_valid_pairs(self.batched_arrays.dist, self.finals_mask, low)
        return self._decode_pairs(np.asarray(valid[qi]), bool(self._simple[qi]))

    def index_size(self, qi: Optional[int] = None) -> Tuple[int, int]:
        """(active roots, populated (x,v,s) entries) — Fig. 5 analogue.
        `qi=None` aggregates over the whole group."""
        low = np.asarray(self.batched_arrays.now - self.windows)  # (Q,)
        pop = np.asarray(self.batched_arrays.dist) > low[:, None, None, None]
        if qi is not None:
            pop = pop[qi : qi + 1]
        roots = int(pop.any(axis=(2, 3)).sum())
        return roots, int(pop.sum())

    # -- state persistence (checkpoint/ckpt.py rides this) --------------------

    def state_arrays(self) -> Dict[str, jnp.ndarray]:
        """The device state as one pytree (checkpointable as-is)."""
        a = self.batched_arrays
        return {"adj": a.adj, "dist": a.dist, "emitted": a.emitted, "now": a.now}

    def load_state_arrays(self, state: Dict[str, jnp.ndarray]) -> None:
        self.batched_arrays = BatchedEngineArrays(
            state["adj"], state["dist"], state["emitted"], state["now"]
        )

    def interner_state(self) -> Dict[str, int]:
        """Vertex interner as JSON-able metadata (str-keyed, like the
        checkpoint manifest)."""
        return {str(k): v for k, v in self.slot_of.items()}

    def load_interner(self, slot_of: Dict[str, int]) -> None:
        self.slot_of = {_maybe_int(k): v for k, v in slot_of.items()}
        self.vertex_of = [None] * self.n_slots
        for vtx, slot in self.slot_of.items():
            self.vertex_of[slot] = vtx
        used = set(self.slot_of.values())
        self.free = [s for s in range(self.n_slots - 1, -1, -1) if s not in used]

    def results_state(self) -> Dict[str, object]:
        return {
            "results": {
                spec.name: sorted(map(list, self.per_query_results[qi]))
                for qi, spec in enumerate(self.query_specs)
            },
            "conflicted": {
                spec.name: self.per_query_conflicted[qi]
                for qi, spec in enumerate(self.query_specs)
            },
        }

    def load_results_state(self, state: Dict[str, object]) -> None:
        for qi, spec in enumerate(self.query_specs):
            self.per_query_results[qi] = {
                tuple(p) for p in state["results"][spec.name]
            }
            self.per_query_log[qi] = []
            self.per_query_conflicted[qi] = bool(state["conflicted"][spec.name])


def _maybe_int(s: str):
    try:
        return int(s)
    except ValueError:
        return s


class DenseRPQEngine(BatchedDenseRPQEngine):
    """Streaming RPQ engine over fixed-capacity dense state — the thin Q=1
    view over the batched core (one registered query).

    path_semantics: "arbitrary" (RAPQ) or "simple" (RSPQ). Simple-path mode
    uses the Mendelzon–Wood tractable class: if the automaton has the suffix
    containment property the dense answer set is provably identical under
    both semantics (DESIGN.md §2); otherwise runtime conflict detection
    flags windows where the dense answer may over-report, and
    ``conflicted`` exposes it (the service layer falls back to the
    reference RSPQ for exactness — the paper's exponential case).
    """

    def __init__(
        self,
        dfa: DFA,
        window: float,
        n_slots: int = 128,
        batch_size: int = 32,
        backend: str = "jnp",
        path_semantics: str = "arbitrary",
    ):
        super().__init__(
            [RegisteredQuery("q0", dfa, float(window), path_semantics)],
            n_slots=n_slots, batch_size=batch_size, backend=backend,
        )
        self.dfa = dfa
        self.window = float(window)
        self.path_semantics = path_semantics
        self.tt = TransitionTable.from_dfa(dfa)  # legacy consumers (dryrun)

    # -- Q=1 adapters --------------------------------------------------------

    @property
    def arrays(self) -> EngineArrays:
        b = self.batched_arrays
        return EngineArrays(b.adj, b.dist[0], b.emitted[0], b.now)

    @arrays.setter
    def arrays(self, a: EngineArrays) -> None:
        self.batched_arrays = BatchedEngineArrays(
            a.adj, a.dist[None], a.emitted[None], a.now
        )

    @property
    def results(self) -> Set[Pair]:
        return self.per_query_results[0]

    @results.setter
    def results(self, value: Set[Pair]) -> None:
        self.per_query_results[0] = set(value)

    @property
    def result_log(self) -> List[Tuple[float, Pair]]:
        return self.per_query_log[0]

    @property
    def conflicted(self) -> bool:
        return self.per_query_conflicted[0]

    @conflicted.setter
    def conflicted(self, value: bool) -> None:
        self.per_query_conflicted[0] = bool(value)

    def insert(self, u: object, v: object, label: str, ts: float) -> Set[Pair]:
        return super().insert_batch([(u, v, label, ts)])[0]

    def insert_batch(self, edges) -> Set[Pair]:
        return super().insert_batch(edges)[0]

    def delete(self, u: object, v: object, label: str, ts: float) -> Set[Pair]:
        return super().delete(u, v, label, ts)[0]

    def current_results(self) -> Set[Pair]:
        return super().current_results(0)

    def index_size(self) -> Tuple[int, int]:
        return super().index_size(0)
