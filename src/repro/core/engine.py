"""Dense TPU-native streaming RPQ engine (the paper's technique, tensorized).

State (all fixed-capacity, jit-static shapes):
    adj     (L, N, N) f32   newest edge timestamp per (label, u, v); -inf none
    dist    (N, N, K) f32   bottleneck closure D[x, v, s] (DESIGN.md §2)
    emitted (N, N)   bool   pairs already reported (implicit-window monotone)
    now     ()       f32    latest event time seen

Key property of the (max, min) formulation (beyond-paper, §Perf): *window
expiry needs no index maintenance* — a pair is valid iff its bottleneck
timestamp exceeds ``now - |W|``, so expiry is a threshold at read time. The
paper's ExpiryRAPQ machinery is only needed for (a) explicit deletions
(closure re-computation, the paper's own uniform machinery) and (b) vertex
slot recycling (python-side compaction).

Semantics vs the paper:
  * micro-batch ingest (batch B of sgts processed per step). With B = 1 the
    result stream matches the paper tuple-for-tuple (tested); with B > 1
    results are evaluated at batch boundaries (documented skew: a path valid
    only strictly inside a batch interval is not reported).
  * implicit windows, eager evaluation, lazy expiration — as in the paper.
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .automaton import DFA
from .semiring import NEG_INF, TransitionTable, closure, relax_round, valid_pairs

Pair = Tuple[object, object]


class EngineArrays(NamedTuple):
    adj: jnp.ndarray      # (L, N, N) f32
    dist: jnp.ndarray     # (N, N, K) f32
    emitted: jnp.ndarray  # (N, N) bool
    now: jnp.ndarray      # () f32


def init_arrays(n_slots: int, n_labels: int, k: int) -> EngineArrays:
    return EngineArrays(
        adj=jnp.full((n_labels, n_slots, n_slots), NEG_INF, jnp.float32),
        dist=jnp.full((n_slots, n_slots, k), NEG_INF, jnp.float32),
        emitted=jnp.zeros((n_slots, n_slots), bool),
        now=jnp.asarray(NEG_INF, jnp.float32),
    )


# ---------------------------------------------------------------------------
# jitted step functions (pure; TransitionTable & co. passed as static/consts)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("backend",), donate_argnums=(0,))
def _ingest(
    arrays: EngineArrays,
    src: jnp.ndarray,        # (B,) int32 slot ids
    dst: jnp.ndarray,        # (B,) int32
    lab: jnp.ndarray,        # (B,) int32
    ts: jnp.ndarray,         # (B,) f32
    mask: jnp.ndarray,       # (B,) bool  (padding)
    tt: TransitionTable,
    finals_mask: jnp.ndarray,  # (K,) bool
    window: jnp.ndarray,       # () f32
    backend: str = "jnp",
):
    eff_ts = jnp.where(mask, ts, NEG_INF)
    adj = arrays.adj.at[lab, src, dst].max(eff_ts, mode="drop")
    now = jnp.maximum(arrays.now, jnp.max(eff_ts))
    dist, rounds = closure(arrays.dist, adj, tt, backend)
    low = now - window
    valid = valid_pairs(dist, finals_mask, low)
    new = jnp.logical_and(valid, jnp.logical_not(arrays.emitted))
    emitted = jnp.logical_or(arrays.emitted, valid)
    return EngineArrays(adj, dist, emitted, now), new, rounds


@functools.partial(jax.jit, static_argnames=("backend",), donate_argnums=(0,))
def _delete(
    arrays: EngineArrays,
    src: jnp.ndarray,        # (B,) int32
    dst: jnp.ndarray,
    lab: jnp.ndarray,
    mask: jnp.ndarray,
    ts_now: jnp.ndarray,     # () f32 event time of the negative tuple(s)
    tt: TransitionTable,
    finals_mask: jnp.ndarray,
    window: jnp.ndarray,
    backend: str = "jnp",
):
    """Explicit deletion (negative tuple): clear adjacency entries and
    recompute the closure from scratch — the paper's uniform machinery
    (Delete -> ExpiryRAPQ re-derivation) in dense form."""
    now = jnp.maximum(arrays.now, ts_now)
    low = now - window
    valid_before = valid_pairs(arrays.dist, finals_mask, low)
    drop = jnp.where(mask, jnp.asarray(NEG_INF, jnp.float32), arrays.adj[lab, src, dst])
    adj = arrays.adj.at[lab, src, dst].set(drop, mode="drop")
    dist0 = jnp.full_like(arrays.dist, NEG_INF)
    dist, rounds = closure(dist0, adj, tt, backend)
    valid_after = valid_pairs(dist, finals_mask, low)
    invalidated = jnp.logical_and(valid_before, jnp.logical_not(valid_after))
    return EngineArrays(adj, dist, arrays.emitted, now), invalidated, rounds


@jax.jit
def _expire(arrays: EngineArrays, tau: jnp.ndarray, window: jnp.ndarray):
    """Lazy expiration at slide boundaries: mask dead adjacency entries and
    report per-slot liveness for python-side slot recycling. dist needs no
    update (stale entries are below the validity threshold by construction)."""
    now = jnp.maximum(arrays.now, tau)
    low = now - window
    adj = jnp.where(arrays.adj > low, arrays.adj, NEG_INF)
    incident = jnp.maximum(
        jnp.max(adj, axis=(0, 2)),  # outgoing per u
        jnp.max(adj, axis=(0, 1)),  # incoming per v
    )
    live = incident > low
    return EngineArrays(adj, arrays.dist, arrays.emitted, now), live


@jax.jit
def _clear_slots(arrays: EngineArrays, slots: jnp.ndarray):
    """Zero out rows/cols of recycled slots (−inf / False)."""
    adj = arrays.adj.at[:, slots, :].set(NEG_INF, mode="drop")
    adj = adj.at[:, :, slots].set(NEG_INF, mode="drop")
    dist = arrays.dist.at[slots, :, :].set(NEG_INF, mode="drop")
    dist = dist.at[:, slots, :].set(NEG_INF, mode="drop")
    emitted = arrays.emitted.at[slots, :].set(False, mode="drop")
    emitted = emitted.at[:, slots].set(False, mode="drop")
    return EngineArrays(adj, dist, emitted, arrays.now)


@jax.jit
def _conflict_possible(
    dist: jnp.ndarray, not_contained: jnp.ndarray, low: jnp.ndarray
) -> jnp.ndarray:
    """Over-approximate RSPQ conflict detection (Definition 16): some root
    reaches some vertex v in states s and t with [s] ⊉ [t]. Ancestorship is
    over-approximated by co-reachability (sound: never misses a conflict)."""
    p = (dist > low).astype(jnp.float32)  # (N, N, K)
    m = not_contained.astype(jnp.float32)  # (K, K), 1 where [s] !>= [t]
    cnt = jnp.einsum("xvs,st,xvt->", p, m, p)
    return cnt > 0


# ---------------------------------------------------------------------------
# Python orchestration: vertex interning, result decoding
# ---------------------------------------------------------------------------


class DenseRPQEngine:
    """Streaming RPQ engine over fixed-capacity dense state.

    path_semantics: "arbitrary" (RAPQ) or "simple" (RSPQ). Simple-path mode
    uses the Mendelzon–Wood tractable class: if the automaton has the suffix
    containment property the dense answer set is provably identical under
    both semantics (DESIGN.md §2); otherwise runtime conflict detection
    flags windows where the dense answer may over-report, and
    ``conflicted`` exposes it (the service layer falls back to the
    reference RSPQ for exactness — the paper's exponential case).
    """

    def __init__(
        self,
        dfa: DFA,
        window: float,
        n_slots: int = 128,
        batch_size: int = 32,
        backend: str = "jnp",
        path_semantics: str = "arbitrary",
    ):
        if dfa.containment is None:
            raise ValueError("compile the query with compile_query()")
        self.dfa = dfa
        self.window = float(window)
        self.n_slots = n_slots
        self.batch_size = batch_size
        self.backend = backend
        self.path_semantics = path_semantics
        self.tt = TransitionTable.from_dfa(dfa)
        fm = np.zeros((dfa.k,), bool)
        for f in dfa.finals:
            fm[f] = True
        self.finals_mask = jnp.asarray(fm)
        self.not_contained = jnp.asarray(~dfa.containment)
        self.arrays = init_arrays(n_slots, dfa.n_labels, dfa.k)
        # vertex interning
        self.slot_of: Dict[object, int] = {}
        self.vertex_of: List[Optional[object]] = [None] * n_slots
        self.free: List[int] = list(range(n_slots - 1, -1, -1))
        # results
        self.results: Set[Pair] = set()
        self.result_log: List[Tuple[float, Pair]] = []
        self.conflicted = False
        self.total_rounds = 0
        self.steps = 0

    # -- interning ----------------------------------------------------------

    def _slot(self, vertex: object) -> int:
        s = self.slot_of.get(vertex)
        if s is None:
            if not self.free:
                self.compact()
                if not self.free:
                    raise RuntimeError(
                        f"vertex capacity {self.n_slots} exhausted; raise n_slots"
                    )
            s = self.free.pop()
            self.slot_of[vertex] = s
            self.vertex_of[s] = vertex
        return s

    # -- public API ----------------------------------------------------------

    def insert(self, u: object, v: object, label: str, ts: float) -> Set[Pair]:
        return self.insert_batch([(u, v, label, ts)])

    def insert_batch(self, edges: Sequence[Tuple[object, object, str, float]]) -> Set[Pair]:
        """Ingest a micro-batch of append sgts (timestamp-ordered)."""
        out: Set[Pair] = set()
        B = self.batch_size
        for i in range(0, len(edges), B):
            out |= self._ingest_chunk(edges[i : i + B])
        return out

    def _ingest_chunk(self, edges) -> Set[Pair]:
        B = self.batch_size
        src = np.zeros((B,), np.int32)
        dst = np.zeros((B,), np.int32)
        lab = np.zeros((B,), np.int32)
        ts = np.full((B,), NEG_INF, np.float32)
        mask = np.zeros((B,), bool)
        j = 0
        for (u, v, label, t) in edges:
            if label not in self.dfa.labels:
                continue  # outside Sigma_Q: discarded (paper §5.2)
            src[j] = self._slot(u)
            dst[j] = self._slot(v)
            lab[j] = self.dfa.labels.index(label)
            ts[j] = t
            mask[j] = True
            j += 1
        if j == 0:
            # still advance the clock
            times = [t for (_u, _v, _l, t) in edges]
            if times:
                self.arrays = self.arrays._replace(
                    now=jnp.maximum(self.arrays.now, jnp.asarray(max(times), jnp.float32))
                )
            return set()
        self.arrays, new, rounds = _ingest(
            self.arrays,
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(lab),
            jnp.asarray(ts), jnp.asarray(mask),
            self.tt, self.finals_mask,
            jnp.asarray(self.window, jnp.float32),
            backend=self.backend,
        )
        self.total_rounds += int(rounds)
        self.steps += 1
        if self.path_semantics == "simple" and not self.dfa.has_containment_property:
            low = self.arrays.now - self.window
            if bool(_conflict_possible(self.arrays.dist, self.not_contained, low)):
                self.conflicted = True
        return self._decode_new(new)

    def delete(self, u: object, v: object, label: str, ts: float) -> Set[Pair]:
        """Explicit deletion (negative tuple). Returns invalidated pairs."""
        if label not in self.dfa.labels or u not in self.slot_of or v not in self.slot_of:
            self.arrays = self.arrays._replace(
                now=jnp.maximum(self.arrays.now, jnp.asarray(ts, jnp.float32))
            )
            return set()
        B = 1
        src = jnp.asarray([self.slot_of[u]], jnp.int32)
        dst = jnp.asarray([self.slot_of[v]], jnp.int32)
        lab = jnp.asarray([self.dfa.labels.index(label)], jnp.int32)
        mask = jnp.asarray([True])
        self.arrays, invalidated, rounds = _delete(
            self.arrays, src, dst, lab, mask,
            jnp.asarray(ts, jnp.float32),
            self.tt, self.finals_mask,
            jnp.asarray(self.window, jnp.float32),
            backend=self.backend,
        )
        self.total_rounds += int(rounds)
        return self._decode_pairs(np.asarray(invalidated))

    def expire(self, tau: Optional[float] = None) -> None:
        """Slide-boundary maintenance: adjacency masking + slot recycling."""
        t = jnp.asarray(tau if tau is not None else float(self.arrays.now), jnp.float32)
        self.arrays, live = _expire(self.arrays, t, jnp.asarray(self.window, jnp.float32))
        self._recycle(np.asarray(live))

    def compact(self) -> None:
        self.expire()

    def _recycle(self, live: np.ndarray) -> None:
        dead_slots = [
            s for s, vtx in enumerate(self.vertex_of)
            if vtx is not None and not bool(live[s])
        ]
        if not dead_slots:
            return
        self.arrays = _clear_slots(self.arrays, jnp.asarray(dead_slots, jnp.int32))
        for s in dead_slots:
            vtx = self.vertex_of[s]
            self.vertex_of[s] = None
            del self.slot_of[vtx]
            self.free.append(s)

    # -- result decoding ------------------------------------------------------

    def _decode_pairs(self, mat: np.ndarray) -> Set[Pair]:
        pairs: Set[Pair] = set()
        xs, vs = np.nonzero(mat)
        simple = self.path_semantics == "simple"
        for x, v in zip(xs.tolist(), vs.tolist()):
            if simple and x == v:
                continue  # a simple path never revisits its source
            xv = self.vertex_of[x]
            vv = self.vertex_of[v]
            if xv is not None and vv is not None:
                pairs.add((xv, vv))
        return pairs

    def _decode_new(self, new: jnp.ndarray) -> Set[Pair]:
        """Returns only pairs NEW to the monotone result set: after slot
        recycling the emitted matrix forgets old occupants, so the device
        diff may resurface already-reported pairs — the python-side set is
        the source of truth for implicit-window monotonicity."""
        pairs = self._decode_pairs(np.asarray(new))
        t = float(self.arrays.now)
        fresh: Set[Pair] = set()
        for p in pairs:
            if p not in self.results:
                self.results.add(p)
                self.result_log.append((t, p))
                fresh.add(p)
        return fresh

    def current_results(self) -> Set[Pair]:
        """Snapshot view (explicit-window semantics): currently valid pairs."""
        low = self.arrays.now - self.window
        valid = valid_pairs(self.arrays.dist, self.finals_mask, low)
        return self._decode_pairs(np.asarray(valid))

    def index_size(self) -> Tuple[int, int]:
        """(active roots, populated (x,v,s) entries) — Fig. 5 analogue."""
        low = self.arrays.now - self.window
        pop = np.asarray(self.arrays.dist > low)
        roots = int((pop.any(axis=(1, 2))).sum())
        return roots, int(pop.sum())
