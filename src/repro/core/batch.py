"""Batch (non-incremental) RPQ evaluation baselines.

Two roles:

* the *oracle* for property tests (product-graph BFS for arbitrary
  semantics; exhaustive simple-path DFS for simple-path semantics), and
* the §5.6 comparison point: the paper emulates persistent evaluation on
  Virtuoso by re-running the batch algorithm on the window content after
  every update; ``benchmarks/fig11_vs_batch.py`` does the same against the
  incremental engines.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, List, Set, Tuple

from .automaton import DFA
from .reference import SnapshotGraph

Pair = Tuple[object, object]
Edge = Tuple[object, object, str, float]  # (u, v, label, ts)


def snapshot_from_edges(edges: Iterable[Edge], low: float = float("-inf"),
                        high: float = float("inf")) -> SnapshotGraph:
    """Window content: edges with ts in (low, high]."""
    g = SnapshotGraph()
    for (u, v, label, ts) in edges:
        if low < ts <= high:
            g.upsert(u, v, label, ts)
    return g


def batch_rapq(graph: SnapshotGraph, dfa: DFA) -> Set[Pair]:
    """Batch RPQ under arbitrary path semantics: BFS of the product graph
    from every (x, s0) (paper §3, 'Batch Algorithm'). O(n·m·k^2)."""
    results: Set[Pair] = set()
    vertices = graph.vertices()
    for x in vertices:
        seen: Set[Tuple[object, int]] = {(x, dfa.start)}
        queue: deque = deque([(x, dfa.start)])
        while queue:
            u, s = queue.popleft()
            for v, label, _ts in graph.out_edges(u):
                t = dfa.step(s, label)
                if t < 0:
                    continue
                # report on every traversal (length >= 1) so genuine cycles
                # back to (x, s0) with s0 final yield (x, x); empty paths
                # are never reported (matches the streaming algorithms)
                if t in dfa.finals:
                    results.add((x, v))
                if (v, t) in seen:
                    continue
                seen.add((v, t))
                queue.append((v, t))
    return results


def batch_rspq_bruteforce(graph: SnapshotGraph, dfa: DFA,
                          max_nodes: int = 200_000) -> Set[Pair]:
    """Exhaustive simple-path enumeration over the product graph (exponential;
    small graphs only). The ground truth for simple-path semantics."""
    results: Set[Pair] = set()
    budget = [max_nodes]

    def dfs(x: object, u: object, s: int, visited: Set[object]) -> None:
        budget[0] -= 1
        if budget[0] < 0:
            raise RuntimeError("bruteforce budget exhausted")
        for v, label, _ts in graph.out_edges(u):
            if v in visited:
                continue
            t = dfa.step(s, label)
            if t < 0:
                continue
            if t in dfa.finals:
                results.add((x, v))
            visited.add(v)
            dfs(x, v, t, visited)
            visited.discard(v)

    for x in graph.vertices():
        dfs(x, x, dfa.start, {x})
    return results


def streaming_oracle(edges: List[Edge], dfa: DFA, window: float,
                     simple: bool = False) -> Set[Pair]:
    """Implicit-window streaming result set via repeated batch evaluation:
    Q(S, W, tau) = union over arrival times of the snapshot results
    (Definition 9). Quadratic in stream length — test oracle only."""
    out: Set[Pair] = set()
    for i, (_u, _v, _label, ts) in enumerate(edges):
        snap = snapshot_from_edges(edges[: i + 1], low=ts - window, high=ts)
        if simple:
            out |= batch_rspq_bruteforce(snap, dfa)
        else:
            out |= batch_rapq(snap, dfa)
    return out
