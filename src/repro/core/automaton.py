"""Query-registration machinery: regex -> NFA -> minimal DFA (+ RSPQ metadata).

Pipeline (paper §2): Thompson's construction builds an NFA for ``L(R)``;
subset construction determinizes; Hopcroft's algorithm minimizes. For RSPQ
(§4) we additionally compute, per DFA state, the *suffix language* containment
relation ``C[s, t] = ([s] ⊇ [t])`` (Definition 14/15) used for conflict
detection (Definition 16), and decide whether the automaton itself has the
suffix-language containment property (which implies conflict-freedom on every
graph, the tractable Mendelzon–Wood class).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

from . import regex as rx


# ---------------------------------------------------------------------------
# Thompson NFA
# ---------------------------------------------------------------------------

EPS = None  # epsilon transition marker


@dataclasses.dataclass
class NFA:
    n_states: int
    start: int
    accept: int
    # transitions: list of (src, label-or-None, dst)
    edges: List[Tuple[int, Optional[str], int]]


def thompson(node: rx.Node) -> NFA:
    """Thompson's construction [65]: one start, one accept, eps-transitions."""
    counter = itertools.count()
    edges: List[Tuple[int, Optional[str], int]] = []

    def fresh() -> int:
        return next(counter)

    def build(n: rx.Node) -> Tuple[int, int]:
        if isinstance(n, rx.Eps):
            s, t = fresh(), fresh()
            edges.append((s, EPS, t))
            return s, t
        if isinstance(n, rx.Sym):
            s, t = fresh(), fresh()
            edges.append((s, n.label, t))
            return s, t
        if isinstance(n, rx.Cat):
            ls, lt = build(n.left)
            rs, rt = build(n.right)
            edges.append((lt, EPS, rs))
            return ls, rt
        if isinstance(n, rx.Alt):
            ls, lt = build(n.left)
            rs, rt = build(n.right)
            s, t = fresh(), fresh()
            edges.extend([(s, EPS, ls), (s, EPS, rs), (lt, EPS, t), (rt, EPS, t)])
            return s, t
        if isinstance(n, rx.Star):
            is_, it = build(n.inner)
            s, t = fresh(), fresh()
            edges.extend([(s, EPS, is_), (it, EPS, t), (s, EPS, t), (it, EPS, is_)])
            return s, t
        if isinstance(n, rx.Plus):
            is_, it = build(n.inner)
            s, t = fresh(), fresh()
            edges.extend([(s, EPS, is_), (it, EPS, t), (it, EPS, is_)])
            return s, t
        if isinstance(n, rx.Opt):
            is_, it = build(n.inner)
            s, t = fresh(), fresh()
            edges.extend([(s, EPS, is_), (it, EPS, t), (s, EPS, t)])
            return s, t
        raise TypeError(f"unknown node {n!r}")

    start, accept = build(node)
    return NFA(n_states=next(counter), start=start, accept=accept, edges=edges)


# ---------------------------------------------------------------------------
# Subset construction + Hopcroft minimization
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DFA:
    """Deterministic finite automaton over the query's label alphabet.

    ``delta`` is a dense ``(k, L)`` int array; ``-1`` encodes "no transition"
    (we keep a partial DFA: the dead state is implicit, which keeps the
    product graph small — the paper's traversal likewise never materializes
    dead product nodes).
    """

    labels: Tuple[str, ...]              # alphabet Sigma_Q, sorted
    delta: np.ndarray                    # (k, L) int32, -1 = undefined
    start: int                           # s0 (always 0 after canonicalization)
    finals: FrozenSet[int]               # F
    # RSPQ metadata (filled by `with_rspq_metadata`):
    containment: Optional[np.ndarray] = None  # (k, k) bool: [s] ⊇ [t]
    has_containment_property: Optional[bool] = None

    @property
    def k(self) -> int:
        return int(self.delta.shape[0])

    @property
    def n_labels(self) -> int:
        return int(self.delta.shape[1])

    def label_index(self, label: str) -> int:
        return self.labels.index(label)

    def step(self, state: int, label: str) -> int:
        """delta(s, a); -1 when undefined (dead)."""
        if label not in self.labels:
            return -1
        return int(self.delta[state, self.labels.index(label)])

    def accepts(self, word: Sequence[str]) -> bool:
        s = self.start
        for a in word:
            s = self.step(s, a)
            if s < 0:
                return False
        return s in self.finals

    def accepts_empty(self) -> bool:
        return self.start in self.finals

    def transitions(self) -> List[Tuple[int, int, int]]:
        """All defined transitions as (s, label_idx, t)."""
        out = []
        for s in range(self.k):
            for li in range(self.n_labels):
                t = int(self.delta[s, li])
                if t >= 0:
                    out.append((s, li, t))
        return out


def _eps_closure(states: Set[int], eps_adj: Dict[int, List[int]]) -> FrozenSet[int]:
    stack = list(states)
    seen = set(states)
    while stack:
        s = stack.pop()
        for t in eps_adj.get(s, ()):  # epsilon edges
            if t not in seen:
                seen.add(t)
                stack.append(t)
    return frozenset(seen)


def determinize(nfa: NFA, labels: Sequence[str]) -> DFA:
    labels = tuple(sorted(labels))
    eps_adj: Dict[int, List[int]] = {}
    lab_adj: Dict[Tuple[int, str], List[int]] = {}
    for s, a, t in nfa.edges:
        if a is EPS:
            eps_adj.setdefault(s, []).append(t)
        else:
            lab_adj.setdefault((s, a), []).append(t)

    start = _eps_closure({nfa.start}, eps_adj)
    index: Dict[FrozenSet[int], int] = {start: 0}
    order: List[FrozenSet[int]] = [start]
    delta_rows: List[List[int]] = []
    i = 0
    while i < len(order):
        cur = order[i]
        row = []
        for a in labels:
            nxt: Set[int] = set()
            for s in cur:
                nxt.update(lab_adj.get((s, a), ()))
            if not nxt:
                row.append(-1)
            else:
                closed = _eps_closure(nxt, eps_adj)
                if closed not in index:
                    index[closed] = len(order)
                    order.append(closed)
                row.append(index[closed])
        delta_rows.append(row)
        i += 1

    finals = frozenset(i for i, ss in enumerate(order) if nfa.accept in ss)
    delta = np.asarray(delta_rows, dtype=np.int32).reshape(len(order), len(labels))
    return DFA(labels=labels, delta=delta, start=0, finals=finals)


def _reachable(delta: np.ndarray, start: int) -> Set[int]:
    k, L = delta.shape
    seen = {start}
    stack = [start]
    while stack:
        s = stack.pop()
        for li in range(L):
            t = int(delta[s, li])
            if t >= 0 and t not in seen:
                seen.add(t)
                stack.append(t)
    return seen


def _coreachable(delta: np.ndarray, finals: FrozenSet[int]) -> Set[int]:
    k, L = delta.shape
    rev: Dict[int, Set[int]] = {}
    for s in range(k):
        for li in range(L):
            t = int(delta[s, li])
            if t >= 0:
                rev.setdefault(t, set()).add(s)
    seen = set(finals)
    stack = list(finals)
    while stack:
        s = stack.pop()
        for p in rev.get(s, ()):  # predecessors
            if p not in seen:
                seen.add(p)
                stack.append(p)
    return seen


def _trim(dfa: DFA) -> DFA:
    """Remove states not on a path start -> final (keeps the DFA partial)."""
    useful = _reachable(dfa.delta, dfa.start) & _coreachable(dfa.delta, dfa.finals)
    if not useful:
        # empty language: single non-final start state, no transitions
        return DFA(
            labels=dfa.labels,
            delta=np.full((1, dfa.n_labels), -1, dtype=np.int32),
            start=0,
            finals=frozenset(),
        )
    remap = {s: i for i, s in enumerate(sorted(useful, key=lambda s: (s != dfa.start, s)))}
    k = len(remap)
    delta = np.full((k, dfa.n_labels), -1, dtype=np.int32)
    for s, li, t in dfa.transitions():
        if s in remap and t in remap:
            delta[remap[s], li] = remap[t]
    finals = frozenset(remap[s] for s in dfa.finals if s in remap)
    return DFA(labels=dfa.labels, delta=delta, start=remap[dfa.start], finals=finals)


def hopcroft_minimize(dfa: DFA) -> DFA:
    """Hopcroft's O(k log k) DFA minimization [41] on the completed DFA,
    then re-trim to a partial DFA."""
    # Complete the DFA with an explicit dead state so Hopcroft applies.
    k = dfa.k
    L = dfa.n_labels
    dead = k
    delta = np.full((k + 1, L), dead, dtype=np.int32)
    delta[:k] = np.where(dfa.delta >= 0, dfa.delta, dead)
    finals = set(dfa.finals)

    # Initial partition: finals / non-finals.
    P: List[Set[int]] = []
    f = set(finals)
    nf = set(range(k + 1)) - f
    if f:
        P.append(f)
    if nf:
        P.append(nf)
    W: List[Set[int]] = [set(min(P, key=len))] if len(P) > 1 else list(map(set, P))

    # Precompute inverse transitions.
    inv: List[Dict[int, Set[int]]] = [dict() for _ in range(L)]
    for s in range(k + 1):
        for li in range(L):
            inv[li].setdefault(int(delta[s, li]), set()).add(s)

    while W:
        A = W.pop()
        for li in range(L):
            X = set()
            for t in A:
                X |= inv[li].get(t, set())
            if not X:
                continue
            newP: List[Set[int]] = []
            for Y in P:
                inter = Y & X
                diff = Y - X
                if inter and diff:
                    newP.extend([inter, diff])
                    if Y in W:
                        W.remove(Y)
                        W.extend([inter, diff])
                    else:
                        W.append(min(inter, diff, key=len))
                else:
                    newP.append(Y)
            P = newP

    block_of = {}
    for bi, block in enumerate(P):
        for s in block:
            block_of[s] = bi
    kk = len(P)
    mdelta = np.full((kk, L), -1, dtype=np.int32)
    for bi, block in enumerate(P):
        rep = next(iter(block))
        for li in range(L):
            mdelta[bi, li] = block_of[int(delta[rep, li])]
    mstart = block_of[dfa.start]
    mfinals = frozenset(block_of[s] for s in finals)
    merged = DFA(labels=dfa.labels, delta=mdelta, start=mstart, finals=mfinals)
    trimmed = _trim(merged)
    # Canonicalize state order by BFS from start for determinism.
    return _canonicalize(trimmed)


def _canonicalize(dfa: DFA) -> DFA:
    order: List[int] = [dfa.start]
    seen = {dfa.start}
    i = 0
    while i < len(order):
        s = order[i]
        for li in range(dfa.n_labels):
            t = int(dfa.delta[s, li])
            if t >= 0 and t not in seen:
                seen.add(t)
                order.append(t)
        i += 1
    # unreachable-from-start states were already trimmed
    remap = {s: i for i, s in enumerate(order)}
    k = len(order)
    delta = np.full((k, dfa.n_labels), -1, dtype=np.int32)
    for s, li, t in dfa.transitions():
        delta[remap[s], li] = remap[t]
    return DFA(
        labels=dfa.labels,
        delta=delta,
        start=0,
        finals=frozenset(remap[s] for s in dfa.finals),
        containment=None,
        has_containment_property=None,
    )


# ---------------------------------------------------------------------------
# RSPQ metadata: suffix languages & containment (Definitions 14-16)
# ---------------------------------------------------------------------------


def suffix_containment(dfa: DFA) -> np.ndarray:
    """C[s, t] = True iff [s] ⊇ [t] (suffix language of s contains that of t).

    [s] ⊇ [t]  ⟺  L(A; start=t) ⊆ L(A; start=s). Decided by the standard
    product construction: explore pairs (p, q) from (t, s); a witness word in
    [t] \\ [s] exists iff some reachable pair has p final and q non-final
    (or q dead). Partial-DFA convention: a dead q rejects everything.
    """
    k, L = dfa.delta.shape
    C = np.zeros((k, k), dtype=bool)
    for s in range(k):
        for t in range(k):
            C[s, t] = _subset_of(dfa, t, s)
    return C


def _subset_of(dfa: DFA, t: int, s: int) -> bool:
    """True iff L(start=t) ⊆ L(start=s)."""
    k, L = dfa.delta.shape
    DEAD = -1
    start = (t, s)
    seen = {start}
    stack = [start]
    finals = dfa.finals
    while stack:
        p, q = stack.pop()
        if p in finals and (q == DEAD or q not in finals):
            return False
        for li in range(L):
            pn = int(dfa.delta[p, li]) if p != DEAD else DEAD
            if pn == DEAD:
                continue  # word leaves L(t): no containment obligation
            qn = int(dfa.delta[q, li]) if q != DEAD else DEAD
            nxt = (pn, qn)
            if nxt not in seen:
                seen.add(nxt)
                stack.append(nxt)
    return True


def containment_property(dfa: DFA, C: np.ndarray) -> bool:
    """Definition 15: for every pair (s, t) on a path s0 -> final with t a
    successor of s (t reachable from s by >=1 transition), require [s] ⊇ [t].

    After `_trim`, every state is on a start->final path, so we only need
    reachability between states.
    """
    k = dfa.k
    # successor relation: t reachable from s via >= 1 transitions
    reach = np.zeros((k, k), dtype=bool)
    for s, _, t in dfa.transitions():
        reach[s, t] = True
    # transitive closure (k is tiny)
    for m in range(k):
        reach = reach | (reach[:, m : m + 1] & reach[m : m + 1, :])
    for s in range(k):
        for t in range(k):
            if reach[s, t] and not C[s, t]:
                return False
    return True


def with_rspq_metadata(dfa: DFA) -> DFA:
    C = suffix_containment(dfa)
    prop = containment_property(dfa, C)
    return dataclasses.replace(dfa, containment=C, has_containment_property=prop)


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------


def compile_query(expr: str, extra_labels: Sequence[str] = ()) -> DFA:
    """Compile an RPQ regex into a minimal DFA with RSPQ metadata.

    ``extra_labels`` lets callers widen the alphabet (e.g. to a shared graph
    alphabet) without changing the language.
    """
    ast = rx.parse(expr)
    labels = sorted(ast.labels() | set(extra_labels))
    nfa = thompson(ast)
    dfa = determinize(nfa, labels)
    dfa = hopcroft_minimize(dfa)
    return with_rspq_metadata(dfa)
