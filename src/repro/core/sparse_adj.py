"""Blocked-sparse adjacency: padded ELL rows plus a replicated spill ring.

The dense engine stores the shared graph as a ``(L, N, N)`` timestamp
slab — O(N^2) memory per label and an O(N^2 K) frontier-seed term that
caps N at tens of thousands (docs/architecture.md, "Per-event cost
model").  This module is the sparse alternative: per ``(label, u)`` row
we keep at most ``ell_cap`` destination slots (``idx``/``ts`` pairs,
ELLPACK layout), where ``ell_cap`` is a power-of-2 degree capacity
bucketed exactly like the Q/F capacities so jit compile caches are
reused across graphs (`ell_cap` only ever doubles — see
``Executor._maybe_grow_ell``).

Rows can overflow.  Overflow never loses an edge and never aborts the
dispatch: the insert scatters the surplus edge into a small replicated
*spill ring* (``spill_src/dst/lab/ts`` + append cursor ``spill_ptr``)
inside the same jitted step.  The host keeps a conservative budget of
how many inserts could have spilled since the last drain and re-packs
(growing ``ell_cap`` x2) before the ring can wrap, so the ELL layout is
bit-identical to the dense slab at every event — the contract
docs/invariants.md records as "bit-identical spill".

Free slots hold ``ts == NEG_INF`` (or the backend ``zero`` after a
bucket encode, which maps NEG_INF to level 0); their ``idx`` may be
stale, which is benign everywhere: contraction and densify fold with
``max`` so a zero-valued candidate is a no-op, deletes clear every
matching copy, expiry thresholds each copy independently.  For the same
reason an edge duplicated between a row slot and the ring (possible
after churn) never changes a result.

Everything here except ``pack_ell`` (host-side, numpy) is traceable and
runs inside the executor's jitted step functions.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

NEG_INF = float("-inf")


class EllAdjacency(NamedTuple):
    """Padded-ELL adjacency + spill ring (a pytree; jit-transparent).

    ``ts`` dtype is float32 in executor state; inside a bucket-backend
    closure ``prepare_state`` swaps in int32 level codes (same shapes).
    """

    idx: jax.Array        # (L, N, E) int32 — destination vertex per slot
    ts: jax.Array         # (L, N, E)       — edge timestamp; zero = free
    spill_src: jax.Array  # (S,) int32
    spill_dst: jax.Array  # (S,) int32
    spill_lab: jax.Array  # (S,) int32
    spill_ts: jax.Array   # (S,)            — zero = free ring entry
    spill_ptr: jax.Array  # ()   int32 — append cursor; host budget keeps < S

    @property
    def n_labels(self) -> int:
        return self.idx.shape[0]

    @property
    def n_slots(self) -> int:
        return self.idx.shape[1]

    @property
    def ell_cap(self) -> int:
        return self.idx.shape[2]

    @property
    def spill_cap(self) -> int:
        return self.spill_src.shape[0]


def ell_empty_np(n_labels: int, n_slots: int, ell_cap: int,
                 spill_cap: int) -> EllAdjacency:
    """Host-side empty ELL state (mirrors ``Executor.init_state``)."""
    return EllAdjacency(
        idx=np.zeros((n_labels, n_slots, ell_cap), np.int32),
        ts=np.full((n_labels, n_slots, ell_cap), NEG_INF, np.float32),
        spill_src=np.zeros((spill_cap,), np.int32),
        spill_dst=np.zeros((spill_cap,), np.int32),
        spill_lab=np.zeros((spill_cap,), np.int32),
        spill_ts=np.full((spill_cap,), NEG_INF, np.float32),
        spill_ptr=np.zeros((), np.int32),
    )


def pack_ell(dense: np.ndarray, ell_cap: int, spill_cap: int) -> EllAdjacency:
    """Host-side pack of a dense ``(L, N, N)`` slab into ELL rows.

    The caller sizes ``ell_cap`` to at least the max live out-degree
    (``Executor.place`` grows it x2 until it fits), so a pack never
    needs the ring; raising instead of silently spilling keeps the
    repack→drain invariant auditable.
    """
    dense = np.asarray(dense, np.float32)
    n_labels, n_slots, _ = dense.shape
    out = ell_empty_np(n_labels, n_slots, ell_cap, spill_cap)
    live = dense > NEG_INF
    l, u, v = np.nonzero(live)
    if l.size:
        deg = live.sum(-1).reshape(-1)
        row_start = np.zeros(n_labels * n_slots + 1, np.int64)
        np.cumsum(deg, out=row_start[1:])
        flat = l.astype(np.int64) * n_slots + u
        pos = np.arange(l.size, dtype=np.int64) - row_start[flat]
        if pos.max() >= ell_cap:
            raise ValueError(
                f"pack_ell: max out-degree {int(pos.max()) + 1} exceeds "
                f"ell_cap={ell_cap}; grow the capacity before packing")
        out.idx[l, u, pos] = v
        out.ts[l, u, pos] = dense[l, u, v]
    return out


def ell_to_dense(ell: EllAdjacency, zero: float = NEG_INF) -> jax.Array:
    """Densify to the canonical ``(L, N, N)`` slab (traceable).

    Exact inverse of ``pack_ell`` up to slot order: max-folding makes
    free slots (``ts == zero``) and duplicated edges no-ops.
    """
    n_labels, n_slots, _ = ell.idx.shape
    dense = jnp.full((n_labels, n_slots, n_slots), zero, ell.ts.dtype)
    dense = dense.at[jnp.arange(n_labels)[:, None, None],
                     jnp.arange(n_slots)[None, :, None],
                     ell.idx].max(ell.ts)
    return dense.at[ell.spill_lab, ell.spill_src,
                    ell.spill_dst].max(ell.spill_ts)


def ell_insert(ell: EllAdjacency, src: jax.Array, dst: jax.Array,
               lab: jax.Array, ts: jax.Array, mask: jax.Array) -> EllAdjacency:
    """Jitted batch insert: per event, max into an existing slot for
    ``(lab, src, dst)``, else claim a free slot, else spill to the ring
    (merge if the triple is already ringed, append otherwise).

    Appends write with ``mode="drop"`` past the ring end — the host
    spill budget guarantees ``spill_ptr < spill_cap`` between drains, so
    the drop leg is unreachable in a budget-honouring executor.
    """
    e_cap = ell.ell_cap
    s_cap = ell.spill_cap

    def body(i, cur):
        u, v, l, t, m = src[i], dst[i], lab[i], ts[i], mask[i]
        row_ts = cur.ts[l, u]
        row_hit = (cur.idx[l, u] == v) & (row_ts > NEG_INF)
        row_free = row_ts == NEG_INF
        has_hit = jnp.any(row_hit)
        has_free = jnp.any(row_free)
        use_row = m & (has_hit | has_free)
        slot = jnp.where(has_hit, jnp.argmax(row_hit), jnp.argmax(row_free))
        slot = jnp.where(use_row, slot, e_cap)
        idx2 = cur.idx.at[l, u, slot].set(v, mode="drop")
        ts2 = cur.ts.at[l, u, slot].max(t, mode="drop")

        do_spill = m & ~(has_hit | has_free)
        ring_hit = ((cur.spill_src == u) & (cur.spill_dst == v)
                    & (cur.spill_lab == l))
        any_ring = jnp.any(ring_hit)
        append = do_spill & ~any_ring
        wslot = jnp.where(any_ring, jnp.argmax(ring_hit), cur.spill_ptr)
        wslot = jnp.where(do_spill, wslot, s_cap)
        new_ts = jnp.where(any_ring,
                           jnp.maximum(cur.spill_ts[jnp.argmax(ring_hit)], t),
                           t)
        return cur._replace(
            idx=idx2, ts=ts2,
            spill_src=cur.spill_src.at[wslot].set(u, mode="drop"),
            spill_dst=cur.spill_dst.at[wslot].set(v, mode="drop"),
            spill_lab=cur.spill_lab.at[wslot].set(l, mode="drop"),
            spill_ts=cur.spill_ts.at[wslot].set(new_ts, mode="drop"),
            spill_ptr=cur.spill_ptr + append.astype(jnp.int32))

    return lax.fori_loop(0, src.shape[0], body, ell)


def ell_delete(ell: EllAdjacency, src: jax.Array, dst: jax.Array,
               lab: jax.Array, mask: jax.Array) -> EllAdjacency:
    """Jitted batch delete: clear every row slot AND ring entry matching
    ``(lab, src, dst)`` (duplicates must all die to match the dense
    ``.set(NEG_INF)``). Cleared slots keep their stale ``idx`` — benign.
    """
    def body(i, cur):
        u, v, l, m = src[i], dst[i], lab[i], mask[i]
        row_ts = cur.ts[l, u]
        hit = (cur.idx[l, u] == v) & m
        ts2 = cur.ts.at[l, u].set(jnp.where(hit, NEG_INF, row_ts))
        ring_hit = ((cur.spill_src == u) & (cur.spill_dst == v)
                    & (cur.spill_lab == l) & m)
        return cur._replace(
            ts=ts2,
            spill_ts=jnp.where(ring_hit, NEG_INF, cur.spill_ts))

    return lax.fori_loop(0, src.shape[0], body, ell)


def ell_expire(ell: EllAdjacency, low: jax.Array) -> EllAdjacency:
    """Window expiry: threshold each timestamp leaf (mirrors the dense
    ``where(adj > low, adj, NEG_INF)``)."""
    return ell._replace(
        ts=jnp.where(ell.ts > low, ell.ts, NEG_INF),
        spill_ts=jnp.where(ell.spill_ts > low, ell.spill_ts, NEG_INF))


def ell_incident(ell: EllAdjacency) -> jax.Array:
    """Per-vertex max incident timestamp, identical to the dense
    ``maximum(adj.max((0, 2)), adj.max((0, 1)))`` reduction."""
    n_slots = ell.n_slots
    out_u = ell.ts.max(axis=(0, 2))
    in_v = jnp.full((n_slots,), NEG_INF, ell.ts.dtype)
    in_v = in_v.at[ell.idx.reshape(-1)].max(ell.ts.reshape(-1))
    out_u = out_u.at[ell.spill_src].max(ell.spill_ts)
    in_v = in_v.at[ell.spill_dst].max(ell.spill_ts)
    return jnp.maximum(out_u, in_v)


def ell_clear_slots(ell: EllAdjacency, dead: jax.Array) -> EllAdjacency:
    """Clear every edge incident to a dead vertex slot (``dead``: (N,)
    bool), mirroring the dense row+column ``.set(NEG_INF)``."""
    ts = jnp.where(dead[None, :, None], NEG_INF, ell.ts)
    ts = jnp.where(dead[ell.idx], NEG_INF, ts)
    kill = dead[ell.spill_src] | dead[ell.spill_dst]
    return ell._replace(ts=ts,
                        spill_ts=jnp.where(kill, NEG_INF, ell.spill_ts))


def ell_live_edges(ell: EllAdjacency) -> jax.Array:
    """Device count of live (non-free) entries — occupancy telemetry.
    Ring duplicates of row-resident edges count once each; the executor
    only reads this at drain boundaries so the bias is visible, small,
    and documented."""
    return (jnp.sum(ell.ts > NEG_INF).astype(jnp.int32)
            + jnp.sum(ell.spill_ts > NEG_INF).astype(jnp.int32))


def ell_max_degree(ell: EllAdjacency) -> jax.Array:
    """Device max live out-degree over ``(label, u)`` rows, counting
    ring entries toward their row — sizes ``ell_cap`` after a drain."""
    row_deg = jnp.sum(ell.ts > NEG_INF, axis=2).astype(jnp.int32)  # (L, N)
    ring_live = (ell.spill_ts > NEG_INF).astype(jnp.int32)
    ring_deg = jnp.zeros_like(row_deg).at[ell.spill_lab,
                                          ell.spill_src].add(ring_live)
    return jnp.max(row_deg + ring_deg)


def ell_label_rows(ell: EllAdjacency, labs: jax.Array,
                   zero: float) -> jax.Array:
    """Densify the per-transition label slabs: ``out[j] == dense[labs[j]]``
    of shape (J, N, N). Used for the base term of the dense batched
    round; free slots fold to ``zero`` (a no-op under max)."""
    j = labs.shape[0]
    n_slots = ell.n_slots
    idx_l = ell.idx[labs]                     # (J, N, E)
    ts_l = ell.ts[labs]
    out = jnp.full((j, n_slots, n_slots), zero, ell.ts.dtype)
    out = out.at[jnp.arange(j)[:, None, None],
                 jnp.arange(n_slots)[None, :, None], idx_l].max(ts_l)
    eff = jnp.where(ell.spill_lab[None, :] == labs[:, None],
                    ell.spill_ts[None, :],
                    jnp.asarray(zero, ell.spill_ts.dtype))  # (J, S)
    return out.at[jnp.arange(j)[:, None], ell.spill_src[None, :],
                  ell.spill_dst[None, :]].max(eff)


def ell_rows_dense(ell: EllAdjacency, labs: jax.Array, rows: jax.Array,
                   zero: float) -> jax.Array:
    """Densify only the frontier rows: ``out[j, f] == dense[labs[j],
    rows[j, f]]`` of shape (J, F, N) — the O(F * d_max) base-term gather
    the frontier round uses instead of materializing (J, N, N)."""
    j, f = rows.shape
    n_slots = ell.n_slots
    idx_r = ell.idx[labs[:, None], rows]      # (J, F, E)
    ts_r = ell.ts[labs[:, None], rows]
    out = jnp.full((j, f, n_slots), zero, ell.ts.dtype)
    out = out.at[jnp.arange(j)[:, None, None],
                 jnp.arange(f)[None, :, None], idx_r].max(ts_r)
    hit = ((ell.spill_lab[None, None, :] == labs[:, None, None])
           & (ell.spill_src[None, None, :] == rows[:, :, None]))  # (J, F, S)
    eff = jnp.where(hit, ell.spill_ts[None, None, :],
                    jnp.asarray(zero, ell.spill_ts.dtype))
    dst = jnp.broadcast_to(ell.spill_dst[None, None, :], hit.shape)
    return out.at[jnp.arange(j)[:, None, None],
                  jnp.arange(f)[None, :, None], dst].max(eff)
