"""Contraction backends: the semiring round's hardware substrate as a
first-class object (PR 4 tentpole).

Before this layer existed, ``backend`` was a bare string threaded through
six modules and silently treated as "jnp" whenever it matched nothing. A
:class:`ContractionBackend` instead owns

  * the operand REPRESENTATION the closure loop runs on —
    :meth:`prepare_state` / :meth:`decode_state` convert from/to the
    engine's canonical f32-timestamp arrays at the dispatch boundary, so
    the loop itself never leaves the backend's representation (identity
    for the float backends; level-quantized int32 for the bucket mode);
  * the batched CONTRACTION over that representation —
    :meth:`contract_batched` for the dense round's gathered form,
    :meth:`contract_rows` for the shard-local form the mesh executor
    feeds, :meth:`contract` for the legacy single-query round;
  * its semiring ZERO in that representation (``-inf`` for timestamps,
    level ``0`` for buckets) and an ``exact`` flag (False marks backends
    whose results are a bounded coarsening of the float semiring rather
    than bit-identical).

Three implementations:

``jnp`` (:class:`JnpBackend`)
    Chunked pure-jnp oracle. Runs everywhere, bit-exact, the default.

``pallas`` (:class:`PallasBackend`)
    The fused batched VPU max-min kernel
    (:func:`~repro.kernels.maxmin.maxmin.maxmin_matmul_fused`): one grid
    launch per round over (J, m/bm, n/bn, k/bk) instead of a vmap of J
    single-pair launches. Bit-exact (max/min never reassociates).

``mxu_bucket`` (:class:`BucketBackend`)
    Level-quantized boolean closure on the MXU (kernels/bucket): inside a
    dispatch the (Q, N, N, K) state lives as int32 levels on an ABSOLUTE
    time grid of step ``w_max / n_levels``, contractions decompose into T
    boolean matmuls the MXU executes natively, and emit decodes levels
    back to grid timestamps — i.e. to a COARSENED expiry. The exactness
    guard (tested): the decoded state equals the float engine's state
    mapped through the grid quantizer, so every float-valid pair is
    reported and any extra pair's true bottleneck lies within one level
    step of the expiry boundary.

``resolve_backend`` is the single entry point: strings validate against
``KNOWN_BACKENDS`` and raise on anything else ("palas" used to run jnp
without a whisper), instances pass through. String-resolved backends are
process-wide singletons so the jitted step functions (which take the
backend as a static argument) share one compile cache.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from ..kernels.bucket.bucket import bucket_maxmin_fused
from ..kernels.bucket.ref import bucket_maxmin_ref
from ..kernels.ell.ops import ell_gather_contract
from ..kernels.maxmin.maxmin import maxmin_matmul, maxmin_matmul_fused
from ..kernels.rowsparse.ops import rowsparse_gather
from ..kernels.maxmin.ref import maxmin_matmul_ref
from .sparse_adj import EllAdjacency

NEG_INF = float("-inf")


def _interp_default(interpret: Optional[bool]) -> bool:
    """interpret=None -> Pallas interpreter everywhere but TPU (the CPU
    validation path; TPU compiles the real kernel)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


class ContractionBackend:
    """One relaxation round's contraction substrate (see module docstring).

    Instances compare and hash BY CONFIGURATION (:meth:`config_key`):
    they ride through ``jax.jit`` as static arguments and key the mesh
    executor's step-function cache, so two identically-configured
    instances share one compile cache (and a service group accepts them
    as "the same backend"). Subclasses that add configuration attributes
    must fold them into :meth:`config_key`.
    """

    name: str = "abstract"
    exact: bool = True
    #: semiring zero in the backend's operand representation
    zero: float = NEG_INF

    def config_key(self) -> tuple:
        """Hashable full-configuration identity (type + every attribute
        that changes traced behavior)."""
        return (type(self).__name__, self.name)

    def __eq__(self, other) -> bool:
        return (isinstance(other, ContractionBackend)
                and self.config_key() == other.config_key())

    def __hash__(self) -> int:
        return hash(self.config_key())

    # -- state representation hooks ------------------------------------------

    def encode(self, x: jnp.ndarray, now=None, w_max=None) -> jnp.ndarray:
        """Timestamp array -> operand representation (identity for float
        backends). ``now``/``w_max`` anchor representation grids that move
        with the stream clock (bucket mode)."""
        return x

    def prepare_state(self, dist, adj, now=None, w_max=None):
        """(dist, adj) f32 timestamps -> closure operands. Called once per
        dispatch, before the round loop."""
        return dist, adj

    def decode_state(self, dist, now=None, w_max=None) -> jnp.ndarray:
        """Closure-result operand -> f32 timestamps (the engine's canonical
        inter-dispatch representation; checkpoints and emit read this)."""
        return dist

    # -- contraction ---------------------------------------------------------

    def contract(self, d: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
        """Single-pair maxmin over u: d (N, N)[x, u] x a (N, N)[u, v] ->
        (N, N)[x, v] (legacy single-query round)."""
        raise NotImplementedError

    def contract_rows(self, d_s: jnp.ndarray, a_l: jnp.ndarray) -> jnp.ndarray:
        """Batched maxmin over u for gathered transition rows:
        d_s (J, N, N)[x, u] x a_l (J, N, N)[u, v] -> (J, N, N)[x, v]."""
        raise NotImplementedError

    def contract_batched(self, dist, adj, btt, mask) -> jnp.ndarray:
        """The dense round's contraction: gather each transition row's
        operands from dist (Q, N, N, K) / adj (L, N, N) per the flattened
        table ``btt``, contract, and zero masked rows. ``mask`` is the
        (J,) active-row mask (shape padding AND converged-lane masking
        folded in by the caller). Returns (J, N, N) contributions in the
        backend's representation; masked rows carry :attr:`zero`."""
        d_s = dist[btt.qidx, :, :, btt.src]           # (J, N, N) [x, u]
        a_l = adj[btt.lab]                            # (J, N, N) [u, v]
        contrib = self.contract_rows(d_s, a_l)
        return jnp.where(mask[:, None, None], contrib,
                         jnp.asarray(self.zero, contrib.dtype))

    # -- ELL (blocked-sparse adjacency) contraction --------------------------
    #
    # The ``adj_layout="ell"`` axis: same contractions, but the adjacency
    # operand is an :class:`~repro.core.sparse_adj.EllAdjacency` instead of
    # the dense (L, N, N) slab. max/min never reassociates and free slots
    # fold to :attr:`zero`, so every variant is bit-identical to running
    # the dense hook on ``ell_to_dense(adj)`` — the conformance suite pins
    # this per backend. Concrete on the base (the chunked jnp reference is
    # exact on both the float and the int32-level lattice, so the bucket
    # backend inherits it unchanged); :class:`PallasBackend` swaps in the
    # fused gather-contract kernel.

    def _fold_spill(self, contrib, d_s, ell: EllAdjacency, labs):
        """Fold the spill ring into a gather-contract result: for ring
        entries on transition j's label, ``contrib[j, :, dst] max=
        min(d_s[j, :, src], spill_ts)``. Free ring entries carry
        :attr:`zero` and annihilate."""
        j, m, _ = contrib.shape
        eff = jnp.where(ell.spill_lab[None, :] == labs[:, None],
                        ell.spill_ts[None, :],
                        jnp.asarray(self.zero, ell.spill_ts.dtype))  # (J, S)
        d_sp = d_s[:, :, ell.spill_src]                              # (J, M, S)
        cand = jnp.minimum(d_sp, eff[:, None, :].astype(d_s.dtype))
        dst = jnp.broadcast_to(ell.spill_dst[None, None, :], cand.shape)
        return contrib.at[jnp.arange(j)[:, None, None],
                          jnp.arange(m)[None, :, None], dst].max(cand)

    def contract_rows_ell(self, d_s, ell: EllAdjacency, labs) -> jnp.ndarray:
        """Batched maxmin over u against ELL rows: d_s (J, M, N)[x, u] x
        the per-label slot rows of ``ell`` -> (J, M, N)[x, v], O(M*N*E)
        work instead of the dense O(M*N*N)."""
        contrib = ell_gather_contract(d_s, ell.idx[labs], ell.ts[labs],
                                      zero=self.zero, use_pallas=False)
        return self._fold_spill(contrib, d_s, ell, labs)

    def contract_batched_ell(self, dist, ell: EllAdjacency, btt,
                             mask) -> jnp.ndarray:
        """ELL twin of :meth:`contract_batched` (same gather of dist, same
        masking contract)."""
        d_s = dist[btt.qidx, :, :, btt.src]           # (J, N, N) [x, u]
        contrib = self.contract_rows_ell(d_s, ell, btt.lab)
        return jnp.where(mask[:, None, None], contrib,
                         jnp.asarray(self.zero, contrib.dtype))

    # -- row-sparse dist gather ----------------------------------------------

    def gather_dist_rows(self, idx, ts, e: int) -> jnp.ndarray:
        """Densify gathered row-sparse dist slot rows: idx/ts (M, C) ->
        the (M, E) f32 slab a frontier round relaxes
        (``dist_layout="row_sparse"``, PR 9). Operates on RAW f32
        timestamps with a -inf zero regardless of :attr:`zero` — the
        caller :meth:`encode`-s the densified slab at the backend
        boundary, exactly where the dense layout encodes its gathered
        rows, so clock-anchored representations never leak into the
        stored sparse state. Pure scatter-max, exact for every backend;
        :class:`PallasBackend` swaps in the fused kernel."""
        return rowsparse_gather(idx, ts, e, zero=NEG_INF, use_pallas=False)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"<{type(self).__name__} {self.name!r}>"


class JnpBackend(ContractionBackend):
    """Chunked pure-jnp (max, min) contraction — the oracle and default.

    VPU-bound on TPU like the pallas kernel, but scheduled by XLA: the
    (m, k, n) broadcast intermediate rematerializes per fusion rather than
    tiling through VMEM. Bit-identical results (same op, same order)."""

    name = "jnp"

    def contract(self, d, a):
        return maxmin_matmul_ref(d, a)

    def contract_rows(self, d_s, a_l):
        return jax.vmap(maxmin_matmul_ref)(d_s, a_l)


class PallasBackend(ContractionBackend):
    """Fused batched VPU max-min kernel (kernels/maxmin).

    One grid launch covers every transition row of a round — grid
    (J, m/bm, n/bn, k/bk), k innermost — so A/B tiles stream HBM→VMEM once
    per output-tile visit instead of once per vmap instance, and the
    output tile stays VMEM-resident across the k sweep. Exact: max/min
    has no floating-point reassociation error, so results are
    bit-identical to :class:`JnpBackend` (asserted by the conformance
    suite and fig15).

    ``interpret=None`` auto-selects the Pallas interpreter off-TPU (the
    CPU validation path used by tests and CI's pallas-interpret leg).
    Block sizes default to the kernels' shape-aware table (``bm=None`` —
    skinny frontier slabs get a small bm / wide bn instead of 8x row
    padding); pass explicit ints to pin them.
    """

    name = "pallas"

    def __init__(self, interpret: Optional[bool] = None,
                 bm: Optional[int] = None, bn: Optional[int] = None,
                 bk: Optional[int] = None):
        self.interpret = interpret
        self.bm, self.bn, self.bk = bm, bn, bk

    def config_key(self) -> tuple:
        return (type(self).__name__, self.interpret,
                self.bm, self.bn, self.bk)

    def contract(self, d, a):
        return maxmin_matmul(d, a, bm=self.bm, bn=self.bn, bk=self.bk,
                             interpret=_interp_default(self.interpret))

    def contract_rows(self, d_s, a_l):
        return maxmin_matmul_fused(d_s, a_l, bm=self.bm, bn=self.bn,
                                   bk=self.bk,
                                   interpret=_interp_default(self.interpret))

    def contract_rows_ell(self, d_s, ell: EllAdjacency, labs):
        contrib = ell_gather_contract(
            d_s, ell.idx[labs], ell.ts[labs], zero=self.zero,
            use_pallas=True, interpret=_interp_default(self.interpret))
        return self._fold_spill(contrib, d_s, ell, labs)

    def gather_dist_rows(self, idx, ts, e: int):
        return rowsparse_gather(idx, ts, e, zero=NEG_INF, use_pallas=True,
                                interpret=_interp_default(self.interpret))


class BucketBackend(ContractionBackend):
    """Level-quantized boolean closure on the MXU (kernels/bucket).

    Representation: timestamps quantize onto an ABSOLUTE grid of step
    ``w_max / n_levels`` — level l decodes to ``origin + l * step`` where
    ``origin = floor((now - w_max) / step) * step`` is the window's lower
    edge snapped DOWN to the grid (so the grid never shifts under a value
    between dispatches: re-encoding an on-grid value is the identity, and
    the one-time coarsening error of ``< step`` per raw timestamp never
    accumulates). Level 0 is the semiring zero: -inf, plus anything at or
    below ``origin`` — i.e. values a full window old, dead for every
    query's read-time threshold. ``n_levels + 1`` levels are allocated so
    the sub-step slack between ``origin`` and ``now - w_max`` never clips
    a live value.

    Exactness guard: the grid map is monotone, so it commutes with max and
    min — the level closure IS the float closure mapped through the grid,
    elementwise (tests/test_backends.py asserts this equality against a
    float engine run on the same stream). Decoded values land in
    ``(true - GRID_EPS*step, true + step)`` (the EPS term is the fp snap
    tolerance that keeps re-quantization idempotent — see
    :attr:`GRID_EPS`), so emit misses no float-valid pair except within
    that vanishing tolerance of the threshold; the error is a COARSENED
    EXPIRY: an extra pair's true bottleneck lies within one step of its
    query's window boundary.

    Contraction: each level matmul decomposes into T boolean matmuls
    (``C >= theta  iff  exists u: A >= theta and B >= theta``) the MXU
    executes natively — ``use_pallas=True`` runs the fused batched kernel
    (levels binarized in registers, A/B read from HBM once for all T
    thresholds); the default jnp decomposition lowers to T XLA dots (MXU
    on TPU, and the portable path everywhere else).
    """

    name = "mxu_bucket"
    exact = False
    zero = 0

    #: FLOOR of the snap tolerance (in level-step units) for the grid
    #: ceil: a decoded on-grid value re-encodes through rounded fp ops
    #: (origin + l*step, then the division), so its ratio lands slightly
    #: ABOVE the integer when the step is not exactly representable (e.g.
    #: w=2.4, T=8). An unguarded ceil would then bump it a full level per
    #: dispatch — unbounded upward drift. The error of the round trip is
    #: ABSOLUTE (~a few ulps of the timestamp magnitude), so the applied
    #: tolerance scales with the stream clock: max(GRID_EPS,
    #: 8 * ulp(now) / step), clamped below half a level. Snapping anything
    #: within tolerance of a grid line down to it restores idempotence;
    #: the price is that a value within tol*step ABOVE a line decodes to
    #: the line (rounds DOWN by < tol*step — at large clocks that is
    #: simply the f32 resolution limit), so the coarsening bound is
    #: (-tol*step, +step) rather than exactly [0, step).
    GRID_EPS: float = 1e-4

    def __init__(self, n_levels: int = 8, use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None):
        if n_levels < 1:
            raise ValueError(f"n_levels must be >= 1, got {n_levels}")
        self.n_levels = int(n_levels)
        self.use_pallas = use_pallas
        self.interpret = interpret

    def config_key(self) -> tuple:
        return (type(self).__name__, self.n_levels, self.use_pallas,
                self.interpret)

    # -- the absolute level grid ---------------------------------------------

    def _grid(self, now, w_max):
        w = jnp.maximum(jnp.asarray(w_max, jnp.float32), 1e-30)
        step = w / self.n_levels
        now_f = jnp.asarray(now, jnp.float32)
        now_safe = jnp.where(jnp.isfinite(now_f), now_f, jnp.float32(0.0))
        origin = jnp.floor((now_safe - w) / step) * step
        return origin, step

    def encode(self, x, now=None, w_max=None):
        if now is None or w_max is None:
            raise ValueError(
                "mxu_bucket needs the stream clock: pass now/w_max through "
                "the closure (the executor dispatches do)")
        origin, step = self._grid(now, w_max)
        # ceil with a snap-down tolerance: keeps re-encoding a decoded
        # value the identity under fp rounding, so the coarsening error
        # never accumulates across dispatches. The round trip's error is
        # absolute (~ulp of the clock magnitude), hence the clock-scaled
        # term; the 0.45-level clamp stops the snap from ever swallowing
        # half a level when the clock outgrows the grid's f32 resolution.
        now_f = jnp.asarray(now, jnp.float32)
        now_mag = jnp.where(jnp.isfinite(now_f), jnp.abs(now_f), 0.0)
        ulp_now = now_mag * jnp.float32(2.0 ** -23)
        tol = jnp.clip(8.0 * ulp_now / step, self.GRID_EPS, 0.45)
        lvl = jnp.ceil((x - origin) / step - tol)
        lvl = jnp.clip(lvl, 0.0, float(self.n_levels + 1))
        lvl = jnp.where(jnp.isfinite(x) & (x > origin), lvl, 0.0)
        return lvl.astype(jnp.int32)

    def prepare_state(self, dist, adj, now=None, w_max=None):
        if isinstance(adj, EllAdjacency):
            # encode the timestamp leaves in place (idx/ptr pass through);
            # free slots (-inf) land on level 0 == the bucket zero, so the
            # free-slot-annihilation contract survives the representation
            adj = adj._replace(ts=self.encode(adj.ts, now, w_max),
                               spill_ts=self.encode(adj.spill_ts, now, w_max))
            return self.encode(dist, now, w_max), adj
        return (self.encode(dist, now, w_max), self.encode(adj, now, w_max))

    def decode_state(self, dist, now=None, w_max=None):
        origin, step = self._grid(now, w_max)
        return jnp.where(
            dist > 0, origin + dist.astype(jnp.float32) * step,
            jnp.float32(NEG_INF),
        )

    # -- contraction on levels -----------------------------------------------

    @property
    def _t_alloc(self) -> int:
        return self.n_levels + 1

    def _use_pallas(self) -> bool:
        if self.use_pallas is None:
            return jax.default_backend() == "tpu"
        return bool(self.use_pallas)

    def contract(self, d, a):
        return bucket_maxmin_ref(d, a, self._t_alloc)

    def contract_rows(self, d_s, a_l):
        if self._use_pallas():
            return bucket_maxmin_fused(
                d_s, a_l, n_levels=self._t_alloc,
                interpret=_interp_default(self.interpret))
        # jnp threshold decomposition; XLA lowers each theta-dot to the MXU
        out = jnp.zeros(d_s.shape[:2] + (a_l.shape[2],), jnp.int32)
        for theta in range(1, self._t_alloc + 1):
            db = (d_s >= theta).astype(jnp.bfloat16)
            ab = (a_l >= theta).astype(jnp.bfloat16)
            reach = jnp.einsum("jxu,juv->jxv", db, ab,
                               preferred_element_type=jnp.float32) > 0.5
            out = out + reach.astype(jnp.int32)
        return out


KNOWN_BACKENDS = ("jnp", "pallas", "mxu_bucket")

_SINGLETONS = {}

BackendLike = Union[str, ContractionBackend]


def resolve_backend(spec: BackendLike) -> ContractionBackend:
    """Resolve a backend name or instance to a :class:`ContractionBackend`.

    Raises ``ValueError`` for unknown names — the old string plumbing ran
    the jnp reference for ANY unrecognized string ("palas" silently got
    jnp), so every construction path now validates here. String-named
    backends are interned process-wide (stable identity keeps the jitted
    steps' static-argument compile cache shared across engines)."""
    if isinstance(spec, ContractionBackend):
        return spec
    if isinstance(spec, str):
        if spec not in KNOWN_BACKENDS:
            raise ValueError(
                f"unknown contraction backend {spec!r}; known backends: "
                f"{', '.join(KNOWN_BACKENDS)} (or pass a ContractionBackend "
                f"instance)")
        if spec not in _SINGLETONS:
            _SINGLETONS[spec] = {
                "jnp": JnpBackend,
                "pallas": PallasBackend,
                "mxu_bucket": BucketBackend,
            }[spec]()
        return _SINGLETONS[spec]
    raise TypeError(
        f"backend must be a name in {KNOWN_BACKENDS} or a ContractionBackend, "
        f"got {type(spec).__name__}")
