"""Core of the paper's contribution: streaming RPQ evaluation.

Public API:
    compile_query(expr)            -- regex -> minimal DFA (+ RSPQ metadata)
    RAPQ / RSPQ                    -- paper-faithful pointer engines (oracle)
    DenseRPQEngine                 -- the TPU-native dense semiring engine
    BatchedDenseRPQEngine          -- Q queries, one shared-adjacency step
    RegisteredQuery                -- one query of a batched group
    batch_rapq / streaming_oracle  -- batch baselines
"""
from .automaton import DFA, compile_query
from .backend import (
    KNOWN_BACKENDS,
    BucketBackend,
    ContractionBackend,
    JnpBackend,
    PallasBackend,
    resolve_backend,
)
from .batch import batch_rapq, batch_rspq_bruteforce, snapshot_from_edges, streaming_oracle
from .engine import BatchedDenseRPQEngine, DenseRPQEngine, RegisteredQuery
from .executor import Executor, LocalExecutor, QueryTables
from .reference import RAPQ, RSPQ, SnapshotGraph

__all__ = [
    "DFA",
    "compile_query",
    "ContractionBackend",
    "JnpBackend",
    "PallasBackend",
    "BucketBackend",
    "resolve_backend",
    "KNOWN_BACKENDS",
    "RAPQ",
    "RSPQ",
    "SnapshotGraph",
    "BatchedDenseRPQEngine",
    "DenseRPQEngine",
    "RegisteredQuery",
    "Executor",
    "LocalExecutor",
    "QueryTables",
    "batch_rapq",
    "batch_rspq_bruteforce",
    "snapshot_from_edges",
    "streaming_oracle",
]
