"""Regular-expression AST + parser for RPQ path constraints.

Grammar (paper Definition 7, plus the sugar the paper uses):

    R := eps | a | R . R | R + R | R* | R? | R^+

Concrete syntax accepted by :func:`parse`:

    alternation:    ``a + b``  (also ``a | b``)
    concatenation:  ``a . b``  (also ``a b`` by juxtaposition, ``a o b``)
    kleene star:    ``a*``
    plus:           ``a+`` suffix -- disambiguated from alternation by position
    optional:       ``a?``
    grouping:       ``( ... )``
    epsilon:        ``()`` or ``eps``

Labels are identifiers ``[A-Za-z_][A-Za-z0-9_]*`` or single characters.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple, Union


class Node:
    """Base class for regex AST nodes."""

    def labels(self) -> frozenset:
        raise NotImplementedError

    def size(self) -> int:
        """Query size per the paper: #labels + #occurrences of * and +."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Eps(Node):
    def labels(self) -> frozenset:
        return frozenset()

    def size(self) -> int:
        return 0

    def __str__(self) -> str:
        return "eps"


@dataclasses.dataclass(frozen=True)
class Sym(Node):
    label: str

    def labels(self) -> frozenset:
        return frozenset({self.label})

    def size(self) -> int:
        return 1

    def __str__(self) -> str:
        return self.label


@dataclasses.dataclass(frozen=True)
class Cat(Node):
    left: Node
    right: Node

    def labels(self) -> frozenset:
        return self.left.labels() | self.right.labels()

    def size(self) -> int:
        return self.left.size() + self.right.size()

    def __str__(self) -> str:
        return f"({self.left} . {self.right})"


@dataclasses.dataclass(frozen=True)
class Alt(Node):
    left: Node
    right: Node

    def labels(self) -> frozenset:
        return self.left.labels() | self.right.labels()

    def size(self) -> int:
        return self.left.size() + self.right.size()

    def __str__(self) -> str:
        return f"({self.left} + {self.right})"


@dataclasses.dataclass(frozen=True)
class Star(Node):
    inner: Node

    def labels(self) -> frozenset:
        return self.inner.labels()

    def size(self) -> int:
        return self.inner.size() + 1

    def __str__(self) -> str:
        return f"{self.inner}*"


@dataclasses.dataclass(frozen=True)
class Plus(Node):
    inner: Node

    def labels(self) -> frozenset:
        return self.inner.labels()

    def size(self) -> int:
        return self.inner.size() + 1

    def __str__(self) -> str:
        return f"{self.inner}^+"


@dataclasses.dataclass(frozen=True)
class Opt(Node):
    inner: Node

    def labels(self) -> frozenset:
        return self.inner.labels()

    def size(self) -> int:
        return self.inner.size()

    def __str__(self) -> str:
        return f"{self.inner}?"


Token = Tuple[str, str]  # (kind, text)


def _tokenize(src: str) -> Iterator[Token]:
    i, n = 0, len(src)
    while i < n:
        c = src[i]
        if c.isspace():
            i += 1
            continue
        if c == "(":
            yield ("LPAR", c)
            i += 1
        elif c == ")":
            yield ("RPAR", c)
            i += 1
        elif c == "*":
            yield ("STAR", c)
            i += 1
        elif c == "?":
            yield ("OPT", c)
            i += 1
        elif c in "+|":
            yield ("PLUSBAR", c)
            i += 1
        elif c in ".":
            yield ("DOT", c)
            i += 1
        elif c == "∘":  # ∘ concatenation
            yield ("DOT", c)
            i += 1
        elif c.isalnum() or c == "_":
            j = i
            while j < n and (src[j].isalnum() or src[j] == "_"):
                j += 1
            word = src[i:j]
            if word == "o" and i > 0:  # infix 'o' as concatenation marker
                yield ("DOT", word)
            elif word == "eps":
                yield ("EPS", word)
            else:
                yield ("SYM", word)
            i = j
        else:
            raise ValueError(f"unexpected character {c!r} in regex {src!r}")


class _Parser:
    """Recursive-descent parser.

    ``+``/``|`` between terms is alternation; ``+`` *immediately following* a
    term with no following term (i.e. used as a postfix where the next token
    cannot start a term) is one-or-more. We disambiguate with one token of
    lookahead: a PLUSBAR is postfix-plus iff the next token is not the start
    of a term (SYM/LPAR/EPS).
    """

    def __init__(self, tokens: List[Token]):
        self.toks = tokens
        self.pos = 0

    def peek(self, off: int = 0) -> Union[Token, None]:
        if self.pos + off < len(self.toks):
            return self.toks[self.pos + off]
        return None

    def eat(self, kind: str) -> Token:
        tok = self.peek()
        if tok is None or tok[0] != kind:
            raise ValueError(f"expected {kind}, got {tok} at {self.pos}")
        self.pos += 1
        return tok

    def parse(self) -> Node:
        node = self.alternation()
        if self.pos != len(self.toks):
            raise ValueError(f"trailing tokens at {self.pos}: {self.toks[self.pos:]}")
        return node

    def alternation(self) -> Node:
        node = self.concatenation()
        while True:
            tok = self.peek()
            if tok is not None and tok[0] == "PLUSBAR" and self._starts_term(self.peek(1)):
                self.eat("PLUSBAR")
                node = Alt(node, self.concatenation())
            else:
                return node

    @staticmethod
    def _starts_term(tok: Union[Token, None]) -> bool:
        return tok is not None and tok[0] in ("SYM", "LPAR", "EPS")

    def concatenation(self) -> Node:
        node = self.postfix()
        while True:
            tok = self.peek()
            if tok is not None and tok[0] == "DOT":
                self.eat("DOT")
                node = Cat(node, self.postfix())
            elif self._starts_term(tok):
                node = Cat(node, self.postfix())
            else:
                return node

    def postfix(self) -> Node:
        node = self.atom()
        while True:
            tok = self.peek()
            if tok is None:
                return node
            if tok[0] == "STAR":
                self.eat("STAR")
                node = Star(node)
            elif tok[0] == "OPT":
                self.eat("OPT")
                node = Opt(node)
            elif tok[0] == "PLUSBAR" and not self._starts_term(self.peek(1)):
                self.eat("PLUSBAR")
                node = Plus(node)
            else:
                return node

    def atom(self) -> Node:
        tok = self.peek()
        if tok is None:
            raise ValueError("unexpected end of regex")
        if tok[0] == "SYM":
            self.eat("SYM")
            return Sym(tok[1])
        if tok[0] == "EPS":
            self.eat("EPS")
            return Eps()
        if tok[0] == "LPAR":
            self.eat("LPAR")
            if self.peek() is not None and self.peek()[0] == "RPAR":
                self.eat("RPAR")
                return Eps()
            node = self.alternation()
            self.eat("RPAR")
            return node
        raise ValueError(f"unexpected token {tok}")


def parse(src: str) -> Node:
    """Parse an RPQ regular expression string into an AST."""
    return _Parser(list(_tokenize(src))).parse()
