"""Paper-faithful reference implementation of the streaming RPQ algorithms.

This module transcribes the paper's pseudocode (Algorithms RAPQ, Insert,
ExpiryRAPQ, Delete — §3; RSPQ, Extend, Unmark, ExpiryRSPQ — §4) into plain
Python with pointer-based spanning trees. It serves two roles:

1. the *paper-faithful baseline* measured in benchmarks (vs the dense TPU
   engine), and
2. the *correctness oracle* for the dense engine and the Pallas kernels
   (property tests compare result sets on randomized streams).

Conventions
-----------
* vertices are hashable ids; labels are strings; timestamps are floats.
* "node" = (vertex, state) occurrence in a spanning tree (paper wording).
* RAPQ keeps exactly one occurrence per (v, t) per tree (Lemma 1, inv. 2);
  RSPQ may keep several when conflicts force re-traversals (§4.1).
* Implicit window model: the result *stream* is append-only; explicit
  deletions / expiry can invalidate (reported separately, §3.2).

Where the paper's pseudocode is ambiguous we document the choice inline and
validate the result sets against the brute-force algorithms in
``core/batch.py`` (see tests/test_reference_vs_batch.py).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Set, Tuple

from .automaton import DFA

NEG_INF = float("-inf")
POS_INF = float("inf")

Vertex = object  # hashable
Pair = Tuple[object, object]


class SnapshotGraph:
    """The window content G_{W,tau}: newest timestamp per (u, v, label)."""

    def __init__(self) -> None:
        self.edge_ts: Dict[Tuple[object, object, str], float] = {}
        self.out_adj: Dict[object, Dict[Tuple[object, str], float]] = {}
        self.in_adj: Dict[object, Dict[Tuple[object, str], float]] = {}

    def upsert(self, u: object, v: object, label: str, ts: float) -> None:
        key = (u, v, label)
        old = self.edge_ts.get(key, NEG_INF)
        if ts >= old:
            self.edge_ts[key] = ts
            self.out_adj.setdefault(u, {})[(v, label)] = ts
            self.in_adj.setdefault(v, {})[(u, label)] = ts

    def remove(self, u: object, v: object, label: str) -> bool:
        key = (u, v, label)
        if key not in self.edge_ts:
            return False
        del self.edge_ts[key]
        self.out_adj.get(u, {}).pop((v, label), None)
        self.in_adj.get(v, {}).pop((u, label), None)
        return True

    def prune(self, low: float) -> None:
        """Drop edges with ts <= low (window maintenance, lazy)."""
        dead = [k for k, ts in self.edge_ts.items() if ts <= low]
        for (u, v, label) in dead:
            self.remove(u, v, label)

    def out_edges(self, u: object) -> Iterable[Tuple[object, str, float]]:
        for (v, label), ts in self.out_adj.get(u, {}).items():
            yield v, label, ts

    def in_edges(self, v: object) -> Iterable[Tuple[object, str, float]]:
        for (u, label), ts in self.in_adj.get(v, {}).items():
            yield u, label, ts

    def n_edges(self) -> int:
        return len(self.edge_ts)

    def vertices(self) -> Set[object]:
        vs: Set[object] = set()
        for (u, v, _l) in self.edge_ts:
            vs.add(u)
            vs.add(v)
        return vs


class _Occ:
    """A spanning-tree node occurrence: (vertex, state) + parent pointer + ts.

    (paper: ``(u, s).pt`` and ``(u, s).ts``, Definition 12.)
    """

    __slots__ = ("vertex", "state", "ts", "parent", "children")

    def __init__(self, vertex: object, state: int, ts: float, parent: Optional["_Occ"]):
        self.vertex = vertex
        self.state = state
        self.ts = ts
        self.parent = parent
        self.children: Set["_Occ"] = set()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Occ({self.vertex},{self.state},ts={self.ts})"


class _Tree:
    """Spanning tree T_x rooted at (x, s0) with a hash index on (v, t)."""

    def __init__(self, root_vertex: object, start_state: int):
        self.x = root_vertex
        self.root = _Occ(root_vertex, start_state, POS_INF, None)
        # RAPQ: exactly one occurrence per (v, t); RSPQ uses _MultiTree below.
        self.index: Dict[Tuple[object, int], _Occ] = {
            (root_vertex, start_state): self.root
        }

    def get(self, v: object, t: int) -> Optional[_Occ]:
        return self.index.get((v, t))

    def states_at(self, v: object) -> List[int]:
        return [s for (u, s) in self.index if u == v]

    def n_nodes(self) -> int:
        return len(self.index)


class RAPQ:
    """Algorithm RAPQ (§3.1) + ExpiryRAPQ (§3.1) + Delete (§3.2).

    Usage: feed tuples in timestamp order via :meth:`insert` /
    :meth:`delete`; call :meth:`expire` at slide boundaries (the driver in
    ``streaming/service.py`` follows eager evaluation / lazy expiration,
    exactly the paper's setting).
    """

    def __init__(self, dfa: DFA, window: float):
        if dfa.containment is None:
            raise ValueError("compile the query with with_rspq_metadata/compile_query")
        self.dfa = dfa
        self.window = float(window)
        self.graph = SnapshotGraph()
        self.delta: Dict[object, _Tree] = {}  # the Δ tree index
        # reverse index: vertex -> set of tree roots whose tree contains it
        self.occurs_in: Dict[object, Set[object]] = {}
        self.results: Set[Pair] = set()       # the (monotone) result set
        self.result_log: List[Tuple[float, Pair]] = []  # append-only stream
        self.now: float = NEG_INF
        # per-label transition lists: label_idx -> [(s, t)]
        self._trans_by_label: Dict[int, List[Tuple[int, int]]] = {}
        for s, li, t in dfa.transitions():
            self._trans_by_label.setdefault(li, []).append((s, t))

    # -- bookkeeping ------------------------------------------------------

    def _low(self) -> float:
        return self.now - self.window

    def _emit(self, x: object, v: object) -> None:
        # implicit-window semantics: the result is a monotone SET (Def. 9);
        # re-derivations of an already-reported pair are not re-emitted
        pair = (x, v)
        if pair in self.results:
            return
        self.results.add(pair)
        self.result_log.append((self.now, pair))

    def _track(self, vertex: object, tree: _Tree) -> None:
        self.occurs_in.setdefault(vertex, set()).add(tree.x)

    # -- Algorithm Insert --------------------------------------------------

    def _insert(self, tree: _Tree, parent: _Occ, v: object, t: int,
                edge_ts: float, reinserted: Optional[Set[Tuple[object, int]]] = None) -> None:
        """Algorithm Insert: attach/improve (v, t) under ``parent``.

        Improvement case (paper Insert line 8 / RAPQ line 10): when (v, t)
        already exists with a *worse* timestamp we re-parent it and propagate
        the improvement; the strict ``<`` makes cycles impossible because a
        descendant's ts can never strictly exceed its ancestor's.
        """
        nts = min(edge_ts, parent.ts)
        if nts <= self._low():
            return  # stale path: outside window (paper gates on validity)
        occ = tree.get(v, t)
        if occ is tree.root:
            # a length>=1 cycle back to (x, s0): a genuine (x, x) answer when
            # s0 is final, but the root node itself is never re-expanded
            if t in self.dfa.finals:
                self._emit(tree.x, v)
            return
        if occ is None:
            occ = _Occ(v, t, nts, parent)
            parent.children.add(occ)
            tree.index[(v, t)] = occ
            self._track(v, tree)
            if reinserted is not None:
                reinserted.add((v, t))
            if t in self.dfa.finals:
                self._emit(tree.x, v)
        elif occ.ts < nts:
            # re-parent with improved (larger) bottleneck timestamp
            if occ.parent is not None:
                occ.parent.children.discard(occ)
            occ.parent = parent
            parent.children.add(occ)
            occ.ts = nts
            if reinserted is not None:
                reinserted.add((v, t))
        else:
            return  # no improvement: prune (Lemma 1 invariant 2)
        # recurse over window edges out of v (Insert lines 7-11)
        for w, label, ets in list(self.graph.out_edges(v)):
            if ets <= self._low():
                continue
            li = self.dfa.labels.index(label) if label in self.dfa.labels else -1
            if li < 0:
                continue
            q = int(self.dfa.delta[t, li])
            if q < 0:
                continue
            child = tree.get(w, q)
            cand = min(occ.ts, ets)
            # `child is tree.root`: cycles back to (x, s0) are reported (not
            # expanded) inside _insert — a genuine (x, x) answer when s0 ∈ F
            if child is None or child is tree.root or child.ts < cand:
                self._insert(tree, occ, w, q, ets, reinserted)

    # -- Algorithm RAPQ (per arriving + tuple) ------------------------------

    def insert(self, u: object, v: object, label: str, ts: float) -> Set[Pair]:
        """Process an append tuple (ts, (u, v), label, +). Returns new pairs."""
        self.now = max(self.now, ts)
        before = len(self.result_log)
        if label not in self.dfa.labels:
            return set()  # tuple label outside Sigma_Q: discarded (§5.2)
        self.graph.upsert(u, v, label, ts)
        li = self.dfa.labels.index(label)
        low = self._low()
        for (s, t) in self._trans_by_label.get(li, ()):  # all (s,l)->t
            if s == self.dfa.start:
                # ensure the tree rooted at (u, s0) exists (Definition 12)
                tree = self.delta.get(u)
                if tree is None:
                    tree = _Tree(u, self.dfa.start)
                    self.delta[u] = tree
                    self._track(u, tree)
                self._insert(tree, tree.root, v, t, ts)
            # all trees that contain (u, s) extend with (v, t)
            for x in list(self.occurs_in.get(u, ())):
                tree = self.delta.get(x)
                if tree is None:
                    continue
                parent = tree.get(u, s)
                if parent is None or parent.ts <= low:
                    continue
                child = tree.get(v, t)
                cand = min(parent.ts, ts)
                if child is None or child is tree.root or child.ts < cand:
                    self._insert(tree, parent, v, t, ts)
        return {p for (_ts, p) in self.result_log[before:]}

    # -- Algorithm ExpiryRAPQ ----------------------------------------------

    def expire(self, tau: Optional[float] = None) -> Set[Pair]:
        """Remove nodes whose ts fell out of the window; try to reconnect.

        Returns the set of *invalidated* results (only meaningful under
        explicit windows / explicit deletions, §3.2).
        """
        if tau is not None:
            self.now = max(self.now, tau)
        low = self._low()
        invalidated: Set[Pair] = set()
        self.graph.prune(low)
        for x, tree in list(self.delta.items()):
            inv = self._expire_tree(tree, low)
            invalidated |= inv
            if tree.n_nodes() <= 1 and not self._root_live(tree, low):
                # only the root remains and no valid start edge: drop tree
                del self.delta[x]
                occs = self.occurs_in.get(x)
                if occs is not None:
                    occs.discard(x)
        return invalidated

    def _root_live(self, tree: _Tree, low: float) -> bool:
        """True if the root still has a valid out-edge on a start transition
        (covers self-loop-only trees, whose only non-root node IS the root)."""
        for v, label, ets in self.graph.out_edges(tree.x):
            if ets <= low or label not in self.dfa.labels:
                continue
            li = self.dfa.labels.index(label)
            if any(s == self.dfa.start for (s, _t) in self._trans_by_label.get(li, ())):
                return True
        return False

    def _expire_tree(self, tree: _Tree, low: float) -> Set[Pair]:
        # Line 2: potentially expired nodes
        P = {(v, t) for (v, t), occ in tree.index.items()
             if occ.ts <= low and occ is not tree.root}
        if not P:
            return set()
        # Line 3: prune T_x (detach whole set; descendants of expired nodes
        # are provably expired too -- see DESIGN.md validation notes)
        for key in P:
            occ = tree.index.pop(key)
            if occ.parent is not None:
                occ.parent.children.discard(occ)
            occ.parent = None
            occs = self.occurs_in.get(key[0])
            if occs is not None and not any(
                key[0] == vv for (vv, _s) in tree.index
            ):
                occs.discard(tree.x)
        # Lines 4-10: reconnect via valid in-edges from surviving nodes
        reinserted: Set[Tuple[object, int]] = set()
        for (v, t) in list(P):
            if (v, t) in reinserted:
                continue
            for u, label, ets in list(self.graph.in_edges(v)):
                if ets <= low or label not in self.dfa.labels:
                    continue
                li = self.dfa.labels.index(label)
                for (s, tt) in self._trans_by_label.get(li, ()):  # (u,s)->(v,t)
                    if tt != t:
                        continue
                    parent = tree.get(u, s) if (u, s) != (tree.x, self.dfa.start) else tree.root
                    if parent is None or parent.ts <= low:
                        continue
                    self._insert(tree, parent, v, t, ets, reinserted)
        # Lines 11-15: results invalidated by permanent removals
        invalidated: Set[Pair] = set()
        for (v, t) in P - reinserted:
            if t in self.dfa.finals:
                # refinement over the paper's line 13: only invalidate when no
                # other valid accepting occurrence of v remains in this tree
                if not any(
                    tree.get(v, tf) is not None and tree.get(v, tf).ts > low
                    for tf in self.dfa.finals
                ):
                    invalidated.add((tree.x, v))
        return invalidated

    # -- Algorithm Delete (negative tuples, §3.2) ---------------------------

    def delete(self, u: object, v: object, label: str, ts: float) -> Set[Pair]:
        """Process an explicit deletion tuple (ts, (u, v), label, -)."""
        self.now = max(self.now, ts)
        if not self.graph.remove(u, v, label):
            return set()
        if label not in self.dfa.labels:
            return set()
        li = self.dfa.labels.index(label)
        low = self._low()
        invalidated: Set[Pair] = set()
        for x in list(self.delta.keys()):
            tree = self.delta[x]
            touched = False
            for (s, t) in self._trans_by_label.get(li, ()):  # tree-edge test
                child = tree.get(v, t)
                if child is None or child.parent is None:
                    continue
                par = child.parent
                if par.vertex == u and par.state == s:
                    # deleted edge is a tree edge: poison the subtree
                    self._poison(child)
                    touched = True
            if touched:
                invalidated |= self._expire_tree(tree, low)
        return invalidated

    @staticmethod
    def _poison(occ: _Occ) -> None:
        stack = [occ]
        while stack:
            o = stack.pop()
            o.ts = NEG_INF
            stack.extend(o.children)

    # -- introspection -----------------------------------------------------

    def current_results(self) -> Set[Pair]:
        """Result set of the *current* snapshot (explicit-window view):
        pairs with a currently valid accepting node."""
        low = self._low()
        out: Set[Pair] = set()
        for x, tree in self.delta.items():
            for (v, t), occ in tree.index.items():
                if t in self.dfa.finals and occ.ts > low and occ is not tree.root:
                    out.add((x, v))
            # diagonal answers (x, x): a valid cycle closing back into the
            # root (x, s0) through an accepting transition
            if (x, x) not in out:
                for u, label, ets in self.graph.in_edges(x):
                    if ets <= low or label not in self.dfa.labels:
                        continue
                    li = self.dfa.labels.index(label)
                    for (s, t) in self._trans_by_label.get(li, ()):
                        if t not in self.dfa.finals:
                            continue
                        node = tree.get(u, s)
                        if node is not None and min(node.ts, ets) > low:
                            out.add((x, x))
                            break
                    if (x, x) in out:
                        break
        return out

    def index_size(self) -> Tuple[int, int]:
        """(number of trees, total nodes) — Fig. 5 metric."""
        trees = len(self.delta)
        nodes = sum(t.n_nodes() for t in self.delta.values())
        return trees, nodes


# ===========================================================================
# RSPQ (§4): simple path semantics with conflict detection
# ===========================================================================


class _SOcc:
    """RSPQ occurrence: same as _Occ but multiple occurrences of a (v, t)
    pair may coexist in one tree when conflicts force re-traversal."""

    __slots__ = ("vertex", "state", "ts", "parent", "children")

    def __init__(self, vertex: object, state: int, ts: float, parent):
        self.vertex = vertex
        self.state = state
        self.ts = ts
        self.parent = parent
        self.children: Set["_SOcc"] = set()


class _STree:
    def __init__(self, root_vertex: object, start_state: int):
        self.x = root_vertex
        self.root = _SOcc(root_vertex, start_state, POS_INF, None)
        self.occs: Dict[Tuple[object, int], List[_SOcc]] = {
            (root_vertex, start_state): [self.root]
        }
        self.markings: Set[Tuple[object, int]] = set()  # M_x

    def all_occs(self, v: object, t: int) -> List[_SOcc]:
        return self.occs.get((v, t), [])

    def add(self, occ: _SOcc) -> None:
        self.occs.setdefault((occ.vertex, occ.state), []).append(occ)

    def remove(self, occ: _SOcc) -> None:
        lst = self.occs.get((occ.vertex, occ.state))
        if lst is not None:
            try:
                lst.remove(occ)
            except ValueError:
                pass
            if not lst:
                del self.occs[(occ.vertex, occ.state)]

    def n_nodes(self) -> int:
        return sum(len(v) for v in self.occs.values())


def _path_of(occ: _SOcc) -> List[_SOcc]:
    out = []
    cur = occ
    while cur is not None:
        out.append(cur)
        cur = cur.parent
    out.reverse()
    return out


class RSPQ:
    """Algorithm RSPQ (§4.1): Extend + Unmark + ExpiryRSPQ.

    Efficient (polynomial) in the absence of conflicts; may re-traverse
    (exponential worst case) when conflicts appear — matching the paper's
    complexity statement (Theorem 5). ``max_extend_budget`` caps runaway
    conflicted traversals for benchmark safety (reported, never silently).
    """

    def __init__(self, dfa: DFA, window: float, max_extend_budget: int = 1_000_000):
        if dfa.containment is None:
            raise ValueError("query must carry RSPQ metadata")
        self.dfa = dfa
        self.window = float(window)
        self.graph = SnapshotGraph()
        self.delta: Dict[object, _STree] = {}
        self.results: Set[Pair] = set()
        self.result_log: List[Tuple[float, Pair]] = []
        self.now: float = NEG_INF
        self.conflicts_detected = 0
        self.extend_calls = 0
        self.max_extend_budget = max_extend_budget
        self._trans_by_label: Dict[int, List[Tuple[int, int]]] = {}
        for s, li, t in dfa.transitions():
            self._trans_by_label.setdefault(li, []).append((s, t))

    def _low(self) -> float:
        return self.now - self.window

    def _emit(self, x: object, v: object) -> None:
        pair = (x, v)
        if pair in self.results:
            return
        self.results.add(pair)
        self.result_log.append((self.now, pair))

    # -- Algorithm Extend ----------------------------------------------------

    def _extend(self, tree: _STree, parent: _SOcc, v: object, t: int,
                edge_ts: float) -> None:
        self.extend_calls += 1
        if self.extend_calls > self.max_extend_budget:
            raise RuntimeError("RSPQ extend budget exhausted (conflict blow-up)")
        nts = min(edge_ts, parent.ts)
        if nts <= self._low():
            return
        path = _path_of(parent)
        # Case 1: t in p[v] -> cycle in the product graph, prune
        states_at_v = [o.state for o in path if o.vertex == v]
        if t in states_at_v:
            return
        # Case 3 (Extend line 2): conflict between FIRST(p[v]) and t at v
        if states_at_v:
            q = states_at_v[0]
            if not bool(self.dfa.containment[q, t]):
                self.conflicts_detected += 1
                self._unmark(tree, parent)
                return
        if v == tree.x:
            # revisiting the root can never yield or extend a simple path
            return
        # Case 2: (v, t) marked -> prune, UNLESS the new path improves the
        # bottleneck timestamp. The paper's RSPQ listing omits the
        # improvement branch, but its own running example (Fig. 2/3,
        # Example 4.2) requires node timestamps to be refreshed by
        # re-insertions exactly as Algorithm Insert does for RAPQ (line 8's
        # "(w,q).ts < min(...)" test); without it, stale timestamps gate
        # valid extensions until the next expiry. We mirror RAPQ here.
        if (v, t) in tree.markings:
            occs = tree.all_occs(v, t)
            best = max(occs, key=lambda o: o.ts) if occs else None
            if best is None or best.ts >= nts:
                return
            # improvement: re-parent; cycle-free because a descendant's ts
            # never strictly exceeds its ancestor's (see RAPQ._insert)
            if best.parent is not None:
                best.parent.children.discard(best)
            best.parent = parent
            parent.children.add(best)
            best.ts = nts
            occ = best
        else:
            # Case 4: extend
            first_occurrence = not tree.all_occs(v, t)
            occ = _SOcc(v, t, nts, parent)
            parent.children.add(occ)
            tree.add(occ)
            if first_occurrence:
                tree.markings.add((v, t))  # Extend lines 7-9
            if t in self.dfa.finals:
                self._emit(tree.x, v)
        # recurse (Extend lines 14-18)
        for w, label, ets in list(self.graph.out_edges(v)):
            if ets <= self._low() or label not in self.dfa.labels:
                continue
            li = self.dfa.labels.index(label)
            r = int(self.dfa.delta[t, li])
            if r < 0:
                continue
            self._extend(tree, occ, w, r, ets)

    # -- Algorithm Unmark ------------------------------------------------------

    def _unmark(self, tree: _STree, last: _SOcc) -> None:
        """Walk the prefix path bottom-up removing markings; re-explore the
        previously pruned extensions of each unmarked node (Unmark lines 7-13).
        """
        Q: List[Tuple[object, int]] = []
        cur: Optional[_SOcc] = last
        while cur is not None and (cur.vertex, cur.state) in tree.markings:
            key = (cur.vertex, cur.state)
            tree.markings.discard(key)
            Q.append(key)
            cur = cur.parent
        for (v, t) in Q:
            # paths previously pruned because (v, t) was marked: any valid
            # in-edge (w, v) with delta(q, label) = t and (w, q) in T_x
            for w, label, ets in list(self.graph.in_edges(v)):
                if ets <= self._low() or label not in self.dfa.labels:
                    continue
                li = self.dfa.labels.index(label)
                for (q, tt) in self._trans_by_label.get(li, ()):  # (w,q)->(v,t)
                    if tt != t:
                        continue
                    parents = list(tree.all_occs(w, q))
                    if (w, q) == (tree.x, self.dfa.start):
                        parents = [tree.root]
                    for pocc in parents:
                        if pocc.ts <= self._low():
                            continue
                        self._extend(tree, pocc, v, t, ets)

    # -- Algorithm RSPQ (per arriving + tuple) ---------------------------------

    def insert(self, u: object, v: object, label: str, ts: float) -> Set[Pair]:
        self.now = max(self.now, ts)
        before = len(self.result_log)
        if label not in self.dfa.labels:
            return set()
        self.graph.upsert(u, v, label, ts)
        li = self.dfa.labels.index(label)
        low = self._low()
        for (s, t) in self._trans_by_label.get(li, ()):  # lines 5-12
            if s == self.dfa.start:
                tree = self.delta.get(u)
                if tree is None:
                    tree = _STree(u, self.dfa.start)
                    self.delta[u] = tree
                self._extend(tree, tree.root, v, t, ts)
            for x, tree in list(self.delta.items()):
                for parent in list(tree.all_occs(u, s)):
                    if parent.ts <= low or parent is tree.root:
                        continue
                    self._extend(tree, parent, v, t, ts)
        return {p for (_ts, p) in self.result_log[before:]}

    # -- Algorithm ExpiryRSPQ ---------------------------------------------------

    def expire(self, tau: Optional[float] = None) -> Set[Pair]:
        if tau is not None:
            self.now = max(self.now, tau)
        low = self._low()
        self.graph.prune(low)
        invalidated: Set[Pair] = set()
        for x, tree in list(self.delta.items()):
            invalidated |= self._expire_tree(tree, low)
            if tree.n_nodes() <= 1:
                del self.delta[x]
        return invalidated

    def _expire_tree(self, tree: _STree, low: float) -> Set[Pair]:
        # E: expired occurrences (line 2)
        expired = [occ for lst in tree.occs.values() for occ in lst
                   if occ.ts <= low and occ is not tree.root]
        if not expired:
            return set()
        P = {(o.vertex, o.state) for o in expired} & tree.markings  # line 3
        for occ in expired:  # lines 4-5
            tree.remove(occ)
            if occ.parent is not None:
                occ.parent.children.discard(occ)
            occ.parent = None
        for key in list(P):
            if not tree.all_occs(*key):
                tree.markings.discard(key)
        # lines 6-11: reconnect marked expired pairs from valid parents
        for (v, t) in list(P):
            for u, label, ets in list(self.graph.in_edges(v)):
                if ets <= low or label not in self.dfa.labels:
                    continue
                li = self.dfa.labels.index(label)
                for (s, tt) in self._trans_by_label.get(li, ()):
                    if tt != t:
                        continue
                    parents = list(tree.all_occs(u, s))
                    if (u, s) == (tree.x, self.dfa.start):
                        parents = [tree.root]
                    for pocc in parents:
                        if pocc.ts <= low:
                            continue
                        self._extend(tree, pocc, v, t, ets)
        # lines 12-19: invalidations (the marking-restoration step of the
        # paper's listing is under-specified; we conservatively leave parents
        # unmarked — correctness of result sets is oracle-validated)
        invalidated: Set[Pair] = set()
        for (v, t) in P:
            if t in self.dfa.finals and not tree.all_occs(v, t):
                if not any(tree.all_occs(v, tf) for tf in self.dfa.finals):
                    invalidated.add((tree.x, v))
        return invalidated

    def current_results(self) -> Set[Pair]:
        low = self._low()
        out: Set[Pair] = set()
        for x, tree in self.delta.items():
            for (v, t), lst in tree.occs.items():
                if t in self.dfa.finals and any(
                    o.ts > low and o is not tree.root for o in lst
                ):
                    out.add((x, v))
        return out

    def index_size(self) -> Tuple[int, int]:
        return len(self.delta), sum(t.n_nodes() for t in self.delta.values())
