"""Row-sparse dist: per-source-row reachable sets + bounded overflow.

The dense engine stores closure state as a ``(Q, N, N, K)`` timestamp
slab — at N=100k a single K=2 query needs ~80 GB, so dist memory and
the O(Q·N²) emit scan cap N even though the PR 8 adjacency is already
∝ live edges.  But each ``(q, x)`` source row is an independent
single-source problem (the (max, min) recurrence couples
``dist[q, x, v, t]`` only to ``dist[q, x, u, s]`` — the same row), and
on sparse streaming windows almost every ``(v, k)`` entry of a row is
unreachable (``-inf``).  This module is the sparse alternative: per
``(q, x)`` row we keep at most ``dist_cap`` reachable entries
(``idx``/``ts`` slot pairs, ``idx`` a flattened ``v * K + k`` key),
where ``dist_cap`` is a power-of-2 capacity bucketed exactly like the
Q/F/ELL capacities so jit compile caches are reused.

Rows can overflow.  Overflow never loses an entry and never aborts the
dispatch: a row that exceeds ``dist_cap`` is routed to the *overflow
table* — ``ovf_rows`` row ids plus full dense ``ovf_ts`` rows — inside
the same jitted step (``rsd_scatter_rows``), the exact row-granular
form of the frontier's ``lax.cond`` dense-superset fallback.  The host
keeps a conservative budget of how many rows could have claimed
overflow slots since the last drain and re-packs (growing ``dist_cap``
×2) before the table can fill, so the row-sparse layout is
bit-identical to the dense slab at every observable point — the
contract docs/invariants.md records as the row-sparse overflow
contract.

A row lives EITHER in its slots OR in the overflow table (slots are
cleared when a row is routed to overflow), so every read path may
max-fold both regions without double counting.  Free slots hold
``ts == NEG_INF``; their ``idx`` may be stale, which is benign
everywhere the ELL layout's stale indices are (max folds, threshold
reads).

Everything here except ``pack_rows`` (host-side, numpy) is traceable
and runs inside the executor's jitted step functions.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = float("-inf")


class RowSparseDist(NamedTuple):
    """Row-sparse closure state (a pytree; jit-transparent).

    ``ts``/``ovf_ts`` dtype is float32 in executor state — the
    canonical inter-dispatch representation.  Backend encodes happen on
    the dense slabs gathered FROM this structure (the frontier slab,
    the fallback densify), never on the structure itself, so clock-
    anchored backends see exactly the operands the dense layout feeds
    them.
    """

    idx: jax.Array       # (Q, N, C) int32 — flattened v * K + k key per slot
    ts: jax.Array        # (Q, N, C)       — entry timestamp; NEG_INF = free
    ovf_rows: jax.Array  # (R,) int32 — flattened q * N + x row id; -1 = free
    ovf_ts: jax.Array    # (R, N*K)   — full dense overflow rows
    ovf_ptr: jax.Array   # () int32 — claim cursor; host budget keeps < R
    lost: jax.Array      # () int32 — rows dropped with the table full

    @property
    def n_lanes(self) -> int:
        return self.idx.shape[0]

    @property
    def n_slots(self) -> int:
        return self.idx.shape[1]

    @property
    def dist_cap(self) -> int:
        return self.idx.shape[2]

    @property
    def ovf_cap(self) -> int:
        return self.ovf_rows.shape[0]

    @property
    def k(self) -> int:
        return self.ovf_ts.shape[1] // self.idx.shape[1]


def rsd_empty_np(q: int, n: int, k: int, dist_cap: int,
                 ovf_cap: int) -> RowSparseDist:
    """Host-side empty row-sparse state (mirrors ``Executor.init_state``)."""
    return RowSparseDist(
        idx=np.zeros((q, n, dist_cap), np.int32),
        ts=np.full((q, n, dist_cap), NEG_INF, np.float32),
        ovf_rows=np.full((ovf_cap,), -1, np.int32),
        ovf_ts=np.full((ovf_cap, n * k), NEG_INF, np.float32),
        ovf_ptr=np.zeros((), np.int32),
        lost=np.zeros((), np.int32),
    )


def pack_rows(dense: np.ndarray, dist_cap: int,
              ovf_cap: int) -> RowSparseDist:
    """Host-side pack of a dense ``(Q, N, N, K)`` slab into row sets.

    Rows whose finite-entry count fits ``dist_cap`` go to slots; the
    rest go to the overflow table.  The caller sizes ``ovf_cap`` to at
    least the overflowing-row count (``Executor.place`` grows it ×2
    until it fits); raising instead of silently dropping keeps the
    repack→drain invariant auditable.
    """
    dense = np.asarray(dense, np.float32)
    q, n, _, k = dense.shape
    out = rsd_empty_np(q, n, k, dist_cap, ovf_cap)
    flat = dense.reshape(q, n, n * k)
    finite = flat > NEG_INF
    counts = finite.sum(-1)
    over_q, over_x = np.nonzero(counts > dist_cap)
    if over_q.size > ovf_cap:
        raise ValueError(
            f"pack_rows: {over_q.size} rows exceed dist_cap={dist_cap} but "
            f"ovf_cap={ovf_cap}; grow the capacity before packing")
    fit_q, fit_x, fit_e = np.nonzero(
        finite & (counts <= dist_cap)[:, :, None])
    if fit_q.size:
        rank = (np.cumsum(finite, axis=-1) - 1)[fit_q, fit_x, fit_e]
        out.idx[fit_q, fit_x, rank] = fit_e
        out.ts[fit_q, fit_x, rank] = flat[fit_q, fit_x, fit_e]
    if over_q.size:
        slots = np.arange(over_q.size)
        out.ovf_rows[slots] = over_q.astype(np.int64) * n + over_x
        out.ovf_ts[slots] = flat[over_q, over_x]
        out.ovf_ptr[...] = over_q.size
    return out


def rsd_to_dense(sd: RowSparseDist) -> jax.Array:
    """Densify to the canonical ``(Q, N, N, K)`` slab (traceable).

    Exact inverse of ``pack_rows`` up to slot order: max-folding makes
    free slots and the slots-XOR-overflow row split no-ops.
    """
    q, n, _c = sd.idx.shape
    e = sd.ovf_ts.shape[1]
    k = e // n
    flat = jnp.full((q, n, e), NEG_INF, sd.ts.dtype)
    flat = flat.at[jnp.arange(q)[:, None, None],
                   jnp.arange(n)[None, :, None], sd.idx].max(sd.ts)
    live = sd.ovf_rows >= 0
    row = jnp.where(live, sd.ovf_rows, 0)
    vals = jnp.where(live[:, None], sd.ovf_ts, NEG_INF)
    flat = flat.at[row // n, row % n].max(vals)
    return flat.reshape(q, n, n, k)


def rsd_from_dense(dense: jax.Array, dist_cap: int, ovf_cap: int,
                   lost: Optional[jax.Array] = None) -> RowSparseDist:
    """Full in-jit repack of a dense ``(Q, N, N, K)`` slab.

    The traced twin of ``pack_rows`` — the tail of every dense-superset
    path (the frontier fallback branch, the non-frontier round trip):
    fitting rows pack their finite entries into slots by cumsum rank,
    overflowing rows claim fresh overflow slots in row order, and rows
    beyond ``ovf_cap`` are counted into ``lost`` (the host budget keeps
    this leg unreachable; a nonzero count is a detectable, repairable
    condition — see docs/invariants.md).
    """
    q, n, _, k = dense.shape
    e = n * k
    flat = dense.reshape(q, n, e)
    finite = flat > NEG_INF
    counts = jnp.sum(finite, axis=-1)
    fits = counts <= dist_cap
    rank = jnp.cumsum(finite, axis=-1) - 1
    pos = jnp.where(finite & fits[:, :, None], rank, dist_cap)
    lane = jnp.arange(q)[:, None, None]
    slot = jnp.arange(n)[None, :, None]
    cols = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32), (q, n, e))
    idx = jnp.zeros((q, n, dist_cap), jnp.int32).at[
        lane, slot, pos].set(cols, mode="drop")
    ts = jnp.full((q, n, dist_cap), NEG_INF, flat.dtype).at[
        lane, slot, pos].set(flat, mode="drop")
    over = (~fits).reshape(q * n)
    opos = jnp.where(over, jnp.cumsum(over) - 1, ovf_cap)
    ovf_rows = jnp.full((ovf_cap,), -1, jnp.int32).at[opos].set(
        jnp.arange(q * n, dtype=jnp.int32), mode="drop")
    ovf_ts = jnp.full((ovf_cap, e), NEG_INF, flat.dtype).at[opos].set(
        flat.reshape(q * n, e), mode="drop")
    n_over = jnp.sum(over).astype(jnp.int32)
    dropped = jnp.maximum(n_over - ovf_cap, 0)
    base = jnp.asarray(0, jnp.int32) if lost is None else lost
    return RowSparseDist(idx, ts, ovf_rows, ovf_ts,
                         jnp.minimum(n_over, ovf_cap), base + dropped)


def rsd_empty_like(sd: RowSparseDist) -> RowSparseDist:
    """Every row cleared — the from-scratch ``dist0`` of the dense delete
    path. ``lost`` is preserved (a monotone diagnostic, never reset);
    ``idx`` is left stale, which free slots make benign."""
    return sd._replace(ts=jnp.full_like(sd.ts, NEG_INF),
                       ovf_rows=jnp.full_like(sd.ovf_rows, -1),
                       ovf_ts=jnp.full_like(sd.ovf_ts, NEG_INF),
                       ovf_ptr=jnp.zeros_like(sd.ovf_ptr))


def _ovf_lookup(sd: RowSparseDist, key: jax.Array):
    """Overflow-table membership for flattened row keys (any shape):
    returns ``(has, slot)`` — free entries (-1) never match (keys are
    >= 0)."""
    match = key[..., None] == sd.ovf_rows
    return jnp.any(match, axis=-1), jnp.argmax(match, axis=-1)


def rsd_gather_rows(sd: RowSparseDist, rows: jax.Array,
                    gather_fn=None) -> jax.Array:
    """Densify the frontier rows: ``out[q, f] == dense[q, rows[q, f]]``
    of shape (Q, F, N, K) — the slab the frontier round loop relaxes.

    ``gather_fn(idx, ts, e) -> (M, E)`` is the backend's slot-densify
    hook (``ContractionBackend.gather_dist_rows``); overflow rows fold
    in afterwards with plain jnp (at most one table hit per row).
    Operands and results are raw f32 timestamps — the caller encodes
    the slab at the backend boundary, exactly where the dense layout
    encodes its gathered slab.
    """
    if gather_fn is None:
        from ..kernels.rowsparse.ops import rowsparse_gather
        gather_fn = rowsparse_gather
    q, n, c = sd.idx.shape
    e = sd.ovf_ts.shape[1]
    k = e // n
    f = rows.shape[1]
    lane = jnp.arange(q)[:, None]
    sid = sd.idx[lane, rows]                       # (Q, F, C)
    sts = sd.ts[lane, rows]
    flat = gather_fn(sid.reshape(q * f, c),
                     sts.reshape(q * f, c), e).reshape(q, f, e)
    has, oslot = _ovf_lookup(sd, lane * n + rows)  # (Q, F)
    flat = jnp.where(has[:, :, None],
                     jnp.maximum(flat, sd.ovf_ts[oslot]), flat)
    return flat.reshape(q, f, n, k)


def rsd_scatter_rows(sd: RowSparseDist, rows: jax.Array,
                     rowmask: jax.Array, slab: jax.Array) -> RowSparseDist:
    """Scatter relaxed frontier rows back into the row sets — the
    in-dispatch half of the overflow contract.

    Each valid ``(q, f)`` slot holds the COMPLETE new value of row
    ``rows[q, f]`` (the slab starts as the gathered row and only grows
    under the max fold for inserts; deletes re-derive from scratch), so
    the write is a full-row overwrite — exact even when a row shrinks:

    * rows already in the overflow table overwrite their table row;
    * rows whose finite count fits ``dist_cap`` overwrite their slots
      (cleared first so stale high-rank entries die);
    * rows newly exceeding ``dist_cap`` claim fresh table slots at the
      cursor (their slots are cleared — a row lives in one region);
    * claims past ``ovf_cap`` drop the row and count into ``lost`` —
      unreachable under the host budget (``Executor._reserve_dist``).

    Valid frontier rows are unique per lane (``pack_frontier`` packs a
    mask), so the scatters are collision-free; masked padding slots are
    routed to drop sentinels.
    """
    q, f, n, k = slab.shape
    e = n * k
    c = sd.idx.shape[2]
    r = sd.ovf_rows.shape[0]
    flat = slab.reshape(q, f, e)
    finite = flat > NEG_INF
    counts = jnp.sum(finite, axis=-1)                    # (Q, F)
    fits = counts <= c
    lane = jnp.arange(q)[:, None]
    key = lane * n + rows
    in_ovf, oslot = _ovf_lookup(sd, key)
    # -- overflow-table writes (existing hit, or fresh claim in order)
    new_claim = rowmask & ~fits & ~in_ovf
    crank = (jnp.cumsum(new_claim.reshape(-1)) - 1).reshape(q, f)
    dest = jnp.where(in_ovf, oslot, sd.ovf_ptr + crank)
    write_ovf = rowmask & (in_ovf | ~fits)
    dest = jnp.where(write_ovf, jnp.minimum(dest, r), r)  # r = drop sentinel
    ovf_rows2 = sd.ovf_rows.at[dest].set(key, mode="drop")
    ovf_ts2 = sd.ovf_ts.at[dest].set(flat, mode="drop")
    n_new = jnp.sum(new_claim).astype(jnp.int32)
    dropped = jnp.sum(new_claim & (sd.ovf_ptr + crank >= r)).astype(jnp.int32)
    # -- slot writes: clear every valid row, then pack the fitting ones
    clear_row = jnp.where(rowmask, rows, n)
    ts1 = sd.ts.at[lane, clear_row].set(NEG_INF, mode="drop")
    write_slots = rowmask & fits & ~in_ovf
    srow = jnp.where(write_slots, rows, n)[:, :, None]    # n = drop sentinel
    rank = jnp.cumsum(finite, axis=-1) - 1
    pos = jnp.where(finite & fits[:, :, None], rank, c)
    cols = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32), (q, f, e))
    lane3 = lane[:, :, None]
    idx2 = sd.idx.at[lane3, srow, pos].set(cols, mode="drop")
    ts2 = ts1.at[lane3, srow, pos].set(flat, mode="drop")
    return RowSparseDist(idx2, ts2, ovf_rows2, ovf_ts2,
                         jnp.minimum(sd.ovf_ptr + n_new, r),
                         sd.lost + dropped)


def rsd_seed_gathered(sd: RowSparseDist, src: jax.Array, smask: jax.Array,
                      query_mask: Optional[jax.Array] = None) -> jax.Array:
    """(Q, N) dirty-row mask of a batch — the row-sparse twin of
    :func:`~repro.core.semiring.frontier_seed`, walking only stored
    entries: O(Q·N·C + R·N·K) instead of the dense O(Q·N²·K) scan.
    Exact: the slots and overflow rows hold exactly the finite entries
    the dense reduction tests, and free slots cannot hit."""
    q, n, _c = sd.idx.shape
    e = sd.ovf_ts.shape[1]
    k = e // n
    idx_b = jnp.where(smask, src, n)
    src_mask = jnp.zeros((n,), bool).at[idx_b].set(True, mode="drop")
    hit = (sd.ts > NEG_INF) & src_mask[sd.idx // k]
    reach = jnp.any(hit, axis=-1).astype(jnp.int32)        # (Q, N)
    live = sd.ovf_rows >= 0
    row = jnp.where(live, sd.ovf_rows, 0)
    ovf = sd.ovf_ts.reshape(-1, n, k)
    hit_r = jnp.any((ovf > NEG_INF) & src_mask[None, :, None],
                    axis=(1, 2)) & live
    reach = reach.at[row // n, row % n].max(hit_r.astype(jnp.int32))
    dirty = (reach > 0) | src_mask[None, :]
    if query_mask is not None:
        dirty = dirty & query_mask[:, None]
    return dirty


def rsd_valid_pairs(sd: RowSparseDist, finals: jax.Array,
                    low: jax.Array) -> jax.Array:
    """(Q, N, N) bool validity per query — the sparse emit.

    The dense scan reduces all Q·N²·K entries against the finals mask
    and the window threshold; here only stored entries contribute:
    slot entries scatter-or into their (q, x, v) cell, overflow rows
    reduce their dense row once.  Identical to
    ``batched_valid_pairs(rsd_to_dense(sd), finals, low)`` — a free
    slot's -inf can never clear a finite threshold.
    """
    q, n, _c = sd.idx.shape
    e = sd.ovf_ts.shape[1]
    k = e // n
    lane = jnp.arange(q)[:, None, None]
    slot = jnp.arange(n)[None, :, None]
    ok = (finals[lane, sd.idx % k] & (sd.ts > low[:, None, None]))
    valid = jnp.zeros((q, n, n), jnp.int32).at[
        lane, slot, sd.idx // k].max(ok.astype(jnp.int32))
    live = sd.ovf_rows >= 0
    row = jnp.where(live, sd.ovf_rows, 0)
    q_r = row // n
    ovf = sd.ovf_ts.reshape(-1, n, k)
    ok_r = jnp.any((ovf > low[q_r][:, None, None])
                   & finals[q_r][:, None, :], axis=2)
    ok_r = ok_r & live[:, None]
    valid = valid.at[q_r, row % n].max(ok_r.astype(jnp.int32))
    return valid > 0


def rsd_clear_slots(sd: RowSparseDist, dead: jax.Array) -> RowSparseDist:
    """Clear every entry whose source OR destination vertex slot is
    dead (``dead``: (N,) bool), mirroring the dense row+column
    ``.set(NEG_INF)`` of ``Executor._clear_slots``."""
    _q, n, _c = sd.idx.shape
    e = sd.ovf_ts.shape[1]
    k = e // n
    ts = jnp.where(dead[None, :, None], NEG_INF, sd.ts)       # source rows
    ts = jnp.where(dead[sd.idx // k], NEG_INF, ts)            # dest entries
    live = sd.ovf_rows >= 0
    row = jnp.where(live, sd.ovf_rows, 0)
    kill_row = dead[row % n] & live                           # (R,)
    ovf = sd.ovf_ts.reshape(-1, n, k)
    ovf = jnp.where(dead[None, :, None], NEG_INF, ovf)        # dest slots
    ovf = jnp.where(kill_row[:, None, None], NEG_INF, ovf)
    return sd._replace(ts=ts, ovf_ts=ovf.reshape(sd.ovf_ts.shape))


def rsd_clear_lane(sd: RowSparseDist, lane: jax.Array) -> RowSparseDist:
    """Clear one query lane (mirrors the dense ``dist.at[lane].set``)."""
    n = sd.idx.shape[1]
    live = sd.ovf_rows >= 0
    hit = (jnp.where(live, sd.ovf_rows, -1) // n) == lane
    return sd._replace(
        ts=sd.ts.at[lane].set(NEG_INF),
        ovf_ts=jnp.where(hit[:, None], NEG_INF, sd.ovf_ts))


def rsd_row_counts(sd: RowSparseDist) -> jax.Array:
    """(Q, N) finite-entry count per row (slots + overflow) — the
    occupancy signal drains size ``dist_cap`` growth from."""
    n = sd.idx.shape[1]
    counts = jnp.sum(sd.ts > NEG_INF, axis=-1).astype(jnp.int32)
    live = sd.ovf_rows >= 0
    row = jnp.where(live, sd.ovf_rows, 0)
    ovf_counts = jnp.where(
        live, jnp.sum(sd.ovf_ts > NEG_INF, axis=-1), 0).astype(jnp.int32)
    return counts.at[row // n, row % n].add(ovf_counts)


def rsd_live_entries(sd: RowSparseDist) -> jax.Array:
    """Device count of finite entries — occupancy telemetry (read only
    at drain boundaries, like ``ell_live_edges``)."""
    live = sd.ovf_rows >= 0
    return (jnp.sum(sd.ts > NEG_INF).astype(jnp.int32)
            + jnp.sum((sd.ovf_ts > NEG_INF)
                      & live[:, None]).astype(jnp.int32))


def rsd_grow_repack(sd: RowSparseDist, dist_cap: int,
                    ovf_cap: int) -> RowSparseDist:
    """Re-pack into grown capacities WITHOUT densifying (O(Q·N·C + R·E)
    instead of O(Q·N²·K)) — the drain-boundary representation change.

    Slot rows copy over (capacity only grows); live overflow rows whose
    finite count now fits ``dist_cap`` pack into their slots, the rest
    re-claim compacted overflow positions.  Pure representation change:
    densify before == densify after (the drain invariant).
    """
    q, n, c = sd.idx.shape
    e = sd.ovf_ts.shape[1]
    pad_c = dist_cap - c
    idx = jnp.pad(sd.idx, ((0, 0), (0, 0), (0, pad_c)))
    ts = jnp.pad(sd.ts, ((0, 0), (0, 0), (0, pad_c)),
                 constant_values=NEG_INF)
    live = sd.ovf_rows >= 0
    finite = (sd.ovf_ts > NEG_INF) & live[:, None]            # (R, E)
    counts = jnp.sum(finite, axis=-1)
    fits = live & (counts <= dist_cap)
    row = jnp.where(live, sd.ovf_rows, 0)
    q_r, x_r = row // n, row % n
    # pack fitting overflow rows into their (now larger) slot rows
    rank = jnp.cumsum(finite, axis=-1) - 1
    pos = jnp.where(finite & fits[:, None], rank, dist_cap)
    cols = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32),
                            sd.ovf_ts.shape)
    idx = idx.at[q_r[:, None], x_r[:, None], pos].set(cols, mode="drop")
    ts = ts.at[q_r[:, None], x_r[:, None], pos].set(sd.ovf_ts, mode="drop")
    # compact the remaining overflow rows into the (possibly grown) table
    overs = live & ~fits
    opos = jnp.where(overs, jnp.cumsum(overs) - 1, ovf_cap)
    ovf_rows = jnp.full((ovf_cap,), -1, jnp.int32).at[opos].set(
        sd.ovf_rows, mode="drop")
    ovf_ts = jnp.full((ovf_cap, e), NEG_INF, sd.ovf_ts.dtype).at[
        opos].set(sd.ovf_ts, mode="drop")
    return RowSparseDist(idx, ts, ovf_rows, ovf_ts,
                         jnp.sum(overs).astype(jnp.int32), sd.lost)
