"""Executor layer: everything device-facing of the batched dense RPQ engine.

The engine (:mod:`repro.core.engine`) is pure orchestration — vertex
interning, query lifecycle, result decoding, checkpoint metadata. The
device state and every jitted dispatch live behind the narrow interface of
:class:`Executor`:

    ingest_batch / delete_batch   one dispatch per micro-batch
    relax                         closure-to-fixpoint in place (lane seeding,
                                  deletion re-derivation)
    emit                          per-query window-valid pairs (device)
    arrays / place / grow         state access, (re)placement, capacity growth
    expire / clear_slots / ...    maintenance ops

Two implementations:

  * :class:`LocalExecutor` — the single-device path, bit-identical to the
    pre-refactor engine (the jitted step functions here ARE the engine's
    old ones, moved verbatim so the jit cache behaves the same).
  * :class:`~repro.distributed.executor.MeshExecutor` — shards the
    ``(Q, N, N, K)`` closure state over a device mesh (Q over ``data``,
    optionally the vertex axis over ``model``) and keeps the per-query
    convergence mask device-resident so converged/inert lanes skip their
    contraction work per shard (convergence-aware dispatch).

Round accounting also lives here (the executor is the only layer that
knows what actually ran): ``rounds_total`` (global closure iterations),
``query_rounds_total`` (sum over queries of ACTIVE rounds under the
convergence mask), and ``unmasked_query_rounds_total`` (what the same
dispatches would have cost with every live lane riding to the global
fixpoint). Benchmarks read these counters instead of re-deriving them —
re-derivation double-counted after lane churn. Counts are accumulated
lazily (device scalars queued, converted on first read) so the streaming
hot path never blocks on a host sync.
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .backend import BackendLike, ContractionBackend, resolve_backend
from .semiring import (
    NEG_INF,
    BatchedTransitionTable,
    batched_closure,
    batched_valid_pairs,
    frontier_closure,
    frontier_delete,
)
from .sparse_adj import (
    EllAdjacency,
    ell_clear_slots,
    ell_delete,
    ell_expire,
    ell_incident,
    ell_insert,
    ell_max_degree,
    ell_to_dense,
    pack_ell,
)
from .sparse_dist import (
    RowSparseDist,
    pack_rows,
    rsd_clear_lane,
    rsd_clear_slots,
    rsd_empty_like,
    rsd_grow_repack,
    rsd_live_entries,
    rsd_row_counts,
    rsd_to_dense,
)

FRONTIER_MODES = ("off", "on", "auto")

#: adjacency representations: "dense" is the canonical (L, N, N) slab,
#: "ell" the blocked-sparse padded-ELL rows + spill ring (sparse_adj.py).
#: The layout is an executor-construction choice, invisible to results —
#: every dispatch is bit-identical across layouts (the conformance suite
#: and docs/invariants.md "bit-identical spill" pin this).
ADJ_LAYOUTS = ("dense", "ell")

#: dist representations: "dense" is the canonical (Q, N, N, K) slab,
#: "row_sparse" the per-(q, x) reachable-set layout (sparse_dist.py) —
#: per-row slot sets plus a bounded overflow table, with the sparse emit
#: that breaks the O(Q·N²) per-event scan. Same contract as ADJ_LAYOUTS:
#: a construction choice, invisible to results (the conformance suite and
#: docs/invariants.md "row-sparse overflow contract" pin this).
DIST_LAYOUTS = ("dense", "row_sparse")


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 1).bit_length()


class BatchedEngineArrays(NamedTuple):
    adj: jnp.ndarray      # (L, N, N) f32 shared
    dist: jnp.ndarray     # (Q, N, N, K) f32
    emitted: jnp.ndarray  # (Q, N, N) bool
    now: jnp.ndarray      # () f32


def init_batched_arrays(
    n_slots: int, n_labels: int, n_queries: int, k: int
) -> BatchedEngineArrays:
    return BatchedEngineArrays(
        adj=jnp.full((n_labels, n_slots, n_slots), NEG_INF, jnp.float32),
        dist=jnp.full((n_queries, n_slots, n_slots, k), NEG_INF, jnp.float32),
        emitted=jnp.zeros((n_queries, n_slots, n_slots), bool),
        now=jnp.asarray(NEG_INF, jnp.float32),
    )


class QueryTables(NamedTuple):
    """Per-lane metadata the engine rebuilds at lifecycle events and the
    executor consumes at every dispatch. ``n_live`` is the host-side live
    lane count (for unmasked-regime round accounting); ``max_window`` is
    the group's retention threshold (largest live window, sticky across an
    empty query set) — clock-anchored backends (mxu_bucket) derive their
    level grid from it at every dispatch."""

    btt: BatchedTransitionTable
    finals_mask: jnp.ndarray  # (Q, K) bool
    windows: jnp.ndarray      # (Q,) f32
    live_mask: jnp.ndarray    # (Q,) bool
    n_live: int
    max_window: float = 0.0


# ---------------------------------------------------------------------------
# jitted step functions (pure; shared across LocalExecutor instances so the
# jit cache is process-wide, exactly as when they lived on the engine)
# ---------------------------------------------------------------------------


def apply_batch(arrays: BatchedEngineArrays, src, dst, lab, ts, mask,
                ts_floor):
    """The ingest dispatch prologue, shared by the dense and frontier
    forms on BOTH executors: fold the masked batch into the adjacency
    (newest-timestamp max) and advance the stream clock. Returns
    ``(adj, now)``. The adjacency layout branches at TRACE time (an
    EllAdjacency is a different pytree, so each layout owns its compile
    cache entry): ELL scatters into row slots with in-dispatch spill on
    per-row overflow — same max-fold, same clock."""
    eff_ts = jnp.where(mask, ts, NEG_INF)
    if isinstance(arrays.adj, EllAdjacency):
        adj = ell_insert(arrays.adj, src, dst, lab, eff_ts, mask)
    else:
        adj = arrays.adj.at[lab, src, dst].max(eff_ts, mode="drop")
    now = jnp.maximum(arrays.now, jnp.maximum(jnp.max(eff_ts), ts_floor))
    return adj, now


def drop_batch(arrays: BatchedEngineArrays, src, dst, lab, mask):
    """The delete dispatch prologue on both layouts: clear the masked
    batch's adjacency entries (every stored copy for ELL — row slots AND
    ring). Returns the retained adjacency."""
    if isinstance(arrays.adj, EllAdjacency):
        return ell_delete(arrays.adj, src, dst, lab, mask)
    drop = jnp.where(mask, jnp.asarray(NEG_INF, jnp.float32),
                     arrays.adj[lab, src, dst])
    return arrays.adj.at[lab, src, dst].set(drop, mode="drop")


def emit_new(arrays: BatchedEngineArrays, dist, adj, now, finals_mask,
             windows):
    """The ingest dispatch epilogue, shared likewise: per-query window
    validity at the new clock, diffed against the emitted frontier.
    Returns ``(new_arrays, new)``."""
    low = now - windows
    valid = batched_valid_pairs(dist, finals_mask, low)
    new = jnp.logical_and(valid, jnp.logical_not(arrays.emitted))
    emitted = jnp.logical_or(arrays.emitted, valid)
    return BatchedEngineArrays(adj, dist, emitted, now), new


@functools.partial(jax.jit, static_argnames=("backend",), donate_argnums=(0,))
def _ingest(
    arrays: BatchedEngineArrays,
    src: jnp.ndarray,          # (B,) int32 slot ids
    dst: jnp.ndarray,          # (B,) int32
    lab: jnp.ndarray,          # (B,) int32 shared-alphabet label ids
    ts: jnp.ndarray,           # (B,) f32
    mask: jnp.ndarray,         # (B,) bool  (padding)
    ts_floor: jnp.ndarray,     # () f32 max event time of the WHOLE chunk
    btt: BatchedTransitionTable,
    finals_mask: jnp.ndarray,  # (Q, K) bool
    windows: jnp.ndarray,      # (Q,) f32
    live_mask: jnp.ndarray,    # (Q,) bool: False for inert padding lanes
    w_max: jnp.ndarray,        # () f32 group retention threshold
    backend: BackendLike = "jnp",
):
    adj, now = apply_batch(arrays, src, dst, lab, ts, mask, ts_floor)
    dist, rounds, qrounds = batched_closure(
        arrays.dist, adj, btt, backend, query_mask=live_mask,
        now=now, w_max=w_max,
    )
    out, new = emit_new(arrays, dist, adj, now, finals_mask, windows)
    return out, new, rounds, qrounds


@functools.partial(jax.jit, static_argnames=("backend", "f_cap"),
                   donate_argnums=(0,))
def _ingest_frontier(
    arrays: BatchedEngineArrays,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    lab: jnp.ndarray,
    ts: jnp.ndarray,
    mask: jnp.ndarray,
    ts_floor: jnp.ndarray,
    btt: BatchedTransitionTable,
    finals_mask: jnp.ndarray,
    windows: jnp.ndarray,
    live_mask: jnp.ndarray,
    w_max: jnp.ndarray,
    backend: BackendLike = "jnp",
    f_cap: int = 32,
):
    """Frontier-restricted ingest: identical to :func:`_ingest` except the
    closure relaxes only the rows the batch dirtied (seeded in-dispatch
    from the batch itself), falling back to the dense loop when a lane's
    dirty set overflows ``f_cap`` (a runtime bit, not a recompile).
    Results are bit-identical to the dense dispatch by construction."""
    adj, now = apply_batch(arrays, src, dst, lab, ts, mask, ts_floor)
    dist, rounds, qrounds, fstats = frontier_closure(
        arrays.dist, adj, btt, backend, src, mask, f_cap,
        query_mask=live_mask, now=now, w_max=w_max,
    )
    out, new = emit_new(arrays, dist, adj, now, finals_mask, windows)
    return out, new, rounds, qrounds, fstats


@functools.partial(jax.jit, static_argnames=("backend",), donate_argnums=(0,))
def _delete(
    arrays: BatchedEngineArrays,
    src: jnp.ndarray,          # (B,) int32
    dst: jnp.ndarray,
    lab: jnp.ndarray,
    mask: jnp.ndarray,
    ts_now: jnp.ndarray,       # () f32 event time of the negative tuple(s)
    btt: BatchedTransitionTable,
    finals_mask: jnp.ndarray,
    windows: jnp.ndarray,
    live_mask: jnp.ndarray,    # (Q,) bool
    w_max: jnp.ndarray,        # () f32
    backend: BackendLike = "jnp",
):
    """Explicit deletion (negative tuple): clear adjacency entries and
    recompute every query's closure from scratch — the paper's uniform
    machinery (Delete -> ExpiryRAPQ re-derivation) in dense batched form."""
    now = jnp.maximum(arrays.now, ts_now)
    low = now - windows
    valid_before = batched_valid_pairs(arrays.dist, finals_mask, low)
    adj = drop_batch(arrays, src, dst, lab, mask)
    if isinstance(arrays.dist, RowSparseDist):
        dist0 = rsd_empty_like(arrays.dist)
    else:
        dist0 = jnp.full_like(arrays.dist, NEG_INF)
    dist, rounds, qrounds = batched_closure(
        dist0, adj, btt, backend, query_mask=live_mask,
        now=now, w_max=w_max,
    )
    valid_after = batched_valid_pairs(dist, finals_mask, low)
    invalidated = jnp.logical_and(valid_before, jnp.logical_not(valid_after))
    return (BatchedEngineArrays(adj, dist, arrays.emitted, now),
            invalidated, rounds, qrounds)


@functools.partial(jax.jit, static_argnames=("backend", "f_cap"),
                   donate_argnums=(0,))
def _delete_frontier(
    arrays: BatchedEngineArrays,
    src: jnp.ndarray,          # (B,) int32
    dst: jnp.ndarray,
    lab: jnp.ndarray,
    mask: jnp.ndarray,
    ts_now: jnp.ndarray,       # () f32 event time of the negative tuple(s)
    btt: BatchedTransitionTable,
    finals_mask: jnp.ndarray,
    windows: jnp.ndarray,
    live_mask: jnp.ndarray,
    w_max: jnp.ndarray,
    backend: BackendLike = "jnp",
    f_cap: int = 32,
):
    """Cone-seeded incremental deletion: identical contract to
    :func:`_delete` except only the rows whose derivations can pass
    through the dropped edges (the cone, computed in-dispatch on the
    pre-delete state) are cleared and re-derived; cone overflow falls back
    to the dense from-scratch loop in-dispatch. Bit-identical to
    :func:`_delete` by the superset argument (semiring.frontier_delete)."""
    now = jnp.maximum(arrays.now, ts_now)
    low = now - windows
    valid_before = batched_valid_pairs(arrays.dist, finals_mask, low)
    adj = drop_batch(arrays, src, dst, lab, mask)
    dist, rounds, qrounds, fstats = frontier_delete(
        arrays.dist, adj, btt, backend, src, mask, f_cap,
        query_mask=live_mask, now=now, w_max=w_max,
    )
    valid_after = batched_valid_pairs(dist, finals_mask, low)
    invalidated = jnp.logical_and(valid_before, jnp.logical_not(valid_after))
    return (BatchedEngineArrays(adj, dist, arrays.emitted, now),
            invalidated, rounds, qrounds, fstats)


@jax.jit
def _expire(arrays: BatchedEngineArrays, tau: jnp.ndarray, max_window: jnp.ndarray):
    """Lazy expiration at slide boundaries: mask dead adjacency entries and
    report per-slot liveness for python-side slot recycling. Thresholded at
    the group's LARGEST window (an edge live for any query stays); dist
    needs no update (stale entries fall below each query's own read-time
    validity threshold by construction)."""
    now = jnp.maximum(arrays.now, tau)
    low = now - max_window
    if isinstance(arrays.adj, EllAdjacency):
        adj = ell_expire(arrays.adj, low)
        incident = ell_incident(adj)
    else:
        adj = jnp.where(arrays.adj > low, arrays.adj, NEG_INF)
        incident = jnp.maximum(
            jnp.max(adj, axis=(0, 2)),  # outgoing per u
            jnp.max(adj, axis=(0, 1)),  # incoming per v
        )
    live = incident > low
    return BatchedEngineArrays(adj, arrays.dist, arrays.emitted, now), live


@jax.jit
def _clear_slots(arrays: BatchedEngineArrays, slots: jnp.ndarray):
    """Zero out rows/cols of recycled slots (−inf / False) for ALL queries."""
    n = arrays.emitted.shape[1]
    dead = jnp.zeros((n,), bool).at[slots].set(True, mode="drop")
    if isinstance(arrays.adj, EllAdjacency):
        adj = ell_clear_slots(arrays.adj, dead)
    else:
        adj = arrays.adj.at[:, slots, :].set(NEG_INF, mode="drop")
        adj = adj.at[:, :, slots].set(NEG_INF, mode="drop")
    if isinstance(arrays.dist, RowSparseDist):
        dist = rsd_clear_slots(arrays.dist, dead)
    else:
        dist = arrays.dist.at[:, slots, :, :].set(NEG_INF, mode="drop")
        dist = dist.at[:, :, slots, :].set(NEG_INF, mode="drop")
    emitted = arrays.emitted.at[:, slots, :].set(False, mode="drop")
    emitted = emitted.at[:, :, slots].set(False, mode="drop")
    return BatchedEngineArrays(adj, dist, emitted, arrays.now)


# ---------------------------------------------------------------------------
# Executor base = the single-device (local) implementation
# ---------------------------------------------------------------------------


class Executor:
    """Device-facing half of :class:`~repro.core.engine.BatchedDenseRPQEngine`.

    Owns the :class:`BatchedEngineArrays` state, every jitted dispatch over
    it, and the round accounting. Capacity quanta (``q_multiple`` for the
    lane axis, ``n_multiple`` for the vertex axis) tell the engine what
    granularity this executor can shard: the engine rounds its capacities
    up to them (1 for the local path; the data/model mesh extents for
    :class:`~repro.distributed.executor.MeshExecutor`).
    """

    q_multiple: int = 1
    n_multiple: int = 1

    def __init__(self, backend: BackendLike = "jnp",
                 frontier: str = "off", frontier_cap: int = 32,
                 adj_layout: str = "dense", ell_cap: int = 8,
                 spill_cap: int = 256,
                 dist_layout: str = "dense", dist_cap: int = 16,
                 dist_ovf_cap: Optional[int] = None):
        # first-class ContractionBackend; unknown names raise HERE, at
        # construction (they used to fall silently back to the jnp oracle)
        self.backend: ContractionBackend = resolve_backend(backend)
        if frontier not in FRONTIER_MODES:
            raise ValueError(
                f"unknown frontier mode {frontier!r}; known modes: "
                f"{', '.join(FRONTIER_MODES)}")
        if frontier_cap < 1:
            raise ValueError(f"frontier_cap must be >= 1, got {frontier_cap}")
        if adj_layout not in ADJ_LAYOUTS:
            raise ValueError(
                f"unknown adj_layout {adj_layout!r}; known layouts: "
                f"{', '.join(ADJ_LAYOUTS)}")
        if ell_cap < 1:
            raise ValueError(f"ell_cap must be >= 1, got {ell_cap}")
        if spill_cap < 1:
            raise ValueError(f"spill_cap must be >= 1, got {spill_cap}")
        if dist_layout not in DIST_LAYOUTS:
            raise ValueError(
                f"unknown dist_layout {dist_layout!r}; known layouts: "
                f"{', '.join(DIST_LAYOUTS)}")
        if dist_cap < 1:
            raise ValueError(f"dist_cap must be >= 1, got {dist_cap}")
        if dist_ovf_cap is not None and dist_ovf_cap < 1:
            raise ValueError(
                f"dist_ovf_cap must be >= 1, got {dist_ovf_cap}")
        #: adjacency representation ("dense" | "ell"); results are layout-
        #: independent, memory and the seed term are not (sparse_adj.py)
        self.adj_layout = adj_layout
        #: per-(label, u) degree capacity — pow2-bucketed like Q/F so the
        #: jit compile cache is reused; grows ×2 at spill drains
        self.ell_cap = _next_pow2(ell_cap) if ell_cap > 1 else 1
        #: spill-ring capacity — the host budget drains before the ring
        #: can hold this many appends, so no append is ever dropped
        self.spill_cap = _next_pow2(spill_cap)
        self._spill_budget = 0    # inserts dispatched since the last drain
        self._ell_repacks = 0
        self._ell_spill_drains = 0
        self._ell_live_edges: Optional[int] = None  # snapshot at last repack
        #: dist representation ("dense" | "row_sparse"); like adj_layout,
        #: results are layout-independent, memory and the emit scan are not
        #: (sparse_dist.py)
        self.dist_layout = dist_layout
        #: per-(q, x) reachable-set capacity — pow2-bucketed like the other
        #: capacities (rule R2); grows ×2 at overflow drains and whenever a
        #: host pack finds a fuller row
        self.dist_cap = _next_pow2(dist_cap) if dist_cap > 1 else 1
        #: overflow-table row capacity; None = sized at first placement to
        #: cover every row at small scale (the tests' lost == 0 guarantee),
        #: clamped so the table's dense rows stay bounded at large N
        self.dist_ovf_cap = (_next_pow2(dist_ovf_cap)
                             if dist_ovf_cap is not None else None)
        self._dist_budget = 0     # claim bound since the last drain
        self._dist_repacks = 0
        self._dist_drains = 0
        self._dist_lost = 0       # host view; refreshed at drains
        self._dist_live_entries: Optional[int] = None
        #: frontier-restricted ingest: "off" = dense dispatch only (the
        #: pre-PR 5 path, bit-identical), "on" = frontier dispatch at a
        #: FIXED capacity, "auto" = frontier dispatch whose capacity grows
        #: ×2 when overflow fallbacks are observed (compile-cache friendly)
        self.frontier = frontier
        self.frontier_cap = _next_pow2(frontier_cap) if frontier_cap > 1 else 1
        self.steps = 0  # jitted ingest/delete dispatches
        self._arrays: Optional[BatchedEngineArrays] = None
        # (rounds_dev, qrounds_dev, n_live, fstats_dev|None, n_slots,
        # is_delete) queue: converted lazily so the per-dispatch hot path
        # never blocks on a device->host sync
        self._pending_counts: List[
            Tuple[object, object, int, object, int, bool]] = []
        self._rounds_total = 0
        self._query_rounds_total = 0
        self._unmasked_query_rounds_total = 0
        # frontier telemetry (aggregated from FrontierStats at flush)
        self._frontier_dispatches = 0
        self._frontier_fallbacks = 0
        self._frontier_rows_relaxed = 0
        self._frontier_dense_row_equiv = 0
        self._frontier_seed_rows = 0
        self._frontier_max_lane_rows = 0
        self._frontier_growth_mark = 0
        # deletion-specific split of the same telemetry (deletes also count
        # in the shared aggregates above: one capacity, one growth policy)
        self._frontier_delete_dispatches = 0
        self._frontier_delete_fallbacks = 0

    # -- state ---------------------------------------------------------------

    def init_state(self, n_slots: int, n_label_slots: int, q_cap: int, k: int) -> None:
        # through place() so subclasses apply their sharding from the very
        # first array (a mesh executor must never materialize the full
        # state on one device)
        self.place({
            "adj": np.full((n_label_slots, n_slots, n_slots), NEG_INF, np.float32),
            "dist": np.full((q_cap, n_slots, n_slots, k), NEG_INF, np.float32),
            "emitted": np.zeros((q_cap, n_slots, n_slots), bool),
            "now": np.float32(NEG_INF),
        })

    @property
    def arrays(self) -> BatchedEngineArrays:
        """The device state (global logical view; np.asarray gathers it)."""
        return self._arrays

    def set_arrays(self, arrays: BatchedEngineArrays) -> None:
        self._arrays = arrays

    def place(self, state: Dict[str, object]) -> None:
        """(Re)place host arrays as this executor's device state — the
        checkpoint-restore entry point (engine.adopt_state builds the
        host-side layout, the executor owns placement/sharding). The
        ``adj`` entry is always the canonical DENSE slab — checkpoints are
        layout-agnostic, so a dense save restores into an ELL executor and
        vice versa; an ELL executor packs here (growing ``ell_cap`` ×2
        until the live max degree fits, so a pack never spills)."""
        adj_dev = self.pack_adj(state["adj"])
        self.set_arrays(BatchedEngineArrays(
            adj_dev,
            self.pack_dist(state["dist"]),
            self._put(np.asarray(state["emitted"], bool), "emitted"),
            self._put(np.asarray(state["now"], np.float32), "now"),
        ))

    def pack_adj(self, adj):
        """Host dense slab -> device adjacency in this executor's layout
        (ELL packs after growing ``ell_cap`` ×2 until the live max degree
        fits, so a pack never spills)."""
        adj_np = np.asarray(adj, np.float32)
        if self.adj_layout == "ell":
            need = int((adj_np > NEG_INF).sum(axis=-1).max()) if adj_np.size \
                else 0
            while self.ell_cap < need:
                self.ell_cap *= 2
            out = self._put_adj(pack_ell(adj_np, self.ell_cap, self.spill_cap))
            self._spill_budget = 0
            return out
        return self._put(adj_np, "adj")

    def pack_dist(self, dist):
        """Host dense slab -> device dist in this executor's layout.

        The row-sparse pack grows ``dist_cap`` ×2 until the fullest row
        fits its slots — a host pack never routes a row to the overflow
        table, the same no-spill-at-pack discipline as :meth:`pack_adj`.
        The overflow table is sized once, at first placement: big enough
        that EVERY row can overflow simultaneously at small scale (so
        nothing is ever lost — the conformance tests' invariant), clamped
        at 4096 rows so its dense (R, N·K) payload stays bounded when N
        is large (where the table is pressure relief, not a fallback —
        drains grow ``dist_cap`` before it can fill)."""
        dist_np = np.asarray(dist, np.float32)
        if self.dist_layout == "row_sparse":
            q, n = dist_np.shape[0], dist_np.shape[1]
            need = int((dist_np > NEG_INF).reshape(q, n, -1).sum(-1).max()) \
                if dist_np.size else 0
            while self.dist_cap < need:
                self.dist_cap *= 2
            if self.dist_ovf_cap is None:
                self.dist_ovf_cap = _next_pow2(min(max(q * n, 64), 4096))
            out = self._put_dist(
                pack_rows(dist_np, self.dist_cap, self.dist_ovf_cap))
            self._dist_budget = 0
            return out
        return self._put(dist_np, "dist")

    def _put(self, arr: np.ndarray, name: str):
        return jnp.asarray(arr)

    def _put_adj(self, ell: EllAdjacency) -> EllAdjacency:
        """Device placement for an ELL adjacency pytree (the mesh executor
        overrides to shard the u-row axis over 'model')."""
        return jax.tree_util.tree_map(jnp.asarray, ell)

    def _put_dist(self, sd: RowSparseDist) -> RowSparseDist:
        """Device placement for a row-sparse dist pytree (the mesh executor
        overrides to shard the lane axis over 'data')."""
        return jax.tree_util.tree_map(jnp.asarray, sd)

    def dense_adj(self) -> jnp.ndarray:
        """The adjacency in canonical dense form regardless of layout —
        checkpoints, retained-edge scans and the reference engines read
        this (maintenance paths; the densify is traced jnp, not a sync)."""
        a = self._arrays.adj
        if isinstance(a, EllAdjacency):
            return ell_to_dense(a)
        return a

    @property
    def adj_shape(self) -> Tuple[int, int, int]:
        """Logical dense ``(L, N, N)`` adjacency shape regardless of layout
        (shape metadata only — never densifies or syncs)."""
        a = self._arrays.adj
        if isinstance(a, EllAdjacency):
            return (a.n_labels, a.n_slots, a.n_slots)
        return tuple(a.shape)

    def dense_dist(self) -> jnp.ndarray:
        """The dist in canonical dense ``(Q, N, N, K)`` form regardless of
        layout — checkpoints, conflict probes and the reference engines
        read this (maintenance paths; the densify is traced jnp, not a
        sync)."""
        d = self._arrays.dist
        if isinstance(d, RowSparseDist):
            return rsd_to_dense(d)
        return d

    @property
    def dist_shape(self) -> Tuple[int, int, int, int]:
        """Logical dense ``(Q, N, N, K)`` dist shape regardless of layout
        (shape metadata only — never densifies or syncs)."""
        d = self._arrays.dist
        if isinstance(d, RowSparseDist):
            q, n, _c = d.idx.shape
            return (q, n, n, d.k)
        return tuple(d.shape)

    def grow(self, *, n_slots: Optional[int] = None, q_cap: Optional[int] = None,
             k: Optional[int] = None, n_label_slots: Optional[int] = None) -> None:
        """Grow device state in place (append-only padding: -inf / False).
        Existing lanes/labels/slots/states keep their indices. Shrinking is
        never performed; passing a smaller capacity is a no-op."""
        a = self._arrays
        # no-op check on shape metadata FIRST: the common lifecycle event
        # (reclaiming an inert lane) must not pay a device->host gather
        if isinstance(a.adj, EllAdjacency):
            l_old, n_old = a.adj.n_labels, a.adj.n_slots
        else:
            l_old, n_old = a.adj.shape[0], a.adj.shape[1]
        q_old, _, _, k_old = self.dist_shape
        n_new = max(n_slots or 0, n_old)
        l_new = max(n_label_slots or 0, l_old)
        q_new = max(q_cap or 0, q_old)
        k_new = max(k or 0, k_old)
        if (n_new, l_new, q_new, k_new) == (n_old, l_old, q_old, k_old):
            return
        # densify-before-gather: growth re-places through the canonical
        # dense slab, so an ELL executor re-packs at the new shape (ring
        # drained as a side effect)
        adj = np.asarray(jax.device_get(self.dense_adj()))
        dist = np.asarray(jax.device_get(self.dense_dist()))
        emitted = np.asarray(jax.device_get(a.emitted))
        adj2 = np.full((l_new, n_new, n_new), NEG_INF, np.float32)
        adj2[:l_old, :n_old, :n_old] = adj
        dist2 = np.full((q_new, n_new, n_new, k_new), NEG_INF, np.float32)
        dist2[:q_old, :n_old, :n_old, :k_old] = dist
        emitted2 = np.zeros((q_new, n_new, n_new), bool)
        emitted2[:q_old, :n_old, :n_old] = emitted
        self.place({"adj": adj2, "dist": dist2, "emitted": emitted2,
                    "now": np.asarray(jax.device_get(a.now))})

    # -- dispatches ----------------------------------------------------------

    def ingest_batch(self, src, dst, lab, ts, mask, ts_floor: float,
                     tables: QueryTables):
        """One jitted ingest dispatch for the whole query group. Returns the
        per-query NEW-validity matrix as a DEVICE array (the engine decodes
        it, possibly deferred so the transfer overlaps the next dispatch).

        With ``frontier != "off"`` the dispatch is the frontier-restricted
        one: per-event work scales with the rows the batch dirties, not N
        (overflow falls back to the dense loop in-dispatch; results are
        bit-identical either way)."""
        if self.adj_layout == "ell":
            self._reserve_spill(len(src))
        if self.dist_layout == "row_sparse":
            self._reserve_dist(self.frontier != "off")
        if self.frontier != "off":
            return self._ingest_frontier_dispatch(
                src, dst, lab, ts, mask, ts_floor, tables)
        self._arrays, new, rounds, qrounds = _ingest(
            self._arrays,
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(lab),
            jnp.asarray(ts), jnp.asarray(mask),
            jnp.asarray(ts_floor, jnp.float32),
            tables.btt, tables.finals_mask, tables.windows, tables.live_mask,
            jnp.asarray(tables.max_window, jnp.float32),
            backend=self.backend,
        )
        self._account(rounds, qrounds, tables.n_live)
        self.steps += 1
        return new

    def _ingest_frontier_dispatch(self, src, dst, lab, ts, mask,
                                  ts_floor: float, tables: QueryTables):
        self._arrays, new, rounds, qrounds, fstats = _ingest_frontier(
            self._arrays,
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(lab),
            jnp.asarray(ts), jnp.asarray(mask),
            jnp.asarray(ts_floor, jnp.float32),
            tables.btt, tables.finals_mask, tables.windows, tables.live_mask,
            jnp.asarray(tables.max_window, jnp.float32),
            backend=self.backend, f_cap=self.frontier_cap,
        )
        self._account(rounds, qrounds, tables.n_live, fstats)
        self.steps += 1
        return new

    def delete_batch(self, src, dst, lab, mask, ts_now: float,
                     tables: QueryTables):
        """Explicit deletion dispatch; returns the invalidated-pairs matrix
        (device).

        With ``frontier != "off"`` the dispatch is the cone-seeded
        incremental one: only rows whose derivations can pass through the
        dropped edges are cleared and re-derived (overflow falls back to
        the dense from-scratch loop in-dispatch; results are bit-identical
        either way)."""
        if self.dist_layout == "row_sparse":
            self._reserve_dist(self.frontier != "off")
        if self.frontier != "off":
            return self._delete_frontier_dispatch(
                src, dst, lab, mask, ts_now, tables)
        self._arrays, invalidated, rounds, qrounds = _delete(
            self._arrays,
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(lab),
            jnp.asarray(mask), jnp.asarray(ts_now, jnp.float32),
            tables.btt, tables.finals_mask, tables.windows, tables.live_mask,
            jnp.asarray(tables.max_window, jnp.float32),
            backend=self.backend,
        )
        self._account(rounds, qrounds, tables.n_live)
        self.steps += 1
        return invalidated

    def _delete_frontier_dispatch(self, src, dst, lab, mask, ts_now: float,
                                  tables: QueryTables):
        self._arrays, invalidated, rounds, qrounds, fstats = _delete_frontier(
            self._arrays,
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(lab),
            jnp.asarray(mask), jnp.asarray(ts_now, jnp.float32),
            tables.btt, tables.finals_mask, tables.windows, tables.live_mask,
            jnp.asarray(tables.max_window, jnp.float32),
            backend=self.backend, f_cap=self.frontier_cap,
        )
        self._account(rounds, qrounds, tables.n_live, fstats, is_delete=True)
        self.steps += 1
        return invalidated

    def relax(self, tables: QueryTables,
              query_mask: Optional[np.ndarray] = None) -> None:
        """Run the batched closure to fixpoint in place (no adjacency
        change): lane seeding at registration (``query_mask`` = just the new
        lane) or any state re-derivation."""
        if self.dist_layout == "row_sparse":
            self._reserve_dist(False)
        a = self._arrays
        mask = tables.live_mask if query_mask is None else jnp.asarray(
            np.asarray(query_mask, bool))
        dist, rounds, qrounds = batched_closure(
            a.dist, a.adj, tables.btt, self.backend, query_mask=mask,
            now=a.now, w_max=jnp.asarray(tables.max_window, jnp.float32),
        )
        self._arrays = a._replace(dist=dist)
        self._account(rounds, qrounds, tables.n_live)

    def emit(self, tables: QueryTables) -> jnp.ndarray:
        """(Q, N, N) bool device matrix of pairs valid over each query's
        window at the current stream clock."""
        a = self._arrays
        low = a.now - tables.windows
        return batched_valid_pairs(a.dist, tables.finals_mask, low)

    def expire(self, tau: float, max_window: float) -> np.ndarray:
        self._arrays, live = _expire(
            self._arrays, jnp.asarray(tau, jnp.float32),
            jnp.asarray(max_window, jnp.float32),
        )
        return np.asarray(live)

    def clear_slots(self, slots: Sequence[int]) -> None:
        self._arrays = _clear_slots(
            self._arrays, jnp.asarray(list(slots), jnp.int32)
        )

    def clear_lane(self, lane: int) -> None:
        a = self._arrays
        if isinstance(a.dist, RowSparseDist):
            dist = rsd_clear_lane(a.dist, jnp.asarray(lane, jnp.int32))
        else:
            dist = a.dist.at[lane].set(NEG_INF)
        self._arrays = a._replace(
            dist=dist,
            emitted=a.emitted.at[lane].set(False),
        )

    def set_lane_emitted(self, lane: int, valid_lane: jnp.ndarray) -> None:
        a = self._arrays
        self._arrays = a._replace(emitted=a.emitted.at[lane].set(valid_lane))

    def advance_clock(self, ts: float) -> None:
        a = self._arrays
        self._arrays = a._replace(
            now=jnp.maximum(a.now, jnp.asarray(ts, jnp.float32))
        )

    # -- ELL spill budget ----------------------------------------------------
    #
    # The ring never drops an append: each ingest dispatch of width B can
    # append at most B ring entries, so the host tracks a conservative
    # budget of appends since the last drain and syncs the ring cursor
    # BEFORE a dispatch could overflow it. A drain that finds the ring
    # occupied means some row overflowed its degree capacity — grow
    # ``ell_cap`` ×2 toward the true max degree and re-pack (which empties
    # the ring). A drain that finds it empty just resets the budget. The
    # sync is explicit (jax.device_get — rule R5's sanctioned form) and
    # amortized: steady-state streams without degree growth never sync.

    def _reserve_spill(self, b: int) -> None:
        bneed = _next_pow2(2 * max(b, 1))
        grew = False
        while self.spill_cap < bneed:
            self.spill_cap *= 2
            grew = True
        if grew:
            self._repack_ell()
        elif self._spill_budget + b > self.spill_cap:
            self._drain_spill()
        self._spill_budget += b

    def _drain_spill(self) -> None:
        self._ell_spill_drains += 1
        ptr = int(jax.device_get(self._arrays.adj.spill_ptr))
        if ptr > 0:
            need = int(jax.device_get(ell_max_degree(self._arrays.adj)))
            while self.ell_cap < need:
                self.ell_cap *= 2
            self._repack_ell()
        else:
            self._spill_budget = 0

    def _repack_ell(self) -> None:
        """Host round-trip re-pack at the current capacities: densify on
        device, re-pack rows (ring folded in, then emptied). Growth and
        compaction reuse this; dist/emitted stay resident."""
        dense = np.asarray(jax.device_get(ell_to_dense(self._arrays.adj)))
        need = int((dense > NEG_INF).sum(axis=-1).max()) if dense.size else 0
        while self.ell_cap < need:
            self.ell_cap *= 2
        self._arrays = self._arrays._replace(
            adj=self._put_adj(pack_ell(dense, self.ell_cap, self.spill_cap)))
        self._ell_repacks += 1
        self._ell_live_edges = int((dense > NEG_INF).sum())
        self._spill_budget = 0

    @property
    def adjacency_stats(self) -> Dict[str, object]:
        """Adjacency-representation telemetry (host-known values only —
        reading this never syncs the device stream). ``live_edges`` and
        ``occupancy`` are snapshots from the last re-pack (None before
        one); ``adj_bytes`` is the exact device footprint of the current
        representation."""
        a = self._arrays.adj if self._arrays is not None else None
        if isinstance(a, EllAdjacency):
            slot_cells = a.n_labels * a.n_slots * a.ell_cap
            adj_bytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                            for x in a)
        else:
            slot_cells = int(np.prod(a.shape)) if a is not None else 0
            adj_bytes = slot_cells * 4
        return {
            "layout": self.adj_layout,
            "ell_cap": self.ell_cap,
            "spill_cap": self.spill_cap,
            "repacks": self._ell_repacks,
            "spill_drains": self._ell_spill_drains,
            "live_edges": self._ell_live_edges,
            "slot_cells": slot_cells,
            "adj_bytes": adj_bytes,
            "occupancy": (self._ell_live_edges / slot_cells
                          if self._ell_live_edges is not None and slot_cells
                          else None),
        }

    # -- row-sparse dist overflow budget -------------------------------------
    #
    # Same shape as the ELL spill budget above, at row granularity: the
    # overflow table never silently grows stale — the host tracks a
    # conservative bound on table claims since the last drain (a frontier
    # dispatch can claim at most its frontier rows; a dense round trip can
    # re-pack up to every row) and syncs the claim cursor BEFORE the bound
    # crosses the table capacity. A drain that finds claims means rows
    # outgrew ``dist_cap`` — grow it ×2 toward the observed max row
    # occupancy and re-pack in place (rsd_grow_repack: no densify), which
    # empties the table. A drain that finds the table empty just resets
    # the budget. While the table can hold every row at once (the default
    # sizing at small scale), nothing can EVER be lost; at large N the
    # clamped table plus these drains keep pressure near zero, and any
    # loss is counted (``dist_stats["lost"]``), never silent.

    def _reserve_dist(self, frontier: bool) -> None:
        q, n = self.dist_shape[0], self.dist_shape[1]
        w = q * min(self.frontier_cap, n) if frontier else q * n
        w = min(w, self.dist_ovf_cap)
        if self._dist_budget + w > self.dist_ovf_cap:
            self._drain_dist()
        self._dist_budget += w

    def _drain_dist(self) -> None:
        self._dist_drains += 1
        d = self._arrays.dist
        ptr, lost = (int(x) for x in jax.device_get((d.ovf_ptr, d.lost)))
        self._dist_lost = lost
        if ptr > 0:
            need = int(jax.device_get(jnp.max(rsd_row_counts(d))))
            while self.dist_cap < need:
                self.dist_cap *= 2
            self._repack_dist()
        else:
            self._dist_budget = 0

    def _repack_dist(self) -> None:
        """In-place re-pack at the current capacities (rsd_grow_repack —
        no densify round trip): overflow rows that now fit move into their
        slots, the table empties. Growth and drains reuse this; adj and
        emitted stay resident."""
        d = self._arrays.dist
        sd = rsd_grow_repack(d, self.dist_cap, self.dist_ovf_cap)
        self._arrays = self._arrays._replace(dist=self._put_dist(sd))
        self._dist_repacks += 1
        self._dist_live_entries = int(jax.device_get(rsd_live_entries(sd)))
        self._dist_budget = 0

    @property
    def dist_stats(self) -> Dict[str, object]:
        """Dist-representation telemetry (host-known values only — reading
        this never syncs the device stream). ``live_entries`` and
        ``occupancy`` are snapshots from the last re-pack (None before
        one); ``lost`` is the host's view from the last drain (rows
        dropped with the overflow table full — 0 whenever the table
        covers every row); ``dist_bytes`` is the exact device footprint
        of the current representation."""
        d = self._arrays.dist if self._arrays is not None else None
        if isinstance(d, RowSparseDist):
            slot_cells = d.n_lanes * d.n_slots * d.dist_cap
            dist_bytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                             for x in d)
        else:
            slot_cells = int(np.prod(d.shape)) if d is not None else 0
            dist_bytes = slot_cells * 4
        return {
            "layout": self.dist_layout,
            "dist_cap": self.dist_cap,
            "ovf_cap": self.dist_ovf_cap,
            "repacks": self._dist_repacks,
            "drains": self._dist_drains,
            "lost": self._dist_lost,
            "live_entries": self._dist_live_entries,
            "slot_cells": slot_cells,
            "dist_bytes": dist_bytes,
            "occupancy": (self._dist_live_entries / slot_cells
                          if self._dist_live_entries is not None and slot_cells
                          else None),
        }

    # -- round accounting ----------------------------------------------------

    def _account(self, rounds, qrounds, n_live: int, fstats=None,
                 is_delete: bool = False) -> None:
        n = self.dist_shape[1] if self._arrays is not None else 0
        self._pending_counts.append(
            (rounds, qrounds, n_live, fstats, n, is_delete))
        # auto-frontier flushes more eagerly: the ×2 capacity growth reads
        # the flushed overflow telemetry, and reacting a couple hundred
        # dispatches late would strand the stream on the dense fallback
        limit = 64 if self.frontier == "auto" else 256
        if len(self._pending_counts) >= limit:
            self._flush_counts()

    def _flush_counts(self) -> None:
        for rounds, qrounds, n_live, fstats, n, is_delete in \
                self._pending_counts:
            self._consume_count(rounds, qrounds, n_live)
            self._consume_frontier(fstats, rounds, n_live, n, is_delete)
        self._pending_counts.clear()
        self._maybe_grow_frontier()

    def _consume_count(self, rounds, qrounds, n_live: int) -> None:
        r = int(np.asarray(rounds))
        self._rounds_total += r
        self._query_rounds_total += int(np.asarray(qrounds).sum())
        self._unmasked_query_rounds_total += n_live * r

    def _consume_frontier(self, fstats, rounds, n_live: int, n: int,
                          is_delete: bool = False) -> None:
        """Aggregate one dispatch's FrontierStats. Works on scalar stats
        (local) and per-shard arrays (mesh) alike: sums/maxes reduce both."""
        if fstats is None:
            return
        self._frontier_dispatches += 1
        fell = int(np.asarray(fstats.fell_back).astype(np.int64).sum())
        self._frontier_fallbacks += fell
        if is_delete:
            self._frontier_delete_dispatches += 1
            self._frontier_delete_fallbacks += fell
        self._frontier_rows_relaxed += int(
            np.asarray(fstats.rows_relaxed).astype(np.int64).sum())
        self._frontier_seed_rows += int(
            np.asarray(fstats.seed_rows).astype(np.int64).sum())
        self._frontier_max_lane_rows = max(
            self._frontier_max_lane_rows,
            int(np.asarray(fstats.max_lane_rows).max()))
        # what a dense loop of the same dispatch relaxes: every live lane
        # rides every round over all N rows (occupancy denominator; for a
        # mesh dispatch `rounds` is per-shard — the max is the sync count)
        r = int(np.asarray(rounds).max())
        self._frontier_dense_row_equiv += n_live * n * r

    def _maybe_grow_frontier(self) -> None:
        """``frontier="auto"``: grow the frontier capacity ×2 toward the
        largest observed lane frontier whenever new overflow fallbacks were
        flushed. Capacity is a trace-time shape, so growth means one new
        compile per ×2 step — the same bucketing discipline as Q/K."""
        if self.frontier != "auto":
            return
        if self._frontier_fallbacks <= self._frontier_growth_mark:
            return
        self._frontier_growth_mark = self._frontier_fallbacks
        n = (self.dist_shape[1]
             if self._arrays is not None else self._frontier_max_lane_rows)
        limit = _next_pow2(n)
        target = min(_next_pow2(max(self._frontier_max_lane_rows,
                                    self.frontier_cap * 2)), limit)
        while self.frontier_cap < target:
            self.frontier_cap *= 2

    @property
    def frontier_stats(self) -> Dict[str, object]:
        """Aggregate frontier telemetry: dispatches taken (ingest and
        delete; the delete split is also reported on its own), overflow
        fallbacks, rows relaxed (summed over rounds) vs the dense-loop row
        equivalent, seed occupancy, and the current capacity.

        ``occupancy`` is ``None`` — NOT 0.0 — when no dense-row-equivalent
        work was observed: an all-idle dispatch window carries no signal
        about how full frontiers run, and downstream health checks
        (service.adapt_batch) must not read it as "frontier doing great"."""
        self._flush_counts()
        dense_rows = self._frontier_dense_row_equiv
        return {
            "mode": self.frontier,
            "cap": self.frontier_cap,
            "dispatches": self._frontier_dispatches,
            "fallbacks": self._frontier_fallbacks,
            "delete_dispatches": self._frontier_delete_dispatches,
            "delete_fallbacks": self._frontier_delete_fallbacks,
            "rows_relaxed": self._frontier_rows_relaxed,
            "dense_row_equiv": dense_rows,
            "seed_rows": self._frontier_seed_rows,
            "max_lane_rows": self._frontier_max_lane_rows,
            "occupancy": (self._frontier_rows_relaxed / dense_rows
                          if dense_rows else None),
        }

    @property
    def rounds_total(self) -> int:
        """Global closure iterations (each dispatch's loop runs until its
        slowest participating query converges)."""
        self._flush_counts()
        return self._rounds_total

    @property
    def query_rounds_total(self) -> int:
        """Sum over queries of ACTIVE rounds (per-query convergence mask)."""
        self._flush_counts()
        return self._query_rounds_total

    @property
    def unmasked_query_rounds_total(self) -> int:
        """What the same dispatches would cost with every live lane riding
        to the global fixpoint — accumulated with the live count at each
        dispatch, so mid-stream lane churn cannot skew the comparison."""
        self._flush_counts()
        return self._unmasked_query_rounds_total


class LocalExecutor(Executor):
    """Single-device executor: the pre-refactor engine behavior, verbatim."""
