"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060], chunked
matmul form for training/prefill + O(1)-state recurrent decode step.

The chunked algorithm splits the sequence into chunks of length Q and
computes (per head):
    intra-chunk:  Y_ij = C_i·B_j * exp(cumA_i - cumA_j) * dt_j * x_j (j<=i)
    chunk state:  S_c  = sum_j exp(cumA_Q - cumA_j) * dt_j * B_j ⊗ x_j
    inter-chunk:  S <- S * exp(sumA_c) + S_c   (scan over chunks)
                  Y_i += C_i · S_prev * exp(cumA_i)
which is matmul-dominated (MXU-friendly) — the TPU-idiomatic form of the
selective scan. ngroups = 1 (B/C shared across heads).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


class SSDConfig(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int        # d_inner // head_dim
    head_dim: int
    d_state: int
    d_conv: int = 4
    chunk: int = 256


def init_ssd(key: jax.Array, cfg: SSDConfig, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    h = cfg.n_heads
    conv_ch = di + 2 * n  # x, B, C go through the causal conv
    s_in = 1.0 / np.sqrt(d)
    return {
        # in_proj -> [z (di), x (di), B (n), C (n), dt (h)]
        "w_in": (jax.random.normal(k1, (d, 2 * di + 2 * n + h), jnp.float32) * s_in).astype(dtype),
        "conv_w": (jax.random.normal(k2, (cfg.d_conv, conv_ch), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "w_out": (jax.random.normal(k3, (di, d), jnp.float32) / np.sqrt(di)).astype(dtype),
        "norm_scale": jnp.ones((di,), dtype),  # gated RMSNorm before out_proj
    }


def _split_proj(cfg: SSDConfig, proj: jnp.ndarray):
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * n]
    dt = proj[..., di + di + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv over time. xbc: (b, s, ch); w: (k, ch).
    Returns (out, new_state) where state is the last (k-1) inputs."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xbc], axis=1)          # (b, s+k-1, ch)
    out = sum(xp[:, i : i + xbc.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(out), new_state


def _gated_norm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray, eps=1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(y.dtype) * scale


def ssd_chunked(
    x: jnp.ndarray,      # (b, s, h, p)
    dt: jnp.ndarray,     # (b, s, h) post-softplus
    A: jnp.ndarray,      # (h,) negative
    B: jnp.ndarray,      # (b, s, n)
    C: jnp.ndarray,      # (b, s, n)
    chunk: int,
    init_state: jnp.ndarray | None = None,  # (b, h, n, p)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (b,s,h,p), final_state (b,h,n,p))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S = s + pad
    nc = S // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    dA = dtc * A[None, None, None, :]                    # (b,nc,Q,h) negative
    cum = jnp.cumsum(dA, axis=2)                          # inclusive cumsum
    # intra-chunk decay matrix L[i,j] = exp(cum_i - cum_j), i >= j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (b,nc,Q,Q,h)
    iq = jnp.arange(chunk)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    # mask BEFORE exp: non-causal li is positive and exp overflows, which
    # would poison gradients through the where (standard where-grad trap)
    L = jnp.exp(jnp.where(causal, li, -jnp.inf))
    # scores: (C_i . B_j)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    W = cb[..., None] * L * dtc[:, :, None, :, :]         # (b,nc,Q,Q,h)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xc.astype(jnp.float32))

    # chunk states: S_c = sum_j exp(cum_Q - cum_j) dt_j B_j (x) x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # (b,nc,Q,h)
    state_c = jnp.einsum(
        "bcjh,bcjn,bcjhp->bchnp",
        decay_to_end * dtc, Bc.astype(jnp.float32), xc.astype(jnp.float32),
    )                                                     # (b,nc,h,n,p)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (b,nc,h)

    def scan_fn(S_prev, inp):
        sc, dec = inp
        S_new = S_prev * dec[..., None, None] + sc        # (b,h,n,p)
        return S_new, S_prev

    S0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    _, S_prevs = jax.lax.scan(
        scan_fn, S0,
        (jnp.moveaxis(state_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    S_final = scan_fn(S_prevs[-1], (state_c[:, -1], chunk_decay[:, -1]))[0]
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                 # (b,nc,h,n,p)

    # inter-chunk: Y_i += exp(cum_i) * C_i . S_prev
    y_inter = jnp.einsum(
        "bcin,bchnp,bcih->bcihp",
        Cc.astype(jnp.float32), S_prevs, jnp.exp(cum),
    )
    y = (y_intra + y_inter).reshape(b, S, h, p)[:, :s]
    return y, S_final


def ssd_decode_step(
    x: jnp.ndarray,      # (b, 1, h, p)
    dt: jnp.ndarray,     # (b, 1, h)
    A: jnp.ndarray,      # (h,)
    B: jnp.ndarray,      # (b, 1, n)
    C: jnp.ndarray,      # (b, 1, n)
    state: jnp.ndarray,  # (b, h, n, p) f32
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    dtf = dt[:, 0].astype(jnp.float32)                    # (b,h)
    dA = jnp.exp(dtf * A[None, :])                        # (b,h)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dtf, B[:, 0].astype(jnp.float32),
                     x[:, 0].astype(jnp.float32))
    state = state * dA[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C[:, 0].astype(jnp.float32), state)
    return y[:, None], state


def apply_ssd(
    params: Params,
    cfg: SSDConfig,
    x: jnp.ndarray,      # (b, s, d)
    cache: Tuple[jnp.ndarray, jnp.ndarray] | None = None,  # (conv_state, ssm_state)
    decode: bool = False,
    constrain=None,
):
    """Returns (y (b,s,d), new_cache).

    `constrain(x, tag)` lets the launcher pin head-parallel shardings: the
    intra-chunk decay tensors scale with (b, s, Q, h) and MUST shard h over
    the model axis at scale (EXPERIMENTS.md §Perf It.3)."""
    if constrain is None:
        constrain = lambda t, _tag: t
    b, s, d = x.shape
    h, p, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    proj = x @ params["w_in"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    conv_state = cache[0] if cache is not None else None
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xs = xbc[..., : cfg.d_inner].reshape(b, s, h, p)
    xs = constrain(xs, "ssm_heads")
    B = xbc[..., cfg.d_inner : cfg.d_inner + n]
    C = xbc[..., cfg.d_inner + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    dt = constrain(dt, "ssm_dt")
    A = -jnp.exp(params["A_log"])
    ssm_state = cache[1] if cache is not None else None
    if decode:
        assert s == 1 and ssm_state is not None
        y, ssm_state = ssd_decode_step(xs, dt, A, B, C, ssm_state)
    else:
        y, ssm_state = ssd_chunked(xs, dt, A, B, C, cfg.chunk, ssm_state)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.astype(x.dtype).reshape(b, s, cfg.d_inner)
    y = _gated_norm(y, z, params["norm_scale"])
    out = y @ params["w_out"]
    return out, (conv_state, ssm_state)


def init_ssd_cache(cfg: SSDConfig, batch: int, dtype=jnp.bfloat16):
    conv_ch = cfg.d_inner + 2 * cfg.d_state
    return (
        jnp.zeros((batch, cfg.d_conv - 1, conv_ch), dtype),
        jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32),
    )
