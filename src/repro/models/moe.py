"""Mixture-of-Experts layer: top-k router, capacity-bounded scatter dispatch.

Expert-parallel layout: expert weight tensors carry a leading E dim sharded
on the `model` mesh axis (one or more experts per chip); the scatter/gather
dispatch lowers to all-to-all under GSPMD. Capacity-dropped tokens pass
through the residual (standard GShard/Switch behavior).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def init_moe(
    key: jax.Array,
    d_model: int,
    d_ff: int,
    n_experts: int,
    dtype=jnp.bfloat16,
) -> Params:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "router": (jax.random.normal(kr, (d_model, n_experts), jnp.float32) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k1, (n_experts, d_model, d_ff), jnp.float32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (n_experts, d_model, d_ff), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (n_experts, d_ff, d_model), jnp.float32) * s_out).astype(dtype),
    }


def apply_moe(
    params: Params,
    x: jnp.ndarray,              # (b, s, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    n_groups: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_loss). Tokens beyond expert capacity are dropped
    (residual passthrough).

    n_groups: GShard-style dispatch groups. Capacity is enforced PER GROUP
    and the dispatch buffers carry a leading (G,) dim that shards over the
    data axes — without it the (E, C_global, d_ff) hidden activation is
    unshardable over batch and blows HBM at scale (measured: 261 GiB/chip
    for jamba train_4k; see EXPERIMENTS.md §Perf iteration 1).
    """
    b, s, d = x.shape
    T_all = b * s
    if n_groups > 1:
        assert T_all % n_groups == 0, (T_all, n_groups)
        xg = x.reshape(n_groups, T_all // n_groups, d)
        yg, aux = jax.vmap(
            lambda xi: _moe_group(params, xi, top_k, capacity_factor)
        )(xg)
        return yg.reshape(b, s, d), jnp.mean(aux)
    y, aux = _moe_group(params, x.reshape(T_all, d), top_k, capacity_factor)
    return y.reshape(b, s, d), aux


def _moe_group(
    params: Params,
    xt: jnp.ndarray,             # (T, d) tokens of one dispatch group
    top_k: int,
    capacity_factor: float,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    T, d = xt.shape
    E = params["w_gate"].shape[0]
    logits = xt.astype(jnp.float32) @ params["router"]        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # (T, k)
    # renormalize the selected gates (Mixtral/DBRX convention)
    gate_vals = gate_vals / jnp.clip(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    capacity = int(np.ceil(capacity_factor * T * top_k / E))
    capacity = max(capacity, 1)

    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)    # (T, k, E)
    flat = onehot.reshape(T * top_k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                      # (T*k, E)
    pos_in_expert = jnp.sum(pos * flat, axis=-1).reshape(T, top_k)
    keep = pos_in_expert < capacity

    # scatter tokens into (E, C, d) buffers
    e_flat = expert_idx.reshape(-1)
    p_flat = jnp.where(keep, pos_in_expert, capacity).reshape(-1)  # drop -> C (OOB)
    src = jnp.repeat(xt, top_k, axis=0)                        # (T*k, d)
    buf = jnp.zeros((E, capacity, d), xt.dtype)
    buf = buf.at[e_flat, p_flat].add(src, mode="drop")

    # expert FFN: (E, C, d) x (E, d, f) batched matmuls
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])      # (E, C, d)

    # gather back and combine with gates
    gathered = y_e.at[e_flat, p_flat].get(mode="fill", fill_value=0)  # (T*k, d)
    gathered = gathered * (gate_vals.reshape(-1, 1).astype(xt.dtype) *
                           keep.reshape(-1, 1).astype(xt.dtype))
    y = jnp.sum(gathered.reshape(T, top_k, d), axis=1)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    f = jnp.mean(jnp.sum(jax.nn.one_hot(expert_idx, E), axis=1), axis=0)  # (E,)
    p_mean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * p_mean)
    return y, aux
