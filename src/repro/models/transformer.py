"""Model assembly for every assigned architecture family.

One generic decoder with a repeating layer *period* (scan-over-periods +
optional remat), covering:
  dense        (qwen*, smollm)                attn + SwiGLU
  moe          (llama4-scout, dbrx)           attn + top-k MoE
  ssm          (mamba2)                       SSD mixer only
  hybrid       (jamba)                        1:7 attn:SSD interleave, MoE/2
  vlm / audio  (paligemma, musicgen)          stub prefix embeddings + decoder

Params are nested dicts; layer params are stacked with a leading
(n_periods,) dim and consumed by `lax.scan` (small HLO, fast compile, remat
per period). Serving caches mirror the same stacking.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import layers as L
from .moe import apply_moe, init_moe
from .ssd import SSDConfig, apply_ssd, init_ssd, init_ssd_cache

Params = Dict[str, Any]
Constrain = Callable[[jnp.ndarray, str], jnp.ndarray]


def _identity_constrain(x: jnp.ndarray, _tag: str) -> jnp.ndarray:
    return x


class Model:
    """cfg + tensor-parallel degree -> init / forward / loss / serve fns."""

    def __init__(self, cfg: ModelConfig, tp: int = 1,
                 constrain: Constrain = _identity_constrain,
                 scan_unroll: bool = False):
        # scan_unroll: fully unroll the layer scan. Used by the dry-run so
        # XLA cost analysis sees every layer (a while-loop body is counted
        # ONCE by HloCostAnalysis, which would undercount flops/collectives
        # by ~n_periods). Training/serving keep the rolled scan.
        self.scan_unroll = scan_unroll
        self.cfg = cfg
        self.tp = tp
        self.H, self.KV = cfg.padded_heads(tp)
        self.V = cfg.padded_vocab(tp)
        self.dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
        self.period = cfg.period
        self.n_periods = cfg.n_layers // cfg.period
        self.constrain = constrain
        self.ssd_cfg = SSDConfig(
            d_model=cfg.d_model,
            d_inner=cfg.d_inner,
            n_heads=cfg.ssm_heads,
            head_dim=cfg.ssm_head_dim,
            d_state=cfg.ssm_state,
            chunk=cfg.ssm_chunk,
        ) if cfg.ssm_state else None

    # -- init ------------------------------------------------------------------

    def _init_one_layer(self, key: jax.Array, offset: int) -> Params:
        cfg = self.cfg
        kmix, kmlp = jax.random.split(key)
        p: Params = {"ln1": L.init_rmsnorm(cfg.d_model, self.dtype)}
        if cfg.layer_kind(offset) == "attn":
            p["attn"] = L.init_attention(
                kmix, cfg.d_model, self.H, self.KV, cfg.head_dim,
                qkv_bias=cfg.qkv_bias, dtype=self.dtype,
                n_heads_logical=cfg.n_heads, n_kv_logical=cfg.n_kv_heads,
            )
        else:
            p["ssd"] = init_ssd(kmix, self.ssd_cfg, self.dtype)
        if cfg.d_ff > 0 or cfg.mlp_kind(offset) == "moe":
            p["ln2"] = L.init_rmsnorm(cfg.d_model, self.dtype)
            if cfg.mlp_kind(offset) == "moe":
                p["moe"] = init_moe(kmlp, cfg.d_model, cfg.d_ff, cfg.n_experts, self.dtype)
            else:
                p["mlp"] = L.init_mlp(kmlp, cfg.d_model, cfg.d_ff, self.dtype)
        return p

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        ke, kh, kl, kf = jax.random.split(key, 4)
        # stacked layers: one stack per period offset, leading (n_periods,)
        stacks = []
        for o in range(self.period):
            keys = jax.random.split(jax.random.fold_in(kl, o), self.n_periods)
            stacks.append(jax.vmap(lambda k, o=o: self._init_one_layer(k, o))(keys))
        params: Params = {
            "embed": L.init_embedding(ke, self.V, cfg.d_model, self.dtype),
            "final_norm": L.init_rmsnorm(cfg.d_model, self.dtype),
            "lm_head": L.init_lm_head(kh, cfg.d_model, self.V, self.dtype),
            "layers": stacks,
        }
        if cfg.frontend != "none":
            # stub frontend projection: maps precomputed modality embeddings
            # (already d_model-sized in the stub) into the decoder space
            params["frontend_proj"] = {
                "w": (jax.random.normal(kf, (cfg.d_model, cfg.d_model), jnp.float32)
                      / np.sqrt(cfg.d_model)).astype(self.dtype)
            }
        return params

    def init_abstract(self) -> Params:
        """ShapeDtypeStruct pytree of params — no allocation (dry-run path)."""
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # -- one transformer layer ----------------------------------------------------

    def _apply_layer(
        self,
        p: Params,
        offset: int,
        x: jnp.ndarray,
        cache: Optional[Params],
        mode: str,                      # train | prefill | decode
        positions: Optional[jnp.ndarray],
        max_len: int,
    ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        h = L.rms_norm(p["ln1"], x, cfg.norm_eps)
        new_cache: Optional[Params] = None
        if cfg.layer_kind(offset) == "attn":
            att_cache = None
            if mode == "decode":
                att_cache = (cache["k"], cache["v"], cache["len"])
            y, att_cache = L.apply_attention(
                p["attn"], h,
                n_heads=self.H, n_kv=self.KV, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
                positions=positions, cache=att_cache,
            )
            if mode != "train":
                k, v, ln = att_cache
                if mode == "prefill" and k.shape[1] < max_len:
                    pad = max_len - k.shape[1]
                    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                new_cache = {"k": k, "v": v, "len": ln}
        else:
            ssd_cache = None
            if mode == "decode":
                ssd_cache = (cache["conv"], cache["ssm"])
            y, ssd_cache = apply_ssd(p["ssd"], self.ssd_cfg, h,
                                     cache=ssd_cache, decode=(mode == "decode"),
                                     constrain=self.constrain)
            if mode != "train":
                new_cache = {"conv": ssd_cache[0], "ssm": ssd_cache[1]}
        x = x + y
        x = self.constrain(x, "hidden")
        if "ln2" in p:
            h = L.rms_norm(p["ln2"], x, cfg.norm_eps)
            if "moe" in p:
                y, aux = apply_moe(p["moe"], h, top_k=cfg.experts_per_token,
                                   capacity_factor=cfg.capacity_factor,
                                   n_groups=cfg.moe_groups)
            else:
                y = L.apply_mlp(p["mlp"], h)
            x = x + y
            x = self.constrain(x, "hidden")
        return x, new_cache, aux

    # -- stacked layers (scan over periods) ------------------------------------

    def _run_layers(
        self,
        params: Params,
        x: jnp.ndarray,
        caches: Optional[list],
        mode: str,
        positions: Optional[jnp.ndarray],
        max_len: int = 0,
    ) -> Tuple[jnp.ndarray, Optional[list], jnp.ndarray]:
        cfg = self.cfg

        def period_body(carry, xs):
            h, aux = carry
            if mode == "decode":
                layer_stacks, cache_stacks = xs
            else:
                layer_stacks, cache_stacks = xs, [None] * self.period
            new_caches = []
            for o in range(self.period):
                def layer_fn(pp, hh, cc, o=o):
                    return self._apply_layer(pp, o, hh, cc, mode, positions, max_len)
                if cfg.remat and mode == "train":
                    layer_fn = jax.checkpoint(layer_fn)
                h, nc, a = layer_fn(layer_stacks[o], h, cache_stacks[o])
                new_caches.append(nc)
                aux = aux + a
            ys = None if mode == "train" else new_caches
            return (h, aux), ys

        body = period_body
        if cfg.remat and mode == "train":
            # NESTED remat: the outer checkpoint keeps only period-boundary
            # activations across the scan; the inner per-layer checkpoints
            # (above) bound the live set during a period's backward to one
            # layer's internals. Forward is computed ~3x (10*N*D flops
            # instead of 8*N*D) -- the classic sqrt-style trade; without
            # the outer level, 9 periods x 8 layer-input residuals are
            # 38 GiB/chip for jamba (EXPERIMENTS.md §Perf It.3).
            body = jax.checkpoint(period_body)

        xs = (params["layers"], caches) if mode == "decode" else params["layers"]
        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs,
            unroll=self.n_periods if self.scan_unroll else 1,
        )
        return x, new_caches, aux

    # -- embedding & frontends --------------------------------------------------

    def _embed_inputs(
        self, params: Params, tokens: jnp.ndarray,
        prefix_embeds: Optional[jnp.ndarray],
    ) -> jnp.ndarray:
        x = L.embed(params["embed"], tokens)
        if self.cfg.frontend != "none":
            assert prefix_embeds is not None, "stub frontend needs prefix_embeds"
            pre = (prefix_embeds.astype(self.dtype) @ params["frontend_proj"]["w"])
            x = jnp.concatenate([pre, x], axis=1)
        return self.constrain(x, "hidden")

    # -- training forward / loss --------------------------------------------------

    def forward(
        self, params: Params, tokens: jnp.ndarray,
        prefix_embeds: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full-sequence causal forward. Returns (logits f32, moe_aux)."""
        x = self._embed_inputs(params, tokens, prefix_embeds)
        x, _caches, aux = self._run_layers(params, x, None, "train", None)
        x = L.rms_norm(params["final_norm"], x, self.cfg.norm_eps)
        logits = L.lm_logits(params["lm_head"], x)
        return self.constrain(logits, "logits"), aux

    def loss(self, params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Next-token CE (+ MoE aux) over the token region.

        The LM head + softmax-CE are FUSED and chunked over the sequence:
        full (b, s, V) logits are never materialized (at jamba train_4k
        scale they alone are ~268 GiB/chip — §Perf iteration 2)."""
        tokens = batch["tokens"]
        prefix = batch.get("prefix_embeds")
        x = self._embed_inputs(params, tokens, prefix)
        x, _caches, aux = self._run_layers(params, x, None, "train", None)
        x = L.rms_norm(params["final_norm"], x, self.cfg.norm_eps)
        P = self.cfg.prefix_len if self.cfg.frontend != "none" else 0
        xs = x[:, P:-1]                      # (b, s_tok-1, d)
        targets = tokens[:, 1:]              # (b, s_tok-1)
        loss = _chunked_softmax_xent(params["lm_head"]["w"], xs, targets,
                                     chunk=max(self.cfg.q_chunk, 16))
        if self.cfg.n_experts:
            loss = loss + 0.01 * aux
        return loss

    # -- serving -------------------------------------------------------------------

    def init_caches(self, batch: int, max_len: int) -> list:
        """Stacked decode caches (capacity max_len)."""
        cfg = self.cfg
        stacks = []
        for o in range(self.period):
            if cfg.layer_kind(o) == "attn":
                c = {
                    "k": jnp.zeros((self.n_periods, batch, max_len, self.KV, cfg.head_dim), self.dtype),
                    "v": jnp.zeros((self.n_periods, batch, max_len, self.KV, cfg.head_dim), self.dtype),
                    "len": jnp.zeros((self.n_periods, batch), jnp.int32),
                }
            else:
                conv, ssm = init_ssd_cache(self.ssd_cfg, batch, self.dtype)
                c = {
                    "conv": jnp.broadcast_to(conv, (self.n_periods,) + conv.shape),
                    "ssm": jnp.broadcast_to(ssm, (self.n_periods,) + ssm.shape),
                }
            stacks.append(c)
        return stacks

    def prefill(
        self, params: Params, tokens: jnp.ndarray,
        prefix_embeds: Optional[jnp.ndarray] = None,
        max_len: Optional[int] = None,
    ) -> Tuple[jnp.ndarray, list]:
        """Run the prompt; returns (last-position logits, caches)."""
        x = self._embed_inputs(params, tokens, prefix_embeds)
        b, s, _ = x.shape
        max_len = max_len or s
        # prefill runs the full-sequence path and emits caches padded to
        # max_len capacity (no pre-allocated cache input needed)
        x, caches, _aux = self._run_layers(params, x, None, "prefill", None,
                                           max_len=max_len)
        x = L.rms_norm(params["final_norm"], x[:, -1:], self.cfg.norm_eps)
        logits = L.lm_logits(params["lm_head"], x)
        return logits, caches

    def decode_step(
        self, params: Params, token: jnp.ndarray, caches: list,
    ) -> Tuple[jnp.ndarray, list]:
        """One decode step. token: (b, 1) int32. Returns (logits, caches)."""
        x = L.embed(params["embed"], token)
        x = self.constrain(x, "hidden")
        x, caches, _aux = self._run_layers(params, x, caches, "decode", None)
        x = L.rms_norm(params["final_norm"], x, self.cfg.norm_eps)
        logits = L.lm_logits(params["lm_head"], x)
        return logits, caches


def _chunked_softmax_xent(w: jnp.ndarray, x: jnp.ndarray, targets: jnp.ndarray,
                          chunk: int) -> jnp.ndarray:
    """Fused LM-head + cross-entropy, chunked over sequence positions so the
    logits working set is (b, chunk, V) instead of (b, s, V)."""
    b, s, d = x.shape
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    mask = (jnp.arange(x.shape[1]) < s)[None, :]         # (1, s+pad)
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, d).swapaxes(0, 1)        # (nc, b, chunk, d)
    tc = targets.reshape(b, nc, chunk).swapaxes(0, 1)
    mc = jnp.broadcast_to(mask.reshape(1, nc, chunk).swapaxes(0, 1), tc.shape)

    @jax.checkpoint
    def one(args):
        # checkpointed: WITHOUT remat the map's backward stacks every
        # chunk's logits -> the full (b, s, V) tensor returns through the
        # back door (measured; EXPERIMENTS.md §Perf It.3)
        xi, ti, mi = args                                  # (b, chunk, ...)
        logits = (xi @ w).astype(jnp.float32)              # (b, chunk, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - tgt) * mi)

    totals = jax.lax.map(one, (xc, tc, mc))
    return jnp.sum(totals) / (b * s)
