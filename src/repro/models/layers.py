"""Shared transformer layers: RMSNorm, RoPE, GQA attention (chunked,
memory-bounded), SwiGLU MLP, embeddings.

Conventions:
  * params are nested dicts of jnp arrays (plain pytrees);
  * every `init_*` has a matching `apply_*`;
  * head counts may be *sharding-padded* (DESIGN.md §Arch-applicability):
    pad q/kv head slots are zero-initialized, so they contribute nothing to
    the output projection; FLOP fidelity is accounted in the roofline's
    MODEL_FLOPS/HLO_FLOPS ratio.
  * attention is chunked over query blocks (scores never materialize more
    than (b, h, q_chunk, kv_len)) — required for the 32k prefill cells.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(orig)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 1e4) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4) -> jnp.ndarray:
    """x: (..., s, heads, head_dim); positions: (..., s)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, chunked)
# ---------------------------------------------------------------------------


def init_attention(
    key: jax.Array,
    d_model: int,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    qkv_bias: bool = False,
    dtype=jnp.bfloat16,
    n_heads_logical: Optional[int] = None,
    n_kv_logical: Optional[int] = None,
) -> Params:
    """Padded head slots (>= logical counts) are zero-initialized."""
    kq, kk, kv_, ko = jax.random.split(key, 4)
    hl = n_heads_logical or n_heads
    kl = n_kv_logical or n_kv
    scale = 1.0 / np.sqrt(d_model)

    def dense(k, out_cols, live_cols):
        w = jax.random.normal(k, (d_model, out_cols), jnp.float32) * scale
        if live_cols < out_cols:
            w = w.at[:, live_cols:].set(0.0)
        return w.astype(dtype)

    wo = jax.random.normal(ko, (n_heads * head_dim, d_model), jnp.float32)
    wo = wo * (1.0 / np.sqrt(n_heads * head_dim))
    wo = wo.at[hl * head_dim :, :].set(0.0)  # pad head slots contribute nothing
    p = {
        "wq": dense(kq, n_heads * head_dim, hl * head_dim),
        "wk": dense(kk, n_kv * head_dim, kl * head_dim),
        "wv": dense(kv_, n_kv * head_dim, kl * head_dim),
        "wo": wo.astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _qkv(params: Params, x: jnp.ndarray, n_heads: int, n_kv: int, head_dim: int):
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    b, s, _ = x.shape
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv, head_dim)
    v = v.reshape(b, s, n_kv, head_dim)
    return q, k, v


def _grouped_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q: (b, sq, kv, g, hd), k: (b, skv, kv, hd) -> (b, kv, g, sq, skv)."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k)


def chunked_causal_attention(
    q: jnp.ndarray,            # (b, s, H, hd)
    k: jnp.ndarray,            # (b, s, KV, hd)
    v: jnp.ndarray,            # (b, s, KV, hd)
    q_chunk: int = 512,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Causal attention, chunked over query blocks: per-block scores are
    (b, H, q_chunk, s) so the full (s, s) score matrix never materializes.
    `q_offset` supports chunked prefill continuation."""
    b, s, H, hd = q.shape
    kvh = k.shape[2]
    g = H // kvh
    scale = 1.0 / np.sqrt(hd)
    pad = (-s) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = q.shape[1] // q_chunk
    qc = q.reshape(b, n_chunks, q_chunk, H, hd)
    kv_pos = jnp.arange(k.shape[1])

    def one_chunk(ci):
        qi = qc[:, ci]                                   # (b, qc, H, hd)
        qi = qi.reshape(b, q_chunk, kvh, g, hd)
        scores = _grouped_scores(qi, k) * scale          # (b, kv, g, qc, skv)
        q_pos = q_offset + ci * q_chunk + jnp.arange(q_chunk)
        mask = kv_pos[None, :] <= q_pos[:, None]         # (qc, skv)
        scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32), -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
        return out.reshape(b, q_chunk, H, hd)

    out = jax.lax.map(one_chunk, jnp.arange(n_chunks))   # (n, b, qc, H, hd)
    out = jnp.moveaxis(out, 0, 1).reshape(b, n_chunks * q_chunk, H, hd)
    return out[:, :s]


def decode_attention(
    q: jnp.ndarray,            # (b, 1, H, hd)
    k_cache: jnp.ndarray,      # (b, S, KV, hd)
    v_cache: jnp.ndarray,      # (b, S, KV, hd)
    cache_len: jnp.ndarray,    # (b,) or scalar int32: valid prefix length
) -> jnp.ndarray:
    b, _one, H, hd = q.shape
    kvh = k_cache.shape[2]
    g = H // kvh
    scale = 1.0 / np.sqrt(hd)
    qi = q.reshape(b, 1, kvh, g, hd)
    scores = _grouped_scores(qi, k_cache) * scale        # (b, kv, g, 1, S)
    pos = jnp.arange(k_cache.shape[1])
    valid = pos[None, :] < jnp.broadcast_to(jnp.asarray(cache_len), (b,))[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v_cache)
    return out.reshape(b, 1, H, hd)


def apply_attention(
    params: Params,
    x: jnp.ndarray,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 1e4,
    q_chunk: int = 512,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]]:
    """Training/prefill when cache is None (causal over x); decode when cache
    = (k_cache, v_cache, cache_len) and x is a single-token slice."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, n_heads, n_kv, head_dim)
    if cache is None:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
        out = chunked_causal_attention(q, k, v, q_chunk=q_chunk)
        new_cache = (k, v, jnp.full((b,), s, jnp.int32))
    else:
        k_cache, v_cache, cache_len = cache
        if positions is None:
            positions = jnp.broadcast_to(jnp.asarray(cache_len)[:, None], (b, s))
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
        idx = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
        k_cache = jax.vmap(lambda c, upd, i: jax.lax.dynamic_update_slice(
            c, upd, (i, 0, 0)))(k_cache, k, idx)
        v_cache = jax.vmap(lambda c, upd, i: jax.lax.dynamic_update_slice(
            c, upd, (i, 0, 0)))(v_cache, v, idx)
        out = decode_attention(q, k_cache, v_cache, idx + 1)
        new_cache = (k_cache, v_cache, idx + 1)
    y = out.reshape(b, s, -1) @ params["wo"]
    return y, new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model), jnp.float32) * s_out).astype(dtype),
    }


def apply_mlp(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embedding(key: jax.Array, vocab: int, d_model: int, dtype=jnp.bfloat16) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)}


def embed(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], tokens, axis=0)


def init_lm_head(key: jax.Array, d_model: int, vocab: int, dtype=jnp.bfloat16) -> Params:
    return {"w": (jax.random.normal(key, (d_model, vocab), jnp.float32) / np.sqrt(d_model)).astype(dtype)}


def lm_logits(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return (x @ params["w"]).astype(jnp.float32)
