"""Sharded, atomic, manifest-based checkpointing with elastic restore.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json        tree structure + array metadata + status
        shard_00000.npz      this host's array shards
    <dir>/LATEST             text file: last COMMITTED step directory

Design points for 1000+-node runs (emulated single-host here, but the
layout is per-host from the start):
  * atomicity: shards are written first, the manifest is written+fsynced
    last, then LATEST is atomically renamed — a crash mid-write can never
    yield a half-checkpoint that restore() would accept;
  * every host writes only its addressable shards (`host_shards`); restore
    reassembles from any number of shard files, so the restoring job may
    run on a DIFFERENT mesh/host count (elastic re-sharding: arrays are
    saved logically, resharding happens at device_put with the new mesh);
  * data-pipeline cursor and optimizer step ride in the manifest for exact
    resume;
  * async save: the array->numpy transfer happens on the caller thread but
    file IO can be deferred to a background thread (``async_save``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

SEP = "/"


class SimulatedCrash(RuntimeError):
    """Raised by :func:`save` at an injected crash point (``_crash_after``)
    — the fault-injection harness's stand-in for the process dying mid-
    checkpoint. Everything written so far stays on disk exactly as a real
    kill would leave it; nothing is cleaned up, and the commit protocol
    must make the partial state invisible to :func:`restore`."""

# npz cannot serialize ml_dtypes (bf16/fp8); store a bit-view + dtype tag
_VIEW_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def tree_structure_of(tree: Any):
    return jax.tree_util.tree_structure(tree)


def gather_leaf(leaf: Any) -> np.ndarray:
    """Device -> host gather of one checkpoint leaf. Mesh-sharded arrays
    (e.g. the dense RPQ group's (Q, N, N, K) state under MeshExecutor) are
    reassembled into their LOGICAL value here — the manifest stores logical
    arrays only, which is what makes a checkpoint written on one mesh
    restorable onto another mesh or onto a single device (the restorer's
    executor re-places them; see restore()'s `shardings`)."""
    if isinstance(leaf, jax.Array) and not leaf.is_fully_addressable:
        raise ValueError(
            "cannot checkpoint a non-fully-addressable array from one "
            "process; gather it (or checkpoint per-host shards) first"
        )
    return np.asarray(jax.device_get(leaf))


def save(
    directory: str,
    step: int,
    tree: Any,
    extra: Optional[Dict[str, Any]] = None,
    host_id: int = 0,
    _crash_after: Optional[str] = None,
) -> str:
    """Synchronous checkpoint of a pytree of (possibly sharded) arrays.

    ``_crash_after`` is a fault-injection hook (tests/chaos harness only):
    raise :class:`SimulatedCrash` after the named stage completes —
    ``"shards"`` (array files written, no manifest), ``"manifest"``
    (manifest fsync'd inside the tmp dir, commit rename not taken), or
    ``"rename"`` (step dir renamed, LATEST not swung). Every one of these
    partial states must leave :func:`latest_step_dir` pointing at the
    previous committed step — that is the atomicity contract the crash-mid-
    save hardening tests pin."""
    flat = _flatten(tree)
    step_dir = os.path.join(directory, f"step_{step:09d}")
    tmp_dir = step_dir + f".tmp.{host_id}"
    os.makedirs(tmp_dir, exist_ok=True)

    arrays = {}
    meta = {}
    for key, leaf in flat.items():
        arr = gather_leaf(leaf)
        dtype_name = str(arr.dtype)
        if dtype_name in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[dtype_name][1])
        arrays[key.replace(SEP, "__")] = arr
        meta[key] = {"shape": list(arr.shape), "dtype": dtype_name}
    np.savez(os.path.join(tmp_dir, f"shard_{host_id:05d}.npz"), **arrays)
    if _crash_after == "shards":
        raise SimulatedCrash(f"injected crash after shard write: {tmp_dir}")

    manifest = {
        "step": step,
        "arrays": meta,
        "extra": extra or {},
        "n_hosts": jax.process_count(),
        "status": "committed",
    }
    mpath = os.path.join(tmp_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if _crash_after == "manifest":
        raise SimulatedCrash(
            f"injected crash after manifest, before commit: {tmp_dir}")
    # commit: rename tmp dir, then swing LATEST atomically
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    if _crash_after == "rename":
        raise SimulatedCrash(
            f"injected crash after rename, before LATEST: {step_dir}")
    latest_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(step_dir))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return step_dir


_pending: Dict[str, threading.Thread] = {}


def async_save(directory: str, step: int, tree: Any,
               extra: Optional[Dict[str, Any]] = None,
               _crash_after: Optional[str] = None) -> None:
    """Device->host transfer now; file IO on a background thread so the
    serving/train loop is not blocked (one in-flight save at a time).

    ``_crash_after`` rides through to :func:`save`; a
    :class:`SimulatedCrash` raised on the background thread is swallowed
    there — exactly like a real process kill between ``async_save`` and
    ``wait_pending``, the save just never commits and the partial tmp dir
    is left behind for the atomicity contract to neutralize."""
    wait_pending(directory)
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

    def _run() -> None:
        try:
            save(directory, step, host_tree, extra, _crash_after=_crash_after)
        except SimulatedCrash:
            pass  # the "process" died mid-save; partial state stays on disk

    t = threading.Thread(target=_run)
    t.start()
    _pending[directory] = t


def wait_pending(directory: str) -> None:
    t = _pending.pop(directory, None)
    if t is not None:
        t.join()


def _is_committed(step_dir: str) -> bool:
    mpath = os.path.join(step_dir, "manifest.json")
    if not os.path.exists(mpath):
        return False
    try:
        with open(mpath) as f:
            return json.load(f).get("status") == "committed"
    except (OSError, ValueError):
        return False


def latest_step_dir(directory: str) -> Optional[str]:
    """The last PUBLISHED step directory, or None. Publication is the
    atomic LATEST swing: while LATEST resolves to a committed dir, that
    dir wins — a newer step dir whose save crashed after the rename but
    before the swing is complete on disk yet deliberately invisible, so
    the commit point stays one unambiguous instruction. Only a missing or
    dangling LATEST (e.g. a crash between an rmtree of a re-saved step
    and its rename) falls back to scanning for the highest committed
    ``step_*`` dir, so a partial checkpoint can never be returned and a
    sole surviving committed one can never be missed."""
    latest = os.path.join(directory, "LATEST")
    if os.path.exists(latest):
        with open(latest) as f:
            name = f.read().strip()
        step_dir = os.path.join(directory, name)
        if _is_committed(step_dir):
            return step_dir
    if not os.path.isdir(directory):
        return None
    for name in sorted(os.listdir(directory), reverse=True):
        # tmp dirs are "step_<n>.tmp.<host>" — excluded by NAME, not by
        # manifest status: a crash after the manifest fsync but before the
        # commit rename leaves a committed-looking manifest inside the tmp
        # dir, and that state must stay invisible
        if name.startswith("step_") and ".tmp" not in name:
            step_dir = os.path.join(directory, name)
            if _is_committed(step_dir):
                return step_dir
    return None


def manifest_extra(directory: str) -> Dict[str, Any]:
    """The `extra` metadata of the latest committed checkpoint WITHOUT
    restoring any arrays — e.g. to inspect the recorded live query set of a
    persistent-query service (`extra["dense"]["order"]`) before deciding
    what to re-register."""
    step_dir = latest_step_dir(directory)
    if step_dir is None:
        raise FileNotFoundError(f"no committed checkpoint under {directory}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        return json.load(f)["extra"]


def restore(
    directory: str,
    like: Any,
    shardings: Any = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Restore the latest committed checkpoint into the structure of `like`.

    `shardings`: optional pytree (or single sharding) applied via device_put
    — this is where ELASTIC re-sharding happens: the checkpoint stores
    logical arrays, so restoring onto a different mesh shape just means
    different shardings here.

    `like` fixes the tree STRUCTURE and leaf dtypes only; leaf shapes come
    from the file. Restorers whose capacities legitimately differ from the
    writer's (e.g. a dense query group with a different bucketed-Q/K/label
    padding history) therefore get the writer's arrays back verbatim and
    re-pad them onto their own layout (engine.adopt_state).
    """
    step_dir = latest_step_dir(directory)
    if step_dir is None:
        raise FileNotFoundError(f"no committed checkpoint under {directory}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("status") != "committed":
        raise IOError(f"checkpoint {step_dir} not committed")
    arrays: Dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(step_dir)):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(step_dir, fn)) as z:
                for k in z.files:
                    arrays[k.replace("__", SEP)] = z[k]

    flat_like = _flatten(like)
    missing = set(flat_like) - set(arrays)
    if missing:
        raise KeyError(f"checkpoint missing arrays: {sorted(missing)[:5]} ...")

    flat_shard = None
    if shardings is not None and not _is_single_sharding(shardings):
        flat_shard = _flatten(shardings)

    out_flat = {}
    meta = manifest["arrays"]
    for key, leaf in flat_like.items():
        arr = arrays[key]
        stored = meta.get(key, {}).get("dtype", str(arr.dtype))
        if stored in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[stored][0])
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        if str(want_dtype) != str(arr.dtype):
            arr = arr.astype(want_dtype)
        if flat_shard is not None:
            out_flat[key] = jax.device_put(arr, flat_shard[key])
        elif shardings is not None:
            out_flat[key] = jax.device_put(arr, shardings)
        else:
            out_flat[key] = jax.device_put(arr)
    # rebuild tree in `like`'s structure
    leaves_order = [
        SEP.join(_path_str(p) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), [out_flat[k] for k in leaves_order]
    )
    return tree, manifest["extra"]


def _is_single_sharding(s: Any) -> bool:
    return isinstance(s, jax.sharding.Sharding)


# ---------------------------------------------------------------------------
# Opaque-object leaves: python engine state (e.g. the reference RPQ engines'
# pointer trees) rides the same manifest/shard machinery as device arrays by
# serializing to a uint8 leaf. Restore sites pass `pickle_like()` as the
# `like` leaf (dtype uint8; stored shape wins at load).
# ---------------------------------------------------------------------------


def pickle_leaf(obj: Any) -> np.ndarray:
    """Serialize an arbitrary python object into a checkpointable array."""
    import pickle

    return np.frombuffer(pickle.dumps(obj), dtype=np.uint8)


def unpickle_leaf(arr: Any) -> Any:
    """Inverse of :func:`pickle_leaf` (accepts np or device arrays)."""
    import pickle

    return pickle.loads(np.asarray(arr).tobytes())


def pickle_like() -> np.ndarray:
    """A `like` placeholder for a pickled leaf (shape comes from the file)."""
    return np.zeros((0,), np.uint8)
