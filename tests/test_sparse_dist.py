"""Row-sparse dist conformance (PR 9 tentpole).

The row-sparse layout must be BIT-identical to the dense (Q, N, N, K)
slab — per event, on both executors, under all three contraction
backends, with the frontier on and off, through deletions, expiry,
per-row overflow (bounded table + ×2 ``dist_cap`` growth), vertex-axis
growth/compaction, query churn, and checkpoints in both directions. The
dense layout is the oracle: every reachable (v, k) entry is folded with
the same (max, min) semantics wherever it lives (row slot or overflow
table), and free slots / stale duplicates annihilate under the max fold
(see core/sparse_dist.py).

Under the mxu_bucket backend identity is OBSERVABLE rather than bitwise:
window-dead entries a sparse row never re-encodes sit below every read
threshold, so emitted streams and valid-pair sets match exactly while
raw timestamps may differ in GC'd cells (the PR 6 deletion precedent).

The mesh legs run on whatever devices this process has (the CI
sparse-dist leg re-runs this file under
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the lane-sharded
row slabs compose with the in-jit densify).
"""
import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import compile_query
from repro.core.backend import BucketBackend, PallasBackend
from repro.core.engine import BatchedDenseRPQEngine, RegisteredQuery
from repro.core.executor import LocalExecutor
from repro.core.semiring import NEG_INF, batched_valid_pairs, frontier_seed
from repro.core.sparse_dist import (
    RowSparseDist,
    pack_rows,
    rsd_from_dense,
    rsd_gather_rows,
    rsd_grow_repack,
    rsd_live_entries,
    rsd_row_counts,
    rsd_scatter_rows,
    rsd_seed_gathered,
    rsd_to_dense,
    rsd_valid_pairs,
)
from repro.distributed.executor import MeshExecutor
from repro.kernels.rowsparse import (
    rowsparse_gather,
    rowsparse_gather_naive,
    rowsparse_gather_ref,
)
from repro.streaming.service import PersistentQueryService

QUERIES = ["a*", "a . b*", "(a | b)*", "a . b* . c", "(a . b)+", "a . b . c"]
LABELS = ["a", "b", "c"]


# -- unit: pack / densify / mutate ------------------------------------------


def _dev(sd):
    """pack_rows builds on host numpy; device-place before traced ops
    (the executor's _put_dist does the same)."""
    return jax.tree_util.tree_map(jnp.asarray, sd)


def _random_dense_dist(rng, q=2, n=10, k=3, density=0.2):
    d = np.full((q, n, n, k), NEG_INF, np.float32)
    for _ in range(int(q * n * n * k * density)):
        d[rng.randrange(q), rng.randrange(n), rng.randrange(n),
          rng.randrange(k)] = float(rng.randrange(1, 50))
    return d


@pytest.mark.parametrize("seed", range(4))
def test_pack_densify_round_trip(seed):
    rng = random.Random(seed)
    dense = _random_dense_dist(rng)
    cap = int(max((dense > NEG_INF).reshape(2, 10, -1).sum(-1).max(), 1))
    sd = pack_rows(dense, cap, 64)
    np.testing.assert_array_equal(np.asarray(rsd_to_dense(sd)), dense)
    assert int(rsd_live_entries(sd)) == int((dense > NEG_INF).sum())
    # tiny cap: overfull rows route to the table, densify still exact
    sd2 = pack_rows(dense, 1, 64)
    np.testing.assert_array_equal(np.asarray(rsd_to_dense(sd2)), dense)
    assert int(sd2.ovf_ptr) > 0


def test_pack_rejects_overfull_table():
    dense = np.full((1, 4, 4, 2), 5.0, np.float32)  # every row holds 8
    with pytest.raises(ValueError):
        pack_rows(dense, 1, 2)  # 4 overfull rows > 2 table slots
    pack_rows(dense, 8, 2)  # fits in slots, table untouched


@pytest.mark.parametrize("seed", range(3))
def test_from_dense_matches_pack(seed):
    """The traced repack (rsd_from_dense) and the host pack agree after
    densify — including rows routed through the overflow table."""
    rng = random.Random(seed)
    dense = _random_dense_dist(rng, density=0.35)
    for cap in (1, 2, 8):
        a = pack_rows(dense, cap, 64)
        b = rsd_from_dense(jnp.asarray(dense), cap, 64)
        np.testing.assert_array_equal(np.asarray(rsd_to_dense(a)),
                                      np.asarray(rsd_to_dense(b)))
        assert int(b.lost) == 0


@pytest.mark.parametrize("seed", range(3))
def test_gather_scatter_round_trip(seed):
    """Row gather equals a dense row take (via slots AND the table), and a
    full-row scatter-back is an exact overwrite — shrink-safe."""
    rng = random.Random(seed)
    q, n, k, f = 2, 10, 3, 4
    dense = _random_dense_dist(rng, q, n, k, density=0.3)
    sd = _dev(pack_rows(dense, 2, 64))  # tiny cap: rows live in the table
    rows = jnp.asarray([[1, 3, 5, 7], [0, 2, 5, 9]], jnp.int32)
    slab = rsd_gather_rows(sd, rows)
    want = jnp.asarray(dense)[jnp.arange(q)[:, None], rows]
    np.testing.assert_array_equal(np.asarray(slab), np.asarray(want))
    # mutate the slab, scatter back, densify: only the touched rows move
    slab2 = jnp.where(slab > NEG_INF, slab + 1.0, slab)
    rowmask = jnp.asarray([[True, True, False, True], [True] * 4])
    sd2 = rsd_scatter_rows(sd, rows, rowmask, slab2)
    want_d = dense.copy()
    for qi in range(q):
        for fi in range(f):
            if bool(rowmask[qi, fi]):
                r = int(rows[qi, fi])
                want_d[qi, r] = np.where(dense[qi, r] > NEG_INF,
                                         dense[qi, r] + 1.0, dense[qi, r])
    np.testing.assert_array_equal(np.asarray(rsd_to_dense(sd2)), want_d)
    assert int(sd2.lost) == 0


def test_seed_gathered_matches_dense_seed():
    rng = random.Random(0)
    q, n, k, b = 3, 9, 4, 5
    dense = _random_dense_dist(rng, q, n, k, density=0.25)
    sd = _dev(pack_rows(dense, 2, 256))
    src = jnp.asarray(rng.sample(range(n), b), jnp.int32)
    smask = jnp.asarray([True, True, False, True, False])
    qmask = jnp.asarray([True, False, True])
    got = rsd_seed_gathered(sd, src, smask, qmask)
    want = frontier_seed(jnp.asarray(dense), src, smask, qmask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_valid_pairs_matches_dense():
    """The sparse emit — O(Q·N·dist_cap) instead of the O(Q·N²·K) dense
    scan — produces the identical (Q, N, N) valid-pair set, and the
    pytree-dispatch in batched_valid_pairs routes to it."""
    rng = random.Random(1)
    q, n, k = 3, 9, 4
    dense = _random_dense_dist(rng, q, n, k, density=0.25)
    sd = _dev(pack_rows(dense, 2, 256))
    finals = jnp.asarray(np.random.default_rng(0).random((q, k)) < 0.5)
    low = jnp.asarray([3.0, 10.0, 25.0], jnp.float32)
    want = batched_valid_pairs(jnp.asarray(dense), finals, low)
    np.testing.assert_array_equal(
        np.asarray(rsd_valid_pairs(sd, finals, low)), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(batched_valid_pairs(sd, finals, low)), np.asarray(want))


def test_grow_repack_drains_table():
    rng = random.Random(2)
    dense = _random_dense_dist(rng, density=0.35)
    sd = _dev(pack_rows(dense, 1, 64))
    assert int(sd.ovf_ptr) > 0
    need = int(np.asarray(jax.device_get(jnp.max(rsd_row_counts(sd)))))
    cap = 1
    while cap < need:
        cap *= 2
    sd2 = rsd_grow_repack(sd, cap, 64)
    assert int(sd2.ovf_ptr) == 0  # every row now fits its slots
    np.testing.assert_array_equal(np.asarray(rsd_to_dense(sd2)), dense)


# -- unit: gather kernel vs naive oracle ------------------------------------


@pytest.mark.parametrize("seed", range(3))
def test_rowsparse_gather_matches_naive(seed):
    rng = np.random.default_rng(seed)
    m, c, e = 12, 4, 30
    idx = rng.integers(0, e, (m, c)).astype(np.int32)
    ts = np.where(rng.random((m, c)) < 0.6,
                  rng.integers(1, 40, (m, c)).astype(np.float32), NEG_INF)
    want = rowsparse_gather_naive(jnp.asarray(idx), jnp.asarray(ts), e)
    got_ref = rowsparse_gather_ref(jnp.asarray(idx), jnp.asarray(ts), e)
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want))
    got_pl = rowsparse_gather(jnp.asarray(idx), jnp.asarray(ts), e,
                              use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_pl), np.asarray(want))


# -- stream conformance: dense vs row-sparse --------------------------------


def _random_events(rng, n_vertices, n_edges, t_max, deletions=True):
    ts = sorted(rng.sample(range(1, t_max), k=min(n_edges, t_max - 1)))
    live = {}
    events = []
    for t in ts:
        u, v = rng.randrange(n_vertices), rng.randrange(n_vertices)
        lab = rng.choice(LABELS)
        if deletions and live and rng.random() < 0.15:
            du, dv, dl = rng.choice(sorted(live))
            del live[(du, dv, dl)]
            events.append(("-", du, dv, dl, float(t)))
        else:
            live[(u, v, lab)] = t
            events.append(("+", u, v, lab, float(t)))
    return events


def _specs(rng, n_queries, window):
    specs = []
    for qi in range(n_queries):
        expr = rng.choice(QUERIES)
        dfa = compile_query(expr)
        semantics = "simple" if (dfa.has_containment_property
                                 and rng.random() < 0.4) else "arbitrary"
        specs.append(RegisteredQuery(f"q{qi}", dfa, window, semantics))
    return specs


def _drive(make_engine, events, slide, n_queries):
    g = make_engine()
    next_exp = slide
    out = []
    for (op, u, v, lab, t) in events:
        if t >= next_exp:
            g.expire(t)
            while next_exp <= t:
                next_exp += slide
        if op == "+":
            fresh = g.insert(u, v, lab, t)
            out.append(("+",) + tuple(
                frozenset(fresh[qi]) for qi in range(n_queries)))
        else:
            inv = g.delete(u, v, lab, t)
            out.append(("-",) + tuple(
                frozenset(inv[qi]) for qi in range(n_queries)))
    return g, out


def _assert_streams_equal(tag, dense, sparse):
    assert len(dense) == len(sparse)
    for i, (d, s) in enumerate(zip(dense, sparse)):
        assert d == s, (tag, i, d, s)


BACKENDS = {
    "jnp": lambda: "jnp",
    "pallas": lambda: PallasBackend(interpret=True),
    "bucket": lambda: BucketBackend(n_levels=6, use_pallas=False),
}


def _conformance(seed, make_executor, backend_key, frontier,
                 dist_kwargs=None, batch_size=1, n_slots=24):
    rng = random.Random(seed)
    window = rng.choice([10.0, 25.0])
    nq = 3
    specs = _specs(rng, nq, window)
    events = _random_events(rng, 14, 80, 70)
    fr = dict(frontier=frontier, frontier_cap=4) if frontier else {}
    dist_kwargs = {"dist_layout": "row_sparse", "dist_cap": 4,
                   **(dist_kwargs or {})}

    def dense():
        ex = make_executor(BACKENDS[backend_key](), **fr)
        return BatchedDenseRPQEngine(specs, n_slots=n_slots,
                                     batch_size=batch_size, executor=ex)

    def sparse():
        ex = make_executor(BACKENDS[backend_key](), **fr, **dist_kwargs)
        return BatchedDenseRPQEngine(specs, n_slots=n_slots,
                                     batch_size=batch_size, executor=ex)

    g_d, ev_d = _drive(dense, events, 5.0, nq)
    g_s, ev_s = _drive(sparse, events, 5.0, nq)
    tag = (seed, backend_key, frontier)
    _assert_streams_equal(tag, ev_d, ev_s)
    assert g_d.retained_edges() == g_s.retained_edges(), tag
    assert g_s.executor.dist_stats["lost"] == 0, tag
    return g_d, g_s


def _local(backend, **kw):
    return LocalExecutor(backend, **kw)


def _mesh(backend, **kw):
    return MeshExecutor(model_axis=2, backend=backend, **kw)


@pytest.mark.parametrize("backend_key", sorted(BACKENDS))
@pytest.mark.parametrize("frontier", [None, "auto"])
def test_row_sparse_matches_dense_local(backend_key, frontier):
    _conformance(0, _local, backend_key, frontier)


@pytest.mark.parametrize("backend_key", sorted(BACKENDS))
def test_row_sparse_matches_dense_mesh(backend_key):
    _conformance(1, _mesh, backend_key, None)


def test_row_sparse_matches_dense_mesh_frontier():
    _conformance(2, _mesh, "jnp", "auto")


def test_overflow_table_regression():
    """dist_cap=1 + a small overflow table: most rows overflow, the host
    budget forces drains, drains force ×2 growth re-packs — and the
    stream stays bit-identical throughout with nothing lost."""
    _, g_s = _conformance(
        3, _local, "jnp", None,
        dist_kwargs=dict(dist_cap=1, dist_ovf_cap=512), batch_size=4)
    st = g_s.executor.dist_stats
    assert st["drains"] > 0, st
    assert st["repacks"] > 0, st
    assert st["dist_cap"] > 1, st  # grew toward the live max row occupancy
    assert st["lost"] == 0, st
    assert st["live_entries"] is not None and st["live_entries"] > 0, st


def test_overflow_table_regression_frontier_mesh():
    _, g_s = _conformance(
        4, _mesh, "jnp", "auto",
        dist_kwargs=dict(dist_cap=1, dist_ovf_cap=512), batch_size=4)
    assert g_s.executor.dist_stats["lost"] == 0


def test_survives_slot_growth_and_compaction():
    """More distinct vertices than n_slots: the engine compacts and grows
    the vertex axis mid-stream; the row-sparse re-pack rides
    executor.grow through the canonical dense slab."""
    _conformance(5, _local, "jnp", None, n_slots=8, batch_size=2)


def test_survives_query_churn():
    """Register a query mid-stream and deregister another: lane lifecycle
    re-pads device state in place; the sparse layout rides along
    bit-identically."""
    rng = random.Random(6)
    specs = _specs(rng, 2, 20.0)
    head = _random_events(rng, 10, 40, 35)
    tail = _random_events(random.Random(7), 10, 30, 35)
    late = RegisteredQuery("late", compile_query("a . b*"), 20.0, "arbitrary")

    def run(layout):
        kw = (dict(dist_layout="row_sparse", dist_cap=2)
              if layout == "row_sparse" else {})
        g = BatchedDenseRPQEngine(
            specs, n_slots=16, batch_size=2,
            executor=LocalExecutor("jnp", **kw))
        _, ev = [g, []]
        out = []
        for (op, u, v, lab, t) in head:
            if op == "+":
                out.append(("+", tuple(map(frozenset, g.insert(u, v, lab, t)))))
            else:
                out.append(("-", tuple(map(frozenset, g.delete(u, v, lab, t)))))
        out.append(("reg", frozenset(g.register_query(late))))
        g.deregister_query("q0")
        for (op, u, v, lab, t) in tail:
            t2 = t + 35.0
            if op == "+":
                out.append(("+", tuple(map(frozenset, g.insert(u, v, lab, t2)))))
            else:
                out.append(("-", tuple(map(frozenset, g.delete(u, v, lab, t2)))))
        return out

    assert run("dense") == run("row_sparse")


# -- checkpoints across layouts --------------------------------------------


def _ckpt_state(g):
    return {k: np.asarray(jax.device_get(v))
            for k, v in g.state_arrays().items()}


@pytest.mark.parametrize("src_layout,dst_layout",
                         [("dense", "row_sparse"), ("row_sparse", "dense")])
def test_checkpoint_cross_layout(src_layout, dst_layout):
    rng = random.Random(7)
    specs = _specs(rng, 2, 20.0)
    events = _random_events(rng, 10, 50, 45)

    def make(layout):
        kw = (dict(dist_layout="row_sparse", dist_cap=2)
              if layout == "row_sparse" else {})
        return BatchedDenseRPQEngine(specs, n_slots=16, batch_size=2, **kw)

    g_src, _ = _drive(lambda: make(src_layout), events, 5.0, 2)
    state = _ckpt_state(g_src)
    assert state["dist"].ndim == 4, "checkpoints are canonical dense"
    g_dst = make(dst_layout)
    g_dst.load_state_arrays(state)
    g_dst.load_interner(g_src.interner_state())  # slot map rides alongside
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(g_src.executor.dense_dist())),
        np.asarray(jax.device_get(g_dst.executor.dense_dist())))
    if dst_layout == "row_sparse":
        assert isinstance(g_dst.executor.arrays.dist, RowSparseDist)
    # the restored engine continues the stream identically to the source
    tail = _random_events(random.Random(8), 10, 20, 45)

    def cont(g):
        out = []
        for (op, u, v, lab, t) in tail:
            t2 = t + 45.0
            if op == "+":
                out.append(tuple(map(frozenset, g.insert(u, v, lab, t2))))
            else:
                out.append(tuple(map(frozenset, g.delete(u, v, lab, t2))))
        return out

    assert cont(g_src) == cont(g_dst)


# -- telemetry + validation --------------------------------------------------


def test_dist_stats_telemetry():
    g_d, g_s = _conformance(8, _local, "jnp", None)
    st = g_s.executor.dist_stats
    assert st["layout"] == "row_sparse"
    assert st["dist_cap"] >= 1 and st["ovf_cap"] >= 1
    assert st["dist_bytes"] > 0 and st["slot_cells"] > 0
    # the per-row slabs are O(Q·N·dist_cap) — N-linear, not N² (the fixed
    # bounded overflow table can dominate at toy scale; the N² memory win
    # is benchmarks/fig19_sparse_dist.py's big-N claim)
    q, n, _, k = g_s.executor.dist_shape
    assert st["slot_cells"] == q * n * st["dist_cap"]
    dense_st = g_d.executor.dist_stats
    assert dense_st["layout"] == "dense"


def test_layout_validation():
    with pytest.raises(ValueError):
        LocalExecutor("jnp", dist_layout="bogus")
    with pytest.raises(ValueError):
        LocalExecutor("jnp", dist_layout="row_sparse", dist_cap=0)
    with pytest.raises(ValueError):
        PersistentQueryService(window=1.0, slide=1.0, dist_layout="bogus")


def test_service_dist_log():
    from repro.streaming.generators import so_like, with_deletions
    from repro.streaming.stream import Stream

    tuples = list(with_deletions(so_like(20, 80, seed=3), ratio=0.05, seed=5))

    def run(layout):
        svc = PersistentQueryService(window=20.0, slide=2.0,
                                     dist_layout=layout, dist_cap=2)
        svc.register("q", "a2q . c2a*", engine="dense", n_slots=32)
        svc.ingest(Stream(tuples))
        return svc

    svc_d, svc_s = run("dense"), run("row_sparse")
    assert svc_d.results("q") == svc_s.results("q")
    assert not svc_d.dist_log
    assert svc_s.dist_log, "row-sparse service logs per-interval dist stats"
    seen, st = svc_s.dist_log[-1]
    assert st["layout"] == "row_sparse" and st["lost"] == 0
