"""Conformance: the multi-query BatchedDenseRPQEngine vs Q independent
DenseRPQEngines vs the core/batch.py oracles, on randomized streams with
inserts, window expiry, and explicit deletions, under both path semantics.

B=1 everywhere: at batch size 1 the batched group is specified to match
every member query tuple-for-tuple (core/engine.py module docstring); the
B>1 / Q>1 boundary skew is covered by the superset-safety test below.
"""
import random

import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (
    RAPQ,
    batch_rapq,
    batch_rspq_bruteforce,
    compile_query,
    snapshot_from_edges,
    streaming_oracle,
)
from repro.core.engine import BatchedDenseRPQEngine, DenseRPQEngine, RegisteredQuery

QUERIES = ["a*", "a . b*", "(a | b)*", "a . b* . c", "(a . b)+", "a . b . c"]
LABELS = ["a", "b", "c"]


def _random_stream(rng, n_vertices, n_edges, t_max):
    ts = sorted(rng.sample(range(1, t_max), k=min(n_edges, t_max - 1)))
    return [
        (rng.randrange(n_vertices), rng.randrange(n_vertices), rng.choice(LABELS), float(t))
        for t in ts
    ]


def _make_group(rng, n_queries, window, n_slots=16):
    """Q random queries (mixed arbitrary/simple; simple only for automata
    where the dense answer is provably exact, i.e. containment property)."""
    specs = []
    for qi in range(n_queries):
        expr = rng.choice(QUERIES)
        dfa = compile_query(expr)
        semantics = "arbitrary"
        if dfa.has_containment_property and rng.random() < 0.4:
            semantics = "simple"
        specs.append(RegisteredQuery(f"q{qi}", dfa, window, semantics))
    group = BatchedDenseRPQEngine(specs, n_slots=n_slots, batch_size=1)
    indep = [
        DenseRPQEngine(s.dfa, window, n_slots=n_slots, batch_size=1,
                       path_semantics=s.path_semantics)
        for s in specs
    ]
    return specs, group, indep


def _check_stream(seed, n_queries=3, with_deletions=False, with_expiry=True):
    rng = random.Random(seed)
    window = rng.choice([8.0, 15.0, 40.0])
    specs, group, indep = _make_group(rng, n_queries, window)
    stream = _random_stream(rng, n_vertices=6, n_edges=20, t_max=60)
    live = {}
    events = []  # (op, u, v, lab, ts)
    for i, (u, v, lab, ts) in enumerate(stream):
        if with_deletions and live and rng.random() < 0.25:
            du, dv, dl = rng.choice(sorted(live))
            del live[(du, dv, dl)]
            events.append(("-", du, dv, dl, ts))
        else:
            live[(u, v, lab)] = ts
            events.append(("+", u, v, lab, ts))
    for i, (op, u, v, lab, ts) in enumerate(events):
        if op == "+":
            fresh = group.insert(u, v, lab, ts)
            for qi, eng in enumerate(indep):
                f1 = eng.insert(u, v, lab, ts)
                assert fresh[qi] == f1, (seed, i, qi, fresh[qi] ^ f1)
        else:
            inv = group.delete(u, v, lab, ts)
            for qi, eng in enumerate(indep):
                i1 = eng.delete(u, v, lab, ts)
                assert inv[qi] == i1, (seed, i, qi)
        if with_expiry and i % 7 == 6:
            group.expire(ts)
            for eng in indep:
                eng.expire(ts)
        # snapshot view agrees with the batch oracle on the live window
        if i % 9 == 8:
            for qi, spec in enumerate(specs):
                cur = group.current_results(qi)
                assert cur == indep[qi].current_results(), (seed, i, qi)
    return specs, group, indep, events


@pytest.mark.parametrize("seed", range(3))
def test_batched_matches_independent_inserts_only(seed):
    """Insert-only streams: per-event result streams AND the final monotone
    sets match Q independent engines and the streaming oracle."""
    specs, group, indep, events = _check_stream(seed, n_queries=3,
                                                with_deletions=False)
    edges = [(u, v, lab, ts) for (_op, u, v, lab, ts) in events]
    for qi, spec in enumerate(specs):
        assert group.per_query_results[qi] == indep[qi].results
        oracle = streaming_oracle(edges, spec.dfa, spec.window,
                                  simple=spec.path_semantics == "simple")
        if spec.path_semantics == "simple":
            # dense simple mode never reports the diagonal
            oracle = {p for p in oracle if p[0] != p[1]}
        assert group.per_query_results[qi] == oracle, (seed, qi, spec)


@pytest.mark.parametrize("seed", range(6, 9))
def test_batched_matches_independent_with_deletions(seed):
    _check_stream(seed, n_queries=3, with_deletions=True)


def test_batched_snapshot_matches_batch_oracle():
    """Explicit-window view vs product-BFS / simple-path DFS on the window
    content, for an arbitrary- and a simple-semantics query side by side."""
    rng = random.Random(4)
    window = 15.0
    d_arb = compile_query("a . b*")
    d_smp = compile_query("(a | b)*")
    assert d_smp.has_containment_property
    group = BatchedDenseRPQEngine(
        [RegisteredQuery("arb", d_arb, window, "arbitrary"),
         RegisteredQuery("smp", d_smp, window, "simple")],
        n_slots=16, batch_size=1,
    )
    stream = _random_stream(rng, n_vertices=7, n_edges=30, t_max=80)
    for i, (u, v, lab, ts) in enumerate(stream):
        group.insert(u, v, lab, ts)
        if i % 6 == 5:
            snap = snapshot_from_edges(stream[: i + 1], low=ts - window, high=ts)
            assert group.current_results(0) == batch_rapq(snap, d_arb)
            expect = {p for p in batch_rspq_bruteforce(snap, d_smp)
                      if p[0] != p[1]}
            assert group.current_results(1) == expect


def test_batched_b1_matches_reference_per_tuple():
    """The whole group matches paper-faithful RAPQ tuple-for-tuple at B=1."""
    rng = random.Random(11)
    window = 20.0
    exprs = ["a . b*", "(a | b)*", "a*"]
    specs = [RegisteredQuery(f"q{i}", compile_query(e), window)
             for i, e in enumerate(exprs)]
    group = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1)
    refs = [RAPQ(s.dfa, window) for s in specs]
    for (u, v, lab, ts) in _random_stream(rng, 8, 35, 90):
        fresh = group.insert(u, v, lab, ts)
        for qi, ref in enumerate(refs):
            assert fresh[qi] == ref.insert(u, v, lab, ts), (qi, (u, v, lab, ts))
    for qi, ref in enumerate(refs):
        assert group.per_query_results[qi] == ref.results


def test_batched_b8_superset_safety():
    """B > 1 group: no spurious results (subset of the oracle) and full
    coverage of everything valid at the final batch boundary."""
    rng = random.Random(9)
    window = 25.0
    exprs = ["a . b*", "a*"]
    specs = [RegisteredQuery(f"q{i}", compile_query(e), window)
             for i, e in enumerate(exprs)]
    group = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=8)
    stream = _random_stream(rng, n_vertices=8, n_edges=40, t_max=100)
    group.insert_batch(stream)
    last_ts = stream[-1][3]
    snap = snapshot_from_edges(stream, low=last_ts - window, high=last_ts)
    for qi, spec in enumerate(specs):
        oracle = streaming_oracle(stream, spec.dfa, window)
        assert group.per_query_results[qi] <= oracle
        assert batch_rapq(snap, spec.dfa) <= group.per_query_results[qi]


def test_batched_shares_dispatches():
    """The whole point: Q queries, ONE jitted dispatch per micro-batch."""
    rng = random.Random(1)
    window = 30.0
    specs = [RegisteredQuery(f"q{i}", compile_query(e), window)
             for i, e in enumerate(QUERIES[:4])]
    group = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1)
    indep = [DenseRPQEngine(s.dfa, window, n_slots=16, batch_size=1)
             for s in specs]
    stream = _random_stream(rng, 8, 30, 90)
    for (u, v, lab, ts) in stream:
        group.insert(u, v, lab, ts)
        for eng in indep:
            eng.insert(u, v, lab, ts)
    assert group.steps == len(stream)
    assert sum(e.steps for e in indep) > group.steps
    for qi, eng in enumerate(indep):
        assert group.per_query_results[qi] == eng.results


def test_batched_conflict_flags_are_per_query():
    """A conflicting simple-path query must not contaminate its neighbors'
    flags (per-query (Q,K,K) containment masks)."""
    window = 100.0
    d_conf = compile_query("(a . b)+")    # no containment property
    d_safe = compile_query("(a | b)*")    # containment property holds
    group = BatchedDenseRPQEngine(
        [RegisteredQuery("conf", d_conf, window, "simple"),
         RegisteredQuery("safe", d_safe, window, "simple")],
        n_slots=8, batch_size=1,
    )
    for e in [("x", "y", "a", 1.0), ("y", "u", "b", 2.0),
              ("u", "v", "a", 3.0), ("v", "y", "b", 4.0)]:
        group.insert(*e)
    assert group.per_query_conflicted[0]
    assert not group.per_query_conflicted[1]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_batched_property_random_streams(seed):
    """Property form of the conformance check (runs when hypothesis is
    installed; skipped with a clear reason otherwise)."""
    _check_stream(seed, n_queries=3,
                  with_deletions=bool(seed % 2), with_expiry=True)
