"""Distributed relaxation schedules (dry-run §Perf variants) must compute
the same round as the single-device reference. Runs in a subprocess with 8
virtual devices (XLA_FLAGS must precede jax import)."""
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # multi-minute: 8-device subprocess compile

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.automaton import compile_query
from repro.core.backend import BucketBackend
from repro.core.semiring import NEG_INF, TransitionTable, relax_round
from repro.launch.mesh import mesh_context
from repro.launch.dryrun_rpq import (N_LEVELS, make_ring_round,
                                     relax_round_vchunked)

mesh = jax.make_mesh((2, 4), ("data", "model"))
dfa = compile_query("a . b*")
tt = TransitionTable.from_dfa(dfa)
N = 64
rng = np.random.default_rng(0)
dist = rng.uniform(0, 100, (N, N, dfa.k)).astype(np.float32)
dist[rng.random(dist.shape) < 0.5] = -np.inf
adj = rng.uniform(0, 100, (dfa.n_labels, N, N)).astype(np.float32)
adj[rng.random(adj.shape) < 0.6] = -np.inf

ref = np.asarray(relax_round(jnp.asarray(dist), jnp.asarray(adj), tt))

# 1) v-chunked GSPMD baseline
dist_sh = NamedSharding(mesh, P("data", "model", None))
adj_sh = NamedSharding(mesh, P(None, None, "model"))
with mesh_context(mesh):
    out = jax.jit(lambda d, a: relax_round_vchunked(d, a, tt, 16),
                  in_shardings=(dist_sh, adj_sh))(jnp.asarray(dist), jnp.asarray(adj))
np.testing.assert_allclose(np.asarray(out), ref)
print("vchunked OK")

# 2) ring schedule (shard_map). NOTE: the ring round omits the base term
# (applied once per ingest outside the iterated round), so compare against
# the round WITHOUT base: mask start transitions' base by feeding adj only
# through the contraction — easiest is to compare rings vs vchunked with a
# dist that already dominates the base.
dist_hi = np.maximum(dist, np.nanmax(np.where(np.isfinite(adj), adj, np.nan)))
ref_hi = np.asarray(relax_round(jnp.asarray(dist_hi), jnp.asarray(adj), tt))
adj_ring_sh = NamedSharding(mesh, P(None, "model", None))
ring = make_ring_round(mesh, tt, N, multi_pod=False)
with mesh_context(mesh):
    out2 = jax.jit(ring, in_shardings=(dist_sh, adj_ring_sh),
                   out_shardings=dist_sh)(jnp.asarray(dist_hi), jnp.asarray(adj))
np.testing.assert_allclose(np.asarray(out2), ref_hi)
print("ring OK")

# 3) MXU bucket mode on quantized levels — the engine's BucketBackend
# contraction through the generic backend-parameterized round (the old
# relax_round_mxu_bucket special case is gone)
T = N_LEVELS
lv = lambda x: np.where(np.isfinite(x), np.clip(np.ceil(x / (100.0 / T)), 0, T), 0).astype(np.int32)
dist_lv, adj_lv = lv(dist), lv(adj)
ref_lv = np.asarray(relax_round(jnp.asarray(dist_lv.astype(np.float32)),
                                jnp.asarray(np.where(adj_lv > 0, adj_lv, -np.inf).astype(np.float32)), tt))
ref_lv = np.where(np.isfinite(ref_lv), ref_lv, 0).astype(np.int32)
bucket = BucketBackend(n_levels=T, use_pallas=False)
with mesh_context(mesh):
    out3 = jax.jit(lambda d, a: relax_round(d, a, tt, bucket),
                   in_shardings=(dist_sh, adj_sh))(jnp.asarray(dist_lv), jnp.asarray(adj_lv))
np.testing.assert_array_equal(np.asarray(out3), ref_lv)
print("mxu OK")
'''


def test_distributed_relax_schedules():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=900, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "vchunked OK" in proc.stdout
    assert "ring OK" in proc.stdout
    assert "mxu OK" in proc.stdout
