"""Import shim so the tier-1 suite collects on a bare interpreter.

``hypothesis`` drives the property tests but is not part of the runtime
dependency set; on machines without it (fresh containers, CI images before
``pip install -r requirements-dev.txt``) the suite previously died at
collection with ImportError. Test modules import ``given``/``settings``/
``st`` from HERE instead of from ``hypothesis``:

* with hypothesis installed, the real objects are re-exported unchanged;
* without it, ``given`` wraps the test in a skip with a clear reason (the
  wrapper deliberately exposes a ``(*args, **kwargs)`` signature so pytest
  does not mistake the property-test arguments for fixtures), ``settings``
  becomes a no-op decorator, and ``st.*`` return inert placeholders.

Deterministic (parametrized) tests in the same modules still run either
way — only the randomized property tests are skipped.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    import pytest

    HAVE_HYPOTHESIS = False
    _REASON = ("property test skipped: hypothesis not installed "
               "(pip install -r requirements-dev.txt)")

    def given(*_args, **_kwargs):
        def deco(fn):
            def wrapper(*args, **kwargs):
                pytest.skip(_REASON)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategy:
        """Inert placeholder accepted anywhere a SearchStrategy is."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _StrategiesStub:
        def __getattr__(self, name):
            return _Strategy()

    st = _StrategiesStub()
