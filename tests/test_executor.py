"""Executor conformance: LocalExecutor vs MeshExecutor must produce
bit-identical per-event result streams for the batched dense engine —
under both path semantics, with explicit deletions, window expiry, query
churn mid-stream, and checkpoint cross-restore (local-written → mesh-
restored and vice versa). Plus regression tests for the PR 3 satellites:
runtime n_slots (vertex-axis) growth and the service's RSPQ fallback.

The mesh tests run on whatever devices this process has: one device yields
the degenerate 1-shard mesh (still exercising the shard_map path); the CI
``tier1-sharded`` job re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so real Q-sharding
(and, where marked, vertex sharding over 'model') is covered.
"""
import random
import tempfile

import jax
import pytest

from repro.core import RSPQ, compile_query
from repro.core.engine import BatchedDenseRPQEngine, DenseRPQEngine, RegisteredQuery
from repro.distributed.executor import MeshExecutor
from repro.streaming.generators import so_like, with_deletions
from repro.streaming.service import PersistentQueryService
from repro.streaming.stream import Stream

QUERIES = ["a*", "a . b*", "(a | b)*", "a . b* . c", "(a . b)+", "a . b . c"]
LABELS = ["a", "b", "c"]


def _random_stream(rng, n_vertices, n_edges, t_max):
    ts = sorted(rng.sample(range(1, t_max), k=min(n_edges, t_max - 1)))
    return [
        (rng.randrange(n_vertices), rng.randrange(n_vertices),
         rng.choice(LABELS), float(t))
        for t in ts
    ]


def _specs(rng, n_queries, window):
    specs = []
    for qi in range(n_queries):
        expr = rng.choice(QUERIES)
        dfa = compile_query(expr)
        semantics = "arbitrary"
        if dfa.has_containment_property and rng.random() < 0.4:
            semantics = "simple"
        specs.append(RegisteredQuery(f"q{qi}", dfa, window, semantics))
    return specs


def _events(rng, stream, with_deletions_=True):
    live = {}
    events = []
    for (u, v, lab, ts) in stream:
        if with_deletions_ and live and rng.random() < 0.2:
            du, dv, dl = rng.choice(sorted(live))
            del live[(du, dv, dl)]
            events.append(("-", du, dv, dl, ts))
        else:
            live[(u, v, lab)] = ts
            events.append(("+", u, v, lab, ts))
    return events


def _assert_lanewise(tag, n_queries, fl, fm):
    """Local fresh list (lane == query) vs mesh fresh list (lane capacity
    may be padded to the shard multiple; padding must stay silent)."""
    for qi in range(n_queries):
        assert fl[qi] == fm[qi], (tag, qi, fl[qi] ^ fm[qi])
    assert all(not s for s in fm[n_queries:]), (tag, "padding lane emitted")


@pytest.mark.parametrize("seed", range(3))
def test_mesh_matches_local_per_event(seed):
    """Inserts + deletions + expiry, mixed semantics: every event's fresh
    results and invalidations are identical between executors."""
    rng = random.Random(seed)
    window = rng.choice([10.0, 25.0])
    nq = 3
    specs = _specs(rng, nq, window)
    local = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1)
    mesh = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1,
                                 executor=MeshExecutor())
    events = _events(rng, _random_stream(rng, 6, 24, 70))
    for i, (op, u, v, lab, ts) in enumerate(events):
        if op == "+":
            _assert_lanewise((seed, i), nq,
                             local.insert(u, v, lab, ts),
                             mesh.insert(u, v, lab, ts))
        else:
            _assert_lanewise((seed, i), nq,
                             local.delete(u, v, lab, ts),
                             mesh.delete(u, v, lab, ts))
        if i % 6 == 5:
            local.expire(ts)
            mesh.expire(ts)
        if i % 9 == 8:
            for qi in range(nq):
                assert local.current_results(qi) == mesh.current_results(qi)
    for qi in range(nq):
        assert local.per_query_results[qi] == mesh.per_query_results[qi]
        assert (local.per_query_conflicted[qi]
                == mesh.per_query_conflicted[qi])


def test_mesh_churn_mid_stream_matches_local():
    """register/deregister mid-stream on both executors: the mesh group's
    lane layout differs (shard-multiple padding, reclaimed holes) but the
    per-query result streams stay identical, matched by name."""
    rng = random.Random(7)
    window = 30.0
    base = [RegisteredQuery("q0", compile_query("a . b*"), window),
            RegisteredQuery("q1", compile_query("(a | b)*"), window)]
    local = BatchedDenseRPQEngine(base, n_slots=16, batch_size=1)
    mesh = BatchedDenseRPQEngine(base, n_slots=16, batch_size=1,
                                 executor=MeshExecutor())
    stream = _random_stream(rng, 6, 30, 90)
    late = RegisteredQuery("late", compile_query("a*"), window)
    for i, (u, v, lab, ts) in enumerate(stream):
        if i == 10:
            il = local.register_query(late)
            im = mesh.register_query(late)
            assert il == im
        if i == 20:
            local.deregister_query("q0")
            mesh.deregister_query("q0")
        fl = local.insert(u, v, lab, ts)
        fm = mesh.insert(u, v, lab, ts)
        for qi_l, spec in local.live_items():
            qi_m = mesh.lane_of(spec.name)
            assert fl[qi_l] == fm[qi_m], (i, spec.name)
        if i % 7 == 6:
            local.expire(ts)
            mesh.expire(ts)
    for qi_l, spec in local.live_items():
        qi_m = mesh.lane_of(spec.name)
        assert local.per_query_results[qi_l] == mesh.per_query_results[qi_m]


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="vertex sharding needs >= 2 devices")
def test_mesh_vertex_sharding_matches_local():
    """model-axis vertex sharding: the u-contraction splits into per-shard
    partials combined by pmax — must stay exact (max/min reassociates)."""
    rng = random.Random(3)
    window = 25.0
    specs = _specs(rng, 4, window)
    local = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1)
    mesh = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1,
                                 executor=MeshExecutor(model_axis=2))
    for i, (op, u, v, lab, ts) in enumerate(
            _events(rng, _random_stream(rng, 7, 28, 80))):
        if op == "+":
            _assert_lanewise(i, 4, local.insert(u, v, lab, ts),
                             mesh.insert(u, v, lab, ts))
        else:
            _assert_lanewise(i, 4, local.delete(u, v, lab, ts),
                             mesh.delete(u, v, lab, ts))
        if i % 5 == 4:
            local.expire(ts)
            mesh.expire(ts)


def test_mesh_skip_accounting_consistent():
    """Convergence-aware dispatch bookkeeping: shard_rounds + skipped ==
    n_shards * sync_rounds, and per-query round counts match the local
    executor's exactly (same convergence criterion per lane)."""
    rng = random.Random(1)
    specs = [RegisteredQuery(f"q{i}", compile_query(e), 30.0)
             for i, e in enumerate(QUERIES[:4])]
    local = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1)
    ex = MeshExecutor()
    mesh = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1, executor=ex)
    for (u, v, lab, ts) in _random_stream(rng, 8, 25, 70):
        local.insert(u, v, lab, ts)
        mesh.insert(u, v, lab, ts)
    assert mesh.total_query_rounds == local.total_query_rounds
    assert mesh.total_rounds == local.total_rounds
    assert (ex.shard_rounds_total + ex.skipped_shard_rounds_total
            == ex.n_shards * ex.sync_rounds_total)
    if ex.n_shards > 1:
        # mixed-depth queries: some shard must have settled early
        assert ex.skipped_shard_rounds_total > 0


# ---------------------------------------------------------------------------
# service-level: executor selection, async decode, cross-executor restore
# ---------------------------------------------------------------------------

WINDOW, SLIDE = 20.0, 2.0


def _service(executor="local", async_decode=False):
    svc = PersistentQueryService(window=WINDOW, slide=SLIDE,
                                 executor=executor, async_decode=async_decode)
    svc.register("arb", "a2q . c2a*", engine="dense", n_slots=32)
    svc.register("plus", "(a2q | c2a)+", engine="dense", n_slots=32)
    svc.register("smp", "(a2q | c2a | c2q)*", engine="dense",
                 path_semantics="simple", n_slots=32)
    return svc


NAMES = ["arb", "plus", "smp"]


def _tuples():
    return list(with_deletions(so_like(20, 90, seed=13), ratio=0.05, seed=7))


@pytest.mark.parametrize("async_decode", [False, True])
def test_service_mesh_executor_matches_local(async_decode):
    tuples = _tuples()
    svc_l = _service("local")
    svc_m = _service("mesh", async_decode=async_decode)
    rep_l = svc_l.ingest(Stream(tuples))
    rep_m = svc_m.ingest(Stream(tuples))
    for name in NAMES:
        assert rep_l[name] == rep_m[name], name
        assert rep_l.invalidated[name] == rep_m.invalidated[name], name
        assert svc_l.results(name) == svc_m.results(name), name


def test_async_decode_matches_sync_per_batch():
    """The deferred decode path returns the SAME report as the blocking
    path even when ingest is called in many small slices (pending handles
    resolved across expiry boundaries and at the end of each call)."""
    tuples = _tuples()
    svc_s = _service("local", async_decode=False)
    svc_a = _service("local", async_decode=True)
    seen_s, seen_a = set(), set()
    for i in range(0, len(tuples), 17):
        batch = tuples[i:i + 17]
        rep_s = svc_s.ingest(Stream(batch))
        rep_a = svc_a.ingest(Stream(batch))
        for name in NAMES:
            assert rep_s[name] == rep_a[name], (i, name)
        seen_s |= rep_s["arb"]
        seen_a |= rep_a["arb"]
        assert not (rep_a["arb"] & (seen_a - rep_a["arb"]))  # no re-emission
    assert svc_s.results("arb") == svc_a.results("arb") == seen_s


@pytest.mark.parametrize("depth", [2, 4])
def test_async_depth_bounded_fifo_matches_sync(depth):
    """PR 4 satellite: up to `async_depth` dispatches in flight before the
    oldest frontier is pulled — reports stay identical to the blocking path
    across slicing, expiry boundaries, and deletions (FIFO drain order)."""
    tuples = _tuples()
    svc_s = _service("local", async_decode=False)
    svc_d = PersistentQueryService(window=WINDOW, slide=SLIDE,
                                   executor="local", async_decode=True,
                                   async_depth=depth)
    for name, expr, kw in [
        ("arb", "a2q . c2a*", {}),
        ("plus", "(a2q | c2a)+", {}),
        ("smp", "(a2q | c2a | c2q)*", {"path_semantics": "simple"}),
    ]:
        svc_d.register(name, expr, engine="dense", n_slots=32, **kw)
    for i in range(0, len(tuples), 13):
        batch = tuples[i:i + 13]
        rep_s = svc_s.ingest(Stream(batch))
        rep_d = svc_d.ingest(Stream(batch))
        for name in NAMES:
            assert rep_s[name] == rep_d[name], (i, name, depth)
            assert rep_s.invalidated[name] == rep_d.invalidated[name]
    for name in NAMES:
        assert svc_s.results(name) == svc_d.results(name)


def test_async_pending_survives_compaction():
    """Interner-snapshot safety at depth > 1: handles dispatched BEFORE a
    compaction that recycles their pairs' slots must decode against the
    snapshot, not the mutated interner. Engine-level: queue several
    pending dispatches, force expiry/recycling, then resolve."""
    dfa = compile_query("a . b*")
    eng = DenseRPQEngine(dfa, window=3.0, n_slots=6, batch_size=1)
    oracle = DenseRPQEngine(dfa, window=3.0, n_slots=6, batch_size=1)
    handles = []
    fresh_oracle = []
    # distinct vertices per step so expiry leaves dead slots to recycle
    for t in range(1, 10):
        u, v = f"u{t}", f"v{t}"
        handles.append(eng.insert_batch_pending([(u, v, "a", float(t))]))
        fresh_oracle.append(oracle.insert(u, v, "a", float(t)))
    eng.expire(9.0)      # recycles slots of expired vertices
    oracle.expire(9.0)
    for h, fo in zip(handles, fresh_oracle):
        assert h.resolve()[0] == fo
    assert eng.results == oracle.results


@pytest.mark.parametrize("writer,reader", [("local", "mesh"), ("mesh", "local")])
def test_checkpoint_cross_restore_between_executors(writer, reader):
    """A checkpoint written under one executor restores under the other
    (arrays are logical; placement is the restoring executor's concern) and
    the tail result stream is identical to the uninterrupted run."""
    tuples = _tuples()
    half = len(tuples) // 2
    svc = _service(writer)
    svc.ingest(Stream(tuples[:half]))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc.snapshot(ckpt_dir, step=half)
        mid = {name: svc.results(name) for name in NAMES}
        tail = svc.ingest(Stream(tuples[half:]))
        final = {name: svc.results(name) for name in NAMES}

        svc2 = _service(reader)
        assert svc2.restore(ckpt_dir) == half
        for name in NAMES:
            assert svc2.results(name) == mid[name], name
        tail2 = svc2.ingest(Stream(tuples[half:]))
        for name in NAMES:
            assert tail2[name] == tail[name], name
            assert svc2.results(name) == final[name], name


# ---------------------------------------------------------------------------
# satellite regressions: runtime n_slots growth
# ---------------------------------------------------------------------------


def test_n_slots_grows_on_demand():
    """A tiny engine ingesting more window-live vertices than it has slots
    must grow the vertex axis instead of raising, and keep producing the
    same results as an amply-sized engine."""
    dfa = compile_query("a . b*")
    small = DenseRPQEngine(dfa, window=1000.0, n_slots=4, batch_size=1)
    big = DenseRPQEngine(dfa, window=1000.0, n_slots=64, batch_size=1)
    rng = random.Random(5)
    for t in range(1, 40):
        u, v = rng.randrange(12), rng.randrange(12)
        lab = rng.choice(["a", "b"])
        assert small.insert(u, v, lab, float(t)) == big.insert(u, v, lab, float(t))
    assert small.n_slots > 4, "vertex capacity never grew"
    assert small.results == big.results
    # the grown engine keeps ALL interned vertices addressable
    assert set(small.slot_of) == set(big.slot_of)


def test_n_slots_growth_prefers_compaction():
    """Growth fires only when compaction cannot free a slot: a small window
    with few concurrently-live vertices never grows."""
    dfa = compile_query("a*")
    eng = DenseRPQEngine(dfa, window=2.0, n_slots=4, batch_size=1)
    for t in range(1, 60):
        eng.insert(t, t + 1, "a", float(t))  # fresh vertices every tuple
    assert eng.n_slots == 4


def test_checkpoint_across_differing_n_slots():
    """Round trip across vertex capacities, both directions: a GROWN
    group's checkpoint restores into a small-capacity service (which grows
    on adopt), and a small checkpoint restores into a larger engine
    (padded)."""
    tuples = list(so_like(40, 120, seed=3))  # forces growth at n_slots=8
    half = len(tuples) // 2
    svc = PersistentQueryService(window=1000.0, slide=50.0)
    svc.register("q", "a2q . c2a*", engine="dense", n_slots=8)
    svc.ingest(Stream(tuples[:half]))
    grown = svc.queries["q"].n_slots
    assert grown > 8
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc.snapshot(ckpt_dir, step=half)
        tail = svc.ingest(Stream(tuples[half:]))

        # small-capacity restorer grows to the checkpoint size
        svc2 = PersistentQueryService(window=1000.0, slide=50.0)
        svc2.register("q", "a2q . c2a*", engine="dense", n_slots=8)
        assert svc2.restore(ckpt_dir) == half
        assert svc2.queries["q"].n_slots >= grown
        tail2 = svc2.ingest(Stream(tuples[half:]))
        assert tail2["q"] == tail["q"]
        assert svc2.results("q") == svc.results("q")

        # large-capacity restorer pads the smaller checkpoint
        svc3 = PersistentQueryService(window=1000.0, slide=50.0)
        svc3.register("q", "a2q . c2a*", engine="dense", n_slots=2 * grown)
        assert svc3.restore(ckpt_dir) == half
        tail3 = svc3.ingest(Stream(tuples[half:]))
        assert tail3["q"] == tail["q"]


# ---------------------------------------------------------------------------
# satellite regressions: RSPQ fallback on conflict
# ---------------------------------------------------------------------------

# (a . b)+ lacks the containment property: simple-path semantics can
# over-report once a conflict materializes (Definition 16)
CONFLICT_EXPR = "(a2q . c2a)+"


def _conflict_stream():
    # the lasso from test_batched_engine: x -a-> y -b-> u -a-> v -b-> y
    # re-reaches y in a different state — a Definition 16 conflict for
    # (a . b)+ simple semantics
    return [("+", "x", "y", "a2q", 1.0), ("+", "y", "u", "c2a", 2.0),
            ("+", "u", "v", "a2q", 3.0), ("+", "v", "y", "c2a", 4.0),
            ("+", "y", "w", "a2q", 5.0), ("+", "w", "x", "c2a", 6.0)]


def test_rspq_fallback_on_conflict():
    """A conflicted simple-path dense lane is routed to the reference RSPQ
    engine; the switch is surfaced in IngestReport.fallbacks and the query
    keeps serving (exactly) from the retained graph."""
    from repro.streaming.stream import SGT

    svc = PersistentQueryService(window=1000.0, slide=100.0)
    svc.register("conf", CONFLICT_EXPR, engine="dense",
                 path_semantics="simple", n_slots=16)
    svc.register("safe", "(a2q | c2a)*", engine="dense",
                 path_semantics="simple", n_slots=16)
    events = _conflict_stream()
    stream = Stream([SGT(ts, u, v, lab, op) for (op, u, v, lab, ts) in events])
    report = svc.ingest(stream)
    assert "conf" in report.fallbacks, "conflict did not trigger the fallback"
    assert "safe" not in report.fallbacks
    assert svc.stats["conf"].conflicted
    # the query now lives on the reference path; the dense group no longer
    # carries its lane
    assert "conf" not in svc._dense_specs
    group = svc.queries["safe"]
    assert all(s is None or s.name != "conf" for s in group.lane_specs)
    # exactness from the switch on: the fallback's window snapshot matches
    # a reference RSPQ fed the same full stream
    oracle = RSPQ(compile_query(CONFLICT_EXPR), 1000.0)
    for (op, u, v, lab, ts) in events:
        oracle.insert(u, v, lab, ts)
    assert svc._ref_engines["conf"].current_results() == oracle.current_results()
    # and it keeps serving the tail exactly
    more = Stream([SGT(13.0, 0, 1, "a2q", "+"), SGT(14.0, 1, 2, "c2a", "+")])
    svc.ingest(more)
    oracle.insert(0, 1, "a2q", 13.0)
    oracle.insert(1, 2, "c2a", 14.0)
    assert svc._ref_engines["conf"].current_results() == oracle.current_results()


def test_rspq_fallback_handles_deletions():
    """The fallback wrapper supports negative tuples (the paper's RSPQ has
    no Delete listing): rebuild from retained edges, exact vs an RSPQ fed
    only the surviving stream."""
    from repro.streaming.stream import SGT

    svc = PersistentQueryService(window=1000.0, slide=100.0)
    svc.register("conf", CONFLICT_EXPR, engine="dense",
                 path_semantics="simple", n_slots=16)
    events = _conflict_stream()
    report = svc.ingest(
        Stream([SGT(ts, u, v, lab, op) for (op, u, v, lab, ts) in events]))
    assert "conf" in report.fallbacks
    # delete one lasso edge: the fallback must re-derive
    svc.ingest(Stream([SGT(20.0, "x", "y", "a2q", "-")]))
    oracle = RSPQ(compile_query(CONFLICT_EXPR), 1000.0)
    live = {}
    for (op, u, v, lab, ts) in events:
        live[(u, v, lab)] = ts
    del live[("x", "y", "a2q")]
    for (u, v, lab), ts in sorted(live.items(), key=lambda kv: kv[1]):
        oracle.insert(u, v, lab, ts)
    oracle.expire(20.0)
    assert svc._ref_engines["conf"].current_results() == oracle.current_results()


def test_rspq_fallback_disabled_keeps_dense_lane():
    from repro.streaming.stream import SGT

    svc = PersistentQueryService(window=1000.0, slide=100.0,
                                 rspq_fallback=False)
    svc.register("conf", CONFLICT_EXPR, engine="dense",
                 path_semantics="simple", n_slots=16)
    events = _conflict_stream()
    report = svc.ingest(
        Stream([SGT(ts, u, v, lab, op) for (op, u, v, lab, ts) in events]))
    assert not report.fallbacks
    assert "conf" in svc._dense_specs       # still dense
    assert svc.stats["conf"].conflicted     # but flagged (PR 2 behavior)
