"""End-to-end behaviour tests for the paper's system: the persistent-query
service over streaming graphs (paper execution model, §2/§5), small-mesh
distributed equivalence, and empirical complexity scaling (Table 1)."""
import subprocess
import sys
import time

import pytest

from repro.core import compile_query
from repro.core.reference import RAPQ
from repro.streaming.generators import so_like, with_deletions, yago_like
from repro.streaming.service import PersistentQueryService


def test_service_mixed_workload_and_deletions():
    stream = with_deletions(so_like(28, 260, seed=11), ratio=0.05, seed=2)
    svc = PersistentQueryService(window=15.0, slide=3.0)
    svc.register("arb", "a2q . c2a*", engine="dense", n_slots=48)
    svc.register("arb_ref", "a2q . c2a*", engine="reference")
    svc.register("smp", "(a2q | c2a | c2q)*", engine="dense",
                 path_semantics="simple", n_slots=48)
    svc.ingest(stream)
    assert svc.results("arb") == svc.results("arb_ref")
    # containment-property query: dense simple == dense arbitrary minus diag
    assert all(a != b for (a, b) in svc.results("smp"))
    assert svc.stats["arb"].tuples == len(stream)


def test_monotone_result_stream():
    """Implicit windows: the emitted result stream never retracts (Def. 9)."""
    stream = so_like(24, 300, seed=5)
    svc = PersistentQueryService(window=10.0, slide=2.0)
    svc.register("q", "a2q . c2a*", engine="dense", n_slots=48)
    seen = set()
    for batch in stream.batches(25):
        from repro.streaming.stream import Stream

        new = svc.ingest(Stream(batch))["q"]
        assert not (new & seen)  # no duplicate emission
        seen |= new
    assert seen == svc.results("q")


@pytest.mark.slow
def test_complexity_scaling_insert_cost():
    """Table 1: amortized per-tuple cost of RAPQ is O(n * k^2) — verify the
    per-tuple cost grows sub-quadratically with window vertex count n."""
    dfa = compile_query("p0 . p1*")
    costs = {}
    for n in (32, 64, 128):
        stream = yago_like(n, 1200, n_labels=4, seed=7)
        eng = RAPQ(dfa, window=40.0)
        t0 = time.perf_counter()
        next_exp = 5.0
        for sgt in stream:
            if sgt.ts >= next_exp:
                eng.expire(sgt.ts)
                next_exp += 5.0
            eng.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
        costs[n] = (time.perf_counter() - t0) / len(stream)
    # 4x vertices should cost far less than 16x (quadratic) per tuple
    assert costs[128] < 16 * costs[32], costs


@pytest.mark.slow
def test_distributed_engine_subprocess():
    """8 fake devices: sharded dense engine == single-device results (the
    example as a test; subprocess so XLA_FLAGS applies before jax init)."""
    proc = subprocess.run(
        [sys.executable, "examples/distributed_rpq.py"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "sharded == single-device" in proc.stdout


@pytest.mark.slow
def test_query_churn_benchmark():
    """benchmarks/fig13_query_churn in the CI slow tier: queries register
    and deregister mid-stream; per-event result-stream identity against
    uninterrupted independents + fresh-group oracles is asserted inside."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig13_query_churn"],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[ok]" in proc.stdout


@pytest.mark.slow
def test_sharded_engine_benchmark():
    """benchmarks/fig14_sharded_engine in the CI slow tier: MeshExecutor on
    a host-local 8-device CPU mesh vs LocalExecutor — per-event result
    identity and a >0 masked-skip shard-round win are asserted inside (the
    subprocess carries XLA_FLAGS so the devices exist before jax init)."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig14_sharded_engine"],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[ok] masked-skip savings > 0" in proc.stdout


@pytest.mark.slow
def test_backend_shootout_benchmark():
    """benchmarks/fig15_backend_shootout in the CI slow tier: jnp vs
    pallas (fused batched kernel, interpret on CPU) vs mxu_bucket through
    BOTH executors on 8 virtual devices — per-event identity for the exact
    backends and the bucket level-coarsening bound are asserted inside."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig15_backend_shootout"],
        capture_output=True, text=True, timeout=2400,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[ok] backend shootout" in proc.stdout


@pytest.mark.slow
def test_frontier_benchmark():
    """benchmarks/fig16_frontier in the CI slow tier: frontier-restricted
    ingest vs the dense relaxation on the sparse generators — per-event
    result identity on both executors AND the >=2x aggregate edges/s
    acceptance bar at Q=8 are asserted inside (XLA_FLAGS gives the mesh
    half real lane shards)."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig16_frontier"],
        capture_output=True, text=True, timeout=2400,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[ok] frontier >= 2x dense" in proc.stdout


@pytest.mark.slow
def test_deletions_benchmark():
    """benchmarks/fig17_deletions in the CI slow tier: cone-restricted
    incremental deletions vs the dense from-scratch re-derivation —
    per-event invalidation-set identity on both executors x all three
    backends AND the >=2x per-delete-event throughput acceptance bar at
    Q=8 are asserted inside."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig17_deletions"],
        capture_output=True, text=True, timeout=2400,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[ok] deletions >= 2x dense" in proc.stdout


@pytest.mark.slow
def test_sparse_adjacency_benchmark():
    """benchmarks/fig18_sparse_adjacency in the CI slow tier: padded-ELL
    adjacency vs the dense (L, N, N) slab — per-event result identity
    (gmark window with deletions and expiry, frontier auto) AND the >=2x
    per-event ingest acceptance bar at the largest measured anchor and at
    the N=100k extrapolation (where the dense slab is infeasible by
    construction) are asserted inside."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig18_sparse_adjacency"],
        capture_output=True, text=True, timeout=2400,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[ok] fig18 >= 2x per-event ingest" in proc.stdout


@pytest.mark.slow
def test_sparse_dist_benchmark():
    """benchmarks/fig19_sparse_dist in the CI slow tier: row-sparse
    reachable-set dist vs the dense (Q, N, N, K) slab — per-event result
    identity (gmark window with deletions and expiry, frontier auto, a
    tiny dist_cap so the overflow/repack path fires) AND the >=2x
    per-event (seed + relax + emit) acceptance bar at the largest
    measured anchor and at the N=128k extrapolation (where the dense
    dist is infeasible by construction) are asserted inside."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig19_sparse_dist"],
        capture_output=True, text=True, timeout=2400,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[ok] fig19 >= 2x per-event throughput" in proc.stdout


@pytest.mark.slow
def test_survival_benchmark():
    """benchmarks/fig20_survival in the CI slow tier: the supervised
    service under seeded chaos plans (crashes before/after dispatch,
    mid-snapshot at every commit stage, during replay, stragglers,
    transient errors) on the sparse layout combination — per-batch
    result-stream identity against the uninterrupted run is asserted
    inside, and recovery time / replay eps are measured."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig20_survival"],
        capture_output=True, text=True, timeout=2400,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[ok] fig20 survival" in proc.stdout
    assert "identical=False" not in proc.stdout


@pytest.mark.slow
def test_dryrun_machinery_smoke():
    """Full dry-run protocol on one cell in a subprocess (512 host devices):
    lower + compile + memory/cost/collective scrape must all succeed."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "smollm-360m", "--shape", "decode_32k", "--mesh", "pod"],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[ok]" in proc.stdout
