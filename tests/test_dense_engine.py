"""Dense engine vs paper-faithful reference: result-set equivalence on
randomized streams (inserts, window expiry, explicit deletions)."""
import random

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import RAPQ, batch_rapq, compile_query, snapshot_from_edges, streaming_oracle
from repro.core.engine import DenseRPQEngine

QUERIES = ["a*", "a . b*", "(a | b)*", "a . b* . c", "(a . b)+", "a . b . c"]
LABELS = ["a", "b", "c"]


def _random_stream(rng, n_vertices, n_edges, t_max):
    ts = sorted(rng.sample(range(1, t_max), k=min(n_edges, t_max - 1)))
    return [
        (rng.randrange(n_vertices), rng.randrange(n_vertices), rng.choice(LABELS), float(t))
        for t in ts
    ]


@pytest.mark.parametrize("query", QUERIES)
@pytest.mark.parametrize("seed", [0, 1])
def test_dense_matches_reference_b1(query, seed):
    """Batch size 1: dense engine must match the reference tuple-for-tuple."""
    rng = random.Random(seed)
    dfa = compile_query(query)
    window = 20.0
    stream = _random_stream(rng, n_vertices=8, n_edges=30, t_max=90)
    ref = RAPQ(dfa, window)
    dense = DenseRPQEngine(dfa, window, n_slots=16, batch_size=1)
    for (u, v, lab, ts) in stream:
        r1 = ref.insert(u, v, lab, ts)
        r2 = dense.insert(u, v, lab, ts)
        assert r2 == r1, (query, seed, (u, v, lab, ts))
    assert dense.results == ref.results


@pytest.mark.parametrize("query", ["a . b*", "(a . b)+"])
def test_dense_snapshot_view_matches_batch(query):
    rng = random.Random(5)
    dfa = compile_query(query)
    window = 15.0
    stream = _random_stream(rng, n_vertices=8, n_edges=40, t_max=100)
    dense = DenseRPQEngine(dfa, window, n_slots=16, batch_size=1)
    for i, (u, v, lab, ts) in enumerate(stream):
        dense.insert(u, v, lab, ts)
        if i % 7 == 6:
            snap = snapshot_from_edges(stream[: i + 1], low=ts - window, high=ts)
            assert dense.current_results() == batch_rapq(snap, dfa)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), query=st.sampled_from(QUERIES))
def test_dense_property_random_with_expiry(seed, query):
    rng = random.Random(seed)
    dfa = compile_query(query)
    window = rng.choice([8.0, 15.0, 40.0])
    stream = _random_stream(rng, n_vertices=6, n_edges=25, t_max=60)
    dense = DenseRPQEngine(dfa, window, n_slots=12, batch_size=1)
    for i, (u, v, lab, ts) in enumerate(stream):
        dense.insert(u, v, lab, ts)
        if i % 6 == 5:
            dense.expire(ts)  # lazy expiration + slot recycling
    assert dense.results == streaming_oracle(stream, dfa, window)


@pytest.mark.parametrize("query", ["a . b*", "a*"])
def test_dense_batched_ingest_superset_safety(query):
    """B > 1: batch-boundary semantics — reported results must be a subset
    of the oracle (no spurious results) and must cover every pair that is
    valid at a batch boundary."""
    rng = random.Random(9)
    dfa = compile_query(query)
    window = 25.0
    stream = _random_stream(rng, n_vertices=8, n_edges=40, t_max=100)
    dense = DenseRPQEngine(dfa, window, n_slots=16, batch_size=8)
    dense.insert_batch(stream)
    oracle = streaming_oracle(stream, dfa, window)
    assert dense.results <= oracle
    # boundary coverage: final-snapshot validity is always caught
    last_ts = stream[-1][3]
    snap = snapshot_from_edges(stream, low=last_ts - window, high=last_ts)
    assert batch_rapq(snap, dfa) <= dense.results


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_dense_explicit_deletions(seed):
    rng = random.Random(seed)
    dfa = compile_query("a . b*")
    ref = RAPQ(dfa, window=10_000.0)
    dense = DenseRPQEngine(dfa, 10_000.0, n_slots=12, batch_size=1)
    live = {}
    t = 0.0
    for _ in range(25):
        t += 1.0
        if live and rng.random() < 0.3:
            key = rng.choice(sorted(live))
            u, v, lab = key
            del live[key]
            ref.delete(u, v, lab, t)
            dense.delete(u, v, lab, t)
        else:
            u, v = rng.randrange(5), rng.randrange(5)
            lab = rng.choice(LABELS)
            live[(u, v, lab)] = t
            ref.insert(u, v, lab, t)
            dense.insert(u, v, lab, t)
        assert dense.current_results() == ref.current_results()


def test_dense_slot_recycling():
    """Vertices cycle through a small slot budget across window slides."""
    dfa = compile_query("a*")
    dense = DenseRPQEngine(dfa, window=5.0, n_slots=8, batch_size=1)
    t = 0.0
    for wave in range(6):
        u, v = f"u{wave}", f"v{wave}"
        t += 10.0  # previous wave fully expired
        dense.expire(t)
        dense.insert(u, v, "a", t)
        assert (u, v) in dense.results
    # only the last wave's vertices occupy slots
    assert len(dense.slot_of) <= 4


def test_dense_simple_path_mode_conflict_flag():
    """(a.b)+ on the Fig.1-style cycle: simple mode must flag the conflict;
    a containment-property query must not."""
    dfa = compile_query("(a . b)+")
    eng = DenseRPQEngine(dfa, window=100.0, n_slots=8, batch_size=1,
                         path_semantics="simple")
    edges = [
        ("x", "y", "a", 1.0), ("y", "u", "b", 2.0),
        ("u", "v", "a", 3.0), ("v", "y", "b", 4.0),  # cycle through y
    ]
    for e in edges:
        eng.insert(*e)
    assert eng.conflicted

    dfa2 = compile_query("(a | b)*")
    assert dfa2.has_containment_property
    eng2 = DenseRPQEngine(dfa2, window=100.0, n_slots=8, batch_size=1,
                          path_semantics="simple")
    for e in edges:
        eng2.insert(*e)
    assert not eng2.conflicted


def test_dense_pallas_backend_matches_jnp():
    rng = random.Random(2)
    dfa = compile_query("a . b*")
    stream = _random_stream(rng, n_vertices=6, n_edges=20, t_max=50)
    e1 = DenseRPQEngine(dfa, 20.0, n_slots=8, batch_size=4, backend="jnp")
    e2 = DenseRPQEngine(dfa, 20.0, n_slots=8, batch_size=4, backend="pallas")
    e1.insert_batch(stream)
    e2.insert_batch(stream)
    assert e1.results == e2.results
    np.testing.assert_allclose(
        np.asarray(e1.arrays.dist), np.asarray(e2.arrays.dist)
    )
