"""Per-architecture smoke tests on REDUCED configs (CPU): one forward +
one train step, shape and finiteness assertions; prefill/decode equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models.transformer import Model

# the 398b reduced config still dominates the suite wall-clock (SSM+MoE
# hybrid); its cases run in the slow tier
_SLOW_ARCHS = {"jamba-1.5-large-398b"}
ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
    for a in ARCH_NAMES
]


def _batch_for(model, cfg, b=2, s=32, key=0):
    rng = np.random.RandomState(key)
    tok_len = s - (cfg.prefix_len if cfg.frontend != "none" else 0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, tok_len)))}
    if cfg.frontend != "none":
        batch["prefix_embeds"] = jnp.asarray(
            rng.randn(b, cfg.prefix_len, cfg.d_model).astype(np.float32)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(model, cfg)
    logits, aux = jax.jit(model.forward)(params, batch["tokens"],
                                         batch.get("prefix_embeds"))
    b = batch["tokens"].shape[0]
    s_total = 32
    assert logits.shape == (b, s_total, model.V)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert bool(jnp.isfinite(aux))


@pytest.mark.slow  # forward coverage stays in tier-1; grad+step per arch is slow-tier
@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_one_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch_for(model, cfg, key=1)

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        # plain SGD step (the full optimizer is exercised in test_optim)
        new = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
        return loss, new

    loss, new_params = step(params, batch)
    assert bool(jnp.isfinite(loss)), arch
    flat = jax.tree.leaves(new_params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in flat), arch
    loss2, _ = step(new_params, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
    for a in ["smollm-360m", "mamba2-370m", "jamba-1.5-large-398b", "dbrx-132b"]
])
def test_prefill_then_decode_matches_full_forward(arch):
    """Teacher-forcing equivalence: logits from (prefill + decode steps) must
    match the full causal forward at the same positions."""
    cfg = get_config(arch).reduced()
    model = Model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(2))
    b, s = 2, 24
    rng = np.random.RandomState(2)
    tok_len = s - (cfg.prefix_len if cfg.frontend != "none" else 0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, tok_len)))
    prefix = None
    if cfg.frontend != "none":
        prefix = jnp.asarray(rng.randn(b, cfg.prefix_len, cfg.d_model).astype(np.float32))

    full_logits, _ = jax.jit(model.forward)(params, tokens, prefix)

    n_decode = 6
    prefill_len = s - n_decode
    pre_tokens = tokens[:, : prefill_len - (cfg.prefix_len if cfg.frontend != "none" else 0)] \
        if cfg.frontend != "none" else tokens[:, :prefill_len]
    logits, caches = jax.jit(lambda p, t, pe: model.prefill(p, t, pe, max_len=s))(
        params, pre_tokens, prefix)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, prefill_len - 1]),
        rtol=2e-2, atol=2e-2,
    )
    decode = jax.jit(model.decode_step)
    for i in range(n_decode):
        pos = prefill_len + i
        tok = tokens[:, pos - (cfg.prefix_len if cfg.frontend != "none" else 0)][:, None]
        logits, caches = decode(params, tok, caches)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, pos]),
            rtol=2e-2, atol=2e-2, err_msg=f"{arch} decode step {i}",
        )


def test_moe_capacity_conservation():
    """Router dispatch invariants: gates nonnegative, combine preserves scale."""
    from repro.models.moe import apply_moe, init_moe
    key = jax.random.PRNGKey(0)
    p = init_moe(key, 32, 64, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = apply_moe(p, x, top_k=2, capacity_factor=8.0)  # ample capacity
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0.0
    # with capacity ~0 every token drops -> output exactly zero
    y0, _ = apply_moe(p, x, top_k=2, capacity_factor=1e-9)
    # capacity floor is 1, so only a handful of tokens survive
    assert float(jnp.abs(y0).mean()) < float(jnp.abs(y).mean())


def test_padded_heads_are_inert():
    """tp-padded head slots must not change the model function."""
    cfg = get_config("qwen1.5-4b").reduced()  # 4 heads reduced
    Model(cfg, tp=1)        # the unpadded twin must still construct
    m8 = Model(cfg, tp=8)   # pads 4 -> 8 heads
    p8 = m8.init(jax.random.PRNGKey(3))
    batch = _batch_for(m8, cfg, key=3)
    logits8, _ = jax.jit(m8.forward)(p8, batch["tokens"])
    assert m8.H == 8 and m8.KV >= cfg.n_kv_heads
    assert bool(jnp.all(jnp.isfinite(logits8)))
    # zero-padded slots: wq columns beyond logical heads are zero at init
    wq = p8["layers"][0]["attn"]["wq"][0]  # [0]: first layer of the stack
    live = cfg.n_heads * cfg.head_dim
    assert float(jnp.abs(wq[:, live:]).max()) == 0.0
