"""Supervision layer: WAL durability, crash-recovery identity under
injected faults, backpressure policies, and circuit-breaker degradation.

The load-bearing contract (ISSUE 10 acceptance): for EVERY injected fault
point — crash before dispatch, after dispatch, mid-snapshot (each stage of
the commit protocol), and during replay — restore + WAL-suffix replay
reproduces the exact per-batch result stream of an uninterrupted run, on
both executors and on a sparse layout combination. The supervisor itself
re-proves replayed batches inline (``verify_replay=True`` raises
:class:`ReplayDivergence` on any mismatch), and these tests additionally
compare the full chaos-run stream against a separately computed clean run.
"""
import os
import tempfile

import pytest

from repro.checkpoint import ckpt
from repro.streaming.generators import so_like, with_deletions
from repro.streaming.service import PersistentQueryService
from repro.streaming.stream import SGT, Stream
from repro.streaming.supervisor import (DENSE_FALLBACK_OVERRIDES,
                                        BoundedIngestQueue, CircuitBreaker,
                                        FaultPlan, ServiceSupervisor)
from repro.streaming.wal import WriteAheadLog

WINDOW, SLIDE = 20.0, 2.0


def _make_service(**overrides):
    kw = dict(window=WINDOW, slide=SLIDE)
    kw.update(overrides)
    svc = PersistentQueryService(**kw)
    svc.register("d_arb", "a2q . c2a*", engine="dense", n_slots=48)
    svc.register("d_plus", "(a2q | c2a)+", engine="dense", n_slots=48)
    svc.register("r_arb", "a2q . c2a*", engine="reference")
    return svc


def _stream_tuples():
    return list(with_deletions(so_like(24, 110, seed=13), ratio=0.04, seed=7))


def _clean_run(tuples, make_service, **sup_kwargs):
    with tempfile.TemporaryDirectory() as d:
        sup = ServiceSupervisor(make_service, d, **sup_kwargs)
        final = sup.run(list(tuples))
        return final, sup.result_stream(), sup.invalidation_stream()


# -- WAL ----------------------------------------------------------------------


def _mixed_batch(ts0):
    # vertex ids across types: int, str, tuple — the interner's encoding
    # must round-trip all of them
    return [SGT(ts0, 1, 2, "a2q"),
            SGT(ts0 + 0.1, "s1", ("p", 3), "c2a"),
            SGT(ts0 + 0.2, ("m", 4), 7, "c2q", "-")]


def test_wal_round_trip_typed_vertices():
    with tempfile.TemporaryDirectory() as d:
        wal = WriteAheadLog(d)
        b1, b2 = _mixed_batch(1.0), _mixed_batch(2.0)
        assert wal.append(b1) == 1
        assert wal.append(b2) == 2
        recs = list(wal.replay())
        assert [r.lsn for r in recs] == [1, 2]
        assert list(recs[0].events) == b1
        assert list(recs[1].events) == b2
        assert recs[0].clock == pytest.approx(1.2)
        wal.close()
        # a fresh instance over the same directory resumes sequencing
        wal2 = WriteAheadLog(d)
        assert wal2.last_lsn == 2
        assert wal2.append(_mixed_batch(3.0)) == 3
        assert [r.lsn for r in wal2.replay(after_lsn=1)] == [2, 3]


def test_wal_refuses_empty_batch():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError):
            WriteAheadLog(d).append([])


def test_wal_torn_tail_is_skipped_and_truncated():
    with tempfile.TemporaryDirectory() as d:
        wal = WriteAheadLog(d)
        for i in range(3):
            wal.append(_mixed_batch(float(i)))
        wal.close()
        seg = os.path.join(d, wal._segments()[-1])
        size = os.path.getsize(seg)
        with open(seg, "r+b") as f:     # tear the last record mid-write
            f.truncate(size - 7)
        wal2 = WriteAheadLog(d)
        assert wal2.torn_records == 1
        assert wal2.last_lsn == 2       # the torn record never happened
        # recovery appends continue the sequence and replay reaches them
        # (the torn bytes were truncated away on reopen)
        assert wal2.append(_mixed_batch(9.0)) == 3
        assert [r.lsn for r in wal2.replay()] == [1, 2, 3]
        assert list(list(wal2.replay())[-1].events) == _mixed_batch(9.0)


def test_wal_crc_rejects_corruption():
    with tempfile.TemporaryDirectory() as d:
        wal = WriteAheadLog(d)
        wal.append(_mixed_batch(1.0))
        wal.append(_mixed_batch(2.0))
        wal.close()
        seg = os.path.join(d, wal._segments()[0])
        blob = open(seg, "rb").read()
        # flip one payload byte of the FIRST record: replay must stop
        # there (order after a bad record cannot be trusted), not skip it
        corrupted = blob[:20] + bytes([blob[20] ^ 0xFF]) + blob[21:]
        open(seg, "wb").write(corrupted)
        wal2 = WriteAheadLog(d)
        assert list(wal2.replay()) == []
        assert wal2.torn_records >= 1


def test_wal_rotation_and_truncate_upto():
    with tempfile.TemporaryDirectory() as d:
        wal = WriteAheadLog(d, segment_records=4)
        for i in range(10):
            wal.append(_mixed_batch(float(i)))
        assert len(wal._segments()) == 3
        # lsn 8 commits everything in the first two segments (1-4, 5-8)
        assert wal.truncate_upto(8) == 2
        assert [r.lsn for r in wal.replay()] == [9, 10]
        # covered-but-active segment is never unlinked
        assert wal.truncate_upto(10) == 0
        assert [r.lsn for r in wal.replay(after_lsn=9)] == [10]


def test_wal_churn_records_ride_the_sequence():
    with tempfile.TemporaryDirectory() as d:
        wal = WriteAheadLog(d)
        wal.append(_mixed_batch(1.0))
        wal.append_churn("register", "q_new",
                         {"expr": "a2q+", "kwargs": {"engine": "dense"}})
        wal.append(_mixed_batch(2.0))
        wal.append_churn("deregister", "q_new")
        kinds = [(r.lsn, r.kind) for r in wal.replay()]
        assert kinds == [(1, "batch"), (2, "register"),
                         (3, "batch"), (4, "deregister")]
        reg = list(wal.replay())[1]
        assert reg.meta["name"] == "q_new"
        assert reg.meta["expr"] == "a2q+"
        assert reg.meta["kwargs"] == {"engine": "dense"}
        with pytest.raises(ValueError):
            wal.append_churn("rename", "q_new")


# -- fault plan / queue / breaker ---------------------------------------------


def test_fault_plan_fires_exactly_once():
    plan = FaultPlan(crash_before_dispatch=[3], crash_mid_snapshot={1: "rename"},
                     slow_dispatch={2: 0.5}, transient_errors={4: 2})
    assert plan.take_crash("before_dispatch", 3)
    assert not plan.take_crash("before_dispatch", 3)   # retried lsn proceeds
    assert plan.take_snapshot_crash(1) == "rename"
    assert plan.take_snapshot_crash(1) is None
    assert plan.take_sleep(2) == 0.5
    assert plan.take_sleep(2) == 0.0
    assert plan.take_transient(4) and plan.take_transient(4)
    assert not plan.take_transient(4)                  # bounded
    assert plan.exhausted


def test_fault_plan_chaos_is_deterministic():
    a = FaultPlan.chaos(seed=11, n_batches=200, snapshot_crash_every=5)
    b = FaultPlan.chaos(seed=11, n_batches=200, snapshot_crash_every=5)
    assert a.__dict__ == b.__dict__
    c = FaultPlan.chaos(seed=12, n_batches=200)
    assert a.__dict__ != c.__dict__
    with pytest.raises(ValueError):
        FaultPlan(crash_mid_snapshot={1: "nonsense"})


def test_bounded_queue_policies():
    evt = [SGT(float(i), i, i + 1, "a2q") for i in range(8)]
    q = BoundedIngestQueue(cap=3, policy="block")
    assert all(q.push(e) for e in evt[:3])
    assert not q.push(evt[3])          # full: the producer must stall
    assert q.blocked == 1 and q.shed == 0
    q.take(1)
    assert q.push(evt[3])

    q = BoundedIngestQueue(cap=3, policy="shed-oldest")
    for e in evt[:5]:
        assert q.push(e)               # never refuses — drops the oldest
    assert q.shed == 2
    assert [s.src for s in q.take(3)] == [2, 3, 4]

    q = BoundedIngestQueue(cap=3, policy="shed-newest")
    for e in evt[:5]:
        assert q.push(e)
    assert q.shed == 2
    assert [s.src for s in q.take(3)] == [0, 1, 2]

    with pytest.raises(ValueError):
        BoundedIngestQueue(cap=0)
    with pytest.raises(ValueError):
        BoundedIngestQueue(cap=1, policy="random-early-drop")


def test_circuit_breaker_trip_and_rearm():
    br = CircuitBreaker(trip_threshold=0.25, rearm_after=2)
    assert br.observe(1, 10) is None          # 0.1 <= threshold: armed
    assert br.observe(5, 10) == "trip"        # 0.5 > threshold
    assert br.tripped
    assert br.observe(0, 10) is None          # quiet 1/2
    assert br.observe(3, 10) is None          # noisy: quiet run resets
    assert br.observe(0, 10) is None          # quiet 1/2
    assert br.observe(0, 10) == "rearm"       # quiet 2/2
    assert not br.tripped
    assert [a for _i, a, _r in br.log] == ["trip", "rearm"]


# -- crash-recovery identity (the acceptance criterion) -----------------------

CONFIGS = {
    "local-dense": {},
    "local-sparse": dict(frontier="on", frontier_cap=16, adj_layout="ell",
                         ell_cap=6, dist_layout="row_sparse", dist_cap=24),
    "mesh-dense": dict(executor="mesh"),
    "mesh-sparse": dict(executor="mesh", frontier="auto", frontier_cap=16,
                        adj_layout="ell", ell_cap=6,
                        dist_layout="row_sparse", dist_cap=24),
}

#: every fault point the issue names, in one plan: crash before dispatch,
#: crash after dispatch (results already recorded), crash mid-snapshot at
#: each stage of the commit protocol, crash DURING the recovery replay,
#: a straggler, and a transient error with retry
ALL_FAULT_POINTS = dict(
    crash_before_dispatch=[3], crash_after_dispatch=[7],
    crash_during_replay=[9],
    crash_mid_snapshot={1: "shards", 2: "manifest", 3: "rename"},
    slow_dispatch={5: 0.001}, transient_errors={6: 2})


@pytest.mark.parametrize("cfg_key", sorted(CONFIGS))
def test_crash_recovery_identity_all_fault_points(cfg_key):
    overrides = CONFIGS[cfg_key]

    def make(**extra):
        kw = dict(overrides)
        kw.update(extra)
        return _make_service(**kw)

    tuples = _stream_tuples()
    clean_final, clean_stream, clean_inval = _clean_run(
        tuples, make, batch_events=8, ckpt_every=4)

    with tempfile.TemporaryDirectory() as d:
        plan = FaultPlan(**ALL_FAULT_POINTS)
        sup = ServiceSupervisor(make, d, batch_events=8, ckpt_every=4,
                                fault_plan=plan, verify_replay=True)
        chaos_final = sup.run(list(tuples))
        assert plan.exhausted, "every scheduled fault must have fired"
        assert sup.restarts >= 4           # 2 dispatch + 3 snapshot crashes
        assert sup.recoveries, "at least one measured recovery"
        assert sup.retries >= 2            # the transient error retried
        # bit-identical per-batch result AND invalidation streams
        assert sup.result_stream() == clean_stream
        assert sup.invalidation_stream() == clean_inval
        assert chaos_final == clean_final
        for r in sup.recoveries:
            assert r.recovery_s >= 0.0
            assert r.replayed_events >= 0


def test_seeded_chaos_matrix_identity():
    """The CI chaos leg's shape: seeded random plans over the dense local
    config; every seed must preserve stream identity."""
    tuples = _stream_tuples()
    clean_final, clean_stream, _ = _clean_run(
        tuples, _make_service, batch_events=8, ckpt_every=4)
    for seed in (0, 1):
        with tempfile.TemporaryDirectory() as d:
            plan = FaultPlan.chaos(seed=seed, n_batches=14, crash_rate=0.2,
                                   transient_rate=0.2, straggler_s=0.0005)
            sup = ServiceSupervisor(_make_service, d, batch_events=8,
                                    ckpt_every=4, fault_plan=plan)
            assert sup.run(list(tuples)) == clean_final, seed
            assert sup.result_stream() == clean_stream, seed


def test_recovery_with_query_churn_in_wal():
    """Mid-stream register/deregister ride the WAL; a crash AFTER churn
    must reconstruct the churned query set (catalog from the checkpoint,
    suffix from the WAL) and keep the result stream identical."""
    tuples = _stream_tuples()

    def drive(sup):
        sup.run(list(tuples[:40]))
        sup.register("late", "c2a . a2q*", engine="dense", n_slots=48)
        sup.run(list(tuples[40:80]))
        sup.deregister("d_plus")
        sup.run(list(tuples[80:]))
        return sup.results()

    with tempfile.TemporaryDirectory() as d:
        clean = drive(ServiceSupervisor(_make_service, d, batch_events=8,
                                        ckpt_every=4))
    with tempfile.TemporaryDirectory() as d:
        # lsn 6 / 12 are the churn records themselves; 7 and 13 are the
        # first batches dispatched AFTER each churn op
        plan = FaultPlan(crash_before_dispatch=[7, 13],
                         crash_mid_snapshot={2: "rename"})
        sup = ServiceSupervisor(_make_service, d, batch_events=8,
                                ckpt_every=4, fault_plan=plan)
        chaos = drive(sup)
        assert plan.exhausted
        assert sup.restarts >= 3
    assert set(chaos) == set(clean)
    assert "late" in chaos and "d_plus" not in chaos
    for name in clean:
        assert chaos[name] == clean[name], name


def test_supervisor_gives_up_after_max_restarts():
    tuples = _stream_tuples()[:40]
    with tempfile.TemporaryDirectory() as d:
        # crash on the same lsn more times than the restart budget: each
        # recovery replays lsn 2 fine (fire-once) but the NEXT batch at
        # lsn 3, 4, ... keeps crashing
        plan = FaultPlan(crash_before_dispatch=[2, 3, 4, 5])
        sup = ServiceSupervisor(_make_service, d, batch_events=8,
                                ckpt_every=4, fault_plan=plan,
                                max_restarts=2)
        with pytest.raises(RuntimeError, match="restarts"):
            sup.run(list(tuples))


# -- backpressure -------------------------------------------------------------


def test_backpressure_block_policy_loses_nothing():
    tuples = _stream_tuples()
    clean_final, clean_stream, _ = _clean_run(
        tuples, _make_service, batch_events=8, ckpt_every=4)
    with tempfile.TemporaryDirectory() as d:
        sup = ServiceSupervisor(_make_service, d, batch_events=8,
                                ckpt_every=4, queue_cap=4,
                                queue_policy="block")
        # offer arrivals far faster than the per-tick drain capacity
        final = sup.run(list(tuples), arrival_chunk=64)
        assert sup.queue.blocked > 0       # the producer actually stalled
        assert sup.queue.shed == 0
        assert sup.queue.accepted == len(tuples)
        assert final == clean_final
        # grouping differs under pressure only if cap < batch; cap=4 <
        # batch_events=8 means batches of 4 — results stay identical as
        # SETS even though batch boundaries moved
        assert sup.wal.last_lsn >= len(clean_stream)


def test_backpressure_shed_policy_drops_explicitly():
    tuples = _stream_tuples()
    with tempfile.TemporaryDirectory() as d:
        sup = ServiceSupervisor(_make_service, d, batch_events=8,
                                ckpt_every=4, queue_cap=8,
                                queue_policy="shed-oldest", drain_batches=1)
        sup.run(list(tuples), arrival_chunk=len(tuples))  # one giant wave
        assert sup.queue.shed > 0
        assert sup.queue.high_water == 8
        # shed events never reached the WAL: the log holds exactly the
        # accepted-and-drained events, so replay stays self-consistent
        logged = sum(len(r.events) for r in sup.wal.replay())
        processed = sum(
            len(r.events)
            for lsn in sup.results_by_lsn
            for r in sup.wal.replay(after_lsn=lsn - 1) if r.lsn == lsn)
        assert processed <= logged


# -- circuit breaker / graceful degradation -----------------------------------


def _overflowy_service(**overrides):
    # capacities small enough that so_like's cyclic core overflows the
    # frontier AND the ELL rows AND the row-sparse dist rows constantly
    kw = dict(window=WINDOW, slide=SLIDE, frontier="on", frontier_cap=2,
              adj_layout="ell", ell_cap=2, dist_layout="row_sparse",
              dist_cap=4)
    kw.update(overrides)
    svc = PersistentQueryService(**kw)
    svc.register("d_arb", "a2q . c2a*", engine="dense", n_slots=48)
    svc.register("d_plus", "(a2q | c2a)+", engine="dense", n_slots=48)
    return svc


def test_breaker_trips_to_dense_and_preserves_results():
    tuples = _stream_tuples()
    clean_final, _, _ = _clean_run(tuples, _overflowy_service,
                                   batch_events=8, ckpt_every=4)
    with tempfile.TemporaryDirectory() as d:
        sup = ServiceSupervisor(
            _overflowy_service, d, batch_events=8, ckpt_every=4,
            health_every=2,
            breaker=CircuitBreaker(trip_threshold=0.5, rearm_after=10_000))
        final = sup.run(list(tuples))
        assert sup.breaker.tripped
        assert [a for _i, a, _r in sup.breaker.log] == ["trip"]
        # the live service is pinned to the dense fallbacks...
        assert sup._overrides == DENSE_FALLBACK_OVERRIDES
        ex = sup.service._group.executor
        assert ex.adjacency_stats["layout"] == "dense"
        assert ex.dist_stats["layout"] == "dense"
        assert sup.service._frontier == "off"
        # ...and the handover was loss-free (layouts are bit-identical)
        assert final == clean_final
        assert any(h.get("degraded") for h in sup.health_log)


def test_breaker_rearms_after_quiet_period():
    tuples = _stream_tuples()
    clean_final, _, _ = _clean_run(tuples, _overflowy_service,
                                   batch_events=8, ckpt_every=4)
    with tempfile.TemporaryDirectory() as d:
        sup = ServiceSupervisor(
            _overflowy_service, d, batch_events=8, ckpt_every=4,
            health_every=2,
            breaker=CircuitBreaker(trip_threshold=0.5, rearm_after=1))
        final = sup.run(list(tuples))
        actions = [a for _i, a, _r in sup.breaker.log]
        assert actions[0] == "trip"
        assert "rearm" in actions          # dense intervals are quiet
        assert final == clean_final        # flapping never loses results
        marks = [h["breaker"] for h in sup.health_log]
        assert "tripped" in marks and "armed" in marks


# -- run_with_restarts port (satellite) ---------------------------------------


def test_run_service_with_restarts_port():
    from repro.distributed.fault import (StragglerMonitor,
                                         run_service_with_restarts)

    tuples = _stream_tuples()
    clean_final, _, _ = _clean_run(tuples, _make_service,
                                   batch_events=8, ckpt_every=4)
    slow_lsns = []
    with tempfile.TemporaryDirectory() as d:
        plan = FaultPlan(crash_before_dispatch=[4],
                         slow_dispatch={9: 0.05, 11: 0.05})
        results, report = run_service_with_restarts(
            _make_service, list(tuples), d,
            batch_events=8, ckpt_every=4, fault_plan=plan,
            on_straggler=slow_lsns.append,
            monitor=StragglerMonitor(deadline_factor=3.0, warmup=5))
        assert results == clean_final
        assert report["restarts"] == 1
        assert report["final_step"] == 14
        assert report["recoveries"] and report["recoveries"][0]["replay_eps"] > 0
        # straggler detection feeds both the callback and health telemetry
        assert report["stragglers"] == slow_lsns
        assert sum(h["stragglers"] for h in report["health_log"]) \
            >= len(slow_lsns) - 1  # tail interval may not have flushed
