"""Fault tolerance of the persistent-query service: crash after a
mid-stream checkpoint, re-attach in a fresh service, and the re-attached
run must produce an IDENTICAL result stream to the uninterrupted one —
for the batched dense group AND the paper-faithful reference engines,
with explicit deletions in the stream.
"""
import tempfile

import pytest

from repro.streaming.generators import so_like, with_deletions
from repro.streaming.service import PersistentQueryService
from repro.streaming.stream import Stream

WINDOW, SLIDE = 20.0, 2.0


def _make_service():
    svc = PersistentQueryService(window=WINDOW, slide=SLIDE)
    svc.register("d_arb", "a2q . c2a*", engine="dense", n_slots=48)
    svc.register("d_plus", "(a2q | c2a)+", engine="dense", n_slots=48)
    svc.register("d_smp", "(a2q | c2a | c2q)*", engine="dense",
                 path_semantics="simple", n_slots=48)
    svc.register("r_arb", "a2q . c2a*", engine="reference")
    # (no reference RSPQ here: the paper's RSPQ listing has no Delete
    # algorithm, so it cannot ride a deletion stream)
    return svc


QUERY_NAMES = ["d_arb", "d_plus", "d_smp", "r_arb"]


def _stream_tuples():
    return list(with_deletions(so_like(24, 110, seed=13), ratio=0.04, seed=7))


def test_crash_restore_identical_result_stream():
    tuples = _stream_tuples()
    half = len(tuples) // 2

    # uninterrupted run: record the post-checkpoint NEW results per query
    svc = _make_service()
    svc.ingest(Stream(tuples[:half]))
    svc_next_expiry_at_ckpt = svc._next_expiry
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc.snapshot(ckpt_dir, step=half)
        mid_results = {name: svc.results(name) for name in QUERY_NAMES}
        tail_new = svc.ingest(Stream(tuples[half:]))
        final_results = {name: svc.results(name) for name in QUERY_NAMES}

        # crash: a brand-new service re-attaches and replays the tail
        svc2 = _make_service()
        step = svc2.restore(ckpt_dir)
        assert step == half
        # restored state matches the checkpoint moment exactly
        for name in QUERY_NAMES:
            assert svc2.results(name) == mid_results[name], name
        assert svc2._next_expiry == svc_next_expiry_at_ckpt
        tail_new2 = svc2.ingest(Stream(tuples[half:]))
        for name in QUERY_NAMES:
            # identical appended result stream (no loss, no duplicates) ...
            assert tail_new2[name] == tail_new[name], name
            # ... and identical final monotone sets
            assert svc2.results(name) == final_results[name], name
            assert svc2.stats[name].conflicted == svc.stats[name].conflicted


def test_restore_rejects_mismatched_query_set():
    tuples = _stream_tuples()[:40]
    svc = _make_service()
    svc.ingest(Stream(tuples))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc.snapshot(ckpt_dir, step=1)
        svc2 = PersistentQueryService(window=WINDOW, slide=SLIDE)
        svc2.register("other", "a2q*", engine="dense", n_slots=48)
        with pytest.raises((ValueError, KeyError)):
            svc2.restore(ckpt_dir)


def test_register_after_ingest_is_live():
    """PR 2: late dense registrations re-pad the live group in place (no
    raise, no silent rebuild) — the new query immediately answers over the
    retained window, and the pre-existing queries keep their state."""
    svc = _make_service()
    svc.ingest(Stream(_stream_tuples()[:20]))
    before = {name: svc.results(name) for name in QUERY_NAMES}
    initial = svc.register("late", "a2q*", engine="dense")
    group = svc.queries["late"]
    lane = group.lane_of("late")
    # the initial result set IS the live-window snapshot for the new query
    assert initial == group.current_results(lane)
    assert svc.results("late") == initial
    # pre-existing queries are untouched by the arrival
    for name in QUERY_NAMES:
        assert svc.results(name) == before[name], name


def test_checkpoint_restore_with_churned_group():
    """Snapshot a group that grew by a LIVE registration (bucketed-Q
    padding), restore into a fresh service that registered the same final
    query set up-front (different lane layout): restore matches lanes by
    name and the tail result streams are identical."""
    tuples = _stream_tuples()
    half = len(tuples) // 2
    svc = _make_service()
    svc.ingest(Stream(tuples[:half]))
    svc.register("late", "a2q . c2q*", engine="dense")
    names = QUERY_NAMES + ["late"]
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc.snapshot(ckpt_dir, step=half)
        tail_new = svc.ingest(Stream(tuples[half:]))
        final = {name: svc.results(name) for name in names}

        svc2 = _make_service()
        svc2.register("late", "a2q . c2q*", engine="dense", n_slots=48)
        assert svc2.restore(ckpt_dir) == half
        tail_new2 = svc2.ingest(Stream(tuples[half:]))
        for name in names:
            assert tail_new2[name] == tail_new[name], name
            assert svc2.results(name) == final[name], name
