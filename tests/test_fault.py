"""Fault tolerance of the persistent-query service: crash after a
mid-stream checkpoint, re-attach in a fresh service, and the re-attached
run must produce an IDENTICAL result stream to the uninterrupted one —
for the batched dense group AND the paper-faithful reference engines,
with explicit deletions in the stream.
"""
import os
import tempfile

import pytest

from repro.checkpoint import ckpt
from repro.streaming.generators import so_like, with_deletions
from repro.streaming.service import PersistentQueryService
from repro.streaming.stream import Stream

WINDOW, SLIDE = 20.0, 2.0


def _make_service(**kwargs):
    svc = PersistentQueryService(window=WINDOW, slide=SLIDE, **kwargs)
    svc.register("d_arb", "a2q . c2a*", engine="dense", n_slots=48)
    svc.register("d_plus", "(a2q | c2a)+", engine="dense", n_slots=48)
    svc.register("d_smp", "(a2q | c2a | c2q)*", engine="dense",
                 path_semantics="simple", n_slots=48)
    svc.register("r_arb", "a2q . c2a*", engine="reference")
    # (no reference RSPQ here: the paper's RSPQ listing has no Delete
    # algorithm, so it cannot ride a deletion stream)
    return svc


QUERY_NAMES = ["d_arb", "d_plus", "d_smp", "r_arb"]


def _stream_tuples():
    return list(with_deletions(so_like(24, 110, seed=13), ratio=0.04, seed=7))


def test_crash_restore_identical_result_stream():
    tuples = _stream_tuples()
    half = len(tuples) // 2

    # uninterrupted run: record the post-checkpoint NEW results per query
    svc = _make_service()
    svc.ingest(Stream(tuples[:half]))
    svc_next_expiry_at_ckpt = svc._next_expiry
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc.snapshot(ckpt_dir, step=half)
        mid_results = {name: svc.results(name) for name in QUERY_NAMES}
        tail_new = svc.ingest(Stream(tuples[half:]))
        final_results = {name: svc.results(name) for name in QUERY_NAMES}

        # crash: a brand-new service re-attaches and replays the tail
        svc2 = _make_service()
        step = svc2.restore(ckpt_dir)
        assert step == half
        # restored state matches the checkpoint moment exactly
        for name in QUERY_NAMES:
            assert svc2.results(name) == mid_results[name], name
        assert svc2._next_expiry == svc_next_expiry_at_ckpt
        tail_new2 = svc2.ingest(Stream(tuples[half:]))
        for name in QUERY_NAMES:
            # identical appended result stream (no loss, no duplicates) ...
            assert tail_new2[name] == tail_new[name], name
            # ... and identical final monotone sets
            assert svc2.results(name) == final_results[name], name
            assert svc2.stats[name].conflicted == svc.stats[name].conflicted


def test_restore_rejects_mismatched_query_set():
    tuples = _stream_tuples()[:40]
    svc = _make_service()
    svc.ingest(Stream(tuples))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc.snapshot(ckpt_dir, step=1)
        svc2 = PersistentQueryService(window=WINDOW, slide=SLIDE)
        svc2.register("other", "a2q*", engine="dense", n_slots=48)
        with pytest.raises((ValueError, KeyError)):
            svc2.restore(ckpt_dir)


def test_register_after_ingest_is_live():
    """PR 2: late dense registrations re-pad the live group in place (no
    raise, no silent rebuild) — the new query immediately answers over the
    retained window, and the pre-existing queries keep their state."""
    svc = _make_service()
    svc.ingest(Stream(_stream_tuples()[:20]))
    before = {name: svc.results(name) for name in QUERY_NAMES}
    initial = svc.register("late", "a2q*", engine="dense")
    group = svc.queries["late"]
    lane = group.lane_of("late")
    # the initial result set IS the live-window snapshot for the new query
    assert initial == group.current_results(lane)
    assert svc.results("late") == initial
    # pre-existing queries are untouched by the arrival
    for name in QUERY_NAMES:
        assert svc.results(name) == before[name], name


def test_checkpoint_restore_with_churned_group():
    """Snapshot a group that grew by a LIVE registration (bucketed-Q
    padding), restore into a fresh service that registered the same final
    query set up-front (different lane layout): restore matches lanes by
    name and the tail result streams are identical."""
    tuples = _stream_tuples()
    half = len(tuples) // 2
    svc = _make_service()
    svc.ingest(Stream(tuples[:half]))
    svc.register("late", "a2q . c2q*", engine="dense")
    names = QUERY_NAMES + ["late"]
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc.snapshot(ckpt_dir, step=half)
        tail_new = svc.ingest(Stream(tuples[half:]))
        final = {name: svc.results(name) for name in names}

        svc2 = _make_service()
        svc2.register("late", "a2q . c2q*", engine="dense", n_slots=48)
        assert svc2.restore(ckpt_dir) == half
        tail_new2 = svc2.ingest(Stream(tuples[half:]))
        for name in names:
            assert tail_new2[name] == tail_new[name], name
            assert svc2.results(name) == final[name], name


# -- crash-mid-save hardening (ISSUE 10 satellite) ----------------------------


def test_crash_between_async_save_and_wait_pending_falls_back():
    """Kill the saver between `ckpt.async_save` and `wait_pending` at each
    stage of the commit protocol: `latest_step_dir` must NEVER surface a
    partial checkpoint. Publication is the LATEST swing — "shards" and
    "manifest" kills leave partial tmp dirs, and a "rename" kill leaves a
    complete-but-unpublished step dir; in every case restore falls back
    to the previously PUBLISHED step."""
    tuples = _stream_tuples()
    svc = _make_service()
    svc.ingest(Stream(tuples[:40]))
    with tempfile.TemporaryDirectory() as d:
        svc.snapshot(d, step=1)
        committed = ckpt.latest_step_dir(d)
        assert committed is not None and committed.endswith("step_000000001")
        mid_results = {name: svc.results(name) for name in QUERY_NAMES}
        tail_new = svc.ingest(Stream(tuples[40:]))

        for step, stage in ((2, "shards"), (3, "manifest")):
            svc.snapshot(d, step=step, async_save=True, _crash_after=stage)
            ckpt.wait_pending(d)  # deterministic stand-in for the kill
            # partial on-disk state exists (the crash left a tmp dir) ...
            assert any(".tmp" in n for n in os.listdir(d)), stage
            # ... but the read path never sees it
            assert ckpt.latest_step_dir(d) == committed, stage

        # restore lands on the previous committed step and the replayed
        # tail reproduces the uninterrupted result stream exactly
        svc2 = _make_service()
        assert svc2.restore(d) == 1
        for name in QUERY_NAMES:
            assert svc2.results(name) == mid_results[name], name
        tail_new2 = svc2.ingest(Stream(tuples[40:]))
        for name in QUERY_NAMES:
            assert tail_new2[name] == tail_new[name], name
            assert svc2.results(name) == svc.results(name), name

        # a kill after the commit rename but before the LATEST swing: the
        # step dir is complete on disk but UNPUBLISHED — recovery still
        # uses the previously published step (publication = LATEST swing,
        # so the commit point is one unambiguous instruction)
        svc.snapshot(d, step=4, async_save=True, _crash_after="rename")
        ckpt.wait_pending(d)
        assert os.path.isdir(os.path.join(d, "step_000000004"))
        assert ckpt.latest_step_dir(d) == committed
        svc3 = _make_service()
        assert svc3.restore(d) == 1
        for name in QUERY_NAMES:
            assert svc3.results(name) == mid_results[name], name


# -- snapshot vs async-decode FIFO (ISSUE 10 satellite) -----------------------


def test_snapshot_drains_pending_async_decode_fifo():
    """`snapshot()` with a non-empty deferred-decode FIFO (async_depth>1)
    must drain it first: the in-flight dispatch has already mutated device
    state (emitted mask included), so saving before its results land in
    `per_query_results` would snapshot a mask ahead of the results —
    restore + replay would then silently DROP those pairs. After the
    drain, state and results agree: nothing dropped, nothing re-emitted."""
    tuples = _stream_tuples()
    svc = _make_service(async_decode=True, async_depth=4)
    svc.ingest(Stream(tuples[:60]))
    group = svc.queries["d_arb"]

    # dispatch a batch directly and leave its decode handle unresolved —
    # exactly the state an async_depth>1 pipeline is in mid-flight
    pending_batch = [(s.src, s.dst, s.label, s.ts)
                     for s in tuples[60:] if s.op == "+"][:8]
    handle = group.insert_batch_pending(pending_batch)
    assert len(group._pending_fifo) == 1

    with tempfile.TemporaryDirectory() as d:
        svc.snapshot(d, step=1)
        # the snapshot was a sequence point: FIFO drained, results landed
        assert len(group._pending_fifo) == 0
        after_snapshot = {name: svc.results(name) for name in QUERY_NAMES}
        # resolving the stale handle afterwards must be a no-op (already
        # decoded by the drain — no double-emit into the result sets)
        handle.resolve()
        assert {name: svc.results(name)
                for name in QUERY_NAMES} == after_snapshot

        # restore sees the in-flight batch's results (no drop) ...
        svc2 = _make_service(async_decode=True, async_depth=4)
        assert svc2.restore(d) == 1
        for name in QUERY_NAMES:
            assert svc2.results(name) == after_snapshot[name], name
        # ... and the two runs continue identically (no double-emit: a
        # re-emitted pair would show up in svc2's NEW stream but not svc's)
        rest = [s for s in tuples[60:]
                if (s.src, s.dst, s.label, s.ts) not in
                [tuple(b) for b in pending_batch]]
        tail_new = svc.ingest(Stream(rest))
        tail_new2 = svc2.ingest(Stream(rest))
        for name in QUERY_NAMES:
            assert tail_new2[name] == tail_new[name], name
            assert svc2.results(name) == svc.results(name), name
