"""Contraction-backend conformance (PR 4): every ContractionBackend vs the
jnp oracle engine on CPU (Pallas/bucket kernels under ``interpret=True``),
with deletions, both path semantics, query churn, and BOTH executors.

Bars per backend:
  * jnp / pallas — EXACT: per-event result streams bit-identical to the
    jnp engine (max/min never reassociates), Local and Mesh.
  * mxu_bucket — BOUNDED COARSENING: the decoded dist equals the float
    engine's dist mapped through the level grid (the exactness guard —
    checked elementwise), so results are a superset of the float engine's
    and every extra pair's true bottleneck sits within one level step of
    its query's expiry boundary. Mesh-vs-local bucket result streams are
    still bit-identical (same deterministic quantization per shard).

Plus the fused-kernel oracles and the unknown-backend validation
regression ("palas" used to silently run the jnp reference).
"""
import random

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import compile_query
from repro.core.backend import (
    KNOWN_BACKENDS,
    BucketBackend,
    JnpBackend,
    PallasBackend,
    resolve_backend,
)
from repro.core.engine import BatchedDenseRPQEngine, DenseRPQEngine, RegisteredQuery
from repro.distributed.executor import MeshExecutor

QUERIES = ["a*", "a . b*", "(a | b)*", "a . b* . c"]
LABELS = ["a", "b", "c"]
N_LEVELS = 8


def _backend(name):
    """Fresh CPU-testable instance per test (interpret=True for kernels)."""
    return {
        "jnp": lambda: JnpBackend(),
        "pallas": lambda: PallasBackend(interpret=True),
        "bucket-jnp": lambda: BucketBackend(n_levels=N_LEVELS,
                                            use_pallas=False),
        "bucket-pallas": lambda: BucketBackend(n_levels=N_LEVELS,
                                               use_pallas=True,
                                               interpret=True),
    }[name]()


EXACT = ["pallas"]
COARSE = ["bucket-jnp", "bucket-pallas"]


def _random_events(rng, n_vertices, n_edges, t_max, deletions=True):
    live, events, t_used = {}, [], sorted(
        rng.sample(range(1, t_max), k=min(n_edges, t_max - 1)))
    for t in t_used:
        u, v = rng.randrange(n_vertices), rng.randrange(n_vertices)
        lab = rng.choice(LABELS)
        if deletions and live and rng.random() < 0.15:
            du, dv, dl = rng.choice(sorted(live))
            del live[(du, dv, dl)]
            events.append(("-", du, dv, dl, float(t)))
        else:
            live[(u, v, lab)] = t
            events.append(("+", u, v, lab, float(t)))
    return events


def _specs(rng, n_queries, window):
    specs = []
    for qi in range(n_queries):
        expr = rng.choice(QUERIES)
        dfa = compile_query(expr)
        semantics = "arbitrary"
        if dfa.has_containment_property and rng.random() < 0.4:
            semantics = "simple"
        specs.append(RegisteredQuery(f"q{qi}", dfa, window, semantics))
    return specs


def _assert_grid_consistent(dist_f, dist_b, now, w_max, n_levels=N_LEVELS):
    """The bucket exactness guard, origin-free form.

    Origins are always multiples of the step on the ABSOLUTE grid, so a
    stored level decodes to ``step * ceil(true_ts / step)`` regardless of
    which dispatch wrote it. Hence, elementwise:

      * every finite bucket entry equals the grid-ceil of the float
        engine's entry (the level closure IS the grid-mapped float
        closure), and
      * every entry the bucket dropped to -inf sat at/below the window
        origin of its writing dispatch — i.e. at/below the CURRENT origin,
        dead for every query's read-time threshold.

    (A naive comparison against the current-origin quantizer fails on
    stale entries: the clock advances between dispatches — expiry,
    out-of-alphabet events — and dist is only rewritten at dispatches.)"""
    step = np.float32(w_max) / np.float32(n_levels)
    origin = np.float32(
        np.floor((np.float32(now) - np.float32(w_max)) / step) * step)
    expected = (np.ceil(dist_f / step) * step).astype(np.float32)
    finite_b = np.isfinite(dist_b)
    np.testing.assert_array_equal(dist_b[finite_b], expected[finite_b])
    assert np.all(dist_f[~finite_b] <= origin + 1e-4), (
        "bucket dropped a value still above the window origin")


# ---------------------------------------------------------------------------
# fused kernels vs their per-row oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("J,m,k,n", [(1, 8, 8, 8), (6, 20, 13, 17),
                                     (3, 33, 70, 9)])
def test_fused_maxmin_matches_vmap_oracle(J, m, k, n):
    from repro.kernels.maxmin.maxmin import maxmin_matmul_fused
    from repro.kernels.maxmin.ref import maxmin_matmul_naive

    rng = np.random.default_rng(J * 100 + m + k + n)
    a = rng.uniform(0, 1000, (J, m, k)).astype(np.float32)
    a[rng.random(a.shape) > 0.6] = -np.inf
    b = rng.uniform(0, 1000, (J, k, n)).astype(np.float32)
    b[rng.random(b.shape) > 0.6] = -np.inf
    ref = np.stack([np.asarray(maxmin_matmul_naive(a[j], b[j]))
                    for j in range(J)])
    out = np.asarray(maxmin_matmul_fused(a, b, interpret=True))
    np.testing.assert_allclose(ref, out)


@pytest.mark.parametrize("J,m,k,n,T", [(4, 16, 16, 16, 4), (2, 20, 33, 9, 8)])
def test_fused_bucket_matches_exact_oracle(J, m, k, n, T):
    from repro.kernels.bucket.bucket import bucket_maxmin_fused
    from repro.kernels.bucket.ref import bucket_maxmin_exact

    rng = np.random.default_rng(J + m + k + n + T)
    a = rng.integers(0, T + 1, (J, m, k)).astype(np.int32)
    b = rng.integers(0, T + 1, (J, k, n)).astype(np.int32)
    ref = np.stack([np.asarray(bucket_maxmin_exact(a[j], b[j]))
                    for j in range(J)])
    out = np.asarray(bucket_maxmin_fused(a, b, n_levels=T, interpret=True))
    np.testing.assert_array_equal(ref, out)


# ---------------------------------------------------------------------------
# exact backends: bit-identical engine conformance, Local + Mesh, churn
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend_name", EXACT)
@pytest.mark.parametrize("seed", [0, 1])
def test_exact_backend_matches_jnp_local(backend_name, seed):
    """Per-event result streams (inserts, deletions, expiry, mixed
    semantics) identical to the jnp engine on the LocalExecutor."""
    rng = random.Random(seed)
    window = rng.choice([10.0, 25.0])
    specs = _specs(rng, 3, window)
    ref = BatchedDenseRPQEngine(specs, n_slots=12, batch_size=1)
    eng = BatchedDenseRPQEngine(specs, n_slots=12, batch_size=1,
                                backend=_backend(backend_name))
    for i, (op, u, v, lab, ts) in enumerate(
            _random_events(rng, 6, 22, 60)):
        if op == "+":
            assert ref.insert(u, v, lab, ts) == eng.insert(u, v, lab, ts), i
        else:
            assert ref.delete(u, v, lab, ts) == eng.delete(u, v, lab, ts), i
        if i % 6 == 5:
            ref.expire(ts)
            eng.expire(ts)
    for qi in range(3):
        assert ref.per_query_results[qi] == eng.per_query_results[qi]
    np.testing.assert_array_equal(
        np.asarray(ref.batched_arrays.dist), np.asarray(eng.batched_arrays.dist))


@pytest.mark.parametrize("backend_name", EXACT + COARSE)
def test_backend_mesh_matches_local_with_churn(backend_name):
    """MeshExecutor runs the SELECTED backend per shard: result streams are
    bit-identical to the same backend on LocalExecutor (even for the
    bucket mode — quantization is deterministic), across mid-stream
    register/deregister and deletions."""
    rng = random.Random(7)
    window = 25.0
    base = [RegisteredQuery("q0", compile_query("a . b*"), window),
            RegisteredQuery("q1", compile_query("(a | b)*"), window)]
    local = BatchedDenseRPQEngine(base, n_slots=12, batch_size=1,
                                  backend=_backend(backend_name))
    mesh = BatchedDenseRPQEngine(
        base, n_slots=12, batch_size=1,
        executor=MeshExecutor(backend=_backend(backend_name)))
    late = RegisteredQuery("late", compile_query("a*"), window)
    for i, (op, u, v, lab, ts) in enumerate(
            _random_events(rng, 6, 24, 70)):
        if i == 8:
            assert local.register_query(late) == mesh.register_query(late)
        if i == 16:
            local.deregister_query("q0")
            mesh.deregister_query("q0")
        if op == "+":
            fl, fm = local.insert(u, v, lab, ts), mesh.insert(u, v, lab, ts)
        else:
            fl, fm = local.delete(u, v, lab, ts), mesh.delete(u, v, lab, ts)
        for qi_l, spec in local.live_items():
            assert fl[qi_l] == fm[mesh.lane_of(spec.name)], (i, spec.name)
        if i % 7 == 6:
            local.expire(ts)
            mesh.expire(ts)
    for qi_l, spec in local.live_items():
        assert (local.per_query_results[qi_l]
                == mesh.per_query_results[mesh.lane_of(spec.name)])


# ---------------------------------------------------------------------------
# bucket mode: the exactness guard and the coarsening bound
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend_name", COARSE)
def test_bucket_dist_is_grid_mapped_float_dist(backend_name):
    """THE exactness guard: at every event, the bucket engine's stored
    dist equals the float engine's dist mapped through the level grid,
    elementwise (level closure == grid map of the float closure — the map
    is monotone, so it commutes with max-min; the absolute grid makes
    re-quantization across dispatches the identity)."""
    rng = random.Random(3)
    window = 20.0
    specs = _specs(rng, 2, window)
    ref = BatchedDenseRPQEngine(specs, n_slots=10, batch_size=1)
    eng = BatchedDenseRPQEngine(specs, n_slots=10, batch_size=1,
                                backend=_backend(backend_name))
    for i, (op, u, v, lab, ts) in enumerate(
            _random_events(rng, 5, 20, 55)):
        if op == "+":
            ref.insert(u, v, lab, ts)
            eng.insert(u, v, lab, ts)
        else:
            ref.delete(u, v, lab, ts)
            eng.delete(u, v, lab, ts)
        if i % 5 == 4:
            ref.expire(ts)
            eng.expire(ts)
        now = float(np.asarray(ref.batched_arrays.now))
        _assert_grid_consistent(np.asarray(ref.batched_arrays.dist),
                                np.asarray(eng.batched_arrays.dist),
                                now, window)


def test_bucket_results_superset_with_bounded_boundary_error():
    """Coarsening bound: the bucket engine reports every float-valid pair,
    and at any instant an extra VALID pair's true best bottleneck sits
    within one level step of its query's expiry threshold."""
    rng = random.Random(11)
    window = 24.0
    step = window / N_LEVELS
    specs = _specs(rng, 3, window)
    ref = BatchedDenseRPQEngine(specs, n_slots=12, batch_size=1)
    eng = BatchedDenseRPQEngine(specs, n_slots=12, batch_size=1,
                                backend=BucketBackend(n_levels=N_LEVELS,
                                                      use_pallas=False))
    finals = np.asarray(ref.finals_mask)
    for i, (op, u, v, lab, ts) in enumerate(
            _random_events(rng, 6, 30, 80)):
        if op == "+":
            fr = ref.insert(u, v, lab, ts)
            eng.insert(u, v, lab, ts)
        else:
            ref.delete(u, v, lab, ts)
            eng.delete(u, v, lab, ts)
            continue
        # every float-fresh pair is already in the bucket's cumulative set
        # (it may have been emitted EARLIER there — decoded ts >= true ts)
        for qi in range(3):
            assert fr[qi] <= eng.per_query_results[qi], (i, qi)
            # snapshot validity: extras are boundary cases only
            vr = ref.current_results(qi)
            ve = eng.current_results(qi)
            assert vr <= ve, (i, qi, vr - ve)
            extras = ve - vr
            if not extras:
                continue
            a = ref.batched_arrays
            dist = np.asarray(a.dist[qi])
            best = np.where(finals[qi][None, None, :], dist, -np.inf).max(2)
            low = float(np.asarray(a.now)) - specs[qi].window
            for (x, y) in extras:
                b = best[ref.slot_of[x], ref.slot_of[y]]
                assert low - step - 1e-4 <= b <= low + 1e-4, (
                    i, qi, (x, y), b, low, step)
    for qi in range(3):
        assert ref.per_query_results[qi] <= eng.per_query_results[qi]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31), window=st.sampled_from([8.0, 16.0, 40.0]))
def test_bucket_grid_property_random(seed, window):
    """Property form of the exactness guard on random single-query
    streams: final bucket dist == grid map of final float dist."""
    rng = random.Random(seed)
    dfa = compile_query("a . b*")
    ref = DenseRPQEngine(dfa, window, n_slots=8, batch_size=1)
    eng = DenseRPQEngine(dfa, window, n_slots=8, batch_size=1,
                         backend=BucketBackend(n_levels=N_LEVELS,
                                               use_pallas=False))
    for (op, u, v, lab, ts) in _random_events(rng, 5, 14, 40,
                                              deletions=False):
        ref.insert(u, v, lab, ts)
        eng.insert(u, v, lab, ts)
    now = float(np.asarray(ref.batched_arrays.now))
    _assert_grid_consistent(np.asarray(ref.batched_arrays.dist),
                            np.asarray(eng.batched_arrays.dist), now, window)


@pytest.mark.parametrize("t0", [0.0, 19999.0])
def test_bucket_no_drift_on_inexact_step(t0):
    """Regression: with a step that is NOT exactly representable (w=2.4,
    T=8 -> step=0.3), re-encoding a decoded on-grid value computes its
    grid ratio slightly above the integer; an unguarded ceil bumped it a
    full level per dispatch, accumulating unbounded upward drift (a pair
    could outlive its window indefinitely). The round-trip fp error is
    ABSOLUTE (~ulp of the clock), so the snap tolerance scales with
    ``now`` — the t0=19999 leg pins the large-clock regime a fixed
    level-relative epsilon missed. Every finite bucket entry must stay
    within one level step of the float engine's across many dispatches,
    and never fall below it by more than the snap tolerance."""
    dfa = compile_query("(a | b)*")
    window = 2.4
    step = window / N_LEVELS  # 0.3: inexact in binary
    ref = DenseRPQEngine(dfa, window, n_slots=8, batch_size=1)
    eng = DenseRPQEngine(dfa, window, n_slots=8, batch_size=1,
                         backend=BucketBackend(n_levels=N_LEVELS,
                                               use_pallas=False))
    rng = random.Random(5)
    t = t0
    for i in range(80):  # many dispatches over a long-lived edge set
        t += 0.07
        u, v = rng.randrange(5), rng.randrange(5)
        lab = rng.choice(["a", "b"])
        ref.insert(u, v, lab, t)
        eng.insert(u, v, lab, t)
        df = np.asarray(ref.batched_arrays.dist)
        db = np.asarray(eng.batched_arrays.dist)
        finite = np.isfinite(db)
        # snap tolerance actually applied at this clock (ulp-scaled)
        tol = max(BucketBackend.GRID_EPS, 8 * abs(t) * 2.0 ** -23 / step)
        tol = min(tol, 0.45) * step
        assert np.all(db[finite] <= df[finite] + step + 1e-5), (
            f"event {i}: bucket value drifted beyond one level step")
        assert np.all(db[finite] >= df[finite] - tol - 1e-5), (
            f"event {i}: bucket value fell below the snap tolerance")


# ---------------------------------------------------------------------------
# validation: unknown backends raise at construction (satellite regression)
# ---------------------------------------------------------------------------


def test_unknown_backend_raises_everywhere():
    """'palas' used to silently run the jnp reference — now every
    construction path validates against the known-backend list."""
    dfa = compile_query("a*")
    with pytest.raises(ValueError, match="jnp.*pallas.*mxu_bucket"):
        DenseRPQEngine(dfa, 10.0, backend="palas")
    with pytest.raises(ValueError, match="known backends"):
        resolve_backend("palas")
    with pytest.raises(ValueError, match="known backends"):
        MeshExecutor(backend="mxu-bucket")
    from repro.streaming.service import PersistentQueryService

    svc = PersistentQueryService(window=10.0, slide=2.0)
    with pytest.raises(ValueError, match="known backends"):
        svc.register("q", "a*", engine="dense", backend="palas")
    # the round functions validate too (they resolve the same way)
    from repro.core.semiring import BatchedTransitionTable, batched_relax_round
    import jax.numpy as jnp

    btt = BatchedTransitionTable.from_dfas([dfa], dfa.labels)
    dist = jnp.full((1, 4, 4, btt.k), -jnp.inf)
    adj = jnp.full((btt.n_labels, 4, 4), -jnp.inf)
    with pytest.raises(ValueError, match="known backends"):
        batched_relax_round(dist, adj, btt, "palas")
    assert set(KNOWN_BACKENDS) == {"jnp", "pallas", "mxu_bucket"}


def test_known_backend_strings_resolve_to_singletons():
    """String-named backends intern: stable identity keeps the jit compile
    cache shared across engines."""
    assert resolve_backend("jnp") is resolve_backend("jnp")
    assert resolve_backend("pallas") is resolve_backend("pallas")
    b = BucketBackend(n_levels=4)
    assert resolve_backend(b) is b


def test_backends_compare_by_configuration():
    """Backends hash/compare by config: equal-but-distinct instances share
    jit compile caches AND count as 'one backend' for a service group
    (regression: identity-based dedup rejected two identically-configured
    instances at first ingest)."""
    assert BucketBackend(n_levels=8) == BucketBackend(n_levels=8)
    assert hash(BucketBackend(n_levels=8)) == hash(BucketBackend(n_levels=8))
    assert BucketBackend(n_levels=8) != BucketBackend(n_levels=4)
    assert PallasBackend(interpret=True) == PallasBackend(interpret=True)
    assert PallasBackend(interpret=True) != PallasBackend(interpret=False)
    assert JnpBackend() != PallasBackend()

    from repro.streaming.generators import so_like
    from repro.streaming.service import PersistentQueryService
    from repro.streaming.stream import Stream

    svc = PersistentQueryService(window=50.0, slide=10.0)
    svc.register("q1", "a2q*", engine="dense", n_slots=16,
                 backend=BucketBackend(n_levels=8, use_pallas=False))
    svc.register("q2", "c2a*", engine="dense", n_slots=16,
                 backend=BucketBackend(n_levels=8, use_pallas=False))
    svc.ingest(Stream(list(so_like(8, 20, seed=1))))  # must not raise
