"""Substrate tests: optimizer, gradient compression, checkpointing,
fault-tolerant driver, data pipeline, streaming service."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw, lr_schedule
from repro.optim.compression import compress, decompress, init_ef


def test_adamw_reduces_quadratic_loss():
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=100,
                      weight_decay=0.0, clip_norm=1.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = init_adamw(cfg, params)

    def loss_fn(p):
        return jnp.sum(jnp.square(p["w"] - target))

    loss0 = float(loss_fn(params))
    for _ in range(100):
        grads = jax.grad(loss_fn)(params)
        params, state, _m = adamw_update(cfg, params, grads, state)
    assert float(loss_fn(params)) < 0.05 * loss0


def test_adamw_bf16_moments_close_to_f32():
    target = jnp.asarray(np.random.RandomState(0).randn(32).astype(np.float32))

    def run(moment_dtype):
        cfg = AdamWConfig(lr_peak=0.05, warmup_steps=2, total_steps=60,
                          weight_decay=0.0, moment_dtype=moment_dtype)
        params = {"w": jnp.zeros(32)}
        state = init_adamw(cfg, params)
        for _ in range(60):
            grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            params, state, _ = adamw_update(cfg, params, grads, state)
        return params["w"]

    w32 = run("float32")
    w16 = run("bfloat16")
    # bf16 moments track f32 within a coarse tolerance (documented policy)
    assert float(jnp.max(jnp.abs(w32 - w16))) < 0.15


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[100] == pytest.approx(1e-4, rel=1e-3)
    assert all(a >= b - 1e-12 for a, b in zip(lrs[10:], lrs[11:]))  # decaying


def test_error_feedback_compression_contracts():
    """EF invariant: sum of dequantized transmissions + final residual equals
    the sum of raw gradients (no gradient information is lost over time)."""
    rng = np.random.RandomState(0)
    grads_seq = [{"w": jnp.asarray(rng.randn(64).astype(np.float32))} for _ in range(20)]
    ef = init_ef(grads_seq[0])
    sent = jnp.zeros(64)
    for g in grads_seq:
        q, s, ef = compress(g, ef)
        sent = sent + decompress(q, s)["w"]
    total = sum(g["w"] for g in grads_seq)
    np.testing.assert_allclose(
        np.asarray(sent + ef.residual["w"]), np.asarray(total), rtol=1e-5, atol=1e-5
    )
    # compression is tight: int8 with per-tensor scale -> bounded error
    assert float(jnp.max(jnp.abs(ef.residual["w"]))) < float(jnp.max(jnp.abs(total))) / 10


def test_checkpoint_roundtrip_and_atomicity():
    from repro.checkpoint import ckpt

    tree = {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "step": jnp.asarray(7),
        "nested": [jnp.ones((2, 2), jnp.bfloat16), jnp.zeros((1,), jnp.int32)],
    }
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 10, tree, extra={"cursor": 123})
        restored, extra = ckpt.restore(d, like=tree)
        assert extra["cursor"] == 123
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert a.dtype == b.dtype
        # a later, torn write must not be visible: fake a partial dir
        os.makedirs(os.path.join(d, "step_000000020.tmp.0"), exist_ok=True)
        restored2, _ = ckpt.restore(d, like=tree)
        np.testing.assert_array_equal(
            np.asarray(restored2["params"]["w"]), np.asarray(tree["params"]["w"])
        )


def test_checkpoint_async_then_restore():
    from repro.checkpoint import ckpt

    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.ones((4,))}
        ckpt.async_save(d, 1, tree, extra={"step": 1})
        ckpt.wait_pending(d)
        restored, extra = ckpt.restore(d, like=tree)
        assert extra["step"] == 1


def test_run_with_restarts_recovers_from_crash():
    from repro.distributed.fault import run_with_restarts

    crashed = {"done": False}

    def step_fn(state, step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")
        return {"x": state["x"] + 1.0}

    with tempfile.TemporaryDirectory() as d:
        final, info = run_with_restarts(
            step_fn, {"x": jnp.zeros(())}, n_steps=12, ckpt_dir=d, ckpt_every=5,
        )
        assert info["restarts"] == 1
        assert info["final_step"] == 12
        assert float(final["x"]) == 12.0  # exactly-once semantics via resume


def test_straggler_monitor():
    from repro.distributed.fault import StragglerMonitor

    mon = StragglerMonitor(deadline_factor=3.0, warmup=3)
    for i in range(10):
        mon.observe(i, 0.1)
    assert mon.observe(10, 1.0)       # 10x median -> straggler
    assert not mon.observe(11, 0.12)
    assert mon.stragglers == [10]


def test_token_pipeline_determinism_and_cursor():
    from repro.data.tokens import TokenPipeline

    p1 = TokenPipeline(vocab_size=100, seq_len=16, batch_per_host=4, seed=1)
    a = next(p1)
    b = next(p1)
    p1.close()
    # resume from cursor=1 reproduces batch #1 exactly
    p2 = TokenPipeline(vocab_size=100, seq_len=16, batch_per_host=4, seed=1,
                       start_step=1)
    b2 = next(p2)
    p2.close()
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_service_end_to_end_with_expiry_and_ckpt():
    from repro.streaming.generators import so_like
    from repro.streaming.service import PersistentQueryService

    stream = so_like(n_vertices=24, n_edges=150, seed=3, rate=10.0)
    svc = PersistentQueryService(window=5.0, slide=1.0)
    svc.register("q1", "a2q . c2a*", engine="dense", n_slots=48)
    svc.register("q1_ref", "a2q . c2a*", engine="reference")
    svc.ingest(stream)
    assert svc.results("q1") == svc.results("q1_ref")
    assert svc.stats["q1"].tuples == len(stream)

    with tempfile.TemporaryDirectory() as d:
        svc.snapshot(d, step=1)
        # new service instance re-attaches to the persisted state
        svc2 = PersistentQueryService(window=5.0, slide=1.0)
        svc2.register("q1", "a2q . c2a*", engine="dense", n_slots=48)
        svc2.restore(d)
        assert svc2.results("q1") == svc.results("q1")
