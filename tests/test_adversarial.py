"""Adversarial workload layer: generator contracts, and adaptive-controller
stability under hostile traffic (ISSUE 10 satellite — the ROADMAP's
"prove it survives production shapes" gap).

Stability here means the controllers SETTLE instead of thrashing:
``batch_size_log`` shows bounded direction changes (no sustained
grow/shrink oscillation), ``frontier_log``'s auto-cap only ever ratchets
up and stays bounded, and ``dist_log`` never reports lost entries while
its drain pressure stops growing — across bursty, churn-storm, and
deletion-heavy streams.
"""
import collections

import pytest

from repro.streaming.generators import (bursty_arrivals, churn_storm_plan,
                                        deletion_storm, mixed_window_streams,
                                        powerlaw_hotspot, so_like)
from repro.streaming.service import PersistentQueryService
from repro.streaming.stream import Stream

# -- generator contracts ------------------------------------------------------


def test_bursty_arrivals_contract():
    a = list(bursty_arrivals(32, 200, seed=3, flash_every=50, flash_len=16,
                             flash_boost=50.0))
    b = list(bursty_arrivals(32, 200, seed=3, flash_every=50, flash_len=16,
                             flash_boost=50.0))
    assert a == b                                # deterministic in the seed
    assert a != list(bursty_arrivals(32, 200, seed=4, flash_every=50))
    assert len(a) == 200
    assert all(x.ts < y.ts for x, y in zip(a, a[1:]))   # strictly increasing
    # flash crowds actually compress time: the minimum inter-arrival gap
    # inside a flash window is far below the off-flash median
    gaps = [y.ts - x.ts for x, y in zip(a, a[1:])]
    flash = sorted(gaps)[:16]
    assert max(flash) < sorted(gaps)[len(gaps) // 2] / 2


def test_powerlaw_hotspot_contract():
    a = list(powerlaw_hotspot(64, 300, seed=3, alpha=1.2))
    assert a == list(powerlaw_hotspot(64, 300, seed=3, alpha=1.2))
    assert len(a) == 300
    assert all(x.ts < y.ts for x, y in zip(a, a[1:]))
    # celebrity skew: the hottest source absorbs a far-above-uniform share
    counts = collections.Counter(s.src for s in a)
    assert counts.most_common(1)[0][1] / len(a) > 10.0 / 64


def test_deletion_storm_contract():
    base = so_like(24, 150, seed=5)
    storm = list(deletion_storm(base, storm_every=40, storm_len=16, seed=5))
    assert storm == list(deletion_storm(so_like(24, 150, seed=5),
                                        storm_every=40, storm_len=16, seed=5))
    assert all(x.ts < y.ts for x, y in zip(storm, storm[1:]))
    # every deletion targets a previously inserted, still-live edge
    live = set()
    n_del = 0
    for s in storm:
        key = (s.src, s.dst, s.label)
        if s.op == "+":
            live.add(key)
        else:
            n_del += 1
            assert key in live
            live.discard(key)
    # it IS deletion-heavy: storms delete in bursts, not a trickle
    assert n_del >= 0.15 * 150


def test_mixed_window_streams_span_100x():
    entries = mixed_window_streams(24, 60, seed=1)
    windows = [e["window"] for e in entries]
    assert max(windows) / min(windows) == pytest.approx(100.0)
    for e in entries:
        assert 0 < e["slide"] <= e["window"]
        assert len(list(e["stream"])) == 60


def test_churn_storm_plan_contract():
    plan = churn_storm_plan(80, seed=2, churn_every=8)
    assert plan == churn_storm_plan(80, seed=2, churn_every=8)
    live = set()
    for batch_idx, op, name, kind, expr in plan:
        assert 0 < batch_idx < 80
        if op == "register":
            assert name not in live and kind in ("rpq", "rapq") and expr
            live.add(name)
        else:
            assert op == "deregister" and name in live
            live.discard(name)
    # it is a storm: the live query set keeps shifting
    assert len(plan) >= 80 // 8 - 1


# -- adaptive-controller stability --------------------------------------------

WINDOW, SLIDE = 20.0, 2.0


def _adaptive_service():
    svc = PersistentQueryService(
        window=WINDOW, slide=SLIDE, adaptive_batch=True, max_batch=16,
        frontier="auto", frontier_cap=8,
        dist_layout="row_sparse", dist_cap=16)
    svc.register("q_arb", "a2q . c2a*", engine="dense", n_slots=48)
    svc.register("q_plus", "(a2q | c2a)+", engine="dense", n_slots=48)
    return svc


def _assert_controllers_settle(svc, regime):
    # batch sizing: power-of-two steps inside bounds, and bounded
    # direction changes — sustained grow/shrink/grow oscillation would
    # show up as many sign flips in the decision log
    sizes = [b for _seen, b in svc.batch_size_log]
    for b in sizes:
        assert 1 <= b <= svc._max_batch and (b & (b - 1)) == 0, regime
    flips = sum(1 for i in range(2, len(sizes))
                if (sizes[i] - sizes[i - 1]) * (sizes[i - 1] - sizes[i - 2]) < 0)
    assert flips <= 2, (regime, sizes)

    # frontier auto-cap: a pure ratchet (monotone non-decreasing), and it
    # settles instead of doubling forever
    caps = [e[1]["cap"] for e in svc.frontier_log if e[1].get("cap")]
    assert all(x <= y for x, y in zip(caps, caps[1:])), (regime, caps)
    if caps:
        assert caps[-1] <= caps[0] * 2 ** 4, (regime, caps)

    # row-sparse dist: overflow drains may fire but NOTHING is ever lost,
    # and per-interval drain pressure stops growing (the last third of the
    # run is no worse than the worst interval overall)
    assert all(e[1]["lost"] == 0 for e in svc.dist_log), regime
    drains = [e[1]["drains"] for e in svc.dist_log]
    deltas = [y - x for x, y in zip(drains, drains[1:])]
    if len(deltas) >= 3:
        tail = deltas[-(len(deltas) // 3):]
        assert max(tail) <= max(deltas), regime  # no late blow-up
        assert all(d >= 0 for d in deltas), regime


def test_stability_under_bursty_arrivals():
    svc = _adaptive_service()
    svc.ingest(Stream(list(bursty_arrivals(
        32, 260, seed=3, flash_every=60, flash_len=20, flash_boost=40.0))))
    assert svc.frontier_log and svc.dist_log
    _assert_controllers_settle(svc, "bursty")


def test_stability_under_deletion_storm():
    svc = _adaptive_service()
    svc.ingest(Stream(list(deletion_storm(
        so_like(24, 200, seed=5), storm_every=48, storm_len=20, seed=5))))
    assert svc.dist_log
    _assert_controllers_settle(svc, "deletion-storm")


def test_stability_under_query_churn_storm():
    svc = _adaptive_service()
    tuples = list(powerlaw_hotspot(48, 240, seed=7, alpha=1.1))
    plan = churn_storm_plan(len(tuples) // 8, seed=2, churn_every=6)
    ops = {b * 8: (op, name, expr) for b, op, name, _kind, expr in plan}
    done = 0
    for cut in sorted(ops) + [len(tuples)]:
        if cut > done:
            svc.ingest(Stream(tuples[done:cut]))
            done = cut
        if cut in ops:
            op, name, expr = ops[cut]
            if op == "register":
                svc.register(name, expr, engine="dense", n_slots=48)
            else:
                svc.deregister(name)
    assert svc.dist_log
    _assert_controllers_settle(svc, "churn-storm")


def test_stability_across_window_scales():
    """The same arrival process under window sizes spanning 100x: every
    scale keeps the no-loss dist contract and a ratcheting frontier."""
    for entry in mixed_window_streams(24, 140, seed=1):
        svc = PersistentQueryService(
            window=entry["window"], slide=entry["slide"],
            adaptive_batch=True, frontier="auto", frontier_cap=8,
            dist_layout="row_sparse", dist_cap=16)
        svc.register("q_arb", "a2q . c2a*", engine="dense", n_slots=48)
        svc.ingest(entry["stream"])
        _assert_controllers_settle(svc, entry["name"])
