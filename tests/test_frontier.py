"""Frontier-restricted relaxation conformance (PR 5 tentpole).

The frontier dispatch must be BIT-identical to the dense dispatch — per
event, on both executors, under all three contraction backends, through
deletions, query churn, compaction + capacity growth mid-stream, and both
path semantics. The dense round is the oracle: restricting a round to the
dirty rows is exact because each source row's closure depends only on
itself and the shared adjacency (see core/semiring.py), and overflow falls
back to the dense loop in-dispatch.

The mesh tests run on whatever devices this process has (the CI frontier
leg re-runs this file under XLA_FLAGS=--xla_force_host_platform_device_count=8
so real lane shards compose the frontier gather with the skip cond).
"""
import random

import numpy as np
import pytest

from repro.core import compile_query
from repro.core.backend import BucketBackend, PallasBackend
from repro.core.engine import BatchedDenseRPQEngine, DenseRPQEngine, RegisteredQuery
from repro.core.executor import LocalExecutor
from repro.core.semiring import frontier_seed, pack_frontier
from repro.distributed.executor import MeshExecutor
from repro.streaming.generators import gmark_like, so_like, with_deletions
from repro.streaming.service import PersistentQueryService
from repro.streaming.stream import Stream

import jax.numpy as jnp

QUERIES = ["a*", "a . b*", "(a | b)*", "a . b* . c", "(a . b)+", "a . b . c"]
LABELS = ["a", "b", "c"]


def _random_events(rng, n_vertices, n_edges, t_max, deletions=True):
    ts = sorted(rng.sample(range(1, t_max), k=min(n_edges, t_max - 1)))
    live = {}
    events = []
    for t in ts:
        u, v = rng.randrange(n_vertices), rng.randrange(n_vertices)
        lab = rng.choice(LABELS)
        if deletions and live and rng.random() < 0.15:
            du, dv, dl = rng.choice(sorted(live))
            del live[(du, dv, dl)]
            events.append(("-", du, dv, dl, float(t)))
        else:
            live[(u, v, lab)] = t
            events.append(("+", u, v, lab, float(t)))
    return events


def _specs(rng, n_queries, window):
    specs = []
    for qi in range(n_queries):
        expr = rng.choice(QUERIES)
        dfa = compile_query(expr)
        semantics = "simple" if (dfa.has_containment_property
                                 and rng.random() < 0.4) else "arbitrary"
        specs.append(RegisteredQuery(f"q{qi}", dfa, window, semantics))
    return specs


def _drive(make_engine, events, slide, n_queries):
    g = make_engine()
    next_exp = slide
    stream_out = []
    for (op, u, v, lab, t) in events:
        if t >= next_exp:
            g.expire(t)
            while next_exp <= t:
                next_exp += slide
        if op == "+":
            fresh = g.insert(u, v, lab, t)
            stream_out.append(("+",) + tuple(
                frozenset(fresh[qi]) for qi in range(n_queries)))
        else:
            inv = g.delete(u, v, lab, t)
            stream_out.append(("-",) + tuple(
                frozenset(inv[qi]) for qi in range(n_queries)))
    return g, stream_out


def _assert_streams_equal(tag, dense, frontier):
    assert len(dense) == len(frontier)
    for i, (d, f) in enumerate(zip(dense, frontier)):
        assert d == f, (tag, i, d, f)


def _valid_view(g):
    """The engine's dist restricted to window-valid entries (everything at
    or below ``now - w`` replaced by -inf) — the observable device state."""
    a = g.batched_arrays
    low = np.asarray(a.now - g.windows)                 # (Q,)
    d = np.asarray(a.dist)
    return np.where(d > low[:, None, None, None], d, -np.inf)


@pytest.mark.parametrize("seed", range(3))
def test_frontier_matches_dense_local(seed):
    """Inserts + deletions + expiry, mixed semantics: every event's fresh
    results and invalidations identical with frontier on vs off."""
    rng = random.Random(seed)
    window = rng.choice([10.0, 25.0])
    nq = 3
    specs = _specs(rng, nq, window)
    events = _random_events(rng, 14, 90, 70)

    def dense():
        return BatchedDenseRPQEngine(specs, n_slots=24, batch_size=1)

    def frontier():
        return BatchedDenseRPQEngine(specs, n_slots=24, batch_size=1,
                                     frontier="auto", frontier_cap=4)

    g_d, ev_d = _drive(dense, events, 5.0, nq)
    g_f, ev_f = _drive(frontier, events, 5.0, nq)
    _assert_streams_equal(f"seed={seed}", ev_d, ev_f)
    # the device state must agree on every WINDOW-VALID entry (the same
    # fixpoint wherever it is observable). Raw arrays may differ at dead
    # entries since PR 6: the cone-restricted delete leaves rows outside
    # the deleted edge's cone untouched, so entries whose support already
    # expired out of the adjacency linger there until the row is next
    # re-derived, while the dense from-scratch delete garbage-collects
    # them. Dead entries can never resurface (bottlenecks only age, the
    # threshold only rises), so the observable state is identical.
    np.testing.assert_array_equal(_valid_view(g_d), _valid_view(g_f))
    st = g_f.executor.frontier_stats
    assert st["dispatches"] > 0
    assert st["delete_dispatches"] > 0          # deletes rode the frontier


@pytest.mark.parametrize("backend_name", ["jnp", "pallas", "mxu_bucket"])
def test_frontier_matches_dense_per_backend(backend_name):
    """Frontier == dense under every contraction backend (the frontier
    slab rides contract_rows in the backend's own representation; for the
    bucket mode both sides coarsen identically)."""
    rng = random.Random(9)
    nq = 2
    specs = _specs(rng, nq, 12.0)
    events = _random_events(rng, 12, 60, 50, deletions=True)

    def mk_backend():
        if backend_name == "pallas":
            return PallasBackend(interpret=True)
        if backend_name == "mxu_bucket":
            return BucketBackend(n_levels=6, use_pallas=False)
        return "jnp"

    def dense():
        return BatchedDenseRPQEngine(specs, n_slots=20, batch_size=1,
                                     backend=mk_backend())

    def frontier():
        return BatchedDenseRPQEngine(specs, n_slots=20, batch_size=1,
                                     backend=mk_backend(),
                                     frontier="on", frontier_cap=8)

    _, ev_d = _drive(dense, events, 4.0, nq)
    _, ev_f = _drive(frontier, events, 4.0, nq)
    _assert_streams_equal(backend_name, ev_d, ev_f)


@pytest.mark.parametrize("backend_name", ["jnp", "mxu_bucket"])
def test_frontier_mesh_matches_dense_local(backend_name):
    """MeshExecutor frontier == LocalExecutor dense, per event: the
    per-shard frontier gather + skip + overflow fallback compose into the
    same result stream the dense single-device path emits."""
    rng = random.Random(4)
    nq = 3
    specs = _specs(rng, nq, 15.0)
    events = _random_events(rng, 14, 80, 60)

    def mk_backend():
        if backend_name == "mxu_bucket":
            return BucketBackend(n_levels=6, use_pallas=False)
        return "jnp"

    def dense_local():
        return BatchedDenseRPQEngine(specs, n_slots=24, batch_size=1,
                                     backend=mk_backend())

    def frontier_mesh():
        return BatchedDenseRPQEngine(
            specs, n_slots=24, batch_size=1,
            executor=MeshExecutor(backend=mk_backend(), frontier="auto",
                                  frontier_cap=4))

    _, ev_d = _drive(dense_local, events, 5.0, nq)
    g_m, ev_m = _drive(frontier_mesh, events, 5.0, nq)
    # mesh lane capacity may be padded; compare the live lanes
    _assert_streams_equal(backend_name, ev_d, ev_m)
    assert g_m.executor.frontier_stats["dispatches"] > 0


def test_frontier_mesh_vertex_sharding_matches_dense():
    """Vertex axis over 'model' (when the process has >= 2 devices): the
    per-shard dirty reduction runs over the LOCAL u block and pmax-combines
    — the frontier must stay uniform across model peers."""
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices for a model axis")
    rng = random.Random(11)
    nq = 2
    specs = _specs(rng, nq, 12.0)
    events = _random_events(rng, 12, 60, 50)

    def dense_local():
        return BatchedDenseRPQEngine(specs, n_slots=24, batch_size=1)

    def frontier_mesh():
        return BatchedDenseRPQEngine(
            specs, n_slots=24, batch_size=1,
            executor=MeshExecutor(model_axis=2, frontier="on",
                                  frontier_cap=8))

    _, ev_d = _drive(dense_local, events, 4.0, nq)
    _, ev_m = _drive(frontier_mesh, events, 4.0, nq)
    _assert_streams_equal("vertex-sharded", ev_d, ev_m)


def test_frontier_overflow_falls_back_dense():
    """Regression: a tiny fixed capacity (frontier="on" never grows) forces
    the dense fallback, results stay identical, and the fallback is
    observable in the stats."""
    rng = random.Random(2)
    nq = 2
    specs = _specs(rng, nq, 30.0)  # big window -> many dirty rows
    # preferential attachment: reach sets grow fast, overflowing F=2
    stream = list(so_like(16, 80, seed=3))
    events = [("+", s.src, s.dst, s.label, s.ts) for s in stream]
    specs = [RegisteredQuery("q0", compile_query("(a2q | c2a)*"), 30.0),
             RegisteredQuery("q1", compile_query("a2q . c2a*"), 30.0)]

    def dense():
        return BatchedDenseRPQEngine(specs, n_slots=24, batch_size=1)

    def frontier():
        return BatchedDenseRPQEngine(specs, n_slots=24, batch_size=1,
                                     frontier="on", frontier_cap=2)

    _, ev_d = _drive(dense, events, 6.0, 2)
    g_f, ev_f = _drive(frontier, events, 6.0, 2)
    _assert_streams_equal("overflow", ev_d, ev_f)
    st = g_f.executor.frontier_stats
    assert st["fallbacks"] > 0, st
    assert st["cap"] == 2  # "on" never grows


def test_frontier_auto_grows_capacity():
    """frontier="auto" reacts to overflow fallbacks by doubling F (and the
    compile-cache-friendly growth is observable in the stats)."""
    stream = list(so_like(16, 120, seed=5))
    specs = [RegisteredQuery("q0", compile_query("(a2q | c2a | c2q)*"), 40.0)]
    g = BatchedDenseRPQEngine(specs, n_slots=24, batch_size=1,
                              frontier="auto", frontier_cap=2)
    for sgt in stream:
        g.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
    st = g.executor.frontier_stats
    assert st["cap"] > 2, st
    assert st["cap"] & (st["cap"] - 1) == 0  # still a power of two


def test_frontier_churn_and_growth_matches_dense():
    """Query churn (register/deregister mid-stream) + vertex-capacity
    growth + compaction with the frontier on: the result stream matches a
    dense engine driven identically."""
    rng = random.Random(7)
    base = [RegisteredQuery("q0", compile_query("a . b*"), 20.0),
            RegisteredQuery("q1", compile_query("(a | b)*"), 15.0)]
    late = RegisteredQuery("late", compile_query("b . c*"), 18.0)
    events = _random_events(rng, 40, 110, 90)  # 40 vertices > n_slots=16

    def drive(frontier):
        kw = (dict(frontier="auto", frontier_cap=4) if frontier else {})
        g = BatchedDenseRPQEngine(base, n_slots=16, batch_size=1, **kw)
        next_exp, out = 6.0, []
        for i, (op, u, v, lab, t) in enumerate(events):
            if t >= next_exp:
                g.expire(t)
                while next_exp <= t:
                    next_exp += 6.0
            if i == 40:
                out.append(("reg", frozenset(g.register_query(late))))
            if i == 80:
                g.deregister_query("q0")
                out.append(("dereg",))
            if op == "+":
                fresh = g.insert(u, v, lab, t)
            else:
                fresh = g.delete(u, v, lab, t)
            out.append(tuple(frozenset(s) for s in fresh))
        return g, out

    g_d, ev_d = drive(False)
    g_f, ev_f = drive(True)
    assert g_f.n_slots > 16  # growth actually happened
    _assert_streams_equal("churn", ev_d, ev_f)


@pytest.mark.parametrize("executor", ["local", "mesh"])
def test_service_frontier_matches_off(executor):
    """Service-level knob: frontier="auto" produces the same IngestReport
    stream as "off" (incl. deletions) and carries per-call frontier stats +
    the per-interval log."""
    stream = with_deletions(
        gmark_like(24, 110, LABELS[:3], seed=6, cyclicity=0.2),
        ratio=0.05, seed=2)

    def run(frontier):
        svc = PersistentQueryService(window=12.0, slide=3.0,
                                     executor=executor, frontier=frontier,
                                     frontier_cap=8)
        svc.register("arb", "a . b*", engine="dense", n_slots=32)
        svc.register("star", "(a | b)*", engine="dense", n_slots=32)
        rep = svc.ingest(stream)
        return svc, rep

    s_off, r_off = run("off")
    s_on, r_on = run("auto")
    assert dict(r_off) == dict(r_on)
    assert r_off.invalidated == r_on.invalidated
    assert s_off.results("arb") == s_on.results("arb")
    assert s_off.results("star") == s_on.results("star")
    assert r_off.frontier_stats == {}
    assert r_on.frontier_stats["dispatches"] > 0
    assert s_on.frontier_log  # per-interval telemetry recorded


def test_frontier_checkpoint_restore_identity(tmp_path):
    """Crash -> restore with the frontier on: the resumed result stream
    matches an uninterrupted frontier run AND an uninterrupted dense run
    (the frontier keeps no persistent state, so restore needs nothing new)."""
    stream = list(gmark_like(20, 80, LABELS[:3], seed=8, cyclicity=0.2))
    head, tail = stream[:40], stream[40:]

    def mk(frontier):
        svc = PersistentQueryService(window=15.0, slide=4.0,
                                     frontier=frontier, frontier_cap=8)
        svc.register("q", "a . b*", engine="dense", n_slots=32)
        return svc

    svc = mk("auto")
    svc.ingest(Stream(head))
    svc.snapshot(str(tmp_path), step=1)
    resumed = mk("auto")  # same registration, then adopt the snapshot
    resumed.restore(str(tmp_path))
    resumed.ingest(Stream(tail))

    oracle = mk("off")
    oracle.ingest(Stream(stream))
    assert resumed.results("q") == oracle.results("q")


def test_frontier_seed_and_pack_shapes():
    """Unit coverage for the jitted seed/pack helpers: base rows + reaching
    rows are dirty, inert lanes are not, overflow counts survive packing."""
    dist = jnp.full((2, 6, 6, 2), float("-inf"))
    # lane 0: row 3 reaches vertex 1 (a batch source below)
    dist = dist.at[0, 3, 1, 0].set(5.0)
    # lane 1 is inert (masked out)
    dist = dist.at[1, 2, 1, 0].set(5.0)
    src = jnp.asarray([1, 4], jnp.int32)
    smask = jnp.asarray([True, False])          # slot 4 is batch padding
    live = jnp.asarray([True, False])
    dirty = frontier_seed(dist, src, smask, live)
    assert dirty.shape == (2, 6)
    np.testing.assert_array_equal(
        np.asarray(dirty[0]), [False, True, False, True, False, False])
    assert not np.asarray(dirty[1]).any()       # inert lane never dirties
    rows, rowmask, cnt = pack_frontier(dirty, 1)  # F=1 < 2 dirty rows
    assert cnt.tolist() == [2, 0]
    assert rowmask.tolist() == [[True], [False]]
    assert rows[0, 0] == 1                      # first dirty row packed
    rows, rowmask, cnt = pack_frontier(dirty, 4)
    assert rows[0, :2].tolist() == [1, 3] and rowmask[0, :2].tolist() == [True, True]


def test_frontier_single_query_view():
    """DenseRPQEngine (the Q=1 view) passes the frontier kwargs through."""
    dfa = compile_query("a . b*")
    d = DenseRPQEngine(dfa, window=10.0, n_slots=16, batch_size=1)
    f = DenseRPQEngine(dfa, window=10.0, n_slots=16, batch_size=1,
                       frontier="on", frontier_cap=4)
    stream = list(gmark_like(10, 50, ["a", "b"], seed=3))
    for sgt in stream:
        assert d.insert(sgt.src, sgt.dst, sgt.label, sgt.ts) == \
            f.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
    assert isinstance(f.executor, LocalExecutor)
    assert f.executor.frontier == "on"


# ---------------------------------------------------------------------------
# PR 6: incremental (cone-restricted) deletions
# ---------------------------------------------------------------------------


def test_delete_cone_unit():
    """The invalidation cone is frontier_seed run against the PRE-delete
    state: rows reaching the deleted edge's source plus the source row
    itself (base-term derivations), inert lanes never dirty."""
    from repro.core.semiring import delete_cone

    dist = jnp.full((2, 6, 6, 2), float("-inf"))
    dist = dist.at[0, 3, 1, 0].set(5.0)         # row 3 reaches src slot 1
    dist = dist.at[1, 2, 1, 1].set(4.0)         # lane 1 is inert below
    src = jnp.asarray([1], jnp.int32)
    smask = jnp.asarray([True])
    live = jnp.asarray([True, False])
    cone = delete_cone(dist, src, smask, live)
    np.testing.assert_array_equal(
        np.asarray(cone[0]), [False, True, False, True, False, False])
    assert not np.asarray(cone[1]).any()


def test_delete_overflow_falls_back_dense():
    """A deletion whose cone overflows a tiny fixed capacity must take the
    in-dispatch dense fallback — results identical, fallback observable in
    the delete-split telemetry."""
    stream = list(with_deletions(so_like(16, 90, seed=3), ratio=0.2, seed=1))
    events = [(s.op, s.src, s.dst, s.label, s.ts) for s in stream]
    specs = [RegisteredQuery("q0", compile_query("(a2q | c2a)*"), 30.0),
             RegisteredQuery("q1", compile_query("a2q . c2a*"), 30.0)]

    def dense():
        return BatchedDenseRPQEngine(specs, n_slots=24, batch_size=1)

    def frontier():
        return BatchedDenseRPQEngine(specs, n_slots=24, batch_size=1,
                                     frontier="on", frontier_cap=2)

    _, ev_d = _drive(dense, events, 6.0, 2)
    g_f, ev_f = _drive(frontier, events, 6.0, 2)
    _assert_streams_equal("delete-overflow", ev_d, ev_f)
    st = g_f.executor.frontier_stats
    assert st["delete_dispatches"] > 0, st
    assert st["delete_fallbacks"] > 0, st


def test_delete_churned_group_padding_lanes_inert():
    """Regression: the delete decode must skip inert padding lanes. A
    churned group (register x2, deregister x1 mid-stream) leaves a hole —
    every delete's lane-indexed output must be empty there, and the live
    lanes' streams must match a dense-engine drive of the same schedule."""
    rng = random.Random(13)
    base = [RegisteredQuery("q0", compile_query("a . b*"), 20.0)]
    e0 = RegisteredQuery("e0", compile_query("(a | b)*"), 16.0)
    e1 = RegisteredQuery("e1", compile_query("b . c*"), 18.0)
    events = _random_events(rng, 12, 80, 70)

    def drive(frontier):
        kw = dict(frontier="auto", frontier_cap=4) if frontier else {}
        g = BatchedDenseRPQEngine(base, n_slots=16, batch_size=1, **kw)
        out = []
        for i, (op, u, v, lab, t) in enumerate(events):
            if i == 20:
                g.register_query(e0)
                g.register_query(e1)
            if i == 45:
                g.deregister_query("e0")    # lane becomes an inert hole
            res = (g.insert if op == "+" else g.delete)(u, v, lab, t)
            assert len(res) == g.q_cap
            live = sorted(qi for qi, _s in g.live_items())
            for qi, pairs in enumerate(res):
                if qi not in live:
                    assert not pairs, (i, qi, pairs)
            out.append((op,) + tuple(frozenset(res[qi]) for qi in live))
        return g, out

    g_d, ev_d = drive(False)
    g_f, ev_f = drive(True)
    assert any(s is None for s in g_f.lane_specs)   # the hole exists
    _assert_streams_equal("churn-delete", ev_d, ev_f)


def test_drain_pending_order_preserved():
    """Regression for the deque'd pending FIFO: resolving a LATER handle
    drains earlier handles first (dispatch order, so monotone dedup holds),
    stops at `upto`, and every chunk's fresh set matches a synchronous
    drive."""
    specs = [RegisteredQuery("q0", compile_query("a . b*"), 30.0)]
    g = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1)
    sync = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1)
    stream = list(gmark_like(10, 30, ["a", "b"], seed=4))
    chunks = [stream[:10], stream[10:20], stream[20:]]
    handles = [
        g.insert_batch_pending([(s.src, s.dst, s.label, s.ts) for s in c])
        for c in chunks
    ]
    expect = []
    for c in chunks:
        fresh = set()
        for s in c:
            fresh |= sync.insert(s.src, s.dst, s.label, s.ts)[0]
        expect.append(fresh)
    mid = handles[1].resolve()      # head must decode before the middle
    assert handles[0]._decoded and not handles[2]._decoded
    assert handles[0].resolve()[0] == expect[0]
    assert mid[0] == expect[1]
    assert handles[2].resolve()[0] == expect[2]


def test_frontier_healthy_gate():
    """adapt_batch's hold-B gate: only a LIVE interval with tiny occupancy
    and no overflow counts as healthy. Idle intervals (no dispatches, or
    occupancy None because zero dense-row-equivalent work ran) carry no
    signal and must NOT freeze batch adaptation."""
    h = PersistentQueryService._frontier_healthy
    assert not h({})
    assert not h({"dispatches": 0, "occupancy": 0.01})
    assert not h({"dispatches": 4, "occupancy": None})
    assert not h({"dispatches": 4, "occupancy": 0.5})
    assert not h({"dispatches": 4, "occupancy": 0.01, "fallbacks": 2})
    assert h({"dispatches": 4, "occupancy": 0.01, "fallbacks": 0})


def test_idle_interval_occupancy_is_none():
    """Regression: a slide interval with zero dense-row-equivalent work
    used to report occupancy 0.0, which the health check read as 'frontier
    healthy' and held B forever. Empty intervals now report None."""
    cur = {"mode": "auto", "cap": 8, "dispatches": 3, "fallbacks": 0,
           "rows_relaxed": 0, "dense_row_equiv": 0, "max_lane_rows": 0}
    delta = PersistentQueryService._stats_delta(cur, {})
    assert delta["occupancy"] is None
    assert not PersistentQueryService._frontier_healthy(delta)
    # a live interval still reports a ratio and can be healthy
    cur2 = dict(cur, rows_relaxed=5, dense_row_equiv=500)
    delta2 = PersistentQueryService._stats_delta(cur2, {})
    assert delta2["occupancy"] == 0.01
    assert PersistentQueryService._frontier_healthy(delta2)


def test_service_delete_batching_and_report():
    """Negative tuples ride the service's micro-batch path: the report
    counts them, invalidations match the per-event engine drive, and the
    frontier split telemetry surfaces delete dispatches."""
    stream = with_deletions(
        gmark_like(20, 90, LABELS[:3], seed=12, cyclicity=0.2),
        ratio=0.15, seed=5)
    n_del = sum(1 for s in stream if s.op == "-")
    assert n_del > 0
    svc = PersistentQueryService(window=12.0, slide=3.0, frontier="auto",
                                 frontier_cap=8)
    svc.register("q", "a . b*", engine="dense", n_slots=32)
    rep = svc.ingest(stream)
    assert rep.deletions == n_del
    assert rep.frontier_stats["delete_dispatches"] > 0
    oracle = PersistentQueryService(window=12.0, slide=3.0, frontier="off")
    oracle.register("q", "a . b*", engine="dense", n_slots=32)
    rep_o = oracle.ingest(stream)
    assert dict(rep) == dict(rep_o)
    assert rep.invalidated == rep_o.invalidated
    assert rep_o.deletions == n_del


def test_frontier_mode_validation():
    with pytest.raises(ValueError, match="frontier"):
        LocalExecutor("jnp", frontier="fast")
    with pytest.raises(ValueError):
        LocalExecutor("jnp", frontier_cap=0)
    with pytest.raises(ValueError, match="frontier"):
        PersistentQueryService(window=5.0, slide=1.0, frontier="frontier")
