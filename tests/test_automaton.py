"""Automaton pipeline tests: parser, Thompson NFA, DFA, Hopcroft, RSPQ meta."""
import re as pyre

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import regex as rx
from repro.core.automaton import compile_query


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------

def test_parse_paper_queries():
    # Table 2 of the paper
    qs = [
        "a*",
        "a . b*",
        "a . b* . c*",
        "(a1 + a2 + a3)*",
        "a . b* . c",
        "a* . b*",
        "a . b . c*",
        "a? . b*",
        "(a1 + a2 + a3)+",
        "(a1 + a2 + a3) . b*",
        "a1 . a2 . a3",
    ]
    for q in qs:
        ast = rx.parse(q)
        assert ast.size() >= 1


def test_parse_postfix_plus_vs_alternation():
    ast = rx.parse("a+")
    assert isinstance(ast, rx.Plus)
    ast = rx.parse("a + b")
    assert isinstance(ast, rx.Alt)
    ast = rx.parse("(a + b)+")
    assert isinstance(ast, rx.Plus)
    assert isinstance(ast.inner, rx.Alt)


def test_parse_juxtaposition_concat():
    ast = rx.parse("a b c")
    assert isinstance(ast, rx.Cat)


def test_query_size_metric():
    # |Q| counts labels plus * and + occurrences (paper §5.1.2)
    assert rx.parse("a . b* . c*").size() == 5
    assert rx.parse("a1 . a2 . a3").size() == 3


# ---------------------------------------------------------------------------
# DFA correctness vs Python's re on single-character label alphabets
# ---------------------------------------------------------------------------

def _to_pyre(expr: str) -> str:
    """Map our syntax to a python re for single-char labels."""
    out = expr.replace(" ", "").replace(".", "").replace("∘", "")
    # '+' between atoms is alternation in our syntax; in test exprs below we
    # only use '|' for alternation to keep the mapping unambiguous.
    return out


WORD_ALPHABET = "abc"

# expressions using '|' for alternation and '.' for concatenation so the
# mapping to python re (strip dots) is unambiguous
RE_CASES = [
    "a*",
    "a.b*",
    "a.b*.c*",
    "(a|b|c)*",
    "a.b*.c",
    "a*.b*",
    "a.b.c*",
    "a?.b*",
    "(a|b|c)+",
    "(a|b).c*",
    "a.b.c",
    "(a.b)+",
    "((a|b).c)*.a",
    "a.(b|c)*.a?",
]


@pytest.mark.parametrize("expr", RE_CASES)
def test_dfa_matches_python_re(expr):
    dfa = compile_query(expr)
    prog = pyre.compile(_to_pyre(expr) + r"\Z")
    # exhaustive words up to length 6
    from itertools import product
    for n in range(0, 7):
        for word in product(WORD_ALPHABET, repeat=n):
            w = "".join(word)
            assert dfa.accepts(list(word)) == bool(prog.match(w)), (expr, w)


@settings(max_examples=200, deadline=None)
@given(st.data())
def test_dfa_matches_python_re_random(data):
    expr = data.draw(st.sampled_from(RE_CASES))
    dfa = compile_query(expr)
    prog = pyre.compile(_to_pyre(expr) + r"\Z")
    word = data.draw(st.text(alphabet=WORD_ALPHABET, min_size=0, max_size=12))
    assert dfa.accepts(list(word)) == bool(prog.match(word))


def test_minimization_is_minimal_for_known_cases():
    # (follows . mentions)+ from Fig. 1(c): 3 states
    dfa = compile_query("(follows . mentions)+")
    assert dfa.k == 3
    assert dfa.start == 0
    # a*: single accepting state
    dfa = compile_query("a*")
    assert dfa.k == 1
    assert dfa.accepts_empty()
    # fixed-length concat: k = len + 1
    dfa = compile_query("a1 . a2 . a3")
    assert dfa.k == 4


def test_partial_dfa_has_no_dead_states():
    dfa = compile_query("a . b")
    # every state must reach a final state
    from repro.core.automaton import _coreachable
    co = _coreachable(dfa.delta, dfa.finals)
    assert set(range(dfa.k)) <= co


# ---------------------------------------------------------------------------
# suffix-language containment (Definitions 14-15)
# ---------------------------------------------------------------------------

def test_containment_star():
    # a*: single state, [0] ⊇ [0]
    dfa = compile_query("a*")
    assert dfa.containment[0, 0]
    assert dfa.has_containment_property


def test_containment_property_examples():
    # Restricted expressions (paper §5.5): Q1 a*, Q4 (a|b)*, Q11 a.b.c are
    # conflict-free on any graph; a* and (a|b)* have the containment property.
    assert compile_query("a*").has_containment_property
    assert compile_query("(a|b|c)*").has_containment_property
    # (follows.mentions)+ does NOT have it: [s1] and [s2] alternate.
    assert not compile_query("(a . b)+").has_containment_property


def test_containment_matrix_semantics():
    dfa = compile_query("a . b*")
    # state after 'a' accepts b^i; start accepts a b^i.
    # suffix language of accepting state = b*, of start = a b*.
    C = dfa.containment
    k = dfa.k
    assert C.shape == (k, k)
    # containment is reflexive
    assert all(C[i, i] for i in range(k))


def test_brute_force_containment_agreement():
    """Compare the product-construction containment with brute-force word
    enumeration on small automata."""
    from itertools import product as iproduct
    for expr in ["a . b*", "(a . b)+", "a* . b*", "a? . b*", "(a|b) . c*"]:
        dfa = compile_query(expr)
        words = [list(w) for n in range(0, 6) for w in iproduct(dfa.labels, repeat=n)]

        def suffix_lang(s):
            acc = set()
            for w in words:
                cur = s
                ok = True
                for ch in w:
                    cur = dfa.step(cur, ch)
                    if cur < 0:
                        ok = False
                        break
                if ok and cur in dfa.finals:
                    acc.add(tuple(w))
            return acc

        langs = [suffix_lang(s) for s in range(dfa.k)]
        for s in range(dfa.k):
            for t in range(dfa.k):
                brute = langs[s] >= langs[t]
                if dfa.containment[s, t]:
                    # claimed containment must hold on sampled words
                    assert brute, (expr, s, t)
                else:
                    # claimed non-containment must have a witness within
                    # bounded length for these tiny automata
                    assert not brute, (expr, s, t)
