"""Live query lifecycle conformance: registering / deregistering dense
queries AFTER ingestion has started (PR 2 tentpole).

Oracle construction for a query registered mid-stream:
`engine.make_churn_oracle` (shared with benchmarks/fig13_query_churn) — a
freshly built engine, clock-synced then fed the live group's retained
graph as one batch, then the tail per-tuple. Surviving queries are instead
held to their own uninterrupted history: Q independent engines replay the
FULL stream and every event's fresh results must match tuple-for-tuple
(churn of other queries must not perturb a member's stream).
"""
import random

import pytest

from repro.core import compile_query
from repro.core.engine import (
    BatchedDenseRPQEngine,
    DenseRPQEngine,
    RegisteredQuery,
    make_churn_oracle,
)
from repro.streaming.stream import SGT, Stream
from repro.streaming.service import PersistentQueryService

QUERIES = ["a*", "a . b*", "(a | b)*", "a . b* . c", "(a . b)+", "a . b . c"]
LABELS = ["a", "b", "c"]


def _random_stream(rng, n_vertices, n_edges, t_max):
    ts = sorted(rng.sample(range(1, t_max), k=min(n_edges, t_max - 1)))
    return [
        (rng.randrange(n_vertices), rng.randrange(n_vertices),
         rng.choice(LABELS), float(t))
        for t in ts
    ]


def _oracle_for(dfa, semantics, live_group, window, n_slots):
    return make_churn_oracle(dfa, live_group, window, n_slots,
                             path_semantics=semantics)


@pytest.mark.parametrize("seed", range(3))
def test_register_mid_stream_matches_fresh_oracle(seed):
    rng = random.Random(seed)
    window = 15.0
    base = [RegisteredQuery("q0", compile_query("a . b*"), window),
            RegisteredQuery("q1", compile_query("(a | b)*"), window)]
    group = BatchedDenseRPQEngine(base, n_slots=16, batch_size=1)
    indep = [DenseRPQEngine(s.dfa, window, n_slots=16, batch_size=1)
             for s in base]
    stream = _random_stream(rng, 6, 30, 80)
    cut = 15
    for i, (u, v, lab, ts) in enumerate(stream[:cut]):
        fresh = group.insert(u, v, lab, ts)
        for qi, eng in enumerate(indep):
            assert fresh[qi] == eng.insert(u, v, lab, ts), (seed, i, qi)
        if i % 7 == 6:
            group.expire(ts)
            for eng in indep:
                eng.expire(ts)

    dfa_new = compile_query("a*")
    oracle, oseed = _oracle_for(dfa_new, "arbitrary", group, window, 16)
    initial = group.register_query(RegisteredQuery("late", dfa_new, window))
    lane = group.lane_of("late")
    # the initial answer over the live window == the fresh oracle's seed
    assert initial == oseed, seed
    assert group.current_results(lane) == oracle.current_results()

    for i, (u, v, lab, ts) in enumerate(stream[cut:]):
        fresh = group.insert(u, v, lab, ts)
        assert fresh[lane] == oracle.insert(u, v, lab, ts), (seed, i)
        for qi, eng in enumerate(indep):
            # survivors: unperturbed by the arrival
            assert fresh[qi] == eng.insert(u, v, lab, ts), (seed, i, qi)
        if i % 7 == 6:
            group.expire(ts)
            oracle.expire(ts)
            for eng in indep:
                eng.expire(ts)
    assert group.per_query_results[lane] == oracle.results
    for qi, eng in enumerate(indep):
        assert group.per_query_results[qi] == eng.results


def test_deregister_keeps_survivors_and_reclaims_lane():
    rng = random.Random(7)
    window = 20.0
    specs = [RegisteredQuery(f"q{i}", compile_query(e), window)
             for i, e in enumerate(QUERIES[:3])]
    group = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1)
    indep = {i: DenseRPQEngine(s.dfa, window, n_slots=16, batch_size=1)
             for i, s in enumerate(specs)}
    stream = _random_stream(rng, 6, 30, 90)
    for (u, v, lab, ts) in stream[:12]:
        fresh = group.insert(u, v, lab, ts)
        for qi, eng in indep.items():
            assert fresh[qi] == eng.insert(u, v, lab, ts)

    cap_before = group.q_cap
    group.deregister_query("q1")
    del indep[1]
    assert group.n_queries == 2
    assert group.q_cap == cap_before          # capacity never shrinks
    assert group.current_results(1) == set()  # inert lane answers nothing

    for (u, v, lab, ts) in stream[12:20]:
        fresh = group.insert(u, v, lab, ts)
        assert fresh[1] == set()              # inert lane stays silent
        for qi, eng in indep.items():
            assert fresh[qi] == eng.insert(u, v, lab, ts)

    # re-registration reclaims the freed lane (no Q growth)
    dfa_new = compile_query("b . a*")
    oracle, oseed = _oracle_for(dfa_new, "arbitrary", group, window, 16)
    initial = group.register_query(RegisteredQuery("q3", dfa_new, window))
    assert group.lane_of("q3") == 1
    assert group.q_cap == cap_before
    assert initial == oseed
    for (u, v, lab, ts) in stream[20:]:
        fresh = group.insert(u, v, lab, ts)
        assert fresh[1] == oracle.insert(u, v, lab, ts)
        for qi, eng in indep.items():
            assert fresh[qi] == eng.insert(u, v, lab, ts)
    assert group.per_query_results[1] == oracle.results


def test_q_axis_bucket_growth():
    """Growing past the allocated lanes buckets the Q axis to the next
    multiple of 4; further registrations reclaim the padding lanes without
    reallocating."""
    window = 30.0
    group = BatchedDenseRPQEngine(
        [RegisteredQuery("q0", compile_query("a*"), window)],
        n_slots=8, batch_size=1)
    assert group.q_cap == 1
    group.insert(0, 1, "a", 1.0)
    group.register_query(RegisteredQuery("q1", compile_query("a . b*"), window))
    assert group.q_cap == 4                   # bucketed growth
    assert group.batched_arrays.dist.shape[0] == 4
    for i in range(2):
        group.register_query(
            RegisteredQuery(f"q{2 + i}", compile_query("b*"), window))
        assert group.q_cap == 4               # padding lanes reclaimed
    group.register_query(RegisteredQuery("q4", compile_query("(a|b)*"), window))
    assert group.q_cap == 8
    # all five queries answer; K grew to the deepest member
    assert group.n_queries == 5
    fresh = group.insert(1, 2, "b", 2.0)     # 0 -a-> 1 -b-> 2
    assert fresh[group.lane_of("q1")] == {(0, 2)}


def test_register_with_new_label_grows_alphabet():
    """A late query can bring labels outside the current union alphabet:
    the label axis grows append-only (existing adjacency rows keep their
    index) and the ×4-rounded label slots absorb small growth."""
    window = 50.0
    group = BatchedDenseRPQEngine(
        [RegisteredQuery("q0", compile_query("a*"), window)],
        n_slots=8, batch_size=1)
    group.insert(0, 1, "a", 1.0)
    assert group.batched_arrays.adj.shape[0] == 4  # 1 label, 4 slots
    group.register_query(
        RegisteredQuery("qd", compile_query("d . a*"), window))
    assert group.labels == ("a", "d")              # append-only
    lane = group.lane_of("qd")
    fresh = group.insert(5, 0, "d", 2.0)
    assert fresh[lane] == {(5, 0), (5, 1)}
    # grow past the 4 label slots
    group.register_query(
        RegisteredQuery("qmany", compile_query("e | f | g | h"), window))
    assert group.labels == ("a", "d", "e", "f", "g", "h")
    assert group.batched_arrays.adj.shape[0] == 8
    fresh = group.insert(7, 8, "g", 3.0)
    assert fresh[group.lane_of("qmany")] == {(7, 8)}
    # original query still answers over its own alphabet
    assert group.current_results(0) == {(0, 1)}


@pytest.mark.parametrize("seed", range(4))
def test_churn_conformance_randomized(seed):
    """Randomized streams with deletions and expiry, both path semantics:
    register + deregister mid-stream; survivors must match uninterrupted
    independent engines tuple-for-tuple, late queries their fresh-group
    oracles (insert, delete and snapshot views)."""
    rng = random.Random(100 + seed)
    window = rng.choice([10.0, 20.0, 40.0])
    specs = []
    for qi in range(3):
        expr = rng.choice(QUERIES)
        dfa = compile_query(expr)
        semantics = "arbitrary"
        if dfa.has_containment_property and rng.random() < 0.4:
            semantics = "simple"
        specs.append(RegisteredQuery(f"q{qi}", dfa, window, semantics))
    group = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1)
    indep = {qi: DenseRPQEngine(s.dfa, window, n_slots=16, batch_size=1,
                                path_semantics=s.path_semantics)
             for qi, s in enumerate(specs)}
    oracles = {}  # lane -> oracle engine for late registrations

    stream = _random_stream(rng, n_vertices=6, n_edges=26, t_max=70)
    live = {}
    events = []
    for (u, v, lab, ts) in stream:
        if live and rng.random() < 0.2:
            du, dv, dl = rng.choice(sorted(live))
            del live[(du, dv, dl)]
            events.append(("-", du, dv, dl, ts))
        else:
            live[(u, v, lab)] = ts
            events.append(("+", u, v, lab, ts))

    def lifecycle(step):
        if step == 8:
            expr = rng.choice(QUERIES)
            dfa = compile_query(expr)
            semantics = ("simple" if dfa.has_containment_property
                         and rng.random() < 0.5 else "arbitrary")
            oracle, oseed = _oracle_for(dfa, semantics, group, window, 16)
            initial = group.register_query(
                RegisteredQuery("late1", dfa, window, semantics))
            assert initial == oseed, (seed, expr)
            oracles[group.lane_of("late1")] = oracle
        elif step == 14:
            group.deregister_query("q1")
            del indep[1]
        elif step == 20:
            dfa = compile_query(rng.choice(QUERIES))
            oracle, oseed = _oracle_for(dfa, "arbitrary", group, window, 16)
            initial = group.register_query(
                RegisteredQuery("late2", dfa, window))
            lane = group.lane_of("late2")
            assert lane == 1, seed  # reclaimed the deregistered lane
            assert initial == oseed, seed
            oracles[lane] = oracle

    for i, (op, u, v, lab, ts) in enumerate(events):
        lifecycle(i)
        if op == "+":
            fresh = group.insert(u, v, lab, ts)
            for qi, eng in indep.items():
                assert fresh[qi] == eng.insert(u, v, lab, ts), (seed, i, qi)
            for lane, oracle in oracles.items():
                assert fresh[lane] == oracle.insert(u, v, lab, ts), (seed, i, lane)
        else:
            inv = group.delete(u, v, lab, ts)
            for qi, eng in indep.items():
                assert inv[qi] == eng.delete(u, v, lab, ts), (seed, i, qi)
            for lane, oracle in oracles.items():
                assert inv[lane] == oracle.delete(u, v, lab, ts), (seed, i, lane)
        if i % 7 == 6:
            group.expire(ts)
            for eng in indep.values():
                eng.expire(ts)
            for oracle in oracles.values():
                oracle.expire(ts)
        if i % 9 == 8:
            for qi, eng in indep.items():
                assert group.current_results(qi) == eng.current_results()
            for lane, oracle in oracles.items():
                assert group.current_results(lane) == oracle.current_results()

    for qi, eng in indep.items():
        assert group.per_query_results[qi] == eng.results, (seed, qi)
    for lane, oracle in oracles.items():
        assert group.per_query_results[lane] == oracle.results, (seed, lane)


def test_convergence_masking_reduces_query_rounds():
    """Mixed-depth group: the shallow query converges (and is masked out)
    rounds before the deep Kleene-star member, so the summed per-query
    active rounds sit strictly below the unmasked Q x global-rounds regime
    — with identical result streams."""
    window = 100.0
    specs = [RegisteredQuery("deep", compile_query("a*"), window),
             RegisteredQuery("shallow", compile_query("b"), window)]
    group = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=1)
    indep = [DenseRPQEngine(s.dfa, window, n_slots=16, batch_size=1)
             for s in specs]
    edges = [(i, i + 1, "a", float(i + 1)) for i in range(10)]
    edges.append((0, 1, "b", 11.0))
    for (u, v, lab, ts) in edges:
        fresh = group.insert(u, v, lab, ts)
        for qi, eng in enumerate(indep):
            assert fresh[qi] == eng.insert(u, v, lab, ts)
    for qi, eng in enumerate(indep):
        assert group.per_query_results[qi] == eng.results
    assert group.total_query_rounds < group.n_queries * group.total_rounds, (
        group.total_query_rounds, group.total_rounds)


def test_service_live_lifecycle_and_invalidations():
    """Service level: live register answers immediately, deregister retires
    cleanly, and ingest() surfaces deletion invalidations alongside the new
    results (satellite fix: they were computed and discarded)."""
    svc = PersistentQueryService(window=100.0, slide=50.0)
    svc.register("d", "a . a*", engine="dense", n_slots=16)
    svc.register("r", "a . a*", engine="reference")
    rep = svc.ingest(Stream([SGT(1.0, 1, 2, "a"), SGT(2.0, 2, 3, "a")]))
    assert rep["d"] == {(1, 2), (2, 3), (1, 3)} == rep["r"]
    assert rep.invalidated["d"] == set() == rep.invalidated["r"]

    rep2 = svc.ingest(Stream([SGT(3.0, 2, 3, "a", "-")]))
    assert rep2["d"] == set()
    assert rep2.invalidated["d"] == {(2, 3), (1, 3)}
    assert rep2.invalidated["r"] == {(2, 3), (1, 3)}

    # live registration: initial answers over the retained window
    initial = svc.register("late", "a", engine="dense")
    assert initial == {(1, 2)}
    assert svc.results("late") == {(1, 2)}

    rep3 = svc.ingest(Stream([SGT(4.0, 3, 4, "a")]))
    assert rep3["late"] == {(3, 4)}

    svc.deregister("late")
    with pytest.raises(KeyError):
        svc.results("late")
    rep4 = svc.ingest(Stream([SGT(5.0, 4, 5, "a")]))
    assert rep4["late"] == set()          # history name stays, stream is dead
    assert (4, 5) in rep4["d"]            # survivors keep flowing
    assert svc.results("r") == svc.results("d")


def test_first_dense_registration_mid_stream_starts_tracking():
    """The FIRST dense query arriving after ingestion started has no dense
    group to seed from (prefix content was only seen by reference engines):
    it is materialized EMPTY at registration — no silent deferral to the
    next ingest — and answers from that point of the stream on."""
    svc = PersistentQueryService(window=100.0, slide=50.0)
    svc.register("r", "a", engine="reference")
    svc.ingest(Stream([SGT(1.0, 1, 2, "a")]))
    initial = svc.register("late", "a", engine="dense", n_slots=16)
    assert initial == set()                 # nothing dense-side to seed from
    group = svc.queries["late"]
    assert group is not None and group.n_queries == 1  # live immediately
    rep = svc.ingest(Stream([SGT(2.0, 3, 4, "a")]))
    assert rep["late"] == {(3, 4)}
    assert svc.results("r") == {(1, 2), (3, 4)}
    # a SECOND dense query joins the (now existing) group seeded: it sees
    # the retained window including the edge the first one tracked
    initial2 = svc.register("late2", "a", engine="dense")
    assert initial2 == {(3, 4)}


def test_reregistered_name_keeps_stats_history():
    """deregister() promises the stats entry stays as history; re-using the
    name must not clobber it."""
    svc = PersistentQueryService(window=100.0, slide=50.0)
    svc.register("d", "a", engine="dense", n_slots=16)
    svc.ingest(Stream([SGT(1.0, 1, 2, "a")]))
    assert svc.stats["d"].tuples == 1
    svc.deregister("d")
    assert svc.stats["d"].tuples == 1       # history kept
    svc.register("d", "a . a*", engine="dense")
    assert svc.stats["d"].tuples == 1       # reuse does not reset history
    svc.ingest(Stream([SGT(2.0, 2, 3, "a")]))
    assert svc.stats["d"].tuples == 2


def test_service_checkpoint_records_live_query_set():
    """The manifest records the live query set lane-by-lane (None = inert
    padding), inspectable without restoring arrays."""
    import tempfile

    from repro.checkpoint import ckpt

    svc = PersistentQueryService(window=50.0, slide=10.0)
    svc.register("q0", "a*", engine="dense", n_slots=16)
    svc.ingest(Stream([SGT(1.0, 0, 1, "a")]))
    svc.register("q1", "a . b*", engine="dense")   # grows Q to a bucket of 4
    svc.deregister("q0")
    with tempfile.TemporaryDirectory() as d:
        svc.snapshot(d, step=3)
        extra = ckpt.manifest_extra(d)
        lanes = extra["dense"]["order"]
        assert lanes[1] == "q1" and lanes[0] is None
        assert extra["dense"]["labels"] == ["a", "b"]
        # restore into a differently-laid-out fresh service: matches by name
        svc2 = PersistentQueryService(window=50.0, slide=10.0)
        svc2.register("q1", "a . b*", engine="dense", n_slots=16)
        assert svc2.restore(d) == 3
        assert svc2.results("q1") == svc.results("q1")
