"""Suite-wide hygiene.

Compiled executables accumulate address-space mappings for as long as
jax's jit caches hold them — across a full tier-1 run that growth is
linear in the number of distinct compiled shapes (~30k maps and rising
as suites are added), and `vm.max_map_count` defaults to 65530. Once
the ceiling is hit, the next `pthread_create` fails with
"can't start new thread" in whatever test happens to run late in the
session. Tests never share compiled shapes across module boundaries,
so dropping the caches between modules keeps the map count bounded
without losing warm-cache speed within a module.

The import is lazy and guarded: test modules that deliberately never
import jax (the analyzer suite runs pure-stdlib) stay jax-free when
run on their own.
"""
import sys

import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax = sys.modules.get("jax")
    if jax is not None:
        jax.clear_caches()
