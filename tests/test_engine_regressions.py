"""Regression tests for the PR-2 satellite bugfixes (each constructed to
fail against the pre-fix engine):

1. stream-clock lag on MIXED chunks: out-of-alphabet timestamps advanced
   `now` only when the whole chunk was skipped, so read-time window
   validity (`now - windows`) in a mixed chunk was computed against a
   stale clock;
2. mid-chunk compaction eviction: `_slot()` could trigger `compact()`
   while a chunk was being packed, and a vertex interned earlier in the
   same chunk (no adjacency entries yet) looked dead and was recycled —
   its slot handed to a different vertex before the edge landed;
3. interner checkpoint round-trip type guessing: string vertex ids like
   "42" came back as int 42 after restore (and tuple vertices did not
   survive at all), breaking crash -> restore -> identical-result-stream.
"""
import json
import tempfile

from repro.core import compile_query
from repro.core.engine import DenseRPQEngine
from repro.streaming.service import PersistentQueryService
from repro.streaming.stream import SGT, Stream


# -- 1. stream clock on mixed chunks ----------------------------------------


def test_mixed_chunk_advances_stream_clock():
    """A chunk mixing in-alphabet and out-of-alphabet tuples must advance
    `now` from ALL event timestamps: the trailing foreign tuple at t=100
    pushes every older pair out of the window, so the chunk's own
    evaluation reports nothing."""
    eng = DenseRPQEngine(compile_query("a"), window=5.0, n_slots=8,
                         batch_size=4)
    eng.insert(0, 1, "a", 1.0)
    assert eng.current_results() == {(0, 1)}
    fresh = eng.insert_batch([(2, 3, "a", 2.0), (7, 8, "zz", 100.0)])
    assert float(eng.arrays.now) == 100.0
    assert fresh == set()          # (2, 3)@2 expired at the chunk boundary
    assert eng.current_results() == set()


def test_whole_chunk_skipped_still_advances_clock():
    """The already-working path (every tuple out-of-alphabet) keeps
    working."""
    eng = DenseRPQEngine(compile_query("a"), window=5.0, n_slots=8,
                         batch_size=4)
    eng.insert(0, 1, "a", 1.0)
    eng.insert_batch([(7, 8, "zz", 50.0), (8, 9, "yy", 60.0)])
    assert float(eng.arrays.now) == 60.0
    assert eng.current_results() == set()


# -- 2. mid-chunk compaction pinning -----------------------------------------


def test_mid_chunk_compaction_preserves_chunk_vertices():
    """n_slots=2, one stale vertex: packing edge (u, v) interns u into the
    last free slot, then interning v triggers compact(). u has no adjacency
    yet — pre-fix it was recycled as dead and v took its slot, turning the
    edge into a (v, v) self-loop and dropping u from the interner."""
    eng = DenseRPQEngine(compile_query("a"), window=5.0, n_slots=2,
                         batch_size=4)
    eng.insert("x", "x", "a", 1.0)
    # advance the stream clock past x's window without recycling slots
    # (a no-op negative tuple for an unknown vertex only bumps `now`)
    eng.delete("ghost", "ghost", "a", 40.0)
    fresh = eng.insert_batch([("u", "v", "a", 50.0)])
    assert set(eng.slot_of) == {"u", "v"}
    assert fresh == {("u", "v")}
    assert eng.current_results() == {("u", "v")}


def test_chunk_overflow_compaction_multi_edge_chunk():
    """Multi-edge chunk at tiny n_slots: compaction fires while an earlier
    edge of the SAME chunk is already packed; its endpoints (and the
    just-interned vertex) stay pinned until the chunk lands."""
    eng = DenseRPQEngine(compile_query("a+"), window=5.0, n_slots=3,
                         batch_size=8)
    eng.insert("o1", "o2", "a", 1.0)
    eng.delete("ghost", "ghost", "a", 40.0)   # expire o1/o2 by clock only
    # chunk interns p (last free slot), then q -> compact() fires with p
    # adjacency-less; then r reuses a recycled slot
    fresh = eng.insert_batch([("p", "q", "a", 50.0), ("q", "r", "a", 51.0)])
    assert set(eng.slot_of) == {"p", "q", "r"}
    assert eng.current_results() == {("p", "q"), ("q", "r"), ("p", "r")}
    assert fresh == eng.current_results()


# -- 3. interner checkpoint round-trip types ---------------------------------


def test_interner_state_preserves_vertex_types():
    """"42" (str), 42 (int), and a tuple id must all survive the JSON
    manifest round trip with their exact types and slots."""
    eng = DenseRPQEngine(compile_query("a"), window=100.0, n_slots=8,
                         batch_size=1)
    eng.insert("42", 42, "a", 1.0)
    eng.insert(("p", 7), "x", "a", 2.0)
    state = json.loads(json.dumps(eng.interner_state()))  # manifest trip
    eng2 = DenseRPQEngine(compile_query("a"), window=100.0, n_slots=8,
                          batch_size=1)
    eng2.load_interner(state)
    assert eng2.slot_of == eng.slot_of
    assert set(eng2.slot_of) == {"42", 42, ("p", 7), "x"}
    assert eng2.vertex_of == eng.vertex_of
    assert sorted(eng2.free) == sorted(eng.free)


def test_legacy_untyped_interner_still_loads():
    """v1 manifests (flat str->slot dict) keep loading via the old
    type-guessing path — including streams whose vertices are literally
    named "format"/"entries" (v2 detection must not be fooled: v1 values
    are int slots, never a list)."""
    eng = DenseRPQEngine(compile_query("a"), window=100.0, n_slots=8,
                         batch_size=1)
    eng.load_interner({"7": 0, "name": 1})
    assert eng.slot_of == {7: 0, "name": 1}
    eng.load_interner({"format": 2, "entries": 3})
    assert eng.slot_of == {"format": 2, "entries": 3}


def test_results_state_roundtrip_tuple_and_numeric_string_vertices():
    eng = DenseRPQEngine(compile_query("a"), window=100.0, n_slots=8,
                         batch_size=1)
    eng.insert("42", ("p", 7), "a", 1.0)
    eng.insert(42, "42", "a", 2.0)
    assert eng.results == {("42", ("p", 7)), (42, "42")}
    state = json.loads(json.dumps(eng.results_state()))
    eng2 = DenseRPQEngine(compile_query("a"), window=100.0, n_slots=8,
                          batch_size=1)
    eng2.load_results_state(state)
    assert eng2.results == eng.results


def test_restore_numeric_string_vertices_identical_stream():
    """Service-level crash -> restore with NUMERIC-STRING vertex ids: the
    re-attached run must produce the identical result stream (pre-fix the
    restored interner held int 42 where the stream carries "42", so tail
    edges re-interned fresh slots and the streams diverged)."""
    tuples = [SGT(float(t), str(u), str(v), "a")
              for t, (u, v) in enumerate(
                  [(1, 2), (2, 3), (3, 4), (4, 5), (2, 6), (6, 7), (7, 2)],
                  start=1)]
    half = 4

    def make():
        svc = PersistentQueryService(window=100.0, slide=10.0)
        svc.register("q", "a . a*", engine="dense", n_slots=16)
        return svc

    svc = make()
    svc.ingest(Stream(tuples[:half]))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc.snapshot(ckpt_dir, step=half)
        tail_new = svc.ingest(Stream(tuples[half:]))
        svc2 = make()
        assert svc2.restore(ckpt_dir) == half
        group = svc2.queries["q"]
        assert all(isinstance(v, str) for v in group.slot_of)
        tail_new2 = svc2.ingest(Stream(tuples[half:]))
        assert tail_new2["q"] == tail_new["q"]
        assert svc2.results("q") == svc.results("q")
