"""Table 1 complexity checks + paper-behaviour micro-validations that are
cheap enough for the default suite (the heavier scaling test lives in
test_system.py)."""
import random

from repro.core import RAPQ, compile_query
from repro.streaming.generators import gmark_like


def test_insert_is_amortized_subquadratic_in_k():
    """Amortized per-tuple cost is O(n*k^2): doubling k must not blow up
    per-tuple work by more than ~4x (+ constant factors)."""
    labels = ["a", "b"]
    stream = gmark_like(48, 600, labels, seed=1, cyclicity=0.3)

    def work(expr):
        dfa = compile_query(expr)
        eng = RAPQ(dfa, window=50.0)
        # count Insert invocations via tree sizes as a proxy for work
        for sgt in stream:
            eng.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
        _trees, nodes = eng.index_size()
        return dfa.k, nodes

    k1, n1 = work("a . b")          # k = 3
    k2, n2 = work("a . b . a . b . a . b")  # k = 7
    assert k2 > k1
    # index population grows at most ~linearly with k (nodes <= n*k)
    assert n2 <= (k2 / k1) * n1 * 3 + 100


def test_monotone_timestamps_invariant():
    """Lemma 1 invariant: stored node timestamps never exceed any ancestor's
    (bottleneck consistency) after arbitrary interleavings."""
    rng = random.Random(5)
    dfa = compile_query("(a | b)*")
    eng = RAPQ(dfa, window=40.0)
    for i in range(300):
        u, v = rng.randrange(10), rng.randrange(10)
        eng.insert(u, v, rng.choice(["a", "b"]), float(i))
        if i % 37 == 36:
            eng.expire(float(i))
    for tree in eng.delta.values():
        for occ in tree.index.values():
            if occ.parent is not None:
                assert occ.ts <= occ.parent.ts + 1e-9


def test_suffix_containment_transitivity():
    """[s] ⊇ [t] and [t] ⊇ [r] implies [s] ⊇ [r] — sanity of the product
    construction used for conflict detection."""
    for expr in ["a . b*", "(a . b)+", "a* . b*", "a? . b*"]:
        dfa = compile_query(expr)
        C = dfa.containment
        k = dfa.k
        for s in range(k):
            for t in range(k):
                for r in range(k):
                    if C[s, t] and C[t, r]:
                        assert C[s, r], (expr, s, t, r)
