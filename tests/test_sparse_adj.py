"""Blocked-sparse (padded-ELL) adjacency conformance.

The ELL layout must be BIT-identical to the dense slab — per event, on
both executors, under all three contraction backends, with the frontier
on and off, through deletions, expiry, per-row degree overflow (spill
ring + ×2 ``ell_cap`` growth), capacity growth, and checkpoints in both
directions. The dense layout is the oracle: every stored edge is folded
with the same (max, min) semantics wherever it lives (row slot or spill
ring), and free slots / stale duplicates annihilate under the max fold
(see core/sparse_adj.py).

The mesh legs run on whatever devices this process has (the CI
sparse-adjacency leg re-runs this file under
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the u-row ELL
shards compose with lane sharding).
"""
import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import compile_query
from repro.core.backend import BucketBackend, PallasBackend
from repro.core.engine import BatchedDenseRPQEngine, RegisteredQuery
from repro.core.executor import LocalExecutor
from repro.core.semiring import NEG_INF, frontier_seed, frontier_seed_gathered
from repro.core.sparse_adj import (
    EllAdjacency,
    ell_delete,
    ell_expire,
    ell_incident,
    ell_insert,
    ell_max_degree,
    ell_to_dense,
    pack_ell,
)
from repro.distributed.executor import MeshExecutor
from repro.kernels.ell import (
    ell_gather_contract,
    ell_gather_contract_naive,
    ell_gather_contract_ref,
)
from repro.streaming.service import PersistentQueryService

QUERIES = ["a*", "a . b*", "(a | b)*", "a . b* . c", "(a . b)+", "a . b . c"]
LABELS = ["a", "b", "c"]


# -- unit: pack / densify / mutate ------------------------------------------


def _random_dense(rng, l=3, n=10, density=0.15):
    adj = np.full((l, n, n), NEG_INF, np.float32)
    for _ in range(int(l * n * n * density)):
        adj[rng.randrange(l), rng.randrange(n), rng.randrange(n)] = float(
            rng.randrange(1, 50))
    return adj


@pytest.mark.parametrize("seed", range(4))
def test_pack_densify_round_trip(seed):
    rng = random.Random(seed)
    adj = _random_dense(rng)
    cap = int(max((adj > NEG_INF).sum(axis=-1).max(), 1)) * 2
    ell = pack_ell(adj, cap, 16)
    np.testing.assert_array_equal(np.asarray(ell_to_dense(ell)), adj)
    assert int(ell_max_degree(ell)) == int((adj > NEG_INF).sum(axis=-1).max())


def test_pack_rejects_overfull_rows():
    adj = np.full((1, 4, 4), 5.0, np.float32)  # degree 4 everywhere
    with pytest.raises(ValueError):
        pack_ell(adj, 2, 8)  # degree > cap: pack never spills, it raises
    pack_ell(adj, 4, 8)  # degree == cap fits exactly


@pytest.mark.parametrize("seed", range(3))
def test_insert_delete_expire_match_dense_ops(seed):
    """Each mutation primitive equals its dense-slab counterpart after
    densify — including per-row overflow into the spill ring."""
    rng = random.Random(seed)
    l, n, cap = 2, 8, 2  # tiny cap so inserts overflow rows
    dense = np.full((l, n, n), NEG_INF, np.float32)
    ell = pack_ell(dense, cap, 32)
    for step in range(60):
        u, v, lab = rng.randrange(n), rng.randrange(n), rng.randrange(l)
        t = float(step + 1)
        op = rng.random()
        if op < 0.6:
            dense[lab, u, v] = max(dense[lab, u, v], t)
            ell = ell_insert(ell, jnp.asarray([u]), jnp.asarray([v]),
                             jnp.asarray([lab]), jnp.asarray([t], jnp.float32),
                             jnp.asarray([True]))
        elif op < 0.8:
            dense[lab, u, v] = NEG_INF
            ell = ell_delete(ell, jnp.asarray([u]), jnp.asarray([v]),
                             jnp.asarray([lab]), jnp.asarray([True]))
        else:
            low = t - 20.0
            dense[dense <= low] = NEG_INF
            ell = ell_expire(ell, jnp.asarray(low, jnp.float32))
        np.testing.assert_array_equal(np.asarray(ell_to_dense(ell)), dense,
                                      err_msg=f"step {step}")
    inc_dense = np.maximum(dense.max(axis=(0, 2)), dense.max(axis=(0, 1)))
    np.testing.assert_array_equal(np.asarray(ell_incident(ell)), inc_dense)
    assert int(ell.spill_ptr) > 0, "tiny cap should have exercised the ring"


# -- unit: gather-contract kernel vs densified oracle -----------------------


@pytest.mark.parametrize("seed", range(3))
def test_gather_contract_matches_naive(seed):
    rng = np.random.default_rng(seed)
    j, m, u, e = 2, 5, 12, 3
    d = np.where(rng.random((j, m, u)) < 0.4,
                 rng.integers(1, 40, (j, m, u)).astype(np.float32), NEG_INF)
    idx = rng.integers(0, u, (j, u, e)).astype(np.int32)
    ts = np.where(rng.random((j, u, e)) < 0.5,
                  rng.integers(1, 40, (j, u, e)).astype(np.float32), NEG_INF)
    want = ell_gather_contract_naive(jnp.asarray(d[0]), jnp.asarray(idx[0]),
                                     jnp.asarray(ts[0]))
    got_ref = ell_gather_contract_ref(jnp.asarray(d[0]), jnp.asarray(idx[0]),
                                      jnp.asarray(ts[0]))
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want))
    got_pl = ell_gather_contract(jnp.asarray(d), jnp.asarray(idx),
                                 jnp.asarray(ts), use_pallas=True,
                                 interpret=True)
    for ji in range(j):
        want_j = ell_gather_contract_naive(
            jnp.asarray(d[ji]), jnp.asarray(idx[ji]), jnp.asarray(ts[ji]))
        np.testing.assert_array_equal(np.asarray(got_pl[ji]),
                                      np.asarray(want_j))


def test_gathered_seed_matches_dense_seed():
    rng = np.random.default_rng(0)
    q, n, k, b = 3, 9, 4, 5
    dist = jnp.where(jnp.asarray(rng.random((q, n, n, k)) < 0.3),
                     jnp.asarray(rng.integers(1, 30, (q, n, n, k)),
                                 jnp.float32), NEG_INF)
    src = jnp.asarray(rng.integers(0, n, (b,)), jnp.int32)
    smask = jnp.asarray([True, True, False, True, False])
    qmask = jnp.asarray([True, False, True])
    np.testing.assert_array_equal(
        np.asarray(frontier_seed_gathered(dist, src, smask, qmask)),
        np.asarray(frontier_seed(dist, src, smask, qmask)))


# -- stream conformance: dense vs ELL --------------------------------------


def _random_events(rng, n_vertices, n_edges, t_max, deletions=True):
    ts = sorted(rng.sample(range(1, t_max), k=min(n_edges, t_max - 1)))
    live = {}
    events = []
    for t in ts:
        u, v = rng.randrange(n_vertices), rng.randrange(n_vertices)
        lab = rng.choice(LABELS)
        if deletions and live and rng.random() < 0.15:
            du, dv, dl = rng.choice(sorted(live))
            del live[(du, dv, dl)]
            events.append(("-", du, dv, dl, float(t)))
        else:
            live[(u, v, lab)] = t
            events.append(("+", u, v, lab, float(t)))
    return events


def _specs(rng, n_queries, window):
    specs = []
    for qi in range(n_queries):
        expr = rng.choice(QUERIES)
        dfa = compile_query(expr)
        semantics = "simple" if (dfa.has_containment_property
                                 and rng.random() < 0.4) else "arbitrary"
        specs.append(RegisteredQuery(f"q{qi}", dfa, window, semantics))
    return specs


def _drive(make_engine, events, slide, n_queries):
    g = make_engine()
    next_exp = slide
    out = []
    for (op, u, v, lab, t) in events:
        if t >= next_exp:
            g.expire(t)
            while next_exp <= t:
                next_exp += slide
        if op == "+":
            fresh = g.insert(u, v, lab, t)
            out.append(("+",) + tuple(
                frozenset(fresh[qi]) for qi in range(n_queries)))
        else:
            inv = g.delete(u, v, lab, t)
            out.append(("-",) + tuple(
                frozenset(inv[qi]) for qi in range(n_queries)))
    return g, out


def _assert_streams_equal(tag, dense, ell):
    assert len(dense) == len(ell)
    for i, (d, e) in enumerate(zip(dense, ell)):
        assert d == e, (tag, i, d, e)


BACKENDS = {
    "jnp": lambda: "jnp",
    "pallas": lambda: PallasBackend(interpret=True),
    "bucket": lambda: BucketBackend(n_levels=6, use_pallas=False),
}


def _conformance(seed, make_executor, backend_key, frontier,
                 ell_kwargs=None, batch_size=1, n_slots=24):
    rng = random.Random(seed)
    window = rng.choice([10.0, 25.0])
    nq = 3
    specs = _specs(rng, nq, window)
    events = _random_events(rng, 14, 80, 70)
    fr = dict(frontier=frontier, frontier_cap=4) if frontier else {}
    ell_kwargs = {"adj_layout": "ell", "ell_cap": 8, **(ell_kwargs or {})}

    def dense():
        ex = make_executor(BACKENDS[backend_key](), **fr)
        return BatchedDenseRPQEngine(specs, n_slots=n_slots,
                                     batch_size=batch_size, executor=ex)

    def ell():
        ex = make_executor(BACKENDS[backend_key](), **fr, **ell_kwargs)
        return BatchedDenseRPQEngine(specs, n_slots=n_slots,
                                     batch_size=batch_size, executor=ex)

    g_d, ev_d = _drive(dense, events, 5.0, nq)
    g_e, ev_e = _drive(ell, events, 5.0, nq)
    tag = (seed, backend_key, frontier)
    _assert_streams_equal(tag, ev_d, ev_e)
    assert g_d.retained_edges() == g_e.retained_edges(), tag
    return g_d, g_e


def _local(backend, **kw):
    return LocalExecutor(backend, **kw)


def _mesh(backend, **kw):
    return MeshExecutor(model_axis=2, backend=backend, **kw)


@pytest.mark.parametrize("backend_key", sorted(BACKENDS))
@pytest.mark.parametrize("frontier", [None, "auto"])
def test_ell_matches_dense_local(backend_key, frontier):
    _conformance(0, _local, backend_key, frontier)


@pytest.mark.parametrize("backend_key", sorted(BACKENDS))
def test_ell_matches_dense_mesh(backend_key):
    _conformance(1, _mesh, backend_key, None)


def test_ell_matches_dense_mesh_frontier():
    _conformance(2, _mesh, "jnp", "auto")


def test_ell_overflow_spill_regression():
    """ell_cap=1 + a tiny spill ring: every multi-degree row overflows, the
    host budget forces drains, drains force ×2 growth re-packs — and the
    stream stays bit-identical throughout."""
    _, g_e = _conformance(
        3, _local, "jnp", None,
        ell_kwargs=dict(ell_cap=1, spill_cap=8), batch_size=4)
    st = g_e.executor.adjacency_stats
    assert st["spill_drains"] > 0, st
    assert st["repacks"] > 0, st
    assert st["ell_cap"] > 1, st  # grew toward the live max degree
    assert st["live_edges"] is not None and st["live_edges"] > 0, st


def test_ell_overflow_spill_regression_frontier_mesh():
    _, g_e = _conformance(
        4, _mesh, "jnp", "auto",
        ell_kwargs=dict(ell_cap=1, spill_cap=8), batch_size=4)
    st = g_e.executor.adjacency_stats
    assert st["spill_drains"] > 0, st


def test_ell_survives_slot_growth_and_compaction():
    """More distinct vertices than n_slots: the engine compacts and grows
    the vertex axis mid-stream; the ELL re-pack rides executor.grow."""
    _conformance(5, _local, "jnp", None, n_slots=8, batch_size=2)


# -- checkpoints across layouts --------------------------------------------


def _ckpt_state(g):
    return {k: np.asarray(jax.device_get(v))
            for k, v in g.state_arrays().items()}


@pytest.mark.parametrize("src_layout,dst_layout",
                         [("dense", "ell"), ("ell", "dense")])
def test_checkpoint_cross_layout(src_layout, dst_layout):
    rng = random.Random(7)
    specs = _specs(rng, 2, 20.0)
    events = _random_events(rng, 10, 50, 45)

    def make(layout):
        return BatchedDenseRPQEngine(
            specs, n_slots=16, batch_size=2, adj_layout=layout, ell_cap=2)

    g_src, _ = _drive(lambda: make(src_layout), events, 5.0, 2)
    state = _ckpt_state(g_src)
    assert state["adj"].ndim == 3, "checkpoints are canonical dense"
    g_dst = make(dst_layout)
    g_dst.load_state_arrays(state)
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(g_src.executor.dense_adj())),
        np.asarray(jax.device_get(g_dst.executor.dense_adj())))
    # the restored engine continues the stream identically
    tail = _random_events(random.Random(8), 10, 20, 45)
    g_dst.interner_state()  # smoke: metadata survives alongside

    if isinstance(g_dst.executor.arrays.adj, EllAdjacency):
        assert g_dst.executor.adj_layout == "ell"


def test_adopt_state_into_ell_engine():
    rng = random.Random(9)
    specs = _specs(rng, 2, 20.0)
    events = _random_events(rng, 10, 40, 45)
    g_src, _ = _drive(
        lambda: BatchedDenseRPQEngine(specs, n_slots=16, batch_size=2),
        events, 5.0, 2)
    state = _ckpt_state(g_src)
    g_dst = BatchedDenseRPQEngine(specs, n_slots=16, batch_size=2,
                                  adj_layout="ell", ell_cap=2)
    g_dst.adopt_state(state, [s.name for s in specs] +
                      [None] * (g_src.q_cap - len(specs)),
                      list(g_src.labels))
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(g_src.executor.dense_adj())),
        np.asarray(jax.device_get(g_dst.executor.dense_adj())))


# -- service layer ----------------------------------------------------------


def test_service_ell_kwarg_and_telemetry():
    from repro.streaming.generators import gmark_like, with_deletions

    def run(adj_layout):
        svc = PersistentQueryService(window=30.0, slide=5.0,
                                     adj_layout=adj_layout, ell_cap=2)
        svc.register("q1", "a . b*", engine="dense", n_slots=32)
        svc.register("q2", "(a | b)*", engine="dense", n_slots=32)
        events = list(with_deletions(
            gmark_like(20, 120, LABELS, seed=3), ratio=0.1, seed=4))
        svc.ingest(events)
        return svc, {n: frozenset(svc.results(n)) for n in ("q1", "q2")}

    svc_d, res_d = run("dense")
    svc_e, res_e = run("ell")
    assert res_d == res_e
    assert svc_e.adjacency_log, "ELL runs log per-interval adjacency stats"
    assert svc_e.adjacency_log[-1][1]["layout"] == "ell"
    assert not svc_d.adjacency_log


# -- validation --------------------------------------------------------------


def test_layout_validation():
    with pytest.raises(ValueError, match="adj_layout"):
        LocalExecutor("jnp", adj_layout="csr")
    with pytest.raises(ValueError, match="ell_cap"):
        LocalExecutor("jnp", adj_layout="ell", ell_cap=0)
    with pytest.raises(ValueError, match="adj_layout"):
        PersistentQueryService(window=10.0, slide=5.0, adj_layout="bogus")
    # non-pow2 caps are bucketed up, not rejected
    ex = LocalExecutor("jnp", adj_layout="ell", ell_cap=5, spill_cap=9)
    assert ex.ell_cap == 8 and ex.spill_cap == 16
