"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles across
shape/dtype sweeps + hypothesis property tests on semiring identities."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.maxmin.maxmin import maxmin_matmul
from repro.kernels.maxmin.ref import maxmin_matmul_naive, maxmin_matmul_ref
from repro.kernels.bucket.bucket import bucket_maxmin
from repro.kernels.bucket.ref import bucket_maxmin_exact, bucket_maxmin_ref


def _rand_ts(rng, shape, dtype, density=0.7):
    x = rng.uniform(0.0, 1000.0, shape).astype(dtype)
    x[rng.random(shape) > density] = -np.inf
    return x


SHAPES = [
    (8, 8, 8),
    (128, 128, 128),
    (130, 70, 200),     # ragged: exercises -inf padding
    (1, 256, 33),
    (257, 1, 129),
    (64, 512, 64),
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_maxmin_pallas_vs_ref_shapes(m, k, n, dtype):
    rng = np.random.default_rng(m * 1000 + k + n)
    a = _rand_ts(rng, (m, k), dtype)
    b = _rand_ts(rng, (k, n), dtype)
    ref = maxmin_matmul_naive(jnp.asarray(a), jnp.asarray(b))
    out = maxmin_matmul(jnp.asarray(a), jnp.asarray(b), interpret=True,
                        bm=64, bn=128, bk=32)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out))


def test_maxmin_chunked_ref_matches_naive():
    rng = np.random.default_rng(0)
    a = _rand_ts(rng, (100, 300), np.float32)
    b = _rand_ts(rng, (300, 50), np.float32)
    np.testing.assert_allclose(
        np.asarray(maxmin_matmul_ref(jnp.asarray(a), jnp.asarray(b), chunk=64)),
        np.asarray(maxmin_matmul_naive(jnp.asarray(a), jnp.asarray(b))),
    )


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 40), k=st.integers(1, 40), n=st.integers(1, 40),
    seed=st.integers(0, 2**31),
    density=st.floats(0.0, 1.0),
)
def test_maxmin_property_random(m, k, n, seed, density):
    rng = np.random.default_rng(seed)
    a = _rand_ts(rng, (m, k), np.float32, density)
    b = _rand_ts(rng, (k, n), np.float32, density)
    ref = maxmin_matmul_naive(jnp.asarray(a), jnp.asarray(b))
    out = maxmin_matmul(jnp.asarray(a), jnp.asarray(b), interpret=True,
                        bm=16, bn=16, bk=16)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out))


def test_maxmin_semiring_identities():
    """Algebraic sanity: -inf is the zero, +inf row acts as identity-ish max,
    and the op is associative over composition (closure well-defined)."""
    rng = np.random.default_rng(1)
    a = _rand_ts(rng, (16, 16), np.float32)
    b = _rand_ts(rng, (16, 16), np.float32)
    c = _rand_ts(rng, (16, 16), np.float32)
    mm = lambda x, y: maxmin_matmul_naive(jnp.asarray(x), jnp.asarray(y))
    left = mm(np.asarray(mm(a, b)), c)
    right = mm(a, np.asarray(mm(b, c)))
    np.testing.assert_allclose(np.asarray(left), np.asarray(right))
    zero = np.full((16, 16), -np.inf, np.float32)
    np.testing.assert_array_equal(np.asarray(mm(a, zero)), zero)


# ---------------------------------------------------------------------------
# bucketized MXU closure kernel
# ---------------------------------------------------------------------------

BUCKET_SHAPES = [(16, 16, 16, 4), (128, 128, 128, 8), (70, 200, 90, 3), (1, 130, 257, 6)]


@pytest.mark.parametrize("m,k,n,T", BUCKET_SHAPES)
def test_bucket_pallas_vs_exact(m, k, n, T):
    rng = np.random.default_rng(m + k + n + T)
    a = rng.integers(0, T + 1, (m, k)).astype(np.int32)
    b = rng.integers(0, T + 1, (k, n)).astype(np.int32)
    exact = bucket_maxmin_exact(jnp.asarray(a), jnp.asarray(b))
    decomp = bucket_maxmin_ref(jnp.asarray(a), jnp.asarray(b), T)
    kern = bucket_maxmin(jnp.asarray(a), jnp.asarray(b), n_levels=T,
                         interpret=True, bm=64, bn=64, bk=32)
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(decomp))
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(kern))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 30), k=st.integers(1, 30), n=st.integers(1, 30),
    T=st.integers(1, 8), seed=st.integers(0, 2**31),
)
def test_bucket_property_random(m, k, n, T, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, T + 1, (m, k)).astype(np.int32)
    b = rng.integers(0, T + 1, (k, n)).astype(np.int32)
    exact = bucket_maxmin_exact(jnp.asarray(a), jnp.asarray(b))
    kern = bucket_maxmin(jnp.asarray(a), jnp.asarray(b), n_levels=T,
                         interpret=True, bm=16, bn=16, bk=16)
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(kern))


def test_bucket_quantization_bound():
    """Level-quantized closure equals the exact float closure after both are
    quantized to the same grid (soundness of the MXU fast path)."""
    rng = np.random.default_rng(3)
    T = 8
    edges = rng.uniform(0.0, 100.0, (32, 32)).astype(np.float32)
    edges[rng.random((32, 32)) > 0.3] = -np.inf
    # quantize: level = ceil(ts / (100/T)) in [0, T]
    lv = np.clip(np.ceil(edges / (100.0 / T)), 0, T)
    lv = np.where(np.isfinite(edges), lv, 0).astype(np.int32)
    exact_f = np.asarray(maxmin_matmul_naive(jnp.asarray(edges), jnp.asarray(edges)))
    lv_exact = np.clip(np.ceil(exact_f / (100.0 / T)), 0, T)
    lv_exact = np.where(np.isfinite(exact_f), lv_exact, 0).astype(np.int32)
    lv_kernel = np.asarray(
        bucket_maxmin(jnp.asarray(lv), jnp.asarray(lv), n_levels=T,
                      interpret=True, bm=16, bn=16, bk=16)
    )
    np.testing.assert_array_equal(lv_exact, lv_kernel)


# ---------------------------------------------------------------------------
# Shape-aware block sizes (PR 5 satellite)
# ---------------------------------------------------------------------------

from repro.kernels.bucket.bucket import bucket_maxmin_fused
from repro.kernels.maxmin.maxmin import maxmin_matmul_fused, pick_block_sizes


def test_pick_block_sizes_table():
    """Skinny frontier slabs get a small bm / wide bn; big square problems
    keep the dense defaults; everything clamps to the aligned problem."""
    assert pick_block_sizes(8, 512, 512) == (8, 256, 128)
    assert pick_block_sizes(16, 512, 512) == (16, 256, 128)
    assert pick_block_sizes(32, 512, 512) == (32, 256, 128)
    assert pick_block_sizes(512, 512, 512) == (128, 128, 64)
    # ultra-skinny row slabs (the row-sparse dist gather: a handful of
    # (q, x) rows against a wide N·K entry axis) double bn again
    assert pick_block_sizes(4, 512, 2048) == (8, 512, 128)
    assert pick_block_sizes(1, 128, 1024) == (8, 512, 128)
    # the wide-bn row still clamps to the aligned problem
    assert pick_block_sizes(4, 16, 40) == (8, 128, 16)
    # clamps: a tiny engine never pays full-tile padding on m/k, and bn
    # keeps the 128-lane alignment floor
    assert pick_block_sizes(5, 24, 24) == (8, 128, 24)
    assert pick_block_sizes(100, 6, 40) == (104, 128, 8)
    # every block divides its padded problem (the kernels pad to block
    # multiples, so any positive block is legal — this is a sanity floor)
    for m, k, n in [(1, 1, 1), (17, 3, 200), (33, 129, 7)]:
        bm, bn, bk = pick_block_sizes(m, k, n)
        assert bm >= 1 and bn >= 1 and bk >= 1


ODD_SHAPES = [
    # (J, m, k, n): skinny frontier slabs (m = F << k = n = N) + ragged odds
    (3, 4, 40, 40),
    (5, 16, 33, 33),
    (2, 1, 7, 19),
    (7, 23, 5, 64),
    (1, 130, 70, 30),
]


@pytest.mark.parametrize("J,m,k,n", ODD_SHAPES)
def test_fused_maxmin_auto_blocks_match_oracle(J, m, k, n):
    """Auto (table-driven) block sizes on odd/small/skinny shapes stay
    bit-identical to the jnp oracle — block choice is a memory schedule,
    never a result change."""
    rng = np.random.default_rng(J * 100 + m + k + n)
    a = _rand_ts(rng, (J, m, k), np.float32)
    b = _rand_ts(rng, (J, k, n), np.float32)
    ref = jnp.stack([maxmin_matmul_naive(jnp.asarray(a[j]), jnp.asarray(b[j]))
                     for j in range(J)])
    out = maxmin_matmul_fused(jnp.asarray(a), jnp.asarray(b), interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


@pytest.mark.parametrize("J,m,k,n", ODD_SHAPES[:3])
def test_fused_bucket_auto_blocks_match_oracle(J, m, k, n):
    T = 6
    rng = np.random.default_rng(J + m + k + n)
    a = rng.integers(0, T + 1, (J, m, k)).astype(np.int32)
    b = rng.integers(0, T + 1, (J, k, n)).astype(np.int32)
    ref = np.stack([
        np.asarray(bucket_maxmin_exact(jnp.asarray(a[j]), jnp.asarray(b[j])))
        for j in range(J)])
    out = bucket_maxmin_fused(jnp.asarray(a), jnp.asarray(b), n_levels=T,
                              interpret=True)
    np.testing.assert_array_equal(ref, np.asarray(out))
