"""Property tests: paper-faithful RAPQ/RSPQ engines vs batch oracles.

Randomized streams (hypothesis) over small vertex sets exercise window
expiry, timestamp improvements, re-insertion, and explicit deletions.
"""
import random

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    RAPQ,
    RSPQ,
    batch_rapq,
    compile_query,
    snapshot_from_edges,
    streaming_oracle,
)

QUERIES = [
    "a*",
    "a . b*",
    "(a | b)*",
    "a . b* . c",
    "(a . b)+",
    "a . b . c",
    "a? . b*",
]

LABELS = ["a", "b", "c"]


def _random_stream(rng, n_vertices, n_edges, t_max):
    """Edges with strictly increasing integer timestamps."""
    ts = sorted(rng.sample(range(1, t_max), k=min(n_edges, t_max - 1)))
    out = []
    for t in ts:
        u = rng.randrange(n_vertices)
        v = rng.randrange(n_vertices)
        lab = rng.choice(LABELS)
        out.append((u, v, lab, float(t)))
    return out


@pytest.mark.parametrize("query", QUERIES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_rapq_monotone_result_set_matches_oracle(query, seed):
    rng = random.Random(seed)
    dfa = compile_query(query)
    window = 20.0
    stream = _random_stream(rng, n_vertices=8, n_edges=40, t_max=100)
    eng = RAPQ(dfa, window)
    for (u, v, lab, ts) in stream:
        eng.insert(u, v, lab, ts)
    oracle = streaming_oracle(stream, dfa, window)
    assert eng.results == oracle, (query, seed)


@pytest.mark.parametrize("query", QUERIES)
def test_rapq_snapshot_results_after_expiry(query):
    """After expiry at time tau, current_results == batch on the snapshot."""
    rng = random.Random(7)
    dfa = compile_query(query)
    window = 15.0
    stream = _random_stream(rng, n_vertices=7, n_edges=35, t_max=80)
    eng = RAPQ(dfa, window)
    for i, (u, v, lab, ts) in enumerate(stream):
        eng.insert(u, v, lab, ts)
        if i % 5 == 4:  # slide boundary: lazy expiration
            eng.expire(ts)
            snap = snapshot_from_edges(stream[: i + 1], low=ts - window, high=ts)
            assert eng.current_results() == batch_rapq(snap, dfa)


@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    query=st.sampled_from(QUERIES),
    window=st.sampled_from([5.0, 12.0, 30.0, 200.0]),
)
def test_rapq_property_random(seed, query, window):
    rng = random.Random(seed)
    dfa = compile_query(query)
    stream = _random_stream(rng, n_vertices=6, n_edges=25, t_max=60)
    eng = RAPQ(dfa, window)
    for i, (u, v, lab, ts) in enumerate(stream):
        eng.insert(u, v, lab, ts)
        if i % 7 == 6:
            eng.expire(ts)
    assert eng.results == streaming_oracle(stream, dfa, window)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    query=st.sampled_from(QUERIES),
)
def test_rapq_explicit_deletions(seed, query):
    """Interleave deletions; after each op the engine snapshot view must
    match batch evaluation of the live edge set (window = inf isolates the
    deletion machinery from expiry)."""
    rng = random.Random(seed)
    dfa = compile_query(query)
    eng = RAPQ(dfa, window=10_000.0)
    live = {}
    t = 0.0
    for _ in range(30):
        t += 1.0
        if live and rng.random() < 0.3:
            key = rng.choice(sorted(live))
            u, v, lab = key
            del live[key]
            eng.delete(u, v, lab, t)
        else:
            u = rng.randrange(5)
            v = rng.randrange(5)
            lab = rng.choice(LABELS)
            live[(u, v, lab)] = t
            eng.insert(u, v, lab, t)
        snap = snapshot_from_edges([(u, v, l, ts) for (u, v, l), ts in live.items()])
        assert eng.current_results() == batch_rapq(snap, dfa), (seed, query)


# ---------------------------------------------------------------------------
# RSPQ vs exhaustive simple-path enumeration
# ---------------------------------------------------------------------------

RSPQ_QUERIES = [
    "a*",                # restricted: conflict-free everywhere
    "(a | b)*",          # restricted
    "a . b . c",         # fixed length: conflict-free
    "a . b*",
    "(a . b)+",          # conflicts on cyclic graphs (Fig. 1 example)
    "a . b* . c",
]


@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    query=st.sampled_from(RSPQ_QUERIES),
)
def test_rspq_matches_bruteforce_simple_paths(seed, query):
    rng = random.Random(seed)
    dfa = compile_query(query)
    window = 1000.0  # effectively no expiry: isolates simple-path logic
    stream = _random_stream(rng, n_vertices=5, n_edges=18, t_max=50)
    eng = RSPQ(dfa, window)
    for (u, v, lab, ts) in stream:
        eng.insert(u, v, lab, ts)
    oracle = streaming_oracle(stream, dfa, window, simple=True)
    assert eng.results == oracle, (seed, query)


@pytest.mark.parametrize("query", ["a*", "(a | b)*", "a . b . c"])
def test_rspq_windowed_matches_bruteforce(query):
    rng = random.Random(3)
    dfa = compile_query(query)
    window = 12.0
    stream = _random_stream(rng, n_vertices=5, n_edges=25, t_max=60)
    eng = RSPQ(dfa, window)
    for i, (u, v, lab, ts) in enumerate(stream):
        eng.insert(u, v, lab, ts)
        if i % 6 == 5:
            eng.expire(ts)
    assert eng.results == streaming_oracle(stream, dfa, window, simple=True)


def test_rspq_fig1_example():
    """The running example of the paper: (follows . mentions)+ on Fig. 1.

    At t=18 the pair (x, y) must be reported under BOTH semantics: the
    arbitrary path <x,y,u,v,y> and the simple path <x,z,u,v,y> exist.
    RSPQ must detect the conflict at v and recover via Unmark (Example 4.2).
    """
    dfa = compile_query("(follows . mentions)+")
    window = 15.0
    # Fig. 1(a): timestamps reconstructed from the example narrative
    edges = [
        ("x", "y", "follows", 3.0),
        ("y", "u", "mentions", 4.0),
        ("x", "z", "follows", 8.0),
        ("u", "v", "follows", 12.0),
        ("x", "y", "follows", 13.0),  # re-insertion freshens the edge
        ("z", "u", "mentions", 14.0),
        ("v", "y", "mentions", 18.0),
    ]
    arb = RAPQ(dfa, window)
    smp = RSPQ(dfa, window)
    for (u, v, lab, ts) in edges:
        arb.insert(u, v, lab, ts)
        smp.insert(u, v, lab, ts)
    assert ("x", "y") in arb.results
    assert ("x", "y") in smp.results
    # NOTE: with eager timestamp improvements (see reference.py Extend),
    # the tree re-parents through the simple path <x,z,u,v> before edge
    # (v,y) arrives, so no conflict fires here; the conflict machinery is
    # exercised deterministically in test_rspq_conflict_machinery below.


def test_rspq_conflict_machinery():
    """Force a genuine conflict: when edge (v,y) arrives, the ONLY tree path
    to (v,1) goes through y, so Extend must detect [1] !>= [2] at y, invoke
    Unmark, and later recover the simple path when (z,u) arrives."""
    dfa = compile_query("(f . m)+")
    window = 30.0
    smp = RSPQ(dfa, window)
    arb = RAPQ(dfa, window)
    edges = [
        ("x", "y", "f", 3.0),
        ("y", "u", "m", 4.0),
        ("x", "z", "f", 8.0),
        ("u", "v", "f", 12.0),
        ("v", "y", "m", 13.0),  # conflict: path x,y,u,v revisits y
        ("z", "u", "m", 14.0),  # completes the simple path x,z,u,v,y
    ]
    for i, (u, v, lab, ts) in enumerate(edges):
        arb.insert(u, v, lab, ts)
        smp.insert(u, v, lab, ts)
        if i == 4:
            # arbitrary semantics accepts the non-simple path already...
            assert ("x", "y") in arb.results
            # ...simple-path semantics must NOT (x,y,u,v,y revisits y)
            assert ("x", "y") not in smp.results
            assert smp.conflicts_detected > 0
    # after (z,u): the simple path <x,z,u,v,y> exists -> both report it
    assert ("x", "y") in smp.results
    # cross-check against exhaustive enumeration
    oracle = streaming_oracle(edges, dfa, window, simple=True)
    assert smp.results == oracle


def test_rspq_conflict_free_has_no_reexploration():
    """For restricted expressions the RSPQ engine must behave like RAPQ:
    no conflicts, each (v, t) visited at most once per tree."""
    dfa = compile_query("(a | b)*")
    assert dfa.has_containment_property
    rng = random.Random(11)
    eng = RSPQ(dfa, window=100.0)
    for (u, v, lab, ts) in _random_stream(rng, 6, 30, 80):
        eng.insert(u, v, lab, ts)
    assert eng.conflicts_detected == 0
    for tree in eng.delta.values():
        for key, occs in tree.occs.items():
            assert len(occs) == 1, key
