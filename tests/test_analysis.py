"""The dispatch-hygiene analyzer: every rule catches its seeded-violation
fixture, stays silent on the clean twin, suppressions work, and the real
tree is clean (the CI gate's contract).

The analyzer is pure stdlib — these tests never import jax, so they run
on the bare tier too.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.analyzer import analyze_sources, run
from repro.analysis.rules import ALL_RULES

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"

# -- fixtures: (rule, bad source, expected minimum hits, clean twin) ---------

R1_BAD = """\
import jax
import jax.numpy as jnp
import numpy as np

@jax.jit
def step(dist):
    total = float(dist.sum())
    host = np.asarray(dist)
    n = dist.item()
    if jnp.any(dist > 0):
        dist = dist + 1
    return dist + total + host + n

@jax.jit
def outer(x):
    return helper(x)

def helper(x):
    return x.item()
"""

R1_CLEAN = """\
import jax
import jax.numpy as jnp
import numpy as np

@jax.jit
def step(dist):
    m = dist.shape[0]
    k = int(dist.ndim)
    dist = jnp.where(dist > 0, dist + 1.0, dist)
    return jax.lax.cond(m > 2, lambda d: d, lambda d: d * 1.0, dist)

def host_prep(x):
    # outside the jit boundary: numpy is the POINT here (arg staging)
    return np.asarray(x)
"""

R2_BAD = """\
import functools

@functools.lru_cache(maxsize=None)
def step_fns(mesh, q_axes):
    return q_axes

def grow(n):
    f_cap = n + 3
    q_cap = 100
    ell_cap = n + 5
    dist_cap = n + 7
    fns = step_fns(1, [1, 2])
    return f_cap, q_cap, ell_cap, dist_cap, fns
"""

R2_CLEAN = """\
import functools

def _next_pow2(n):
    return 1 << (max(1, n) - 1).bit_length()

@functools.lru_cache(maxsize=None)
def step_fns(mesh, q_axes):
    return q_axes

def grow(n, dist):
    f_cap = _next_pow2(n)
    f_cap *= 2
    q_cap = dist.shape[0]
    ell_cap = _next_pow2(n)
    spill_cap = ell_cap
    spill_cap *= 2
    dist_cap = _next_pow2(n)
    dist_ovf_cap = min(dist_cap, 4096)
    fns = step_fns(1, (1, 2))
    return f_cap, q_cap, ell_cap, spill_cap, dist_cap, dist_ovf_cap, fns
"""

R3_BAD = """\
from jax.experimental import pallas as pl

_OFFSET = 2

def lower(x):
    return pl.BlockSpec((128, 128), lambda i, j: (i + _OFFSET, j))
"""

R3_CLEAN = """\
from jax.experimental import pallas as pl
from ..maxmin.maxmin import pick_block_sizes

def lower(x, m, n, k):
    bm, bk, bn = pick_block_sizes(m, k, n)
    return pl.BlockSpec((1, bm, bn), lambda i, j: (0, i, j))
"""

R4_BAD = """\
class ContractionBackend:
    zero = 0.0
    exact = True

    def contract(self, d, a):
        raise NotImplementedError

    def contract_rows(self, d_s, a_l):
        raise NotImplementedError

    def contract_batched(self, dist, adj, btt, mask):
        return dist

    def prepare_state(self, dist, adj):
        return dist, adj

    def decode_state(self, dist):
        return dist


class HalfBackend(ContractionBackend):
    def contract(self, d, a):
        return d


def use(make_engine, resolve_backend):
    resolve_backend("palas")
    return make_engine(backend="palas")
"""

R4_CLEAN = """\
class ContractionBackend:
    zero = 0.0
    exact = True

    def contract(self, d, a):
        raise NotImplementedError

    def contract_rows(self, d_s, a_l):
        raise NotImplementedError

    def contract_batched(self, dist, adj, btt, mask):
        return dist

    def prepare_state(self, dist, adj):
        return dist, adj

    def decode_state(self, dist):
        return dist


class FullBackend(ContractionBackend):
    def contract(self, d, a):
        return d

    def contract_rows(self, d_s, a_l):
        return d_s


def use(make_engine, resolve_backend):
    resolve_backend("pallas")
    return make_engine(backend="jnp")
"""

R5_BAD = """\
import numpy as np

class Engine:
    def drain(self, pending):
        while pending:
            h = pending.pop(0)
        return h

    def requeue(self, pending, h):
        pending.insert(0, h)

    def telemetry(self, arrays, shard_rounds):
        t = float(arrays.now)
        r = np.asarray(shard_rounds)
        return t, r
"""

R5_CLEAN = """\
import numpy as np
import jax

class Engine:
    def drain(self, pending):
        while pending:
            h = pending.popleft()
        return h

    def _flush_counts(self, shard_rounds):
        return np.asarray(shard_rounds)

    def _flush_health(self, overflow_counts):
        # the supervisor's per-interval telemetry flush is a sanctioned
        # site, same as the executor counter flushes
        return np.asarray(overflow_counts)

    def restore(self, state):
        return float(np.asarray(jax.device_get(state.now)))
"""

FIXTURES = {
    "R1": (R1_BAD, 5, R1_CLEAN),
    "R2": (R2_BAD, 5, R2_CLEAN),
    "R3": (R3_BAD, 3, R3_CLEAN),
    "R4": (R4_BAD, 3, R4_CLEAN),
    "R5": (R5_BAD, 4, R5_CLEAN),
}

# fixture files live under a kernels/ dir so R3's path scoping applies
FIXTURE_RELPATH = "src/fake/kernels/fixture.py"


def _hits(source, rule):
    findings = analyze_sources({FIXTURE_RELPATH: source}, rules=[rule])
    return [f for f in findings if f.rule == rule]


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_catches_seeded_fixture(rule):
    bad, n_min, _clean = FIXTURES[rule]
    hits = _hits(bad, rule)
    assert len(hits) >= n_min, (
        f"{rule} found {len(hits)} of >= {n_min} seeded violations:\n"
        + "\n".join(f.format() for f in hits))


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_silent_on_clean_twin(rule):
    _bad, _n, clean = FIXTURES[rule]
    hits = _hits(clean, rule)
    assert not hits, "\n".join(f.format() for f in hits)


def test_r1_reaches_through_helper_calls():
    hits = _hits(R1_BAD, "R1")
    assert any("helper" in f.message for f in hits), (
        "the .item() in the un-decorated helper must be reached through "
        "the jitted caller")


def test_r1_ignores_host_side_numpy():
    hits = _hits(R1_CLEAN + "\n", "R1")
    assert not hits  # host_prep's np.asarray is outside the jit boundary


def test_noqa_suppresses_but_still_reports():
    src = R5_BAD.replace(
        "h = pending.pop(0)",
        "h = pending.pop(0)  # repro: noqa[R5]")
    findings = analyze_sources({FIXTURE_RELPATH: src}, rules=["R5"])
    popfinds = [f for f in findings if "pop(0)" in f.message]
    assert popfinds and all(f.suppressed for f in popfinds)
    assert any(not f.suppressed for f in findings)  # the others still fail


def test_bare_noqa_suppresses_all_rules():
    src = "def f(n):\n    f_cap = n + 3  # repro: noqa\n    return f_cap\n"
    findings = analyze_sources({"m.py": src})
    assert findings and all(f.suppressed for f in findings)


def test_whole_repo_is_clean():
    findings, n_files = run([str(SRC)])
    live = [f for f in findings if not f.suppressed]
    assert n_files > 40
    assert not live, "\n".join(f.format() for f in live)


def test_rule_registry_complete():
    assert sorted(m.RULE for m in ALL_RULES) == ["R1", "R2", "R3", "R4", "R5"]
    for m in ALL_RULES:
        assert m.TITLE


def test_cli_exit_codes_and_json(tmp_path):
    bad = tmp_path / "kernels" / "bad.py"
    bad.parent.mkdir()
    bad.write_text(R5_BAD)
    env_src = str(SRC)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(bad), "--format=json"],
        capture_output=True, text=True,
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 1, proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["unsuppressed"] >= 4
    assert payload["counts_by_rule"].get("R5", 0) >= 4
    assert payload["checked_files"] == 1

    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(SRC), "--format=json"],
        capture_output=True, text=True,
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin"},
    )
    assert ok.returncode == 0, ok.stdout + ok.stderr
    assert json.loads(ok.stdout)["unsuppressed"] == 0
