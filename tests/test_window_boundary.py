"""Window-boundary inclusivity: the shared strict-`>` convention.

The window is the half-open interval (now - w, now]: an edge (or a result
pair's bottleneck) timestamped EXACTLY ``now - w`` is expired. Three layers
must agree on this — ``_expire`` retains adjacency ``> low``,
``batched_valid_pairs`` emits bottlenecks ``> low``, and the bucket
backend's absolute grid maps anything at or below its window-aligned origin
to the dead level 0 — or a pair could be emitted whose support the expiry
pass already evicted (or vice versa). These tests pin each layer at the
exact boundary timestamp.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import compile_query
from repro.core.backend import BucketBackend
from repro.core.engine import BatchedDenseRPQEngine, RegisteredQuery
from repro.core.semiring import NEG_INF, batched_valid_pairs


def _engine(window=10.0, expr="a . a*"):
    specs = [RegisteredQuery("q", compile_query(expr), window)]
    return BatchedDenseRPQEngine(specs, n_slots=8, batch_size=1)


def test_expire_drops_edge_at_exact_boundary():
    """low = tau - w; an edge with ts == low is NOT retained (strict >)."""
    g = _engine(10.0)
    g.insert("u", "v", "a", 5.0)
    assert g.current_results(0) == {("u", "v")}
    g.expire(15.0)                       # low = 5.0: the edge sits ON it
    assert not np.isfinite(np.asarray(g.batched_arrays.adj)).any()
    assert g.current_results(0) == set()


def test_expire_keeps_edge_just_inside_boundary():
    g = _engine(10.0)
    g.insert("u", "v", "a", 5.001)
    g.expire(15.0)                       # low = 5.0 < 5.001: retained
    assert np.isfinite(np.asarray(g.batched_arrays.adj)).any()
    assert g.current_results(0) == {("u", "v")}


def test_emit_excludes_bottleneck_at_exact_boundary():
    """The read-time validity threshold uses the same strict >: advancing
    the clock to exactly ts + w (no expiry pass!) kills the pair's
    emit-view while a younger pair survives."""
    g = _engine(10.0)
    g.insert("u", "v", "a", 5.0)
    g.insert("x", "y", "a", 15.0)        # clock -> 15.0, low -> 5.0
    assert g.current_results(0) == {("x", "y")}
    # the emitted HISTORY is monotone and keeps (u, v); only the
    # current-window view drops it
    assert ("u", "v") in g.per_query_results[0]


def test_delete_invalidation_respects_boundary():
    """A pair whose bottleneck sits exactly on the boundary is already
    invalid, so deleting its edge at that instant reports NO invalidation
    (nothing valid became invalid)."""
    g = _engine(10.0)
    g.insert("u", "v", "a", 5.0)
    inv = g.delete("u", "v", "a", 15.0)  # low = 5.0 at the delete's clock
    assert inv[0] == set()
    # same schedule, one tick earlier: the pair is still valid -> reported
    g2 = _engine(10.0)
    g2.insert("u", "v", "a", 5.0)
    inv2 = g2.delete("u", "v", "a", 14.999)
    assert inv2[0] == {("u", "v")}


def test_batched_valid_pairs_strict_threshold():
    """Unit pin of the kernel-side comparison: best == low is invalid."""
    q, n, k = 1, 3, 2
    dist = jnp.full((q, n, n, k), NEG_INF)
    dist = dist.at[0, 0, 1, 1].set(5.0)
    finals = jnp.zeros((q, k), bool).at[0, 1].set(True)
    at_low = batched_valid_pairs(dist, finals, jnp.asarray([5.0]))
    below_low = batched_valid_pairs(dist, finals, jnp.asarray([4.999]))
    assert not bool(at_low[0, 0, 1])
    assert bool(below_low[0, 0, 1])


def test_bucket_encode_boundary_is_dead():
    """The bucket grid anchors its origin at (a grid-aligned) now - w_max:
    a timestamp a full window old encodes to level 0 and decodes to -inf,
    while anything above the origin stays finite. Pick now/w so the origin
    lands exactly on now - w (no floor slack)."""
    be = BucketBackend(n_levels=5, use_pallas=False)
    now, w = jnp.float32(14.0), jnp.float32(10.0)   # step 2, origin = 4.0
    x = jnp.asarray([4.0, 3.0, 4.5, 14.0, NEG_INF], jnp.float32)
    lvl = be.encode(x, now, w)
    assert lvl[0] == 0 and lvl[1] == 0 and lvl[4] == 0   # at/below origin
    assert lvl[2] > 0 and lvl[3] > 0
    dec = np.asarray(be.decode_state(lvl, now, w))
    assert dec[0] == NEG_INF and dec[1] == NEG_INF and dec[4] == NEG_INF
    assert np.isfinite(dec[2]) and np.isfinite(dec[3])
