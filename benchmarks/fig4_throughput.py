"""Fig. 4 analogue: throughput (edges/s) and p99 tuple latency of streaming
RAPQ per query per graph, for BOTH engines (paper-faithful pointer baseline
and the dense TPU engine on CPU) — the paper's headline table."""
from __future__ import annotations

import time

from repro.core.automaton import compile_query
from repro.core.engine import DenseRPQEngine
from repro.core.reference import RAPQ
from repro.streaming.generators import ldbc_like, so_like, yago_like

from .common import emit, percentile, so_queries


def _run_engine(make_engine, stream, window, slide, batch=1):
    eng = make_engine()
    if batch > 1:
        # warm the jit cache (compile excluded from timing)
        warm = make_engine()
        warm.insert_batch([s.as_edge() for s in list(stream)[:batch]])
    lat = []
    next_exp = slide
    t_start = time.perf_counter()
    n = 0
    pending = []
    for sgt in stream:
        if sgt.ts >= next_exp:
            if pending:
                t0 = time.perf_counter_ns()
                eng.insert_batch([s.as_edge() for s in pending])
                lat.append((time.perf_counter_ns() - t0) / 1e3 / len(pending))
                n += len(pending)
                pending = []
            eng.expire(sgt.ts)
            while next_exp <= sgt.ts:
                next_exp += slide
        if batch > 1:
            pending.append(sgt)
            if len(pending) >= batch:
                t0 = time.perf_counter_ns()
                eng.insert_batch([s.as_edge() for s in pending])
                lat.append((time.perf_counter_ns() - t0) / 1e3 / len(pending))
                n += len(pending)
                pending = []
        else:
            t0 = time.perf_counter_ns()
            eng.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
            lat.append((time.perf_counter_ns() - t0) / 1e3)
            n += 1
    if pending:
        eng.insert_batch([s.as_edge() for s in pending])
        n += len(pending)
    wall = time.perf_counter() - t_start
    return {
        "throughput": n / wall,
        "p99_us": percentile(lat, 0.99),
        "mean_us": sum(lat) / max(len(lat), 1),
        "results": len(eng.results),
    }


def run(n_edges: int = 1500, n_vertices: int = 48) -> None:
    graphs = {
        "so": so_like(n_vertices, n_edges, seed=1),
        "ldbc": ldbc_like(n_vertices, n_edges, seed=1),
        "yago": yago_like(n_vertices * 4, n_edges, n_labels=20, seed=1),
    }
    window, slide = 30.0, 5.0
    for gname, stream in graphs.items():
        # choose queries whose labels exist in the graph
        if gname == "so":
            queries = so_queries()
        elif gname == "ldbc":
            queries = {"Q2": "knows . replyOf*", "Q11": "knows . replyOf . hasCreator",
                       "Q1": "knows*"}
        else:
            queries = {"Q1": "p0*", "Q2": "p0 . p1*", "Q11": "p0 . p1 . p2"}
        for qname, expr in queries.items():
            dfa = compile_query(expr)
            ref = _run_engine(lambda: RAPQ(dfa, window), stream, window, slide)
            # dense engine runs in (realistic) micro-batch mode; results are
            # evaluated at batch boundaries, so the monotone set is a subset
            # of the per-tuple reference (exact B=1 equality is covered by
            # tests/test_dense_engine.py)
            dense = _run_engine(
                lambda: DenseRPQEngine(dfa, window, n_slots=256, batch_size=32),
                stream, window, slide, batch=32)
            assert dense["results"] <= ref["results"], (gname, qname)
            cover = dense["results"] / max(ref["results"], 1)
            emit(f"fig4/{gname}/{qname}/reference", ref["mean_us"],
                 f"thr={ref['throughput']:.0f}eps p99={ref['p99_us']:.0f}us "
                 f"results={ref['results']}")
            emit(f"fig4/{gname}/{qname}/dense_b32", dense["mean_us"],
                 f"thr={dense['throughput']:.0f}eps p99={dense['p99_us']:.0f}us "
                 f"results={dense['results']} coverage={cover:.3f}")


if __name__ == "__main__":
    run()
