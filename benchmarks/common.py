"""Shared benchmark utilities: the paper's Table-2 query workload, timing,
CSV emission (``name,us_per_call,derived``)."""
from __future__ import annotations

import re
import time
from typing import Callable, Dict, List

# Table 2: most common real-world RPQs (k=3 labels, matching the SO graph)
PAPER_QUERIES: Dict[str, str] = {
    "Q1": "a*",
    "Q2": "a . b*",
    "Q3": "a . b* . c*",
    "Q4": "(a | b | c)*",
    "Q5": "a . b* . c",
    "Q6": "a* . b*",
    "Q7": "a . b . c*",
    "Q8": "a? . b*",
    "Q9": "(a | b | c)+",
    "Q10": "(a | b | c) . b*",
    "Q11": "a . b . c",
}

# label mapping for the SO-like generator (paper Table 3)
SO_LABEL_MAP = {"a": "a2q", "b": "c2a", "c": "c2q"}


def so_queries() -> Dict[str, str]:
    # simultaneous substitution: sequential str.replace would re-match the
    # 'a'/'c' inside already-substituted labels ("c2a" -> "c2q2a", a phantom
    # label that silently empties the query against the SO stream)
    return {
        name: re.sub(r"[abc]", lambda m: SO_LABEL_MAP[m.group(0)], expr)
        for name, expr in PAPER_QUERIES.items()
    }


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def time_stream(fn: Callable[[], None]) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def percentile(xs: List[float], p: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(int(p * len(s)), len(s) - 1)]
