"""Beyond-paper figure: blocked-sparse (padded-ELL) adjacency vs the dense
``(L, N, N)`` slab on gmark-style sparse windows — the tentpole of the
representation PR that breaks the adjacency O(N²) wall.

Three legs:

1. **Identity** (asserted, not sampled): a sparse gmark window with
   deletions and expiry driven through dense and ELL engines, frontier
   auto — per-event result streams must be bit-identical.

2. **Per-stage split** at N ∈ anchors ∪ {N_big} (the maxtext
   microbenchmark idiom — each stage jitted, timed around
   ``block_until_ready``): *ingest-seed* (dense ``frontier_seed`` scan
   over the (Q, N, N, K) dist vs the ELL ``frontier_seed_gathered``
   O(Q·N·B·K) gather), *insert* (dense slab scatter vs ELL row scatter),
   *relax* (dense row contraction + (J, N, N) base slab vs the ELL
   gather-contract + (J, F, N) row densify), *emit*
   (``batched_valid_pairs`` — identical code on both layouts, reported
   once as the shared dense-dist wall this PR does NOT touch).

3. **Scale** at N_big = 100k: the dense layout is INFEASIBLE by
   construction (the slab alone needs L·N²·4 bytes ≈ 112 GiB at L=3 —
   that infeasibility is the figure's point), so dense per-event cost is
   extrapolated from the measured anchors with an N² fit while the
   ELL stages that touch only adjacency-sized state run for real.
   Adjacency memory is reported measured (ELL leaf bytes) vs analytic
   (dense slab bytes): ELL stays ∝ live edges.

Headline (asserted in ``__main__`` and by the run.py summary): per-event
ingest (seed + insert + relax) is >= 2x dense at the largest measured
anchor AND at N=100k, where dense additionally cannot be materialized at
all.

    PYTHONPATH=src python -m benchmarks.fig18_sparse_adjacency
"""
from __future__ import annotations

import time
from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.automaton import compile_query
from repro.core.backend import resolve_backend
from repro.core.engine import BatchedDenseRPQEngine, RegisteredQuery
from repro.core.semiring import (
    NEG_INF,
    batched_valid_pairs,
    frontier_seed,
    frontier_seed_gathered,
)
from repro.core.sparse_adj import ell_empty_np, ell_insert, ell_rows_dense
from repro.streaming.generators import gmark_like, with_deletions

from .common import emit

LABELS = ["a", "b", "c"]
L = len(LABELS)
Q, K, B, F, J = 1, 2, 8, 4, 2
ELL_CAP = 8
DENSE_BUDGET_BYTES = 64 << 30  # refuse to materialize dense above this


# -- leg 1: per-event identity ----------------------------------------------


def _identity_leg(n_vertices: int = 40, n_edges: int = 150,
                  n_slots: int = 64) -> Dict:
    specs = [RegisteredQuery(f"q{i}", compile_query(e), 12.0)
             for i, e in enumerate(["a . b*", "(a | b)*", "a . b* . c"])]
    events = list(with_deletions(
        gmark_like(n_vertices, n_edges, LABELS, seed=11, cyclicity=0.25),
        ratio=0.12, seed=12))

    def drive(layout):
        g = BatchedDenseRPQEngine(specs, n_slots=n_slots, batch_size=1,
                                  frontier="auto", frontier_cap=4,
                                  adj_layout=layout, ell_cap=2)
        out, next_exp = [], 4.0
        for sgt in events:
            if sgt.ts >= next_exp:
                g.expire(sgt.ts)
                while next_exp <= sgt.ts:
                    next_exp += 4.0
            if sgt.op == "+":
                res = g.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
            else:
                res = g.delete(sgt.src, sgt.dst, sgt.label, sgt.ts)
            out.append(tuple(frozenset(res[qi]) for qi in range(len(specs))))
        return out

    ev_d, ev_e = drive("dense"), drive("ell")
    assert len(ev_d) == len(ev_e)
    for i, (d, e) in enumerate(zip(ev_d, ev_e)):
        assert d == e, f"fig18 identity: event {i} dense != ell"
    return {"events": len(ev_d), "identical": True}


# -- leg 2: per-stage probes -------------------------------------------------


def _timeit(fn, reps: int) -> float:
    fn()  # warm the jit cache out of the timed loop
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _timeit_threaded(fn, state, reps: int) -> float:
    """Timed loop threading a donated buffer through fn (scatter probes:
    donation keeps the update in place, matching the engine's dispatch)."""
    state = fn(state)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        state = fn(state)
    jax.block_until_ready(state)
    return (time.perf_counter() - t0) / reps


def _sparse_dist(rng, n: int, n_live: int) -> jnp.ndarray:
    d = np.full((Q, n, n, K), NEG_INF, np.float32)
    xs = rng.integers(0, n, n_live)
    vs = rng.integers(0, n, n_live)
    ks = rng.integers(0, K, n_live)
    d[0, xs, vs, ks] = rng.integers(1, 100, n_live).astype(np.float32)
    return jnp.asarray(d)


def _sparse_window(rng, n: int, n_edges: int, dense_ok: bool):
    """Sparse gmark-shaped window with bounded out-degree, built as ELL rows
    directly — never touches (L, N, N) storage unless ``dense_ok``, which is
    the whole point at N_big. Returns (ell_np, dense_np | None, live_edges).
    """
    deg = ELL_CAP // 2
    n_rows = max(n_edges // deg, 1)
    labs = rng.integers(0, L, n_rows)
    us = rng.integers(0, n, n_rows)
    vs = rng.integers(0, n, (n_rows, deg)).astype(np.int32)
    ws = rng.integers(1, 100, (n_rows, deg)).astype(np.float32)

    ell = ell_empty_np(L, n, ELL_CAP, 256)
    # whole-row writes: duplicate (lab, u) rows resolve last-wins in both
    # representations identically
    ell.idx[labs, us, :deg] = vs
    ell.ts[labs, us, :deg] = ws
    dense = None
    if dense_ok:
        dense = np.full((L, n, n), NEG_INF, np.float32)
        keep = np.full((L, n), -1, np.int64)
        keep[labs, us] = np.arange(n_rows)       # the surviving row per slot
        rows = keep[keep >= 0]
        dense[labs[rows][:, None].repeat(deg, 1),
              us[rows][:, None].repeat(deg, 1), vs[rows]] = ws[rows]
    return ell, dense, int((ell.ts > NEG_INF).sum())


def _stage_probe(n: int, reps: int, rng) -> Dict[str, Dict[str, float]]:
    """Per-stage µs at vertex capacity ``n``; dense stages only run when the
    slab fits DENSE_BUDGET_BYTES (N_big exceeds it by construction)."""
    dense_bytes = L * n * n * 4
    dense_ok = dense_bytes <= DENSE_BUDGET_BYTES
    dist_ok = Q * n * n * K * 4 <= DENSE_BUDGET_BYTES  # dist is dense EITHER way
    out: Dict[str, Dict[str, float]] = {"dense": {}, "ell": {}}

    ell_np, adj_np, live_edges = _sparse_window(rng, n, 4 * n, dense_ok)
    ell = jax.tree_util.tree_map(jnp.asarray, ell_np)
    src = jnp.asarray(rng.integers(0, n, B), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, B), jnp.int32)
    lab = jnp.asarray(rng.integers(0, L, B), jnp.int32)
    ts = jnp.asarray(rng.integers(1, 100, B).astype(np.float32))
    smask = jnp.ones((B,), bool)
    backend = resolve_backend("jnp")

    # seed: O(Q·N²·K) scan vs O(Q·N·B·K) gather (needs the dense dist)
    if dist_ok:
        dist = _sparse_dist(rng, n, n_live=8 * n)
        seed_d = jax.jit(frontier_seed)
        seed_e = jax.jit(frontier_seed_gathered)
        if dense_ok:
            out["dense"]["seed"] = _timeit(
                lambda: jax.block_until_ready(seed_d(dist, src, smask)), reps)
        out["ell"]["seed"] = _timeit(
            lambda: jax.block_until_ready(seed_e(dist, src, smask)), reps)

        # emit: identical code both layouts (the dist wall this PR keeps)
        finals = jnp.zeros((Q, K), bool).at[:, K - 1].set(True)
        low = jnp.full((Q,), 1.0, jnp.float32)
        emit_fn = jax.jit(batched_valid_pairs)
        t_emit = _timeit(
            lambda: jax.block_until_ready(emit_fn(dist, finals, low)), reps)
        out["dense"]["emit"] = out["ell"]["emit"] = t_emit
        del dist

    # insert: donated scatter into the slab vs the ELL rows
    if dense_ok:
        adj_dev = jnp.asarray(adj_np)
        ins_d = jax.jit(
            lambda a: a.at[lab, src, dst].max(ts, mode="drop"),
            donate_argnums=(0,))
        out["dense"]["insert"] = _timeit_threaded(ins_d, adj_dev, reps)
        del adj_dev
    ins_e = jax.jit(
        lambda e: ell_insert(e, src, dst, lab, ts, smask),
        donate_argnums=(0,))
    # donation consumes the argument buffers — probe on a fresh copy so the
    # relax/footprint stages below keep the original ell alive
    out["ell"]["insert"] = _timeit_threaded(
        ins_e, jax.tree_util.tree_map(jnp.asarray, ell_np), reps)

    # relax: one frontier-restricted contraction + base-term gather
    labs = jnp.asarray(rng.integers(0, L, J), jnp.int32)
    rows = jnp.asarray(rng.integers(0, n, (J, F)), jnp.int32)
    d_s = jnp.asarray(np.where(
        np.asarray(rng.random((J, F, n)), np.float32) < 0.05,
        rng.integers(1, 100, (J, F, n)).astype(np.float32), NEG_INF))
    if dense_ok:
        adj_dev = jnp.asarray(adj_np)

        @jax.jit
        def relax_dense(d, adj, lbs, rws):
            a_l = adj[lbs]
            base = jnp.take_along_axis(
                a_l, rws[:, :, None], axis=1)            # (J, F, N)
            return backend.contract_rows(d, a_l), base

        out["dense"]["relax"] = _timeit(
            lambda: jax.block_until_ready(
                relax_dense(d_s, adj_dev, labs, rows)), reps)
        del adj_dev

    @jax.jit
    def relax_ell(d, e, lbs, rws):
        return (backend.contract_rows_ell(d, e, lbs),
                ell_rows_dense(e, lbs, rws, backend.zero))

    out["ell"]["relax"] = _timeit(
        lambda: jax.block_until_ready(relax_ell(d_s, ell, labs, rows)), reps)

    # adjacency footprint: measured ELL leaf bytes vs the analytic slab
    out["ell"]["adj_bytes"] = float(sum(
        int(np.prod(x.shape)) * x.dtype.itemsize for x in ell))
    out["dense"]["adj_bytes"] = float(dense_bytes)
    out["dense"]["feasible"] = float(dense_ok)
    out["ell"]["live_edges"] = float(live_edges)
    return out


def _per_event(stage: Dict[str, float]) -> float:
    """Composed per-event ingest cost: seed + insert + relax (emit excluded
    — identical code on both layouts)."""
    return sum(stage.get(k, 0.0) for k in ("seed", "insert", "relax"))


def _fit_n2(ns: Sequence[int], ts: Sequence[float]) -> float:
    """Least-squares coefficient c for t ≈ c·N² through the anchors."""
    ns2 = np.asarray(ns, np.float64) ** 2
    return float((ns2 * np.asarray(ts)).sum() / (ns2 * ns2).sum())


def _fit_n1(ns: Sequence[int], ts: Sequence[float]) -> float:
    ns1 = np.asarray(ns, np.float64)
    return float((ns1 * np.asarray(ts)).sum() / (ns1 * ns1).sum())


def run(anchors: Sequence[int] = (2048, 4096, 8192), n_big: int = 100_000,
        reps: int = 3, identity_edges: int = 150) -> Dict:
    rng = np.random.default_rng(0)
    out: Dict = {"ok": True, "devices": len(jax.devices()),
                 "params": {"Q": Q, "K": K, "B": B, "F": F, "J": J, "L": L,
                            "ell_cap": ELL_CAP, "anchors": list(anchors),
                            "n_big": n_big},
                 "identity": _identity_leg(n_edges=identity_edges),
                 "stages": {}}

    per_event: Dict[str, Dict[int, float]] = {"dense": {}, "ell": {}}
    for n in anchors:
        st = _stage_probe(n, reps, rng)
        out["stages"][n] = st
        for layout in ("dense", "ell"):
            per_event[layout][n] = _per_event(st[layout])
        for layout in ("dense", "ell"):
            for k, v in st[layout].items():
                if k in ("seed", "insert", "relax", "emit"):
                    emit(f"fig18/N={n}/{layout}/{k}", v * 1e6)

    # measured headline at the largest anchor
    n_top = max(anchors)
    ratio_meas = per_event["dense"][n_top] / per_event["ell"][n_top]

    # N_big: ELL adjacency-sized stages run for real; dense (and the dense
    # dist both layouts share) exceed the budget, so dense is extrapolated
    # with an N² fit and the ELL seed with a linear fit from the anchors
    st_big = _stage_probe(n_big, reps, rng)
    out["stages"][n_big] = st_big
    dense_big = _fit_n2(list(anchors),
                        [per_event["dense"][n] for n in anchors]) * n_big ** 2
    ell_big = (st_big["ell"]["insert"] + st_big["ell"]["relax"]
               + _fit_n1(list(anchors),
                         [out["stages"][n]["ell"]["seed"] for n in anchors])
               * n_big)
    ratio_big = dense_big / ell_big

    mem_big = st_big["ell"]["adj_bytes"]
    live_big = st_big["ell"]["live_edges"]
    out["headline"] = {
        "per_event_us_dense_top": per_event["dense"][n_top] * 1e6,
        "per_event_us_ell_top": per_event["ell"][n_top] * 1e6,
        "speedup_measured_top": ratio_meas,
        "n_big_dense_feasible": bool(st_big["dense"]["feasible"]),
        "per_event_us_dense_big_extrapolated": dense_big * 1e6,
        "per_event_us_ell_big": ell_big * 1e6,
        "speedup_big": ratio_big,
        "adj_bytes_ell_big": mem_big,
        "adj_bytes_dense_big_analytic": st_big["dense"]["adj_bytes"],
        "adj_bytes_per_live_edge_big": mem_big / max(live_big, 1.0),
    }
    emit(f"fig18/N={n_top}/speedup", ratio_meas)
    emit(f"fig18/N={n_big}/speedup_extrapolated", ratio_big)
    emit(f"fig18/N={n_big}/ell_adj_mb", mem_big / 2**20)
    return out


if __name__ == "__main__":
    r = run()
    h = r["headline"]
    n_top = max(r["params"]["anchors"])
    n_big = r["params"]["n_big"]
    print(f"[ok] fig18 identity: dense == ell per event "
          f"({r['identity']['events']} events)")
    print(f"[ok] fig18 N={n_top}: per-event ingest {h['speedup_measured_top']:.1f}x "
          f"dense (measured; {h['per_event_us_dense_top']:.0f}us -> "
          f"{h['per_event_us_ell_top']:.0f}us)")
    assert not h["n_big_dense_feasible"], (
        "dense slab unexpectedly fit at N_big — raise n_big")
    print(f"[ok] fig18 N={n_big}: dense slab infeasible "
          f"({h['adj_bytes_dense_big_analytic'] / 2**30:.0f} GiB); ELL runs in "
          f"{h['adj_bytes_ell_big'] / 2**20:.1f} MiB "
          f"({h['adj_bytes_per_live_edge_big']:.0f} B/live edge)")
    print(f"[ok] fig18 N={n_big}: {h['speedup_big']:.0f}x per-event ingest vs "
          f"dense (dense extrapolated N^2 from anchors)")
    assert h["speedup_measured_top"] >= 2.0, h["speedup_measured_top"]
    assert h["speedup_big"] >= 2.0, h["speedup_big"]
    print("[ok] fig18 >= 2x per-event ingest throughput over dense")
