"""Beyond-paper figure: contraction-backend shootout — the three
first-class :class:`~repro.core.backend.ContractionBackend` substrates
(jnp oracle, fused batched pallas VPU kernel, level-quantized mxu_bucket)
on the fig12 multi-query serving workload, through BOTH executors
(LocalExecutor and MeshExecutor).

Run with host-local virtual devices to exercise real lane sharding:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.fig15_backend_shootout

Asserted, not sampled, per Q in {8, 32}:
  * jnp and pallas per-event result streams are BIT-identical, on both
    executors (the (max, min) semiring has no reassociation error; the
    fused kernel contracts exactly what the oracle contracts);
  * mesh == local per event for EVERY backend (the bucket quantization is
    deterministic, so even the coarsened mode shards exactly);
  * the bucket mode never misses a jnp-reported pair (decoded levels
    round timestamps UP within one grid step), and at every event each
    extra VALID pair's true bottleneck sits within one level step
    (w / n_levels) of its query's expiry threshold — the stated
    level-coarsening bound. The extra-pair count and the observed worst
    boundary distance are reported.

On this CPU host the pallas backends run under ``interpret=True`` (the
Mosaic kernels need a TPU), so wall-clock columns here rank dispatch
structure, not kernel speed — the roofline (launch/dryrun_rpq.py
``batched-pallas`` / ``batched-mxu_bucket`` cells) prices the kernels on
the production mesh.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.core.automaton import compile_query
from repro.core.backend import BucketBackend, JnpBackend, PallasBackend
from repro.core.engine import BatchedDenseRPQEngine, RegisteredQuery
from repro.distributed.executor import MeshExecutor
from repro.streaming.generators import so_like

from .common import emit, so_queries

N_LEVELS = 8


def _specs(n_queries: int, window: float) -> List[RegisteredQuery]:
    exprs = list(so_queries().values())
    exprs = (exprs * ((n_queries + len(exprs) - 1) // len(exprs)))[:n_queries]
    return [RegisteredQuery(f"q{i}", compile_query(e), window)
            for i, e in enumerate(exprs)]


def _drive(group: BatchedDenseRPQEngine, stream, slide: float):
    next_exp = slide
    events: List[List] = []
    t0 = time.perf_counter()
    for sgt in stream:
        if sgt.ts >= next_exp:
            group.expire(sgt.ts)
            while next_exp <= sgt.ts:
                next_exp += slide
        events.append(group.insert(sgt.src, sgt.dst, sgt.label, sgt.ts))
    return time.perf_counter() - t0, events


def _backends():
    return [
        ("jnp", lambda: JnpBackend()),
        ("pallas", lambda: PallasBackend(interpret=None)),  # interp off-TPU
        ("mxu_bucket", lambda: BucketBackend(n_levels=N_LEVELS,
                                             use_pallas=False)),
    ]


def run(n_queries: int = 8, n_edges: int = 240, n_vertices: int = 18,
        n_slots: int = 24, window: float = 30.0, slide: float = 5.0) -> Dict:
    specs = _specs(n_queries, window)
    stream = so_like(n_vertices, n_edges, seed=21)
    step = window / N_LEVELS

    runs: Dict[str, Dict] = {}
    for bname, mk in _backends():
        for ename, mk_exec in (("local", lambda b: None),
                               ("mesh", lambda b: MeshExecutor(backend=b))):
            b = mk()
            group = BatchedDenseRPQEngine(
                specs, n_slots=n_slots, batch_size=1, backend=b,
                executor=mk_exec(b))
            # warm the jit cache out of the timed loop, then time a FRESH
            # engine reusing the same backend instance (backends hash by
            # config, so the warmed compile cache carries over; a fresh
            # instance would too, but identity makes it unmistakable)
            for sgt in list(stream)[:2]:
                group.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
                group.expire(sgt.ts)
            group = BatchedDenseRPQEngine(
                specs, n_slots=n_slots, batch_size=1, backend=b,
                executor=mk_exec(b))
            wall, events = _drive(group, stream, slide)
            runs[f"{bname}/{ename}"] = {
                "wall": wall, "events": events, "group": group}

    agg = n_queries * len(stream)
    base = runs["jnp/local"]["events"]

    # --- exact backends: bit-identical per event, both executors -----------
    for key in ("jnp/mesh", "pallas/local", "pallas/mesh"):
        ev = runs[key]["events"]
        assert len(ev) == len(base)
        for i, (fb, fe) in enumerate(zip(base, ev)):
            for qi in range(n_queries):
                assert fb[qi] == fe[qi], (
                    f"{key} event {i} lane {qi}: != jnp/local "
                    f"({fb[qi] ^ fe[qi]})")

    # --- bucket: mesh == local exactly; vs jnp the stated level bound ------
    for i, (fl, fm) in enumerate(zip(runs["mxu_bucket/local"]["events"],
                                     runs["mxu_bucket/mesh"]["events"])):
        for qi in range(n_queries):
            assert fl[qi] == fm[qi], f"bucket mesh != local at event {i}"

    ref = BatchedDenseRPQEngine(specs, n_slots=n_slots, batch_size=1)
    bkt = BatchedDenseRPQEngine(specs, n_slots=n_slots, batch_size=1,
                                backend=BucketBackend(n_levels=N_LEVELS,
                                                      use_pallas=False))
    finals = np.asarray(ref.finals_mask)
    extras_total, worst_boundary = 0, 0.0
    next_exp = slide
    for sgt in stream:
        if sgt.ts >= next_exp:
            ref.expire(sgt.ts)
            bkt.expire(sgt.ts)
            while next_exp <= sgt.ts:
                next_exp += slide
        fr = ref.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
        bkt.insert(sgt.src, sgt.dst, sgt.label, sgt.ts)
        a = ref.batched_arrays
        dist = np.asarray(a.dist)
        now = float(np.asarray(a.now))
        for qi in range(n_queries):
            assert fr[qi] <= bkt.per_query_results[qi], (
                "bucket missed a jnp-reported pair")
            extras = bkt.current_results(qi) - ref.current_results(qi)
            extras_total += len(extras)
            low = now - specs[qi].window
            best = np.where(finals[qi][None, None, :], dist[qi],
                            -np.inf).max(2)
            for (x, y) in extras:
                b = float(best[ref.slot_of[x], ref.slot_of[y]])
                assert low - step - 1e-4 <= b <= low + 1e-4, (
                    f"extra pair {x, y} outside the level bound: "
                    f"best={b} low={low} step={step}")
                worst_boundary = max(worst_boundary, low - b)

    n_shards = runs["jnp/mesh"]["group"].executor.n_shards
    for bname, _ in _backends():
        for ename in ("local", "mesh"):
            key = f"{bname}/{ename}"
            wall = runs[key]["wall"]
            tag = (f"shards={n_shards}" if ename == "mesh" else "d1")
            extra = ""
            if bname == "mxu_bucket":
                extra = (f" extras={extras_total}"
                         f" worst_boundary={worst_boundary:.3f}"
                         f" level_step={step:.3f}")
            emit(f"fig15/Q={n_queries}/{key}", wall / agg * 1e6,
                 f"agg_eps={agg / wall:.0f} {tag}{extra}")
    return {
        "ok": True,
        "devices": len(jax.devices()),
        "n_shards": n_shards,
        "agg_eps": {k: agg / v["wall"] for k, v in runs.items()},
        "bucket_extras": extras_total,
        "bucket_worst_boundary": worst_boundary,
        "level_step": step,
    }


if __name__ == "__main__":
    for q in (8, 32):
        out = run(n_queries=q, n_edges=240 if q == 8 else 160)
        print(f"[ok] fig15 Q={q}: devices={out['devices']} "
              f"shards={out['n_shards']}; jnp==pallas bit-identical on both "
              f"executors; bucket extras={out['bucket_extras']} all within "
              f"one level step ({out['level_step']:.3f}) of expiry "
              f"(worst {out['bucket_worst_boundary']:.3f})")
    print("[ok] backend shootout: all three backends through both executors")
