"""Roofline analysis: three terms per (arch x shape x mesh) from the
dry-run artifacts (benchmarks/results/dryrun/*.json).

    compute   = HLO_FLOPs / (chips x peak_FLOP/s)
    memory    = HLO_bytes / (chips x HBM_bw)
    collective= collective_bytes / (chips x link_bw)

HLO_FLOPs/HLO_bytes are the PER-DEVICE post-SPMD extrapolated values (see
dryrun.probe_period_costs; device_* values already per chip — do not divide
again). MODEL_FLOPS uses 6·N·D (train) / 2·N·D (decode/prefill) with
N = active params. Emits CSV and writes results/roofline.csv.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12     # bf16 MXU / chip (v5e)
VPU_PEAK = 3.9e12       # elementwise ops/s / chip (v5e VPU, 8x128 lanes)
HBM_BW = 819e9          # B/s / chip
LINK_BW = 50e9          # B/s / link (ICI)

RESULTS = os.path.join(os.path.dirname(__file__), "results")
DRYRUN = os.path.join(RESULTS, "dryrun")


def model_flops(rec: Dict) -> float:
    n_active = rec.get("params_active", 0)
    if rec.get("kind") == "rpq":
        # semiring ops on the VPU; report as the analytic term
        return rec.get("semiring_ops", 0.0)
    if rec["kind"] == "train":
        tokens = _tokens(rec)
        return 6.0 * n_active * tokens
    if rec["kind"] == "prefill":
        return 2.0 * n_active * _tokens(rec)
    # decode: one token per sequence
    return 2.0 * n_active * _batch(rec)


def _tokens(rec: Dict) -> float:
    shape = rec["shape"]
    seq = {"train_4k": 4096, "prefill_32k": 32768}.get(shape, 0)
    return seq * _batch(rec)


def _batch(rec: Dict) -> float:
    return {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
            "long_500k": 1}.get(rec["shape"], 1)


def analyze(rec: Dict) -> Dict:
    chips = rec["chips"]
    dev_flops = rec.get("device_flops_extrap", rec.get("device_flops", 0.0))
    dev_bytes = rec.get("device_bytes_extrap", rec.get("device_bytes", 0.0))
    wire = rec.get("collective_wire_bytes_extrap",
                   rec.get("collective_wire_bytes_rolled", 0.0))
    peak = PEAK_FLOPS
    if rec.get("kind") == "rpq":
        # HLO flop counts under-count fori bodies (counted once); use the
        # ANALYTIC semiring op count, on the unit each mode actually uses
        ops = rec.get("semiring_ops", 0.0)
        # n_levels > 0 marks every level-quantized lowering: the single-
        # query "mxu" cell AND the batched bucket-backend cells. Executed
        # dot count is level_dots (= T+1: BucketBackend's alloc includes
        # the origin-snap slack level); legacy artifacts fall back to T.
        if rec.get("n_levels", 0) > 0:
            dots = rec.get("level_dots", 0) or rec.get("n_levels", 1)
            dev_flops = ops * max(dots, 1) / chips
            peak = PEAK_FLOPS   # boolean matmuls on the MXU
        else:
            dev_flops = ops / chips
            peak = VPU_PEAK     # (max,min) has no MXU contraction
    t_compute = dev_flops / peak
    t_memory = dev_bytes / HBM_BW
    t_coll = wire / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = rec.get("global_flops_extrap", 0.0) or (dev_flops * chips)
    ratio = mf / hlo_global if hlo_global else 0.0
    if rec.get("kind") == "rpq":
        # useful = semiring ops / executed ops (mxu pays T x for MXU speed)
        dots = rec.get("level_dots", 0) or rec.get("n_levels", 1)
        ratio = (1.0 / max(dots, 1)
                 if rec.get("n_levels", 0) > 0 else 1.0)
    # roofline fraction: useful model flops per chip-second at the bound
    t_bound = max(terms.values())
    use_peak = PEAK_FLOPS
    if rec.get("kind") == "rpq" and rec.get("n_levels", 0) <= 0:
        use_peak = VPU_PEAK
    frac = min((mf / chips / use_peak) / t_bound, 1.0) if t_bound else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": ratio,
        "roofline_frac": frac,
        "fits_hbm": rec.get("fits_hbm"),
    }


def run() -> List[Dict]:
    rows = []
    if not os.path.isdir(DRYRUN):
        print("roofline/no_dryrun_artifacts,0.0,run repro.launch.dryrun first")
        return rows
    for fn in sorted(os.listdir(DRYRUN)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(DRYRUN, fn)) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            continue
        row = analyze(rec)
        rows.append(row)
        print(
            f"roofline/{row['arch']}/{row['shape']}/{row['mesh']},"
            f"{max(row['t_compute_s'], row['t_memory_s'], row['t_collective_s'])*1e6:.1f},"
            f"compute={row['t_compute_s']*1e3:.2f}ms memory={row['t_memory_s']*1e3:.2f}ms "
            f"coll={row['t_collective_s']*1e3:.2f}ms bottleneck={row['bottleneck']} "
            f"useful={row['useful_ratio']:.2f} frac={row['roofline_frac']:.2f}",
            flush=True,
        )
    os.makedirs(RESULTS, exist_ok=True)
    import csv

    with open(os.path.join(RESULTS, "roofline.csv"), "w", newline="") as f:
        if rows:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
    return rows


if __name__ == "__main__":
    run()
